"""Paper Figure 6: IVF search QPS vs recall — ADSampling on the horizontal
layout (vectorized masked Δd stepping, the charitable 'SIMD-ADS' analogue)
vs PDXearch (PDX-ADS), plus linear-scan IVF baselines (the FAISS/Milvus
stand-ins) — all sharing the same k-means buckets, as in the paper.
"""
from __future__ import annotations

import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.engine import SearchSpec, VectorSearchEngine
from repro.core.pruners import make_adsampling
from repro.data.synthetic import ground_truth, recall_at_k
from repro.index.kmeans import kmeans

from .common import dataset, emit

NPROBES = [2, 4, 8, 16]


class HorizontalIVF:
    """N-ary (row-major) IVF with optional ADSampling Δd-stepped pruning."""

    def __init__(self, X, nlist, centroids, assignments, pruner=None, delta_d=32):
        order = np.argsort(assignments, kind="stable")
        self.X = jnp.asarray(X[order])
        self.ids = jnp.asarray(order.astype(np.int32))
        counts = np.bincount(assignments, minlength=nlist)
        self.offsets = np.concatenate([[0], np.cumsum(counts)])
        self.centroids = jnp.asarray(centroids)
        self.pruner = pruner
        self.delta_d = delta_d
        self.dim = X.shape[1]

    def _probe_rows(self, q, nprobe):
        d = jnp.sum((self.centroids - q[None, :]) ** 2, axis=1)
        buckets = np.asarray(jnp.argsort(d))[:nprobe]
        rows = np.concatenate(
            [np.arange(self.offsets[b], self.offsets[b + 1]) for b in buckets]
        )
        cap = 1 << max(int(np.ceil(np.log2(max(len(rows), 1)))), 5)
        pad = np.full(cap - len(rows), -1, np.int64)
        return jnp.asarray(np.concatenate([rows, pad]))

    @functools.partial(jax.jit, static_argnames=("self", "k"))
    def _linear(self, rows, q, k):
        Xs = self.X[jnp.maximum(rows, 0)]
        d = jnp.sum((Xs - q[None, :]) ** 2, axis=1)
        d = jnp.where(rows < 0, jnp.inf, d)
        neg, idx = jax.lax.top_k(-d, k)
        return -neg, self.ids[jnp.maximum(rows, 0)[idx]]

    @functools.partial(jax.jit, static_argnames=("self", "k"))
    def _ads(self, rows, q, k, thr0):
        Xs = self.X[jnp.maximum(rows, 0)]
        valid = rows >= 0
        D, dd = self.dim, self.delta_d
        acc = jnp.zeros(Xs.shape[0])
        alive = valid
        d0 = 0
        while d0 < D:
            d1 = min(d0 + dd, D)
            diff = Xs[:, d0:d1] - q[d0:d1][None, :]
            acc = acc + jnp.sum(diff * diff, axis=1)
            alive = alive & self.pruner.keep_mask(acc, jnp.float32(d1), thr0)
            d0 = d1
        d = jnp.where(alive, acc, jnp.inf)
        neg, idx = jax.lax.top_k(-d, k)
        return -neg, self.ids[jnp.maximum(rows, 0)[idx]]

    def search(self, q, k, nprobe, mode="linear"):
        q = jnp.asarray(q)
        qt = self.pruner.transform_query(q) if self.pruner else q
        rows = self._probe_rows(qt, nprobe)
        if mode == "linear":
            return self._linear(rows, qt, k)
        # seed threshold: linear scan of the first bucket (as PDXearch START)
        d0, _ = self._linear(rows, qt, k)
        return self._ads(rows, qt, k, d0[-1])


def run(scale: str = "smoke"):
    n = 20000 if scale == "smoke" else 100000
    dim = 96 if scale == "smoke" else 768
    nq = 8 if scale == "smoke" else 32
    X, Q = dataset(n, dim, "clustered", n_queries=nq)
    k = 10
    gt_ids, _ = ground_truth(X, Q, k)
    nlist = int(np.sqrt(n))
    centroids, assignments = kmeans(X, nlist, iters=8)

    ads = make_adsampling(dim, eps0=2.1, seed=0)
    Xp = ads.preprocess(X)
    cen_p, asn_p = kmeans(Xp, nlist, iters=8)

    pdx_ads = VectorSearchEngine.build(
        X, index="ivf", pruner="adsampling", capacity=1024, nlist=nlist,
        precomputed_ivf=(cen_p, asn_p),
    )
    pdx_lin = VectorSearchEngine.build(
        X, index="ivf", pruner="linear", capacity=1024, nlist=nlist,
        precomputed_ivf=(centroids, assignments),
    )
    hor_lin = HorizontalIVF(X, nlist, centroids, assignments)
    hor_ads = HorizontalIVF(Xp, nlist, cen_p, asn_p, pruner=ads)

    def bench(name, fn):
        for nprobe in NPROBES:
            for q in Q[: min(4, len(Q))]:  # warm capacity-bucket variants
                fn(q, nprobe)
            t0 = time.perf_counter()
            found = [np.asarray(fn(q, nprobe)) for q in Q]
            dt = time.perf_counter() - t0
            rec = recall_at_k(np.stack([f[:k] for f in found]), gt_ids)
            emit(
                f"fig6/{name}/nprobe{nprobe}", dt / len(Q) * 1e6,
                f"qps={len(Q)/dt:.1f};recall={rec:.3f}",
            )

    bench("pdx-ads",
          lambda q, np_: pdx_ads.search(q, SearchSpec(k=k, nprobe=np_)).ids)
    bench("pdx-linear",
          lambda q, np_: pdx_lin.search(q, SearchSpec(k=k, nprobe=np_)).ids)
    bench("nary-linear(faiss-like)",
          lambda q, np_: np.asarray(hor_lin.search(q, k, np_, "linear")[1]))
    bench("nary-ads(simd-like)",
          lambda q, np_: np.asarray(hor_ads.search(q, k, np_, "ads")[1]))


if __name__ == "__main__":
    run()
