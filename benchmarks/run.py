"""Benchmark harness entry point — one module per paper table/figure.
Prints ``name,us_per_call,derived`` CSV rows (harness contract).

    PYTHONPATH=src python -m benchmarks.run              # smoke scale
    PYTHONPATH=src python -m benchmarks.run --scale paper
    PYTHONPATH=src python -m benchmarks.run --only fig9,table4
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback

MODULES = [
    ("table4", "benchmarks.bench_kernels"),
    ("cascade", "benchmarks.bench_cascade"),
    ("table5", "benchmarks.bench_blocksize"),
    ("fig6", "benchmarks.bench_ivf_ads"),
    ("fig7", "benchmarks.bench_adaptive"),
    ("fig8+table2_6", "benchmarks.bench_bond"),
    ("fig9", "benchmarks.bench_exact"),
    ("fig10", "benchmarks.bench_threshold"),
    ("table7", "benchmarks.bench_breakdown"),
    ("fig12", "benchmarks.bench_gather"),
    ("roofline", "benchmarks.roofline"),
    ("serve", "benchmarks.bench_serve"),
    ("tiered", "benchmarks.bench_tiered"),
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", default="smoke", choices=["smoke", "paper"])
    ap.add_argument("--only", default=None,
                    help="comma-separated artifact keys (e.g. fig9,table4)")
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None

    print("name,us_per_call,derived")
    failures = 0
    for key, modname in MODULES:
        if only and not any(o in key for o in only):
            continue
        t0 = time.time()
        print(f"# === {key} ({modname}) ===", flush=True)
        try:
            mod = __import__(modname, fromlist=["run"])
            mod.run(scale=args.scale)
        except Exception:  # noqa: BLE001
            failures += 1
            print(f"# {key} FAILED:\n{traceback.format_exc()}", file=sys.stderr)
        print(f"# === {key} done in {time.time()-t0:.1f}s ===", flush=True)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
