"""Batched distributed search: per-query vs per-batch top-k collectives on
an 8-fake-CPU-device ``data`` mesh — the ROADMAP "batched distributed
search" item.  Both paths go through the one public entry point; the spec's
``batch_collectives`` hint flips the planner between the per-query
block-sharded executor (2 all-gathers per query) and the fused
batch-block-sharded executor (1 packed all-gather per batch).  Emits CSV
rows plus a ``BENCH_batch_dist.json`` record with queries/sec for both.

Standalone only (NOT in run.py's MODULES): the XLA device-count flag is
process-global and must be set before jax initializes.

    PYTHONPATH=src python -m benchmarks.bench_batch_dist [--scale paper]
"""
from __future__ import annotations

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import argparse

import jax
import numpy as np

from repro.core.engine import SearchSpec, VectorSearchEngine
from repro.data.synthetic import ground_truth, recall_at_k
from repro.dist.pdx_sharded import (
    collective_counts,
    search_batch_block_sharded,
    search_block_sharded,
)

from .common import dataset, emit, timeit, write_json


def run(scale: str = "smoke"):
    n, dim, cap, nq = (
        (16384, 64, 128, 16) if scale == "smoke" else (131072, 128, 512, 64)
    )
    k = 10
    X, Q = dataset(n, dim, "normal", n_queries=nq, seed=0)
    n_dev = jax.device_count()
    parts = max(n // cap // n_dev, 1) * n_dev
    X = X[: parts * cap]
    gt_ids, gt_d = ground_truth(X, Q, k=k)

    mesh = jax.make_mesh((n_dev,), ("data",))
    eng = VectorSearchEngine.build(X, pruner="linear", capacity=cap, mesh=mesh)
    spec_batch = SearchSpec(k=k)                          # fused collective
    spec_query = SearchSpec(k=k, batch_collectives=False)  # per-query loop

    # correctness + dispatch gate before timing
    res_b = eng.search(Q, spec_batch)
    res_q = eng.search(Q, spec_query)
    assert res_b.plan.executor == "batch-block-sharded", res_b.plan
    assert res_q.plan.executor == "block-sharded", res_q.plan
    assert recall_at_k(res_b.ids, gt_ids) == 1.0
    assert recall_at_k(res_q.ids, gt_ids) == 1.0

    # collective counts from the traced jaxprs (independent of B for fused)
    data, ids, Qj = eng.store.data, eng.store.ids, jax.numpy.asarray(Q)
    n_batched = collective_counts(
        lambda d, i, q: search_batch_block_sharded(mesh, d, i, q, k),
        data, ids, Qj,
    ).get("all_gather", 0)
    n_per_query = len(Q) * collective_counts(
        lambda d, i, q: search_block_sharded(mesh, d, i, q, k),
        data, ids, Qj[0],
    ).get("all_gather", 0)

    t_batch = timeit(lambda: eng.search(Q, spec_batch))
    t_query = timeit(lambda: eng.search(Q, spec_query))
    qps_batch = len(Q) / t_batch
    qps_query = len(Q) / t_query
    emit(
        f"batch_dist/fused/n{parts*cap}/D{dim}/B{len(Q)}/dev{n_dev}",
        t_batch / len(Q) * 1e6,
        f"qps={qps_batch:.1f};all_gathers={n_batched}",
    )
    emit(
        f"batch_dist/per_query/n{parts*cap}/D{dim}/B{len(Q)}/dev{n_dev}",
        t_query / len(Q) * 1e6,
        f"qps={qps_query:.1f};all_gathers={n_per_query};"
        f"fused_speedup={t_query/t_batch:.2f}",
    )
    write_json(
        "BENCH_batch_dist.json",
        {
            "bench": "batch_dist_per_batch_vs_per_query_collectives",
            "scale": scale,
            "n_vectors": parts * cap,
            "dim": dim,
            "capacity": cap,
            "k": k,
            "batch": len(Q),
            "n_devices": n_dev,
            "all_gathers_per_batch_fused": n_batched,
            "all_gathers_per_batch_per_query": n_per_query,
            "t_fused_us_per_query": t_batch / len(Q) * 1e6,
            "t_per_query_us_per_query": t_query / len(Q) * 1e6,
            "queries_per_s_fused": qps_batch,
            "queries_per_s_per_query": qps_query,
            "fused_speedup": t_query / t_batch,
        },
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", default="smoke", choices=["smoke", "paper"])
    args = ap.parse_args()
    print("name,us_per_call,derived")
    run(scale=args.scale)


if __name__ == "__main__":
    main()
