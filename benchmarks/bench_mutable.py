"""Mutable PDX store under churn: insert/delete/repack throughput and
search latency with a live write-head vs the sealed-store baseline —
the ISSUE-3 acceptance gate is that batched search latency under churn
stays within 2x of the sealed store.  Emits CSV rows plus a
``BENCH_mutable.json`` record.

    PYTHONPATH=src python -m benchmarks.bench_mutable [--scale paper]
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from repro.core.engine import SearchSpec, VectorSearchEngine

from .common import dataset, emit, timeit, write_json


def run(scale: str = "smoke"):
    n, dim, cap, nq = (
        (8192, 64, 256, 16) if scale == "smoke" else (131072, 128, 1024, 64)
    )
    k, churn = 10, max(n // 16, 256)
    X, Q = dataset(n, dim, "normal", n_queries=nq, seed=0)
    rng = np.random.default_rng(1)
    spec = SearchSpec(k=k)

    # ---- sealed baseline: batched exact scan on the frozen store ----------
    sealed = VectorSearchEngine.build(X, pruner="linear", capacity=cap)
    t_sealed = timeit(lambda: sealed.search(Q, spec))
    emit(
        f"mutable/sealed_search/n{n}/D{dim}/B{nq}",
        t_sealed / nq * 1e6,
        f"qps={nq / t_sealed:.1f}",
    )

    # ---- mutation throughput ---------------------------------------------
    eng = VectorSearchEngine.build(X, pruner="linear", capacity=cap)
    new = rng.standard_normal((churn, dim)).astype(np.float32)

    t0 = time.perf_counter()
    ids = eng.insert(new)
    t_insert = time.perf_counter() - t0
    emit(
        f"mutable/insert/n{n}/rows{churn}",
        t_insert / churn * 1e6,
        f"rows_per_s={churn / t_insert:.0f}",
    )

    victims = rng.choice(n, size=churn, replace=False)
    t0 = time.perf_counter()
    eng.delete(victims)
    t_delete = time.perf_counter() - t0
    emit(
        f"mutable/delete/n{n}/rows{churn}",
        t_delete / churn * 1e6,
        f"rows_per_s={churn / t_delete:.0f}",
    )

    # ---- search latency under churn (write-head live + tombstones) -------
    assert eng.store.head_count > 0, "churn config must leave a live head"
    eng.search(Q, spec)  # compile against the churned version
    t_churn = timeit(lambda: eng.search(Q, spec))
    ratio = t_churn / t_sealed
    emit(
        f"mutable/churned_search/n{n}/D{dim}/B{nq}",
        t_churn / nq * 1e6,
        f"qps={nq / t_churn:.1f};vs_sealed={ratio:.2f}x"
        f";head={eng.store.head_count}",
    )
    if ratio > 2.0:
        print(f"# WARNING churned search {ratio:.2f}x sealed (budget: 2x)")

    # ---- repack + post-compact latency -----------------------------------
    t0 = time.perf_counter()
    eng.compact()
    t_repack = time.perf_counter() - t0
    emit(
        f"mutable/repack/n{eng.store.num_vectors}",
        t_repack * 1e6,
        f"rows_per_s={eng.store.num_vectors / t_repack:.0f}",
    )
    eng.search(Q, spec)
    t_compacted = timeit(lambda: eng.search(Q, spec))
    emit(
        f"mutable/compacted_search/n{eng.store.num_vectors}/D{dim}/B{nq}",
        t_compacted / nq * 1e6,
        f"qps={nq / t_compacted:.1f};vs_sealed={t_compacted / t_sealed:.2f}x",
    )

    write_json(
        "BENCH_mutable.json",
        {
            "bench": "mutable_store_churn_vs_sealed",
            "scale": scale,
            "n_vectors": n,
            "dim": dim,
            "capacity": cap,
            "k": k,
            "batch": nq,
            "churn_rows": int(churn),
            "insert_rows_per_s": churn / t_insert,
            "delete_rows_per_s": churn / t_delete,
            "repack_s": t_repack,
            "t_sealed_us_per_query": t_sealed / nq * 1e6,
            "t_churned_us_per_query": t_churn / nq * 1e6,
            "t_compacted_us_per_query": t_compacted / nq * 1e6,
            "churned_over_sealed": ratio,
            "compacted_over_sealed": t_compacted / t_sealed,
            "within_2x_budget": bool(ratio <= 2.0),
        },
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", default="smoke", choices=["smoke", "paper"])
    args = ap.parse_args()
    print("name,us_per_call,derived")
    run(scale=args.scale)


if __name__ == "__main__":
    main()
