"""Paper Figure 12 / Section 7: on-the-fly gather-transposition of N-ary
storage into PDX form vs stored PDX vs direct N-ary — demonstrating that PDX
must be the *storage* layout, not a runtime view.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.distance import nary_distance, pdx_distance
from .common import emit, timeit


@jax.jit
def _nary_gather_pdx(X, q):
    """Transpose 64-vector blocks on the fly, then run the PDX kernel
    (paper's N-ary+Gather): the transposition cost is on the query path."""
    n, d = X.shape
    tiles = X.reshape(n // 64, 64, d).transpose(0, 2, 1)  # the gather
    def body(_, tile):
        diff = tile - q[:, None]
        return None, jnp.sum(diff * diff, axis=0)
    _, out = jax.lax.scan(body, None, tiles)
    return out.reshape(-1)


def run(scale: str = "smoke"):
    n = 16384 if scale == "smoke" else 131072
    rng = np.random.default_rng(2)
    for d in (64, 256, 1024):
        X = rng.standard_normal((n, d)).astype(np.float32)
        q = rng.standard_normal(d).astype(np.float32)
        Xj, Tj, qj = jnp.asarray(X), jnp.asarray(X.T), jnp.asarray(q)
        t_gather = timeit(_nary_gather_pdx, Xj, qj)
        t_pdx = timeit(pdx_distance, Tj, qj, "l2")
        t_nary = timeit(nary_distance, Xj, qj, "l2")
        emit(
            f"fig12/D{d}/nary+gather", t_gather * 1e6,
            f"stored_pdx_us={t_pdx*1e6:.1f};nary_us={t_nary*1e6:.1f};"
            f"gather_slowdown_vs_pdx={t_gather/t_pdx:.2f}",
        )


if __name__ == "__main__":
    run()
