"""Paper Figure 9: exact-search QPS — PDX-BOND, PDX linear scan, N-ary
linear scan (sklearn/FAISS-flat stand-in), DSM (fully decomposed) linear
scan, and the beyond-paper batched MXU-form scan.
"""
from __future__ import annotations

import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.engine import SearchSpec, VectorSearchEngine
from repro.data.synthetic import ground_truth, recall_at_k
from .common import dataset, emit


@functools.partial(jax.jit, static_argnames=("k",))
def _nary_scan(X, q, k):
    d = jnp.sum((X - q[None, :]) ** 2, axis=1)
    neg, idx = jax.lax.top_k(-d, k)
    return -neg, idx


@functools.partial(jax.jit, static_argnames=("k",))
def _dsm_scan(XT, q, k):
    """Fully decomposed layout: one (D, N) array, dimension-at-a-time with a
    full-length accumulator (extra load/stores vs PDX's blocked tiles)."""
    def body(acc, inp):
        row, qd = inp
        return acc + (row - qd) ** 2, None

    acc, _ = jax.lax.scan(body, jnp.zeros(XT.shape[1]), (XT, q))
    neg, idx = jax.lax.top_k(-acc, k)
    return -neg, idx


def run(scale: str = "smoke"):
    n = 20000 if scale == "smoke" else 100000
    dim = 128 if scale == "smoke" else 768
    nq = 8 if scale == "smoke" else 32
    k = 10
    X, Q = dataset(n, dim, "skewed", n_queries=nq, seed=7)
    gt_ids, _ = ground_truth(X, Q, k)

    # paper setting: 10K-vector partitions for exact PDX-BOND
    spec = SearchSpec(k=k)
    bond = VectorSearchEngine.build(X, pruner="bond", capacity=4096)
    lin = VectorSearchEngine.build(X, pruner="linear", capacity=4096)
    Xj = jnp.asarray(X)
    XTj = jnp.asarray(np.ascontiguousarray(X.T))

    def bench(name, fn):
        for q in Q[: min(4, len(Q))]:  # warm all capacity-bucket jit variants
            fn(q)
        t0 = time.perf_counter()
        found = [np.asarray(fn(q)) for q in Q]
        dt = time.perf_counter() - t0
        rec = recall_at_k(np.stack([f[:k] for f in found]), gt_ids)
        emit(f"fig9/{name}", dt / len(Q) * 1e6,
             f"qps={len(Q)/dt:.1f};recall={rec:.3f}")

    bench("pdx-bond", lambda q: bond.search(q, spec).ids)
    bench("pdx-linear", lambda q: lin.search(q, spec).ids)
    bench("nary-linear", lambda q: _nary_scan(Xj, jnp.asarray(q), k)[1])
    bench("dsm-linear", lambda q: _dsm_scan(XTj, jnp.asarray(q), k)[1])

    # beyond-paper: batched MXU-form exact scan, amortized per query — the
    # same entry point; a (B, D) batch makes the planner pick the MXU scan.
    lin.search(Q, spec)  # warmup
    t0 = time.perf_counter()
    res = lin.search(Q, spec)
    dt = time.perf_counter() - t0
    assert res.plan.executor == "batch-matmul", res.plan
    rec = recall_at_k(res.ids, gt_ids)
    emit("fig9/pdx-batched-matmul", dt / len(Q) * 1e6,
         f"qps={len(Q)/dt:.1f};recall={rec:.3f}")


if __name__ == "__main__":
    run()
