"""Paper Table 5: PDX block-size sweep.  Times the L2 scan as a lax.scan
over (N/V, D, V) partitions for V in {16..1024} against the N-ary kernel.
On TPU the analogous knob is the lane-tile width (kernels/ops.py v_tile);
on CPU the sweet spot reflects register/cache pressure as in the paper.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.distance import nary_distance

from .common import emit, timeit

BLOCKS = [16, 32, 64, 128, 256, 512, 1024]


@functools.partial(jax.jit, static_argnames=())
def _pdx_blocked(tiles: jax.Array, q: jax.Array) -> jax.Array:
    def body(_, tile):
        diff = tile - q[:, None]
        return None, jnp.sum(diff * diff, axis=0)

    _, out = jax.lax.scan(body, None, tiles)
    return out.reshape(-1)


def run(scale: str = "smoke"):
    n = 16384 if scale == "smoke" else 131072
    d = 128 if scale == "smoke" else 768
    rng = np.random.default_rng(1)
    X = rng.standard_normal((n, d)).astype(np.float32)
    q = jnp.asarray(rng.standard_normal(d).astype(np.float32))
    t_nary = timeit(nary_distance, jnp.asarray(X), q, "l2")
    for V in BLOCKS:
        tiles = jnp.asarray(
            X.reshape(n // V, V, d).transpose(0, 2, 1)
        )  # (P, D, V)
        t = timeit(_pdx_blocked, tiles, q)
        emit(
            f"table5/block{V}", t * 1e6,
            f"nary_us={t_nary*1e6:.2f};speedup={t_nary/t:.2f}",
        )


if __name__ == "__main__":
    run()
