"""Paper Figure 7: adaptive (exponential) steps vs fixed Δd=32 on PDX-ADS.
Per-query runtime ratios; reports the fraction of queries improved and the
distribution tails, matching the paper's presentation.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core.engine import SearchSpec, VectorSearchEngine
from .common import dataset, emit


def run(scale: str = "smoke"):
    n = 20000 if scale == "smoke" else 100000
    dim = 256 if scale == "smoke" else 960  # GIST-like when full
    nq = 12 if scale == "smoke" else 50
    X, Q = dataset(n, dim, "skewed", n_queries=nq, seed=5)

    # One engine, two specs — the boundary schedule is a per-query choice.
    eng = VectorSearchEngine.build(X, pruner="adsampling", capacity=1024)
    spec_a = SearchSpec(k=10, schedule="adaptive")
    spec_f = SearchSpec(k=10, schedule="fixed", delta_d=32)
    eng.search(Q[0], spec_a)
    eng.search(Q[0], spec_f)

    ratios = []
    for q in Q:
        t0 = time.perf_counter(); eng.search(q, spec_f); tf = time.perf_counter() - t0
        t0 = time.perf_counter(); eng.search(q, spec_a); ta = time.perf_counter() - t0
        ratios.append(tf / ta)
    ratios = np.array(ratios)
    emit(
        "fig7/adaptive_vs_fixed", float(np.mean(ratios)) * 100,
        f"frac_improved={float((ratios > 1.0).mean()):.2f};"
        f"frac_1.5x={float((ratios > 1.5).mean()):.2f};"
        f"p50_ratio={float(np.median(ratios)):.2f};"
        f"worst={float(ratios.min()):.2f}",
    )


if __name__ == "__main__":
    run()
