"""Paper Figure 10: PRUNE-phase selectivity threshold sweep — speedup of
PDX-ADS over the PDX linear scan as a function of sel_frac.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core.engine import SearchSpec, VectorSearchEngine
from .common import dataset, emit

FRACS = [0.02, 0.05, 0.1, 0.2, 0.4, 0.6]


def run(scale: str = "smoke"):
    n = 20000 if scale == "smoke" else 100000
    dim = 128 if scale == "smoke" else 768
    nq = 8 if scale == "smoke" else 24
    X, Q = dataset(n, dim, "skewed", n_queries=nq, seed=9)

    lin = VectorSearchEngine.build(X, pruner="linear", capacity=1024)
    lin.search(Q[0], SearchSpec(k=10))
    t0 = time.perf_counter()
    for q in Q:
        lin.search(q, SearchSpec(k=10))
    t_lin = (time.perf_counter() - t0) / len(Q)

    # One preprocessed engine; sel_frac is a per-query SearchSpec knob.
    eng = VectorSearchEngine.build(X, pruner="adsampling", capacity=1024)
    for frac in FRACS:
        spec = SearchSpec(k=10, sel_frac=frac)
        for q in Q[: min(4, len(Q))]:  # warm capacity-bucket jit variants
            eng.search(q, spec)
        t0 = time.perf_counter()
        for q in Q:
            eng.search(q, spec)
        t = (time.perf_counter() - t0) / len(Q)
        emit(f"fig10/selfrac{frac}", t * 1e6,
             f"speedup_vs_linear={t_lin/t:.2f}")


if __name__ == "__main__":
    run()
