"""Tiered bucket-cache serving gate -> BENCH_tiered.json.

Serves an IVF store whose quantized mirror is >= 4x the configured HBM
bucket-cache capacity (``SearchSpec.hbm_slots``): host-RAM f32 masters stay
authoritative, the device pool holds only the routed working set, and
routing prefetches bucket extents ahead of each scan chunk.  A skewed
(zipf-over-clusters) workload models serving traffic with a hot set.

Also gates the two-level centroid routing tree: at the seed nlist the
descent ranks ``SK + nprobe_super * M`` centroids per query — sub-linear in
nlist — while selecting (near-)identical buckets to the flat scan.

Acceptance (asserted in-process):
  * store tiles >= 4x cache capacity (the beyond-HBM premise),
  * tiered recall@k == fully-resident recall@k (exact host re-rank),
  * warm-cache tiered p50 <= 1.5x the fully-resident p50,
  * warm prefetch hit rate >= 0.8 on the skewed workload,
  * tree routing_cost() < nlist with bucket-selection overlap >= 0.9,
  * cold-miss p50 with async host-staged uploads <= 0.7x the legacy
    synchronous f32-upload path at identical miss counts and ids.
"""
from __future__ import annotations

import numpy as np

from repro.core.engine import SearchSpec, VectorSearchEngine
from repro.data.synthetic import recall_at_k
from repro.obs import metrics

from .common import emit, timeit, write_json


def _async_upload_section(scale: str) -> dict:
    """Cold-miss latency, async host-staged uploads vs the legacy
    synchronous path (f32 over the bus, device-side quantize, hard block
    at issue — ``BucketCache.sync_uploads``).  Two query batches whose
    combined routed demand overflows the slot pool thrash each other out,
    so every timed search re-uploads most of its working set; the same
    pair runs in both modes and the registry confirms identical miss
    counts and ids.

    Runs on its own wide-dim engine (256-dim x 128-capacity: 128 KiB f32
    tiles vs the seed serving config's 16 KiB) so upload traffic is a
    first-order cost of a cold miss, the regime the async path targets.

    Acceptance: async cold-miss p50 <= 0.7x synchronous.  The async win is
    overlap — staging runs on a worker thread while the query thread
    drives the scan — which needs a second core to exist: on single-core
    runners (no concurrency is physically possible, total work is all
    that counts) the gate degrades to cost parity, async <= 1.05x sync."""
    import os

    from repro.core.plan import _get_bucket_cache

    n, dim, cap, nlist, nprobe, batch = (
        (16384, 256, 128, 64, 8, 8) if scale == "smoke"
        else (65536, 256, 128, 256, 8, 8)
    )
    X, _ = _clustered(n, dim, nlist, 1, seed=2)
    eng = VectorSearchEngine.build(
        X, index="ivf", nlist=nlist, capacity=cap, pruner="linear",
    )
    P = eng.store.data.shape[0]
    slots = P // 4
    tiered = SearchSpec(k=10, nprobe=nprobe, scan_dtype="int8",
                        hbm_slots=slots)
    rng = np.random.default_rng(3)
    Q = (X[rng.choice(n, 256, replace=False)]
         + rng.standard_normal((256, dim)).astype(np.float32) * 0.1)
    sel = np.asarray(eng.ivf.route_batch(Q, nprobe))
    cnts = np.asarray(eng.ivf.part_counts)

    def take(exclude, avoid):
        """Greedy batch of per-pass-feasible queries biased away from
        ``avoid``: each query's OWN demand must fit the pool (it becomes
        its own ensure+scan round), while the batch union deliberately
        overflows it — so the executor pipelines one upload per round and
        every round is a cold-miss scan.  Hot attractor buckets land in
        every routed set, so full disjointness is not achievable —
        mostly-fresh is enough to force evictions."""
        picked, dem = [], set()
        for qi in range(len(Q)):
            if qi in exclude:
                continue
            bs = {int(b) for b in sel[qi] if b >= 0}
            if int(sum(cnts[list(bs)])) > int(slots * 0.85):
                continue
            if len(bs - dem - avoid) < max(nprobe // 4, 2):
                continue  # demand too warm: not enough fresh buckets
            picked.append(qi)
            dem |= bs
            if len(picked) == batch // 2:
                break
        return picked, dem

    pA, demA = take(set(), set())
    pB, demB = take(set(pA), demA)
    bA = np.ascontiguousarray(Q[pA])
    bB = np.ascontiguousarray(Q[pB])
    union_tiles = int(sum(cnts[list(demA | demB)]))
    # the pair's union overflows the pool: LRU evicts the other batch's
    # tiles on every alternation, so each timed search is a cold-miss scan
    assert len(pA) and len(pB) and union_tiles > slots, (
        len(pA), len(pB), union_tiles, slots)

    bc = _get_bucket_cache(eng.store, tiered, ivf=eng.ivf)
    reg = metrics.get_registry()
    was = metrics.enabled()

    def cold_pair():
        ia = np.asarray(eng.search(bA, tiered).ids)
        ib = np.asarray(eng.search(bB, tiered).ids)
        return ia, ib

    out = {}
    metrics.set_enabled(True)
    try:
        for mode in ("sync", "async"):
            bc.sync_uploads = mode == "sync"
            cold_pair()  # compile + settle the thrash pattern
            m0 = reg.sum("repro_tiered_cache_events_total", event="miss")
            ids = cold_pair()
            m1 = reg.sum("repro_tiered_cache_events_total", event="miss")
            t = timeit(cold_pair, reps=5, warmup=1)
            out[mode] = {
                "p50_us": t / (len(bA) + len(bB)) * 1e6,
                "misses_per_pair": m1 - m0,
                "ids": ids,
            }
    finally:
        bc.sync_uploads = False
        metrics.set_enabled(was)
    a, s = out["async"], out["sync"]
    assert a["misses_per_pair"] == s["misses_per_pair"] > 0, (
        a["misses_per_pair"], s["misses_per_pair"])
    for x, y in zip(a.pop("ids"), s.pop("ids")):
        assert np.array_equal(x, y), "upload mode changed the result set"
    ratio = a["p50_us"] / s["p50_us"]
    cores = os.cpu_count() or 1
    gate = 0.7 if cores > 1 else 1.05
    section = {
        "config": {"n": n, "dim": dim, "capacity": cap, "nlist": nlist,
                   "partitions": P, "hbm_slots": slots, "nprobe": nprobe,
                   "cpu_count": cores},
        "batch_pair": [len(bA), len(bB)],
        "demand_tiles": [int(sum(cnts[list(demA)])),
                         int(sum(cnts[list(demB)])), union_tiles],
        "cold_p50_us": {"async": a["p50_us"], "sync": s["p50_us"]},
        "cold_misses_per_pair": a["misses_per_pair"],
        "cold_p50_ratio_async_vs_sync": ratio,
        "gate": gate,
    }
    emit(
        f"tiered-async/slots{slots}-miss{a['misses_per_pair']:.0f}",
        a["p50_us"],
        f"sync_p50={s['p50_us']:.0f}us;ratio={ratio:.2f};"
        f"gate={gate};cores={cores}",
    )
    assert ratio <= gate, section
    return section


def _clustered(n, dim, k_clusters, n_queries, seed=0, zipf_a=3.0):
    """Clustered dataset + a zipf-skewed query stream over the clusters."""
    rng = np.random.default_rng(seed)
    cents = rng.standard_normal((k_clusters, dim)).astype(np.float32) * 4
    X = (cents[rng.integers(0, k_clusters, n)]
         + rng.standard_normal((n, dim)).astype(np.float32))
    ranks = rng.zipf(zipf_a, size=n_queries)
    hot = rng.permutation(k_clusters)[np.minimum(ranks - 1, k_clusters - 1)]
    Q = cents[hot] + rng.standard_normal((n_queries, dim)).astype(np.float32)
    return X.astype(np.float32), Q.astype(np.float32)


def run(scale: str = "smoke"):
    n, dim, cap, nlist, nq, k = (
        (16384, 64, 64, 256, 64, 10) if scale == "smoke"
        else (131072, 128, 64, 1024, 256, 10)
    )
    X, Q = _clustered(n, dim, nlist, nq, seed=0)
    eng = VectorSearchEngine.build(
        X, index="ivf", nlist=nlist, capacity=cap, pruner="linear",
        tree=True, super_k=max(8, int(np.sqrt(nlist))), nprobe_super=4,
    )
    P = eng.store.data.shape[0]
    nprobe = 8
    slots = P // 4  # the quantized mirror is 4x the cache capacity
    demand_floor = int(np.sort(np.asarray(eng.ivf.part_counts))[-nprobe:].sum())
    assert slots >= demand_floor, (slots, demand_floor)
    batch = 16

    tiered = SearchSpec(k=k, nprobe=nprobe, scan_dtype="int8",
                        hbm_slots=slots)
    resident = tiered.replace(hbm_slots=P)  # whole mirror fits: no evictions
    batches = [Q[i : i + batch] for i in range(0, len(Q), batch)]

    # ---- recall parity: tiered vs fully-resident vs non-tiered routed
    ids_t = np.concatenate([np.asarray(eng.search(b, tiered).ids)
                            for b in batches])
    ids_r = np.concatenate([np.asarray(eng.search(b, resident).ids)
                            for b in batches])
    ids_ref = np.concatenate([np.asarray(eng.search(
        b, SearchSpec(k=k, nprobe=nprobe)).ids) for b in batches])
    rec_t = recall_at_k(ids_t, ids_ref)
    rec_r = recall_at_k(ids_r, ids_ref)

    # ---- warm prefetch hit rate on the skewed stream
    reg = metrics.get_registry()
    was = metrics.enabled()
    metrics.set_enabled(True)
    try:
        for b in batches:           # warm pass populates the hot set
            eng.search(b, tiered)
        h0 = reg.sum("repro_tiered_cache_events_total", event="hit")
        m0 = reg.sum("repro_tiered_cache_events_total", event="miss")
        pb0 = reg.sum("repro_tiered_prefetch_bytes_total")
        for b in batches:           # measured warm pass
            eng.search(b, tiered)
        h1 = reg.sum("repro_tiered_cache_events_total", event="hit")
        m1 = reg.sum("repro_tiered_cache_events_total", event="miss")
        pb1 = reg.sum("repro_tiered_prefetch_bytes_total")
    finally:
        metrics.set_enabled(was)
    hits, misses = h1 - h0, m1 - m0
    hit_rate = hits / max(hits + misses, 1)
    prefetch_bytes = (pb1 - pb0) / max(len(batches), 1)

    # ---- warm p50: tiered (cache steady) vs fully-resident
    hot_b = batches[0]
    t_tier = timeit(lambda: eng.search(hot_b, tiered), reps=5, warmup=2)
    t_res = timeit(lambda: eng.search(hot_b, resident), reps=5, warmup=2)
    p50_ratio = t_tier / t_res

    # ---- two-level routing tree: sub-linear cost, bucket parity
    ivf = eng.ivf
    SK, M = ivf.super_children.shape
    cost = ivf.routing_cost()
    flat_eng = VectorSearchEngine.build(
        X, index="ivf", nlist=nlist, capacity=cap, pruner="linear",
        tree=False,
    )
    sel_tree = np.asarray(ivf.route_batch(Q[:batch], nprobe))
    sel_flat = np.asarray(flat_eng.ivf.route_batch(Q[:batch], nprobe))
    bucket_overlap = np.mean([
        len(set(a.tolist()) & set(b.tolist())) / nprobe
        for a, b in zip(sel_tree, sel_flat)
    ])

    record = {
        "scale": scale,
        "config": {
            "n": n, "dim": dim, "capacity": cap, "nlist": nlist,
            "partitions": P, "hbm_slots": slots,
            "mirror_over_cache": P / slots, "nprobe": nprobe,
            "scan_dtype": "int8", "batch": batch, "n_queries": nq,
        },
        "recall_at_k": {"tiered": rec_t, "fully_resident": rec_r},
        "warm_hit_rate": hit_rate,
        "prefetch_bytes_per_batch": prefetch_bytes,
        "p50_us": {"tiered": t_tier * 1e6, "fully_resident": t_res * 1e6},
        "p50_ratio": p50_ratio,
        "tree": {
            "super_k": SK, "max_children": M,
            "nprobe_super": ivf.nprobe_super, "routing_cost": cost,
            "nlist": nlist, "bucket_overlap_vs_flat": bucket_overlap,
        },
    }
    emit(
        f"tiered/n{n}-slots{slots}of{P}-int8", t_tier * 1e6,
        f"recall={rec_t:.3f};hit_rate={hit_rate:.3f};"
        f"p50_ratio={p50_ratio:.2f};route_cost={cost}/{nlist}",
    )

    # acceptance gates
    assert P >= 4 * slots, record["config"]
    assert rec_t >= rec_r, record
    assert rec_t >= 0.99, record
    assert hit_rate >= 0.8, record
    assert p50_ratio <= 1.5, record
    assert cost == SK + ivf.nprobe_super * M and cost < nlist, record
    assert bucket_overlap >= 0.9, record

    # ---- cold-miss uploads: async host-staged vs legacy synchronous
    record["async_uploads"] = _async_upload_section(scale)
    write_json("BENCH_tiered.json", record)


if __name__ == "__main__":
    run()
