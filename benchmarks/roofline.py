"""Roofline analysis from the dry-run artifacts (harness deliverable (g)).

Per (arch x shape x mesh) cell:
    compute term    = HLO_FLOPs / (chips x 197 TF/s bf16)
    memory term     = HLO_bytes / (chips x 819 GB/s HBM)
    collective term = wire bytes per chip / 50 GB/s/link ICI
                      (all-reduce payloads x2 for the ring's reduce+broadcast
                      halves; parsed from the partitioned HLO with while-loop
                      trip multipliers — see repro.launch.analysis)
FLOPs/bytes are the loop-aware jaxpr counts (global program); XLA's own
cost_analysis is recorded alongside but undercounts scan bodies (visits
while bodies once).  MODEL_FLOPS uses 6·N_active·D (train) / 2·N_active·D
(inference) and the ratio flags remat/dispatch waste.
"""
from __future__ import annotations

import glob
import json
import os

PEAK_FLOPS = 197e12       # bf16 / chip (TPU v5e)
HBM_BW = 819e9            # B/s / chip
ICI_BW = 50e9             # B/s / link

AR_FACTOR = 2.0           # ring all-reduce moves ~2x payload per chip


def load_records(dirname: str = "results/dryrun") -> list[dict]:
    recs = []
    for d, variant in [
        (dirname, "baseline"),
        (dirname + "_hints", "optimized"),
        (dirname + "_pdx", "pdx"),
    ]:
        if not os.path.isdir(d):
            continue
        for f in sorted(glob.glob(os.path.join(d, "*.json"))):
            with open(f) as fh:
                rec = json.load(fh)
            rec["variant"] = variant
            recs.append(rec)
    return recs


def roofline_terms(rec: dict) -> dict:
    chips = rec["n_devices"]
    jc = rec.get("jaxpr_cost", {})
    flops = jc.get("flops", 0.0)
    bytes_ = jc.get("bytes", 0.0)
    coll = rec.get("collectives", {})
    cb = coll.get("bytes", {})
    wire = sum(
        v * (AR_FACTOR if k == "all-reduce" else 1.0) for k, v in cb.items()
    )
    t_compute = flops / (chips * PEAK_FLOPS)
    t_memory = bytes_ / (chips * HBM_BW)
    t_coll = wire / ICI_BW  # per-chip program payload over per-chip links
    terms = {"compute_s": t_compute, "memory_s": t_memory,
             "collective_s": t_coll}
    dominant = max(terms, key=terms.get)
    # model flops
    na = rec.get("params_active", 0.0)
    tokens = rec.get("tokens", 0)
    mult = 6.0 if rec.get("step") == "train" else 2.0
    model_flops = mult * na * tokens
    bound = max(terms.values()) or 1.0
    return {
        **terms,
        "dominant": dominant.replace("_s", ""),
        "model_flops": model_flops,
        "hlo_flops": flops,
        "useful_ratio": (model_flops / flops) if flops else 0.0,
        "roofline_fraction": t_compute / bound,
        "mfu_bound": model_flops / (chips * PEAK_FLOPS * bound) if bound else 0.0,
        "peak_bytes_per_dev": rec.get("memory", {}).get("peak_memory_in_bytes"),
    }


SUGGEST = {
    "compute": "compute-bound: raise MFU via larger per-chip tiles or fewer "
               "remat recomputes",
    "memory": "HBM-bound: fuse elementwise chains / cast activations to bf16 "
              "/ shrink the working set per step",
    "collective": "ICI-bound: overlap collectives with compute, shard to cut "
                  "payloads (reduce-scatter grads), or compress gradients",
}


def run(scale: str = "smoke", dirname: str = "results/dryrun"):
    from .common import emit

    recs = load_records(dirname)
    if not recs:
        print("roofline: no dry-run records found (run scripts/run_dryruns.sh)")
        return
    rows = []
    for rec in recs:
        name = (f"roofline/{rec.get('variant','baseline')}/"
                f"{rec['arch']}/{rec['shape']}/{rec['mesh']}")
        if rec.get("status") == "skipped":
            if rec.get("variant") == "baseline":
                emit(name, 0.0, f"skipped:{rec.get('reason','')[:60]}")
            continue
        if rec.get("status") != "ok":
            emit(name, 0.0, f"error:{rec.get('error','')[:60]}")
            continue
        t = roofline_terms(rec)
        rows.append((rec, t))
        emit(
            name, t["compute_s"] * 1e6,
            f"mem_s={t['memory_s']:.2e};coll_s={t['collective_s']:.2e};"
            f"dominant={t['dominant']};useful={t['useful_ratio']:.2f};"
            f"frac={t['roofline_fraction']:.2f}",
        )
    # write the markdown table for EXPERIMENTS.md
    os.makedirs("results", exist_ok=True)
    with open("results/roofline_table.md", "w") as f:
        f.write("| arch | shape | mesh | variant | compute s | memory s "
                "| collective s | dominant | MODEL/HLO | roofline frac "
                "| next move |\n")
        f.write("|---|---|---|---|---|---|---|---|---|---|---|\n")
        for rec, t in rows:
            f.write(
                f"| {rec['arch']} | {rec['shape']} | {rec['mesh']} "
                f"| {rec.get('variant','baseline')} "
                f"| {t['compute_s']:.3e} | {t['memory_s']:.3e} "
                f"| {t['collective_s']:.3e} | {t['dominant']} "
                f"| {t['useful_ratio']:.2f} | {t['roofline_fraction']:.2f} "
                f"| {SUGGEST[t['dominant']][:58]} |\n"
            )
    print("roofline: wrote results/roofline_table.md")


if __name__ == "__main__":
    run()
