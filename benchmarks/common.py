"""Shared benchmark utilities: timing, CSV/JSON emission, dataset cache."""
from __future__ import annotations

import json
import os
import time
from typing import Callable

import jax
import numpy as np

RESULTS: list[tuple[str, float, str]] = []


def block(x):
    for leaf in jax.tree.leaves(x):
        if hasattr(leaf, "block_until_ready"):
            leaf.block_until_ready()
    return x


def timeit(fn: Callable, *args, reps: int = 5, warmup: int = 2, **kw) -> float:
    """Min wall seconds over reps (after warmup)."""
    for _ in range(warmup):
        block(fn(*args, **kw))
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        block(fn(*args, **kw))
        best = min(best, time.perf_counter() - t0)
    return best


def emit(name: str, us_per_call: float, derived: str = ""):
    """Accumulate + print one CSV row: name,us_per_call,derived."""
    RESULTS.append((name, us_per_call, derived))
    print(f"{name},{us_per_call:.2f},{derived}", flush=True)


def write_json(filename: str, record: dict, out_dir: str = ".") -> str:
    """Write one benchmark record as a BENCH_*.json artifact; returns path."""
    path = os.path.join(out_dir, filename)
    with open(path, "w") as f:
        json.dump(record, f, indent=1, sort_keys=True)
    print(f"# wrote {path}", flush=True)
    return path


_DATASETS: dict = {}


def dataset(n: int, dim: int, kind: str, n_queries: int = 16, seed: int = 0):
    from repro.data.synthetic import make_dataset

    key = (n, dim, kind, n_queries, seed)
    if key not in _DATASETS:
        _DATASETS[key] = make_dataset(n, dim, kind, n_queries=n_queries, seed=seed)
    return _DATASETS[key]
