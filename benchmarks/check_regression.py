"""Perf regression gate: BENCH_*.json vs benchmarks/perf_baseline.json.

Runs after the benchmark smokes in CI and fails the build when a tracked
metric regresses by more than the tolerance (default 20%) against the
committed baseline.  Tracked metrics are the PR-level acceptance numbers —
realized bytes/query, batch-vs-host-loop speedup, warm/cold p50 ratios —
chosen because they are self-normalized or deterministic and therefore
stable across runner hardware; raw wall-clock entries get the same
tolerance but are expected to be the noisiest.

Usage:
    python -m benchmarks.check_regression            # gate (exit 1 on fail)
    python -m benchmarks.check_regression --update   # rebase from current
                                                     # BENCH files

Baseline format (benchmarks/perf_baseline.json):
    {"tolerance": 0.20,
     "metrics": {"BENCH_foo.json:dotted.path": {"value": 1.23,
                                                "better": "lower|higher"}}}
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent
BASELINE = pathlib.Path(__file__).resolve().parent / "perf_baseline.json"


def _lookup(record, dotted: str) -> float:
    cur = record
    for part in dotted.split("."):
        cur = cur[part]
    return float(cur)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument(
        "--update", action="store_true",
        help="rewrite the baseline from the current BENCH_*.json files",
    )
    args = ap.parse_args(argv)

    base = json.loads(BASELINE.read_text())
    tol = float(base.get("tolerance", 0.20))
    failures: list[str] = []
    for key, meta in sorted(base["metrics"].items()):
        fname, dotted = key.split(":", 1)
        path = ROOT / fname
        if not path.exists():
            failures.append(f"{key}: {fname} not found — run the bench first")
            continue
        try:
            cur = _lookup(json.loads(path.read_text()), dotted)
        except (KeyError, TypeError):
            failures.append(f"{key}: metric missing from {fname}")
            continue
        if args.update:
            meta["value"] = cur
            print(f"[rebase] {key} = {cur:.6g}")
            continue
        ref = float(meta["value"])
        better = meta.get("better", "lower")
        if better == "lower":
            worse = cur > ref * (1.0 + tol)
        else:
            worse = cur < ref * (1.0 - tol)
        status = "FAIL" if worse else "  ok"
        print(f"[{status}] {key}: current={cur:.6g} baseline={ref:.6g} "
              f"({better} is better, tolerance {tol:.0%})")
        if worse:
            failures.append(
                f"{key}: {cur:.6g} vs baseline {ref:.6g} "
                f"(> {tol:.0%} regression, {better} is better)"
            )

    if args.update:
        BASELINE.write_text(
            json.dumps(base, indent=2, sort_keys=True) + "\n"
        )
        print(f"wrote {BASELINE}")
        return 0
    if failures:
        print("\nperf regression gate FAILED:", file=sys.stderr)
        for f in failures:
            print(f"  - {f}", file=sys.stderr)
        return 1
    print("perf regression gate passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
