"""Bucket-routed vs broadcast distributed search on an 8-fake-CPU-device
``data`` mesh — the ROADMAP "IVF bucket routing across hosts" item.

The broadcast baseline is the fused batch-block-sharded executor: every
query replicates to every shard and the whole striped store is scanned.
The routed path ships each query only to the shards owning its top-nprobe
buckets (one all-to-all) and merges candidates hierarchically (one packed
all-gather).  For each nprobe we report modeled *bytes moved per query*
(the actual collective payload sizes) and p50 latency — bytes shrink as
nprobe drops because fewer owner shards means fewer occupied send slots.

Standalone only (NOT in run.py's MODULES): the XLA device-count flag is
process-global and must be set before jax initializes.

    PYTHONPATH=src python -m benchmarks.bench_routing [--scale paper]
"""
from __future__ import annotations

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.engine import SearchSpec, VectorSearchEngine
from repro.core.plan import _get_placement
from repro.data.synthetic import ground_truth, recall_at_k
from repro.dist.routing import build_send_buffer, plan_routing

from .common import dataset, emit, write_json


def _p50(fn, reps: int = 9, warmup: int = 2) -> float:
    for _ in range(warmup):
        fn()
    times = []
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        times.append(time.perf_counter() - t0)
    return float(np.median(times))


def run(scale: str = "smoke"):
    n, dim, cap, nq, nlist = (
        (16384, 64, 128, 64, 64) if scale == "smoke"
        else (131072, 128, 512, 256, 256)
    )
    k = 10
    X, Q = dataset(n, dim, "clustered", n_queries=nq, seed=0)
    n_dev = jax.device_count()
    gt_ids, _ = ground_truth(X, Q, k=k)

    mesh = jax.make_mesh((n_dev,), ("data",))
    eng = VectorSearchEngine.build(
        X, index="ivf", pruner="linear", capacity=cap, nlist=nlist, mesh=mesh,
    )
    B = len(Q)

    # broadcast baseline: replicated queries, full striped-store scan
    spec_bcast = SearchSpec(k=k, executor="batch-block-sharded")
    res = eng.search(Q, spec_bcast)
    assert res.plan.executor == "batch-block-sharded", res.plan
    assert recall_at_k(res.ids, gt_ids) == 1.0
    t_bcast = _p50(lambda: eng.search(Q, spec_bcast))
    bytes_bcast = (n_dev * B * dim + n_dev * B * 2 * k) * 4  # Q bcast + merge
    emit(
        f"routing/broadcast/n{n}/D{dim}/B{B}/dev{n_dev}",
        t_bcast / B * 1e6,
        f"bytes_per_q={bytes_bcast / B:.0f}",
    )

    record = {
        "bench": "bucket_routed_vs_broadcast",
        "scale": scale,
        "n_vectors": n, "dim": dim, "capacity": cap, "k": k,
        "batch": B, "n_devices": n_dev, "nlist": nlist,
        "broadcast": {
            "p50_us_per_query": t_bcast / B * 1e6,
            "bytes_per_query": bytes_bcast / B,
        },
        "bucket_routed": {},
    }

    pl = _get_placement(eng.store, n_dev, "bucket", ivf=eng.ivf)
    prev_bytes = float("inf")
    for nprobe in (16, 4, 1):
        spec = SearchSpec(k=k, nprobe=nprobe)
        res = eng.search(Q, spec)
        assert res.plan.executor == "routed_bucket", res.plan
        rec = recall_at_k(res.ids, gt_ids)
        t_routed = _p50(lambda: eng.search(Q, spec))

        sel = eng.ivf.route_batch(jnp.asarray(Q), nprobe)
        rp = plan_routing(sel, pl.bucket_shard, pl.bucket_parts, n_dev)
        buf = build_send_buffer(Q, sel, rp)
        # actual collective payloads: padded all-to-all + packed all-gather
        bytes_a2a = buf.nbytes
        bytes_gather = n_dev * (n_dev * rp.budget) * 2 * k * 4
        bytes_q = (bytes_a2a + bytes_gather) / B
        emit(
            f"routing/bucket/nprobe{nprobe}/n{n}/D{dim}/B{B}/dev{n_dev}",
            t_routed / B * 1e6,
            f"bytes_per_q={bytes_q:.0f};recall={rec:.3f};"
            f"budget={rp.budget};occupancy={rp.occupancy}",
        )
        record["bucket_routed"][f"nprobe_{nprobe}"] = {
            "p50_us_per_query": t_routed / B * 1e6,
            "bytes_per_query": bytes_q,
            "bytes_all_to_all": bytes_a2a,
            "bytes_all_gather": bytes_gather,
            "send_budget": rp.budget,
            "send_occupancy": rp.occupancy,
            "recall_at_k": rec,
        }
        # the acceptance claim: wire bytes shrink as nprobe drops
        assert bytes_q <= prev_bytes, (nprobe, bytes_q, prev_bytes)
        prev_bytes = bytes_q

    write_json("BENCH_routing.json", record)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", default="smoke", choices=["smoke", "paper"])
    args = ap.parse_args()
    print("name,us_per_call,derived")
    run(scale=args.scale)


if __name__ == "__main__":
    main()


