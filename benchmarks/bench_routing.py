"""Bucket-routed vs broadcast distributed search on an 8-fake-CPU-device
``data`` mesh — the ROADMAP "IVF bucket routing across hosts" item.

The broadcast baseline is the fused batch-block-sharded executor: every
query replicates to every shard and the whole striped store is scanned.
The routed path ships each query only to the shards owning its top-nprobe
buckets (one all-to-all) and merges candidates hierarchically (one packed
all-gather).  For each nprobe we report modeled *bytes moved per query*
(the actual collective payload sizes) and p50 latency — bytes shrink as
nprobe drops because fewer owner shards means fewer occupied send slots.

The quantized-mirror section accounts the full bandwidth story per scan
dtype: device-scan bytes (each shard streams its arranged mirror slice
once per batch, at mirror width, plus the f32 master columns its local
re-rank gathers) + collective bytes (the wire stays f32: rounding queries
or candidate distances breaks exact k-boundary ordering — see
repro.dist.routing).  Acceptance: bf16 / int8 cut the combined bytes per
query >= 1.9x / 3.5x vs the f32 routed path, with ids identical to the
f32 run (the on-shard f32 re-rank makes candidate distances exact before
the merge).

Standalone only (NOT in run.py's MODULES): the XLA device-count flag is
process-global and must be set before jax initializes.

    PYTHONPATH=src python -m benchmarks.bench_routing [--scale paper]
"""
from __future__ import annotations

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.engine import SearchSpec, VectorSearchEngine
from repro.core.plan import _get_placement
from repro.data.synthetic import ground_truth, recall_at_k
from repro.dist.routing import build_send_buffer, plan_routing
from repro.obs import meters

from .common import dataset, emit, write_json


def _p50(fn, reps: int = 9, warmup: int = 2) -> float:
    for _ in range(warmup):
        fn()
    times = []
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        times.append(time.perf_counter() - t0)
    return float(np.median(times))


def run(scale: str = "smoke"):
    n, dim, cap, nq, nlist = (
        (16384, 64, 128, 64, 64) if scale == "smoke"
        else (131072, 128, 512, 256, 256)
    )
    k = 10
    X, Q = dataset(n, dim, "clustered", n_queries=nq, seed=0)
    n_dev = jax.device_count()
    gt_ids, _ = ground_truth(X, Q, k=k)

    mesh = jax.make_mesh((n_dev,), ("data",))
    eng = VectorSearchEngine.build(
        X, index="ivf", pruner="linear", capacity=cap, nlist=nlist, mesh=mesh,
    )
    B = len(Q)

    # broadcast baseline: replicated queries, full striped-store scan
    spec_bcast = SearchSpec(k=k, executor="batch-block-sharded")
    res = eng.search(Q, spec_bcast)
    assert res.plan.executor == "batch-block-sharded", res.plan
    assert recall_at_k(res.ids, gt_ids) == 1.0
    t_bcast = _p50(lambda: eng.search(Q, spec_bcast))
    # Q broadcast + packed merge, from the runtime's own wire model
    bytes_bcast = sum(
        meters.broadcast_batch_bytes(n_shards=n_dev, B=B, D=dim, k=k).values()
    )
    emit(
        f"routing/broadcast/n{n}/D{dim}/B{B}/dev{n_dev}",
        t_bcast / B * 1e6,
        f"bytes_per_q={bytes_bcast / B:.0f}",
    )

    record = {
        "bench": "bucket_routed_vs_broadcast",
        "scale": scale,
        "n_vectors": n, "dim": dim, "capacity": cap, "k": k,
        "batch": B, "n_devices": n_dev, "nlist": nlist,
        "broadcast": {
            "p50_us_per_query": t_bcast / B * 1e6,
            "bytes_per_query": bytes_bcast / B,
        },
        "bucket_routed": {},
    }

    pl = _get_placement(eng.store, n_dev, "bucket", ivf=eng.ivf)
    prev_bytes = float("inf")
    for nprobe in (16, 4, 1):
        spec = SearchSpec(k=k, nprobe=nprobe)
        res = eng.search(Q, spec)
        assert res.plan.executor == "routed_bucket", res.plan
        rec = recall_at_k(res.ids, gt_ids)
        t_routed = _p50(lambda: eng.search(Q, spec))

        sel = eng.ivf.route_batch(jnp.asarray(Q), nprobe)
        rp = plan_routing(sel, pl.bucket_shard, pl.bucket_parts, n_dev)
        buf = build_send_buffer(Q, sel, rp)
        # collective payloads from the runtime's wire model — and the
        # all-to-all entry must equal the actual padded send buffer
        wire = meters.routed_batch_bytes(
            rp, n_shards=n_dev, D=dim, C=pl.data.shape[2],
            num_slots=pl.data.shape[0], nprobe=nprobe, k=k,
        )
        bytes_a2a = wire["all_to_all"]
        bytes_gather = wire["all_gather"]
        assert bytes_a2a == buf.nbytes, (bytes_a2a, buf.nbytes)
        bytes_q = (bytes_a2a + bytes_gather) / B
        emit(
            f"routing/bucket/nprobe{nprobe}/n{n}/D{dim}/B{B}/dev{n_dev}",
            t_routed / B * 1e6,
            f"bytes_per_q={bytes_q:.0f};recall={rec:.3f};"
            f"budget={rp.budget};occupancy={rp.occupancy}",
        )
        record["bucket_routed"][f"nprobe_{nprobe}"] = {
            "p50_us_per_query": t_routed / B * 1e6,
            "bytes_per_query": bytes_q,
            "bytes_all_to_all": bytes_a2a,
            "bytes_all_gather": bytes_gather,
            "send_budget": rp.budget,
            "send_occupancy": rp.occupancy,
            "recall_at_k": rec,
        }
        # the acceptance claim: wire bytes shrink as nprobe drops
        assert bytes_q <= prev_bytes, (nprobe, bytes_q, prev_bytes)
        prev_bytes = bytes_q

    record["scan_dtype"] = _scan_dtypes(scale, k)
    write_json("BENCH_routing.json", record)


def _scan_dtypes(scale: str, k: int) -> dict:
    """Quantized-mirror accounting: device-scan + collective bytes per
    query, per scan dtype, on the routed path."""
    import jax

    from repro.core.layout import device_mirror
    from repro.dist.routing import RoutingPlan  # noqa: F401 (doc pointer)

    n, dim, cap, nq, nlist, nprobe, rmult = (
        (65536, 64, 128, 16, 256, 2, 2) if scale == "smoke"
        else (262144, 128, 256, 32, 512, 4, 2)
    )
    n_dev = jax.device_count()
    X, Q = dataset(n, dim, "clustered", n_queries=nq, seed=1)
    gt_ids, _ = ground_truth(X, Q, k=k)
    mesh = jax.make_mesh((n_dev,), ("data",))
    eng = VectorSearchEngine.build(
        X, index="ivf", pruner="linear", capacity=cap, nlist=nlist,
        mesh=mesh,
    )
    pl = _get_placement(eng.store, n_dev, "bucket", ivf=eng.ivf)
    B = len(Q)
    slots, D, C = pl.data.shape

    sel = eng.ivf.route_batch(jnp.asarray(Q), nprobe)
    rp = plan_routing(sel, pl.bucket_shard, pl.bucket_parts, n_dev)

    out = {"config": {
        "n": n, "dim": dim, "capacity": cap, "k": k, "batch": B,
        "nlist": nlist, "nprobe": nprobe, "rerank_mult": rmult,
        "n_devices": n_dev, "placement_slots": slots,
    }}
    ids_f32 = None
    total_f32 = None
    for dt in ("f32", "bf16", "int8"):
        # the acceptance recall gate: at exact coverage (nprobe == nlist)
        # the quantized path must return the true top-k — the on-shard f32
        # re-rank makes every candidate distance exact before the merge
        full = eng.search(
            Q, SearchSpec(k=k, nprobe=nlist, scan_dtype=dt,
                          rerank_mult=rmult),
        )
        rec_gt = recall_at_k(np.asarray(full.ids), gt_ids)
        assert rec_gt == 1.0, (dt, rec_gt)

        spec = SearchSpec(k=k, nprobe=nprobe, scan_dtype=dt,
                          rerank_mult=rmult)
        res = eng.search(Q, spec)
        assert res.plan.executor == "routed_bucket", res.plan
        if dt == "f32":
            ids_f32 = np.asarray(res.ids)
            recall_vs_f32 = 1.0
        else:
            # id-parity with the f32 run at partial probe: exact by
            # construction (on-shard f32 re-rank + exact f32 wire)
            recall_vs_f32 = recall_at_k(np.asarray(res.ids), ids_f32)
            assert recall_vs_f32 == 1.0, (dt, recall_vs_f32)
        t = _p50(lambda: eng.search(Q, spec), reps=5, warmup=1)

        quant = dt != "f32"
        mirror = device_mirror(eng.store, dt)  # authoritative byte width
        # the runtime's wire model: mirror-slice scan + on-shard re-rank
        # gathers + f32 collectives (the wire stays f32 throughout) — the
        # same numbers dist.routing records into repro_device_bytes_total
        comps = meters.routed_batch_bytes(
            rp, n_shards=n_dev, D=D, C=C, num_slots=slots, nprobe=nprobe,
            k=k, bytes_per_value=mirror.bytes_per_value, rerank_mult=rmult,
            quantized=quant,
        )
        scan_b = comps["scan"] / B
        rerank_b = comps["rerank"] / B
        a2a_b = comps["all_to_all"] / B
        gather_b = comps["all_gather"] / B
        total = scan_b + rerank_b + a2a_b + gather_b
        if dt == "f32":
            total_f32 = total
        ratio = total_f32 / total
        emit(
            f"routing/scan_dtype/{dt}/n{n}/D{dim}/B{B}",
            t / B * 1e6,
            f"bytes_per_q={total:.0f};ratio_vs_f32={ratio:.2f};"
            f"recall_full_probe={rec_gt:.3f};"
            f"recall_vs_f32={recall_vs_f32:.3f}",
        )
        out[dt] = {
            "p50_us_per_query": t / B * 1e6,
            "scan_bytes_per_query": scan_b,
            "rerank_bytes_per_query": rerank_b,
            "all_to_all_bytes_per_query": a2a_b,
            "all_gather_bytes_per_query": gather_b,
            "total_bytes_per_query": total,
            "ratio_vs_f32": ratio,
            "recall_at_k_full_probe": rec_gt,
            "recall_vs_f32": recall_vs_f32,
        }
    # the acceptance gates: mirrors cut device-scan + collective bytes
    assert out["bf16"]["ratio_vs_f32"] >= 1.9, out["bf16"]
    assert out["int8"]["ratio_vs_f32"] >= 3.5, out["int8"]
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", default="smoke", choices=["smoke", "paper"])
    args = ap.parse_args()
    print("name,us_per_call,derived")
    run(scale=args.scale)


if __name__ == "__main__":
    main()


