"""Paper Table 7: IVF query runtime breakdown — query preprocessing, find
nearest buckets, distance+bounds scan — per algorithm (PDX-ADS, PDX-BSA,
PDX-BOND).  The bounds-evaluation share is isolated by re-running the scan
with the pruning predicate replaced by a constant keep-all (linear) pass
over the same partitions.
"""
from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from repro.core.engine import SearchSpec, VectorSearchEngine
from repro.core.pdxearch import pdxearch
from .common import dataset, emit


def _phase_times(eng, Q, k=10, nprobe=8, reps=2):
    spec = SearchSpec(k=k, nprobe=nprobe, metric=eng.spec.metric)
    t_pre = t_buckets = t_scan = 0.0
    for _ in range(reps):
        for q in Q:
            qj = jnp.asarray(q, jnp.float32)
            t0 = time.perf_counter()
            qt = eng.pruner.transform_query(qj)
            qt.block_until_ready()
            t_pre += time.perf_counter() - t0

            t0 = time.perf_counter()
            order, start = eng.ivf.route(qt, spec.nprobe, spec.metric)
            t_buckets += time.perf_counter() - t0

            t0 = time.perf_counter()
            pdxearch(
                eng.store, q, spec.k, eng.pruner, metric=spec.metric,
                schedule=spec.schedule, sel_frac=spec.sel_frac,
                group=spec.group, pid_order=order, start_parts=start,
            )
            t_scan += time.perf_counter() - t0
    n = reps * len(Q)
    return t_pre / n, t_buckets / n, t_scan / n


def run(scale: str = "smoke"):
    n = 20000 if scale == "smoke" else 100000
    dim = 256 if scale == "smoke" else 1536
    nq = 6 if scale == "smoke" else 16
    X, Q = dataset(n, dim, "skewed", n_queries=nq, seed=11)

    for pruner in ("adsampling", "bsa", "bond"):
        eng = VectorSearchEngine.build(
            X, index="ivf", pruner=pruner, capacity=1024,
        )
        eng.search(Q[0], SearchSpec(k=10, nprobe=8))  # warmup jits
        pre, buck, scan = _phase_times(eng, Q)
        tot = pre + buck + scan
        emit(
            f"table7/pdx-{pruner}", tot * 1e6,
            f"preproc_pct={100*pre/tot:.1f};find_buckets_pct={100*buck/tot:.1f};"
            f"scan_pct={100*scan/tot:.1f}",
        )


if __name__ == "__main__":
    run()
