"""Paper Table 4 + the fused-executor bandwidth gate -> BENCH_kernels.json.

Part 1 (Table 4): PDX vs N-ary (horizontal) distance kernels across
dimensionalities, L2/IP/L1.  Both are XLA-autovectorized jnp — the layout is
the only variable, which is exactly the paper's claim (no intrinsics needed).
Derived column: speedup of PDX over N-ary.

Part 2 (fused executors): the megakernel (``fused-scan``) vs the jnp masked
path (``jit-masked``) at f32/bf16/int8 scan dtypes.  On the CI CPU run the
Pallas kernels execute in interpret mode, so wall-clock is meaningless for
them; correctness is gated by comparing interpret-mode ids against the jnp
body, and the throughput gate uses **demand bytes per query** as the proxy
(the scan is bandwidth-bound — paper Section 7): the masked path needs
every f32 dimension value of every partition, the megakernel needs a
partition's d-tiles only until all its lanes are pruned, at mirror width
(4/2/1 B).  Two components of that win have different status today: the
**dtype factor** (2x/4x) is realized — the mirror IS bf16/int8 in HBM —
while the **pruning factor** counts tiles whose loads the fused keep-mask
makes unnecessary; the shipped kernel skips their VPU work but the
automatic Pallas pipeline still streams them, so that factor is realized
once tile fetches are hoisted behind the keep-mask (the manual-DMA /
PrefetchScalarGridSpec follow-up in the kernel design notes and ROADMAP).
Acceptance: fused f32 demands >= 1.5x fewer bytes than the masked path at
equal recall.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core.engine import SearchSpec, VectorSearchEngine
from repro.core.layout import device_mirror
from repro.core.pdxearch import make_boundaries  # noqa: F401  (doc pointer)
from repro.data.synthetic import ground_truth, make_dataset, recall_at_k
from repro.core.distance import nary_distance, pdx_distance
from repro.obs import meters

from .common import dataset, emit, timeit, write_json

DIMS_FULL = [8, 16, 32, 64, 128, 192, 256, 384, 512, 768, 1024, 1536]
DIMS_SMOKE = [8, 32, 128, 512, 1536]


def _table4(scale: str, record: dict):
    dims = DIMS_SMOKE if scale == "smoke" else DIMS_FULL
    n = 16384 if scale == "smoke" else 131072
    rng = np.random.default_rng(0)
    rows = {}
    for metric in ("l2", "ip", "l1"):
        for d in dims:
            X = rng.standard_normal((n, d)).astype(np.float32)
            q = rng.standard_normal(d).astype(np.float32)
            Xj, Tj, qj = jnp.asarray(X), jnp.asarray(X.T), jnp.asarray(q)
            t_nary = timeit(nary_distance, Xj, qj, metric)
            t_pdx = timeit(pdx_distance, Tj, qj, metric)
            sp = t_nary / t_pdx
            rows[(metric, d)] = sp
            emit(
                f"table4/{metric}/D{d}/pdx", t_pdx * 1e6,
                f"nary_us={t_nary*1e6:.2f};speedup={sp:.2f}",
            )
    record["table4"] = {}
    for metric in ("l2", "ip", "l1"):
        lo = [v for (m, d), v in rows.items() if m == metric and d <= 32]
        hi = [v for (m, d), v in rows.items() if m == metric and d > 32]
        alls = [v for (m, d), v in rows.items() if m == metric]
        gm = lambda xs: float(np.exp(np.mean(np.log(xs))))  # noqa: E731
        record["table4"][metric] = {
            "geomean_speedup_lowD": gm(lo),
            "geomean_speedup_highD": gm(hi),
            "geomean_speedup_all": gm(alls),
        }
        emit(
            f"table4/{metric}/summary", 0.0,
            f"geomean_speedup_D<=32={gm(lo):.2f};"
            f"D>32={gm(hi):.2f};all={gm(alls):.2f}",
        )


def _scan_bytes_per_query(
    store, pruner, Q, starts, thr_per_q, eps0, dtype, d_tile=64
):
    """Mean DEMAND bytes per query via ``repro.obs.meters`` — the same
    keep-mask replay the runtime records into
    ``repro_device_bytes_total{component="scan"}``, so the bench gates and
    the registry agree by construction (see the module docstring: the dtype
    factor is realized today, the pruning factor once fetches are hoisted
    behind the keep-mask)."""
    mirror = device_mirror(store, dtype)
    total = 0.0
    for q, p0, thr in zip(Q, starts, thr_per_q):
        qt = pruner.transform_query(jnp.asarray(q, jnp.float32))
        total += meters.fused_demand_bytes(
            mirror, store.ids, qt, thr, p0=p0, eps0=eps0, d_tile=d_tile,
        )
    return total / len(Q)


def _fused(scale: str, record: dict):
    # IVF-bucketed clustered store: the megakernel's unit of skip is the
    # partition, and with buckets ≡ partitions a far bucket's lanes die at
    # the first hypothesis test — the paper's IVF serving shape.
    n, dim, cap, nq, nlist = (
        (16384, 256, 256, 8, 64) if scale == "smoke"
        else (131072, 256, 512, 32, 256)
    )
    k = 10
    X, Q = dataset(n, dim, "clustered", n_queries=nq, seed=0)
    gt_ids, _ = ground_truth(X, Q, k=k)
    eng = VectorSearchEngine.build(
        X, index="ivf", pruner="adsampling", capacity=cap, nlist=nlist,
    )
    store, pruner = eng.store, eng.pruner
    eps0 = float(pruner.aux["eps0"])
    P, D, C = store.data.shape
    store_bytes = P * D * C * 4  # what the jnp masked path streams, per query

    # per-query START partition (IVF-routed, as the executor does) and the
    # exact kth-distance threshold it seeds
    starts, thrs = [], []
    for q in Q:
        qt = pruner.transform_query(jnp.asarray(q, jnp.float32))
        order, _ = eng.ivf.route(qt, 1, "l2")
        p0 = int(order[0]) if len(order) else 0
        starts.append(p0)
        d0 = np.asarray(pdx_distance(store.data[p0], qt, "l2"))
        live = np.asarray(store.ids[p0]) >= 0
        thrs.append(float(np.sort(d0[live])[min(k - 1, live.sum() - 1)]))

    fused = {
        "config": {"n": n, "dim": dim, "capacity": cap, "k": k,
                   "nlist": nlist, "n_queries": nq, "d_tile": 64,
                   "eps0": eps0},
        "bytes_model": (
            "demand bytes via repro.obs.meters.fused_demand_bytes: d-tiles "
            "needed per the fused keep-mask, at mirror width; dtype factor "
            "realized in HBM today, pruning factor once fetches hoist "
            "behind the mask (see module doc)"
        ),
        "bytes_per_query": {"jnp-masked-f32": float(store_bytes)},
        "bytes_speedup_vs_jnp_masked": {},
        "throughput_us_per_query": {},
        "recall_at_k": {},
    }

    # jnp masked baseline: correctness + wall clock (pdxearch_jit directly —
    # the executor refuses IVF engines, but the masked scan itself is
    # index-agnostic: every partition, every dimension row, masked)
    from repro.core.pdxearch import pdxearch_jit

    ids_masked = np.stack([
        np.asarray(pdxearch_jit(store, q, k, pruner).ids) for q in Q
    ])
    fused["recall_at_k"]["jit-masked"] = recall_at_k(ids_masked, gt_ids)
    t = timeit(lambda: pdxearch_jit(store, Q[0], k, pruner),
               reps=3, warmup=1)
    fused["throughput_us_per_query"]["jit-masked-f32"] = t * 1e6
    emit(f"kernels/jit-masked/f32/n{n}/D{dim}", t * 1e6,
         f"bytes_per_q={store_bytes:.0f}")

    for dt in ("f32", "bf16", "int8"):
        spec = SearchSpec(k=k, scan_dtype=dt, kernel="jnp",
                          executor="fused-scan")
        ids_j = np.stack([np.asarray(eng.search(q, spec).ids) for q in Q])
        rec = recall_at_k(ids_j, gt_ids)
        fused["recall_at_k"][f"fused-scan-{dt}"] = rec
        # interpret-mode Pallas gates correctness (one query keeps CI fast)
        ids_p = np.asarray(
            eng.search(Q[0], spec.replace(kernel="pallas")).ids
        )
        assert np.array_equal(ids_p, ids_j[0]), (
            "pallas interpret body disagrees with jnp body", dt)
        bq = _scan_bytes_per_query(store, pruner, Q, starts, thrs, eps0, dt)
        sp = store_bytes / bq
        fused["bytes_per_query"][f"fused-scan-{dt}"] = bq
        fused["bytes_speedup_vs_jnp_masked"][dt] = sp
        t = timeit(lambda: eng.search(Q[0], spec), reps=3, warmup=1)
        fused["throughput_us_per_query"][f"fused-scan-{dt}-jnp"] = t * 1e6
        emit(f"kernels/fused-scan/{dt}/n{n}/D{dim}", t * 1e6,
             f"bytes_per_q={bq:.0f};bytes_speedup={sp:.2f};recall={rec:.3f}")

    fused["pallas_interpret_matches_jnp"] = True
    record["fused"] = fused

    # acceptance gates: >= 1.5x fewer bytes at equal recall; the bf16/int8
    # mirrors cut the fused scan's bytes a further >= 1.9x / 3.5x
    bq = fused["bytes_per_query"]
    assert fused["bytes_speedup_vs_jnp_masked"]["f32"] >= 1.5, fused
    assert fused["recall_at_k"]["fused-scan-f32"] >= \
        fused["recall_at_k"]["jit-masked"], fused
    assert bq["fused-scan-f32"] / bq["fused-scan-bf16"] >= 1.9, fused
    assert bq["fused-scan-f32"] / bq["fused-scan-int8"] >= 3.5, fused

    # cascade section: the multi-resolution scan (projection mirror ->
    # int4 over survivors -> exact f32 re-rank, prefetch-skip on later
    # stages) against this config's int8 fused-scan — >= 2x fewer realized
    # bytes per query at recall@k == 1.0 (gated inside cascade_section)
    from .bench_cascade import cascade_section

    record["cascade"] = cascade_section(eng, Q, gt_ids, k)


def run(scale: str = "smoke"):
    record = {"bench": "kernels", "scale": scale}
    _table4(scale, record)
    _fused(scale, record)
    write_json("BENCH_kernels.json", record)


if __name__ == "__main__":
    run()
