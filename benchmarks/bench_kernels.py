"""Paper Table 4: PDX vs N-ary (horizontal) distance kernels across
dimensionalities, L2/IP/L1.  Both are XLA-autovectorized jnp — the layout is
the only variable, which is exactly the paper's claim (no intrinsics needed).
Derived column: speedup of PDX over N-ary.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core.distance import nary_distance, pdx_distance

from .common import dataset, emit, timeit

DIMS_FULL = [8, 16, 32, 64, 128, 192, 256, 384, 512, 768, 1024, 1536]
DIMS_SMOKE = [8, 32, 128, 512, 1536]


def run(scale: str = "smoke"):
    dims = DIMS_SMOKE if scale == "smoke" else DIMS_FULL
    n = 16384 if scale == "smoke" else 131072
    rng = np.random.default_rng(0)
    rows = {}
    for metric in ("l2", "ip", "l1"):
        for d in dims:
            X = rng.standard_normal((n, d)).astype(np.float32)
            q = rng.standard_normal(d).astype(np.float32)
            Xj, Tj, qj = jnp.asarray(X), jnp.asarray(X.T), jnp.asarray(q)
            t_nary = timeit(nary_distance, Xj, qj, metric)
            t_pdx = timeit(pdx_distance, Tj, qj, metric)
            sp = t_nary / t_pdx
            rows[(metric, d)] = sp
            emit(
                f"table4/{metric}/D{d}/pdx", t_pdx * 1e6,
                f"nary_us={t_nary*1e6:.2f};speedup={sp:.2f}",
            )
    for metric in ("l2", "ip", "l1"):
        lo = [v for (m, d), v in rows.items() if m == metric and d <= 32]
        hi = [v for (m, d), v in rows.items() if m == metric and d > 32]
        alls = [v for (m, d), v in rows.items() if m == metric]
        emit(
            f"table4/{metric}/summary", 0.0,
            f"geomean_speedup_D<=32={np.exp(np.mean(np.log(lo))):.2f};"
            f"D>32={np.exp(np.mean(np.log(hi))):.2f};"
            f"all={np.exp(np.mean(np.log(alls))):.2f}",
        )


if __name__ == "__main__":
    run()
