"""Telemetry layer gates -> BENCH_obs.json + BENCH_obs_metrics.json.

Three sections:

* **overhead** — the same batched search timed with observability off and
  on.  Acceptance: enabled overhead < 5% (disabled mode is the baseline —
  its entire cost is one boolean check per instrumentation site).
* **routed invariants** — 8-fake-device bucket-routed batches per scan
  dtype, with every number read back FROM THE REGISTRY: the collective
  gate (``rounds`` all-to-alls + exactly one packed all-gather per batch,
  and runtime-issued == compile-time jaxpr count), and the quantized
  bandwidth story (bf16 / int8 cut total device bytes per batch >= 1.9x /
  3.5x vs f32).
* **trace** — the last routed batch's ``QueryTrace`` must carry the full
  plan -> route -> scan -> rerank -> merge taxonomy; the ring exports to
  Chrome/Perfetto JSON.

The structural gates are also compared against the committed
``benchmarks/obs_baseline.json`` so a regression shows up as a CI
diff, not just a local assert.  The registry snapshot is written to
``BENCH_obs_metrics.json`` and uploaded as a CI artifact.

Standalone only (NOT in run.py's MODULES): the XLA device-count flag is
process-global and must be set before jax initializes.

    PYTHONPATH=src python -m benchmarks.bench_obs [--scale paper]
"""
from __future__ import annotations

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")
os.environ.pop("REPRO_OBS", None)  # sections toggle the flag themselves

import argparse
import json
import time

import jax
import numpy as np

from repro.core.engine import SearchSpec, VectorSearchEngine
from repro.obs import metrics, trace

from .common import dataset, emit, write_json

BASELINE = os.path.join(os.path.dirname(__file__), "obs_baseline.json")


def _tmin_pair(fn, reps: int = 21, warmup: int = 3) -> tuple[float, float]:
    """Min wall time of ``fn`` with obs off and on.  Reps are interleaved
    (order alternating each rep) so both modes see the same machine drift,
    and min-of-many is robust to load spikes.  Always restores disabled."""
    def once(on: bool) -> float:
        metrics.set_enabled(on)
        t0 = time.perf_counter()
        fn()
        return time.perf_counter() - t0

    try:
        for on in (False, True) * warmup:
            once(on)
        t_off = t_on = float("inf")
        for i in range(reps):
            first = bool(i % 2)
            a, b = once(first), once(not first)
            t_on = min(t_on, a if first else b)
            t_off = min(t_off, b if first else a)
    finally:
        metrics.set_enabled(False)
    return t_off, t_on


def _overhead(scale: str, record: dict) -> None:
    n, dim, nq = (65536, 64, 64) if scale == "smoke" else (262144, 128, 128)
    X, Q = dataset(n, dim, "clustered", n_queries=nq, seed=0)
    eng = VectorSearchEngine.build(X, pruner="linear", capacity=256)
    spec = SearchSpec(k=10)
    run = lambda: eng.search(Q, spec)  # noqa: E731
    assert run().plan.executor == "batch-matmul"

    t_off, t_on = _tmin_pair(run)

    frac = t_on / t_off - 1.0
    emit(
        f"obs/overhead/batch-matmul/n{n}/D{dim}/B{nq}",
        t_on / nq * 1e6,
        f"off_us_per_q={t_off / nq * 1e6:.2f};overhead_frac={frac:.4f}",
    )
    record["overhead"] = {
        "executor": "batch-matmul", "n": n, "dim": dim, "batch": nq,
        "enabled_us_per_query": t_on / nq * 1e6,
        "disabled_us_per_query": t_off / nq * 1e6,
        "overhead_frac": frac,
    }
    assert frac < 0.05, record["overhead"]


def _routed(scale: str, record: dict) -> None:
    n, dim, cap, nq, nlist, nprobe, rmult = (
        (65536, 64, 128, 16, 256, 2, 2) if scale == "smoke"
        else (262144, 128, 256, 32, 512, 4, 2)
    )
    n_dev = jax.device_count()
    X, Q = dataset(n, dim, "clustered", n_queries=nq, seed=1)
    mesh = jax.make_mesh((n_dev,), ("data",))
    eng = VectorSearchEngine.build(
        X, index="ivf", pruner="linear", capacity=cap, nlist=nlist, mesh=mesh,
    )
    reg = metrics.get_registry()
    reg.reset()
    trace.get_tracer().clear()
    metrics.set_enabled(True)
    try:
        n_batches = 3
        ids_by_dt = {}
        for dt in ("f32", "bf16", "int8"):
            spec = SearchSpec(
                k=10, nprobe=nprobe, scan_dtype=dt, rerank_mult=rmult,
            )
            for _ in range(n_batches):
                res = eng.search(Q, spec)
            assert res.plan.executor == "routed_bucket", res.plan
            ids_by_dt[dt] = np.asarray(res.ids)

        # on-shard f32 re-rank + exact f32 wire: quantized ids == f32 ids
        for dt in ("bf16", "int8"):
            assert np.array_equal(ids_by_dt[dt], ids_by_dt["f32"]), dt

        g = lambda prim: reg.get(  # noqa: E731
            "repro_collectives_issued_total",
            executor="routed_bucket", primitive=prim,
        )
        pc = lambda prim: reg.get(  # noqa: E731
            "repro_collectives_per_call",
            executor="routed_bucket", primitive=prim,
        )
        total_batches = 3 * n_batches
        coll = {
            "all_to_all_per_call": pc("all_to_all"),
            "all_gather_per_call": pc("all_gather"),
            "all_to_all_issued": g("all_to_all"),
            "all_gather_issued": g("all_gather"),
            "batches": total_batches,
        }
        # the collective gate, straight from the registry: one packed
        # all-gather per batch, `rounds` all-to-alls, and the runtime
        # account equal to the compile-time jaxpr count x batches
        assert coll["all_gather_per_call"] == 1.0, coll
        assert coll["all_gather_issued"] == total_batches, coll
        assert coll["all_to_all_issued"] == \
            coll["all_to_all_per_call"] * total_batches, coll

        bytes_by_dt = {
            dt: reg.sum(
                "repro_device_bytes_total", executor="routed_bucket",
                dtype=dt,
            ) / n_batches
            for dt in ("f32", "bf16", "int8")
        }
        ratios = {
            dt: bytes_by_dt["f32"] / bytes_by_dt[dt]
            for dt in ("bf16", "int8")
        }
        for dt, floor in (("bf16", 1.9), ("int8", 3.5)):
            emit(
                f"obs/routed/{dt}/n{n}/D{dim}/B{nq}/dev{n_dev}",
                0.0,
                f"bytes_per_batch={bytes_by_dt[dt]:.0f};"
                f"ratio_vs_f32={ratios[dt]:.2f}",
            )
            assert ratios[dt] >= floor, (dt, ratios)

        # trace acceptance: full span taxonomy on the routed quantized path
        qt = trace.get_tracer().last()
        names = qt.span_names()
        for phase in ("plan", "route", "scan", "rerank", "merge"):
            assert phase in names, (phase, names)
        doc = trace.get_tracer().export_chrome()
        assert any(e["name"] == "query" for e in doc["traceEvents"])

        record["routed"] = {
            "config": {
                "n": n, "dim": dim, "capacity": cap, "batch": nq,
                "nlist": nlist, "nprobe": nprobe, "rerank_mult": rmult,
                "n_devices": n_dev, "batches_per_dtype": n_batches,
            },
            "collectives": coll,
            "bytes_per_batch": bytes_by_dt,
            "bytes_ratio_vs_f32": ratios,
            "trace_spans": list(names),
            "quantized_ids_match_f32": True,
        }
        write_json("BENCH_obs_metrics.json", reg.snapshot())
    finally:
        metrics.set_enabled(False)


def _check_baseline(record: dict) -> None:
    """Structural gates vs the committed baseline (timings are machine-
    dependent and only gated by the in-run 5% assert)."""
    with open(BASELINE) as f:
        base = json.load(f)
    assert record["overhead"]["overhead_frac"] <= base["max_overhead_frac"], (
        record["overhead"], base,
    )
    coll = record["routed"]["collectives"]
    for key, want in base["collectives_per_call"].items():
        assert coll[f"{key}_per_call"] == want, (key, coll, base)
    for dt, floor in base["min_bytes_ratio_vs_f32"].items():
        assert record["routed"]["bytes_ratio_vs_f32"][dt] >= floor, (
            dt, record["routed"]["bytes_ratio_vs_f32"], base,
        )
    assert record["routed"]["trace_spans"] == base["trace_spans"], (
        record["routed"]["trace_spans"], base["trace_spans"],
    )
    record["baseline_ok"] = True


def run(scale: str = "smoke"):
    record = {"bench": "obs", "scale": scale}
    _overhead(scale, record)
    _routed(scale, record)
    _check_baseline(record)
    write_json("BENCH_obs.json", record)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", default="smoke", choices=["smoke", "paper"])
    args = ap.parse_args()
    print("name,us_per_call,derived")
    run(scale=args.scale)


if __name__ == "__main__":
    main()
