"""Distributed PDXearch throughput: ``search_block_sharded`` on an 8-fake-
CPU-device ``data`` mesh vs single-device ``pdxearch_jit``, same store, same
queries.  Emits CSV rows plus a ``BENCH_dist.json`` record.

Standalone only (NOT in run.py's MODULES): the XLA device-count flag is
process-global and must be set before jax initializes, which would leak into
the other benchmarks' processes.

    PYTHONPATH=src python -m benchmarks.bench_dist [--scale paper]
"""
from __future__ import annotations

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.layout import build_flat_store
from repro.core.pdxearch import pdxearch_jit
from repro.core.pruners import make_plain_pruner
from repro.data.synthetic import ground_truth
from repro.dist.pdx_sharded import search_block_sharded

from .common import dataset, emit, timeit, write_json


def run(scale: str = "smoke"):
    n, dim, cap = (16384, 64, 256) if scale == "smoke" else (131072, 128, 1024)
    k = 10
    X, Q = dataset(n, dim, "normal", n_queries=4, seed=0)
    n_dev = jax.device_count()
    # both paths search the same vectors: truncate to a shardable tile count
    parts = max(n // cap // n_dev, 1) * n_dev
    X = X[: parts * cap]
    store = build_flat_store(X, capacity=cap)
    data, ids = store.data, store.ids
    mesh = jax.make_mesh((n_dev,), ("data",))
    pruner = make_plain_pruner()

    sharded = jax.jit(
        lambda d, i, q: search_block_sharded(mesh, d, i, q, k, pruner=pruner),
        static_argnames=(),
    )
    single = lambda q: pdxearch_jit(store, q, k, pruner)

    qj = jnp.asarray(Q[0])
    # correctness gate before timing: exact pruner => exact top-k distances
    gt_ids, gt_d = ground_truth(X, Q[:1], k=k)
    res = sharded(data, ids, qj)
    np.testing.assert_allclose(
        np.sort(np.asarray(res.dists)), np.sort(gt_d[0]), rtol=1e-4
    )

    t_sharded = timeit(sharded, data, ids, qj)
    t_single = timeit(single, qj)
    speedup = t_single / t_sharded
    emit(
        f"dist/block_sharded/n{n}/D{dim}/dev{n_dev}", t_sharded * 1e6,
        f"single_us={t_single*1e6:.2f};speedup={speedup:.2f};"
        f"qps={1.0/t_sharded:.1f}",
    )
    write_json(
        "BENCH_dist.json",
        {
            "bench": "dist_block_sharded_vs_single",
            "scale": scale,
            "n_vectors": parts * cap,
            "dim": dim,
            "capacity": cap,
            "k": k,
            "n_devices": n_dev,
            "t_single_us": t_single * 1e6,
            "t_block_sharded_us": t_sharded * 1e6,
            "speedup": speedup,
            "queries_per_s_sharded": 1.0 / t_sharded,
            "queries_per_s_single": 1.0 / t_single,
        },
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", default="smoke", choices=["smoke", "paper"])
    args = ap.parse_args()
    print("name,us_per_call,derived")
    run(scale=args.scale)


if __name__ == "__main__":
    main()
