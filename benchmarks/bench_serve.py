"""Online serving tier gates -> BENCH_serve.json.

One scenario, mirroring how the tier will actually run: a ``VectorServer``
over a flat adsampling engine takes a *skewed open-loop* arrival process
(lognormal inter-arrival gaps at ~3x the serial engine's throughput) while
a churn thread runs balanced insert/delete through the server and the
background maintenance thread repacks behind the version fence.

Acceptance (asserted, so a regression fails CI):

* sustained QPS >= 2x the serial blocking ``engine.search`` baseline
* p99 latency <= 5x p50 (continuous batching must not starve the tail)
* ZERO XLA compiles after ``warmup()`` — read from the
  ``repro_serve_jit_compiles`` obs gauge — i.e. the pow2 shape buckets,
  the static-shape write-head merge, and the shape-keyed batch executor
  really do absorb drifting batch sizes + concurrent churn without
  minting executables.

The collection size is chosen to leave more free sealed slots than the
churn batch, so background repacks never change the partition count (the
batch executor is shape-keyed; a growing tile grid would be a recompile).

    PYTHONPATH=src python -m benchmarks.bench_serve [--scale paper]
"""
from __future__ import annotations

import argparse
import threading
import time

import numpy as np

from repro.core.engine import VectorSearchEngine
from repro.obs import metrics
from repro.serve.batcher import ServerOverloaded
from repro.serve.vector import VectorServer

from .common import dataset, emit, write_json

DIM = 64
K = 10
CHURN_ROWS = 8


def _serial_qps(eng, Q, reps: int) -> float:
    """Blocking one-query-at-a-time engine.search — the pre-serving story."""
    for q in Q[:4]:                      # warm the serial path's own jits
        eng.search(q, k=K)
    t0 = time.perf_counter()
    for i in range(reps):
        eng.search(Q[i % len(Q)], k=K)
    return reps / (time.perf_counter() - t0)


def _churn_loop(srv, dim, stop: threading.Event) -> int:
    """Balanced insert/delete through the server (live count returns to the
    baseline each cycle, so repacks keep the tile grid shape)."""
    rng = np.random.default_rng(7)
    cycles = 0
    while not stop.is_set():
        ids = srv.insert(
            rng.standard_normal((CHURN_ROWS, dim)).astype(np.float32)
        ).result(timeout=60)
        srv.delete([int(i) for i in ids]).result(timeout=60)
        cycles += 1
        # leave mutation-free gaps wider than a repack (~10ms at this scale)
        # so some version-fenced swaps actually land; back-to-back churn
        # would discard every clone — also worth observing, but the bench
        # asserts the maintenance path end to end
        time.sleep(0.03)
    return cycles


def run(scale: str = "smoke") -> None:
    # n deliberately NOT a multiple of capacity: ~5% of sealed slots stay
    # free, so churn (+CHURN_ROWS transient rows) never grows a partition.
    n = 63488 if scale == "paper" else 8000
    n_open = 2000 if scale == "paper" else 400
    serial_reps = 60 if scale == "paper" else 40

    metrics.set_enabled(True)
    X, Q = dataset(n, DIM, "clustered", n_queries=64, seed=0)
    eng = VectorSearchEngine.build(
        X, pruner="adsampling", capacity=1024, metric="l2"
    )

    # 1) serial blocking baseline (before the compile snapshot: its jits are
    # part of process history, not of the serving steady state)
    serial_qps = _serial_qps(eng, Q, serial_reps)
    emit("serve_serial_qps", 1e6 / serial_qps, f"qps={serial_qps:.1f}")

    # serving implies churn: upgrade to the mutable store NOW so warmup can
    # pre-compile the (bucket, head_capacity) write-head merge shapes too
    eng._ensure_mutable()

    spec = eng.spec.replace(k=K, executor="batch-matmul")
    srv = VectorServer(
        eng, spec=spec, max_batch=64, queue_depth=512,
        flush_interval_s=0.002,
        maintenance_interval_s=0.25, head_fill_threshold=0.02,
        fragmentation_threshold=0.01,
    )
    try:
        srv.warmup()
        compiles_at_warmup = metrics.get_registry().get(
            "repro_serve_jit_compiles"
        )

        # 2) skewed open-loop arrivals at ~3x the serial rate + churn
        rate = 3.0 * serial_qps
        rng = np.random.default_rng(1)
        # lognormal gaps, mean 1/rate: sigma=1 gives the heavy-tailed
        # burstiness ("skewed") an open-loop client actually produces
        sigma = 1.0
        gaps = rng.lognormal(
            mean=np.log(1.0 / rate) - sigma**2 / 2, sigma=sigma, size=n_open
        )
        stop = threading.Event()
        churn_out = {}
        churn = threading.Thread(
            target=lambda: churn_out.setdefault(
                "cycles", _churn_loop(srv, DIM, stop)
            ),
            daemon=True,
        )
        churn.start()

        done_at = {}
        lock = threading.Lock()

        def _mark(i):
            def cb(fut):
                with lock:
                    done_at[i] = time.perf_counter()
            return cb

        submitted_at = {}
        rejected = 0
        futs = {}
        t_start = time.perf_counter()
        next_at = t_start
        for i in range(n_open):
            next_at += gaps[i]
            delay = next_at - time.perf_counter()
            if delay > 0:
                time.sleep(delay)
            try:
                f = srv.submit(Q[i % len(Q)])
            except ServerOverloaded:
                rejected += 1
                continue
            submitted_at[i] = time.perf_counter()
            futs[i] = f
            f.add_done_callback(_mark(i))
        for f in futs.values():
            f.result(timeout=120)
        t_end = max(done_at.values())
        stop.set()
        churn.join(timeout=60)

        lat = np.array(
            sorted(done_at[i] - submitted_at[i] for i in futs)
        )
        served_qps = len(futs) / (t_end - t_start)
        p50 = float(np.percentile(lat, 50))
        p99 = float(np.percentile(lat, 99))
        compiles_at_end = metrics.get_registry().get(
            "repro_serve_jit_compiles"
        )
        compiles_after_warmup = int(compiles_at_end - compiles_at_warmup)

        snap = metrics.get_registry().snapshot()
        maint = snap["counters"].get("repro_serve_maintenance_total", {})
        swaps = sum(v for k, v in maint.items() if "event=swap" in k)
        discards = sum(v for k, v in maint.items() if "event=discard" in k)
        buckets = sorted(
            snap["counters"].get("repro_serve_batches_total", {})
        )

        ratio = served_qps / serial_qps
        emit("serve_sustained_qps", 1e6 / served_qps,
             f"qps={served_qps:.1f},ratio={ratio:.2f}x")
        emit("serve_latency_p50", p50 * 1e6, f"p99={p99*1e6:.0f}us")
        emit("serve_compiles_after_warmup", float(compiles_after_warmup),
             f"swaps={swaps:.0f},discards={discards:.0f}")

        record = {
            "scale": scale,
            "n_vectors": n,
            "n_open_loop": n_open,
            "serial_qps": serial_qps,
            "served_qps": served_qps,
            "qps_ratio": ratio,
            "p50_s": p50,
            "p99_s": p99,
            "p99_over_p50": p99 / p50,
            "rejected": rejected,
            "churn_cycles": churn_out.get("cycles", 0),
            "maintenance_swaps": swaps,
            "maintenance_discards": discards,
            "compiles_after_warmup": compiles_after_warmup,
            "shape_buckets_used": buckets,
        }
        write_json("BENCH_serve.json", record)

        assert ratio >= 2.0, (
            f"sustained QPS only {ratio:.2f}x serial (need >= 2x)"
        )
        assert p99 <= 5.0 * p50, (
            f"p99 {p99*1e3:.2f}ms > 5x p50 {p50*1e3:.2f}ms"
        )
        assert compiles_after_warmup == 0, (
            f"{compiles_after_warmup} XLA compiles after warmup "
            "(shape buckets leaked)"
        )
        assert churn_out.get("cycles", 0) > 0, "churn thread never cycled"
        assert swaps + discards > 0, "maintenance thread never attempted"
    finally:
        srv.close(drain=True)
    metrics.set_enabled(False)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", default="smoke", choices=["smoke", "paper"])
    run(scale=ap.parse_args().scale)
