"""Cascaded multi-resolution scan gate -> BENCH_cascade.json.

Compares the cascade executor (skinny projection mirror -> packed int4
full-dimension pass over survivors -> exact f32 re-rank, with prefetch-skip
on later stages) against the PR 5 single-level int8 fused-scan path on the
seed IVF/clustered config, in REALIZED bytes per query:

  * PR 5 int8 fused-scan streams every partition's full-dimension int8
    mirror (its Pallas pipeline fetches tiles ahead of the keep-mask), plus
    the exact f32 START partition and the f32 re-rank gather.
  * The cascade's first stage streams every partition of the skinny
    projection mirror; each later stage is scheduled through the
    prefetch-skip grid, so only partitions with a surviving lane are
    fetched, at that stage's mirror width.  The executor meters exactly
    this model into ``repro_device_bytes_total{executor="cascade-scan"}``,
    which is what this bench reads — the gate and the registry agree by
    construction.

Acceptance (asserted in-process): cascade recall@10 == the exact ground
truth on the seed config, and >= 2x fewer realized bytes per query than the
PR 5 int8 fused-scan path.
"""
from __future__ import annotations

import numpy as np

from repro.core.engine import SearchSpec, VectorSearchEngine
from repro.data.synthetic import ground_truth, recall_at_k
from repro.obs import metrics

from .common import dataset, emit, timeit, write_json

CASCADE = ("proj32:int8", "int4", "f32")


def cascade_section(eng, Q, gt_ids, k: int) -> dict:
    """Measure + gate the cascade on an already-built IVF engine; returns
    the JSON record section (shared with bench_kernels' cascade section)."""
    store = eng.store
    P, D, C = store.data.shape
    rk = min(SearchSpec(k=k).rerank_mult * k, P * C)
    # PR 5 int8 fused-scan realized traffic: exact f32 START partition +
    # every partition's full-dim int8 mirror + the f32 re-rank gather
    base_bytes = float(D * C * 4 + P * D * C * 1 + rk * D * 4)

    spec8 = SearchSpec(k=k, scan_dtype="int8", kernel="jnp",
                       executor="fused-scan")
    ids8 = np.stack([np.asarray(eng.search(q, spec8).ids) for q in Q])
    rec8 = recall_at_k(ids8, gt_ids)

    spec = SearchSpec(k=k, cascade=CASCADE, kernel="jnp")
    reg = metrics.get_registry()
    was = metrics.enabled()
    metrics.set_enabled(True)
    try:
        def _sums():
            return (
                reg.sum("repro_device_bytes_total", executor="cascade-scan"),
                [reg.get("repro_cascade_stage_survivors", stage=str(si),
                         stage_name=CASCADE[si]) for si in range(2)],
                [reg.get("repro_cascade_stage_bytes", stage=str(si),
                         stage_name=CASCADE[si]) for si in range(2)],
            )

        b0, s0, sb0 = _sums()
        ids_c = np.stack([np.asarray(eng.search(q, spec).ids) for q in Q])
        b1, s1, sb1 = _sums()
    finally:
        metrics.set_enabled(was)
    rec_c = recall_at_k(ids_c, gt_ids)
    nq = len(Q)
    casc_bytes = (b1 - b0) / nq
    survivors = [(a - b) / nq for a, b in zip(s1, s0)]
    stage_bytes = [(a - b) / nq for a, b in zip(sb1, sb0)]

    # interpret-mode Pallas (incl. the prefetch-skip grid) gates correctness
    ids_p = np.asarray(eng.search(Q[0], spec.replace(kernel="pallas")).ids)
    assert np.array_equal(ids_p, ids_c[0]), (
        "cascade pallas interpret body disagrees with jnp body")

    t_c = timeit(lambda: eng.search(Q[0], spec), reps=3, warmup=1)
    t_8 = timeit(lambda: eng.search(Q[0], spec8), reps=3, warmup=1)
    speedup = base_bytes / casc_bytes
    section = {
        "cascade": list(CASCADE),
        "bytes_model": (
            "realized HBM traffic from repro_device_bytes_total{executor="
            "\"cascade-scan\"}: stage 0 streams all partitions at its "
            "mirror width, prefetch-skip stages fetch only alive-at-entry "
            "partitions; baseline = START f32 + full int8 stream + rerank"
        ),
        "bytes_per_query": {
            "fused-scan-int8": base_bytes,
            "cascade": casc_bytes,
            "cascade_stages": stage_bytes,
        },
        "bytes_speedup_vs_int8_fused": speedup,
        "stage_survivors_per_query": survivors,
        "recall_at_k": {"fused-scan-int8": rec8, "cascade": rec_c},
        "throughput_us_per_query": {
            "cascade-jnp": t_c * 1e6, "fused-scan-int8-jnp": t_8 * 1e6,
        },
        "pallas_interpret_matches_jnp": True,
    }
    emit(
        f"cascade/{'-'.join(CASCADE)}", t_c * 1e6,
        f"bytes_per_q={casc_bytes:.0f};int8_bytes_per_q={base_bytes:.0f};"
        f"bytes_speedup={speedup:.2f};recall={rec_c:.3f}",
    )

    # acceptance gates: exact recall at parity, >= 2x fewer realized bytes
    assert rec_c == 1.0, section
    assert rec_c >= rec8, section
    assert speedup >= 2.0, section
    return section


def batched_section(eng, X, k: int, batch: int = 64) -> dict:
    """Batch-native cascade vs the per-query host-loop executor on the SAME
    engine, spec, and query batch.  The batched executor runs every stage
    once over the whole batch (shared survivor bitmap, compacted-union
    gather), so the host-loop's per-query jit dispatch + device round-trips
    amortize away; the ids stay bitwise identical (gated in-process).

    Acceptance: recall@k == 1.0 and >= 3x queries/s over the host loop."""
    rng = np.random.default_rng(7)
    Qb = (X[rng.choice(len(X), batch, replace=False)]
          + rng.standard_normal((batch, X.shape[1])).astype(np.float32) * 0.05)
    gt_b, _ = ground_truth(X, Qb, k=k)

    spec_b = SearchSpec(k=k, cascade=CASCADE, kernel="jnp")
    spec_s = spec_b.replace(executor="cascade-scan")
    res_b = eng.search(Qb, spec_b)
    assert res_b.plan.executor == "cascade-batch", res_b.plan
    res_s = eng.search(Qb, spec_s)
    assert res_s.plan.executor == "cascade-scan", res_s.plan
    assert np.array_equal(np.asarray(res_b.ids), np.asarray(res_s.ids)), (
        "batched cascade ids diverge from the per-query host loop")
    rec_b = recall_at_k(np.asarray(res_b.ids), gt_b)

    t_b = timeit(lambda: eng.search(Qb, spec_b), reps=3, warmup=1)
    t_s = timeit(lambda: eng.search(Qb, spec_s), reps=3, warmup=1)
    qps_b, qps_s = batch / t_b, batch / t_s
    speedup = qps_b / qps_s
    section = {
        "batch": batch,
        "recall_at_k": rec_b,
        "queries_per_s": {"cascade-batch": qps_b, "cascade-scan": qps_s},
        "batch_speedup_vs_host_loop": speedup,
        "ids_bitwise_equal": True,
    }
    emit(
        f"cascade-batch/B{batch}-{'-'.join(CASCADE)}", t_b / batch * 1e6,
        f"qps={qps_b:.0f};host_loop_qps={qps_s:.0f};"
        f"speedup={speedup:.2f};recall={rec_b:.3f}",
    )
    assert rec_b == 1.0, section
    assert speedup >= 3.0, section
    return section


def run(scale: str = "smoke"):
    n, dim, cap, nq, nlist = (
        (16384, 256, 256, 8, 64) if scale == "smoke"
        else (131072, 256, 512, 32, 256)
    )
    k = 10
    X, Q = dataset(n, dim, "clustered", n_queries=nq, seed=0)
    gt_ids, _ = ground_truth(X, Q, k=k)
    eng = VectorSearchEngine.build(
        X, index="ivf", pruner="adsampling", capacity=cap, nlist=nlist,
    )
    record = {
        "bench": "cascade", "scale": scale,
        "config": {"n": n, "dim": dim, "capacity": cap, "k": k,
                   "nlist": nlist, "n_queries": nq},
    }
    record.update(cascade_section(eng, Q, gt_ids, k))
    record["batched"] = batched_section(eng, X, k)
    write_json("BENCH_cascade.json", record)


if __name__ == "__main__":
    run()
