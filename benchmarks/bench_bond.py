"""Paper Figure 8 + Tables 2/6: PDX-BOND vs PDX-ADS vs PDX-BSA on an IVF
index (QPS at fixed nprobe), plus pruning-power quantiles (best/p50/p25/
worst) per pruner on normal vs skewed collections.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core.engine import SearchSpec, SearchStats, VectorSearchEngine
from repro.data.synthetic import ground_truth, recall_at_k
from .common import dataset, emit


def _pruning_power_quantiles(eng, Q, k=10, nprobe=8):
    spec = SearchSpec(k=k, nprobe=nprobe)  # planner handles flat vs IVF
    powers = []
    for q in Q:
        st = SearchStats()
        eng.search(q, spec, stats=st)
        powers.append(st.pruning_power * 100)
    p = np.array(powers)
    return (
        f"best={p.max():.1f};p50={np.percentile(p, 50):.1f};"
        f"p25={np.percentile(p, 25):.1f};worst={p.min():.1f}"
    )


def run(scale: str = "smoke"):
    n = 20000 if scale == "smoke" else 100000
    dim = 128 if scale == "smoke" else 768
    nq = 8 if scale == "smoke" else 32
    k, nprobe = 10, 8

    # ---- Tables 2/6: pruning power per distribution --------------------
    for kind in ("normal", "skewed"):
        Xp, Qp = dataset(n // 2, dim, kind, n_queries=nq, seed=3)
        for pruner in ("adsampling", "bond"):
            eng = VectorSearchEngine.build(Xp, pruner=pruner, capacity=1024)
            emit(
                f"table2_6/{pruner}/{kind}", 0.0,
                _pruning_power_quantiles(eng, Qp),
            )

    # ---- Figure 8: QPS comparison on shared IVF ------------------------
    X, Q = dataset(n, dim, "clustered", n_queries=nq, seed=4)
    gt_ids, _ = ground_truth(X, Q, k)
    engines = {}
    for pruner in ("bond", "adsampling", "bsa", "linear"):
        engines[pruner] = VectorSearchEngine.build(
            X, index="ivf", pruner=pruner, capacity=1024,
        )
    spec = SearchSpec(k=k, nprobe=nprobe)
    for name, eng in engines.items():
        for q in Q[: min(4, len(Q))]:  # warm capacity-bucket jit variants
            eng.search(q, spec)
        t0 = time.perf_counter()
        found = [eng.search(q, spec).ids for q in Q]
        dt = time.perf_counter() - t0
        rec = recall_at_k(np.stack(found), gt_ids)
        emit(
            f"fig8/pdx-{name}", dt / len(Q) * 1e6,
            f"qps={len(Q)/dt:.1f};recall={rec:.3f}",
        )


if __name__ == "__main__":
    run()
