"""Serve a small model with batched requests + PDX retrieval (the paper's
technique as the retrieval substrate of an LLM pipeline).

    PYTHONPATH=src python examples/rag_serve.py
"""
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models.lm import build_model
from repro.serve.engine import GenerationEngine
from repro.serve.rag import RagPipeline


def main():
    cfg = get_config("gemma-2b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    eng = GenerationEngine(model=model, params=params, cache_len=128)

    rng = np.random.default_rng(0)
    docs = rng.integers(0, cfg.vocab, (128, 16)).astype(np.int32)
    rag = RagPipeline.build(eng, docs, pruner="adsampling", retrieve_k=2)

    # batched requests
    batch = {"tokens": rng.integers(0, cfg.vocab, (8, 12)).astype(np.int32)}
    t0 = time.perf_counter()
    out, doc_ids = rag.answer(batch, max_new_tokens=12)
    dt = time.perf_counter() - t0
    print(f"answered 8 requests in {dt*1e3:.0f} ms "
          f"({8*12/dt:.0f} tok/s incl. retrieval)")
    print("retrieved:", doc_ids[:, 0].tolist())
    print("generations shape:", out.shape)

    # sanity: identical query retrieves its own doc
    probe = {"tokens": docs[3:4, :12]}
    ids = rag.retrieve(probe)
    print("self-retrieval check:", "OK" if ids[0, 0] == 3 else f"got {ids[0]}")


if __name__ == "__main__":
    main()
