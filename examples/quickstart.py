"""Quickstart: build a PDX store, search it through the spec/plan API.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core.engine import SearchSpec, SearchStats, VectorSearchEngine
from repro.data.synthetic import ground_truth, make_dataset, recall_at_k


def main():
    # 50K skewed vectors, 128-dim (SIFT-like per the paper's taxonomy)
    X, Q = make_dataset(50_000, 128, "skewed", n_queries=8, seed=0)
    gt_ids, gt_d = ground_truth(X, Q, k=10)
    spec = SearchSpec(k=10)

    # --- exact search with PDX-BOND (no preprocessing, no recall loss) ----
    bond = VectorSearchEngine.build(X, pruner="bond", capacity=4096)
    stats = SearchStats()
    ids, dists = bond.search(Q[0], spec, stats=stats)
    print(f"PDX-BOND exact: recall={recall_at_k(ids[None], gt_ids[:1]):.2f} "
          f"pruning_power={stats.pruning_power:.1%}")

    # --- approximate IVF search with ADSampling ---------------------------
    # Same entry point: the planner routes through the IVF index.
    ads = VectorSearchEngine.build(
        X, index="ivf", pruner="adsampling", capacity=1024
    )
    ivf_spec = spec.replace(nprobe=16)
    recs = []
    for qi, q in enumerate(Q):
        ids, _ = ads.search(q, ivf_spec)
        recs.append(recall_at_k(ids[None], gt_ids[qi : qi + 1]))
    print(f"PDX-ADSampling IVF (nprobe=16): recall={np.mean(recs):.2f}")

    # --- batched queries: same entry point, planner picks the MXU scan ----
    res = bond.search(Q, spec)
    print(f"batched ({res.plan.executor}): "
          f"recall={recall_at_k(res.ids, gt_ids):.2f}")
    print(f"  plan: {res.plan.reason}")

    # --- reduced-precision device scan: 2x fewer bytes, exact results -----
    # The store keeps f32 masters; scan_dtype="bf16" streams a bfloat16
    # device mirror through the fused executors and re-ranks the top
    # rerank_mult*k candidates against the masters, so the returned
    # distances are still exact f32.  (The fused batch executor scans the
    # whole store exactly — hence the higher recall than nprobe=16 above.)
    from repro.core.layout import device_mirror

    ads32 = ads.search(Q, spec.replace(nprobe=16))
    res16 = ads.search(Q, spec.replace(nprobe=16, scan_dtype="bf16"))
    m32 = device_mirror(ads.store, "f32")
    m16 = device_mirror(ads.store, "bf16")
    m8 = device_mirror(ads.store, "int8")
    bytes32 = m32.data.size * m32.bytes_per_value
    bytes16 = m16.data.size * m16.bytes_per_value
    bytes8 = m8.data.size * m8.bytes_per_value
    print(f"bf16 mirror ({res16.plan.executor}): "
          f"recall={recall_at_k(res16.ids, gt_ids):.2f} "
          f"(f32 path: {recall_at_k(ads32.ids, gt_ids):.2f})")
    print(f"  scan bytes/query: {bytes32/1e6:.1f} MB (f32) -> "
          f"{bytes16/1e6:.1f} MB (bf16, {bytes32/bytes16:.1f}x fewer) -> "
          f"{bytes8/1e6:.1f} MB (int8, {bytes32/bytes8:.1f}x fewer)")

    # --- cascaded multi-resolution scan: proj mirror -> int4 -> exact f32 -
    # cascade=(...) declares a stage ladder: a rank-32 PCA projection
    # mirror kills most candidates at 32 of 256 dims, the packed int4
    # mirror (0.5 B/dim) re-checks survivors at full dimensionality with a
    # quantization-inflated (still exact-safe) threshold, and an f32
    # re-rank over every remaining survivor keeps results exact.  Later
    # stages prefetch only the partitions with surviving lanes — at
    # (partition, d-tile) granularity, so a partition stops streaming at
    # the first d-tile where its last lane dies.  The cascade pays off
    # when IVF routing seeds a tight threshold (clustered data), so build
    # that shape here — on it the realized bytes/query land ~5.4x below
    # the one-level int8 fused scan at recall@10 == 1.0 (gated in
    # BENCH_cascade.json).
    #
    # Batches take a different executor: at B > 1 the planner dispatches
    # to cascade-batch, which runs every stage ONCE over the whole batch
    # on the MXU (shared (B, lanes) survivor bitmap, pow2-compacted union
    # gather) instead of looping queries on the host.  Ids and distances
    # are bitwise-equal to the per-query loop; at B=64 it sustains ~3.2x
    # the queries/s of the host loop (BENCH_cascade.json "batched").
    from repro.obs import metrics

    Xc, Qc = make_dataset(16_384, 256, "clustered", n_queries=8, seed=1)
    gtc, _ = ground_truth(Xc, Qc, k=10)
    casc_eng = VectorSearchEngine.build(
        Xc, index="ivf", pruner="adsampling", capacity=256, nlist=64
    )
    casc_spec = spec.replace(cascade=("proj32:int8", "int4", "f32"),
                             kernel="jnp")
    metrics.set_enabled(True)
    try:
        res_c = casc_eng.search(Qc, casc_spec)       # batch -> cascade-batch
        res_1 = casc_eng.search(Qc[0], casc_spec)    # single -> cascade-scan
        reg = metrics.get_registry()
        casc_bytes = reg.sum("repro_device_bytes_total",
                             executor=res_c.plan.executor) / len(Qc)
        surv = [reg.get("repro_cascade_stage_survivors", stage=str(si),
                        stage_name=st) / (len(Qc) + 1)
                for si, st in enumerate(casc_spec.cascade[:-1])]
    finally:
        metrics.set_enabled(False)
    int8_full = float(np.prod(casc_eng.store.data.shape))  # 1 B/value
    print(f"cascade {'->'.join(casc_spec.cascade)} "
          f"(batch: {res_c.plan.executor}, single: {res_1.plan.executor}): "
          f"recall={recall_at_k(res_c.ids, gtc):.2f}")
    print(f"  realized bytes/query: {casc_bytes/1e6:.2f} MB "
          f"(int8 mirror full scan: {int8_full/1e6:.2f} MB, "
          f"{int8_full/casc_bytes:.1f}x fewer); mean survivors/stage: "
          + ", ".join(f"{s:.0f}" for s in surv))

    # --- runtime telemetry: metrics registry + per-query trace spans ------
    # Off by default (zero cost); flip it on (or export REPRO_OBS=1) and
    # every search populates a process-wide registry and a per-call
    # QueryTrace of plan -> route -> scan -> rerank -> merge spans.
    from repro.obs import metrics

    metrics.set_enabled(True)
    try:
        res = ads.search(Q, spec.replace(nprobe=16, scan_dtype="bf16"))
        qt = res.trace
        spans = ", ".join(
            f"{s.name}={s.duration_s*1e3:.1f}ms" for s in qt.spans
        )
        print(f"trace #{qt.trace_id} ({qt.attrs['executor']}): {spans}")
        snap = ads.metrics()               # deterministic dict snapshot
        batches = snap["counters"]["repro_search_batches_total"]
        print(f"registry: search batches by executor = {batches}")
        ads.dump_trace("/tmp/quickstart_trace.json")  # open in ui.perfetto.dev
        print("Perfetto trace -> /tmp/quickstart_trace.json; "
              "Prometheus text via metrics.get_registry().prometheus_text()")
    finally:
        metrics.set_enabled(False)

    # --- tiered serving: stores bigger than HBM -------------------------
    # hbm_slots caps the device-resident quantized mirror at a fixed slot
    # pool of tile-aligned bucket extents; host-RAM f32 masters stay
    # authoritative.  Routing decides which bucket extents each batch
    # needs, prefetches them into the pool (LRU evicting cold buckets),
    # and the exact re-rank runs against the host masters — so recall
    # matches the fully-resident path while the device holds only the
    # routed working set.  Fine-grained buckets (nlist up, capacity down)
    # keep each extent small, so a cache 4x smaller than the mirror still
    # fits any query's routed demand; on a skewed (hot-cluster) workload
    # the warm hit rate stays high.  A two-level centroid tree (tree=True)
    # keeps the routing itself sub-linear in nlist.
    #
    # Cold misses upload asynchronously: BucketCache.ensure is split into
    # issue (evict + start the H2D copies, non-blocking) and wait (install
    # + block once per batch), so chunk N+1's uploads overlap chunk N's
    # scan through the depth-1 pipeline.  On multi-core hosts / device
    # backends a staging worker thread quantizes extents host-side so the
    # wire carries 1-2 bytes/dim instead of f32; on a single-core CPU
    # backend staging degrades to the fused device quantize (same total
    # work, one block per batch instead of one per miss).  Set
    # bc.sync_uploads = True to A/B against the fully synchronous path;
    # bench_tiered.py gates the cold-miss p50 ratio (<= 0.7 with real
    # parallelism, cost parity on one core).  The
    # repro_cache_upload_wait_us histogram and ..._overlap_ratio gauge
    # below show how much of each upload hid behind compute.
    tiered_eng = VectorSearchEngine.build(
        Xc, index="ivf", nlist=256, capacity=64, pruner="linear",
        tree=True,
    )
    Pt = tiered_eng.store.data.shape[0]
    tiered_spec = spec.replace(nprobe=16, scan_dtype="int8",
                               hbm_slots=Pt // 4)
    hot = Qc[:4]                 # a hot working set, like serving traffic
    gt_hot = gtc[:4]
    full = tiered_eng.search(hot, tiered_spec.replace(hbm_slots=None))
    metrics.set_enabled(True)
    try:
        reg = metrics.get_registry()
        res_t = tiered_eng.search(hot, tiered_spec)  # cold: prefetch fills
        h0 = reg.sum("repro_tiered_cache_events_total", event="hit")
        m0 = reg.sum("repro_tiered_cache_events_total", event="miss")
        res_t = tiered_eng.search(hot, tiered_spec)  # warm: set resident
        hits = reg.sum("repro_tiered_cache_events_total", event="hit") - h0
        miss = reg.sum("repro_tiered_cache_events_total", event="miss") - m0
        snap = reg.snapshot()
        up = snap["histograms"].get("repro_cache_upload_wait_us", {}).get("")
        overlap = snap["gauges"].get(
            "repro_cache_upload_overlap_ratio", {}).get("")
    finally:
        metrics.set_enabled(False)
    print(f"tiered ({res_t.plan.executor}, {tiered_spec.hbm_slots} of {Pt} "
          f"tiles resident): recall={recall_at_k(res_t.ids, gt_hot):.2f} "
          f"(fully-resident: {recall_at_k(full.ids, gt_hot):.2f}), "
          f"warm cache hit rate={hits / max(hits + miss, 1):.2f}, "
          f"routing cost {tiered_eng.ivf.routing_cost()} of "
          f"{tiered_eng.ivf.nlist} centroids/query")
    if up and up["count"]:
        print(f"  async uploads: {up['count']:.0f} waits, mean host block "
              f"{up['sum']/up['count']/1e3:.2f}ms, last overlap ratio "
              f"{overlap:.2f} (1.0 = copy fully hidden behind compute)")

    # --- online serving: continuous batching over the same engine ---------
    # VectorServer coalesces async submissions into pow2 compiled-shape
    # batches (warmup() pre-compiles every bucket, so a drifting arrival
    # rate mints no new executables), applies deadline/backpressure at the
    # admission queue, and runs store maintenance (repack) on a background
    # thread behind a version fence.  submit() returns a Future; queue
    # wait shows up as a "queue" span on the query's trace.
    from repro.serve import VectorServer

    metrics.set_enabled(True)
    try:
        serve_spec = spec.replace(executor="batch-matmul")
        with VectorServer(bond, spec=serve_spec, max_batch=16,
                          maintenance_interval_s=0.5) as server:
            server.warmup()
            futures = [server.submit(q) for q in Q]       # async fan-in
            ids0, _ = futures[0].result()
            new_ids = server.insert(X[:2] + 0.01).result()  # live mutation
            print(f"served {len(futures)} async queries "
                  f"(top-1 of q0 = {ids0[0]}), inserted ids {new_ids.tolist()}, "
                  f"compiles after warmup = {server.jit_compiles_since_warmup()}")
            snap = server.metrics()
            hist = snap["histograms"]["repro_serve_queue_wait_seconds"][""]
            print(f"queue wait: {hist['count']} queries, "
                  f"mean {hist['sum']/hist['count']*1e3:.2f}ms; depth gauge = "
                  f"{snap['gauges']['repro_serve_queue_depth']['']:.0f}")
            qt = bond.dump_trace()["traceEvents"]
            print(f"trace ring now holds served-query spans "
                  f"({sum(1 for e in qt if e['name'] == 'queue')} queue spans)")
    finally:
        metrics.set_enabled(False)


if __name__ == "__main__":
    main()
