"""Quickstart: build a PDX store, search it through the spec/plan API.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core.engine import SearchSpec, SearchStats, VectorSearchEngine
from repro.data.synthetic import ground_truth, make_dataset, recall_at_k


def main():
    # 50K skewed vectors, 128-dim (SIFT-like per the paper's taxonomy)
    X, Q = make_dataset(50_000, 128, "skewed", n_queries=8, seed=0)
    gt_ids, gt_d = ground_truth(X, Q, k=10)
    spec = SearchSpec(k=10)

    # --- exact search with PDX-BOND (no preprocessing, no recall loss) ----
    bond = VectorSearchEngine.build(X, pruner="bond", capacity=4096)
    stats = SearchStats()
    ids, dists = bond.search(Q[0], spec, stats=stats)
    print(f"PDX-BOND exact: recall={recall_at_k(ids[None], gt_ids[:1]):.2f} "
          f"pruning_power={stats.pruning_power:.1%}")

    # --- approximate IVF search with ADSampling ---------------------------
    # Same entry point: the planner routes through the IVF index.
    ads = VectorSearchEngine.build(
        X, index="ivf", pruner="adsampling", capacity=1024
    )
    ivf_spec = spec.replace(nprobe=16)
    recs = []
    for qi, q in enumerate(Q):
        ids, _ = ads.search(q, ivf_spec)
        recs.append(recall_at_k(ids[None], gt_ids[qi : qi + 1]))
    print(f"PDX-ADSampling IVF (nprobe=16): recall={np.mean(recs):.2f}")

    # --- batched queries: same entry point, planner picks the MXU scan ----
    res = bond.search(Q, spec)
    print(f"batched ({res.plan.executor}): "
          f"recall={recall_at_k(res.ids, gt_ids):.2f}")
    print(f"  plan: {res.plan.reason}")


if __name__ == "__main__":
    main()
