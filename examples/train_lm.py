"""End-to-end driver: train a ~100M-parameter decoder LM for a few hundred
steps on the synthetic pipeline, with checkpoint/resume.

    PYTHONPATH=src python examples/train_lm.py [--steps 300]

The config is a scaled llama3.2 family member (~100M params: 12L x 512d,
vocab 32k); loss must decrease.  Uses the exact same train loop the launcher
exposes for the assigned architectures.
"""
import argparse
import dataclasses

from repro.configs import get_config
from repro.launch.train import train_loop


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    # ~100M params: 12 x (4*512^2 + 3*512*2048) + 32000*512*2 ≈ 84M
    base = get_config("llama3.2-3b")
    cfg = dataclasses.replace(
        base, name="llama-100m", n_layers=12, d_model=512, n_heads=8,
        n_kv_heads=4, head_dim=64, d_ff=2048, vocab=32_000,
    )
    from repro.configs.base import _REGISTRY

    _REGISTRY["llama-100m"] = lambda: cfg
    out = train_loop(
        "llama-100m", reduced=False, steps=args.steps, batch=8, seq=256,
        lr=3e-4, ckpt_dir=args.ckpt_dir, ckpt_every=100,
    )
    print(f"final loss {out['final_loss']:.3f} "
          f"(start {out['history'][0]:.3f}); "
          f"median step {out['median_step_s']*1e3:.0f} ms")
    assert out["final_loss"] < out["history"][0], "loss did not decrease"


if __name__ == "__main__":
    main()
