"""SearchSpec / planner / executor tests: dispatch rules, cross-executor
agreement against brute-force ground truth (including the sharded executors
under 8 fake CPU devices, in subprocesses — see tests/test_dist.py for why),
the one-collective-per-batch guarantee, and the bounded fingerprint-keyed
exec cache."""
import dataclasses

import numpy as np
import pytest

from repro.core.engine import SearchSpec, VectorSearchEngine
from repro.core.plan import executor_names, plan_search
from repro.core.pruners import make_adsampling, make_bond
from repro.data.synthetic import ground_truth, make_dataset, recall_at_k

from test_dist import run_devices


# ------------------------------------------------------------------ SearchSpec
def test_spec_is_frozen_and_validated():
    spec = SearchSpec(k=5)
    with pytest.raises(dataclasses.FrozenInstanceError):
        spec.k = 7
    assert spec.replace(k=7).k == 7 and spec.k == 5
    for bad in (
        dict(k=0), dict(metric="cosine"), dict(schedule="geometric"),
        dict(sel_frac=0.0), dict(sel_frac=1.5), dict(nprobe=0),
        dict(delta_d=0), dict(group=0), dict(routing="unicast"),
    ):
        with pytest.raises(ValueError):
            SearchSpec(**bad)


def test_search_result_unpacks_like_tuple():
    X, Q = make_dataset(500, 16, "normal", n_queries=2, seed=0)
    eng = VectorSearchEngine.build(X, pruner="linear", capacity=128)
    res = eng.search(Q[0], SearchSpec(k=3))
    ids, dists = res
    assert ids is res.ids and dists is res.dists
    assert res[0] is res.ids and res[1] is res.dists and len(res) == 2
    assert res.plan.executor in executor_names()


# --------------------------------------------------------------- planner rules
class _FakeMesh:
    """Duck-typed mesh for planner unit tests (no devices needed)."""

    def __init__(self, **axes):
        self.axis_names = tuple(axes)
        self.shape = dict(axes)


def _store(n=512, dim=32, cap=64):
    X, _ = make_dataset(n, dim, "normal", n_queries=1, seed=0)
    return VectorSearchEngine.build(X, pruner="linear", capacity=cap).store


def test_planner_dispatch_rules():
    spec = SearchSpec(k=5)
    store = _store()  # 8 partitions, D=32

    assert plan_search(spec, store, 1).executor == "adaptive"
    assert plan_search(spec, store, 8).executor == "batch-matmul"
    assert plan_search(spec.replace(prefer_static=True), store, 1).executor \
        == "jit-masked"

    data_mesh = _FakeMesh(data=8)
    assert plan_search(spec, store, 1, mesh=data_mesh).executor \
        == "block-sharded"
    assert plan_search(spec, store, 4, mesh=data_mesh).executor \
        == "batch-block-sharded"
    assert plan_search(
        spec.replace(batch_collectives=False), store, 4, mesh=data_mesh
    ).executor == "block-sharded"
    assert plan_search(spec, store, 1, mesh=_FakeMesh(model=8)).executor \
        == "dim-sharded"

    # indivisible mesh axes fall back to host executors, with the reason
    p = plan_search(spec, store, 1, mesh=_FakeMesh(data=7))
    assert p.executor == "adaptive" and "not divisible" in p.reason
    p = plan_search(spec, store, 4, mesh=_FakeMesh(model=7))
    assert p.executor == "batch-matmul" and "not divisible" in p.reason

    # IVF + 'data' mesh routes by bucket ownership; "broadcast" opts out
    # (full routed-executor coverage lives in tests/test_routing.py)
    ivf = object()
    p = plan_search(spec, store, 4, ivf=ivf, mesh=data_mesh)
    assert p.executor == "routed_bucket" and "bucket-owned" in p.reason
    p = plan_search(spec.replace(routing="broadcast"), store, 4, ivf=ivf,
                    mesh=data_mesh)
    assert p.executor == "adaptive" and "broadcast" in p.reason
    assert plan_search(spec, store, 4, ivf=ivf).executor == "adaptive"

    # forced executor wins over everything
    p = plan_search(spec.replace(executor="jit-masked"), store, 4,
                    mesh=data_mesh)
    assert p.executor == "jit-masked" and "forced" in p.reason
    with pytest.raises(ValueError, match="unknown executor"):
        plan_search(spec.replace(executor="warp-drive"), store, 1)


def test_plan_trace_records_pruner_fingerprint():
    X, Q = make_dataset(400, 16, "normal", n_queries=1, seed=1)
    eng = VectorSearchEngine.build(X, pruner="bond", capacity=128)
    res = eng.search(Q[0], SearchSpec(k=3))
    assert res.plan.pruner == eng.pruner.fingerprint
    assert res.plan.pruner.startswith("bond:")


# ----------------------------------------- executor agreement (host executors)
HOST_CASES = [
    ("flat", "linear"),
    ("flat", "bond"),
    ("ivf", "linear"),
]


@pytest.mark.parametrize("index,pruner", HOST_CASES)
def test_host_executors_match_ground_truth(index, pruner):
    """Every host executor the planner can pick returns brute-force top-k
    for exact pruners — single query and batch, flat and IVF."""
    X, Q = make_dataset(1536, 24, "clustered", n_queries=4, seed=21)
    gt_ids, gt_d = ground_truth(X, Q, k=5)
    nlist = 8
    eng = VectorSearchEngine.build(
        X, index=index, pruner=pruner, capacity=128, nlist=nlist,
    )
    # full probe makes IVF exact; flat ignores nprobe
    spec = SearchSpec(k=5, nprobe=nlist)
    executors = ["adaptive"]
    if index == "flat":
        executors += ["jit-masked", "batch-matmul"]
    else:
        executors += ["batch-matmul"]  # exact full scan over all buckets
    for ex in executors:
        res = eng.search(Q, spec.replace(executor=ex))
        assert res.plan.executor == ex
        assert recall_at_k(res.ids, gt_ids) == 1.0, (ex, res.ids)
        np.testing.assert_allclose(
            np.sort(res.dists, axis=1), np.sort(gt_d, axis=1),
            rtol=1e-3, atol=1e-2,
        )
        # single-query form agrees with the batch form
        res1 = eng.search(Q[0], spec.replace(executor=ex))
        assert res1.ids.shape == (5,)
        assert set(res1.ids.tolist()) == set(np.asarray(res.ids[0]).tolist())


def test_batch_entry_point_vmaps_query_transform():
    """Projection pruners transform batches via one vmapped transform; the
    batched executor must match per-query transforms exactly."""
    X, Q = make_dataset(1024, 32, "normal", n_queries=6, seed=3)
    gt_ids, _ = ground_truth(X, Q, k=5)
    eng = VectorSearchEngine.build(X, pruner="adsampling", capacity=256)
    res = eng.search(Q, SearchSpec(k=5))
    assert res.plan.executor == "batch-matmul"
    assert recall_at_k(res.ids, gt_ids) == 1.0  # exact: batch path never prunes


# --------------------------------------------- sharded executors (8 fake CPUs)
def test_sharded_executors_match_ground_truth_8dev():
    run_devices("""
    from repro.core.engine import SearchSpec, VectorSearchEngine
    from repro.data.synthetic import make_dataset, ground_truth, recall_at_k

    X, Q = make_dataset(2048, 64, "normal", n_queries=4, seed=0)
    gt_ids, gt_d = ground_truth(X, Q, k=5)
    spec = SearchSpec(k=5)

    # data mesh: 16 partitions over 8 shards
    mesh = jax.make_mesh((8,), ("data",))
    eng = VectorSearchEngine.build(X, pruner="linear", capacity=128, mesh=mesh)
    r1 = eng.search(Q[0], spec)
    assert r1.plan.executor == "block-sharded", r1.plan
    rb = eng.search(Q, spec)
    assert rb.plan.executor == "batch-block-sharded", rb.plan
    rq = eng.search(Q, spec.replace(batch_collectives=False))
    assert rq.plan.executor == "block-sharded", rq.plan
    for r in (rb, rq):
        assert recall_at_k(r.ids, gt_ids) == 1.0
        np.testing.assert_allclose(np.sort(r.dists, axis=1),
                                   np.sort(gt_d, axis=1), rtol=1e-3, atol=1e-2)
    assert set(r1.ids.tolist()) == set(gt_ids[0].tolist())

    # model mesh: D=64 over 8 shards, with a projection pruner
    meshm = jax.make_mesh((8,), ("model",))
    engm = VectorSearchEngine.build(X, pruner="adsampling", capacity=128,
                                    mesh=meshm)
    rm = engm.search(Q[0], spec)
    assert rm.plan.executor == "dim-sharded", rm.plan
    assert set(rm.ids.tolist()) == set(gt_ids[0].tolist())
    print("OK")
    """)


def test_batched_executor_one_allgather_per_batch_8dev():
    """Acceptance gate: the fused batched executor issues exactly ONE top-k
    all-gather per query batch (dists+ids packed), independent of B, while
    the per-query path pays two per query."""
    run_devices("""
    from repro.core.layout import build_flat_store
    from repro.data.synthetic import make_dataset
    from repro.dist.pdx_sharded import (collective_counts,
                                        search_batch_block_sharded,
                                        search_block_sharded)

    X, Q = make_dataset(2048, 32, "normal", n_queries=16, seed=0)
    store = build_flat_store(X, capacity=128)
    mesh = jax.make_mesh((8,), ("data",))
    d, i = store.data, store.ids
    for B in (2, 4, 16):
        counts = collective_counts(
            lambda dd, ii, qq: search_batch_block_sharded(mesh, dd, ii, qq, 5),
            d, i, jnp.asarray(Q[:B]))
        assert counts == {"all_gather": 1}, (B, counts)
    per_q = collective_counts(
        lambda dd, ii, qq: search_block_sharded(mesh, dd, ii, qq, 5),
        d, i, jnp.asarray(Q[0]))
    assert per_q.get("all_gather") == 2, per_q
    print("OK")
    """)


# ------------------------------------------------------------------ exec cache
def test_exec_cache_fingerprint_keyed_and_bounded():
    from repro.core.pdxearch import _EXEC_CACHE, _EXEC_CACHE_MAX, _get_exec

    # identical params => identical fingerprint => shared cache entry
    a1 = make_adsampling(16, eps0=2.1, seed=0)
    a2 = make_adsampling(16, eps0=2.1, seed=0)
    assert a1 is not a2 and a1.fingerprint == a2.fingerprint
    assert _get_exec(a1, "l2") is _get_exec(a2, "l2")
    # different params => distinct entries
    assert make_adsampling(16, eps0=3.0, seed=0).fingerprint != a1.fingerprint
    assert make_adsampling(16, eps0=2.1, seed=1).fingerprint != a1.fingerprint

    # the cache stays bounded no matter how many pruners come and go
    rng = np.random.default_rng(0)
    for _ in range(2 * _EXEC_CACHE_MAX + 3):
        pr = make_bond(rng.standard_normal(8).astype(np.float32))
        _get_exec(pr, "l2")
        assert len(_EXEC_CACHE) <= _EXEC_CACHE_MAX
    # LRU: the most recent entry survived (version 0 = frozen store)
    assert (pr.fingerprint, "l2", 0) in _EXEC_CACHE


# --------------------------------------------------- legacy surface is gone
def test_deprecated_shims_removed_and_legacy_call_shapes_work():
    X, Q = make_dataset(600, 16, "normal", n_queries=3, seed=5)
    gt_ids, _ = ground_truth(X, Q, k=4)
    eng = VectorSearchEngine.build(X, pruner="linear", capacity=128)
    # PR 2's DeprecationWarning shims were removed: search() is the only door
    assert not hasattr(eng, "search_batch")
    assert not hasattr(eng, "search_jit")
    ids, dists = eng.search(Q, k=4)
    assert ids.shape == (3, 4) and recall_at_k(ids, gt_ids) == 1.0
    # legacy kwarg/positional call shapes on the unified entry point
    ids, dists = eng.search(Q[0], 4)
    assert ids.shape == (4,)
    ids, dists = eng.search(Q[0], np.int64(4))  # k computed from array shapes
    assert ids.shape == (4,)
    ids, dists = eng.search(Q[0], k=4)
    assert set(ids.tolist()) == set(gt_ids[0].tolist())


def test_directly_constructed_pruners_never_share_cache_entries():
    import jax.numpy as jnp

    from repro.core.pruners import Pruner

    def mk(keep):
        return Pruner(
            name="custom", is_exact=True, needs_preprocess=False,
            preprocess=lambda X: X, transform_query=lambda q: q,
            keep_mask=keep,
        )

    a = mk(lambda partial, d, thr: jnp.ones_like(partial, dtype=bool))
    b = mk(lambda partial, d, thr: partial <= thr)
    assert a.fingerprint != b.fingerprint  # no factory => unique fallback


def test_stats_populated_on_forced_non_adaptive_executor():
    # every executor now fills the SearchStats work account (exact scans
    # report computed == total); the old adaptive-pinning warning is gone
    from repro.core.pdxearch import SearchStats

    X, Q = make_dataset(400, 16, "normal", n_queries=2, seed=8)
    eng = VectorSearchEngine.build(X, pruner="linear", capacity=128)
    stats = SearchStats()
    import warnings

    with warnings.catch_warnings():
        warnings.simplefilter("error")
        eng.search(Q, SearchSpec(k=3, executor="batch-matmul"), stats=stats)
    total = float(np.asarray(eng.store.counts).sum()) * eng.store.dim * len(Q)
    assert stats.values_total == total
    assert stats.values_computed == total      # exact scan: nothing avoided
    assert stats.values_avoided == 0.0
    assert stats.partitions_visited == eng.store.data.shape[0] * len(Q)
