"""Cascaded multi-resolution scan: the int4 packed mirror (roundtrip error
bound on skewed data, packed/unpacked parity), the projection mirror's
exact-safe lower bound + caching, the cascade stage grammar and spec
validation, planner dispatch, the cascade-scan executor's Pallas(interpret)
== jnp parity and exact recall at non-aligned D/V with PAD lanes, and
quantized centroid routing parity with f32 routing."""
import numpy as np
import pytest

from repro.core.engine import SearchSpec, VectorSearchEngine
from repro.core.layout import device_mirror, projection_mirror, unpack_int4
from repro.core.plan import plan_search
from repro.core.spec import parse_cascade_stage
from repro.data.synthetic import ground_truth, make_dataset, recall_at_k
from repro.kernels.ref import dequantize_ref


# ------------------------------------------------------------ stage grammar
def test_cascade_stage_grammar():
    assert parse_cascade_stage("f32") == ("exact", "f32", 0)
    assert parse_cascade_stage("int8") == ("scan", "int8", 0)
    assert parse_cascade_stage("int4") == ("scan", "int4", 0)
    assert parse_cascade_stage("bf16") == ("scan", "bf16", 0)
    assert parse_cascade_stage("proj32") == ("proj", "f32", 32)
    assert parse_cascade_stage("proj16:int4") == ("proj", "int4", 16)
    for bad in ("fp8", "proj0", "proj:int8", "projx:int8", "proj8:fp8",
                "f64", ""):
        with pytest.raises(ValueError, match="bad cascade stage"):
            parse_cascade_stage(bad)


def test_spec_validates_cascade():
    # well-formed cascades construct
    SearchSpec(cascade=("proj32:int8", "int4", "f32"))
    SearchSpec(cascade=("int8", "f32"), route_dtype="int8")
    cases = [
        dict(cascade=("f32",)),                      # too short
        dict(cascade="int8,f32"),                    # not a tuple
        dict(cascade=("int8", "int4")),              # missing terminator
        dict(cascade=("f32", "int8", "f32")),        # f32 not terminal
        dict(cascade=("int8", "proj16", "f32")),     # proj not first
        dict(cascade=("int8", "int8", "f32")),       # duplicate stage
        dict(cascade=("int8", "f32"), metric="ip"),  # L2 only
        dict(route_dtype="fp8"),                     # bad routing dtype
    ]
    for bad in cases:
        with pytest.raises(ValueError):
            SearchSpec(**bad)


# ------------------------------------------------------------- int4 mirror
def test_int4_mirror_roundtrip_error_bounded():
    """15-level observed-range affine on heavy-tailed data: live-value
    reconstruction error is at most half a quantization step, and the
    packed payload is half the dimension bytes."""
    X, _ = make_dataset(2000, 17, "skewed", n_queries=1, seed=3)  # odd D too
    eng = VectorSearchEngine.build(X, pruner="linear", capacity=256)
    m = device_mirror(eng.store, "int4")
    assert m.packed and m.quantized and m.bytes_per_value == 0.5
    assert m.dim == 17 and m.data.shape[1] == 9  # ceil(17 / 2) packed bytes
    assert m.data.dtype == np.uint8

    T = np.asarray(eng.store.data)
    live = np.asarray(eng.store.ids) >= 0
    levels = np.asarray(unpack_int4(m.data, dim_axis=1, dim=m.dim), np.float32)
    deq = (levels * np.asarray(m.scale)[None, :, None]
           + np.asarray(m.offset)[None, :, None])
    err = np.abs(deq - T)[np.broadcast_to(live[:, None, :], T.shape)]
    step = np.asarray(m.scale).max()  # = per-dim absmax / 7
    assert err.max() <= step / 2 + 1e-5  # no clipping, ever


def test_int4_packed_unpacked_parity():
    """``dequantize_ref(packed=True)`` == unpack-then-affine, on both tile
    layouts the kernels use ((D, V) single tile and (P, D, V) stacks)."""
    X, _ = make_dataset(900, 21, "normal", n_queries=1, seed=5)
    eng = VectorSearchEngine.build(X, pruner="linear", capacity=128)
    m = device_mirror(eng.store, "int4")
    want = (np.asarray(unpack_int4(m.data, dim_axis=1, dim=m.dim), np.float32)
            * np.asarray(m.scale)[None, :, None]
            + np.asarray(m.offset)[None, :, None])
    got = np.asarray(dequantize_ref(m.data, m.scale, m.offset, dim_axis=1,
                                    packed=True, dim=m.dim))
    np.testing.assert_array_equal(got, want)
    got0 = np.asarray(dequantize_ref(m.data[0], m.scale, m.offset,
                                     dim_axis=0, packed=True, dim=m.dim))
    np.testing.assert_array_equal(got0, want[0])


# ------------------------------------------------------- projection mirror
def test_projection_mirror_cache_and_lower_bound():
    X, Q = make_dataset(1200, 32, "clustered", n_queries=4, seed=7)
    eng = VectorSearchEngine.build(X, pruner="linear", capacity=128)
    m = projection_mirror(eng.store, 8)
    assert m.rank == 8 and m.data.shape[1] == 8
    # cached per version; the PCA fit is shared across rank/dtype variants
    assert projection_mirror(eng.store, 8) is m
    m4 = projection_mirror(eng.store, 8, "int4")
    assert m4 is not m and m4.packed and m4.data.shape[1] == 4
    assert ("comps", 0) in eng.store._proj_cache
    with pytest.raises(ValueError, match="rank"):
        projection_mirror(eng.store, 64)

    # orthonormal columns: projected L2 lower-bounds the full L2 for every
    # query/vector pair — the cascade's exact-safe keep test rests on this
    C = np.asarray(m.components)
    np.testing.assert_allclose(C.T @ C, np.eye(8), atol=1e-4)
    P = np.asarray(m.data)          # (P, rank, C) projected tiles
    live = np.asarray(eng.store.ids) >= 0
    T = np.asarray(eng.store.data)  # (P, D, C) masters
    for q in Q:
        qp = q @ C
        d_proj = ((P - qp[None, :, None]) ** 2).sum(axis=1)
        d_full = ((T - q[None, :, None]) ** 2).sum(axis=1)
        assert np.all(d_proj[live] <= d_full[live] + 1e-2)


# ---------------------------------------------------------------- planner
def test_cascade_planner_dispatch():
    X, _ = make_dataset(512, 16, "normal", n_queries=1, seed=1)
    store = VectorSearchEngine.build(X, pruner="linear", capacity=128).store
    spec = SearchSpec(k=5, cascade=("proj8:int8", "int4", "f32"))
    p = plan_search(spec, store, 1)
    assert p.executor == "cascade-scan"
    assert "proj8:int8" in p.reason and "cascade" in p.reason
    p = plan_search(spec, store, 4)  # batches go MXU-native
    assert p.executor == "cascade-batch"
    assert "proj8:int8" in p.reason
    # no cascade -> the single-level dispatch is untouched
    assert plan_search(SearchSpec(k=5), store, 1).executor == "adaptive"


# ----------------------------------------------------- executor correctness
CASCADES = [
    ("proj16:int8", "int4", "f32"),
    ("proj16:int4", "int8", "f32"),
    ("int8", "int4", "f32"),
    ("bf16", "int8", "f32"),
]


@pytest.mark.parametrize("cascade", CASCADES, ids=lambda c: "→".join(c))
def test_cascade_exact_and_kernel_parity_on_nonaligned_store(cascade):
    """cascade-batch (B=4 dispatch) vs brute-force ground truth at
    non-aligned D (50) with PAD lanes (1900 % 256 != 0): recall@k == 1.0
    after the f32 re-rank on BOTH kernel bodies, and the Pallas(interpret)
    ids match the jnp twin exactly (same survivors -> same re-rank
    candidates)."""
    X, Q = make_dataset(1900, 50, "clustered", n_queries=4, seed=7)
    gt_ids, gt_d = ground_truth(X, Q, k=5)
    eng = VectorSearchEngine.build(X, pruner="adsampling", capacity=256)
    base = SearchSpec(k=5, cascade=cascade)

    res_j = eng.search(Q, base.replace(kernel="jnp"))
    assert res_j.plan.executor == "cascade-batch", res_j.plan
    assert recall_at_k(res_j.ids, gt_ids) == 1.0, (cascade, res_j.ids)
    np.testing.assert_allclose(  # re-ranked distances are exact f32
        np.sort(res_j.dists, axis=1), np.sort(gt_d, axis=1),
        rtol=1e-4, atol=1e-3,
    )
    res_p = eng.search(Q, base.replace(kernel="pallas"))
    np.testing.assert_array_equal(res_p.ids, res_j.ids)


@pytest.mark.parametrize("kernel", ["jnp", "pallas"])
def test_cascade_batch_matches_per_query_bitwise(kernel):
    """Forcing the per-query host-loop executor on the same engine and
    queries returns bitwise-identical ids and distances to the batched
    executor: the batch path only restructures the stage ladder (shared
    bitmap, compacted gather), never the survivor set or the exact f32
    re-rank."""
    X, Q = make_dataset(1900, 50, "clustered", n_queries=6, seed=11)
    eng = VectorSearchEngine.build(X, pruner="adsampling", capacity=256)
    for cascade in [("int8", "f32"), ("proj8:int8", "int4", "f32")]:
        base = SearchSpec(k=5, cascade=cascade, kernel=kernel)
        res_b = eng.search(Q, base)
        assert res_b.plan.executor == "cascade-batch", res_b.plan
        res_s = eng.search(Q, base.replace(executor="cascade-scan"))
        assert res_s.plan.executor == "cascade-scan", res_s.plan
        np.testing.assert_array_equal(res_b.ids, res_s.ids)
        np.testing.assert_array_equal(
            np.asarray(res_b.dists), np.asarray(res_s.dists)
        )


def test_cascade_on_ivf_store_with_quantized_routing():
    """With an IVF engine the cascade seeds its threshold from the routed
    nearest bucket — through a quantized centroid scan when asked — and
    still returns the true top-k."""
    X, Q = make_dataset(2048, 32, "clustered", n_queries=3, seed=4)
    gt_ids, _ = ground_truth(X, Q, k=5)
    eng = VectorSearchEngine.build(
        X, index="ivf", pruner="adsampling", capacity=128, nlist=8,
    )
    for rdt in ("f32", "int8", "int4"):
        spec = SearchSpec(k=5, cascade=("proj8:int8", "int4", "f32"),
                          kernel="jnp", route_dtype=rdt)
        res = eng.search(Q, spec)
        assert res.plan.executor == "cascade-batch", res.plan
        assert recall_at_k(res.ids, gt_ids) == 1.0, rdt


def test_cascade_rejects_non_l2_at_the_spec():
    with pytest.raises(ValueError, match="L2-only"):
        SearchSpec(k=3, metric="l1", cascade=("int8", "f32"))


# ------------------------------------------------ quantized centroid routing
def test_quantized_centroid_routing_parity():
    """Centroid routing through the int8/int4 centroid mirror selects the
    same nearest bucket as f32 routing on well-separated clusters, and a
    full-probe search with quantized routing stays exact."""
    X, Q = make_dataset(2048, 32, "clustered", n_queries=6, seed=0)
    nlist = 16
    eng = VectorSearchEngine.build(
        X, index="ivf", pruner="linear", capacity=64, nlist=nlist,
    )
    gt_ids, _ = ground_truth(X, Q, k=5)
    import jax.numpy as jnp

    sel_f32 = np.asarray(eng.ivf.route_batch(jnp.asarray(Q), 1))
    for rdt in ("int8", "int4"):
        sel_q = np.asarray(eng.ivf.route_batch(jnp.asarray(Q), 1, "l2", rdt))
        np.testing.assert_array_equal(sel_q, sel_f32)
        res = eng.search(
            Q, SearchSpec(k=5, nprobe=nlist, route_dtype=rdt,
                          executor="adaptive"),
        )
        assert recall_at_k(res.ids, gt_ids) == 1.0, rdt
