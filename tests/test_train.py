"""Training substrate: optimizer math, compression, checkpointing (incl.
elastic restore + async), straggler monitor, resumable data pipeline, and a
short end-to-end loss-goes-down run."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.data.pipeline import Prefetcher, TokenStream
from repro.models.lm import build_model
from repro.train import checkpoint as ckpt
from repro.train.compression import dequantize, ef_compress, ef_init, quantize
from repro.train.optimizer import OptConfig, global_norm, opt_init, opt_update
from repro.train.straggler import Heartbeat, StepTimeMonitor
from repro.train.trainer import TrainConfig, make_train_step


def _tiny_model():
    cfg = get_config("llama3.2-3b").reduced()
    return cfg, build_model(cfg)


def test_adamw_decreases_quadratic():
    params = {"w": jnp.asarray([4.0, -3.0])}
    oc = OptConfig(lr=0.1, weight_decay=0.0, warmup_steps=0)
    state = opt_init(params, oc)
    loss = lambda p: jnp.sum(p["w"] ** 2)
    val0 = float(loss(params))
    for _ in range(50):
        g = jax.grad(loss)(params)
        params, state, _ = opt_update(g, state, params, oc)
    assert float(loss(params)) < val0 * 0.1


def test_quantize_roundtrip_error_bounded(rng):
    g = jnp.asarray(rng.standard_normal(1000).astype(np.float32))
    q, s = quantize(g)
    assert q.dtype == jnp.int8
    err = np.abs(np.asarray(dequantize(q, s)) - np.asarray(g))
    assert err.max() <= float(s) * 0.5 + 1e-7


def test_error_feedback_preserves_signal(rng):
    """Sum of compressed grads over steps tracks sum of raw grads."""
    gs = [jnp.asarray(rng.standard_normal(64).astype(np.float32) * 0.01) for _ in range(20)]
    ef = ef_init({"g": gs[0]})
    tot_c = np.zeros(64)
    tot_r = np.zeros(64)
    for g in gs:
        out, ef = ef_compress({"g": g}, ef)
        tot_c += np.asarray(out["g"])
        tot_r += np.asarray(g)
    # residual carries what compression lost
    final_err = np.abs(tot_c + np.asarray(ef["g"]) - tot_r)
    assert final_err.max() < 1e-4


def test_train_loop_loss_decreases(tmp_path):
    cfg, model = _tiny_model()
    oc = OptConfig(lr=1e-2, warmup_steps=0)
    step_fn = jax.jit(make_train_step(model, TrainConfig(opt=oc)))
    params = model.init(jax.random.key(0))
    state = opt_init(params, oc)
    stream = TokenStream(cfg, seq_len=16, batch=4, seed=0)
    batch0 = {k: jnp.asarray(v) for k, v in stream.batch_at(0).items()}
    losses = []
    for i in range(20):
        b = {k: jnp.asarray(v) for k, v in stream.batch_at(0).items()}  # overfit one batch
        params, state, m = step_fn(params, state, b)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.5, losses


def test_grad_accum_matches_full_batch():
    cfg, model = _tiny_model()
    oc = OptConfig(lr=1e-3, warmup_steps=0)
    params = model.init(jax.random.key(1))
    stream = TokenStream(cfg, seq_len=16, batch=8, seed=3)
    b = {k: jnp.asarray(v) for k, v in stream.batch_at(0).items()}
    s1 = opt_init(params, oc)
    p1, _, m1 = jax.jit(make_train_step(model, TrainConfig(opt=oc)))(params, s1, b)
    s2 = opt_init(params, oc)
    p2, _, m2 = jax.jit(make_train_step(model, TrainConfig(opt=oc, accum_steps=4)))(
        params, s2, b
    )
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]), rtol=1e-4)
    d = global_norm(jax.tree.map(lambda a, b: a - b, p1, p2))
    assert float(d) < 1e-3


def test_checkpoint_roundtrip_and_retention(tmp_path):
    tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3), "b": {"c": jnp.ones(4)}}
    root = str(tmp_path / "ck")
    for s in [1, 2, 3, 4, 5]:
        ckpt.save(root, s, tree, keep=2)
    assert ckpt.all_steps(root) == [4, 5]
    step, restored = ckpt.restore(root, tree)
    assert step == 5
    np.testing.assert_array_equal(np.asarray(restored["a"]), np.asarray(tree["a"]))


def test_async_checkpointer(tmp_path):
    root = str(tmp_path / "ck")
    ac = ckpt.AsyncCheckpointer(root)
    tree = {"w": jnp.full((8,), 7.0)}
    ac.save(10, tree)
    ac.wait()
    step, restored = ckpt.restore(root, tree)
    assert step == 10
    np.testing.assert_array_equal(np.asarray(restored["w"]), np.full((8,), 7.0))


def test_checkpoint_resume_is_bitexact(tmp_path):
    """Kill-and-restart: resumed run == uninterrupted run (fault tolerance)."""
    cfg, model = _tiny_model()
    oc = OptConfig(lr=1e-2, warmup_steps=0)
    step_fn = jax.jit(make_train_step(model, TrainConfig(opt=oc)))
    stream = TokenStream(cfg, seq_len=16, batch=2, seed=1)

    def run(n, params, state, start=0):
        for i in range(start, n):
            b = {k: jnp.asarray(v) for k, v in stream.batch_at(i).items()}
            params, state, _ = step_fn(params, state, b)
        return params, state

    p0 = model.init(jax.random.key(0))
    s0 = opt_init(p0, oc)
    p_full, s_full = run(6, p0, s0)

    p_half, s_half = run(3, p0, s0)
    root = str(tmp_path / "ck")
    ckpt.save(root, 3, {"params": p_half, "opt": s_half})
    step, restored = ckpt.restore(root, {"params": p_half, "opt": s_half})
    p_res, s_res = run(6, restored["params"], restored["opt"], start=step)
    d = global_norm(jax.tree.map(lambda a, b: a - b, p_full, p_res))
    assert float(d) == 0.0


def test_straggler_monitor_flags_outlier():
    m = StepTimeMonitor(window=32, factor=2.0)
    import time

    for _ in range(10):
        m.start()
        time.sleep(0.001)
        m.stop()
    m.start()
    time.sleep(0.05)
    _, slow = m.stop()
    assert slow and m.flagged == 1


def test_heartbeat_stale_detection(tmp_path):
    hb0 = Heartbeat(str(tmp_path), 0, timeout=1.0)
    hb1 = Heartbeat(str(tmp_path), 1, timeout=1.0)
    hb0.beat()
    hb1.beat()
    assert hb0.stale_hosts() == []
    assert hb0.stale_hosts(now=os.path.getmtime(str(tmp_path)) + 10_000) == [0, 1]


def test_pipeline_deterministic_and_prefetch():
    cfg, _ = _tiny_model()
    s1 = TokenStream(cfg, 16, 2, seed=9)
    s2 = TokenStream(cfg, 16, 2, seed=9)
    np.testing.assert_array_equal(s1.batch_at(5)["tokens"], s2.batch_at(5)["tokens"])
    pf = Prefetcher(s1.iter_from(0), depth=2)
    b0 = pf.next()
    np.testing.assert_array_equal(b0["tokens"], s2.batch_at(0)["tokens"])
    b1 = pf.next()
    np.testing.assert_array_equal(b1["tokens"], s2.batch_at(1)["tokens"])
    pf.close()
