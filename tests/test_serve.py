"""Serving engine + RAG pipeline tests (tiny model, CPU)."""
import jax
import numpy as np

from repro.configs import get_config
from repro.models.lm import build_model
from repro.serve.engine import GenerationEngine
from repro.serve.rag import RagPipeline


def _engine(arch="llama3.2-3b", cache_len=64):
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    return cfg, GenerationEngine(model=model, params=params, cache_len=cache_len)


def test_generate_batched_greedy_deterministic():
    cfg, eng = _engine()
    rng = np.random.default_rng(0)
    batch = {"tokens": rng.integers(0, cfg.vocab, (3, 8)).astype(np.int32)}
    a = eng.generate(batch, max_new_tokens=5)
    b = eng.generate(batch, max_new_tokens=5)
    assert a.shape == (3, 5)
    np.testing.assert_array_equal(a, b)
    assert (a >= 0).all() and (a < cfg.vocab).all()


def test_generate_temperature_sampling_runs():
    cfg, eng = _engine()
    rng = np.random.default_rng(1)
    batch = {"tokens": rng.integers(0, cfg.vocab, (2, 8)).astype(np.int32)}
    out = eng.generate(batch, max_new_tokens=4, temperature=1.0, seed=7)
    assert out.shape == (2, 4)


def test_rag_pipeline_end_to_end():
    cfg, eng = _engine(cache_len=96)
    rng = np.random.default_rng(2)
    docs = rng.integers(0, cfg.vocab, (20, 12)).astype(np.int32)
    rag = RagPipeline.build(eng, docs, pruner="bond", index="flat", retrieve_k=2)
    q = {"tokens": rng.integers(0, cfg.vocab, (2, 8)).astype(np.int32)}
    out, doc_ids = rag.answer(q, max_new_tokens=4)
    assert out.shape == (2, 4)
    assert doc_ids.shape == (2, 2)
    assert (doc_ids >= 0).all() and (doc_ids < 20).all()


def test_rag_retrieves_self_document():
    """A query identical to a stored doc must retrieve that doc (exact BOND)."""
    cfg, eng = _engine(cache_len=96)
    rng = np.random.default_rng(3)
    docs = rng.integers(0, cfg.vocab, (16, 10)).astype(np.int32)
    rag = RagPipeline.build(eng, docs, pruner="bond", index="flat", retrieve_k=1)
    q = {"tokens": docs[5:6]}
    ids = rag.retrieve(q)
    assert ids[0, 0] == 5


def test_rag_add_documents_live():
    """Documents added after build are retrievable immediately (write-head),
    with ids that keep indexing doc_tokens."""
    cfg, eng = _engine(cache_len=96)
    rng = np.random.default_rng(4)
    docs = rng.integers(0, cfg.vocab, (12, 10)).astype(np.int32)
    rag = RagPipeline.build(eng, docs, pruner="bond", index="flat", retrieve_k=1)
    extra = rng.integers(0, cfg.vocab, (3, 10)).astype(np.int32)
    new_ids = rag.add_documents(extra)
    assert new_ids.tolist() == [12, 13, 14]
    assert rag.doc_tokens.shape == (15, 10)
    # self-retrieval of a freshly added (unflushed, write-head) document
    ids = rag.retrieve({"tokens": extra[1:2]})
    assert ids[0, 0] == 13
    # the full pipeline prepends the right doc tokens
    out, doc_ids = rag.answer({"tokens": extra[1:2]}, max_new_tokens=2)
    assert doc_ids[0, 0] == 13 and out.shape == (1, 2)
