"""Serving engine + RAG pipeline tests (tiny model, CPU)."""
import jax
import numpy as np

from repro.configs import get_config
from repro.models.lm import build_model
from repro.serve.engine import GenerationEngine
from repro.serve.rag import RagPipeline


def _engine(arch="llama3.2-3b", cache_len=64):
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    return cfg, GenerationEngine(model=model, params=params, cache_len=cache_len)


def test_generate_batched_greedy_deterministic():
    cfg, eng = _engine()
    rng = np.random.default_rng(0)
    batch = {"tokens": rng.integers(0, cfg.vocab, (3, 8)).astype(np.int32)}
    a = eng.generate(batch, max_new_tokens=5)
    b = eng.generate(batch, max_new_tokens=5)
    assert a.shape == (3, 5)
    np.testing.assert_array_equal(a, b)
    assert (a >= 0).all() and (a < cfg.vocab).all()


def test_generate_temperature_sampling_runs():
    cfg, eng = _engine()
    rng = np.random.default_rng(1)
    batch = {"tokens": rng.integers(0, cfg.vocab, (2, 8)).astype(np.int32)}
    out = eng.generate(batch, max_new_tokens=4, temperature=1.0, seed=7)
    assert out.shape == (2, 4)


def test_rag_pipeline_end_to_end():
    cfg, eng = _engine(cache_len=96)
    rng = np.random.default_rng(2)
    docs = rng.integers(0, cfg.vocab, (20, 12)).astype(np.int32)
    rag = RagPipeline.build(eng, docs, pruner="bond", index="flat", retrieve_k=2)
    q = {"tokens": rng.integers(0, cfg.vocab, (2, 8)).astype(np.int32)}
    out, doc_ids = rag.answer(q, max_new_tokens=4)
    assert out.shape == (2, 4)
    assert doc_ids.shape == (2, 2)
    assert (doc_ids >= 0).all() and (doc_ids < 20).all()


def test_rag_retrieves_self_document():
    """A query identical to a stored doc must retrieve that doc (exact BOND)."""
    cfg, eng = _engine(cache_len=96)
    rng = np.random.default_rng(3)
    docs = rng.integers(0, cfg.vocab, (16, 10)).astype(np.int32)
    rag = RagPipeline.build(eng, docs, pruner="bond", index="flat", retrieve_k=1)
    q = {"tokens": docs[5:6]}
    ids = rag.retrieve(q)
    assert ids[0, 0] == 5


def test_rag_add_documents_live():
    """Documents added after build are retrievable immediately (write-head),
    with ids that keep indexing doc_tokens."""
    cfg, eng = _engine(cache_len=96)
    rng = np.random.default_rng(4)
    docs = rng.integers(0, cfg.vocab, (12, 10)).astype(np.int32)
    rag = RagPipeline.build(eng, docs, pruner="bond", index="flat", retrieve_k=1)
    extra = rng.integers(0, cfg.vocab, (3, 10)).astype(np.int32)
    new_ids = rag.add_documents(extra)
    assert new_ids.tolist() == [12, 13, 14]
    assert rag.doc_tokens.shape == (15, 10)
    # self-retrieval of a freshly added (unflushed, write-head) document
    ids = rag.retrieve({"tokens": extra[1:2]})
    assert ids[0, 0] == 13
    # the full pipeline prepends the right doc tokens
    out, doc_ids = rag.answer({"tokens": extra[1:2]}, max_new_tokens=2)
    assert doc_ids[0, 0] == 13 and out.shape == (1, 2)


# ---------------------------------------------------------------------------
# Vector-serving tier: batcher primitives + VectorServer
# ---------------------------------------------------------------------------
import threading
import time
from concurrent.futures import Future

import pytest

from repro.core.engine import VectorSearchEngine
from repro.serve.batcher import (
    AdmissionQueue,
    DeadlineExceeded,
    QueryItem,
    ServerClosed,
    ServerOverloaded,
    pad_batch,
    shape_bucket,
)
from repro.serve.vector import VectorServer, jit_compile_count


def _item(spec="s", deadline=None, q=None):
    return QueryItem(
        query=q if q is not None else np.zeros(4, np.float32),
        spec=spec,
        future=Future(),
        t_enqueue=time.perf_counter(),
        deadline=deadline,
    )


def _vec_engine(n=1024, dim=32, seed=0, **kw):
    rng = np.random.default_rng(seed)
    X = rng.standard_normal((n, dim)).astype(np.float32)
    eng = VectorSearchEngine.build(
        X, pruner=kw.pop("pruner", "adsampling"),
        capacity=kw.pop("capacity", 256), **kw,
    )
    return eng, X


def test_shape_bucket_pow2():
    assert [shape_bucket(n, 64) for n in (1, 2, 3, 5, 8, 9, 64)] == [
        1, 2, 4, 8, 8, 16, 64
    ]
    assert shape_bucket(100, 64) == 64
    with pytest.raises(ValueError):
        shape_bucket(0, 64)


def test_pad_batch_repeats_last_row():
    Q = np.arange(12, dtype=np.float32).reshape(3, 4)
    P = pad_batch(Q, 8)
    assert P.shape == (8, 4)
    np.testing.assert_array_equal(P[3:], np.repeat(Q[-1:], 5, axis=0))
    assert pad_batch(Q, 3) is Q
    with pytest.raises(ValueError):
        pad_batch(Q, 2)


def test_admission_queue_empty_flush_times_out():
    q = AdmissionQueue(8)
    t0 = time.perf_counter()
    batch, expired = q.drain(4, window_s=0.0, timeout_s=0.02)
    assert batch == [] and expired == []
    assert time.perf_counter() - t0 < 1.0


def test_admission_queue_deadline_expiry_mid_queue():
    q = AdmissionQueue(8)
    live = _item()
    dead = _item(deadline=time.perf_counter() - 1.0)
    live2 = _item()
    for it in (live, dead, live2):
        assert q.put(it)
    batch, expired = q.drain(4, timeout_s=0.1)
    assert batch == [live, live2]
    assert expired == [dead]
    assert len(q) == 0


def test_admission_queue_groups_by_spec_preserving_order():
    q = AdmissionQueue(8)
    a1, b1, a2 = _item("a"), _item("b"), _item("a")
    for it in (a1, b1, a2):
        q.put(it)
    batch, _ = q.drain(4, timeout_s=0.1)
    assert batch == [a1, a2]          # same-spec coalesced
    batch2, _ = q.drain(4, timeout_s=0.1)
    assert batch2 == [b1]             # different spec waited its turn


def test_admission_queue_backpressure_and_close():
    q = AdmissionQueue(2)
    assert q.put(_item()) and q.put(_item())
    assert not q.put(_item())          # full -> reject, never block
    q.close()
    with pytest.raises(ServerClosed):
        q.put(_item())
    # closed but non-empty: drain still returns the queued work
    batch, _ = q.drain(4, timeout_s=0.1)
    assert len(batch) == 2
    assert q.drain(4, timeout_s=0.1) == ([], [])


def test_server_single_query_smallest_bucket_no_recompile():
    eng, X = _vec_engine()
    spec = eng.spec.replace(k=5, executor="batch-matmul")
    with VectorServer(eng, spec=spec, max_batch=8) as srv:
        srv.warmup()
        ids, dists = srv.search(X[3])
        assert ids.shape == (5,) and ids[0] == 3
        assert srv.jit_compiles_since_warmup() == 0


def test_server_cascade_warmup_zero_recompiles():
    """warmup() with a cascade spec compiles the executors' data-dependent
    pow2 shape menus (survivor compaction S, widened re-rank rk_eff)
    exhaustively — a served cascade workload whose survivor counts land on
    shapes the warm batch itself never hit must still mint nothing."""
    eng, X = _vec_engine(n=1024, dim=32)
    spec = eng.spec.replace(
        k=5, cascade=("int8", "f32"), kernel="jnp",
    )
    with VectorServer(eng, spec=spec, max_batch=8) as srv:
        srv.warmup()
        futs = [srv.submit(X[i]) for i in range(16)]
        for i, f in enumerate(futs):
            ids, _ = f.result()
            assert ids[0] == i
        assert srv.jit_compiles_since_warmup() == 0


def test_server_matches_engine_results():
    eng, X = _vec_engine()
    spec = eng.spec.replace(k=10, executor="batch-matmul")
    ref = eng.search(X[:6], spec)
    with VectorServer(eng, spec=spec, max_batch=8) as srv:
        futs = [srv.submit(X[i]) for i in range(6)]
        for i, f in enumerate(futs):
            ids, dists = f.result(timeout=30)
            np.testing.assert_array_equal(ids, np.asarray(ref.ids)[i])


def test_server_shutdown_drains_in_flight():
    eng, X = _vec_engine()
    spec = eng.spec.replace(k=5, executor="batch-matmul")
    srv = VectorServer(eng, spec=spec, max_batch=4, flush_interval_s=0.0)
    futs = [srv.submit(X[i]) for i in range(12)]
    srv.close(drain=True)
    for i, f in enumerate(futs):
        ids, _ = f.result(timeout=1)   # already done: drain completed them
        assert ids[0] == i
    with pytest.raises(ServerClosed):
        srv.submit(X[0])


def test_server_close_without_drain_fails_queued():
    eng, X = _vec_engine()
    spec = eng.spec.replace(k=5, executor="batch-matmul")
    srv = VectorServer(eng, spec=spec, max_batch=4)
    futs = [srv.submit(X[i]) for i in range(8)]
    srv.close(drain=False)
    outcomes = set()
    for f in futs:
        try:
            f.result(timeout=1)
            outcomes.add("ok")
        except ServerClosed:
            outcomes.add("closed")
    assert "closed" in outcomes        # at least the still-queued ones failed


def test_server_deadline_exceeded():
    eng, X = _vec_engine()
    spec = eng.spec.replace(k=5, executor="batch-matmul")
    with VectorServer(eng, spec=spec, max_batch=4) as srv:
        fut = srv.submit(X[0], timeout_s=-0.001)   # already expired
        with pytest.raises(DeadlineExceeded):
            fut.result(timeout=30)


def test_server_overload_rejects():
    eng, X = _vec_engine()
    spec = eng.spec.replace(k=5, executor="batch-matmul")
    srv = VectorServer(eng, spec=spec, max_batch=1, queue_depth=1,
                       flush_interval_s=0.0)
    # stall the executor stage so submissions pile up in the bounded queue
    rejected = 0
    try:
        for i in range(200):
            try:
                srv.submit(X[i % len(X)])
            except ServerOverloaded:
                rejected += 1
                break
        assert rejected >= 1
    finally:
        srv.close(drain=True)


def test_server_mutations_and_version_fenced_maintenance():
    eng, X = _vec_engine()
    spec = eng.spec.replace(k=5, executor="batch-matmul")
    with VectorServer(eng, spec=spec, max_batch=8,
                      maintenance_interval_s=0.02,
                      head_fill_threshold=0.0) as srv:
        rng = np.random.default_rng(1)
        V = rng.standard_normal((4, X.shape[1])).astype(np.float32)
        new_ids = srv.insert(V).result(timeout=30)
        assert len(new_ids) == 4
        # a freshly inserted vector is immediately searchable via the server
        ids, _ = srv.search(V[2])
        assert ids[0] == new_ids[2]
        assert srv.delete([int(new_ids[0])]).result(timeout=30) == 1
        deadline = time.time() + 10
        while time.time() < deadline:
            if getattr(eng.store, "head_count", 1) == 0:
                break                   # background repack drained the head
            time.sleep(0.02)
        assert eng.store.head_count == 0
        ids, _ = srv.search(V[2])       # survives the adopted repack
        assert ids[0] == new_ids[2]


def test_store_adopt_version_fence():
    from repro.core.layout import MutablePDXStore, build_flat_store

    rng = np.random.default_rng(0)
    X = rng.standard_normal((100, 8)).astype(np.float32)
    ms = MutablePDXStore.from_store(build_flat_store(X, capacity=32),
                                    head_capacity=16)
    ms.insert(rng.standard_normal((2, 8)).astype(np.float32))
    base = ms.version
    clone = ms.clone()
    clone.repack()
    # a mutation lands between clone and adopt -> the swap must be refused
    ms.insert(rng.standard_normal((1, 8)).astype(np.float32))
    assert not ms.adopt(clone, expect_version=base)
    assert ms.num_vectors == 103
    # retry against the now-current version succeeds
    base2 = ms.version
    clone2 = ms.clone()
    clone2.repack()
    assert ms.adopt(clone2, expect_version=base2)
    assert ms.num_vectors == 103 and ms.head_count == 0
