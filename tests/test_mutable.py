"""Mutable PDX store: write-head, tombstones, free-slot reuse, repack,
version-keyed exec caches, and parity-under-churn for every executor the
planner can pick (host executors here; the 8-fake-device sharded paths in a
subprocess, as in tests/test_dist.py).

Parity oracle: the acceptance bar is that a churned store answers exactly
like a store REBUILT from scratch from the surviving vectors — so the
oracle is a rebuilt engine searched with the same kernels (bit-identical
per-vector distances), not a float64 brute-force scan.  Mutable-store ids
are sparse (never reused); ``searchsorted`` over the sorted live ids maps
them onto the rebuilt store's dense 0..n-1 ids.
"""
import numpy as np
import pytest

from repro.core.engine import SearchSpec, VectorSearchEngine
from repro.core.layout import (
    PAD_VALUE,
    MutablePDXStore,
    build_bucketed_store,
    build_flat_store,
    pdx_to_nary,
)
from repro.data.synthetic import make_dataset

from test_dist import run_devices


class Oracle:
    """Shadow dict of live id -> vector, mirroring engine mutations."""

    def __init__(self, X):
        self.rows = {i: np.asarray(X[i]) for i in range(len(X))}

    def insert(self, eng, V):
        ids = eng.insert(V)
        for r, i in enumerate(ids):
            self.rows[int(i)] = np.asarray(V[r])
        return ids

    def delete(self, eng, ids):
        removed = eng.delete(ids)
        for i in np.atleast_1d(ids):
            self.rows.pop(int(i), None)
        return removed

    @property
    def live_ids(self):
        return np.asarray(sorted(self.rows))

    @property
    def surviving(self):
        return np.stack([self.rows[i] for i in sorted(self.rows)])


def _assert_matches_rebuilt(eng, oracle, Q, spec, executors, **build_kw):
    ref = VectorSearchEngine.build(oracle.surviving, **build_kw)
    im = oracle.live_ids
    for ex in executors:
        got = eng.search(Q, spec.replace(executor=ex))
        want = ref.search(Q, spec.replace(executor=ex))
        assert got.plan.executor == ex
        np.testing.assert_array_equal(
            np.searchsorted(im, got.ids), want.ids, err_msg=ex
        )
        np.testing.assert_allclose(
            got.dists, want.dists, rtol=1e-5, atol=1e-5, err_msg=ex
        )


# ------------------------------------------------------------- store invariants
def test_roundtrip_under_interleaved_mutation(rng):
    X = rng.standard_normal((300, 16)).astype(np.float32)
    store = MutablePDXStore.from_store(
        build_flat_store(X, capacity=64), head_capacity=32
    )
    rows = {i: X[i] for i in range(300)}
    v0 = store.version

    new = rng.standard_normal((20, 16)).astype(np.float32)
    ids = store.insert(new)
    assert ids.tolist() == list(range(300, 320))
    for r, i in enumerate(ids):
        rows[int(i)] = new[r]
    assert store.delete([0, 5, 299, 305, 9999]) == 4  # 9999 never existed
    for i in (0, 5, 299, 305):
        rows.pop(i)

    expected = np.stack([rows[i] for i in sorted(rows)])
    np.testing.assert_array_equal(pdx_to_nary(store), expected)
    assert store.num_vectors == len(rows)
    assert store.version > v0

    # interleave more mutations with repacks
    store.repack()
    assert store.head_count == 0
    np.testing.assert_array_equal(pdx_to_nary(store), expected)

    more = rng.standard_normal((50, 16)).astype(np.float32)
    ids2 = store.insert(more)  # 50 > head_capacity=32: forces a mid-insert flush
    for r, i in enumerate(ids2):
        rows[int(i)] = more[r]
    store.delete(ids2[:10])
    for i in ids2[:10]:
        rows.pop(int(i))
    expected = np.stack([rows[i] for i in sorted(rows)])
    np.testing.assert_array_equal(pdx_to_nary(store), expected)
    store.repack()
    np.testing.assert_array_equal(pdx_to_nary(store), expected)


def test_tombstoned_slots_are_poisoned_and_reusable(rng):
    X = rng.standard_normal((128, 8)).astype(np.float32)
    store = MutablePDXStore.from_store(
        build_flat_store(X, capacity=64), head_capacity=16
    )
    assert store.delete([3, 17]) == 2
    data = np.asarray(store.data)
    ids = np.asarray(store.ids)
    assert (ids[0, 3] == -1) and (ids[0, 17] == -1)
    assert (data[0, :, 3] == PAD_VALUE).all()
    assert (data[0, :, 17] == PAD_VALUE).all()

    # flush drains the write-head into exactly those freed slots: the store
    # is full otherwise, so partition count must NOT grow
    P0 = store.num_partitions
    store.insert(rng.standard_normal((2, 8)).astype(np.float32))
    store.flush()
    assert store.head_count == 0
    assert store.num_partitions == P0
    ids = np.asarray(store.ids)
    assert {int(ids[0, 3]), int(ids[0, 17])} == {128, 129}


def test_write_head_absorbs_until_flush(rng):
    X = rng.standard_normal((100, 8)).astype(np.float32)
    store = MutablePDXStore.from_store(
        build_flat_store(X, capacity=64), head_capacity=8
    )
    store.insert(rng.standard_normal((5, 8)).astype(np.float32))
    assert store.head_count == 5
    hids, hvecs = store.head_live()
    assert hids.tolist() == [100, 101, 102, 103, 104]
    assert hvecs.shape == (5, 8)
    # 4 more overflow the 8-slot head mid-insert -> automatic flush
    store.insert(rng.standard_normal((4, 8)).astype(np.float32))
    assert store.head_count < 9
    assert store.num_vectors == 109


def test_version_is_monotone_and_recorded_in_plan(rng):
    X, Q = make_dataset(400, 16, "normal", n_queries=1, seed=3)
    eng = VectorSearchEngine.build(X, pruner="linear", capacity=128)
    res = eng.search(Q[0], SearchSpec(k=3))
    assert res.plan.store_version == 0  # frozen store

    versions = [0]
    eng.insert(np.zeros((1, 16), np.float32))
    versions.append(eng.store.version)
    eng.delete([0])
    versions.append(eng.store.version)
    eng.compact()
    versions.append(eng.store.version)
    assert versions == sorted(set(versions)), versions  # strictly increasing
    res = eng.search(Q[0], SearchSpec(k=3))
    assert res.plan.store_version == eng.store.version > 0


# ----------------------------------------------------------------- cache safety
def test_exec_cache_invalidated_by_store_version():
    from repro.core.pdxearch import _EXEC_CACHE

    X, Q = make_dataset(300, 16, "normal", n_queries=1, seed=4)
    eng = VectorSearchEngine.build(X, pruner="linear", capacity=64)
    fp = eng.pruner.fingerprint
    spec = SearchSpec(k=3, executor="adaptive")

    eng.search(Q[0], spec)
    assert (fp, "l2", 0) in _EXEC_CACHE  # frozen store -> version 0 entry

    eng.insert(np.ones((1, 16), np.float32))
    v1 = eng.store.version
    assert v1 > 0
    eng.search(Q[0], spec)
    # the post-insert search may not touch the stale-version entry: a fresh
    # entry keyed on the new version must exist (fresh jit wrappers, so an
    # executor traced against the old tiles can never be reused)
    assert (fp, "l2", v1) in _EXEC_CACHE
    assert _EXEC_CACHE[(fp, "l2", v1)] is not _EXEC_CACHE.get((fp, "l2", 0))

    eng.delete([1])
    v2 = eng.store.version
    assert v2 > v1
    eng.search(Q[0], spec)
    assert (fp, "l2", v2) in _EXEC_CACHE


# ------------------------------------------------------- parity under churn
def _churn(eng, oracle, rng, rounds=3, ins=15, dels=10):
    for _ in range(rounds):
        oracle.insert(
            eng, rng.standard_normal((ins, eng.dim)).astype(np.float32)
        )
        victims = rng.choice(oracle.live_ids, size=dels, replace=False)
        oracle.delete(eng, victims)


@pytest.mark.parametrize("pruner", ["linear", "bond"])
def test_host_executor_parity_under_churn_flat(pruner):
    rng = np.random.default_rng(11)
    X, Q = make_dataset(1024, 24, "normal", n_queries=3, seed=11)
    build_kw = dict(pruner=pruner, capacity=128)
    eng = VectorSearchEngine.build(X, **build_kw)
    eng.head_capacity = 32
    oracle = Oracle(X)
    spec = SearchSpec(k=5)
    executors = ("adaptive", "jit-masked", "batch-matmul")

    _churn(eng, oracle, rng)
    assert eng.store.head_count > 0  # write-head populated: merged exactly
    _assert_matches_rebuilt(eng, oracle, Q, spec, executors, **build_kw)

    eng.compact()
    assert eng.store.head_count == 0
    _assert_matches_rebuilt(eng, oracle, Q, spec, executors, **build_kw)


def test_adaptive_ivf_parity_under_churn():
    rng = np.random.default_rng(12)
    X, Q = make_dataset(1536, 24, "clustered", n_queries=3, seed=12)
    nlist = 8
    build_kw = dict(index="ivf", pruner="linear", capacity=128, nlist=nlist)
    eng = VectorSearchEngine.build(X, **build_kw)
    eng.head_capacity = 16  # small head: churn forces bucket-local flushes
    oracle = Oracle(X)
    spec = SearchSpec(k=5, nprobe=nlist)  # full probe -> exact

    _churn(eng, oracle, rng, rounds=4, ins=20, dels=15)
    im = oracle.live_ids
    ref = VectorSearchEngine.build(oracle.surviving, **build_kw)
    got = eng.search(Q, spec)
    want = ref.search(Q, spec)
    assert got.plan.executor == "adaptive"
    np.testing.assert_array_equal(np.searchsorted(im, got.ids), want.ids)

    eng.compact()
    # bucket structure stays consistent after repack
    assert eng.ivf.part_counts.sum() == eng.store.num_partitions
    assert (eng.ivf.part_offsets == eng.store.part_offsets).all()
    got = eng.search(Q, spec)
    np.testing.assert_array_equal(np.searchsorted(im, got.ids), want.ids)
    # exact full scan agrees too
    got = eng.search(Q, spec.replace(executor="batch-matmul"))
    want = ref.search(Q, spec.replace(executor="batch-matmul"))
    np.testing.assert_array_equal(np.searchsorted(im, got.ids), want.ids)


def test_sharded_executor_parity_under_churn_8dev():
    run_devices("""
    from repro.core.engine import SearchSpec, VectorSearchEngine
    from repro.data.synthetic import make_dataset

    X, Q = make_dataset(2048, 32, "normal", n_queries=4, seed=0)
    mesh = jax.make_mesh((8,), ("data",))
    eng = VectorSearchEngine.build(X, pruner="linear", capacity=128, mesh=mesh)
    rows = {i: X[i] for i in range(len(X))}
    rng = np.random.default_rng(9999)

    new = rng.standard_normal((60, 32)).astype(np.float32)
    ids = eng.insert(new)
    for r, i in enumerate(ids):
        rows[int(i)] = new[r]
    dels = rng.choice(2048, size=300, replace=False)
    eng.delete(dels)
    for i in dels:
        rows.pop(int(i), None)

    im = np.asarray(sorted(rows))
    Xs = np.stack([rows[i] for i in sorted(rows)])
    ref = VectorSearchEngine.build(Xs, pruner="linear", capacity=128)
    spec = SearchSpec(k=5)

    def check():
        r1 = eng.search(Q[0], spec)
        assert r1.plan.executor == "block-sharded", r1.plan
        w1 = ref.search(Q[0], spec.replace(executor="adaptive"))
        np.testing.assert_array_equal(np.searchsorted(im, r1.ids), w1.ids)
        rb = eng.search(Q, spec)
        assert rb.plan.executor == "batch-block-sharded", rb.plan
        wb = ref.search(Q, spec.replace(executor="batch-matmul"))
        np.testing.assert_array_equal(np.searchsorted(im, rb.ids), wb.ids)

    check()                 # write-head rows reachable through sharded paths
    eng.compact()
    # live count leaves P=15: indivisible by 8, so the executors must pad
    assert eng.store.num_partitions % 8 != 0, eng.store.num_partitions
    check()
    rb = eng.search(Q, spec)
    assert "padded" in rb.plan.reason, rb.plan.reason
    print("OK")
    """)


# ----------------------------------------------------- batch-delete satellite
def test_batch_delete_10k_single_vectorized_pass(rng):
    """delete() resolves the whole id array to coordinates up front and
    poisons every slot in one fancy-indexed pass — 10k deletes across
    sealed tiles AND the write-head in one call, with running moments,
    counts, and the id map staying exact."""
    X = rng.standard_normal((20000, 16)).astype(np.float32)
    store = MutablePDXStore.from_store(
        build_flat_store(X, capacity=256), head_capacity=64
    )
    head = rng.standard_normal((50, 16)).astype(np.float32)
    store.insert(head)  # ids 20000..20049 live in the write-head
    rows = {i: X[i] for i in range(20000)}
    rows.update({20000 + r: head[r] for r in range(50)})

    victims = rng.choice(20050, size=10000, replace=False)
    # repeated + never-existing ids must not double-count
    removed = store.delete(np.concatenate([victims, victims[:7], [10**6]]))
    assert removed == 10000
    for i in victims:
        rows.pop(int(i))
    assert store.num_vectors == len(rows) == 10050
    expected = np.stack([rows[i] for i in sorted(rows)])
    np.testing.assert_array_equal(pdx_to_nary(store), expected)
    # tombstoned sealed slots are poisoned and re-usable
    ids_arr = np.asarray(store.ids)
    data_arr = np.asarray(store.data)
    assert (data_arr[:, 0, :][ids_arr < 0] == PAD_VALUE).all()
    assert int((ids_arr >= 0).sum()) == int(store._counts.sum())
    # moments stayed in sync -> a repack reproduces identical metadata
    before = np.asarray(store.dim_means).copy()
    store.repack()
    np.testing.assert_allclose(np.asarray(store.dim_means), before, atol=1e-4)
    np.testing.assert_array_equal(pdx_to_nary(store), expected)


# ------------------------------------------------------ BSA-recal satellite
def test_bsa_recalibrated_on_compact():
    """compact() refits BSA's PCA from a fresh survivor sample and
    re-projects the live rows in place, so a churned-then-compacted engine
    prunes like one freshly built from the survivors (ROADMAP follow-up:
    previously only BOND metadata refreshed)."""
    from repro.core.pdxearch import SearchStats
    from repro.data.synthetic import ground_truth, recall_at_k

    rng = np.random.default_rng(31)
    X, Q = make_dataset(4096, 32, "clustered", n_queries=8, seed=31)
    build_kw = dict(pruner="bsa", capacity=128)
    eng = VectorSearchEngine.build(X, **build_kw)
    fp0 = eng.pruner.fingerprint
    oracle = Oracle(X)
    # churn WITH distribution shift: the build-time PCA goes stale
    shifted = (rng.standard_normal((600, 32)) * 0.5 + 4.0).astype(np.float32)
    oracle.insert(eng, shifted)
    oracle.delete(eng, rng.choice(4096, size=1500, replace=False))

    eng.compact()
    assert eng.pruner.fingerprint != fp0  # recalibrated -> new identity

    fresh = VectorSearchEngine.build(oracle.surviving, **build_kw)
    gt_ids, _ = ground_truth(oracle.surviving, Q, k=10)
    im = oracle.live_ids
    got = eng.search(Q, SearchSpec(k=10, executor="adaptive"))
    want = fresh.search(Q, SearchSpec(k=10, executor="adaptive"))
    r_got = recall_at_k(np.searchsorted(im, got.ids), gt_ids)
    r_fresh = recall_at_k(want.ids, gt_ids)
    assert abs(r_got - r_fresh) <= 0.02, (r_got, r_fresh)
    # pruning power matches the freshly calibrated pruner too
    s_got, s_fresh = SearchStats(), SearchStats()
    eng.search(Q[0], SearchSpec(k=10), stats=s_got)
    fresh.search(Q[0], SearchSpec(k=10), stats=s_fresh)
    assert abs(s_got.pruning_power - s_fresh.pruning_power) <= 0.05


def test_bsa_recal_keeps_ivf_centroids_consistent():
    """The recalibration rotates the stored coordinates; IVF centroids must
    rotate along (bucket membership is rotation-invariant), keeping
    full-probe search exact after compact."""
    from repro.data.synthetic import ground_truth, recall_at_k

    rng = np.random.default_rng(32)
    X, Q = make_dataset(2048, 24, "clustered", n_queries=6, seed=32)
    eng = VectorSearchEngine.build(
        X, index="ivf", pruner="bsa", capacity=128, nlist=8,
    )
    oracle = Oracle(X)
    oracle.insert(eng, rng.standard_normal((200, 24)).astype(np.float32))
    oracle.delete(eng, rng.choice(2048, size=400, replace=False))
    eng.compact()
    assert eng.ivf.part_counts.sum() == eng.store.num_partitions
    gt_ids, _ = ground_truth(oracle.surviving, Q, k=5)
    got = eng.search(Q, SearchSpec(k=5, nprobe=8))
    fresh = VectorSearchEngine.build(
        oracle.surviving, index="ivf", pruner="bsa", capacity=128, nlist=8,
    )
    want = fresh.search(Q, SearchSpec(k=5, nprobe=8))
    r_got = recall_at_k(np.searchsorted(oracle.live_ids, got.ids), gt_ids)
    r_fresh = recall_at_k(want.ids, gt_ids)
    assert abs(r_got - r_fresh) <= 0.05, (r_got, r_fresh)


# ------------------------------------------------------- empty-bucket satellite
def test_empty_buckets_cost_zero_partitions(rng):
    X = rng.standard_normal((50, 4)).astype(np.float32)
    assign = np.zeros(50, dtype=np.int64)  # buckets 1, 2 empty
    store, offsets, nparts = build_bucketed_store(X, assign, 3, capacity=64)
    assert nparts.tolist() == [1, 0, 0]
    assert store.num_partitions == 1  # regression: was 3 (2 all-PAD tiles)
    assert offsets.tolist() == [0, 1, 1]
    # scan work is zero for the empty buckets and search is still exact
    eng = VectorSearchEngine.build(
        X, index="ivf", pruner="linear", capacity=64, nlist=4,
        precomputed_ivf=(X[:4], np.zeros(50, dtype=np.int64)),
    )
    assert eng.ivf.part_counts.tolist() == [1, 0, 0, 0]
    res = eng.search(X[7], SearchSpec(k=1, nprobe=4))
    assert res.ids[0] == 7


def test_route_skips_empty_buckets_for_start_phase(rng):
    X = rng.standard_normal((40, 4)).astype(np.float32)
    # everything in bucket 2; centroids placed so bucket 0 ranks nearest
    cents = np.stack([
        np.zeros(4, np.float32),
        np.ones(4, np.float32) * 50,
        np.ones(4, np.float32) * 100,
    ])
    assign = np.full(40, 2, dtype=np.int64)
    eng = VectorSearchEngine.build(
        X, index="ivf", pruner="linear", capacity=64, nlist=3,
        precomputed_ivf=(cents, assign),
    )
    order, start_parts = eng.ivf.route(np.zeros(4, np.float32), nprobe=3)
    assert start_parts == 1  # bucket 2's single partition seeds START
    assert order.tolist() == [0]
    res = eng.search(X[3], SearchSpec(k=1, nprobe=3))
    assert res.ids[0] == 3
