"""Minimal vendored stand-in for the hypothesis API surface the property
tests use, so ``tests/test_property.py`` runs (instead of skipping) in
environments where the real ``hypothesis`` package is not installed.

This is NOT a property-testing engine: no shrinking, no adaptive search, no
database.  It is a deterministic seeded sweep — ``@given`` draws
``max_examples`` pseudo-random example dicts from the declared strategies
(seeded per test function, so failures reproduce) and calls the test once
per example, reporting the falsifying example on the first failure.  When
the real hypothesis is available (``pip install .[test]``), the import in
``test_property.py`` prefers it and this module is inert.

Supported surface (exactly what the tests import):
  ``given(**strategies)``, ``settings(max_examples=, deadline=)``,
  ``strategies.integers / floats / sampled_from / lists`` (aliased ``st``).
"""
from __future__ import annotations

import inspect
import zlib

import numpy as np

__all__ = ["given", "settings", "strategies", "st"]

_DEFAULT_MAX_EXAMPLES = 50


class _Strategy:
    """A draw rule: ``rng -> example``."""

    def __init__(self, draw):
        self._draw = draw

    def draw(self, rng: np.random.Generator):
        return self._draw(rng)


class strategies:
    """The ``hypothesis.strategies`` names the tests use."""

    @staticmethod
    def integers(min_value: int, max_value: int) -> _Strategy:
        return _Strategy(
            lambda rng: int(rng.integers(min_value, max_value + 1))
        )

    @staticmethod
    def floats(min_value: float, max_value: float) -> _Strategy:
        return _Strategy(
            lambda rng: float(rng.uniform(min_value, max_value))
        )

    @staticmethod
    def sampled_from(options) -> _Strategy:
        opts = list(options)
        return _Strategy(lambda rng: opts[int(rng.integers(0, len(opts)))])

    @staticmethod
    def lists(elements: _Strategy, min_size: int = 0,
              max_size: int = 10) -> _Strategy:
        return _Strategy(
            lambda rng: [
                elements.draw(rng)
                for _ in range(int(rng.integers(min_size, max_size + 1)))
            ]
        )


st = strategies


class settings:
    """Decorator applied OVER a ``@given``-wrapped test (hypothesis's
    composition order); records ``max_examples`` on the wrapper.  The
    ``deadline`` knob is accepted and ignored (there is no watchdog)."""

    def __init__(self, max_examples: int = _DEFAULT_MAX_EXAMPLES,
                 deadline=None, **_ignored):
        self.max_examples = int(max_examples)

    def __call__(self, fn):
        fn._minihyp_max_examples = self.max_examples
        return fn


def given(**strategy_kwargs):
    """Seeded-sweep ``@given``: run the test once per drawn example dict.

    The per-test RNG seed derives from the function's qualified name (CRC32
    — stable across processes, unlike ``hash(str)``), so a red run's
    falsifying example reproduces on re-run without a shared database."""

    def deco(fn):
        base_seed = zlib.crc32(fn.__qualname__.encode())

        def wrapper(*args, **kwargs):
            n = getattr(wrapper, "_minihyp_max_examples",
                        _DEFAULT_MAX_EXAMPLES)
            for case in range(n):
                rng = np.random.default_rng((base_seed, case))
                drawn = {
                    name: strat.draw(rng)
                    for name, strat in strategy_kwargs.items()
                }
                try:
                    fn(*args, **drawn, **kwargs)
                except Exception as exc:
                    raise AssertionError(
                        f"minihyp falsifying example "
                        f"(case {case}/{n}): {drawn!r}"
                    ) from exc

        # identity without functools.wraps: copying __wrapped__ would make
        # pytest read the original signature and hunt fixtures named after
        # the strategy parameters — the wrapper must look zero-argument
        for attr in ("__name__", "__qualname__", "__doc__", "__module__"):
            setattr(wrapper, attr, getattr(fn, attr))
        wrapper.__signature__ = inspect.Signature()
        return wrapper

    return deco
