"""Placement + bucket-routed distributed search: greedy bucket->shard
assignment invariants, block-placement equivalence with the old padding,
the placement cache (keyed per (tiles_version, n_shards, kind) so two mesh
sizes never thrash), the routed executor's exactness vs single-host IVF
ground truth (8 fake devices, in subprocesses — see tests/test_dist.py for
why), and the per-batch collective gate: exactly one all-to-all plus one
packed all-gather, independent of B and nprobe."""
import dataclasses

import numpy as np
import pytest

from repro.core.engine import SearchSpec, VectorSearchEngine
from repro.core.plan import _get_placement, plan_search
from repro.data.synthetic import make_dataset
from repro.dist.placement import Placement, assign_buckets

from test_dist import run_devices


# ---------------------------------------------------------------- placement
def test_assign_buckets_greedy_balance():
    rng = np.random.default_rng(0)
    for trial in range(20):
        parts = rng.integers(0, 12, size=rng.integers(1, 40))
        n = int(rng.integers(1, 9))
        shard = assign_buckets(parts, n)
        assert shard.shape == parts.shape and (0 <= shard).all() and (shard < n).all()
        load = np.bincount(shard, weights=parts, minlength=n)
        # LPT bound: spread never exceeds the largest single bucket
        assert load.max() - load.min() <= max(int(parts.max(initial=0)), 1)
    # deterministic
    parts = np.asarray([5, 1, 3, 3, 0, 7])
    assert (assign_buckets(parts, 3) == assign_buckets(parts, 3)).all()


def test_block_placement_matches_legacy_padding():
    from repro.core.layout import PAD_VALUE, build_flat_store

    X, _ = make_dataset(500, 8, "normal", n_queries=1, seed=1)
    store = build_flat_store(X, capacity=64)  # 8 partitions
    pl = Placement.block(store.data, store.ids, 3)
    assert pl.num_slots == 9 and pl.parts_per_shard == 3
    np.testing.assert_array_equal(np.asarray(pl.data[:8]), np.asarray(store.data))
    assert (np.asarray(pl.data[8]) == PAD_VALUE).all()
    assert (np.asarray(pl.ids[8]) == -1).all()
    assert pl.part_perm.tolist() == [0, 1, 2, 3, 4, 5, 6, 7, -1]
    # already divisible: untouched, zero copies
    pl2 = Placement.block(store.data, store.ids, 4)
    assert pl2.data is store.data and pl2.ids is store.ids


def test_bucket_placement_invariants():
    X, _ = make_dataset(1024, 16, "clustered", n_queries=1, seed=2)
    eng = VectorSearchEngine.build(
        X, index="ivf", pruner="linear", capacity=64, nlist=8,
    )
    pl = _get_placement(eng.store, 4, "bucket", ivf=eng.ivf)
    assert pl.kind == "bucket" and pl.num_slots % 4 == 0
    # every source partition placed exactly once
    real = np.sort(pl.part_perm[pl.part_perm >= 0])
    np.testing.assert_array_equal(real, np.arange(eng.store.num_partitions))
    # each slot's bucket is owned by the shard whose slice holds the slot
    width = pl.parts_per_shard
    for i in range(pl.num_slots):
        b = pl.slot_bucket[i]
        if b >= 0:
            assert pl.bucket_shard[b] == i // width
    # arranged tiles are the source tiles, permuted
    src = np.asarray(eng.store.data)
    for i, p in enumerate(pl.part_perm):
        if p >= 0:
            np.testing.assert_array_equal(np.asarray(pl.data[i]), src[p])
    # the same slice holds every partition of each owned bucket contiguously
    pl.check()


def test_placement_check_rejects_corruption():
    X, _ = make_dataset(256, 8, "normal", n_queries=1, seed=3)
    eng = VectorSearchEngine.build(
        X, index="ivf", pruner="linear", capacity=64, nlist=4,
    )
    pl = _get_placement(eng.store, 2, "bucket", ivf=eng.ivf)
    dup = pl.part_perm.copy()
    dup[-1] = dup[0]  # place a partition twice
    with pytest.raises(ValueError, match="more than once"):
        dataclasses.replace(pl, part_perm=dup).check()
    flipped = pl.bucket_shard.copy()
    flipped[:] = (flipped + 1) % 2  # every bucket claims the other shard
    with pytest.raises(ValueError, match="span shard slices"):
        dataclasses.replace(pl, bucket_shard=flipped).check()


def test_placement_cache_no_thrash_across_mesh_sizes():
    """Satellite: the cache keys on (tiles_version, n_shards, kind), so one
    store serving two mesh sizes (or block + bucket layouts) keeps every
    entry live, and head-only inserts never invalidate them."""
    X, _ = make_dataset(600, 8, "normal", n_queries=1, seed=4)
    eng = VectorSearchEngine.build(
        X, index="ivf", pruner="linear", capacity=64, nlist=4,
    )
    eng.insert(np.zeros((1, 8), np.float32))  # upgrade to mutable
    store = eng.store
    a2 = _get_placement(store, 2, "block")
    a4 = _get_placement(store, 4, "block")
    b2 = _get_placement(store, 2, "bucket", ivf=eng.ivf)
    # alternating mesh sizes / kinds returns the SAME objects — no rebuild
    assert _get_placement(store, 2, "block") is a2
    assert _get_placement(store, 4, "block") is a4
    assert _get_placement(store, 2, "bucket", ivf=eng.ivf) is b2
    # head-only insert: tiles untouched -> placements stay valid
    eng.insert(np.ones((1, 8), np.float32))
    assert _get_placement(store, 2, "block") is a2
    # compact moves sealed tiles -> stale entries evicted, fresh ones built
    eng.compact()
    a2b = _get_placement(store, 2, "block")
    assert a2b is not a2
    assert all(k[0] == store.tiles_version for k in store._placement_cache)


# ------------------------------------------------------------- planner rules
class _FakeMesh:
    def __init__(self, **axes):
        self.axis_names = tuple(axes)
        self.shape = dict(axes)


def test_planner_routes_ivf_on_data_mesh():
    X, _ = make_dataset(512, 16, "normal", n_queries=1, seed=5)
    store = VectorSearchEngine.build(X, pruner="linear", capacity=64).store
    spec = SearchSpec(k=5)
    ivf = object()
    mesh = _FakeMesh(data=8)
    p = plan_search(spec, store, 4, ivf=ivf, mesh=mesh)
    assert p.executor == "routed_bucket" and "bucket-owned" in p.reason
    # opt-out keeps the IVF routing host-side
    p = plan_search(spec.replace(routing="broadcast"), store, 4, ivf=ivf,
                    mesh=mesh)
    assert p.executor == "adaptive" and "broadcast" in p.reason
    # no data axis -> cannot route
    p = plan_search(spec, store, 1, ivf=ivf, mesh=_FakeMesh(model=8))
    assert p.executor == "adaptive" and "'data' axis" in p.reason
    # stats no longer pin the executor — the routed path fills SearchStats
    # from the selected buckets' host-side metadata
    p = plan_search(spec, store, 4, ivf=ivf, mesh=mesh)
    assert p.executor == "routed_bucket"


# ----------------------------------------------------------- budget spill
def test_plan_routing_spills_oversubscribed_budgets():
    """Satellite: under skewed demand the exchange splits into two rounds
    (b1, b2) whenever that moves fewer padded slots than one round at the
    global max's pow2 ceiling; balanced demand stays single-round."""
    from repro.dist.routing import _pow2_at_least, plan_routing

    bucket_shard = np.asarray([0, 1, 2, 3])
    bucket_parts = np.asarray([2, 2, 2, 2])

    # balanced: every query to every shard -> equal demand, one round
    sel = np.tile(np.arange(4), (16, 1))
    rp = plan_routing(sel, bucket_shard, bucket_parts, 4)
    assert rp.round_budgets[1] == 0
    assert rp.budget == rp.round_budgets[0]

    # high skew: all 33 queries select bucket 0 -> per-(src, dst) demand 9
    # (the batch splits over 4 source shards); the padded single round
    # would cost pow2(9) = 16 slots, the spilled plan (8, 4) = 12
    sel = np.zeros((33, 1), np.int64)
    rp = plan_routing(sel, bucket_shard, bucket_parts, 4)
    assert rp.round_budgets == (8, 4)
    assert rp.budget == 12 and rp.budget < _pow2_at_least(9)

    # bytes moved are pinned by the budget: the send buffer is
    # n * n * (b1 + b2) slots, not n * n * pow2(max demand)
    from repro.dist.routing import build_send_buffer

    Q = np.zeros((33, 8), np.float32)
    buf = build_send_buffer(Q, sel, rp)
    assert buf.shape == (4, 4, 12, 8 + 1)
    single_round_bytes = 4 * 4 * 16 * (8 + 1) * 4
    assert buf.nbytes == single_round_bytes * 3 // 4


def test_spilled_routing_matches_single_round_8dev():
    """A plan that spills into two all-to-all rounds returns exactly the
    same top-k as the unspilled executor (the rounds are slices of one
    buffer; concatenation reproduces the single-round layout)."""
    run_devices("""
    from repro.core.engine import SearchSpec, VectorSearchEngine
    from repro.data.synthetic import make_dataset, ground_truth, recall_at_k
    from repro.dist.routing import plan_routing
    from repro.core.plan import _get_placement

    # 24 near-copies of one vector: every query routes to the same bucket,
    # so one owner shard absorbs the whole batch = maximally skewed demand
    X, _ = make_dataset(2048, 32, "clustered", n_queries=1, seed=3)
    rng = np.random.default_rng(11)
    Q = (X[0][None] + rng.normal(0, 0.01, (24, 32))).astype(np.float32)
    nlist = 16
    mesh = jax.make_mesh((8,), ("data",))
    eng = VectorSearchEngine.build(X, index="ivf", pruner="linear",
                                   capacity=64, nlist=nlist, mesh=mesh)
    gt_ids, _ = ground_truth(X, Q, k=5)

    pl = _get_placement(eng.store, 8, "bucket", ivf=eng.ivf)
    sel = eng.ivf.route_batch(jnp.asarray(Q), 1)
    rp = plan_routing(sel, pl.bucket_shard, pl.bucket_parts, 8)
    assert rp.round_budgets[1] > 0, rp.round_budgets  # the spill engaged

    res = eng.search(Q, SearchSpec(k=5, nprobe=1))
    assert res.plan.executor == "routed_bucket", res.plan
    host = VectorSearchEngine.build(X, index="ivf", pruner="linear",
                                    capacity=64, nlist=nlist)
    want = host.search(Q, SearchSpec(k=5, nprobe=1, executor="adaptive"))
    for qi in range(len(Q)):
        assert set(res.ids[qi].tolist()) == set(want.ids[qi].tolist()), qi
    print("OK")
    """)


# ------------------------------------------- routed executor (8 fake devices)
def test_routed_bucket_matches_single_host_ivf_8dev():
    run_devices("""
    from repro.core.engine import SearchSpec, VectorSearchEngine
    from repro.data.synthetic import make_dataset, ground_truth, recall_at_k

    X, Q = make_dataset(2048, 32, "clustered", n_queries=6, seed=0)
    nlist = 16
    mesh = jax.make_mesh((8,), ("data",))
    eng = VectorSearchEngine.build(X, index="ivf", pruner="linear",
                                   capacity=64, nlist=nlist, mesh=mesh)
    host = VectorSearchEngine.build(X, index="ivf", pruner="linear",
                                    capacity=64, nlist=nlist)
    gt_ids, gt_d = ground_truth(X, Q, k=5)

    # full probe: exact vs brute-force ground truth
    res = eng.search(Q, SearchSpec(k=5, nprobe=nlist))
    assert res.plan.executor == "routed_bucket", res.plan
    assert recall_at_k(res.ids, gt_ids) == 1.0
    np.testing.assert_allclose(np.sort(res.dists, axis=1),
                               np.sort(gt_d, axis=1), rtol=1e-3, atol=1e-2)

    # partial probe: identical answer set to single-host IVF at the same
    # nprobe (both rank buckets with the same centroid arithmetic)
    for nprobe in (1, 4):
        r = eng.search(Q, SearchSpec(k=5, nprobe=nprobe))
        assert r.plan.executor == "routed_bucket", r.plan
        w = host.search(Q, SearchSpec(k=5, nprobe=nprobe, executor="adaptive"))
        for qi in range(len(Q)):
            assert set(r.ids[qi].tolist()) == set(w.ids[qi].tolist()), qi
        np.testing.assert_allclose(np.sort(r.dists, axis=1),
                                   np.sort(w.dists, axis=1),
                                   rtol=1e-4, atol=1e-4)

    # single query routes too, and broadcast opt-out falls back host-side
    r1 = eng.search(Q[0], SearchSpec(k=5, nprobe=nlist))
    assert r1.plan.executor == "routed_bucket"
    assert set(r1.ids.tolist()) == set(gt_ids[0].tolist())
    rb = eng.search(Q, SearchSpec(k=5, routing="broadcast"))
    assert rb.plan.executor == "adaptive", rb.plan
    print("OK")
    """)


def test_routed_bucket_one_alltoall_one_allgather_8dev():
    """Acceptance gate: the routed executor issues exactly ONE all-to-all
    (query exchange) + ONE packed all-gather (hierarchical merge) per query
    batch, independent of B and nprobe — no replicated query broadcast."""
    run_devices("""
    from repro.core.engine import SearchSpec, VectorSearchEngine
    from repro.core.plan import _get_placement
    from repro.data.synthetic import make_dataset
    from repro.dist.pdx_sharded import collective_counts
    from repro.dist.routing import (build_send_buffer, make_routed_fn,
                                    plan_routing)

    X, Q = make_dataset(2048, 32, "clustered", n_queries=16, seed=1)
    mesh = jax.make_mesh((8,), ("data",))
    eng = VectorSearchEngine.build(X, index="ivf", pruner="linear",
                                   capacity=64, nlist=16, mesh=mesh)
    pl = _get_placement(eng.store, 8, "bucket", ivf=eng.ivf)
    for B in (2, 4, 16):
        for nprobe in (1, 4, 16):
            sel = eng.ivf.route_batch(jnp.asarray(Q[:B]), nprobe)
            rp = plan_routing(sel, pl.bucket_shard, pl.bucket_parts, 8)
            fn = make_routed_fn(mesh, pl, rp, Q.shape[1], sel.shape[1], 5)
            buf = jnp.asarray(build_send_buffer(Q[:B], sel, rp))
            counts = collective_counts(fn, buf)
            assert counts == {"all_to_all": 1, "all_gather": 1}, \
                (B, nprobe, counts)
    print("OK")
    """)


def test_routed_bucket_quantized_routing_keeps_collective_gate_8dev():
    """Quantized centroid routing (route_dtype="int8") is host-side and
    pre-collective: the routed executor still issues exactly ONE all-to-all
    + ONE packed all-gather per batch, and full-probe answers stay exact."""
    run_devices("""
    from repro.core.engine import SearchSpec, VectorSearchEngine
    from repro.data.synthetic import make_dataset, ground_truth, recall_at_k
    from repro.obs import metrics

    metrics.set_enabled(True)
    X, Q = make_dataset(2048, 32, "clustered", n_queries=6, seed=0)
    nlist = 16
    mesh = jax.make_mesh((8,), ("data",))
    eng = VectorSearchEngine.build(X, index="ivf", pruner="linear",
                                   capacity=64, nlist=nlist, mesh=mesh)
    gt_ids, _ = ground_truth(X, Q, k=5)
    reg = metrics.get_registry()

    res = eng.search(Q, SearchSpec(k=5, nprobe=nlist, route_dtype="int8"))
    assert res.plan.executor == "routed_bucket", res.plan
    assert recall_at_k(res.ids, gt_ids) == 1.0
    assert reg.get("repro_collectives_issued_total",
                   executor="routed_bucket", primitive="all_to_all") == 1.0
    assert reg.get("repro_collectives_issued_total",
                   executor="routed_bucket", primitive="all_gather") == 1.0
    # the quantized centroid scan's bytes are metered at the routing dtype
    assert reg.get("repro_device_bytes_total", executor="route",
                   component="scan", dtype="int8") > 0

    # partial probe: same answer set as f32 routing on separated clusters
    rq = eng.search(Q, SearchSpec(k=5, nprobe=4, route_dtype="int8"))
    rf = eng.search(Q, SearchSpec(k=5, nprobe=4))
    for qi in range(len(Q)):
        assert set(rq.ids[qi].tolist()) == set(rf.ids[qi].tolist()), qi
    print("OK")
    """)


def test_routed_bucket_parity_under_churn_8dev():
    """A churned MutablePDXStore answers through the routed path exactly
    like a store rebuilt from the survivors: write-head rows reachable,
    tombstones invisible, placement re-derived only after compact."""
    run_devices("""
    from repro.core.engine import SearchSpec, VectorSearchEngine
    from repro.data.synthetic import make_dataset

    X, Q = make_dataset(2048, 32, "clustered", n_queries=4, seed=2)
    nlist = 8
    mesh = jax.make_mesh((8,), ("data",))
    eng = VectorSearchEngine.build(X, index="ivf", pruner="linear",
                                   capacity=64, nlist=nlist, mesh=mesh)
    rows = {i: X[i] for i in range(len(X))}
    rng = np.random.default_rng(77)
    new = rng.standard_normal((50, 32)).astype(np.float32)
    ids = eng.insert(new)
    for r, i in enumerate(ids):
        rows[int(i)] = new[r]
    dels = rng.choice(2048, size=250, replace=False)
    eng.delete(dels)
    for i in dels:
        rows.pop(int(i), None)

    im = np.asarray(sorted(rows))
    Xs = np.stack([rows[i] for i in sorted(rows)])
    ref = VectorSearchEngine.build(Xs, index="ivf", pruner="linear",
                                   capacity=64, nlist=nlist)
    spec = SearchSpec(k=5, nprobe=nlist)  # full probe -> exact

    def check():
        got = eng.search(Q, spec)
        assert got.plan.executor == "routed_bucket", got.plan
        want = ref.search(Q, spec.replace(executor="batch-matmul"))
        np.testing.assert_array_equal(np.searchsorted(im, got.ids), want.ids)

    check()          # mid-churn: head merged exactly through the routed path
    v0 = eng.store.tiles_version
    eng.compact()
    assert eng.store.tiles_version > v0
    check()          # post-compact: placement rebuilt from the new tiles
    print("OK")
    """)
