"""Distance kernels: PDX vs N-ary layouts must agree; matmul form vs direct."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.distance import (
    METRICS,
    batched_distance_matmul,
    nary_distance,
    pdx_accumulate,
    pdx_distance,
)


@pytest.mark.parametrize("metric", METRICS)
@pytest.mark.parametrize("n,dim", [(64, 8), (100, 33), (17, 128)])
def test_pdx_equals_nary(metric, n, dim, rng):
    X = rng.standard_normal((n, dim)).astype(np.float32)
    q = rng.standard_normal(dim).astype(np.float32)
    d_nary = nary_distance(jnp.asarray(X), jnp.asarray(q), metric)
    d_pdx = pdx_distance(jnp.asarray(X.T), jnp.asarray(q), metric)
    np.testing.assert_allclose(np.asarray(d_nary), np.asarray(d_pdx), rtol=2e-5)


@pytest.mark.parametrize("metric", METRICS)
def test_accumulate_partial_sums_to_full(metric, rng):
    n, dim = 40, 24
    T = rng.standard_normal((dim, n)).astype(np.float32)
    q = rng.standard_normal(dim).astype(np.float32)
    acc = jnp.zeros((n,), jnp.float32)
    for lo, hi in [(0, 2), (2, 6), (6, 14), (14, 24)]:
        acc = pdx_accumulate(jnp.asarray(T[lo:hi]), jnp.asarray(q[lo:hi]), acc, metric)
    full = pdx_distance(jnp.asarray(T), jnp.asarray(q), metric)
    np.testing.assert_allclose(np.asarray(acc), np.asarray(full), rtol=2e-5, atol=1e-5)


@pytest.mark.parametrize("metric", METRICS)
def test_batched_matmul_form(metric, rng):
    n, dim, b = 96, 48, 5
    T = rng.standard_normal((dim, n)).astype(np.float32)
    Q = rng.standard_normal((b, dim)).astype(np.float32)
    got = batched_distance_matmul(jnp.asarray(T), jnp.asarray(Q), metric)
    want = np.stack(
        [np.asarray(pdx_distance(jnp.asarray(T), jnp.asarray(q), metric)) for q in Q]
    )
    np.testing.assert_allclose(np.asarray(got), want, rtol=5e-4, atol=1e-3)


def test_l2_partial_is_monotone(rng):
    """Monotonicity underpins BOND's exact pruning bound."""
    dim, n = 64, 32
    T = rng.standard_normal((dim, n)).astype(np.float32)
    q = rng.standard_normal(dim).astype(np.float32)
    acc = jnp.zeros((n,), jnp.float32)
    prev = np.zeros(n)
    for lo in range(0, dim, 8):
        acc = pdx_accumulate(jnp.asarray(T[lo : lo + 8]), jnp.asarray(q[lo : lo + 8]), acc, "l2")
        cur = np.asarray(acc)
        assert (cur >= prev - 1e-6).all()
        prev = cur
