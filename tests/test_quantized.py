"""Quantized device mirrors + fused executors: the dtype policy
(``core.layout.device_mirror``), the fused-scan/fused-batch executors at
f32/bf16/int8 with both kernel bodies (Pallas interpret mode gates the
kernels on CPU), exact-recall-after-re-rank on seed datasets incl. a
churned ``MutablePDXStore``, and the 8-fake-device sharded paths scanning
bf16/int8 mirrors (see tests/test_dist.py for the subprocess harness)."""
import numpy as np
import pytest

from repro.core.engine import SearchSpec, VectorSearchEngine
from repro.core.layout import device_mirror
from repro.core.plan import plan_search
from repro.data.synthetic import ground_truth, make_dataset, recall_at_k

from test_dist import run_devices

DTYPES = ("f32", "bf16", "int8")


# ----------------------------------------------------------------- spec knobs
def test_spec_validates_scan_knobs():
    assert SearchSpec().scan_dtype == "f32"
    assert SearchSpec(scan_dtype="int8", kernel="pallas").rerank_mult == 4
    for bad in (
        dict(scan_dtype="fp8"), dict(kernel="cuda"), dict(rerank_mult=0),
    ):
        with pytest.raises(ValueError):
            SearchSpec(**bad)


# -------------------------------------------------------------- device mirror
def test_device_mirror_caching_and_versions():
    X, _ = make_dataset(600, 24, "normal", n_queries=1, seed=0)
    eng = VectorSearchEngine.build(X, pruner="linear", capacity=128)
    eng.insert(np.zeros((1, 24), np.float32))  # upgrade to mutable first
    m1 = device_mirror(eng.store, "int8")
    assert m1.data.dtype == np.int8 and m1.bytes_per_value == 1
    assert device_mirror(eng.store, "int8") is m1  # cached per version
    assert device_mirror(eng.store, "bf16").bytes_per_value == 2

    # head-only insert: sealed tiles untouched -> same mirror object
    eng.insert(np.ones((1, 24), np.float32))
    assert device_mirror(eng.store, "int8") is m1
    # compact moves sealed tiles -> stale entries evicted, fresh quantization
    eng.compact()
    m2 = device_mirror(eng.store, "int8")
    assert m2 is not m1 and m2.tiles_version == eng.store.tiles_version
    assert all(
        k[1] == eng.store.tiles_version for k in eng.store._mirror_cache
    )

    with pytest.raises(ValueError, match="scan dtype"):
        device_mirror(eng.store, "fp64")


def test_int8_mirror_roundtrip_error_bounded():
    """Exact-range quantization: reconstruction error of live values is at
    most half a quantization step of the observed per-dim deviation."""
    X, _ = make_dataset(2000, 16, "skewed", n_queries=1, seed=3)  # heavy tails
    eng = VectorSearchEngine.build(X, pruner="linear", capacity=256)
    m = device_mirror(eng.store, "int8")
    T = np.asarray(eng.store.data)
    live = np.asarray(eng.store.ids) >= 0
    deq = (np.asarray(m.data, np.float32)
           * np.asarray(m.scale)[None, :, None]
           + np.asarray(m.offset)[None, :, None])
    err = np.abs(deq - T)[np.broadcast_to(live[:, None, :], T.shape)]
    step = np.asarray(m.scale).max()
    assert err.max() <= step / 2 + 1e-5  # no clipping, ever


# ---------------------------------------------------- fused executor parity
@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("kernel", ["pallas", "jnp"])
def test_fused_executors_exact_on_nonaligned_store(dtype, kernel, rng):
    """fused-scan + fused-batch vs brute-force ground truth at non-aligned
    D with PAD lanes (n % capacity != 0): recall@k == 1.0 after the f32
    re-rank, and bf16 returns bitwise-identical ids to ground truth."""
    X, Q = make_dataset(1900, 50, "normal", n_queries=4, seed=7)
    gt_ids, gt_d = ground_truth(X, Q, k=5)
    eng = VectorSearchEngine.build(X, pruner="adsampling", capacity=256)
    spec = SearchSpec(k=5, scan_dtype=dtype, kernel=kernel)

    res = eng.search(Q, spec.replace(executor="fused-batch"))
    assert recall_at_k(res.ids, gt_ids) == 1.0, (dtype, kernel, res.ids)
    if dtype == "bf16":
        np.testing.assert_array_equal(res.ids, gt_ids)  # bitwise-equal ids
    if dtype != "f32":  # re-ranked distances are exact f32
        np.testing.assert_allclose(
            np.sort(res.dists, axis=1), np.sort(gt_d, axis=1),
            rtol=1e-4, atol=1e-3,
        )

    r1 = eng.search(Q[0], spec.replace(executor="fused-scan"))
    assert set(r1.ids.tolist()) == set(gt_ids[0].tolist()), (dtype, kernel)


def test_fused_planner_dispatch():
    X, _ = make_dataset(512, 16, "normal", n_queries=1, seed=1)
    store = VectorSearchEngine.build(X, pruner="linear", capacity=128).store
    spec = SearchSpec(k=5)

    # default CPU dispatch is unchanged (kernel="auto" resolves to jnp)
    assert plan_search(spec, store, 1).executor == "adaptive"
    assert plan_search(spec, store, 4).executor == "batch-matmul"
    # forcing pallas or requesting a mirror dtype engages the fused path
    p = plan_search(spec.replace(kernel="pallas"), store, 1)
    assert p.executor == "fused-scan" and "pallas" in p.reason
    p = plan_search(spec.replace(scan_dtype="bf16"), store, 4)
    assert p.executor == "fused-batch" and "bf16" in p.reason
    # non-l2 single queries take the batch kernel (megakernel is L2-only)
    p = plan_search(spec.replace(scan_dtype="int8", metric="ip"), store, 1)
    assert p.executor == "fused-batch"
    p = plan_search(spec.replace(scan_dtype="int8"), store, 1)
    assert p.executor == "fused-scan"


def test_fused_scan_rejects_non_l2():
    X, Q = make_dataset(400, 16, "normal", n_queries=1, seed=2)
    eng = VectorSearchEngine.build(X, pruner="linear", capacity=128)
    with pytest.raises(ValueError, match="L2-only"):
        eng.search(Q[0], SearchSpec(k=3, metric="l1",
                                    executor="fused-scan"))


def test_fused_ivf_store_scans_exactly(rng):
    """With an IVF engine the fused executors scan every bucket (exact),
    and fused-scan seeds its threshold from the routed nearest bucket."""
    X, Q = make_dataset(2048, 32, "clustered", n_queries=3, seed=4)
    gt_ids, _ = ground_truth(X, Q, k=5)
    eng = VectorSearchEngine.build(
        X, index="ivf", pruner="adsampling", capacity=128, nlist=8,
    )
    for dtype in ("bf16", "int8"):
        spec = SearchSpec(k=5, scan_dtype=dtype, kernel="jnp")
        res = eng.search(Q, spec)
        assert res.plan.executor == "fused-batch", res.plan
        assert recall_at_k(res.ids, gt_ids) == 1.0, dtype
        r1 = eng.search(Q[0], spec)
        assert r1.plan.executor == "fused-scan", r1.plan
        assert set(r1.ids.tolist()) == set(gt_ids[0].tolist()), dtype


# ------------------------------------------------------------- churned store
def test_fused_executors_on_churned_mutable_store():
    """A churned MutablePDXStore answers through the quantized fused path
    exactly like a store rebuilt from the survivors: write-head rows are
    merged exactly, tombstones never surface, and the mirror re-quantizes
    only when sealed tiles change."""
    X, Q = make_dataset(1500, 24, "normal", n_queries=3, seed=9)
    eng = VectorSearchEngine.build(X, pruner="linear", capacity=128)
    rows = {i: X[i] for i in range(len(X))}
    rng = np.random.default_rng(5)
    new = rng.standard_normal((40, 24)).astype(np.float32)
    ids = eng.insert(new)
    for r, i in enumerate(ids):
        rows[int(i)] = new[r]
    dels = rng.choice(1500, size=200, replace=False)
    eng.delete(dels)
    for i in dels:
        rows.pop(int(i), None)

    im = np.asarray(sorted(rows))
    Xs = np.stack([rows[i] for i in sorted(rows)])
    gt_ids, _ = ground_truth(Xs, Q, k=5)

    def check():
        for dtype in ("bf16", "int8"):
            res = eng.search(
                Q, SearchSpec(k=5, scan_dtype=dtype, kernel="jnp"))
            assert res.plan.executor == "fused-batch", res.plan
            got = np.searchsorted(im, np.asarray(res.ids))
            assert recall_at_k(got, gt_ids) == 1.0, dtype

    check()          # mid-churn: head merged exactly, tombstones invisible
    v0 = eng.store.tiles_version
    eng.compact()
    assert eng.store.tiles_version > v0
    check()          # post-compact: mirror rebuilt from the new tiles


# ------------------------------------------------- sharded mirrors (8 dev)
def test_routed_bucket_bf16_parity_8dev():
    """Satellite: the routed-bucket path scanning a bf16 mirror returns the
    true top-k at full probe and agrees with the f32 routed run at partial
    probe, on the seed dataset."""
    run_devices("""
    from repro.core.engine import SearchSpec, VectorSearchEngine
    from repro.data.synthetic import make_dataset, ground_truth, recall_at_k

    X, Q = make_dataset(2048, 32, "clustered", n_queries=6, seed=0)
    nlist = 16
    mesh = jax.make_mesh((8,), ("data",))
    eng = VectorSearchEngine.build(X, index="ivf", pruner="linear",
                                   capacity=64, nlist=nlist, mesh=mesh)
    gt_ids, gt_d = ground_truth(X, Q, k=5)

    for dt in ("bf16", "int8"):
        res = eng.search(Q, SearchSpec(k=5, nprobe=nlist, scan_dtype=dt))
        assert res.plan.executor == "routed_bucket", res.plan
        assert recall_at_k(res.ids, gt_ids) == 1.0, dt
        np.testing.assert_allclose(   # re-ranked dists are exact f32
            np.sort(res.dists, axis=1), np.sort(gt_d, axis=1),
            rtol=1e-4, atol=1e-3)

    for nprobe in (1, 4):
        rf = eng.search(Q, SearchSpec(k=5, nprobe=nprobe))
        rq = eng.search(Q, SearchSpec(k=5, nprobe=nprobe,
                                      scan_dtype="bf16"))
        for qi in range(len(Q)):
            assert set(rq.ids[qi].tolist()) == set(rf.ids[qi].tolist()), \
                (nprobe, qi)
    print("OK")
    """)


def test_batch_block_sharded_quantized_one_allgather_8dev():
    """The quantized batch-block path still issues exactly ONE all-gather
    per batch (carrying exact f32 candidates — see pdx_sharded for why the
    wire is not rounded), and matches ground truth after its on-shard f32
    re-rank."""
    run_devices("""
    from repro.core.engine import SearchSpec, VectorSearchEngine
    from repro.core.layout import build_flat_store, device_mirror
    from repro.core.plan import _get_placement
    from repro.data.synthetic import make_dataset, ground_truth, recall_at_k
    from repro.dist.pdx_sharded import (collective_counts,
                                        search_batch_block_sharded)

    X, Q = make_dataset(2048, 32, "normal", n_queries=8, seed=0)
    gt_ids, _ = ground_truth(X, Q, k=5)
    mesh = jax.make_mesh((8,), ("data",))
    eng = VectorSearchEngine.build(X, pruner="linear", capacity=128,
                                   mesh=mesh)
    for dt in ("bf16", "int8"):
        res = eng.search(Q, SearchSpec(k=5, scan_dtype=dt))
        assert res.plan.executor == "batch-block-sharded", res.plan
        assert recall_at_k(res.ids, gt_ids) == 1.0, dt

    pl = _get_placement(eng.store, 8, "block")
    mirror = device_mirror(eng.store, "int8")
    counts = collective_counts(
        lambda qq: search_batch_block_sharded(
            mesh, Q=qq, k=5, placement=pl, mirror=mirror),
        jnp.asarray(Q))
    assert counts == {"all_gather": 1}, counts
    print("OK")
    """)
