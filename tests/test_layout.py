"""PDX layout: transposition round-trips, padding, bucketed packing."""
import numpy as np
import pytest

from repro.core.layout import (
    PAD_VALUE,
    build_bucketed_store,
    build_flat_store,
    pdx_to_nary,
)


@pytest.mark.parametrize("n,dim,cap", [(100, 16, 32), (257, 7, 64), (64, 128, 64)])
def test_flat_roundtrip(n, dim, cap, rng):
    X = rng.standard_normal((n, dim)).astype(np.float32)
    store = build_flat_store(X, capacity=cap)
    assert store.dim == dim
    assert store.capacity == cap
    assert store.num_vectors == n
    np.testing.assert_array_equal(pdx_to_nary(store), X)


def test_flat_padding_is_sentinel(rng):
    X = rng.standard_normal((10, 4)).astype(np.float32)
    store = build_flat_store(X, capacity=8)
    data = np.asarray(store.data)
    ids = np.asarray(store.ids)
    # second partition holds 2 vectors + 6 pads
    assert int(store.counts[1]) == 2
    assert (ids[1, 2:] == -1).all()
    assert (data[1, :, 2:] == PAD_VALUE).all()


def test_bucketed_layout_groups_by_bucket(rng):
    X = rng.standard_normal((200, 8)).astype(np.float32)
    assign = rng.integers(0, 5, size=200)
    store, offsets, nparts = build_bucketed_store(X, assign, 5, capacity=32)
    # every bucket's vectors appear exactly in its partitions
    ids = np.asarray(store.ids)
    for b in range(5):
        mine = set(np.nonzero(assign == b)[0].tolist())
        got = set()
        for p in range(offsets[b], offsets[b] + nparts[b]):
            got |= set(i for i in ids[p].tolist() if i >= 0)
        assert got == mine
    np.testing.assert_allclose(
        np.sort(pdx_to_nary(store), axis=0), np.sort(X, axis=0)
    )


def test_empty_bucket_gets_zero_partitions(rng):
    """Empty buckets must cost zero scan work: no partition at all (they used
    to emit a full all-PAD_VALUE tile each — wasted DMA + FLOPs per query)."""
    X = rng.standard_normal((50, 4)).astype(np.float32)
    assign = np.zeros(50, dtype=np.int64)  # bucket 1 and 2 empty
    store, offsets, nparts = build_bucketed_store(X, assign, 3, capacity=64)
    assert nparts[1] == 0 and nparts[2] == 0
    assert store.num_partitions == 1
    assert offsets.tolist() == [0, 1, 1]


def test_metadata_matches_collection(rng):
    X = rng.standard_normal((500, 12)).astype(np.float32) * 3 + 1
    store = build_flat_store(X, capacity=128)
    np.testing.assert_allclose(np.asarray(store.dim_means), X.mean(0), rtol=1e-5)
    np.testing.assert_allclose(
        np.asarray(store.dim_vars), X.var(0), rtol=1e-4, atol=1e-5
    )
