"""Per-architecture smoke tests: reduced same-family config, one forward /
train-grad step + prefill + decode on CPU; output shapes + finite values.
The FULL configs are exercised only via the dry-run (no allocation).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, list_configs
from repro.launch.specs import make_concrete_batch, text_len
from repro.models.lm import build_model

ARCHS = [
    "internvl2-1b",
    "llama3.2-3b",
    "gemma-2b",
    "qwen2-72b",
    "granite-3-8b",
    "deepseek-v3-671b",
    "deepseek-moe-16b",
    "jamba-v0.1-52b",
    "mamba2-370m",
    "whisper-small",
]

SEQ, BATCH = 32, 2


def _finite(tree):
    return all(
        bool(jnp.isfinite(x).all()) for x in jax.tree.leaves(tree)
    )


def test_registry_has_all_assigned():
    assert set(ARCHS) <= set(list_configs())


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_smoke(arch):
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    batch = make_concrete_batch(cfg, SEQ, BATCH, "train")
    loss, grads = jax.jit(jax.value_and_grad(model.loss))(params, batch)
    assert loss.shape == ()
    assert np.isfinite(float(loss))
    assert _finite(grads), f"non-finite grads for {arch}"


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_then_decode_smoke(arch):
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.key(1))
    cache_len = SEQ + 8
    batch = make_concrete_batch(cfg, SEQ, BATCH, "prefill")
    logits, caches = jax.jit(
        lambda p, b: model.prefill(p, b, cache_len)
    )(params, batch)
    assert logits.shape == (BATCH, cfg.vocab)
    assert np.isfinite(np.asarray(logits)).all()

    # decode two tokens from the prefill cache
    pos = text_len(cfg, SEQ) + (cfg.n_patches if cfg.vlm else 0)
    step = jax.jit(model.decode_step)
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    for t in range(2):
        logits2, caches = step(params, tok, caches, pos + t)
        assert logits2.shape == (BATCH, cfg.vocab)
        assert np.isfinite(np.asarray(logits2)).all()
        tok = jnp.argmax(logits2, -1)[:, None].astype(jnp.int32)


@pytest.mark.parametrize("arch", ["llama3.2-3b", "mamba2-370m"])
def test_decode_matches_teacher_forcing(arch):
    """Prefill+decode must agree with the parallel (train-mode) forward."""
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.key(2))
    S = 16
    batch = make_concrete_batch(cfg, S, 1, "train")
    h = model.forward_train(params, batch, remat=False)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits_par = np.asarray(h[:, -1, :] @ head)

    pre = {"tokens": batch["tokens"][:, : S - 1]}
    if cfg.vlm:
        pre["vision_embeds"] = batch["vision_embeds"]
    _, caches = model.prefill(params, pre, S + 4)
    logits_dec, _ = model.decode_step(
        params, batch["tokens"][:, S - 1 :], caches, S - 1
    )
    np.testing.assert_allclose(
        logits_par, np.asarray(logits_dec), rtol=2e-2, atol=2e-3
    )


def test_full_configs_have_exact_assigned_dims():
    spec = {
        "internvl2-1b": (24, 896, 14, 2, 4864, 151655),
        "llama3.2-3b": (28, 3072, 24, 8, 8192, 128256),
        "gemma-2b": (18, 2048, 8, 1, 16384, 256000),
        "qwen2-72b": (80, 8192, 64, 8, 29568, 152064),
        "granite-3-8b": (40, 4096, 32, 8, 12800, 49155),
        "deepseek-v3-671b": (61, 7168, 128, 128, 2048, 129280),
        "deepseek-moe-16b": (28, 2048, 16, 16, 1408, 102400),
        "jamba-v0.1-52b": (32, 4096, 32, 8, 14336, 65536),
        "mamba2-370m": (48, 1024, 0, 0, 0, 50280),
        "whisper-small": (12, 768, 12, 12, 3072, 51865),
    }
    for name, (L, d, H, Hkv, ff, V) in spec.items():
        c = get_config(name)
        assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff, c.vocab) == (
            L, d, H, Hkv, ff, V,
        ), name
    assert get_config("deepseek-v3-671b").n_experts == 256
    assert get_config("deepseek-v3-671b").top_k == 8
    assert get_config("deepseek-moe-16b").n_experts == 64
    assert get_config("deepseek-moe-16b").top_k == 6
    assert get_config("jamba-v0.1-52b").n_experts == 16
    assert get_config("mamba2-370m").ssm_state == 128
