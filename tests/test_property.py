"""Property-based tests for the system's invariants.

Prefers the real ``hypothesis`` package (``pip install .[test]``); falls
back to the vendored seeded-sweep shim (``tests/minihyp.py``) so the suite
never skips these invariants in environments without it."""
import jax.numpy as jnp
import numpy as np

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # vendored fallback: deterministic seeded sweep
    from minihyp import given, settings, strategies as st  # noqa: F401

from repro.core.distance import nary_distance, pdx_distance
from repro.core.engine import SearchSpec, VectorSearchEngine
from repro.core.layout import MutablePDXStore, build_flat_store, pdx_to_nary
from repro.core.pdxearch import make_boundaries
from repro.core.pruners import make_adsampling, make_bond, random_orthogonal
from repro.core.topk import topk_init, topk_merge

SETTINGS = settings(max_examples=25, deadline=None)


@SETTINGS
@given(
    n=st.integers(1, 200),
    dim=st.integers(1, 64),
    cap=st.sampled_from([16, 64, 128]),
    seed=st.integers(0, 2**31 - 1),
)
def test_layout_roundtrip_property(n, dim, cap, seed):
    rng = np.random.default_rng(seed)
    X = rng.standard_normal((n, dim)).astype(np.float32)
    np.testing.assert_array_equal(pdx_to_nary(build_flat_store(X, capacity=cap)), X)


@SETTINGS
@given(
    n=st.integers(2, 100),
    dim=st.integers(2, 48),
    seed=st.integers(0, 2**31 - 1),
    metric=st.sampled_from(["l2", "l1", "ip"]),
)
def test_layout_invariance_of_distance(n, dim, seed, metric):
    """Distance must not depend on the storage layout."""
    rng = np.random.default_rng(seed)
    X = rng.standard_normal((n, dim)).astype(np.float32)
    q = rng.standard_normal(dim).astype(np.float32)
    a = np.asarray(nary_distance(jnp.asarray(X), jnp.asarray(q), metric))
    b = np.asarray(pdx_distance(jnp.asarray(X.T), jnp.asarray(q), metric))
    np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-4)


@SETTINGS
@given(dim=st.integers(2, 96), seed=st.integers(0, 10_000))
def test_random_orthogonal_is_isometry(dim, seed):
    P = random_orthogonal(dim, seed)
    np.testing.assert_allclose(P @ P.T, np.eye(dim), atol=1e-4)
    rng = np.random.default_rng(seed)
    x, y = rng.standard_normal((2, dim)).astype(np.float32)
    d0 = ((x - y) ** 2).sum()
    d1 = ((P @ x - P @ y) ** 2).sum()
    np.testing.assert_allclose(d0, d1, rtol=1e-3)


@SETTINGS
@given(dim=st.integers(1, 4096))
def test_boundaries_cover_every_dim_once(dim):
    for sched, dd in [("adaptive", 32), ("fixed", 32), ("fixed", 7)]:
        b = make_boundaries(dim, sched, dd)
        assert b[-1] == dim
        assert all(x < y for x, y in zip(b, b[1:]))  # strictly increasing


@SETTINGS
@given(
    k=st.integers(1, 16),
    m=st.integers(1, 64),
    seed=st.integers(0, 2**31 - 1),
)
def test_topk_merge_equals_global_topk(k, m, seed):
    rng = np.random.default_rng(seed)
    d1 = rng.standard_normal(m).astype(np.float32) ** 2
    d2 = rng.standard_normal(m).astype(np.float32) ** 2
    i1 = np.arange(m, dtype=np.int32)
    i2 = np.arange(m, 2 * m, dtype=np.int32)
    s = topk_init(k)
    s = topk_merge(s, jnp.asarray(d1), jnp.asarray(i1))
    s = topk_merge(s, jnp.asarray(d2), jnp.asarray(i2))
    alld = np.concatenate([d1, d2])
    want = np.sort(alld)[: min(k, 2 * m)]
    got = np.asarray(s.dists)[: min(k, 2 * m)]
    np.testing.assert_allclose(got, want, rtol=1e-6)


@SETTINGS
@given(
    seed=st.integers(0, 2**31 - 1),
    thr_scale=st.floats(0.1, 10.0),
    d_seen=st.integers(1, 64),
)
def test_adsampling_keep_mask_monotone_in_threshold(seed, thr_scale, d_seen):
    """A vector kept at threshold t must be kept at any t' > t."""
    dim = 64
    pr = make_adsampling(dim, eps0=2.1, seed=0)
    rng = np.random.default_rng(seed)
    partial = jnp.asarray(rng.uniform(0, 100, size=32).astype(np.float32))
    t = jnp.float32(thr_scale * 10)
    keep_lo = np.asarray(pr.keep_mask(partial, jnp.float32(d_seen), t))
    keep_hi = np.asarray(pr.keep_mask(partial, jnp.float32(d_seen), t * 2))
    assert np.all(keep_hi >= keep_lo)


_MUT_SETTINGS = settings(max_examples=10, deadline=None)


@_MUT_SETTINGS
@given(
    seed=st.integers(0, 2**31 - 1),
    ops=st.lists(st.sampled_from(["ins", "del", "repack"]), min_size=1,
                 max_size=8),
)
def test_mutable_store_always_matches_rebuilt_store(seed, ops):
    """After ANY interleaving of insert/delete/repack, search results equal a
    store rebuilt from scratch from the surviving vectors, and pdx_to_nary
    round-trips them (ids map via rank order since they are sparse)."""
    rng = np.random.default_rng(seed)
    dim, cap, k = 8, 32, 3
    X = rng.standard_normal((60, dim)).astype(np.float32)
    eng = VectorSearchEngine.build(X, pruner="linear", capacity=cap)
    eng.head_capacity = 8  # tiny head: flushes + free-slot reuse get exercised
    rows = {i: X[i] for i in range(len(X))}

    for op in ops:
        if op == "ins":
            V = rng.standard_normal((int(rng.integers(1, 12)), dim)).astype(
                np.float32
            )
            for r, i in enumerate(eng.insert(V)):
                rows[int(i)] = V[r]
        elif op == "del" and len(rows) > k:
            victims = rng.choice(
                sorted(rows), size=int(rng.integers(1, 6)), replace=False
            )
            eng.delete(victims)
            for i in victims:
                rows.pop(int(i), None)
        elif op == "repack":
            eng.compact()

    assert isinstance(eng.store, MutablePDXStore)
    im = np.asarray(sorted(rows))
    Xs = np.stack([rows[i] for i in sorted(rows)])
    np.testing.assert_array_equal(pdx_to_nary(eng.store), Xs)
    assert eng.store.num_vectors == len(rows)

    ref = VectorSearchEngine.build(Xs, pruner="linear", capacity=cap)
    q = rng.standard_normal(dim).astype(np.float32)
    for ex in ("adaptive", "jit-masked", "batch-matmul"):
        got = eng.search(q, SearchSpec(k=k, executor=ex))
        want = ref.search(q, SearchSpec(k=k, executor=ex))
        np.testing.assert_array_equal(
            np.searchsorted(im, got.ids), want.ids, err_msg=ex
        )


@SETTINGS
@given(seed=st.integers(0, 2**31 - 1), dim=st.integers(4, 64))
def test_bond_zone_order_is_permutation(seed, dim):
    rng = np.random.default_rng(seed)
    means = jnp.asarray(rng.standard_normal(dim).astype(np.float32))
    for zone in (0, 2, 3):
        pr = make_bond(means, zone_size=zone)
        q = jnp.asarray(rng.standard_normal(dim).astype(np.float32))
        perm = np.asarray(pr.dim_order(q))
        assert sorted(perm.tolist()) == list(range(dim))
