"""Shared fixtures. NOTE: no XLA_FLAGS device forcing here — tests must see
the single real CPU device (the 512-device mesh is dryrun.py-only).  Tests
that need multiple devices spawn subprocesses (see tests/test_dist.py).
"""
import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(1234)


def pytest_configure(config):
    config.addinivalue_line("markers", "slow: long-running integration test")
