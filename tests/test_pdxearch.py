"""PDXearch framework: exactness of exact pruners, recall of probabilistic
pruners, agreement between host-adaptive and jitted modes, stats accounting."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.engine import VectorSearchEngine
from repro.core.layout import build_flat_store
from repro.core.pdxearch import (
    SearchStats,
    make_boundaries,
    pdxearch,
    pdxearch_jit,
    search_batch_matmul,
)
from repro.core.pruners import make_adsampling, make_bond, make_plain_pruner
from repro.data.synthetic import ground_truth, make_dataset, recall_at_k


def test_boundaries_adaptive():
    assert make_boundaries(30) == (2, 6, 14, 30)
    assert make_boundaries(100) == (2, 6, 14, 30, 62, 100)
    assert make_boundaries(64, "fixed", 32) == (32, 64)
    assert make_boundaries(70, "fixed", 32) == (32, 64, 70)


@pytest.mark.parametrize("pruner_name", ["linear", "bond", "bond-decreasing"])
@pytest.mark.parametrize("kind", ["normal", "skewed"])
def test_exact_pruners_match_bruteforce(pruner_name, kind):
    X, Q = make_dataset(2000, 32, kind, n_queries=4, seed=7)
    gt_ids, gt_d = ground_truth(X, Q, k=10)
    eng = VectorSearchEngine.build(X, pruner=pruner_name, capacity=256)
    for qi, q in enumerate(Q):
        ids, dists = eng.search(q, k=10)
        np.testing.assert_allclose(
            np.sort(dists), np.sort(gt_d[qi]), rtol=1e-4, atol=1e-4
        )
        assert recall_at_k(ids[None], gt_ids[qi][None]) == 1.0


def test_adsampling_high_recall_normal_data():
    X, Q = make_dataset(4000, 64, "normal", n_queries=8, seed=3)
    gt_ids, _ = ground_truth(X, Q, k=10)
    eng = VectorSearchEngine.build(X, pruner="adsampling", capacity=512, eps0=2.1)
    recs = []
    for qi, q in enumerate(Q):
        ids, _ = eng.search(q, k=10)
        recs.append(recall_at_k(ids[None], gt_ids[qi][None]))
    assert np.mean(recs) >= 0.95, np.mean(recs)


def test_bsa_high_recall():
    X, Q = make_dataset(4000, 48, "clustered", n_queries=8, seed=4)
    gt_ids, _ = ground_truth(X, Q, k=10)
    eng = VectorSearchEngine.build(X, pruner="bsa", capacity=512, bsa_m=4.0)
    recs = []
    for qi, q in enumerate(Q):
        ids, _ = eng.search(q, k=10)
        recs.append(recall_at_k(ids[None], gt_ids[qi][None]))
    assert np.mean(recs) >= 0.9, np.mean(recs)


def test_jit_mode_matches_adaptive_mode_exact():
    X, Q = make_dataset(1500, 24, "skewed", n_queries=3, seed=9)
    store = build_flat_store(X, capacity=256)
    pruner = make_bond(store.dim_means)
    for q in Q:
        a = pdxearch(store, q, 5, pruner)
        b = pdxearch_jit(store, jnp.asarray(q), 5, pruner)
        np.testing.assert_allclose(
            np.sort(np.asarray(a.dists)), np.sort(np.asarray(b.dists)), rtol=1e-4
        )
        assert set(np.asarray(a.ids).tolist()) == set(np.asarray(b.ids).tolist())


def test_batched_matmul_search_exact():
    X, Q = make_dataset(3000, 40, "normal", n_queries=6, seed=2)
    gt_ids, gt_d = ground_truth(X, Q, k=10)
    store = build_flat_store(X, capacity=512)
    res = search_batch_matmul(store.data, store.ids, jnp.asarray(Q), 10)
    for qi in range(len(Q)):
        np.testing.assert_allclose(
            np.sort(np.asarray(res.dists[qi])), np.sort(gt_d[qi]), rtol=1e-3, atol=1e-2
        )


def test_stats_pruning_power_skewed_exceeds_zero():
    X, Q = make_dataset(4000, 64, "skewed", n_queries=2, seed=5)
    eng = VectorSearchEngine.build(X, pruner="bond", capacity=512)
    stats = SearchStats()
    eng.search(Q[0], k=10, stats=stats)
    assert 0.0 < stats.pruning_power <= 1.0
    assert stats.values_computed <= stats.values_total
    # accounting identity: computed + avoided <= total (untouched survivors'
    # remaining dims are both computed... avoided only counts pruned vectors)
    assert stats.values_avoided <= stats.values_total


def test_ivf_search_recall():
    X, Q = make_dataset(6000, 32, "clustered", n_queries=6, seed=11)
    gt_ids, _ = ground_truth(X, Q, k=10)
    eng = VectorSearchEngine.build(
        X, index="ivf", pruner="adsampling", capacity=128, nlist=32
    )
    recs = []
    for qi, q in enumerate(Q):
        ids, _ = eng.search(q, k=10, nprobe=16)
        recs.append(recall_at_k(ids[None], gt_ids[qi][None]))
    assert np.mean(recs) >= 0.9, np.mean(recs)


def test_ivf_full_probe_linear_is_exact():
    X, Q = make_dataset(2000, 16, "clustered", n_queries=3, seed=13)
    gt_ids, gt_d = ground_truth(X, Q, k=5)
    eng = VectorSearchEngine.build(
        X, index="ivf", pruner="linear", capacity=128, nlist=8
    )
    for qi, q in enumerate(Q):
        ids, dists = eng.search(q, k=5, nprobe=8)
        np.testing.assert_allclose(np.sort(dists), np.sort(gt_d[qi]), rtol=1e-4)
