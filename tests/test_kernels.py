"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.ops import (
    batched_distance_op,
    batched_distance_quant_op,
    nary_distance_op,
    pdx_distance_op,
    pdx_prune_scan_multi_op,
    pdx_prune_scan_op,
)

SHAPES = [(8, 64), (96, 128), (128, 1000), (384, 96), (33, 130)]
DTYPES = [jnp.float32, jnp.bfloat16]


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-1) if dtype == jnp.bfloat16 else dict(rtol=2e-5, atol=1e-4)


@pytest.mark.parametrize("metric", ["l2", "ip", "l1"])
@pytest.mark.parametrize("D,V", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_pdx_distance_kernel(metric, D, V, dtype, rng):
    T = jnp.asarray(rng.standard_normal((D, V)), dtype)
    q = jnp.asarray(rng.standard_normal(D), dtype)
    got = pdx_distance_op(T, q, metric)
    want = ref.pdx_distance_ref(T, q, metric)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), **_tol(dtype))


@pytest.mark.parametrize("metric", ["l2", "ip", "l1"])
@pytest.mark.parametrize("N,D", [(64, 8), (1000, 128), (130, 33)])
@pytest.mark.parametrize("dtype", DTYPES)
def test_nary_distance_kernel(metric, N, D, dtype, rng):
    X = jnp.asarray(rng.standard_normal((N, D)), dtype)
    q = jnp.asarray(rng.standard_normal(D), dtype)
    got = nary_distance_op(X, q, metric)
    want = ref.nary_distance_ref(X, q, metric)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), **_tol(dtype))


@pytest.mark.parametrize("metric", ["l2", "ip"])
@pytest.mark.parametrize("B,D,V", [(4, 32, 64), (16, 128, 256), (3, 50, 130)])
@pytest.mark.parametrize("dtype", DTYPES)
def test_batched_distance_kernel(metric, B, D, V, dtype, rng):
    T = jnp.asarray(rng.standard_normal((D, V)), dtype)
    Q = jnp.asarray(rng.standard_normal((B, D)), dtype)
    got = batched_distance_op(T, Q, metric)
    want = ref.batched_distance_ref(T, Q, metric)
    tol = dict(rtol=3e-2, atol=5e-1) if dtype == jnp.bfloat16 else dict(rtol=1e-4, atol=1e-3)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), **tol)


@pytest.mark.parametrize("D,V", [(64, 128), (128, 256), (96, 1000)])
@pytest.mark.parametrize("d_tile", [16, 32, 64])
def test_prune_scan_kernel_matches_ref(D, V, d_tile, rng):
    T = jnp.asarray(rng.standard_normal((D, V)), jnp.float32)
    q = jnp.asarray(rng.standard_normal(D), jnp.float32)
    # threshold near the 10th smallest distance so pruning actually happens
    full = np.asarray(ref.pdx_distance_ref(T, q))
    thr = jnp.float32(np.partition(full, 10)[10])
    got_d, got_a = pdx_prune_scan_op(T, q, thr, eps0=2.1, d_tile=d_tile)
    want_d, want_a = ref.pdx_prune_scan_ref(T, q, thr, d_tile=d_tile, eps0=2.1)
    np.testing.assert_allclose(np.asarray(got_d), np.asarray(want_d), rtol=1e-4, atol=1e-4)
    np.testing.assert_array_equal(np.asarray(got_a), np.asarray(want_a))


def test_prune_scan_never_prunes_nearest(rng):
    """Survivors must include the true nearest neighbour at sane eps0."""
    D, V = 128, 512
    T = jnp.asarray(rng.standard_normal((D, V)), jnp.float32)
    q = jnp.asarray(rng.standard_normal(D), jnp.float32)
    full = np.asarray(ref.pdx_distance_ref(T, q))
    thr = jnp.float32(np.partition(full, 10)[10])
    _, alive = pdx_prune_scan_op(T, q, thr, eps0=2.1)
    assert np.asarray(alive)[int(np.argmin(full))] == 1.0


def test_prune_scan_all_pruned_when_thr_zero(rng):
    D, V = 64, 256
    T = jnp.asarray(rng.standard_normal((D, V)) + 10.0, jnp.float32)
    q = jnp.asarray(np.zeros(D), jnp.float32)
    _, alive = pdx_prune_scan_op(T, q, jnp.float32(1e-3))
    assert np.asarray(alive).sum() == 0.0


def test_prune_scan_returns_bool_and_masks_pad_lanes(rng):
    """Satellite: alive is a bool mask (not the kernel's f32 encoding) and
    lanes whose ids are -1 (PAD columns) can never surface as survivors —
    even with an infinite threshold that keeps everything else alive."""
    D, V = 64, 130
    T = jnp.asarray(rng.standard_normal((D, V)), jnp.float32)
    q = jnp.asarray(rng.standard_normal(D), jnp.float32)
    ids = np.arange(V, dtype=np.int32)
    ids[5] = -1
    ids[-3:] = -1
    _, alive = pdx_prune_scan_op(T, q, jnp.float32(np.inf), jnp.asarray(ids))
    alive = np.asarray(alive)
    assert alive.dtype == np.bool_
    assert not alive[ids < 0].any()
    assert alive[ids >= 0].all()


# ---------------------------------------------------------------- megakernel
MULTI_SHAPES = [(2, 64, 128), (3, 50, 130), (4, 96, 1000)]


def _quantize(T, rng):
    """Per-dimension affine int8, exact-range (mirrors the layout policy)."""
    offset = T.mean(axis=(0, 2))
    dev = np.abs(T - offset[None, :, None]).max(axis=(0, 2))
    scale = np.maximum(dev, 1e-6) / 127.0
    q = np.clip(np.round((T - offset[None, :, None]) / scale[None, :, None]),
                -127, 127).astype(np.int8)
    return q, scale.astype(np.float32), offset.astype(np.float32)


@pytest.mark.parametrize("P,D,V", MULTI_SHAPES)
@pytest.mark.parametrize("use_pallas", [True, False])
def test_prune_scan_multi_matches_ref(P, D, V, use_pallas, rng):
    """Megakernel vs oracle at non-aligned D/V with PAD lanes, both bodies."""
    T = jnp.asarray(rng.standard_normal((P, D, V)), jnp.float32)
    q = jnp.asarray(rng.standard_normal(D), jnp.float32)
    ids = rng.integers(0, 10_000, (P, V)).astype(np.int32)
    ids[:, -7:] = -1
    ids[0, 3] = -1
    full = np.asarray(ref.pdx_distance_ref(T[1], q))
    thr = jnp.float32(np.partition(full, 10)[10])
    got_d, got_a = pdx_prune_scan_multi_op(
        T, jnp.asarray(ids), q, thr, use_pallas=use_pallas
    )
    want_d, want_a = ref.pdx_prune_scan_multi_ref(
        T, jnp.asarray(ids), q, thr, d_tile=min(64, D), eps0=2.1
    )
    assert np.asarray(got_a).dtype == np.bool_
    np.testing.assert_allclose(
        np.asarray(got_d), np.asarray(want_d), rtol=1e-4, atol=1e-4
    )
    np.testing.assert_array_equal(np.asarray(got_a), np.asarray(want_a) != 0)
    assert not np.asarray(got_a)[ids < 0].any()  # PAD lanes never survive


@pytest.mark.parametrize("use_pallas", [True, False])
def test_prune_scan_multi_quantized_operands(use_pallas, rng):
    """int8 operands dequantize in-register; bf16 casts — both match the
    oracle run on the same quantized values."""
    P, D, V = 3, 96, 130
    T = rng.standard_normal((P, D, V)).astype(np.float32)
    q = jnp.asarray(rng.standard_normal(D), jnp.float32)
    ids = rng.integers(0, 10_000, (P, V)).astype(np.int32)
    ids[:, -5:] = -1
    thr = jnp.float32(np.partition(
        np.asarray(ref.pdx_distance_ref(jnp.asarray(T[0]), q)), 10)[10])

    Tq, scale, offset = _quantize(T, rng)
    got_d, got_a = pdx_prune_scan_multi_op(
        jnp.asarray(Tq), jnp.asarray(ids), q, thr,
        jnp.asarray(scale), jnp.asarray(offset), use_pallas=use_pallas,
    )
    want_d, want_a = ref.pdx_prune_scan_multi_ref(
        jnp.asarray(Tq), jnp.asarray(ids), q, thr, d_tile=64, eps0=2.1,
        scale=jnp.asarray(scale), offset=jnp.asarray(offset),
    )
    np.testing.assert_allclose(
        np.asarray(got_d), np.asarray(want_d), rtol=1e-4, atol=1e-4
    )
    np.testing.assert_array_equal(np.asarray(got_a), np.asarray(want_a) != 0)

    Tb = jnp.asarray(T, jnp.bfloat16)
    got_d, got_a = pdx_prune_scan_multi_op(
        Tb, jnp.asarray(ids), q, thr, use_pallas=use_pallas
    )
    want_d, want_a = ref.pdx_prune_scan_multi_ref(
        Tb, jnp.asarray(ids), q, thr, d_tile=64, eps0=2.1
    )
    np.testing.assert_allclose(
        np.asarray(got_d), np.asarray(want_d), rtol=1e-4, atol=1e-4
    )
    np.testing.assert_array_equal(np.asarray(got_a), np.asarray(want_a) != 0)


@pytest.mark.parametrize("metric", ["l2", "ip"])
@pytest.mark.parametrize("B,D,V", [(4, 32, 64), (3, 50, 130)])
@pytest.mark.parametrize("use_pallas", [True, False])
def test_batched_distance_quant_kernel(metric, B, D, V, use_pallas, rng):
    T = rng.standard_normal((1, D, V)).astype(np.float32)
    Q = jnp.asarray(rng.standard_normal((B, D)), jnp.float32)
    Tq, scale, offset = _quantize(T, rng)
    got = batched_distance_quant_op(
        jnp.asarray(Tq[0]), Q, jnp.asarray(scale), jnp.asarray(offset),
        metric, use_pallas,
    )
    want = ref.batched_distance_quant_ref(
        jnp.asarray(Tq[0]), Q, jnp.asarray(scale), jnp.asarray(offset),
        metric,
    )
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), rtol=1e-3, atol=1e-3
    )
    # bf16 operands without dequant vectors
    Tb = jnp.asarray(T[0], jnp.bfloat16)
    got = batched_distance_quant_op(Tb, Q, metric=metric,
                                    use_pallas=use_pallas)
    want = ref.batched_distance_quant_ref(Tb, Q, metric=metric)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), rtol=3e-2, atol=5e-1
    )


@pytest.mark.parametrize("use_pallas", [True, False])
def test_prune_scan_prefetch_dtile_skip(use_pallas, rng):
    """The prefetch-skip wrapper returns (dists, alive, streamed) matching
    the d-skip oracle on both bodies; entry-dead partitions stream zero
    tiles, partitions whose last lane dies mid-scan stop at that tile, and
    the realized d-tile byte model never exceeds (and here strictly
    undercuts) the partition-granular model."""
    from repro.kernels.ops import pdx_prune_scan_multi_prefetch_op

    P, D, V = 5, 200, 130
    T = rng.standard_normal((P, D, V)).astype(np.float32)
    # partition 0 is near the query (survives), the rest drift further out
    # so whole partitions and individual lanes die at varying tiles
    q = T[0, :, 3] + rng.standard_normal(D).astype(np.float32) * 0.01
    for p in range(1, P):
        T[p] += p * 0.8
    ids = rng.integers(0, 10_000, (P, V)).astype(np.int32)
    ids[:, -7:] = -1
    ids[4] = -1  # entry-dead partition: must stream nothing
    full = np.asarray(ref.pdx_distance_ref(jnp.asarray(T[0]), jnp.asarray(q)))
    thr = jnp.float32(np.partition(full, 10)[10])
    got_d, got_a, got_s = pdx_prune_scan_multi_prefetch_op(
        jnp.asarray(T), jnp.asarray(ids), jnp.asarray(q), thr,
        use_pallas=use_pallas,
    )
    want_d, want_a, want_s = ref.pdx_prune_scan_multi_dskip_ref(
        jnp.asarray(T), jnp.asarray(ids), jnp.asarray(q), thr,
        d_tile=64, eps0=2.1,
    )
    np.testing.assert_allclose(
        np.asarray(got_d)[np.asarray(got_a)],
        np.asarray(want_d)[np.asarray(got_a)], rtol=1e-4, atol=1e-4,
    )
    np.testing.assert_array_equal(np.asarray(got_a), np.asarray(want_a) != 0)
    np.testing.assert_array_equal(np.asarray(got_s), np.asarray(want_s))
    s = np.asarray(got_s)
    assert s[4] == 0.0
    n_tiles = -(-D // 64)
    dtile_bytes = np.minimum(s * 64, D).sum() * V * 4
    part_bytes = (s > 0).sum() * D * V * 4
    assert dtile_bytes <= part_bytes
    # the drifted partitions die mid-scan: the d-tile model must realize
    # a strict saving over partition-granular skip on this data
    assert (s[(s > 0)] < n_tiles).any()
    assert dtile_bytes < part_bytes
