"""dist.hints off-mesh behavior: outside an ``activation_sharding`` context
(single CPU device, no mesh) every hint must be an exact identity — same
values, no resharding errors — both eagerly and under jit."""
import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.dist import hints


def _arrays():
    key = jax.random.key(0)
    return {
        "act": jax.random.normal(key, (2, 8, 16)),          # (B, S, d)
        "heads": jax.random.normal(key, (2, 8, 4, 4)),      # (B, S, H, hd)
        "ffn_hidden": jax.random.normal(key, (2, 8, 32)),   # (B, S, f)
    }


def test_hints_are_identity_off_mesh_eager():
    for name, x in _arrays().items():
        y = getattr(hints, name)(x)
        assert y is x, f"{name} must return its input unchanged off-mesh"


def test_hints_are_identity_off_mesh_under_jit():
    for name, x in _arrays().items():
        fn = getattr(hints, name)
        y = jax.jit(fn)(x)
        np.testing.assert_array_equal(np.asarray(y), np.asarray(x))


def test_hints_identity_values_inside_single_device_mesh():
    """On a 1x1 mesh the constraint is trivially satisfiable: same values."""
    devs = np.array(jax.devices()[:1]).reshape(1, 1)
    mesh = jax.sharding.Mesh(devs, ("data", "model"))
    for name, x in _arrays().items():
        fn = getattr(hints, name)
        with hints.activation_sharding(mesh):
            y = jax.jit(fn)(x)
        np.testing.assert_array_equal(np.asarray(y), np.asarray(x))
    # context restored: hints are identities again
    for name, x in _arrays().items():
        assert getattr(hints, name)(x) is x


def test_activation_sharding_context_is_reentrant_and_restores():
    devs = np.array(jax.devices()[:1]).reshape(1, 1)
    mesh = jax.sharding.Mesh(devs, ("data", "model"))
    x = jnp.ones((2, 4, 8))
    assert not hints._ACTIVE
    with hints.activation_sharding(mesh, ("data",)):
        with hints.activation_sharding(mesh):
            assert len(hints._ACTIVE) == 2
            np.testing.assert_array_equal(np.asarray(hints.act(x)), np.asarray(x))
        assert len(hints._ACTIVE) == 1
    assert not hints._ACTIVE
    assert hints.act(x) is x


def test_divisibility_guard_drops_unfit_axes_in_hints():
    """Head count not divisible by the model axis -> hint falls back to a
    batch-only constraint instead of erroring (guard shared w/ sharding)."""
    from repro.dist.sharding import _divisible

    class FakeMesh:
        shape = {"data": 2, "model": 16}

    spec = _divisible(P("data", None, "model", None), (4, 8, 6, 4), FakeMesh())
    assert spec == P("data", None, None, None)
