"""Distributed substrate tests — run in subprocesses with 8 fake CPU devices
(XLA_FLAGS device-count forcing is process-global, so it must not leak into
this test process; see conftest note)."""
import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_devices(body: str, n: int = 8) -> str:
    code = textwrap.dedent(
        f"""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={n}"
        import jax, numpy as np, jax.numpy as jnp
        assert jax.device_count() == {n}, jax.devices()
        """
    ) + textwrap.dedent(body)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    r = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True,
        timeout=600, env=env,
    )
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    return r.stdout


def test_block_sharded_search_matches_single_device():
    run_devices("""
    from jax.sharding import Mesh
    from repro.core.layout import build_flat_store
    from repro.core.pdxearch import search_batch_matmul
    from repro.dist.pdx_sharded import search_block_sharded
    from repro.data.synthetic import make_dataset, ground_truth

    X, Q = make_dataset(2048, 32, "normal", n_queries=2, seed=0)
    store = build_flat_store(X, capacity=128)  # 16 partitions -> 2/device
    mesh = jax.make_mesh((8,), ("data",))
    gt_ids, gt_d = ground_truth(X, Q, k=5)
    for qi, q in enumerate(Q):
        res = search_block_sharded(mesh, store.data, store.ids, jnp.asarray(q), 5)
        np.testing.assert_allclose(np.sort(np.asarray(res.dists)),
                                   np.sort(gt_d[qi]), rtol=1e-4)
    print("OK")
    """)


def test_dim_sharded_search_matches_single_device():
    run_devices("""
    from jax.sharding import Mesh
    from repro.core.layout import build_flat_store
    from repro.dist.pdx_sharded import search_dim_sharded
    from repro.data.synthetic import make_dataset, ground_truth

    X, Q = make_dataset(1024, 64, "skewed", n_queries=2, seed=1)  # D=64 /8
    store = build_flat_store(X, capacity=256)
    mesh = jax.make_mesh((8,), ("model",))
    gt_ids, gt_d = ground_truth(X, Q, k=5)
    for qi, q in enumerate(Q):
        res = search_dim_sharded(mesh, store.data, store.ids, jnp.asarray(q), 5)
        np.testing.assert_allclose(np.sort(np.asarray(res.dists)),
                                   np.sort(gt_d[qi]), rtol=1e-4)
    print("OK")
    """)


def test_pipeline_parallel_matches_sequential():
    run_devices("""
    from jax.sharding import Mesh
    from repro.dist.pipeline import pipeline_apply

    n_stages, n_micro, mb, d = 8, 6, 4, 16
    mesh = jax.make_mesh((8,), ("stage",))
    key = jax.random.key(0)
    ws = jax.random.normal(key, (n_stages, d, d)) * 0.3

    def stage_fn(w, x):
        return jnp.tanh(x @ w)

    x = jax.random.normal(jax.random.key(1), (n_micro, mb, d))
    got = pipeline_apply(mesh, stage_fn, ws, x)
    want = x
    for s in range(n_stages):
        want = jnp.tanh(want @ ws[s])
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-5)
    print("OK")
    """)


def test_compressed_psum_dp_grads():
    run_devices("""
    from jax.sharding import Mesh, PartitionSpec as P
    from jax.experimental.shard_map import shard_map
    from repro.train.compression import compressed_psum

    mesh = jax.make_mesh((8,), ("data",))
    g = jax.random.normal(jax.random.key(0), (8, 64)) * 0.01

    def local(gl):
        return compressed_psum({"g": gl[0]}, "data")["g"]

    fn = shard_map(local, mesh=mesh, in_specs=(P("data"),), out_specs=P(),
                   check_rep=False)
    got = np.asarray(jax.jit(fn)(g))
    want = np.asarray(jnp.mean(g, axis=0))
    err = np.abs(got - want).max()
    scale = float(jnp.abs(g).max()) / 127.0
    assert err <= scale * 1.5 + 1e-7, (err, scale)
    print("OK")
    """)


def test_gspmd_train_step_8dev_fsdp_tp():
    """End-to-end: tiny model, (2,4) data x model mesh, sharded params+batch,
    one jitted train step under GSPMD — the mini version of the dry-run."""
    run_devices("""
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
    from repro.configs import get_config
    from repro.models.lm import build_model
    from repro.train.trainer import TrainConfig, make_train_step
    from repro.train.optimizer import OptConfig, opt_init
    from repro.dist.sharding import param_shardings, batch_shardings
    from repro.data.pipeline import TokenStream

    cfg = get_config("llama3.2-3b").reduced()
    model = build_model(cfg)
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    params = model.init(jax.random.key(0))
    oc = OptConfig(warmup_steps=0)
    opt = opt_init(params, oc)
    ps = param_shardings(params, mesh, cfg)
    params = jax.device_put(params, ps)
    opt = jax.device_put(opt, jax.tree.map(
        lambda s: s, {"mu": ps, "nu": ps,
                      "step": NamedSharding(mesh, P())}))
    stream = TokenStream(cfg, 16, 4, seed=0)
    b = {k: jnp.asarray(v) for k, v in stream.batch_at(0).items()}
    b = jax.device_put(b, batch_shardings(b, mesh))
    step = jax.jit(make_train_step(model, TrainConfig(opt=oc)))
    p2, o2, m = step(params, opt, b)
    assert np.isfinite(float(m["loss"]))
    print("OK", float(m["loss"]))
    """)


def test_elastic_checkpoint_restore_onto_mesh(tmp_path):
    """Save on 1 device -> restore sharded onto an 8-device mesh."""
    run_devices(f"""
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.train import checkpoint as ckpt

    tree = {{"w": jnp.arange(64, dtype=jnp.float32).reshape(8, 8)}}
    root = r"{tmp_path}/ck"
    ckpt.save(root, 1, tree)
    mesh = jax.make_mesh((8,), ("data",))
    sh = {{"w": NamedSharding(mesh, P("data", None))}}
    step, restored = ckpt.restore(root, tree, shardings=sh)
    assert step == 1
    assert restored["w"].sharding.is_equivalent_to(sh["w"], 2)
    np.testing.assert_array_equal(np.asarray(restored["w"]), np.asarray(tree["w"]))
    print("OK")
    """)
