"""End-to-end behaviour tests for the full system: train -> checkpoint ->
resume -> serve -> retrieval-augmented answer, through the public drivers."""
import jax
import numpy as np

from repro.configs import get_config
from repro.launch.train import train_loop
from repro.models.lm import build_model
from repro.serve.engine import GenerationEngine
from repro.serve.rag import RagPipeline


def test_train_checkpoint_resume_serve_rag(tmp_path):
    ckpt_dir = str(tmp_path / "ck")

    # 1) train a reduced llama a few steps with checkpointing
    out1 = train_loop(
        "llama3.2-3b", reduced=True, steps=12, batch=2, seq=32,
        lr=5e-3, ckpt_dir=ckpt_dir, ckpt_every=6, log_every=100,
    )
    assert np.isfinite(out1["final_loss"])

    # 2) resume from the checkpoint and keep training — loss stays finite
    #    and the driver picks up at the saved step
    out2 = train_loop(
        "llama3.2-3b", reduced=True, steps=16, batch=2, seq=32,
        lr=5e-3, ckpt_dir=ckpt_dir, ckpt_every=100, log_every=100,
    )
    assert len(out2["history"]) == 4  # 16 - 12 resumed steps
    assert np.isfinite(out2["final_loss"])

    # 3) serve the trained weights with the paper's retrieval in front
    cfg = get_config("llama3.2-3b").reduced()
    model = build_model(cfg)
    from repro.train import checkpoint as ckpt
    from repro.train.optimizer import OptConfig, opt_init

    params = model.init(jax.random.key(0))
    _, tree = ckpt.restore(
        ckpt_dir, {"params": params, "opt": opt_init(params, OptConfig())}
    )
    eng = GenerationEngine(model=model, params=tree["params"], cache_len=96)
    rng = np.random.default_rng(0)
    docs = rng.integers(0, cfg.vocab, (12, 10)).astype(np.int32)
    rag = RagPipeline.build(eng, docs, pruner="bond")
    q = {"tokens": rng.integers(0, cfg.vocab, (2, 8)).astype(np.int32)}
    answer, doc_ids = rag.answer(q, max_new_tokens=4)
    assert answer.shape == (2, 4)
    assert (doc_ids >= 0).all()


def _overfit_one_batch(arch, tc, steps=25, lr_seed=0):
    """Fresh random tokens have an irreducible ln(vocab) loss floor, so
    convergence is asserted by overfitting one fixed batch."""
    import jax.numpy as jnp

    from repro.data.pipeline import TokenStream
    from repro.train.optimizer import opt_init
    from repro.train.trainer import make_train_step

    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.key(lr_seed))
    state = opt_init(params, tc.opt)
    step_fn = jax.jit(make_train_step(model, tc))
    b = {
        k: jnp.asarray(v)
        for k, v in TokenStream(cfg, 16, 2, seed=4).batch_at(0).items()
    }
    losses = []
    extra = ()
    if tc.compress_grads:
        from repro.train.compression import ef_init

        extra = (ef_init(params),)
    for _ in range(steps):
        out = step_fn(params, state, b, *extra)
        params, state, metrics = out[:3]
        extra = out[3:]
        losses.append(float(metrics["loss"]))
    return losses


def test_gradient_compression_training_converges():
    from repro.train.optimizer import OptConfig
    from repro.train.trainer import TrainConfig

    tc = TrainConfig(
        opt=OptConfig(lr=1e-2, warmup_steps=0), compress_grads=True
    )
    losses = _overfit_one_batch("gemma-2b", tc)
    assert losses[-1] < losses[0] - 0.5, losses


def test_adafactor_training_converges():
    from repro.train.optimizer import OptConfig
    from repro.train.trainer import TrainConfig

    tc = TrainConfig(
        opt=OptConfig(lr=2e-2, warmup_steps=0, kind="adafactor")
    )
    losses = _overfit_one_batch("deepseek-moe-16b", tc)
    assert losses[-1] < losses[0] - 0.5, losses
