"""repro.obs telemetry tests: registry semantics (snapshot determinism,
log2 bucket edges, label-cardinality bound), tracer ring + disabled-mode
no-op guarantees, SearchStats on every executor, and the collective-meter
parity invariant on the routed 8-fake-device path (subprocess, see
tests/test_dist.py for why)."""
import json

import numpy as np
import pytest

from repro.core.engine import SearchSpec, VectorSearchEngine
from repro.core.pdxearch import SearchStats
from repro.data.synthetic import make_dataset
from repro.obs import metrics, trace
from repro.obs.metrics import MetricsRegistry, bucket_edge, bucket_index
from repro.obs.trace import Tracer

from test_dist import run_devices


@pytest.fixture
def obs():
    """Enable telemetry on a clean registry/ring; always restore disabled."""
    reg = metrics.get_registry()
    tr = trace.get_tracer()
    reg.reset()
    tr.clear()
    metrics.set_enabled(True)
    try:
        yield reg
    finally:
        metrics.set_enabled(False)
        reg.reset()
        tr.clear()


# ------------------------------------------------------------------- registry
def test_histogram_bucket_edges():
    # bucket i holds (2**(i-1), 2**i]; exact powers land on their own edge
    assert bucket_index(0.0) is None and bucket_index(-3.0) is None
    assert bucket_index(1.0) == 0
    assert bucket_index(1.0001) == 1
    assert bucket_index(2.0) == 1
    assert bucket_index(3.0) == 2
    assert bucket_index(4.0) == 2
    assert bucket_index(0.5) == -1
    assert bucket_index(0.3) == -1       # (0.25, 0.5]
    assert bucket_index(1e-30) == -64    # clamped underflow floor
    assert bucket_edge(None) == 0.0
    assert bucket_edge(3) == 8.0 and bucket_edge(-2) == 0.25


def test_snapshot_determinism():
    # same events, different arrival order and label kwarg order -> the
    # serialized snapshots are byte-identical
    events = [
        ("counter", "repro_x_total", 2.0, {"a": "1", "b": "2"}),
        ("counter", "repro_x_total", 1.0, {"b": "2", "a": "1"}),
        ("counter", "repro_x_total", 5.0, {"a": "9"}),
        ("gauge", "repro_g", 7.0, {"z": "q"}),
        ("observe", "repro_h", 3.0, {}),
        ("observe", "repro_h", 0.4, {}),
        ("observe", "repro_h", 1000.0, {}),
    ]
    r1, r2 = MetricsRegistry(), MetricsRegistry()
    for kind, name, v, labels in events:
        getattr(r1, kind)(name, v, **labels)
    for kind, name, v, labels in reversed(events):
        getattr(r2, kind)(name, v, **labels)
    assert r1.dump_json() == r2.dump_json()
    snap = r1.snapshot()
    assert snap["counters"]["repro_x_total"]["a=1,b=2"] == 3.0
    assert snap["histograms"]["repro_h"][""]["count"] == 3


def test_label_cardinality_bound():
    reg = MetricsRegistry(max_series_per_metric=4)
    for i in range(10):
        reg.counter("repro_leak_total", 1.0, qid=str(i))
    series = reg.snapshot()["counters"]["repro_leak_total"]
    assert len(series) == 5                      # 4 real + the overflow sink
    assert series["other=true"] == 6.0
    assert reg.dropped_series == 6
    # existing series keep accumulating past the cap
    reg.counter("repro_leak_total", 1.0, qid="0")
    assert reg.get("repro_leak_total", qid="0") == 2.0


def test_get_sum_and_prometheus_text():
    reg = MetricsRegistry()
    reg.counter("repro_bytes_total", 100.0, executor="a", component="scan")
    reg.counter("repro_bytes_total", 50.0, executor="a", component="wire")
    reg.counter("repro_bytes_total", 7.0, executor="b", component="scan")
    reg.gauge("repro_fill", 0.5)
    reg.observe("repro_lat_seconds", 0.3, executor="a")
    reg.observe("repro_lat_seconds", 0.6, executor="a")
    assert reg.get("repro_bytes_total", executor="b", component="scan") == 7.0
    assert reg.sum("repro_bytes_total", executor="a") == 150.0
    assert reg.sum("repro_bytes_total") == 157.0
    text = reg.prometheus_text()
    assert "# TYPE repro_bytes_total counter" in text
    assert 'repro_bytes_total{component="scan",executor="a"} 100' in text
    assert "# TYPE repro_lat_seconds histogram" in text
    # cumulative buckets: 0.3 -> le=0.5, 0.6 -> le=1; +Inf == count
    assert 'repro_lat_seconds_bucket{executor="a",le="0.5"} 1' in text
    assert 'repro_lat_seconds_bucket{executor="a",le="1"} 2' in text
    assert 'repro_lat_seconds_bucket{executor="a",le="+Inf"} 2' in text
    assert 'repro_lat_seconds_count{executor="a"} 2' in text


# --------------------------------------------------------------------- tracer
def test_tracer_ring_eviction(obs):
    tr = Tracer(capacity=3)
    for i in range(5):
        with tr.query(i=i):
            with tr.span("scan"):
                pass
    kept = tr.traces()
    assert len(kept) == 3
    assert [t.attrs["i"] for t in kept] == [2, 3, 4]
    assert kept[-1].span_names() == ("scan",)
    assert tr.last().attrs["i"] == 4


def test_tracer_no_nested_query_traces(obs):
    tr = trace.get_tracer()
    with trace.query(outer=True) as outer:
        with trace.query(inner=True) as inner:
            assert inner is None          # nested call records nothing
        with trace.span("scan"):
            pass
    assert len(tr.traces()) == 1
    assert outer.span_names() == ("scan",)


def test_disabled_mode_is_noop():
    assert not metrics.enabled()
    reg = metrics.get_registry()
    before = reg.dump_json()
    metrics.counter("repro_x_total", 1.0)
    metrics.gauge("repro_g", 1.0)
    metrics.observe("repro_h", 1.0)
    with trace.query(a=1) as t:
        assert t is None
        with trace.span("scan") as s:
            assert s is None
    assert trace.current_trace() is None
    assert trace.get_tracer().last() is None
    assert reg.dump_json() == before

    # a full engine search mutates neither registry nor ring, and the
    # result carries no trace — including the cascade executor, whose
    # per-stage survivor/byte meters must be strict no-ops when disabled
    X, Q = make_dataset(512, 16, "normal", n_queries=2, seed=0)
    eng = VectorSearchEngine.build(X, pruner="linear", capacity=128)
    res = eng.search(Q, SearchSpec(k=3))
    assert res.trace is None
    res = eng.search(Q, SearchSpec(k=3, cascade=("int8", "f32"),
                                   kernel="jnp"))
    assert res.plan.executor == "cascade-batch" and res.trace is None
    assert reg.dump_json() == before
    assert trace.get_tracer().last() is None

    # the async upload meters too: both the wait histogram and the overlap
    # gauge must leave the registry untouched when metrics are disabled
    from repro.obs.meters import cache_upload_wait

    cache_upload_wait(12.5, 100.0)
    cache_upload_wait(0.0, 0.0)
    assert reg.dump_json() == before


# ------------------------------------------------------------ engine telemetry
def test_engine_metrics_trace_and_stats_parity(obs, tmp_path):
    X, Q = make_dataset(2048, 32, "clustered", n_queries=4, seed=1)
    eng = VectorSearchEngine.build(
        X, index="ivf", pruner="adsampling", capacity=128, nlist=16,
    )
    stats = SearchStats()
    res = eng.search(Q[0], SearchSpec(k=5), stats=stats)
    assert res.plan.executor == "adaptive"
    qt = res.trace
    assert qt is not None and qt.attrs["executor"] == "adaptive"
    names = qt.span_names()
    assert names.index("plan") < names.index("route") < names.index("scan")
    assert "merge" in names
    assert qt.duration_s > 0 and all(s.duration_s >= 0 for s in qt.spans)

    snap = eng.metrics()
    assert snap["counters"]["repro_search_batches_total"]["executor=adaptive"] \
        == 1.0
    assert snap["counters"]["repro_search_queries_total"]["executor=adaptive"] \
        == 1.0
    # the registry mirrors the SearchStats work account exactly
    reg = metrics.get_registry()
    for kind, want in (
        ("total", stats.values_total),
        ("computed", stats.values_computed),
        ("avoided", stats.values_avoided),
    ):
        got = reg.get(
            "repro_pruning_values_total", executor="adaptive", kind=kind,
        )
        assert got == pytest.approx(want), (kind, got, want)
    hist = snap["histograms"]["repro_search_latency_seconds"]
    assert hist["executor=adaptive"]["count"] == 1

    # Perfetto export round-trips through engine.dump_trace
    path = tmp_path / "trace.json"
    doc = eng.dump_trace(str(path))
    names = [e["name"] for e in doc["traceEvents"]]
    assert "query" in names and "scan" in names
    assert json.loads(path.read_text()) == doc


def test_stats_populated_on_every_single_device_executor(obs):
    X, Q = make_dataset(2048, 32, "clustered", n_queries=4, seed=2)
    eng = VectorSearchEngine.build(
        X, index="ivf", pruner="adsampling", capacity=128, nlist=16,
    )
    total_1 = float(np.asarray(eng.store.counts).sum()) * eng.store.dim
    # exact=True: the executor scans the whole store at full width, so the
    # work account must be saturated (computed == total == live * D * B);
    # exact=False paths account only what they visit/compute
    flat = VectorSearchEngine.build(X, pruner="adsampling", capacity=128)
    cases = [
        (eng, "adaptive", SearchSpec(k=5), Q[0], False),
        (flat, "batch-matmul", SearchSpec(k=5), Q, True),
        (eng, "fused-scan", SearchSpec(k=5, scan_dtype="int8", kernel="jnp",
                                       executor="fused-scan"), Q[0], False),
        (eng, "fused-batch", SearchSpec(k=5, scan_dtype="bf16",
                                        executor="fused-batch"), Q, True),
    ]
    for e, name, spec, q, exact in cases:
        stats = SearchStats()
        res = e.search(q, spec, stats=stats)
        assert res.plan.executor == name, res.plan
        B = 1 if q.ndim == 1 else len(q)
        assert 0 < stats.values_total <= total_1 * B + 1e-6, name
        assert 0 < stats.values_computed <= stats.values_total, name
        if exact:
            assert stats.values_total == pytest.approx(total_1 * B), name
            assert stats.values_computed == stats.values_total, name
        assert stats.values_avoided == pytest.approx(
            stats.values_total - stats.values_computed
        ), name
        assert stats.partitions_visited > 0, name
    # jit-masked (flat store) obeys the same identity
    stats = SearchStats()
    res = flat.search(Q[0], SearchSpec(k=5, prefer_static=True), stats=stats)
    assert res.plan.executor == "jit-masked", res.plan
    assert stats.values_total > 0
    assert stats.values_avoided == pytest.approx(
        stats.values_total - stats.values_computed
    )


def test_cascade_stage_meters(obs):
    """The cascade executor reports per-stage survivors and realized bytes:
    survivors are monotone non-increasing across stages (each stage only
    prunes), never drop below k on an exact-recall config, and the byte
    meters reflect each stage mirror's width."""
    # flat store on normal data: true neighbours scatter across partitions,
    # so the scan stages (which exclude the exact START partition) must keep
    # at least ~k survivors per query for the re-rank to stay exact
    X, Q = make_dataset(2048, 32, "normal", n_queries=4, seed=6)
    eng = VectorSearchEngine.build(X, pruner="adsampling", capacity=128)
    cascade = ("proj8:int8", "int4", "f32")
    stats = SearchStats()
    res = eng.search(
        Q, SearchSpec(k=5, cascade=cascade, kernel="jnp",
                      executor="cascade-scan"),  # per-query meters under test
        stats=stats,
    )
    assert res.plan.executor == "cascade-scan", res.plan

    reg = metrics.get_registry()
    surv = [
        reg.get("repro_cascade_stage_survivors", stage=str(si),
                stage_name=cascade[si])
        for si in range(2)
    ]
    byts = [
        reg.get("repro_cascade_stage_bytes", stage=str(si),
                stage_name=cascade[si])
        for si in range(2)
    ]
    assert surv[0] >= surv[1] >= len(Q) * 5  # monotone, >= k per query
    # stage 0 streams every partition of the rank-8 int8 projection mirror;
    # stage 1 fetches at most the full int4 store (prefetch-skip can only
    # shrink it), and both meters carry real traffic
    P, C, D = (eng.store.num_partitions, eng.store.capacity, eng.store.dim)
    assert byts[0] == pytest.approx(len(Q) * P * 8 * C * 1)
    assert 0 < byts[1] <= len(Q) * P * D * C * 0.5
    # the realized d-tile meter never exceeds the partition-granular model
    # (an entering partition billed for its full stage mirror); stage 0's
    # single proj tile makes them equal there by construction
    pmodel = [
        reg.get("repro_cascade_stage_bytes_partition_model", stage=str(si),
                stage_name=cascade[si])
        for si in range(2)
    ]
    assert byts[0] == pytest.approx(pmodel[0])
    assert 0 < byts[1] <= pmodel[1]
    # the device-bytes account carries the same scan traffic per dtype,
    # plus the exact f32 START and re-rank components
    assert reg.get("repro_device_bytes_total", executor="cascade-scan",
                   component="scan", dtype="int8") == byts[0]
    assert reg.get("repro_device_bytes_total", executor="cascade-scan",
                   component="scan", dtype="int4") == byts[1]
    assert reg.get("repro_device_bytes_total", executor="cascade-scan",
                   component="start", dtype="f32") > 0
    assert reg.get("repro_device_bytes_total", executor="cascade-scan",
                   component="rerank", dtype="f32") > 0
    # SearchStats: total is the single-resolution full-scan equivalent;
    # cascade work may exceed it when pruning is weak (each stage re-reads
    # survivors at a new width), so only "avoided" is clamped at zero
    total_1 = float(np.asarray(eng.store.counts).sum()) * eng.store.dim
    assert stats.values_computed > 0
    assert stats.values_total == pytest.approx(total_1 * len(Q))
    assert stats.values_avoided == max(
        stats.values_total - stats.values_computed, 0.0
    )


def test_cascade_batch_meters_amortize_bytes(obs):
    """The batched cascade pays each stage's compacted-union gather ONCE
    per batch: its scan-bytes account must undercut B per-query mirror
    walks, and stage-0 bytes equal the pow2-padded union width exactly."""
    X, Q = make_dataset(2048, 32, "normal", n_queries=4, seed=6)
    eng = VectorSearchEngine.build(X, pruner="adsampling", capacity=128)
    cascade = ("proj8:int8", "int4", "f32")
    res = eng.search(Q, SearchSpec(k=5, cascade=cascade, kernel="jnp"))
    assert res.plan.executor == "cascade-batch", res.plan
    reg = metrics.get_registry()
    P, C = eng.store.num_partitions, eng.store.capacity
    from repro.core.plan import pow2_bucket

    # every slot outside the per-query START partition enters stage 0; the
    # batch's union is all live slots minus the intersection of the START
    # partitions, pow2-padded — with distinct starts that is all P*C slots
    b0 = reg.get("repro_cascade_stage_bytes", stage="0",
                 stage_name=cascade[0])
    assert b0 == pytest.approx(pow2_bucket(P * C, P * C) * 8 * 1)
    assert b0 <= len(Q) * P * 8 * C  # never worse than B per-query walks
    assert reg.get("repro_device_bytes_total", executor="cascade-batch",
                   component="scan", dtype="int8") == b0
    assert reg.get("repro_device_bytes_total", executor="cascade-batch",
                   component="rerank", dtype="f32") > 0


def test_cache_and_mutation_metrics(obs):
    X, _ = make_dataset(1024, 16, "normal", n_queries=1, seed=3)
    eng = VectorSearchEngine.build(X, pruner="linear", capacity=128)
    reg = metrics.get_registry()
    eng.insert(X[:8] + 0.5)
    assert reg.get("repro_store_mutations_total", op="insert") == 1.0
    assert reg.get("repro_store_rows_mutated_total", op="insert") == 8.0
    assert reg.get("repro_store_live_vectors") == 1032.0
    assert 0.0 < reg.get("repro_store_head_fill") <= 1.0
    eng.delete(np.arange(4))
    assert reg.get("repro_store_mutations_total", op="delete") == 1.0
    assert reg.get("repro_store_live_vectors") == 1028.0


# ----------------------------------------------- routed-path meter invariants
def test_routed_collective_meters_and_trace_8dev():
    run_devices("""
    from repro.core.engine import SearchSpec, VectorSearchEngine
    from repro.core.pdxearch import SearchStats
    from repro.data.synthetic import make_dataset
    from repro.obs import metrics, trace

    metrics.set_enabled(True)
    X, Q = make_dataset(8192, 32, "clustered", n_queries=16, seed=0)
    mesh = jax.make_mesh((8,), ("data",))
    eng = VectorSearchEngine.build(
        X, index="ivf", pruner="linear", capacity=128, nlist=32, mesh=mesh,
    )
    reg = metrics.get_registry()
    n_batches = 3
    stats = SearchStats()
    for _ in range(n_batches):
        res = eng.search(Q, SearchSpec(k=5, nprobe=4, scan_dtype="bf16"),
                         stats=stats)
        assert res.plan.executor == "routed_bucket", res.plan

    # routed stats: work accounted over the selected buckets only
    full = float(np.asarray(eng.store.counts).sum()) * eng.store.dim
    assert 0 < stats.values_total <= full * len(Q) * n_batches
    assert stats.values_computed == stats.values_total  # no pruning on-shard
    assert stats.partitions_visited > 0

    # acceptance trace: plan -> route -> scan with rerank + merge recorded
    qt = res.trace
    names = qt.span_names()
    assert "plan" in names and "route" in names and "scan" in names, names
    assert "rerank" in names and "merge" in names, names
    assert qt.find("rerank").attrs.get("fused") == "on-shard"

    # collective gate: the issued account is exactly per-batch rounds
    # all-to-alls + ONE packed all-gather, and it matches what the compile
    # -time jaxpr meter counted per call
    issued_a2a = reg.get("repro_collectives_issued_total",
                         executor="routed_bucket", primitive="all_to_all")
    issued_ag = reg.get("repro_collectives_issued_total",
                        executor="routed_bucket", primitive="all_gather")
    per_call_a2a = reg.get("repro_collectives_per_call",
                           executor="routed_bucket", primitive="all_to_all")
    per_call_ag = reg.get("repro_collectives_per_call",
                          executor="routed_bucket", primitive="all_gather")
    assert issued_ag == n_batches, (issued_ag, n_batches)
    assert per_call_ag == 1.0, per_call_ag
    assert issued_a2a == per_call_a2a * n_batches, (issued_a2a, per_call_a2a)

    # wire/scan bytes recorded per component at the mirror dtype
    scan_b = reg.get("repro_device_bytes_total", executor="routed_bucket",
                     component="scan", dtype="bf16")
    a2a_b = reg.get("repro_device_bytes_total", executor="routed_bucket",
                    component="all_to_all", dtype="bf16")
    rr_b = reg.get("repro_device_bytes_total", executor="routed_bucket",
                   component="rerank", dtype="bf16")
    assert scan_b > 0 and a2a_b > 0 and rr_b > 0
    print("OK")
    """)


# ---------------------------------------------------------------------------
# Thread-locality: concurrent worker traces + cross-thread query lifecycle
# ---------------------------------------------------------------------------
def test_concurrent_worker_traces_land_in_shared_ring(obs):
    """N worker threads x M searches each: every query trace must land in
    the one shared ring with the full span taxonomy, and the aggregated
    registry (engine.metrics()) must account every query."""
    import threading

    X, Q = make_dataset(n=512, dim=16, n_queries=8, seed=0)
    eng = VectorSearchEngine.build(X, pruner="adsampling", capacity=128)
    tr = trace.get_tracer()
    tr.clear()
    n_threads, per_thread = 4, 5
    errs = []

    def worker(t):
        try:
            for i in range(per_thread):
                eng.search(Q[(t + i) % len(Q)], k=3)
        except Exception as e:  # pragma: no cover
            errs.append(e)

    threads = [threading.Thread(target=worker, args=(t,))
               for t in range(n_threads)]
    for th in threads:
        th.start()
    for th in threads:
        th.join()
    assert not errs
    traces = tr.traces()
    assert len(traces) == n_threads * per_thread
    for qt in traces:
        assert qt.t1 > qt.t0
        assert "plan" in qt.span_names() and "scan" in qt.span_names()
    snap = eng.metrics()
    total = sum(
        snap["counters"]["repro_search_queries_total"].values()
    )
    assert total == n_threads * per_thread


def test_cross_thread_query_lifecycle_no_dangling_current(obs):
    """start_query on one thread, use/span on a worker, finish on a third:
    the trace lands in the ring with its spans, and NO thread is left with
    a dangling current trace."""
    import threading

    tr = trace.get_tracer()
    tr.clear()
    qt = tr.start_query(bucket=4)
    assert qt is not None and trace.current_trace() is None  # not bound here

    def worker():
        with tr.use(qt):
            assert trace.current_trace() is qt
            tr.span_at("queue", qt.t0, qt.t0 + 0.001, depth_at_drain=3)
            with tr.span("scan", executor="batch-matmul"):
                pass
        assert trace.current_trace() is None

    th = threading.Thread(target=worker)
    th.start()
    th.join()

    def finisher():
        tr.finish_query(qt)

    th2 = threading.Thread(target=finisher)
    th2.start()
    th2.join()
    assert trace.current_trace() is None        # starter thread not clobbered
    assert tr.last() is qt
    assert qt.span_names() == ("queue", "scan")
    assert qt.find("queue").attrs["depth_at_drain"] == 3
    # a new query on this thread still traces normally (no stale binding)
    with tr.query(n_queries=1) as q2:
        assert q2 is not None and q2 is not qt
    assert len(tr.traces()) == 2


def test_use_restores_previous_binding(obs):
    """A worker interleaving a served trace inside its own query context
    gets its own binding back afterwards (use() is re-entrant-safe)."""
    tr = trace.get_tracer()
    tr.clear()
    served = tr.start_query()
    with tr.query() as outer:
        with tr.use(served):
            assert trace.current_trace() is served
        assert trace.current_trace() is outer
    tr.finish_query(served)
    assert {t.trace_id for t in tr.traces()} == {
        served.trace_id, outer.trace_id
    }
