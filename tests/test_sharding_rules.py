"""Unit tests for the declarative sharding rules (repro.dist.sharding)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.dist.sharding import (
    _divisible,
    batch_pspec,
    param_pspec,
    param_shardings,
    strip_axes,
)
from repro.models.lm import build_model


class FakeMesh:
    """Duck-typed mesh with .shape mapping (no device init needed)."""

    def __init__(self, shape: dict):
        self.shape = shape
        self.axis_names = tuple(shape)


def test_column_row_pairing():
    cfg = get_config("llama3.2-3b")
    # stacked layer params carry a leading unit axis (L, d_in, d_out)
    wq = jax.ShapeDtypeStruct((28, 3072, 3072), jnp.bfloat16)
    wo = jax.ShapeDtypeStruct((28, 3072, 3072), jnp.bfloat16)

    class K:  # fake DictKey
        def __init__(s, k):
            s.key = k

    assert param_pspec((K("stack0"), K("sub0"), K("wq")), wq, cfg) == P(
        None, "data", "model"
    )
    assert param_pspec((K("stack0"), K("sub0"), K("wo")), wo, cfg) == P(
        None, "model", "data"
    )
    # unstacked embeddings: vocab over model, d over data
    emb = jax.ShapeDtypeStruct((128256, 3072), jnp.bfloat16)
    assert param_pspec((K("embed"),), emb, cfg) == P("model", "data")


def test_divisibility_guard_drops_axes():
    mesh = FakeMesh({"data": 16, "model": 16})
    # 14 heads * 64 = 896 not divisible by 16 -> model axis dropped
    spec = _divisible(P("data", "model"), (896, 896), mesh)
    assert spec == P("data", "model")  # 896 % 16 == 0 actually divisible
    spec = _divisible(P("data", "model"), (896, 14), mesh)
    assert spec == P("data", None)
    spec = _divisible(P(("data", "model"), None), (100, 4), mesh)
    assert spec == P(None, None)  # 100 % 256 != 0


def test_batch_pspec_prefers_all_data_axes():
    mesh = FakeMesh({"pod": 2, "data": 16, "model": 16})
    assert batch_pspec(mesh, 256) == P(("pod", "data"))
    assert batch_pspec(mesh, 16) == P("data")
    assert batch_pspec(mesh, 1) == P()


def test_strip_axes_removes_data_everywhere():
    devs = np.array(jax.devices()[:1]).reshape(1, 1)
    from jax.sharding import Mesh, NamedSharding

    mesh = Mesh(devs, ("data", "model"))
    sh = {
        "w": NamedSharding(mesh, P("data", "model")),
        "b": NamedSharding(mesh, P(("data", "model"))),
    }
    out = strip_axes(sh, ("data",))
    assert out["w"].spec == P(None, "model")
    assert out["b"].spec == P(("model",))


@pytest.mark.parametrize("arch", ["llama3.2-3b", "deepseek-moe-16b", "mamba2-370m"])
def test_param_shardings_cover_full_tree(arch):
    """Every param leaf gets a NamedSharding whose spec fits its rank."""
    from jax.sharding import Mesh

    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    params = jax.eval_shape(lambda: model.init(jax.random.key(0)))
    devs = np.array(jax.devices()[:1]).reshape(1, 1)
    mesh = Mesh(devs, ("data", "model"))
    sh = param_shardings(params, mesh, cfg)
    leaves_p = jax.tree.leaves(params)
    leaves_s = jax.tree.leaves(sh, is_leaf=lambda x: hasattr(x, "spec"))
    assert len(leaves_p) == len(leaves_s)
    for p, s in zip(leaves_p, leaves_s):
        assert len(s.spec) <= len(p.shape)
