"""Unit tests for model sub-blocks against naive references: chunked
(flash-style) attention, Mamba2 SSD vs step recurrence, MoE dispatch."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models.common import chunked_attention, decode_attention
from repro.models.mamba import mamba2
from repro.models.moe import dense_ffn, moe_ffn, pick_group_count


# --------------------------------------------------------------------------
# chunked attention vs naive softmax
# --------------------------------------------------------------------------
def _naive_attention(q, k, v, causal=True):
    B, Sq, H, Dh = q.shape
    Hkv = k.shape[2]
    rep = H // Hkv
    k = np.repeat(np.asarray(k), rep, axis=2)
    v = np.repeat(np.asarray(v), rep, axis=2)
    q, k, v = map(np.asarray, (q, k, v))
    s = np.einsum("bqhd,bkhd->bhqk", q, k) / np.sqrt(Dh)
    if causal:
        Sk = k.shape[1]
        mask = np.arange(Sk)[None, :] <= np.arange(Sq)[:, None]
        s = np.where(mask[None, None], s, -1e30)
    p = np.exp(s - s.max(-1, keepdims=True))
    p = p / p.sum(-1, keepdims=True)
    return np.einsum("bhqk,bkhd->bqhd", p, v)


@pytest.mark.parametrize("H,Hkv", [(4, 4), (4, 2), (8, 1)])
@pytest.mark.parametrize("causal", [True, False])
def test_chunked_attention_matches_naive(H, Hkv, causal, rng):
    B, S, Dh = 2, 64, 16
    q = jnp.asarray(rng.standard_normal((B, S, H, Dh)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, S, Hkv, Dh)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, S, Hkv, Dh)), jnp.float32)
    got = chunked_attention(q, k, v, causal=causal, q_chunk=16, kv_chunk=16)
    want = _naive_attention(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(got), want, rtol=2e-4, atol=2e-5)


def test_decode_attention_masks_unfilled_cache(rng):
    B, S, Hkv, Dh, H = 1, 32, 2, 8, 4
    q = jnp.asarray(rng.standard_normal((B, 1, H, Dh)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, S, Hkv, Dh)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, S, Hkv, Dh)), jnp.float32)
    out_full = decode_attention(q, k, v, jnp.int32(8))
    # garbage beyond position 8 must not matter
    k2 = k.at[:, 8:].set(1e6)
    v2 = v.at[:, 8:].set(-1e6)
    out_masked = decode_attention(q, k2, v2, jnp.int32(8))
    np.testing.assert_allclose(
        np.asarray(out_full), np.asarray(out_masked), rtol=1e-5
    )


# --------------------------------------------------------------------------
# Mamba2 SSD (chunked matmul form) vs naive per-token recurrence
# --------------------------------------------------------------------------
def test_ssd_matches_naive_recurrence(rng):
    cfg = get_config("mamba2-370m").reduced()
    d_model = 32
    p = mamba2.init(jax.random.key(0), cfg, d_model)
    B, S = 2, 32
    x = jnp.asarray(rng.standard_normal((B, S, d_model)) * 0.5, jnp.float32)

    y_par, state_par = mamba2.forward_train(
        p, x, cfg, d_model, return_state=True
    )
    # naive: run the decode recurrence token by token
    cache = mamba2.init_cache(cfg, d_model, B)
    ys = []
    for t in range(S):
        y_t, cache = mamba2.forward_decode(p, x[:, t : t + 1], cfg, cache, d_model)
        ys.append(y_t)
    y_seq = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(
        np.asarray(y_par), np.asarray(y_seq), rtol=2e-3, atol=2e-4
    )
    np.testing.assert_allclose(
        np.asarray(state_par["ssm"]), np.asarray(cache["ssm"]),
        rtol=2e-3, atol=2e-4,
    )


# --------------------------------------------------------------------------
# MoE dispatch
# --------------------------------------------------------------------------
def _moe_cfg(**kw):
    base = get_config("deepseek-moe-16b").reduced()
    return dataclasses.replace(base, **kw)


def test_moe_single_expert_equals_dense(rng):
    cfg = _moe_cfg(n_experts=1, top_k=1, n_shared=0, capacity_factor=2.0)
    key = jax.random.key(1)
    p = moe_ffn.init(key, cfg, jnp.float32)
    B, S = 2, 8
    x = jnp.asarray(rng.standard_normal((B, S, cfg.d_model)), jnp.float32)
    got = moe_ffn.forward(p, x, cfg)
    dense_p = {
        "w_gate": p["w_gate"][0], "w_up": p["w_up"][0], "w_down": p["w_down"][0]
    }
    want = dense_ffn.forward(dense_p, x, cfg.act)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=1e-5)


def test_moe_output_finite_and_shaped(rng):
    cfg = _moe_cfg()
    p = moe_ffn.init(jax.random.key(2), cfg, jnp.float32)
    x = jnp.asarray(rng.standard_normal((2, 16, cfg.d_model)), jnp.float32)
    y = moe_ffn.forward(p, x, cfg)
    assert y.shape == x.shape
    assert bool(jnp.isfinite(y).all())


def test_moe_grads_flow_to_experts_and_router(rng):
    cfg = _moe_cfg()
    p = moe_ffn.init(jax.random.key(3), cfg, jnp.float32)
    x = jnp.asarray(rng.standard_normal((1, 16, cfg.d_model)), jnp.float32)
    g = jax.grad(lambda pp: jnp.sum(moe_ffn.forward(pp, x, cfg) ** 2))(p)
    assert float(jnp.abs(g["router"]).sum()) > 0
    assert float(jnp.abs(g["w_down"]).sum()) > 0


def test_pick_group_count():
    assert pick_group_count(128, 256, 8) == 1          # decode batch
    g = pick_group_count(4096 * 256, 256, 8)
    assert g >= 256 and (g & (g - 1)) == 0             # train: many pow2 groups
