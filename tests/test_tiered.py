"""Tiered serving tests: the bucket-granular HBM cache (``BucketCache``),
the tiered-scan / routed_tiered executors, the two-level centroid routing
tree, and the maintenance-clone delta replay (oplog)."""
import numpy as np
import pytest

import repro.core.engine  # noqa: F401  (breaks the engine<->ivf import cycle)
from repro.core.engine import VectorSearchEngine
from repro.core.layout import BucketCache, MutablePDXStore, build_flat_store
from repro.core.spec import SearchSpec
from repro.index.ivf import build_ivf
from repro.obs import metrics as _metrics
from test_dist import run_devices


def _clustered(n, d, k, seed=0):
    rng = np.random.default_rng(seed)
    cents = rng.standard_normal((k, d)).astype(np.float32) * 4
    X = (cents[rng.integers(0, k, n)]
         + rng.standard_normal((n, d)).astype(np.float32))
    Q = (cents[rng.integers(0, k, 16)]
         + rng.standard_normal((16, d)).astype(np.float32))
    return X.astype(np.float32), Q.astype(np.float32)


def _engine(n=4000, d=32, nlist=16, **kw):
    X, Q = _clustered(n, d, nlist)
    kw.setdefault("capacity", 64)  # ~4 partitions/bucket: room to evict
    eng = VectorSearchEngine.build(
        X, index="ivf", nlist=nlist, pruner="linear", **kw
    )
    return eng, X, Q


def _recall(ids, ref_ids):
    ids, ref_ids = np.asarray(ids), np.asarray(ref_ids)
    k = ids.shape[1]
    return np.mean([
        len(set(a.tolist()) & set(b.tolist())) / k
        for a, b in zip(ids, ref_ids)
    ])


# ------------------------------------------------------------ single host
def test_tiered_f32_bitwise_parity_with_routed():
    eng, X, Q = _engine()
    ref = eng.search(Q, SearchSpec(k=10, nprobe=4))
    res = eng.search(Q, SearchSpec(k=10, nprobe=4, hbm_slots=64))
    assert res.plan.executor == "tiered-scan"
    np.testing.assert_array_equal(np.asarray(res.ids), np.asarray(ref.ids))
    np.testing.assert_allclose(
        np.asarray(res.dists), np.asarray(ref.dists), rtol=1e-5
    )


def test_tiered_eviction_readmission_parity_small_capacity():
    """A cache far smaller than the store forces evict/readmit between
    batches; results must match a fully-resident cache exactly (f32) and
    a non-tiered reference at recall 1.0 (int8)."""
    eng, X, Q = _engine()
    spec = SearchSpec(k=10, nprobe=4)
    ref = eng.search(Q, spec)
    # The smallest legal cache: one query's worst-case routed demand (the
    # 4 fattest buckets at once).  Well under the store's partition count,
    # so alternating disjoint query halves forces evict + readmit.
    cnts = np.sort(np.asarray(eng.ivf.part_counts))
    slots = int(cnts[-4:].sum())
    assert slots < eng.store.data.shape[0]
    small = spec.replace(hbm_slots=slots)
    for batch in (Q[:8], Q[8:], Q[:8], Q):
        r = eng.search(batch, small)
        assert r.plan.executor == "tiered-scan"
    got = eng.search(Q, small)
    np.testing.assert_array_equal(np.asarray(got.ids), np.asarray(ref.ids))
    got8 = eng.search(Q, small.replace(scan_dtype="int8"))
    assert _recall(got8.ids, ref.ids) >= 0.95


def test_tiered_oversized_demand_splits_instead_of_raising():
    """A slot pool smaller than ONE query's routed demand — even smaller
    than a single bucket's extent — no longer fails the query: the run
    loop cuts oversized extents into region-sized sub-extents, scans them
    in sequential passes, and merges top-k.  Results stay exact (f32)."""
    eng, X, Q = _engine()
    ref = eng.search(Q, SearchSpec(k=10, nprobe=8))
    res = eng.search(Q, SearchSpec(k=10, nprobe=8, hbm_slots=4))
    assert res.plan.executor == "tiered-scan"
    assert _recall(res.ids, ref.ids) == 1.0
    np.testing.assert_allclose(
        np.sort(np.asarray(res.dists), axis=1),
        np.sort(np.asarray(ref.dists), axis=1), rtol=1e-5,
    )
    # direct cache misuse (no parts split requested) still refuses loudly
    cache = next(iter(eng.store._tiered_cache.values()))
    big = int(np.argmax(np.asarray(eng.ivf.part_counts)))
    with pytest.raises(ValueError, match="hbm_slots"):
        cache.ensure(np.array([big]))


def test_tiered_generation_invalidation_on_repack():
    """repack()/adopt() bump tiles_version; the cache must drop every slot
    (generation tag) and repopulate from the new extents correctly."""
    eng, X, Q = _engine()
    spec = SearchSpec(k=10, nprobe=4, hbm_slots=64)
    rng = np.random.default_rng(7)
    new_ids = eng.insert(X[:3] + rng.standard_normal((3, X.shape[1]))
                         .astype(np.float32) * 0.01)  # upgrade to mutable
    eng.search(Q, spec)
    cache = next(iter(eng.store._tiered_cache.values()))
    gen0 = cache.generation
    assert cache.resident_slots > 0
    eng.delete(new_ids[:1])
    eng.compact()  # repack -> tiles_version bump
    ref = eng.search(Q, SearchSpec(k=10, nprobe=4))
    got = eng.search(Q, spec)
    assert cache.generation != gen0
    np.testing.assert_array_equal(np.asarray(got.ids), np.asarray(ref.ids))


def test_bucket_cache_lru_evicts_unpinned_only():
    X, _ = _clustered(2000, 16, 8, seed=3)
    ivf = build_ivf(X, 8, capacity=64)
    store = ivf.store
    cnts = np.asarray(ivf.part_counts)
    cap = int(cnts.max() * 2 + 1)
    bc = BucketCache(store, capacity_slots=cap, dtype="f32",
                     part_offsets=ivf.part_offsets,
                     part_counts=ivf.part_counts)
    bc.ensure(np.array([0, 1]))
    st = bc.ensure(np.array([2]))  # may evict 0 or 1, never 2
    assert st["misses"] == 1
    st2 = bc.ensure(np.array([2]))
    assert st2 == {"hits": 1, "misses": 0, "evicted": 0, "uploaded_slots": 0}


# ------------------------------------------------------------- async uploads
def test_host_quantize_matches_device_quantizers_bitwise():
    """``issue`` stages uploads by quantizing on the HOST (so the H2D copy
    moves 1-2 bytes/dim, not f32); NumPy's rint/clip/sub/div must reproduce
    the jitted device quantizers bit for bit or eviction/readmission could
    flip candidate sets."""
    import jax.numpy as jnp
    from repro.core.layout import (
        _quantize_extent_int4, _quantize_extent_int8,
    )

    X, _ = _clustered(3000, 17, 8, seed=5)  # odd D: int4 pads a nibble
    ivf = build_ivf(X, 8, capacity=64)
    for dtype, dev_fn in (("int8", _quantize_extent_int8),
                          ("int4", _quantize_extent_int4)):
        bc = BucketCache(ivf.store, capacity_slots=32, dtype=dtype,
                         part_offsets=ivf.part_offsets,
                         part_counts=ivf.part_counts)
        bc._revalidate()
        data, _, _ = bc._masters()
        ext = np.asarray(data[:7], np.float32)
        host = bc._host_quantize(ext)
        dev = np.asarray(dev_fn(
            jnp.asarray(ext), jnp.asarray(bc._scale_np),
            jnp.asarray(bc._offset_np),
        ))
        np.testing.assert_array_equal(host, dev)


def test_async_issue_wait_parity_with_sync_ensure():
    """The split prefetch (issue -> overlapped work -> wait) must leave the
    cache in exactly the state one synchronous ensure produces: same slot
    assignment, bitwise-equal pool tiles and id table, for every pool
    dtype AND every staging strategy (worker host-quantize, async device
    quantize, legacy blocking f32 upload); depth-1 discipline auto-drains
    the previous ticket."""
    X, _ = _clustered(2000, 16, 8, seed=3)
    ivf = build_ivf(X, 8, capacity=64)
    cap = int(np.asarray(ivf.part_counts).max() * 3 + 1)
    for dtype in ("f32", "bf16", "int8", "int4"):
        mk = lambda: BucketCache(ivf.store, capacity_slots=cap, dtype=dtype,
                                 part_offsets=ivf.part_offsets,
                                 part_counts=ivf.part_counts)
        sync, asy, dev, leg = mk(), mk(), mk(), mk()
        asy.stage_on_host = True    # worker staging even on 1-core CI
        dev.stage_on_host = False   # async fused device quantize
        leg.sync_uploads = True     # legacy blocking f32 + device quantize
        sync.ensure(np.array([0, 1, 2]))
        dev.ensure(np.array([0, 1, 2]))
        leg.ensure(np.array([0, 1, 2]))
        t1 = asy.issue(np.array([0, 1]))
        t2 = asy.issue(np.array([2]))   # depth-1: drains t1 first
        assert t1.done and not t2.done
        st = asy.wait(t2)
        assert st["misses"] == 1
        assert asy.wait(t2) == st       # idempotent settle
        ps, _, sbs, _, _ = sync.arrays()
        pa, _, sba, _, _ = asy.arrays()
        assert np.asarray(ps).tobytes() == np.asarray(pa).tobytes(), dtype
        for other in (dev, leg):
            po, _, _, _, _ = other.arrays()
            assert np.asarray(ps).tobytes() == np.asarray(po).tobytes(), (
                dtype, other.stage_on_host, other.sync_uploads)
        np.testing.assert_array_equal(np.asarray(sbs), np.asarray(sba))
        np.testing.assert_array_equal(sync.slot_ids_host(),
                                      asy.slot_ids_host())
        # arrays() on a cache with an undrained ticket settles it first
        t3 = asy.issue(np.array([3]))
        _, ids_dev, _, _, _ = asy.arrays()
        assert t3.done
        slots = asy._resident[0][3]
        off = int(np.asarray(ivf.part_offsets)[3])
        cnt = int(np.asarray(ivf.part_counts)[3])
        np.testing.assert_array_equal(
            asy.slot_ids_host()[slots],
            np.asarray(ivf.store.ids)[off: off + cnt],
        )


# -------------------------------------------------------- two-level routing
def test_tree_routing_sublinear_cost():
    """At serving-scale nlist the two-level descent ranks sub-linearly many
    centroids (SK + nprobe_super * M < nlist) at bucket-recall parity."""
    eng, X, Q = _engine(n=8000, d=16, nlist=128, tree=True, super_k=16,
                        nprobe_super=2)
    ivf = eng.ivf
    assert ivf.tree_enabled
    SK, M = ivf.super_children.shape
    assert ivf.routing_cost() == SK + ivf.nprobe_super * M
    assert ivf.routing_cost() < ivf.nlist
    ref = VectorSearchEngine.build(X, index="ivf", nlist=128, capacity=64,
                                   pruner="linear", tree=False)
    r_tree = eng.search(Q, SearchSpec(k=10, nprobe=8))
    r_flat = ref.search(Q, SearchSpec(k=10, nprobe=8))
    assert _recall(r_tree.ids, r_flat.ids) >= 0.9


def test_tree_full_descent_matches_flat_exactly():
    """nprobe_super == super_k covers every child, so the ranked candidate
    set equals the flat scan's and the routed buckets are identical."""
    X, Q = _clustered(3000, 24, 12, seed=5)
    flat = build_ivf(X, 12, capacity=64, tree=False)
    tree = build_ivf(X, 12, capacity=64, tree=True, super_k=3, nprobe_super=3)
    sf = flat.route_batch(Q, nprobe=4)
    st = tree.route_batch(Q, nprobe=4)
    np.testing.assert_array_equal(np.asarray(sf), np.asarray(st))


def test_tree_auto_threshold():
    X, _ = _clustered(1500, 16, 8, seed=9)
    ivf = build_ivf(X, 8, capacity=64)  # tree="auto", nlist < threshold
    assert not ivf.tree_enabled


# ------------------------------------------------------------ delta replay
def _mutable(seed=0):
    rng = np.random.default_rng(seed)
    X = rng.standard_normal((200, 8)).astype(np.float32)
    return MutablePDXStore.from_store(build_flat_store(X, capacity=32),
                                      head_capacity=32), rng


def test_oplog_replay_makes_adopt_succeed_under_traffic():
    ms, rng = _mutable()
    clone = ms.clone()
    ms.oplog_start()
    clone.repack()
    # traffic lands on the master while the clone repacks
    ids = ms.insert(rng.standard_normal((3, 8)).astype(np.float32))
    assert ms.delete(ids[:1]) == 1
    ops = ms.oplog_take()
    assert ops is not None and len(ops) == 2
    replayed = clone.replay(ops)
    assert replayed == 4  # 3 inserted + 1 deleted
    assert ms.adopt(clone, expect_version=ms.version)
    assert ms.num_vectors == 200 + 3 - 1
    live = np.concatenate([np.asarray(ms.ids).ravel(), ms._head_ids])
    live = set(live[live >= 0].tolist())
    assert set(ids[1:].tolist()) <= live and int(ids[0]) not in live


def test_oplog_overflow_returns_none():
    ms, rng = _mutable(1)
    ms.oplog_start(limit=2)
    ms.insert(rng.standard_normal((3, 8)).astype(np.float32))
    assert ms.oplog_take() is None
    assert ms.oplog_take() is None  # never-started is also None


def test_oplog_replay_id_divergence_raises():
    ms, rng = _mutable(2)
    clone = ms.clone()
    ms.oplog_start()
    ops_ids = ms.insert(rng.standard_normal((2, 8)).astype(np.float32))
    ops = ms.oplog_take()
    clone.insert(rng.standard_normal((1, 8)).astype(np.float32))  # diverge
    with pytest.raises(ValueError, match="id divergence"):
        clone.replay(ops)
    del ops_ids


def test_server_delta_replay_under_continuous_inserts():
    """Background repacks under a steady insert stream must keep adopting
    (delta replay) — every inserted id stays searchable afterwards."""
    from repro.serve.vector import VectorServer

    rng = np.random.default_rng(11)
    X = rng.standard_normal((256, 16)).astype(np.float32)
    eng = VectorSearchEngine.build(X, index="flat", pruner="linear",
                                   capacity=64)
    spec = eng.spec.replace(k=4, executor="batch-matmul")
    with VectorServer(eng, spec=spec, max_batch=8,
                      maintenance_interval_s=0.01,
                      head_fill_threshold=0.0) as srv:
        all_ids = []
        for _ in range(12):
            V = rng.standard_normal((4, 16)).astype(np.float32)
            all_ids.append((srv.insert(V).result(timeout=30), V))
        import time
        deadline = time.time() + 15
        while time.time() < deadline and eng.store.head_count:
            time.sleep(0.02)
        for ids, V in all_ids:
            got, _ = srv.search(V[0])
            assert got[0] == ids[0]
    assert eng.store.num_vectors == 256 + 48


# ------------------------------------------------------------- observability
def test_tiered_obs_strict_noop_when_disabled():
    assert not _metrics.enabled()
    before = _metrics.get_registry().snapshot()
    eng, X, Q = _engine(n=2000, nlist=8)
    eng.search(Q, SearchSpec(k=5, nprobe=4, hbm_slots=64))
    eng.search(Q[:4], SearchSpec(k=5, nprobe=4, hbm_slots=48))
    after = _metrics.get_registry().snapshot()
    assert before == after


def test_tiered_cache_gauges_recorded_when_enabled():
    _metrics.set_enabled(True)
    try:
        _metrics.get_registry().reset()
        eng, X, Q = _engine(n=2000, nlist=8)
        spec = SearchSpec(k=5, nprobe=4, hbm_slots=64)
        eng.search(Q, spec)
        eng.search(Q, spec)  # warm: all hits
        snap = eng.metrics()
        ev = {
            k: v for k, v in snap.get("counters", snap).items()
            if "repro_tiered_cache_events_total" in str(k)
        }
        flat = str(snap)
        assert "repro_tiered_cache_events_total" in flat
        assert "repro_tiered_prefetch_bytes_total" in flat
        assert "hit" in flat and "miss" in flat
        # the async upload split meters its settle: the wait histogram
        # records every non-empty upload batch, and the overlap gauge is a
        # valid ratio (the warm second batch uploads nothing — no samples)
        assert "repro_cache_upload_wait_us" in flat
        reg = _metrics.get_registry()
        ratio = reg.get("repro_cache_upload_overlap_ratio")
        assert 0.0 <= ratio <= 1.0
        del ev
    finally:
        _metrics.set_enabled(False)
        _metrics.get_registry().reset()


# ------------------------------------------------- routed tiered (8 devices)
def test_routed_tiered_capacity_smaller_than_store():
    run_devices("""
    import repro.core.engine
    from repro.core.engine import VectorSearchEngine
    from repro.core.spec import SearchSpec

    rng = np.random.default_rng(0)
    cents = rng.standard_normal((64, 32)).astype(np.float32) * 4
    X = (cents[rng.integers(0, 64, 8000)]
         + rng.standard_normal((8000, 32)).astype(np.float32)).astype(np.float32)
    Q = (cents[rng.integers(0, 64, 12)]
         + rng.standard_normal((12, 32)).astype(np.float32)).astype(np.float32)
    mesh = jax.make_mesh((8,), ("data",))
    eng = VectorSearchEngine.build(X, index="ivf", nlist=64, pruner="linear",
                                   capacity=64, mesh=mesh)
    P = eng.store.data.shape[0]
    spec = SearchSpec(k=10, nprobe=4)
    ref = eng.search(Q, spec)
    assert ref.plan.executor == "routed_bucket", ref.plan.executor
    tiered = spec.replace(hbm_slots=64)   # 64 slots < P partitions
    assert 64 < P, P
    res = eng.search(Q, tiered)
    assert res.plan.executor == "routed_tiered", res.plan.executor
    np.testing.assert_array_equal(np.asarray(res.ids), np.asarray(ref.ids))
    res8 = eng.search(Q, tiered.replace(scan_dtype="int8"))
    k = 10
    rec = np.mean([len(set(a.tolist()) & set(b.tolist())) / k
                   for a, b in zip(np.asarray(res8.ids), np.asarray(ref.ids))])
    assert rec >= 0.95, rec
    cache = next(iter(eng.store._tiered_cache.values()))
    assert cache.resident_slots <= 64
    print("OK")
    """)
