"""Dry-run machinery tests: mesh construction, analysis parsers, and one
real full-config 512-device lower+compile cell in a subprocess (slow)."""
import json
import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_mesh_shapes_are_lazy_and_correct():
    # importing must not init devices; calling builds the documented shapes
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
        from repro.launch.mesh import make_production_mesh
        m1 = make_production_mesh()
        assert m1.axis_names == ("data", "model") and m1.devices.size == 256
        m2 = make_production_mesh(multi_pod=True)
        assert m2.axis_names == ("pod", "data", "model")
        assert m2.devices.size == 512
        print("OK")
    """)
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=300, env=env)
    assert r.returncode == 0, r.stderr


def test_collective_parser_trip_counts():
    from repro.launch.analysis import collective_bytes_hlo

    hlo = textwrap.dedent("""
    HloModule test

    %body (p: (s32[], f32[64])) -> (s32[], f32[64]) {
      %p = (s32[], f32[64]) parameter(0)
      %g = f32[64]{0} get-tuple-element(%p), index=1
      %ar = f32[64]{0} all-reduce(%g), replica_groups={{0,1}}, to_apply=%sum
      ROOT %t = (s32[], f32[64]) tuple(%g, %ar)
    }

    %cond (p: (s32[], f32[64])) -> pred[] {
      %p = (s32[], f32[64]) parameter(0)
      %i = s32[] get-tuple-element(%p), index=0
      %c = s32[] constant(7)
      ROOT %lt = pred[] compare(%i, %c), direction=LT
    }

    ENTRY %main (x: f32[64]) -> f32[64] {
      %x = f32[64]{0} parameter(0)
      %ag = f32[128]{0} all-gather(%x), dimensions={0}
      %w = (s32[], f32[64]) while(%t0), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"7"}}
      ROOT %r = f32[64]{0} get-tuple-element(%w), index=1
    }
    """)
    res = collective_bytes_hlo(hlo)
    assert res["bytes"]["all-gather"] == 128 * 4
    assert res["bytes"]["all-reduce"] == 64 * 4 * 7  # trip-multiplied
    assert res["count"]["all-reduce"] == 7


def test_jaxpr_cost_counts_attention_flops():
    import jax
    import jax.numpy as jnp

    from repro.launch.analysis import jaxpr_cost
    from repro.models.common import chunked_attention

    B, S, H, D = 2, 256, 4, 32
    q = jax.ShapeDtypeStruct((B, S, H, D), jnp.float32)
    jx = jax.make_jaxpr(
        lambda q, k, v: chunked_attention(q, k, v, q_chunk=128, kv_chunk=128)
    )(q, q, q)
    c = jaxpr_cost(jx)
    expect = 2 * 2 * B * H * S * S * D  # qk + pv
    assert 0.9 * expect <= c["dot_flops"] <= 1.6 * expect, (
        c["dot_flops"], expect,
    )


@pytest.mark.slow
def test_full_config_cell_compiles_on_512_devices(tmp_path):
    """qwen2-72b prefill_32k: full assigned dims, 16x16 mesh, ShapeDtype
    inputs, lower+compile must succeed (the fastest full cell, ~10s)."""
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", "qwen2-72b",
         "--shape", "prefill_32k", "--mesh", "single_pod",
         "--out", str(tmp_path)],
        capture_output=True, text=True, timeout=500, env=env, cwd=REPO,
    )
    assert r.returncode == 0, r.stdout + r.stderr
    rec = json.load(open(tmp_path / "qwen2-72b__prefill_32k__single_pod.json"))
    assert rec["status"] == "ok"
    assert rec["jaxpr_cost"]["flops"] > 1e15  # 32k prefill is heavy
    assert rec["memory"].get("peak_memory_in_bytes", 0) > 0
