"""Train-step construction: loss -> grads (remat scan inside the model) ->
optional error-feedback compression -> clip -> AdamW.  Supports gradient
accumulation (scan over microbatches) and mixed precision (bf16 params /
f32 master handled by the optimizer's f32 math).

This is the GSPMD path: called under jit with sharded params/batch, XLA
inserts the FSDP all-gathers and the DP gradient reduction.  The explicit
shard_map DP trainer with int8-compressed all-reduce lives in repro.dist.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from ..models.lm import LMModel
from .compression import ef_compress
from .optimizer import OptConfig, opt_init, opt_update

__all__ = ["TrainState", "make_train_step", "init_train_state"]


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    opt: OptConfig = OptConfig()
    accum_steps: int = 1
    compress_grads: bool = False
    remat: bool = True


def init_train_state(model: LMModel, key, opt_cfg: OptConfig, dtype=jnp.float32):
    params = model.init(key, dtype=dtype)
    return params, opt_init(params, opt_cfg)


def make_train_step(
    model: LMModel,
    tc: TrainConfig = TrainConfig(),
) -> Callable:
    """Returns train_step(params, opt_state, batch[, ef_state]) ->
    (params, opt_state, metrics[, ef_state])."""

    def loss_fn(params, batch):
        return model.loss(params, batch, remat=tc.remat)

    def compute_grads(params, batch):
        if tc.accum_steps == 1:
            return jax.value_and_grad(loss_fn)(params, batch)
        B = jax.tree.leaves(batch)[0].shape[0]
        assert B % tc.accum_steps == 0
        micro = B // tc.accum_steps
        mb = jax.tree.map(
            lambda x: x.reshape((tc.accum_steps, micro) + x.shape[1:]), batch
        )

        def step(carry, b):
            loss_sum, g_sum = carry
            l, g = jax.value_and_grad(loss_fn)(params, b)
            return (
                loss_sum + l,
                jax.tree.map(lambda a, c: a + c.astype(a.dtype), g_sum, g),
            ), None

        g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (loss_sum, g_sum), _ = jax.lax.scan(step, (jnp.float32(0.0), g0), mb)
        inv = 1.0 / tc.accum_steps
        return loss_sum * inv, jax.tree.map(lambda g: g * inv, g_sum)

    if tc.compress_grads:

        def train_step(params, opt_state, batch, ef_state):
            loss, grads = compute_grads(params, batch)
            grads, ef_state = ef_compress(grads, ef_state)
            params, opt_state, metrics = opt_update(
                grads, opt_state, params, tc.opt
            )
            metrics["loss"] = loss
            return params, opt_state, metrics, ef_state

        return train_step

    def train_step(params, opt_state, batch):
        loss, grads = compute_grads(params, batch)
        params, opt_state, metrics = opt_update(grads, opt_state, params, tc.opt)
        metrics["loss"] = loss
        return params, opt_state, metrics

    return train_step
