"""Gradient compression for cross-pod all-reduce: int8 quantization with
error feedback (1-bit-Adam-family residual carrying).

Two entry points:
  * ``quantize``/``dequantize`` — pure transforms (unit-testable anywhere).
  * ``compressed_psum`` — the shard_map collective: int8 payload summed in
    int32 across the named axis (4x fewer bytes on the wire than f32),
    used by the explicit-DP trainer in repro.dist.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = [
    "quantize",
    "dequantize",
    "ef_compress",
    "compressed_psum",
    "ef_init",
]


def quantize(g: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Per-tensor symmetric int8: returns (q int8, scale f32)."""
    g32 = g.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(g32)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g32 / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize(q: jax.Array, scale: jax.Array, dtype=jnp.float32) -> jax.Array:
    return (q.astype(jnp.float32) * scale).astype(dtype)


def ef_init(grads):
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)


def ef_compress(grads, residual):
    """Error-feedback compression: (grads, residual) -> (decompressed grads,
    new residual).  The returned grads are exactly what a compressed
    all-reduce would deliver; the quantization error is carried, not lost."""

    def one(g, e):
        corrected = g.astype(jnp.float32) + e
        q, s = quantize(corrected)
        deq = dequantize(q, s)
        return deq.astype(g.dtype), corrected - deq

    flat_g, tdef = jax.tree.flatten(grads)
    flat_e = tdef.flatten_up_to(residual)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return tdef.unflatten([o[0] for o in out]), tdef.unflatten([o[1] for o in out])


def compressed_psum(grads, axis_name: str):
    """int8-payload gradient all-reduce for use inside shard_map: quantize
    locally, sum int8 payloads in int32 across the axis, dequantize with the
    max scale.  Wire bytes: 1/4 of f32 psum (+ one scalar per tensor)."""

    def one(g):
        q, s = quantize(g)
        s_max = jax.lax.pmax(s, axis_name)
        # Requantize against the shared scale so the int32 sum is coherent.
        q_shared = jnp.clip(
            jnp.round(g.astype(jnp.float32) / s_max), -127, 127
        ).astype(jnp.int8)
        total = jax.lax.psum(q_shared.astype(jnp.int32), axis_name)
        n = jax.lax.psum(jnp.ones((), jnp.float32), axis_name)
        return (total.astype(jnp.float32) * s_max / n).astype(g.dtype)

    return jax.tree.map(one, grads)
