"""Straggler / failure detection for multi-host runs.

Two host-side mechanisms (both file/host-level — they do not touch jitted
code, matching how production JAX fleets handle this):

* ``StepTimeMonitor`` — per-host step-time ring buffer; flags steps slower
  than ``factor`` x rolling median.  The launcher's policy hook decides what
  to do (log, drop batch via skip-ahead, request reshard).
* ``Heartbeat`` — each host touches ``<dir>/host_<id>``; ``stale_hosts()``
  on the coordinator lists hosts whose heartbeat is older than the timeout —
  the trigger for elastic rescale (checkpoint restore on a smaller mesh via
  repro.train.checkpoint's elastic restore path).
"""
from __future__ import annotations

import os
import time
from collections import deque
from typing import Optional

__all__ = ["StepTimeMonitor", "Heartbeat"]


class StepTimeMonitor:
    def __init__(self, window: int = 64, factor: float = 2.5):
        self.times: deque[float] = deque(maxlen=window)
        self.factor = factor
        self.flagged = 0
        self._t0: Optional[float] = None

    def start(self):
        self._t0 = time.perf_counter()

    def stop(self) -> tuple[float, bool]:
        """-> (step_seconds, is_straggler)."""
        assert self._t0 is not None, "start() not called"
        dt = time.perf_counter() - self._t0
        self._t0 = None
        slow = False
        if len(self.times) >= 8:
            med = sorted(self.times)[len(self.times) // 2]
            slow = dt > self.factor * med
        if slow:
            self.flagged += 1
        self.times.append(dt)
        return dt, slow

    @property
    def median(self) -> float:
        if not self.times:
            return 0.0
        return sorted(self.times)[len(self.times) // 2]


class Heartbeat:
    def __init__(self, directory: str, host_id: int, timeout: float = 60.0):
        self.dir = directory
        self.host_id = host_id
        self.timeout = timeout
        os.makedirs(directory, exist_ok=True)

    def _path(self, host: int) -> str:
        return os.path.join(self.dir, f"host_{host:05d}")

    def beat(self):
        with open(self._path(self.host_id), "w") as f:
            f.write(str(time.time()))

    def stale_hosts(self, now: Optional[float] = None) -> list[int]:
        now = now if now is not None else time.time()
        stale = []
        for name in os.listdir(self.dir):
            if not name.startswith("host_"):
                continue
            host = int(name.split("_")[1])
            try:
                with open(os.path.join(self.dir, name)) as f:
                    last = float(f.read().strip() or 0)
            except (OSError, ValueError):
                last = 0.0
            if now - last > self.timeout:
                stale.append(host)
        return sorted(stale)
