"""AdamW + Adafactor with dtype-configurable moments (pure pytree ops).

Large-model configs pick their optimizer for the HBM budget: qwen2-72b keeps
AdamW (f32 moments fit at 256-chip FSDP+TP); deepseek-v3-671b uses Adafactor
(factored second moments, no first moment — the PaLM/T5 production choice)
because Adam moments alone would exceed the pod's 4TB HBM.  The dry-run's
memory_analysis is the proof.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

__all__ = ["OptConfig", "opt_init", "opt_update", "global_norm", "clip_by_global_norm"]


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    state_dtype: Any = jnp.float32  # bf16 for the largest configs
    warmup_steps: int = 100
    kind: str = "adamw"             # adamw | adafactor


def _factored(shape) -> bool:
    return len(shape) >= 2 and shape[-1] > 1 and shape[-2] > 1


def opt_init(params, cfg: OptConfig):
    if cfg.kind == "adafactor":
        def vr(p):  # row second-moment accumulator (drop last dim)
            return jnp.zeros(p.shape[:-1], jnp.float32)

        def vc(p):  # col accumulator (drop second-to-last dim)
            if _factored(p.shape):
                return jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)
            return jnp.zeros((1,), jnp.float32)  # unused for unfactored

        def vfull(p):
            if _factored(p.shape):
                return jnp.zeros((1,), jnp.float32)  # unused for factored
            return jnp.zeros(p.shape, jnp.float32)

        return {
            "vr": jax.tree.map(vr, params),
            "vc": jax.tree.map(vc, params),
            "v": jax.tree.map(vfull, params),
            "step": jnp.zeros((), jnp.int32),
        }
    zeros = lambda p: jnp.zeros(p.shape, cfg.state_dtype)
    return {
        "mu": jax.tree.map(zeros, params),
        "nu": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(sum(leaves))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads), norm


def _schedule(step, cfg: OptConfig):
    warm = jnp.minimum(step.astype(jnp.float32) / max(cfg.warmup_steps, 1), 1.0)
    return cfg.lr * warm


def _adafactor_update(grads, state, params, cfg: OptConfig):
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    step = state["step"] + 1
    lr = _schedule(step, cfg)
    t = step.astype(jnp.float32)
    beta2 = 1.0 - t ** (-0.8)   # Adafactor's increasing decay schedule

    def upd(p, g, vr, vc, v):
        g32 = g.astype(jnp.float32)
        g2 = g32 * g32 + 1e-30
        if _factored(p.shape):
            vr_n = beta2 * vr + (1 - beta2) * jnp.mean(g2, axis=-1)
            vc_n = beta2 * vc + (1 - beta2) * jnp.mean(g2, axis=-2)
            r = vr_n / jnp.maximum(
                jnp.mean(vr_n, axis=-1, keepdims=True), 1e-30
            )
            precond = r[..., None] * vc_n[..., None, :]
            update = g32 * jax.lax.rsqrt(precond + 1e-30)
            v_n = v
        else:
            v_n = beta2 * v + (1 - beta2) * g2
            update = g32 * jax.lax.rsqrt(v_n + 1e-30)
            vr_n, vc_n = vr, vc
        # relative update clipping (Adafactor d=1.0)
        rms_u = jnp.sqrt(jnp.mean(update * update) + 1e-30)
        update = update / jnp.maximum(1.0, rms_u)
        new_p = (
            p.astype(jnp.float32)
            - lr * update
            - lr * cfg.weight_decay * p.astype(jnp.float32)
        )
        return new_p.astype(p.dtype), vr_n, vc_n, v_n

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_vr = tdef.flatten_up_to(state["vr"])
    flat_vc = tdef.flatten_up_to(state["vc"])
    flat_v = tdef.flatten_up_to(state["v"])
    out = [
        upd(p, g, vr, vc, v)
        for p, g, vr, vc, v in zip(flat_p, flat_g, flat_vr, flat_vc, flat_v)
    ]
    return (
        tdef.unflatten([o[0] for o in out]),
        {
            "vr": tdef.unflatten([o[1] for o in out]),
            "vc": tdef.unflatten([o[2] for o in out]),
            "v": tdef.unflatten([o[3] for o in out]),
            "step": step,
        },
        {"grad_norm": gnorm, "lr": lr},
    )


def opt_update(grads, state, params, cfg: OptConfig):
    """-> (new_params, new_state, metrics)."""
    if cfg.kind == "adafactor":
        return _adafactor_update(grads, state, params, cfg)
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    step = state["step"] + 1
    lr = _schedule(step, cfg)
    t = step.astype(jnp.float32)
    bc1 = 1.0 - cfg.b1**t
    bc2 = 1.0 - cfg.b2**t

    def upd(p, g, mu, nu):
        g32 = g.astype(jnp.float32)
        mu32 = mu.astype(jnp.float32) * cfg.b1 + g32 * (1 - cfg.b1)
        nu32 = nu.astype(jnp.float32) * cfg.b2 + g32 * g32 * (1 - cfg.b2)
        mhat = mu32 / bc1
        vhat = nu32 / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p.astype(
            jnp.float32
        )
        return (
            (p.astype(jnp.float32) - lr * delta).astype(p.dtype),
            mu32.astype(cfg.state_dtype),
            nu32.astype(cfg.state_dtype),
        )

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_mu = tdef.flatten_up_to(state["mu"])
    flat_nu = tdef.flatten_up_to(state["nu"])
    out = [upd(p, g, m, n) for p, g, m, n in zip(flat_p, flat_g, flat_mu, flat_nu)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_mu = tdef.unflatten([o[1] for o in out])
    new_nu = tdef.unflatten([o[2] for o in out])
    return (
        new_p,
        {"mu": new_mu, "nu": new_nu, "step": step},
        {"grad_norm": gnorm, "lr": lr},
    )
