"""Fault-tolerant checkpointing: atomic on-disk snapshots, async writes,
retention, and **elastic restore** (re-shard onto any mesh — the restore path
takes target shardings, so a 256-chip checkpoint resumes on 512 chips or on
one CPU; this is the node-failure / elastic-rescale story).

Format: one .npz of flattened arrays + meta.json (step, tree paths, user
metadata).  Writes go to ``<dir>/tmp.<step>`` then rename — a crashed writer
never corrupts the latest checkpoint.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np

__all__ = ["save", "restore", "latest_step", "AsyncCheckpointer"]

_SEP = "||"


def _flatten(tree) -> dict[str, np.ndarray]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = _SEP.join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        out[key] = np.asarray(jax.device_get(leaf))
    return out


def _ckpt_dir(root: str, step: int) -> str:
    return os.path.join(root, f"step_{step:010d}")


def save(
    root: str,
    step: int,
    tree: Any,
    *,
    meta: Optional[dict] = None,
    keep: int = 3,
) -> str:
    """Atomic checkpoint write; prunes to the newest ``keep`` snapshots."""
    os.makedirs(root, exist_ok=True)
    tmp = os.path.join(root, f"tmp.{step}")
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    arrays = _flatten(tree)
    np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
    with open(os.path.join(tmp, "meta.json"), "w") as f:
        json.dump({"step": step, "meta": meta or {}}, f)
    final = _ckpt_dir(root, step)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    # retention
    steps = sorted(all_steps(root))
    for s in steps[:-keep]:
        shutil.rmtree(_ckpt_dir(root, s), ignore_errors=True)
    return final


def all_steps(root: str) -> list[int]:
    if not os.path.isdir(root):
        return []
    out = []
    for name in os.listdir(root):
        if name.startswith("step_") and os.path.isdir(os.path.join(root, name)):
            out.append(int(name.split("_")[1]))
    return sorted(out)


def latest_step(root: str) -> Optional[int]:
    steps = all_steps(root)
    return steps[-1] if steps else None


def restore(
    root: str,
    template: Any,
    *,
    step: Optional[int] = None,
    shardings: Any = None,
) -> tuple[int, Any]:
    """Restore into the structure of ``template``.

    ``shardings``: optional pytree (same structure) of jax.sharding.Sharding —
    arrays are placed directly onto the *target* mesh, whatever its size
    (elastic restore).  Without it, arrays land on the default device.
    """
    if step is None:
        step = latest_step(root)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {root}")
    d = _ckpt_dir(root, step)
    with np.load(os.path.join(d, "arrays.npz")) as z:
        arrays = {k: z[k] for k in z.files}

    paths, tdef = jax.tree_util.tree_flatten_with_path(template)
    shard_flat = (
        tdef.flatten_up_to(shardings) if shardings is not None else [None] * len(paths)
    )
    leaves = []
    for (path, leaf), shd in zip(paths, shard_flat):
        key = _SEP.join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        if key not in arrays:
            raise KeyError(f"checkpoint missing {key}")
        arr = arrays[key].astype(np.asarray(leaf).dtype if hasattr(leaf, "dtype") else None)
        if shd is not None:
            leaves.append(jax.device_put(arr, shd))
        else:
            leaves.append(jax.device_put(arr))
    return step, tdef.unflatten(leaves)


class AsyncCheckpointer:
    """Overlaps checkpoint I/O with training: device->host copy happens on
    the caller thread (cheap, required for consistency), serialization and
    disk I/O on a background thread.  ``wait()`` before exit."""

    def __init__(self, root: str, keep: int = 3):
        self.root = root
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self.last_error: Optional[BaseException] = None

    def save(self, step: int, tree: Any, meta: Optional[dict] = None):
        self.wait()
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)

        def _work():
            try:
                save(self.root, step, host_tree, meta=meta, keep=self.keep)
            except BaseException as e:  # surfaced on next wait()
                self.last_error = e

        self._thread = threading.Thread(target=_work, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self.last_error is not None:
            err, self.last_error = self.last_error, None
            raise err
