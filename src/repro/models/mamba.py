"""Mamba2 (SSD — state-space duality) block, chunked matmul form.

The quadratic-within-chunk / recurrent-across-chunk factorization keeps all
heavy ops as batched matmuls (MXU-friendly) while the cross-chunk state
recurrence is a short lax.scan.  Decode is the O(1)-per-token recurrence on
an (H, N, P) state — this is what makes the SSM archs runnable at the
long_500k cell (no KV growth).

Shapes: d_inner = expand*d_model, H heads of head_dim P, state N, groups G.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import dense_init, rms_norm

__all__ = ["mamba2"]


def _dims(cfg, d_model: int):
    di = cfg.ssm_expand * d_model
    P = cfg.ssm_head_dim
    H = di // P
    G, N = cfg.ssm_groups, cfg.ssm_state
    return di, H, P, G, N


class mamba2:
    @staticmethod
    def init(key, cfg, d_model: int, dtype=jnp.float32) -> dict:
        di, H, P, G, N = _dims(cfg, d_model)
        K = cfg.conv_kernel
        conv_dim = di + 2 * G * N
        ks = jax.random.split(key, 4)
        return {
            "in_proj": dense_init(
                ks[0], (d_model, 2 * di + 2 * G * N + H), dtype
            ),
            "conv_w": dense_init(ks[1], (K, conv_dim), dtype, std=0.1),
            "conv_b": jnp.zeros((conv_dim,), dtype),
            "A_log": jnp.zeros((H,), jnp.float32),
            "dt_bias": jnp.full((H,), -2.0, jnp.float32),
            "D": jnp.ones((H,), jnp.float32),
            "norm_w": jnp.ones((di,), dtype),
            "out_proj": dense_init(ks[2], (di, d_model), dtype),
        }

    # ------------------------------------------------------------------
    @staticmethod
    def _split(p, x, cfg, d_model):
        di, H, P, G, N = _dims(cfg, d_model)
        proj = x @ p["in_proj"]  # (B,S,2di+2GN+H)
        z, xs, Bc, Cc, dt = jnp.split(
            proj, [di, 2 * di, 2 * di + G * N, 2 * di + 2 * G * N], axis=-1
        )
        return z, xs, Bc, Cc, dt

    @staticmethod
    def _conv_train(p, u, K):
        """Causal depthwise conv along time: u (B,S,C)."""
        pad = jnp.pad(u, ((0, 0), (K - 1, 0), (0, 0)))
        out = sum(
            pad[:, i : i + u.shape[1], :] * p["conv_w"][i][None, None, :]
            for i in range(K)
        )
        return jax.nn.silu(out + p["conv_b"])

    # ------------------------------------------------------------------
    @staticmethod
    def forward_train(p, x, cfg, d_model: int, return_state: bool = False):
        B, S, _ = x.shape
        di, H, P, G, N = _dims(cfg, d_model)
        Q = min(cfg.ssm_chunk, S)
        assert S % Q == 0, f"seq {S} must divide chunk {Q}"
        nc = S // Q
        K = cfg.conv_kernel

        z, xs, Bc, Cc, dt = mamba2._split(p, x, cfg, d_model)
        conv_in = jnp.concatenate([xs, Bc, Cc], axis=-1)
        conv_out = mamba2._conv_train(p, conv_in, K)
        xs, Bc, Cc = jnp.split(conv_out, [di, di + G * N], axis=-1)

        dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])   # (B,S,H)
        A = -jnp.exp(p["A_log"])                                       # (H,)
        a = dt * A[None, None, :]                                      # (B,S,H) <= 0

        # Scan over chunks: one (B, Q, ...) working set at a time (bounds the
        # per-device transient at long S), carrying the (B, H, N, P) state.
        rep = H // G
        xh = xs.reshape(B, nc, Q, H, P).astype(jnp.float32).transpose(1, 0, 2, 3, 4)
        Bh = Bc.reshape(B, nc, Q, G, N).astype(jnp.float32).transpose(1, 0, 2, 3, 4)
        Ch = Cc.reshape(B, nc, Q, G, N).astype(jnp.float32).transpose(1, 0, 2, 3, 4)
        ac = a.reshape(B, nc, Q, H).transpose(1, 0, 2, 3)
        dtc = dt.reshape(B, nc, Q, H).transpose(1, 0, 2, 3)
        mask = jnp.tril(jnp.ones((Q, Q), bool))

        def chunk_step(h, inp):
            xc, bc, cc, a_c, dt_c = inp          # (B,Q,H,P) (B,Q,G,N) ... (B,Q,H)
            xbar = xc * dt_c[..., None]
            cum = jnp.cumsum(a_c, axis=1)        # (B,Q,H)
            li = cum[:, :, None, :] - cum[:, None, :, :]      # (B,Q,Q,H)
            Lm = jnp.where(mask[None, :, :, None], jnp.exp(li), 0.0)
            scores = jnp.einsum("bqgn,bsgn->bqsg", cc, bc)    # (B,Q,Q,G)
            att = jnp.repeat(scores, rep, axis=-1) * Lm
            y_intra = jnp.einsum("bqsh,bshp->bqhp", att, xbar)
            # inter-chunk contribution from the carried state
            cc_h = jnp.repeat(cc, rep, axis=2)                # (B,Q,H,N)
            y_inter = jnp.einsum(
                "bqh,bqhn,bhnp->bqhp", jnp.exp(cum), cc_h, h
            )
            # state update
            decay_to_end = jnp.exp(cum[:, -1:, :] - cum)      # (B,Q,H)
            bc_h = jnp.repeat(bc, rep, axis=2)
            s_c = jnp.einsum("bqh,bqhn,bqhp->bhnp", decay_to_end, bc_h, xbar)
            h_new = h * jnp.exp(cum[:, -1, :])[:, :, None, None] + s_c
            return h_new, y_intra + y_inter

        h0 = jnp.zeros((B, H, N, P), jnp.float32)
        h_last, ys = jax.lax.scan(chunk_step, h0, (xh, Bh, Ch, ac, dtc))
        y = ys.transpose(1, 0, 2, 3, 4).reshape(B, S, H, P)
        y = y + p["D"][None, None, :, None] * xs.reshape(B, S, H, P).astype(
            jnp.float32
        )
        y = y.reshape(B, S, di).astype(x.dtype)
        y = rms_norm(y * jax.nn.silu(z), p["norm_w"], cfg.rms_eps)
        out = y @ p["out_proj"]
        if not return_state:
            return out
        conv_tail = conv_in[:, -(K - 1) :, :] if K > 1 else conv_in[:, :0, :]
        return out, {"ssm": h_last, "conv": conv_tail.astype(x.dtype)}

    # ------------------------------------------------------------------
    @staticmethod
    def init_cache(cfg, d_model: int, batch: int, dtype=jnp.float32) -> dict:
        di, H, P, G, N = _dims(cfg, d_model)
        K = cfg.conv_kernel
        return {
            "ssm": jnp.zeros((batch, H, N, P), jnp.float32),
            "conv": jnp.zeros((batch, K - 1, di + 2 * G * N), dtype),
        }

    @staticmethod
    def forward_decode(p, x, cfg, cache, d_model: int):
        """x (B, 1, d); O(1) state recurrence."""
        B = x.shape[0]
        di, H, P, G, N = _dims(cfg, d_model)
        K = cfg.conv_kernel

        z, xs, Bc, Cc, dt = mamba2._split(p, x, cfg, d_model)
        u = jnp.concatenate([xs, Bc, Cc], axis=-1)                     # (B,1,C)
        window = jnp.concatenate([cache["conv"], u], axis=1)           # (B,K,C)
        conv_out = jax.nn.silu(
            jnp.einsum("bkc,kc->bc", window, p["conv_w"]) + p["conv_b"]
        )[:, None, :]
        xs, Bc, Cc = jnp.split(conv_out, [di, di + G * N], axis=-1)

        dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])[:, 0]  # (B,H)
        A = -jnp.exp(p["A_log"])
        dec = jnp.exp(dt * A[None, :])                                  # (B,H)
        xh = xs.reshape(B, H, P).astype(jnp.float32)
        rep = H // G
        Bh = jnp.repeat(Bc.reshape(B, G, N), rep, axis=1)               # (B,H,N)
        Ch = jnp.repeat(Cc.reshape(B, G, N), rep, axis=1)
        xbar = xh * dt[..., None]
        h = cache["ssm"] * dec[:, :, None, None] + jnp.einsum(
            "bhn,bhp->bhnp", Bh, xbar
        )
        y = jnp.einsum("bhn,bhnp->bhp", Ch, h) + p["D"][None, :, None] * xh
        y = y.reshape(B, 1, di).astype(x.dtype)
        y = rms_norm(y * jax.nn.silu(z), p["norm_w"], cfg.rms_eps)
        return y @ p["out_proj"], {
            "ssm": h,
            "conv": window[:, 1:, :].astype(x.dtype),
        }
