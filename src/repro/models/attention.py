"""Attention variants: GQA/MQA (optionally biased QKV) and MLA (DeepSeek-V3
multi-head latent attention with compressed-KV decode via weight absorption).

Each variant exposes:
    init(key, cfg, dtype)                       -> params
    forward_train(p, x, cfg, positions)         -> y                (causal)
    forward_prefill(p, x, cfg, positions)       -> y, cache
    forward_decode(p, x, cfg, cache, pos)       -> y, cache         (Sq == 1)

Caches are dicts of arrays sized to the target context length; ``pos`` is the
current fill level.  GQA caches (k, v); MLA caches the *compressed* latent
(c_kv, k_rope) — its decode attention runs in latent space (absorbed W_uk /
W_uv), which is the production MLA memory saving.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from ..dist import hints
from .common import (
    apply_rope,
    chunked_attention,
    decode_attention,
    dense_init,
    rms_norm,
    rope_sin_cos,
)

__all__ = ["gqa", "mla"]


# ==========================================================================
# GQA
# ==========================================================================
class gqa:
    @staticmethod
    def init(key, cfg, dtype=jnp.float32) -> dict:
        d, H, Hkv = cfg.d_model, cfg.n_heads, cfg.n_kv_heads
        hd = cfg.resolved_head_dim
        ks = jax.random.split(key, 4)
        p = {
            "wq": dense_init(ks[0], (d, H * hd), dtype),
            "wk": dense_init(ks[1], (d, Hkv * hd), dtype),
            "wv": dense_init(ks[2], (d, Hkv * hd), dtype),
            "wo": dense_init(ks[3], (H * hd, d), dtype),
        }
        if cfg.qkv_bias:
            p["bq"] = jnp.zeros((H * hd,), dtype)
            p["bk"] = jnp.zeros((Hkv * hd,), dtype)
            p["bv"] = jnp.zeros((Hkv * hd,), dtype)
        return p

    @staticmethod
    def _qkv(p, x, cfg, positions):
        B, S, d = x.shape
        H, Hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
        q = x @ p["wq"]
        k = x @ p["wk"]
        v = x @ p["wv"]
        if cfg.qkv_bias:
            q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
        q = hints.heads(q.reshape(B, S, H, hd))
        k = hints.heads(k.reshape(B, S, Hkv, hd))
        v = hints.heads(v.reshape(B, S, Hkv, hd))
        sin, cos = rope_sin_cos(positions, hd, cfg.rope_theta)
        return apply_rope(q, sin, cos), apply_rope(k, sin, cos), v

    @staticmethod
    def forward_train(p, x, cfg, positions, causal: bool = True):
        q, k, v = gqa._qkv(p, x, cfg, positions)
        y = chunked_attention(q, k, v, causal=causal)
        B, S = x.shape[:2]
        return y.reshape(B, S, -1) @ p["wo"]

    @staticmethod
    def forward_prefill(p, x, cfg, positions, cache_len: int):
        B, S, _ = x.shape
        q, k, v = gqa._qkv(p, x, cfg, positions)
        y = chunked_attention(q, k, v, causal=True)
        Hkv, hd = cfg.n_kv_heads, cfg.resolved_head_dim
        k_cache = jnp.zeros((B, cache_len, Hkv, hd), x.dtype)
        v_cache = jnp.zeros((B, cache_len, Hkv, hd), x.dtype)
        cache = {
            "k": jax.lax.dynamic_update_slice(k_cache, k, (0, 0, 0, 0)),
            "v": jax.lax.dynamic_update_slice(v_cache, v, (0, 0, 0, 0)),
        }
        return y.reshape(B, S, -1) @ p["wo"], cache

    @staticmethod
    def forward_decode(p, x, cfg, cache, pos):
        B = x.shape[0]
        positions = jnp.full((B, 1), pos, jnp.int32)
        q, k, v = gqa._qkv(p, x, cfg, positions)
        cd = cache["k"].dtype  # cache may be narrower (f8 KV quantization)
        kc = jax.lax.dynamic_update_slice(
            cache["k"], k.astype(cd), (0, pos, 0, 0)
        )
        vc = jax.lax.dynamic_update_slice(
            cache["v"], v.astype(cd), (0, pos, 0, 0)
        )
        y = decode_attention(q, kc, vc, pos + 1)
        return y.reshape(B, 1, -1) @ p["wo"], {"k": kc, "v": vc}

    # -- cross attention (whisper decoder) ---------------------------------
    @staticmethod
    def forward_cross(p, x, kv_src, cfg):
        """x (B, Sq, d) attends over kv_src (B, Sk, d); no RoPE, no causal."""
        B, Sq, d = x.shape
        H, Hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
        q = (x @ p["wq"]).reshape(B, Sq, H, hd)
        k = (kv_src @ p["wk"]).reshape(B, -1, Hkv, hd)
        v = (kv_src @ p["wv"]).reshape(B, -1, Hkv, hd)
        y = chunked_attention(q, k, v, causal=False)
        return y.reshape(B, Sq, -1) @ p["wo"]

    @staticmethod
    def cross_kv(p, kv_src, cfg):
        """Precompute cross-attention K/V once per request (decode path)."""
        B = kv_src.shape[0]
        Hkv, hd = cfg.n_kv_heads, cfg.resolved_head_dim
        k = (kv_src @ p["wk"]).reshape(B, -1, Hkv, hd)
        v = (kv_src @ p["wv"]).reshape(B, -1, Hkv, hd)
        return k, v

    @staticmethod
    def forward_cross_cached(p, x, k, v, cfg):
        B, Sq, _ = x.shape
        H, hd = cfg.n_heads, cfg.resolved_head_dim
        q = (x @ p["wq"]).reshape(B, Sq, H, hd)
        y = decode_attention(q, k, v, jnp.int32(k.shape[1]))
        return y.reshape(B, Sq, -1) @ p["wo"]


# ==========================================================================
# MLA — multi-head latent attention (DeepSeek-V2/V3).
# ==========================================================================
class mla:
    @staticmethod
    def init(key, cfg, dtype=jnp.float32) -> dict:
        d, H = cfg.d_model, cfg.n_heads
        dn, dr, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
        rq, rkv = cfg.q_lora_rank, cfg.kv_lora_rank
        ks = jax.random.split(key, 8)
        p = {
            "w_dkv": dense_init(ks[0], (d, rkv), dtype),
            "kv_norm": jnp.ones((rkv,), dtype),
            "w_uk": dense_init(ks[1], (rkv, H, dn), dtype),
            "w_uv": dense_init(ks[2], (rkv, H, dv), dtype),
            "w_kr": dense_init(ks[3], (d, dr), dtype),
            "wo": dense_init(ks[4], (H * dv, d), dtype),
        }
        if rq:
            p["w_dq"] = dense_init(ks[5], (d, rq), dtype)
            p["q_norm"] = jnp.ones((rq,), dtype)
            p["w_uq"] = dense_init(ks[6], (rq, H, dn + dr), dtype)
        else:
            p["w_q"] = dense_init(ks[6], (d, H, dn + dr), dtype)
        return p

    @staticmethod
    def _q(p, x, cfg, positions):
        B, S, _ = x.shape
        dn, dr = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim
        if cfg.q_lora_rank:
            cq = rms_norm(x @ p["w_dq"], p["q_norm"], cfg.rms_eps)
            q = jnp.einsum("bsr,rhd->bshd", cq, p["w_uq"])
        else:
            q = jnp.einsum("bsd,dhe->bshe", x, p["w_q"])
        q_nope, q_rope = q[..., :dn], q[..., dn:]
        sin, cos = rope_sin_cos(positions, dr, cfg.rope_theta)
        return q_nope, apply_rope(q_rope, sin, cos)

    @staticmethod
    def _latent(p, x, cfg, positions):
        c_kv = rms_norm(x @ p["w_dkv"], p["kv_norm"], cfg.rms_eps)  # (B,S,rkv)
        k_rope = (x @ p["w_kr"])[:, :, None, :]                     # (B,S,1,dr)
        sin, cos = rope_sin_cos(positions, cfg.qk_rope_head_dim, cfg.rope_theta)
        return c_kv, apply_rope(k_rope, sin, cos)[:, :, 0, :]       # (B,S,dr)

    @staticmethod
    def forward_train(p, x, cfg, positions, causal: bool = True):
        """Materialized form (cheaper when Sq is long)."""
        B, S, _ = x.shape
        dn, dv = cfg.qk_nope_head_dim, cfg.v_head_dim
        q_nope, q_rope = mla._q(p, x, cfg, positions)
        c_kv, k_rope = mla._latent(p, x, cfg, positions)
        k_nope = jnp.einsum("bsr,rhd->bshd", c_kv, p["w_uk"])
        v = jnp.einsum("bsr,rhd->bshd", c_kv, p["w_uv"])
        H = cfg.n_heads
        k_rope_h = jnp.broadcast_to(
            k_rope[:, :, None, :], (B, S, H, cfg.qk_rope_head_dim)
        )
        q = jnp.concatenate([q_nope, q_rope], -1)
        k = jnp.concatenate([k_nope, k_rope_h], -1)
        y = chunked_attention(q, k, v, causal=causal)
        return y.reshape(B, S, -1) @ p["wo"]

    @staticmethod
    def forward_prefill(p, x, cfg, positions, cache_len: int):
        B, S, _ = x.shape
        y = mla.forward_train(p, x, cfg, positions, causal=True)
        c_kv, k_rope = mla._latent(p, x, cfg, positions)
        ckv_cache = jnp.zeros((B, cache_len, cfg.kv_lora_rank), x.dtype)
        kr_cache = jnp.zeros((B, cache_len, cfg.qk_rope_head_dim), x.dtype)
        cache = {
            "c_kv": jax.lax.dynamic_update_slice(ckv_cache, c_kv, (0, 0, 0)),
            "k_rope": jax.lax.dynamic_update_slice(kr_cache, k_rope, (0, 0, 0)),
        }
        return y, cache

    @staticmethod
    def forward_decode(p, x, cfg, cache, pos):
        """Absorbed-latent decode: scores and values computed against the
        compressed cache; per-token cost O(S * (r_kv + d_rope)) per head."""
        B = x.shape[0]
        positions = jnp.full((B, 1), pos, jnp.int32)
        q_nope, q_rope = mla._q(p, x, cfg, positions)       # (B,1,H,dn/dr)
        c_kv_new, k_rope_new = mla._latent(p, x, cfg, positions)
        cd = cache["c_kv"].dtype
        ckv_store = jax.lax.dynamic_update_slice(
            cache["c_kv"], c_kv_new.astype(cd), (0, pos, 0)
        )
        kr_store = jax.lax.dynamic_update_slice(
            cache["k_rope"], k_rope_new.astype(cd), (0, pos, 0)
        )
        ckv = ckv_store.astype(x.dtype)
        kr = kr_store.astype(x.dtype)
        # absorb W_uk into the query: q_lat (B,1,H,rkv)
        q_lat = jnp.einsum("bqhd,rhd->bqhr", q_nope, p["w_uk"])
        s_lat = jnp.einsum(
            "bqhr,bsr->bhqs", q_lat, ckv, preferred_element_type=jnp.float32
        )
        s_rope = jnp.einsum(
            "bqhd,bsd->bhqs", q_rope, kr, preferred_element_type=jnp.float32
        )
        dh = cfg.qk_nope_head_dim + cfg.qk_rope_head_dim
        s = (s_lat + s_rope) / jnp.sqrt(jnp.float32(dh))
        S = ckv.shape[1]
        valid = jnp.arange(S)[None, None, None, :] < (pos + 1)
        s = jnp.where(valid, s, jnp.float32(-1e30))
        w = jax.nn.softmax(s, axis=-1)
        ctx_lat = jnp.einsum(
            "bhqs,bsr->bqhr", w.astype(ckv.dtype), ckv,
            preferred_element_type=jnp.float32,
        )
        y = jnp.einsum("bqhr,rhd->bqhd", ctx_lat.astype(x.dtype), p["w_uv"])
        y = y.reshape(B, 1, -1) @ p["wo"]
        return y, {"c_kv": ckv_store, "k_rope": kr_store}
