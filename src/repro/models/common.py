"""Shared model building blocks: norms, RoPE, activations, memory-efficient
attention.  Pure functions over explicit param pytrees (dict-of-arrays);
no framework dependency beyond jax.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

__all__ = [
    "Param",
    "dense_init",
    "rms_norm",
    "act_fn",
    "rope_sin_cos",
    "apply_rope",
    "chunked_attention",
    "decode_attention",
]

DEFAULT_INIT_STD = 0.02


def dense_init(key, shape, dtype=jnp.float32, std: float = DEFAULT_INIT_STD):
    return (jax.random.normal(key, shape, jnp.float32) * std).astype(dtype)


def rms_norm(x: jax.Array, w: jax.Array, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return ((x32 * jax.lax.rsqrt(var + eps)) * w.astype(jnp.float32)).astype(dt)


def act_fn(name: str, x: jax.Array) -> jax.Array:
    if name == "silu":
        return jax.nn.silu(x)
    if name == "gelu":
        return jax.nn.gelu(x)
    raise ValueError(name)


# --------------------------------------------------------------------------
# Rotary position embeddings.
# --------------------------------------------------------------------------
def rope_sin_cos(positions: jax.Array, head_dim: int, theta: float):
    """positions (...,) -> sin/cos (..., head_dim/2)."""
    half = head_dim // 2
    freqs = 1.0 / (
        theta ** (jnp.arange(0, half, dtype=jnp.float32) / half)
    )
    ang = positions.astype(jnp.float32)[..., None] * freqs  # (..., half)
    return jnp.sin(ang), jnp.cos(ang)


def apply_rope(x: jax.Array, sin: jax.Array, cos: jax.Array) -> jax.Array:
    """x (B, S, H, D); sin/cos (B?, S, D/2) broadcast over heads."""
    dt = x.dtype
    x = x.astype(jnp.float32)
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    if sin.ndim == 2:  # (S, half) -> broadcast batch + heads
        sin = sin[None, :, None, :]
        cos = cos[None, :, None, :]
    else:  # (B, S, half)
        sin = sin[:, :, None, :]
        cos = cos[:, :, None, :]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(dt)


# --------------------------------------------------------------------------
# Memory-efficient (flash-style) attention in pure JAX.
#
# Never materializes the full (S, S) score matrix: scans KV chunks with a
# running (max, denom, acc) triple; queries are processed in chunks via an
# outer map.  This is the XLA-lowerable form used by every dry-run config —
# a Pallas flash kernel would only change constants, not the roofline FLOPs.
# --------------------------------------------------------------------------
NEG_INF = jnp.float32(-1e30)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "q_chunk", "kv_chunk", "q_offset_static"),
)
def chunked_attention(
    q: jax.Array,        # (B, Sq, H, Dh)
    k: jax.Array,        # (B, Sk, Hkv, Dh)
    v: jax.Array,        # (B, Sk, Hkv, Dv)
    *,
    causal: bool = True,
    q_chunk: int = 1024,
    kv_chunk: int = 1024,
    q_offset: Optional[jax.Array] = None,
    q_offset_static: int = 0,
    kv_valid_len: Optional[jax.Array] = None,
) -> jax.Array:
    """Grouped-query flash-style attention.  Returns (B, Sq, H, Dv).

    q_offset: position of q[0] within the kv sequence (for cached prefill);
    kv_valid_len: mask out kv positions >= this (ragged caches).
    """
    B, Sq, H, Dh = q.shape
    Sk, Hkv = k.shape[1], k.shape[2]
    rep = H // Hkv
    scale = 1.0 / jnp.sqrt(jnp.float32(Dh))
    q_chunk = min(q_chunk, Sq)
    kv_chunk = min(kv_chunk, Sk)
    if Sq % q_chunk:   # non-divisible (e.g. whisper's 1500 frames): one block
        q_chunk = Sq
    if Sk % kv_chunk:
        kv_chunk = Sk
    nq, nk = Sq // q_chunk, Sk // kv_chunk
    qoff = (
        q_offset.astype(jnp.int32)
        if q_offset is not None
        else jnp.int32(q_offset_static)
    )

    # fold head-groups: q (B, H, Sq, Dh) with H = Hkv * rep
    qh = q.transpose(0, 2, 1, 3).reshape(B, Hkv, rep, Sq, Dh)
    kh = k.transpose(0, 2, 1, 3)  # (B, Hkv, Sk, Dh)
    vh = v.transpose(0, 2, 1, 3)  # (B, Hkv, Sk, Dv)
    Dv = vh.shape[-1]

    def q_block(qi, qc):  # qc: (B, Hkv, rep, qchunk, Dh)
        q_pos = qoff + qi * q_chunk + jnp.arange(q_chunk)

        def kv_step(carry, ki):
            m, l, acc = carry
            kc = jax.lax.dynamic_slice_in_dim(kh, ki * kv_chunk, kv_chunk, 2)
            vc = jax.lax.dynamic_slice_in_dim(vh, ki * kv_chunk, kv_chunk, 2)
            s = jnp.einsum(
                "bgrqd,bgkd->bgrqk", qc, kc,
                preferred_element_type=jnp.float32,
            ) * scale
            k_pos = ki * kv_chunk + jnp.arange(kv_chunk)
            mask = jnp.ones((q_chunk, kv_chunk), bool)
            if causal:
                mask = mask & (k_pos[None, :] <= q_pos[:, None])
            if kv_valid_len is not None:
                mask = mask & (k_pos[None, :] < kv_valid_len)
            s = jnp.where(mask[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bgrqk,bgkd->bgrqd", p.astype(vc.dtype), vc,
                preferred_element_type=jnp.float32,
            )
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, Hkv, rep, q_chunk), NEG_INF)
        l0 = jnp.zeros((B, Hkv, rep, q_chunk))
        a0 = jnp.zeros((B, Hkv, rep, q_chunk, Dv))
        (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0), jnp.arange(nk))
        return acc / jnp.maximum(l[..., None], 1e-30)

    if nq == 1:
        out = q_block(0, qh)
    else:
        qh_blocks = qh.reshape(B, Hkv, rep, nq, q_chunk, Dh).transpose(
            3, 0, 1, 2, 4, 5
        )
        out = jax.lax.map(lambda t: q_block(t[0], t[1]),
                          (jnp.arange(nq), qh_blocks))
        out = out.transpose(1, 2, 3, 0, 4, 5).reshape(B, Hkv, rep, Sq, Dv)
    return out.reshape(B, H, Sq, Dv).transpose(0, 2, 1, 3).astype(q.dtype)


@jax.jit
def decode_attention(
    q: jax.Array,          # (B, 1, H, Dh)
    k_cache: jax.Array,    # (B, S, Hkv, Dh)
    v_cache: jax.Array,    # (B, S, Hkv, Dv)
    pos: jax.Array,        # scalar int — number of valid cache entries
) -> jax.Array:
    """Single-token attention against a (possibly partially filled) cache.
    Caches may be stored in a narrower dtype (e.g. f8 KV quantization — the
    decode-cell memory-roofline lever); compute runs in q's dtype."""
    B, S, Hkv, Dh = k_cache.shape
    k_cache = k_cache.astype(q.dtype)
    v_cache = v_cache.astype(q.dtype)
    H = q.shape[2]
    rep = H // Hkv
    scale = 1.0 / jnp.sqrt(jnp.float32(Dh))
    qh = q.reshape(B, Hkv, rep, Dh)
    s = jnp.einsum(
        "bgrd,bsgd->bgrs", qh, k_cache, preferred_element_type=jnp.float32
    ) * scale
    valid = jnp.arange(S)[None, None, None, :] < pos
    s = jnp.where(valid, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum(
        "bgrs,bsgd->bgrd", p.astype(v_cache.dtype), v_cache,
        preferred_element_type=jnp.float32,
    )
    return out.reshape(B, 1, H, -1).astype(q.dtype)
