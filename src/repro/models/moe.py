"""FFN blocks: dense GLU and routed Mixture-of-Experts.

The MoE uses grouped sort-based dispatch (MegaBlocks-style, no (T,E,C)
one-hot): tokens are grouped (group axis shards over the data mesh axis),
each group's routed tokens are sorted by expert and scattered into an
(E, C, d) buffer (expert axis shards over the model mesh axis — this is the
EP boundary; GSPMD emits the all-to-all), batched expert GEMMs run at
capacity, and outputs are combined with router weights.  Shared experts
(DeepSeek-style) run densely.  Aux-free balancing bias (DeepSeek-V3) is a
router parameter added to the selection logits only.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..dist import hints
from .common import act_fn, dense_init

__all__ = ["dense_ffn", "moe_ffn", "pick_group_count"]


# --------------------------------------------------------------------------
# Dense GLU FFN (SwiGLU / GeGLU).
# --------------------------------------------------------------------------
class dense_ffn:
    @staticmethod
    def init(key, d_model: int, d_ff: int, dtype=jnp.float32) -> dict:
        ks = jax.random.split(key, 3)
        return {
            "w_gate": dense_init(ks[0], (d_model, d_ff), dtype),
            "w_up": dense_init(ks[1], (d_model, d_ff), dtype),
            "w_down": dense_init(ks[2], (d_ff, d_model), dtype),
        }

    @staticmethod
    def forward(p, x, act: str = "silu"):
        h = act_fn(act, x @ p["w_gate"]) * (x @ p["w_up"])
        return hints.ffn_hidden(h) @ p["w_down"]


# --------------------------------------------------------------------------
# Routed MoE.
# --------------------------------------------------------------------------
def pick_group_count(n_tokens: int, n_experts: int, top_k: int) -> int:
    """Groups sized so per-group expert capacity lands >= ~8 slots (avoids
    rounding waste at decode shapes while keeping the dispatch buffer
    shardable at train shapes)."""
    g = max(1, n_tokens * top_k // (n_experts * 8))
    # round down to a power of two for even mesh divisibility
    p = 1
    while p * 2 <= g:
        p *= 2
    return p


class moe_ffn:
    @staticmethod
    def init(key, cfg, dtype=jnp.float32) -> dict:
        d, E, fe = cfg.d_model, cfg.n_experts, cfg.d_ff_expert
        ks = jax.random.split(key, 6)
        p = {
            "router": dense_init(ks[0], (d, E), dtype, std=0.006),
            "w_gate": dense_init(ks[1], (E, d, fe), dtype),
            "w_up": dense_init(ks[2], (E, d, fe), dtype),
            "w_down": dense_init(ks[3], (E, fe, d), dtype),
        }
        if cfg.router_aux_free:
            p["router_bias"] = jnp.zeros((E,), jnp.float32)
        if cfg.n_shared:
            p["shared"] = dense_ffn.init(
                ks[4], d, cfg.d_ff_expert * cfg.n_shared, dtype
            )
        return p

    @staticmethod
    def forward(p, x, cfg):
        """x (B, S, d) -> (B, S, d)."""
        B, S, d = x.shape
        E, k = cfg.n_experts, cfg.top_k
        T = B * S
        G = pick_group_count(T, E, k)
        Sg = T // G
        assert G * Sg == T, f"tokens {T} not divisible into {G} groups"
        xt = x.reshape(G, Sg, d)

        logits = jnp.einsum("gsd,de->gse", xt, p["router"]).astype(jnp.float32)
        probs = jax.nn.softmax(logits, axis=-1)
        select = logits + p["router_bias"] if cfg.router_aux_free else logits
        _, top_idx = jax.lax.top_k(select, k)                   # (G, Sg, k)
        top_w = jnp.take_along_axis(probs, top_idx, axis=-1)
        top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)

        C = int(Sg * k * cfg.capacity_factor / E) + 1
        C = max(8, ((C + 7) // 8) * 8)  # lane-friendly capacity
        C = min(C, Sg * k)

        def dispatch_group(xg, idx_g, w_g):
            # xg (Sg, d); idx/w (Sg, k)
            fe_ = idx_g.reshape(-1)                              # (Sg*k,)
            order = jnp.argsort(fe_)
            se = fe_[order]
            tok = order // k
            pos = jnp.arange(se.shape[0]) - jnp.searchsorted(se, se, side="left")
            keep = pos < C
            slot = jnp.where(keep, se * C + pos, E * C)          # E*C = drop bin
            buf = jnp.zeros((E * C + 1, d), xg.dtype)
            buf = buf.at[slot].set(xg[tok] * keep[:, None].astype(xg.dtype))
            return buf[:-1].reshape(E, C, d), slot, tok, order, keep

        buf, slot, tok, order, keep = jax.vmap(dispatch_group)(xt, top_idx, top_w)

        # batched expert GEMMs (g e c d) x (e d f) — EP along e
        h_gate = jnp.einsum("gecd,edf->gecf", buf, p["w_gate"])
        h_up = jnp.einsum("gecd,edf->gecf", buf, p["w_up"])
        h = act_fn(cfg.act, h_gate) * h_up
        out_buf = jnp.einsum("gecf,efd->gecd", h, p["w_down"])

        def combine_group(out_g, slot_g, tok_g, order_g, keep_g, w_g):
            flat = out_g.reshape(E * C, d)
            vals = flat[jnp.minimum(slot_g, E * C - 1)]         # (Sg*k, d)
            vals = vals * keep_g[:, None].astype(vals.dtype)
            w_flat = w_g.reshape(-1)[order_g]
            y = jnp.zeros((Sg, d), out_g.dtype)
            return y.at[tok_g].add(vals * w_flat[:, None].astype(vals.dtype))

        y = jax.vmap(combine_group)(out_buf, slot, tok, order, keep, top_w)
        y = y.reshape(B, S, d)
        if cfg.n_shared:
            y = y + dense_ffn.forward(p["shared"], x, cfg.act)
        return y
