"""Language-model assembly: layer specs -> scanned stacks -> full models.

A model is a sequence of *stacks*; each stack scans ``count`` identical
*units*; a unit is an ordered list of sub-blocks (pre-norm residual each):

    dense LM            : 1 stack,  unit = [attn, ffn]           x n_layers
    deepseek-v3         : 2 stacks, [mla, ffn] x 3 ; [mla, moe] x 58
    jamba               : 1 stack,  unit = 8 sub-layer pairs (1 attn : 7
                          mamba, MoE every 2nd)                  x 4
    mamba2              : 1 stack,  unit = [mamba]                x 48
    whisper (enc-dec)   : encoder stack + decoder stack (w/ cross-attn)
    internvl2 (vlm)     : dense LM consuming [patch embeds ; token embeds]

Caches are per-stack pytrees with a leading unit axis, scanned alongside the
stacked params in decode.  Training scans with jax.checkpoint (remat).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from ..dist import hints
from .attention import gqa, mla
from .common import dense_init, rms_norm
from .mamba import mamba2
from .moe import dense_ffn, moe_ffn

__all__ = ["LayerSpec", "LMModel", "build_model", "chunked_ce_loss"]

# A sub-block: (kind, options). kinds: gqa | mla | mamba | ffn | moe | cross
LayerSpec = tuple[tuple[str, dict], ...]


# --------------------------------------------------------------------------
# Unit init / apply.
# --------------------------------------------------------------------------
def _init_sub(key, kind: str, opt: dict, cfg: ArchConfig, dtype):
    norm = jnp.ones((cfg.d_model,), dtype)
    if kind in ("gqa", "cross"):
        return {"norm": norm, **gqa.init(key, cfg, dtype)}
    if kind == "mla":
        return {"norm": norm, **mla.init(key, cfg, dtype)}
    if kind == "mamba":
        return {"norm": norm, **mamba2.init(key, cfg, cfg.d_model, dtype)}
    if kind == "ffn":
        d_ff = opt.get("d_ff", cfg.d_ff)
        return {"norm": norm, **dense_ffn.init(key, cfg.d_model, d_ff, dtype)}
    if kind == "moe":
        return {"norm": norm, **moe_ffn.init(key, cfg, dtype)}
    raise ValueError(kind)


def init_unit(key, spec: LayerSpec, cfg: ArchConfig, dtype) -> dict:
    ks = jax.random.split(key, len(spec))
    return {
        f"sub{i}": _init_sub(ks[i], kind, opt, cfg, dtype)
        for i, (kind, opt) in enumerate(spec)
    }


def _empty_cache():
    return {}


def init_unit_cache(
    spec: LayerSpec, cfg: ArchConfig, batch: int, cache_len: int, dtype,
    kv_dtype=None,
) -> dict:
    kv_dtype = kv_dtype or dtype  # attention caches may be narrower (f8 KV)
    out = {}
    for i, (kind, opt) in enumerate(spec):
        if kind == "gqa":
            hkv, hd = cfg.n_kv_heads, cfg.resolved_head_dim
            out[f"sub{i}"] = {
                "k": jnp.zeros((batch, cache_len, hkv, hd), kv_dtype),
                "v": jnp.zeros((batch, cache_len, hkv, hd), kv_dtype),
            }
        elif kind == "mla":
            out[f"sub{i}"] = {
                "c_kv": jnp.zeros((batch, cache_len, cfg.kv_lora_rank), kv_dtype),
                "k_rope": jnp.zeros(
                    (batch, cache_len, cfg.qk_rope_head_dim), kv_dtype
                ),
            }
        elif kind == "mamba":
            out[f"sub{i}"] = mamba2.init_cache(cfg, cfg.d_model, batch, dtype)
        elif kind == "cross":
            hkv, hd = cfg.n_kv_heads, cfg.resolved_head_dim
            out[f"sub{i}"] = {
                "ck": jnp.zeros((batch, cfg.enc_seq, hkv, hd), dtype),
                "cv": jnp.zeros((batch, cfg.enc_seq, hkv, hd), dtype),
            }
        else:
            out[f"sub{i}"] = _empty_cache()
    return out


def apply_unit(
    params: dict,
    x: jax.Array,
    spec: LayerSpec,
    cfg: ArchConfig,
    mode: str,                      # train | prefill | decode
    positions: Optional[jax.Array],
    cache: Optional[dict] = None,
    pos: Any = 0,
    cache_len: int = 0,
    enc_out: Optional[jax.Array] = None,
    causal: bool = True,
):
    new_cache = {}
    for i, (kind, opt) in enumerate(spec):
        p = params[f"sub{i}"]
        h = rms_norm(x, p["norm"], cfg.rms_eps)
        c = cache[f"sub{i}"] if cache is not None else None
        if kind == "gqa":
            if mode == "train":
                y = gqa.forward_train(p, h, cfg, positions, causal=causal)
                nc = _empty_cache()
            elif mode == "prefill":
                y, nc = gqa.forward_prefill(p, h, cfg, positions, cache_len)
            else:
                y, nc = gqa.forward_decode(p, h, cfg, c, pos)
        elif kind == "mla":
            if mode == "train":
                y = mla.forward_train(p, h, cfg, positions)
                nc = _empty_cache()
            elif mode == "prefill":
                y, nc = mla.forward_prefill(p, h, cfg, positions, cache_len)
            else:
                y, nc = mla.forward_decode(p, h, cfg, c, pos)
        elif kind == "mamba":
            if mode == "train":
                y = mamba2.forward_train(p, h, cfg, cfg.d_model)
                nc = _empty_cache()
            elif mode == "prefill":
                y, nc = mamba2.forward_train(
                    p, h, cfg, cfg.d_model, return_state=True
                )
            else:
                y, nc = mamba2.forward_decode(p, h, cfg, c, cfg.d_model)
        elif kind == "cross":
            if mode == "train":
                y = gqa.forward_cross(p, h, enc_out, cfg)
                nc = _empty_cache()
            elif mode == "prefill":
                ck, cv = gqa.cross_kv(p, enc_out, cfg)
                y = gqa.forward_cross(p, h, enc_out, cfg)
                nc = {"ck": ck, "cv": cv}
            else:
                y = gqa.forward_cross_cached(p, h, c["ck"], c["cv"], cfg)
                nc = c
        elif kind == "ffn":
            y = dense_ffn.forward(p, h, cfg.act)
            nc = _empty_cache()
        elif kind == "moe":
            y = moe_ffn.forward(p, h, cfg)
            nc = _empty_cache()
        else:
            raise ValueError(kind)
        x = hints.act(x + y)  # re-anchor the residual stream's sharding
        new_cache[f"sub{i}"] = nc
    return x, new_cache


# --------------------------------------------------------------------------
# Loss (sequence-chunked CE: never materializes (B, S, V) logits).
# --------------------------------------------------------------------------
def chunked_ce_loss(
    h: jax.Array, labels: jax.Array, w_head: jax.Array, chunk: int = 512
) -> jax.Array:
    """h (B, S, d), labels (B, S) -> mean next-token CE (logits from w_head)."""
    B, S, d = h.shape
    chunk = min(chunk, S)
    while S % chunk:  # largest divisor of S at most the requested chunk
        chunk -= 1
    hs = h.reshape(B, S // chunk, chunk, d).transpose(1, 0, 2, 3)
    ls = labels.reshape(B, S // chunk, chunk).transpose(1, 0, 2)

    def step(tot, inp):
        hc, lc = inp
        logits = (hc @ w_head).astype(jnp.float32)  # (B, chunk, V)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lc[..., None], axis=-1)[..., 0]
        return tot + jnp.sum(lse - gold), None

    tot, _ = jax.lax.scan(step, jnp.float32(0.0), (hs, ls))
    return tot / (B * S)


# --------------------------------------------------------------------------
# Model.
# --------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class StackDef:
    count: int
    spec: LayerSpec
    role: str = "decoder"  # decoder | encoder


@dataclasses.dataclass(frozen=True)
class LMModel:
    cfg: ArchConfig
    stacks: tuple[StackDef, ...]

    # ------------------------------------------------------------- params
    def init(self, key, dtype=jnp.float32) -> dict:
        cfg = self.cfg
        keys = jax.random.split(key, len(self.stacks) + 4)
        params: dict = {
            "embed": dense_init(keys[0], (cfg.vocab, cfg.d_model), dtype),
            "final_norm": jnp.ones((cfg.d_model,), dtype),
        }
        if not cfg.tie_embeddings:
            params["lm_head"] = dense_init(
                keys[1], (cfg.d_model, cfg.vocab), dtype
            )
        if cfg.encdec:
            params["enc_final_norm"] = jnp.ones((cfg.d_model,), dtype)
        for si, sd in enumerate(self.stacks):
            uks = jax.random.split(keys[2 + si], sd.count)
            units = [init_unit(uk, sd.spec, cfg, dtype) for uk in uks]
            params[f"stack{si}"] = jax.tree.map(
                lambda *xs: jnp.stack(xs), *units
            )
        return params

    def _head(self, params):
        return (
            params["embed"].T
            if self.cfg.tie_embeddings
            else params["lm_head"]
        )

    def _embed(self, params, tokens):
        x = params["embed"][tokens]
        if self.cfg.scale_embed:
            x = x * jnp.sqrt(jnp.float32(self.cfg.d_model)).astype(x.dtype)
        return x

    # --------------------------------------------------------------- runs
    def _run_stacks(
        self, params, x, mode, positions, caches=None, pos=0,
        cache_len=0, enc_out=None, role="decoder", remat=True, causal=True,
    ):
        new_caches = []
        for si, sd in enumerate(self.stacks):
            if sd.role != role:
                new_caches.append(caches[si] if caches else None)
                continue
            stack_p = params[f"stack{si}"]

            if mode == "train":
                def body(h, unit_p, _sd=sd):
                    h2, _ = apply_unit(
                        unit_p, h, _sd.spec, self.cfg, "train", positions,
                        enc_out=enc_out, causal=causal,
                    )
                    return h2, None

                if remat:
                    body = jax.checkpoint(
                        body,
                        policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable,
                    )
                x, _ = jax.lax.scan(body, x, stack_p)
                new_caches.append(None)
            elif mode == "prefill":
                def body_p(h, unit_p, _sd=sd):
                    h2, nc = apply_unit(
                        unit_p, h, _sd.spec, self.cfg, "prefill", positions,
                        cache_len=cache_len, enc_out=enc_out,
                    )
                    return h2, nc

                x, ncs = jax.lax.scan(body_p, x, stack_p)
                new_caches.append(ncs)
            else:  # decode
                def body_d(h, xs, _sd=sd):
                    unit_p, unit_c = xs
                    h2, nc = apply_unit(
                        unit_p, h, _sd.spec, self.cfg, "decode", None,
                        cache=unit_c, pos=pos, enc_out=enc_out,
                    )
                    return h2, nc

                x, ncs = jax.lax.scan(body_d, x, (stack_p, caches[si]))
                new_caches.append(ncs)
        return x, new_caches

    def _encode(self, params, enc_frames, remat=True):
        """Whisper encoder over stubbed conv-frontend frames (B, Se, d)."""
        cfg = self.cfg
        Se = enc_frames.shape[1]
        pos = jnp.arange(Se)
        half = cfg.d_model // 2
        freqs = jnp.exp(
            -jnp.arange(half, dtype=jnp.float32) * (9.21 / max(half - 1, 1))
        )
        ang = pos[:, None].astype(jnp.float32) * freqs[None, :]
        pe = jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], -1)
        x = enc_frames + pe[None].astype(enc_frames.dtype)
        x, _ = self._run_stacks(
            params, x, "train", pos, role="encoder", remat=remat, causal=False
        )
        return rms_norm(x, params["enc_final_norm"], cfg.rms_eps)

    def _inputs_to_x(self, params, batch):
        """Merge modality inputs -> (x, positions, enc_out)."""
        cfg = self.cfg
        tokens = batch["tokens"]
        x = self._embed(params, tokens)
        enc_out = None
        if cfg.vlm:
            x = jnp.concatenate(
                [batch["vision_embeds"].astype(x.dtype), x], axis=1
            )
        if cfg.encdec:
            enc_out = self._encode(params, batch["enc_frames"])
        S = x.shape[1]
        positions = jnp.arange(S)
        return x, positions, enc_out

    # --------------------------------------------------------------- API
    def forward_train(self, params, batch, remat: bool = True) -> jax.Array:
        """-> final hidden states (B, S, d)."""
        x, positions, enc_out = self._inputs_to_x(params, batch)
        x, _ = self._run_stacks(
            params, x, "train", positions, enc_out=enc_out, remat=remat
        )
        return rms_norm(x, params["final_norm"], self.cfg.rms_eps)

    def loss(self, params, batch, remat: bool = True) -> jax.Array:
        h = self.forward_train(params, batch, remat=remat)
        labels = batch["labels"]
        if self.cfg.vlm:  # loss only over the text positions
            h = h[:, self.cfg.n_patches :, :]
        return chunked_ce_loss(h, labels, self._head(params))

    def prefill(self, params, batch, cache_len: int):
        """-> (last-token logits (B, V), caches)."""
        x, positions, enc_out = self._inputs_to_x(params, batch)
        x, caches = self._run_stacks(
            params, x, "prefill", positions, cache_len=cache_len,
            enc_out=enc_out,
        )
        h = rms_norm(x[:, -1, :], params["final_norm"], self.cfg.rms_eps)
        return h @ self._head(params), caches

    def init_caches(
        self, batch: int, cache_len: int, dtype=jnp.float32, kv_dtype=None
    ):
        out = []
        for sd in self.stacks:
            if sd.role != "decoder":
                out.append(None)
                continue
            one = init_unit_cache(
                sd.spec, self.cfg, batch, cache_len, dtype, kv_dtype
            )
            out.append(
                jax.tree.map(
                    lambda x: jnp.broadcast_to(
                        x[None], (sd.count,) + x.shape
                    ),
                    one,
                )
            )
        return out

    def decode_step(self, params, tokens, caches, pos):
        """tokens (B, 1) -> (logits (B, V), new caches)."""
        x = self._embed(params, tokens)
        x, new_caches = self._run_stacks(
            params, x, "decode", None, caches=caches, pos=pos
        )
        h = rms_norm(x[:, -1, :], params["final_norm"], self.cfg.rms_eps)
        return h @ self._head(params), new_caches


# --------------------------------------------------------------------------
# Spec construction from ArchConfig.
# --------------------------------------------------------------------------
def build_model(cfg: ArchConfig) -> LMModel:
    attn_kind = "mla" if cfg.mla else "gqa"
    stacks: list[StackDef] = []

    if cfg.encdec:
        enc_spec: LayerSpec = (("gqa", {}), ("ffn", {}))
        dec_spec: LayerSpec = (("gqa", {}), ("cross", {}), ("ffn", {}))
        stacks.append(StackDef(cfg.n_enc_layers, enc_spec, role="encoder"))
        stacks.append(StackDef(cfg.n_layers, dec_spec, role="decoder"))
    elif cfg.hybrid_period:
        sub: list[tuple[str, dict]] = []
        for i in range(cfg.hybrid_period):
            mixer = "gqa" if i in cfg.attn_positions else "mamba"
            ff = "moe" if (cfg.moe and i % cfg.moe_period == 1) else "ffn"
            sub.append((mixer, {}))
            sub.append((ff, {}))
        stacks.append(StackDef(cfg.n_layers // cfg.hybrid_period, tuple(sub)))
    elif cfg.ssm:
        stacks.append(StackDef(cfg.n_layers, (("mamba", {}),)))
    elif cfg.moe:
        if cfg.n_dense_layers:
            dspec: LayerSpec = (
                (attn_kind, {}),
                ("ffn", {"d_ff": cfg.d_ff_dense or cfg.d_ff}),
            )
            stacks.append(StackDef(cfg.n_dense_layers, dspec))
        mspec: LayerSpec = ((attn_kind, {}), ("moe", {}))
        stacks.append(StackDef(cfg.n_layers - cfg.n_dense_layers, mspec))
    else:
        stacks.append(StackDef(cfg.n_layers, ((attn_kind, {}), ("ffn", {}))))
    return LMModel(cfg=cfg, stacks=tuple(stacks))
