"""Pallas TPU kernel for the horizontal (N-ary) distance scan — the paper's
baseline layout.  Each row tile reduces along the dimension axis, which is the
reduction the paper shows to be lane-inefficient at low D (Figure 3): on TPU
the per-row reduce crosses lanes, whereas the PDX kernel reduces across
sublanes and keeps lanes independent.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["nary_distance_pallas"]


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def _nary_kernel(q_ref, x_ref, o_ref, *, metric: str):
    i = pl.program_id(1)  # dim-tile index, innermost

    @pl.when(i == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...].astype(jnp.float32)  # (nt, dt)
    q = q_ref[...].astype(jnp.float32)  # (1, dt)
    if metric == "l2":
        d = x - q
        o_ref[...] += jnp.sum(d * d, axis=1, keepdims=True)
    elif metric == "l1":
        o_ref[...] += jnp.sum(jnp.abs(x - q), axis=1, keepdims=True)
    else:
        o_ref[...] += -jnp.sum(x * q, axis=1, keepdims=True)


@functools.partial(jax.jit, static_argnames=("metric", "n_tile", "d_tile"))
def nary_distance_pallas(
    X: jax.Array,
    q: jax.Array,
    metric: str = "l2",
    n_tile: int = 256,
    d_tile: int = 512,
) -> jax.Array:
    """(N, D), (D,) -> (N,) float32."""
    N, D = X.shape
    n_tile = min(n_tile, N)
    d_tile = min(d_tile, D)
    nn = pl.cdiv(N, n_tile)
    nd = pl.cdiv(D, d_tile)
    q2 = q.reshape(1, D)
    out = pl.pallas_call(
        functools.partial(_nary_kernel, metric=metric),
        grid=(nn, nd),
        in_specs=[
            pl.BlockSpec((1, d_tile), lambda j, i: (0, i)),
            pl.BlockSpec((n_tile, d_tile), lambda j, i: (j, i)),
        ],
        out_specs=pl.BlockSpec((n_tile, 1), lambda j, i: (j, 0)),
        out_shape=jax.ShapeDtypeStruct((N, 1), jnp.float32),
        interpret=_interpret(),
    )(q2, X)
    return out[:, 0]
