"""Pallas TPU kernel: batched-query distance via the MXU (beyond-paper).

dist(b, v) = ||q_b||^2 - 2 <q_b, x_v> + ||x_v||^2 over a PDX tile (D, V):
the tile is contraction-major, so the cross term is a straight MXU matmul
with no relayout — the TPU analogue of the paper's observation that the PDX
layout is what the compute unit natively wants.  Norm terms are fused as an
epilogue on the last K step.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["batched_distance_pallas", "batched_distance_quant_pallas"]


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def _bmm_kernel(q_ref, x_ref, qn_ref, xn_ref, o_ref, *, nd: int, metric: str):
    i = pl.program_id(2)  # K (dimension) tile, innermost

    @pl.when(i == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    q = q_ref[...].astype(jnp.float32)  # (bt, dt)
    x = x_ref[...].astype(jnp.float32)  # (dt, vt)
    cross = jax.lax.dot_general(
        q, x, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    if metric == "ip":
        o_ref[...] += -cross
    else:
        o_ref[...] += -2.0 * cross

        @pl.when(i == nd - 1)
        def _epilogue():
            o_ref[...] += qn_ref[...] + xn_ref[...]


@functools.partial(
    jax.jit, static_argnames=("metric", "b_tile", "d_tile", "v_tile")
)
def batched_distance_pallas(
    T: jax.Array,
    Q: jax.Array,
    metric: str = "l2",
    b_tile: int = 128,
    d_tile: int = 256,
    v_tile: int = 512,
) -> jax.Array:
    """(D, V), (B, D) -> (B, V) float32 distances (l2) or neg-IP."""
    D, V = T.shape
    B = Q.shape[0]
    b_tile = min(b_tile, B)
    d_tile = min(d_tile, D)
    v_tile = min(v_tile, V)
    nb, nv, nd = pl.cdiv(B, b_tile), pl.cdiv(V, v_tile), pl.cdiv(D, d_tile)
    qn = jnp.sum(
        Q.astype(jnp.float32) * Q.astype(jnp.float32), axis=1, keepdims=True
    )  # (B, 1)
    xn = jnp.sum(
        T.astype(jnp.float32) * T.astype(jnp.float32), axis=0, keepdims=True
    )  # (1, V)
    out = pl.pallas_call(
        functools.partial(_bmm_kernel, nd=nd, metric=metric),
        grid=(nb, nv, nd),
        in_specs=[
            pl.BlockSpec((b_tile, d_tile), lambda b, v, i: (b, i)),
            pl.BlockSpec((d_tile, v_tile), lambda b, v, i: (i, v)),
            pl.BlockSpec((b_tile, 1), lambda b, v, i: (b, 0)),
            pl.BlockSpec((1, v_tile), lambda b, v, i: (0, v)),
        ],
        out_specs=pl.BlockSpec((b_tile, v_tile), lambda b, v, i: (b, v)),
        out_shape=jax.ShapeDtypeStruct((B, V), jnp.float32),
        interpret=_interpret(),
    )(Q, T, qn, xn)
    return out


# --------------------------------------------------------------------------
# Quantized-operand variant: the tile streams at mirror width (bf16/int8),
# dequantizes in-register, and accumulates both the MXU cross term and the
# tile's own squared norm per K step (so no f32 norm array over the store
# needs to exist anywhere — each stored byte is touched exactly once).
# --------------------------------------------------------------------------
def _bmm_quant_kernel(
    q_ref, x_ref, qn_ref, scale_ref, offset_ref, o_ref,
    *, nd: int, metric: str, quantized: bool,
):
    i = pl.program_id(2)  # K (dimension) tile, innermost

    @pl.when(i == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...].astype(jnp.float32)  # (dt, vt)
    if quantized:
        x = x * scale_ref[...] + offset_ref[...]
    q = q_ref[...].astype(jnp.float32)  # (bt, dt)
    cross = jax.lax.dot_general(
        q, x, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    if metric == "ip":
        o_ref[...] += -cross
    else:
        xn = jnp.sum(x * x, axis=0, keepdims=True)  # (1, vt) this K tile
        o_ref[...] += -2.0 * cross + xn

        @pl.when(i == nd - 1)
        def _epilogue():
            o_ref[...] += qn_ref[...]


@functools.partial(
    jax.jit,
    static_argnames=("metric", "quantized", "b_tile", "d_tile", "v_tile"),
)
def batched_distance_quant_pallas(
    T: jax.Array,
    Q: jax.Array,
    scale: jax.Array,
    offset: jax.Array,
    metric: str = "l2",
    quantized: bool = False,
    b_tile: int = 128,
    d_tile: int = 256,
    v_tile: int = 512,
) -> jax.Array:
    """(D, V) bf16/int8 tile + (D,) dequant vectors, (B, D) f32 -> (B, V)."""
    D, V = T.shape
    B = Q.shape[0]
    b_tile = min(b_tile, B)
    d_tile = min(d_tile, D)
    v_tile = min(v_tile, V)
    nb, nv, nd = pl.cdiv(B, b_tile), pl.cdiv(V, v_tile), pl.cdiv(D, d_tile)
    Q32 = Q.astype(jnp.float32)
    qn = jnp.sum(Q32 * Q32, axis=1, keepdims=True)  # (B, 1)
    out = pl.pallas_call(
        functools.partial(
            _bmm_quant_kernel, nd=nd, metric=metric, quantized=quantized
        ),
        grid=(nb, nv, nd),
        in_specs=[
            pl.BlockSpec((b_tile, d_tile), lambda b, v, i: (b, i)),
            pl.BlockSpec((d_tile, v_tile), lambda b, v, i: (i, v)),
            pl.BlockSpec((b_tile, 1), lambda b, v, i: (b, 0)),
            pl.BlockSpec((d_tile, 1), lambda b, v, i: (i, 0)),
            pl.BlockSpec((d_tile, 1), lambda b, v, i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((b_tile, v_tile), lambda b, v, i: (b, v)),
        out_shape=jax.ShapeDtypeStruct((B, V), jnp.float32),
        interpret=_interpret(),
    )(Q32, T, qn, scale.reshape(D, 1), offset.reshape(D, 1))
    return out
