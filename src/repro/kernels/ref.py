"""Pure-jnp oracles for every Pallas kernel in this package.

Each ``*_ref`` mirrors one kernel's contract exactly (shapes, dtypes,
accumulation order up to float-reassociation).  Kernel tests sweep shapes and
dtypes and assert allclose against these.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = [
    "pdx_distance_ref",
    "nary_distance_ref",
    "batched_distance_ref",
    "pdx_prune_scan_ref",
]


def pdx_distance_ref(T: jax.Array, q: jax.Array, metric: str = "l2") -> jax.Array:
    """(D, V), (D,) -> (V,) float32 accumulation regardless of input dtype."""
    T32 = T.astype(jnp.float32)
    q32 = q.astype(jnp.float32)
    if metric == "l2":
        d = T32 - q32[:, None]
        return jnp.sum(d * d, axis=0)
    if metric == "l1":
        return jnp.sum(jnp.abs(T32 - q32[:, None]), axis=0)
    return -jnp.sum(T32 * q32[:, None], axis=0)


def nary_distance_ref(X: jax.Array, q: jax.Array, metric: str = "l2") -> jax.Array:
    """(N, D), (D,) -> (N,)."""
    X32 = X.astype(jnp.float32)
    q32 = q.astype(jnp.float32)
    if metric == "l2":
        d = X32 - q32[None, :]
        return jnp.sum(d * d, axis=1)
    if metric == "l1":
        return jnp.sum(jnp.abs(X32 - q32[None, :]), axis=1)
    return -jnp.sum(X32 * q32[None, :], axis=1)


def batched_distance_ref(T: jax.Array, Q: jax.Array, metric: str = "l2") -> jax.Array:
    """(D, V), (B, D) -> (B, V); l2 or ip (matmul family)."""
    T32 = T.astype(jnp.float32)
    Q32 = Q.astype(jnp.float32)
    cross = Q32 @ T32
    if metric == "ip":
        return -cross
    qn = jnp.sum(Q32 * Q32, axis=1, keepdims=True)
    xn = jnp.sum(T32 * T32, axis=0, keepdims=True)
    return qn - 2.0 * cross + xn


def pdx_prune_scan_ref(
    T: jax.Array,
    q: jax.Array,
    thr: jax.Array,
    *,
    d_tile: int,
    eps0: float,
) -> tuple[jax.Array, jax.Array]:
    """Oracle for the fused PDXearch-ADSampling partition kernel.

    Walks dimension tiles of size ``d_tile``; after each tile evaluates the
    ADSampling hypothesis test and freezes pruned vectors' accumulators
    (paper: once pruned, a vector's remaining dims are never visited).
    Returns (dists (V,), alive (V,) f32 mask); pruned vectors report their
    partial distance at pruning time.
    """
    D, V = T.shape
    T32 = T.astype(jnp.float32)
    q32 = q.astype(jnp.float32)
    acc = jnp.zeros((V,), jnp.float32)
    alive = jnp.ones((V,), jnp.float32)
    d_seen = 0
    while d_seen < D:
        hi = min(d_seen + d_tile, D)
        blk = T32[d_seen:hi] - q32[d_seen:hi, None]
        contrib = jnp.sum(blk * blk, axis=0)
        acc = acc + contrib * alive  # frozen lanes stay frozen
        d_seen = hi
        d = jnp.float32(d_seen)
        bound = thr * (1.0 + eps0 / jnp.sqrt(d)) ** 2
        keep = acc * (D / d) <= bound
        alive = alive * keep.astype(jnp.float32)
    return acc, alive
