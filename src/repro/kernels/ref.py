"""Pure-jnp oracles for every Pallas kernel in this package.

Each ``*_ref`` mirrors one kernel's contract exactly (shapes, dtypes,
accumulation order up to float-reassociation).  Kernel tests sweep shapes and
dtypes and assert allclose against these.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = [
    "pdx_distance_ref",
    "nary_distance_ref",
    "batched_distance_ref",
    "batched_distance_quant_ref",
    "pdx_prune_scan_ref",
    "pdx_prune_scan_multi_ref",
    "pdx_prune_scan_multi_dskip_ref",
    "dequantize_ref",
]


def dequantize_ref(
    T: jax.Array,
    scale: jax.Array | None,
    offset: jax.Array | None,
    dim_axis: int = 0,
    packed: bool = False,
    dim: int | None = None,
) -> jax.Array:
    """Mirror-dtype tile -> f32, applying the per-dimension affine
    dequantization when scale/offset are given (int8/int4 mirrors; bf16/f32
    pass None and just upcast).  ``dim_axis`` is the axis holding the D
    dimension values (0 for a (D, V) tile, 1 for (P, D, V) stacks).
    ``packed`` unpacks an int4 two-per-byte tile first (low nibble = even
    dim, +8 bias — the ``core.layout`` packing), slicing the doubled axis
    back to logical ``dim`` when given."""
    if packed:
        p = T.astype(jnp.int32)
        full = jnp.stack([(p & 0xF) - 8, (p >> 4) - 8], axis=dim_axis + 1)
        shape = list(T.shape)
        shape[dim_axis] *= 2
        T = full.reshape(shape)
        if dim is not None and dim != shape[dim_axis]:
            T = jax.lax.slice_in_dim(T, 0, dim, axis=dim_axis)
    T32 = T.astype(jnp.float32)
    if scale is None:
        return T32
    shape = [1] * T32.ndim
    shape[dim_axis] = -1
    return T32 * scale.reshape(shape) + offset.reshape(shape)


def pdx_distance_ref(T: jax.Array, q: jax.Array, metric: str = "l2") -> jax.Array:
    """(D, V), (D,) -> (V,) float32 accumulation regardless of input dtype."""
    T32 = T.astype(jnp.float32)
    q32 = q.astype(jnp.float32)
    if metric == "l2":
        d = T32 - q32[:, None]
        return jnp.sum(d * d, axis=0)
    if metric == "l1":
        return jnp.sum(jnp.abs(T32 - q32[:, None]), axis=0)
    return -jnp.sum(T32 * q32[:, None], axis=0)


def nary_distance_ref(X: jax.Array, q: jax.Array, metric: str = "l2") -> jax.Array:
    """(N, D), (D,) -> (N,)."""
    X32 = X.astype(jnp.float32)
    q32 = q.astype(jnp.float32)
    if metric == "l2":
        d = X32 - q32[None, :]
        return jnp.sum(d * d, axis=1)
    if metric == "l1":
        return jnp.sum(jnp.abs(X32 - q32[None, :]), axis=1)
    return -jnp.sum(X32 * q32[None, :], axis=1)


def batched_distance_ref(T: jax.Array, Q: jax.Array, metric: str = "l2") -> jax.Array:
    """(D, V), (B, D) -> (B, V); l2 or ip (matmul family)."""
    T32 = T.astype(jnp.float32)
    Q32 = Q.astype(jnp.float32)
    cross = Q32 @ T32
    if metric == "ip":
        return -cross
    qn = jnp.sum(Q32 * Q32, axis=1, keepdims=True)
    xn = jnp.sum(T32 * T32, axis=0, keepdims=True)
    return qn - 2.0 * cross + xn


def batched_distance_quant_ref(
    T: jax.Array,
    Q: jax.Array,
    scale: jax.Array | None = None,
    offset: jax.Array | None = None,
    metric: str = "l2",
) -> jax.Array:
    """Oracle for the quantized batched kernel: dequantize, then the exact
    ``batched_distance_ref`` arithmetic."""
    return batched_distance_ref(dequantize_ref(T, scale, offset), Q, metric)


def pdx_prune_scan_ref(
    T: jax.Array,
    q: jax.Array,
    thr: jax.Array,
    *,
    d_tile: int,
    eps0: float,
) -> tuple[jax.Array, jax.Array]:
    """Oracle for the fused PDXearch-ADSampling partition kernel.

    Walks dimension tiles of size ``d_tile``; after each tile evaluates the
    ADSampling hypothesis test and freezes pruned vectors' accumulators
    (paper: once pruned, a vector's remaining dims are never visited).
    Returns (dists (V,), alive (V,) f32 mask); pruned vectors report their
    partial distance at pruning time.
    """
    D, V = T.shape
    T32 = T.astype(jnp.float32)
    q32 = q.astype(jnp.float32)
    acc = jnp.zeros((V,), jnp.float32)
    alive = jnp.ones((V,), jnp.float32)
    d_seen = 0
    while d_seen < D:
        hi = min(d_seen + d_tile, D)
        blk = T32[d_seen:hi] - q32[d_seen:hi, None]
        contrib = jnp.sum(blk * blk, axis=0)
        acc = acc + contrib * alive  # frozen lanes stay frozen
        d_seen = hi
        d = jnp.float32(d_seen)
        bound = thr * (1.0 + eps0 / jnp.sqrt(d)) ** 2
        keep = acc * (D / d) <= bound
        alive = alive * keep.astype(jnp.float32)
    return acc, alive


def pdx_prune_scan_multi_ref(
    T: jax.Array,
    ids: jax.Array,
    q: jax.Array,
    thr: jax.Array,
    *,
    d_tile: int,
    eps0: float,
    scale: jax.Array | None = None,
    offset: jax.Array | None = None,
    packed: bool = False,
    dim: int | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Oracle for the multi-partition megakernel.

    (P, D, V) mirror-dtype tiles, (P, V) ids -> (dists (P, V), alive (P, V)
    f32 mask).  Matches the kernel's contract: lanes with ``ids < 0`` start
    dead (and accumulate nothing), operands dequantize before the L2
    accumulation, the hypothesis test runs once per d-tile.  ``packed``
    takes an int4 mirror, (P, ceil(dim/2), V) uint8 with logical ``dim``.
    """
    T32 = dequantize_ref(T, scale, offset, dim_axis=1, packed=packed, dim=dim)
    P, D, V = T32.shape
    q32 = q.astype(jnp.float32)
    acc = jnp.zeros((P, V), jnp.float32)
    alive = (ids >= 0).astype(jnp.float32)
    d_seen = 0
    while d_seen < D:
        hi = min(d_seen + d_tile, D)
        blk = T32[:, d_seen:hi, :] - q32[None, d_seen:hi, None]
        contrib = jnp.sum(blk * blk, axis=1)
        acc = acc + contrib * alive
        d_seen = hi
        d = jnp.float32(d_seen)
        bound = thr * (1.0 + eps0 / jnp.sqrt(d)) ** 2
        keep = acc * (D / d) <= bound
        alive = alive * keep.astype(jnp.float32)
    return acc, alive


def pdx_prune_scan_multi_dskip_ref(
    T: jax.Array,
    ids: jax.Array,
    q: jax.Array,
    thr: jax.Array,
    *,
    d_tile: int,
    eps0: float,
    scale: jax.Array | None = None,
    offset: jax.Array | None = None,
    packed: bool = False,
    dim: int | None = None,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Oracle for the d-tile-granular prefetch-skip megakernel: identical
    dists/alive to ``pdx_prune_scan_multi_ref``, plus a per-partition
    ``streamed`` (P,) count of d-tiles the skipping kernel would actually
    fetch — a tile is streamed iff any of the partition's lanes is alive
    when the tile is reached (the hardware path's conditional DMA)."""
    T32 = dequantize_ref(T, scale, offset, dim_axis=1, packed=packed, dim=dim)
    P, D, V = T32.shape
    q32 = q.astype(jnp.float32)
    acc = jnp.zeros((P, V), jnp.float32)
    alive = (ids >= 0).astype(jnp.float32)
    streamed = jnp.zeros((P,), jnp.float32)
    d_seen = 0
    while d_seen < D:
        hi = min(d_seen + d_tile, D)
        streamed = streamed + jnp.any(alive > 0, axis=1).astype(jnp.float32)
        blk = T32[:, d_seen:hi, :] - q32[None, d_seen:hi, None]
        contrib = jnp.sum(blk * blk, axis=1)
        acc = acc + contrib * alive
        d_seen = hi
        d = jnp.float32(d_seen)
        bound = thr * (1.0 + eps0 / jnp.sqrt(d)) ** 2
        keep = acc * (D / d) <= bound
        alive = alive * keep.astype(jnp.float32)
    return acc, alive, streamed
