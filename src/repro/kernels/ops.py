"""Jitted public wrappers for the Pallas kernels: shape padding, dtype policy,
tile-size selection.  Callers use these; the raw kernels stay minimal.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .batched_matmul import batched_distance_pallas
from .nary_scan import nary_distance_pallas
from .pdx_scan import pdx_distance_pallas, pdx_prune_scan_pallas

__all__ = [
    "pdx_distance_op",
    "nary_distance_op",
    "batched_distance_op",
    "pdx_prune_scan_op",
]


def _pad_to(x: jax.Array, axis: int, mult: int) -> jax.Array:
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def _pick(size: int, pref: int, align: int) -> int:
    """Largest aligned tile <= pref covering `size` if small."""
    if size <= pref:
        return max(((size + align - 1) // align) * align, align)
    return pref


@functools.partial(jax.jit, static_argnames=("metric",))
def pdx_distance_op(T: jax.Array, q: jax.Array, metric: str = "l2") -> jax.Array:
    """(D, V), (D,) -> (V,); handles non-aligned shapes by zero-padding
    (zero dims contribute 0 to every metric)."""
    D, V = T.shape
    dt = _pick(D, 256, 8)
    vt = _pick(V, 1024, 128)
    Tp = _pad_to(_pad_to(T, 0, dt), 1, vt)
    qp = _pad_to(q, 0, dt)
    return pdx_distance_pallas(Tp, qp, metric, dt, vt)[:V]


@functools.partial(jax.jit, static_argnames=("metric",))
def nary_distance_op(X: jax.Array, q: jax.Array, metric: str = "l2") -> jax.Array:
    N, D = X.shape
    nt = _pick(N, 256, 8)
    dt = _pick(D, 512, 128)
    Xp = _pad_to(_pad_to(X, 0, nt), 1, dt)
    qp = _pad_to(q, 0, dt)
    return nary_distance_pallas(Xp, qp, metric, nt, dt)[:N]


@functools.partial(jax.jit, static_argnames=("metric",))
def batched_distance_op(T: jax.Array, Q: jax.Array, metric: str = "l2") -> jax.Array:
    D, V = T.shape
    B = Q.shape[0]
    bt = _pick(B, 128, 8)
    dt = _pick(D, 256, 128)
    vt = _pick(V, 512, 128)
    Tp = _pad_to(_pad_to(T, 0, dt), 1, vt)
    Qp = _pad_to(_pad_to(Q, 1, dt), 0, bt)
    return batched_distance_pallas(Tp, Qp, metric, bt, dt, vt)[:B, :V]


@functools.partial(jax.jit, static_argnames=("eps0", "d_tile"))
def pdx_prune_scan_op(
    T: jax.Array, q: jax.Array, thr: jax.Array, eps0: float = 2.1, d_tile: int = 64
) -> tuple[jax.Array, jax.Array]:
    """Fused PDXearch/ADSampling partition scan.  Zero-pads both axes; the
    hypothesis test keeps counting in logical (un-padded) dimensions."""
    D, V = T.shape
    vt = _pick(V, 1024, 128)
    dt = min(d_tile, D)
    Tp = _pad_to(_pad_to(T, 0, dt), 1, vt)
    qp = _pad_to(q, 0, dt)
    dists, alive = pdx_prune_scan_pallas(Tp, qp, thr, eps0, dt, vt, logical_dim=D)
    return dists[:V], alive[:V]
