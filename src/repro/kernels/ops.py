"""Jitted public wrappers for the Pallas kernels: shape padding, dtype policy,
tile-size selection.  Callers use these; the raw kernels stay minimal.

Every executor-facing op takes a static ``use_pallas`` knob: True runs the
Pallas kernel (interpret mode off-TPU — the correctness gate), False runs
the pure-jnp oracle body from ``ref`` under the same contract (the XLA
fallback the planner picks via ``SearchSpec.kernel="jnp"``).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import ref
from .batched_matmul import (
    batched_distance_pallas,
    batched_distance_quant_pallas,
)
from .nary_scan import nary_distance_pallas
from .pdx_scan import (
    pdx_distance_pallas,
    pdx_prune_scan_multi_pallas,
    pdx_prune_scan_multi_prefetch_pallas,
    pdx_prune_scan_pallas,
)

__all__ = [
    "pdx_distance_op",
    "nary_distance_op",
    "batched_distance_op",
    "batched_distance_quant_op",
    "pdx_prune_scan_op",
    "pdx_prune_scan_multi_op",
    "pdx_prune_scan_multi_prefetch_op",
    "batched_cascade_stage_op",
]

# Padding a packed int4 tile must stay harmless after in-kernel unpacking:
# 0x88 decodes to the (0, 0) level pair, which dequantizes to 0 under the
# zero-padded scale/offset — exactly like the 0 padding of unpacked tiles.
_INT4_PAD_BYTE = 0x88


def _unpack_int4_levels(T: jax.Array, dim: int) -> jax.Array:
    """(Dp, ...) packed bytes -> (dim, ...) int8 quantization levels."""
    p = T.astype(jnp.int32)
    full = jnp.stack([(p & 0xF) - 8, (p >> 4) - 8], axis=1)
    return full.reshape((2 * T.shape[0],) + T.shape[1:])[:dim].astype(jnp.int8)


def _pad_to(
    x: jax.Array, axis: int, mult: int, value: float | int = 0
) -> jax.Array:
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value)


def _pick(size: int, pref: int, align: int) -> int:
    """Largest aligned tile <= pref covering `size` if small."""
    if size <= pref:
        return max(((size + align - 1) // align) * align, align)
    return pref


@functools.partial(jax.jit, static_argnames=("metric",))
def pdx_distance_op(T: jax.Array, q: jax.Array, metric: str = "l2") -> jax.Array:
    """(D, V), (D,) -> (V,); handles non-aligned shapes by zero-padding
    (zero dims contribute 0 to every metric)."""
    D, V = T.shape
    dt = _pick(D, 256, 8)
    vt = _pick(V, 1024, 128)
    Tp = _pad_to(_pad_to(T, 0, dt), 1, vt)
    qp = _pad_to(q, 0, dt)
    return pdx_distance_pallas(Tp, qp, metric, dt, vt)[:V]


@functools.partial(jax.jit, static_argnames=("metric",))
def nary_distance_op(X: jax.Array, q: jax.Array, metric: str = "l2") -> jax.Array:
    N, D = X.shape
    nt = _pick(N, 256, 8)
    dt = _pick(D, 512, 128)
    Xp = _pad_to(_pad_to(X, 0, nt), 1, dt)
    qp = _pad_to(q, 0, dt)
    return nary_distance_pallas(Xp, qp, metric, nt, dt)[:N]


@functools.partial(jax.jit, static_argnames=("metric",))
def batched_distance_op(T: jax.Array, Q: jax.Array, metric: str = "l2") -> jax.Array:
    D, V = T.shape
    B = Q.shape[0]
    bt = _pick(B, 128, 8)
    dt = _pick(D, 256, 128)
    vt = _pick(V, 512, 128)
    Tp = _pad_to(_pad_to(T, 0, dt), 1, vt)
    Qp = _pad_to(_pad_to(Q, 1, dt), 0, bt)
    return batched_distance_pallas(Tp, Qp, metric, bt, dt, vt)[:B, :V]


@functools.partial(jax.jit, static_argnames=("eps0", "d_tile"))
def pdx_prune_scan_op(
    T: jax.Array,
    q: jax.Array,
    thr: jax.Array,
    ids: jax.Array | None = None,
    eps0: float = 2.1,
    d_tile: int = 64,
) -> tuple[jax.Array, jax.Array]:
    """Fused PDXearch/ADSampling partition scan -> (dists f32, alive bool).

    Zero-pads both axes; the hypothesis test keeps counting in logical
    (un-padded) dimensions.  ``ids`` is the partition's (V,) id row: lanes
    with ``ids < 0`` (PAD columns) start dead and can never surface as
    candidates.  Padded lanes introduced here are masked the same way.
    """
    D, V = T.shape
    vt = _pick(V, 1024, 128)
    dt = min(d_tile, D)
    Tp = _pad_to(_pad_to(T, 0, dt), 1, vt)
    qp = _pad_to(q, 0, dt)
    if ids is None:
        ids = jnp.zeros((V,), jnp.int32)  # all lanes real
    idp = _pad_to(ids, 0, vt, value=-1)
    dists, alive = pdx_prune_scan_pallas(
        Tp, qp, thr, idp, eps0, dt, vt, logical_dim=D
    )
    return dists[:V], alive[:V] != 0.0


def _prep_multi(T, ids, q, scale, offset, d_tile, packed, dim):
    """Shared padding/tiling for the megakernel wrappers.

    Returns (Tp, idp, qp, sp, op, dt, logical_dim, quantized).  For packed
    int4 mirrors the byte axis pads with ``_INT4_PAD_BYTE`` to ``dt/2`` and
    q/scale/offset pad out to the padded *logical* (even) dimension count.
    """
    if packed:
        P, Dp, V = T.shape
        dt = min(d_tile, 2 * Dp)
        dt += dt % 2  # packed bytes hold dim pairs; 2*Dp is even, so safe
        Tp = _pad_to(_pad_to(T, 1, dt // 2, value=_INT4_PAD_BYTE), 2, _pick(V, 1024, 128))
        Dlog = 2 * Tp.shape[1]
        qp = jnp.pad(q, (0, Dlog - dim))
        sp = jnp.pad(scale, (0, Dlog - dim))
        op = jnp.pad(offset, (0, Dlog - dim))
        idp = _pad_to(ids, 1, Tp.shape[2], value=-1)
        return Tp, idp, qp, sp, op, dt, dim, True
    P, D, V = T.shape
    quantized = scale is not None
    vt = _pick(V, 1024, 128)
    dt = min(d_tile, D)
    Tp = _pad_to(_pad_to(T, 1, dt), 2, vt)
    qp = _pad_to(q, 0, dt)
    idp = _pad_to(ids, 1, vt, value=-1)
    if quantized:
        sp = _pad_to(scale, 0, dt)
        op = _pad_to(offset, 0, dt)
    else:
        sp = jnp.ones((Tp.shape[1],), jnp.float32)
        op = jnp.zeros((Tp.shape[1],), jnp.float32)
    return Tp, idp, qp, sp, op, dt, D, quantized


@functools.partial(
    jax.jit, static_argnames=("eps0", "d_tile", "use_pallas", "packed", "dim")
)
def pdx_prune_scan_multi_op(
    T: jax.Array,
    ids: jax.Array,
    q: jax.Array,
    thr: jax.Array,
    scale: jax.Array | None = None,
    offset: jax.Array | None = None,
    eps0: float = 2.1,
    d_tile: int = 64,
    use_pallas: bool = True,
    packed: bool = False,
    dim: int | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Megakernel wrapper: whole-store fused scan -> ((P, V) dists f32,
    (P, V) alive bool).

    ``T`` is a device mirror at any scan dtype (f32/bf16/int8/int4);
    ``scale``/``offset`` are the (D,) dequant vectors for quantized mirrors
    (None means the operands are plain floats).  ``packed`` marks an int4
    mirror, (P, ceil(dim/2), V) uint8 with logical dimensionality ``dim``
    (q/scale/offset stay length-``dim``).  PAD lanes (``ids < 0``) start
    dead.
    """
    if not use_pallas:
        D = dim if packed else T.shape[1]
        dists, alive = ref.pdx_prune_scan_multi_ref(
            T, ids, q, thr, d_tile=min(d_tile, D), eps0=eps0,
            scale=scale, offset=offset, packed=packed, dim=dim,
        )
        return dists, alive != 0.0
    V = T.shape[2]
    Tp, idp, qp, sp, op, dt, Dlog, quantized = _prep_multi(
        T, ids, q, scale, offset, d_tile, packed, dim
    )
    dists, alive = pdx_prune_scan_multi_pallas(
        Tp, idp, qp, thr, sp, op, eps0, dt,
        logical_dim=Dlog, quantized=quantized, packed=packed,
    )
    return dists[:, :V], alive[:, :V] != 0.0


@functools.partial(
    jax.jit, static_argnames=("eps0", "d_tile", "use_pallas", "packed", "dim")
)
def pdx_prune_scan_multi_prefetch_op(
    T: jax.Array,
    ids: jax.Array,
    q: jax.Array,
    thr: jax.Array,
    scale: jax.Array | None = None,
    offset: jax.Array | None = None,
    eps0: float = 2.1,
    d_tile: int = 64,
    use_pallas: bool = True,
    packed: bool = False,
    dim: int | None = None,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Prefetch-skip megakernel wrapper for the later cascade stages ->
    ``(dists (P, V) f32, alive (P, V) bool, streamed (P,) f32)``.

    Builds the *(partition, d-tile)* pair schedule from ``ids`` itself:
    partitions with any live lane (``ids >= 0``) are listed first,
    partition-major over their d-tiles; tail slots carry partition -1 and
    fetch nothing.  On the Pallas path an entry-dead partition's tiles are
    never DMA'd AND a partition whose last lane dies at d-tile t stops
    fetching at t (see ``pdx_prune_scan_multi_prefetch_pallas``); slot-
    ordered outputs scatter back to partition order (dead partitions report
    dist 0 / alive False / streamed 0).  ``streamed`` counts the d-tiles
    each partition actually fetched — the realized-traffic meter.  The jnp
    twin (``use_pallas=False``) computes identical dists/alive and the same
    streamed model, with no actual traffic skip.
    """
    if not use_pallas:
        D = dim if packed else T.shape[1]
        dists, alive, streamed = ref.pdx_prune_scan_multi_dskip_ref(
            T, ids, q, thr, d_tile=min(d_tile, D), eps0=eps0,
            scale=scale, offset=offset, packed=packed, dim=dim,
        )
        return dists, alive != 0.0, streamed
    P, _, V = T.shape
    Tp, idp, qp, sp, op, dt, Dlog, quantized = _prep_multi(
        T, ids, q, scale, offset, d_tile, packed, dim
    )
    nd = -(-(2 * Tp.shape[1] if packed else Tp.shape[1]) // dt)
    part_alive = jnp.any(idp >= 0, axis=1)
    n_alive = jnp.sum(part_alive)
    perm = jnp.argsort(~part_alive).astype(jnp.int32)  # stable: alive first
    slot_real = jnp.arange(P) < n_alive                # (P,)
    sched_p = jnp.where(slot_real, perm, -1)
    order_p = jnp.repeat(sched_p, nd)                  # (P*nd,) pair schedule
    order_t = jnp.tile(jnp.arange(nd, dtype=jnp.int32), P)
    out_d, out_a, out_s = pdx_prune_scan_multi_prefetch_pallas(
        Tp, idp, qp, thr, sp, op, order_p, order_t, eps0, dt,
        logical_dim=Dlog, quantized=quantized, packed=packed,
    )
    # slot -> partition scatter through the (duplicate-free) permutation;
    # tail slots write zeros into the partitions the schedule skipped
    m = slot_real[:, None]
    dists = jnp.zeros_like(out_d).at[perm].set(jnp.where(m, out_d, 0.0))
    alive = jnp.zeros_like(out_a).at[perm].set(jnp.where(m, out_a, 0.0))
    streamed = jnp.zeros((P,), jnp.float32).at[perm].set(
        jnp.where(slot_real, out_s[:, 0], 0.0)
    )
    return dists[:, :V], alive[:, :V] != 0.0, streamed


@functools.partial(
    jax.jit, static_argnames=("metric", "use_pallas", "packed", "dim")
)
def batched_distance_quant_op(
    T: jax.Array,
    Q: jax.Array,
    scale: jax.Array | None = None,
    offset: jax.Array | None = None,
    metric: str = "l2",
    use_pallas: bool = True,
    packed: bool = False,
    dim: int | None = None,
) -> jax.Array:
    """Quantized-operand MXU batch scan: (D, V) mirror tile + (B, D) f32
    queries -> (B, V) f32 distances, dequantizing in-register.  ``packed``
    takes an int4 tile ((ceil(dim/2), V) uint8): the nibbles unpack to int8
    levels outside the kernel (XLA fuses the unpack into the feed) and the
    existing quantized MXU path runs unchanged."""
    if packed:
        T = _unpack_int4_levels(T, dim)
    if not use_pallas:
        return ref.batched_distance_quant_ref(T, Q, scale, offset, metric)
    D, V = T.shape
    B = Q.shape[0]
    quantized = scale is not None
    bt = _pick(B, 128, 8)
    dt = _pick(D, 256, 128)
    vt = _pick(V, 512, 128)
    Tp = _pad_to(_pad_to(T, 0, dt), 1, vt)
    Qp = _pad_to(_pad_to(Q, 1, dt), 0, bt)
    if quantized:
        sp = _pad_to(scale, 0, dt)
        op = _pad_to(offset, 0, dt)
    else:
        sp = jnp.ones((Tp.shape[0],), jnp.float32)
        op = jnp.zeros((Tp.shape[0],), jnp.float32)
    out = batched_distance_quant_pallas(
        Tp, Qp, sp, op, metric, quantized, bt, dt, vt
    )
    return out[:B, :V]


@functools.partial(
    jax.jit, static_argnames=("eps0", "d_tile", "use_pallas", "packed", "dim")
)
def batched_cascade_stage_op(
    T: jax.Array,
    alive: jax.Array,
    Q: jax.Array,
    thr: jax.Array,
    scale: jax.Array | None = None,
    offset: jax.Array | None = None,
    eps0: float = 2.1,
    d_tile: int = 64,
    use_pallas: bool = True,
    packed: bool = False,
    dim: int | None = None,
) -> tuple[jax.Array, jax.Array]:
    """MXU-batched cascade stage ladder: (Dp, S) compacted survivor columns
    + (B, D) stage queries -> ((B, S) dists f32, (B, S) alive bool).

    Each d-tile runs through the batched quantized MXU kernel
    (``batched_distance_quant_op``) over the whole query batch at once,
    accumulating per-(query, slot) partial distances with frozen
    accumulators for dead slots; between tiles the ADSampling hypothesis
    test fires exactly as the per-query megakernel's does —
    ``acc * (D / d_seen) <= thr * (1 + eps0 / sqrt(d_seen))**2`` with
    per-query thresholds.  ``alive`` carries the cross-stage survivor
    bitmap in: slots dead on entry accumulate nothing and never revive.
    ``packed`` int4 columns unpack to int8 levels once up front; per-tile
    scale/offset slices ride into the kernel's in-register dequant."""
    if packed:
        T = _unpack_int4_levels(T, dim)
    D = T.shape[0]
    quantized = scale is not None
    a = alive.astype(jnp.float32)
    acc = jnp.zeros((Q.shape[0], T.shape[1]), jnp.float32)
    d_seen = 0
    while d_seen < D:
        hi = min(d_seen + d_tile, D)
        sc = scale[d_seen:hi] if quantized else None
        off = offset[d_seen:hi] if quantized else None
        contrib = batched_distance_quant_op(
            T[d_seen:hi], Q[:, d_seen:hi], sc, off, metric="l2",
            use_pallas=use_pallas,
        )
        acc = acc + contrib * a
        d_seen = hi
        d = jnp.float32(d_seen)
        bound = thr[:, None] * (1.0 + eps0 / jnp.sqrt(d)) ** 2
        keep = acc * (D / d) <= bound
        a = a * keep.astype(jnp.float32)
    return acc, a != 0.0
