"""Pallas TPU kernels for PDX dimension-major distance scans.

TPU adaptation of the paper's Algorithm 1: the partition tile ``(D, V)`` puts
vectors on the 128-wide lane axis and dimensions on sublanes, so the running
``distances`` array is one (or a few) vector registers / a VMEM accumulator —
exactly the paper's "distances array fits into the available SIMD registers",
scaled to TPU widths.  There is no horizontal reduction and no dependency
between lanes (paper Figure 3).

Kernels:
  * ``pdx_distance_pallas``  — plain distance scan (L2/L1/IP).
  * ``pdx_prune_scan_pallas`` — fused PDXearch step: distance accumulation +
    ADSampling hypothesis test per dimension tile, with whole-tile compute
    skip once every lane is pruned (the PRUNE phase at tile granularity —
    VPU work is skipped; the HBM→VMEM fetch of later tiles is the remaining
    cost, hoistable with manual DMA; design notes live in the
    ``repro.kernels`` package docstring).
  * ``pdx_prune_scan_multi_pallas`` — the *megakernel*: one grid over
    (partition, d-tile) covering the whole store, quantized (bf16/int8)
    operands dequantized in-register into an f32 VMEM accumulator, the
    keep-mask seeded from ``ids >= 0`` so PAD lanes can never surface.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = [
    "pdx_distance_pallas",
    "pdx_prune_scan_pallas",
    "pdx_prune_scan_multi_pallas",
    "pdx_prune_scan_multi_prefetch_pallas",
]


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


# --------------------------------------------------------------------------
# Plain PDX distance scan.
# --------------------------------------------------------------------------
def _pdx_dist_kernel(q_ref, x_ref, o_ref, *, metric: str):
    i = pl.program_id(1)  # dimension-tile index (innermost => accumulation)

    @pl.when(i == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...].astype(jnp.float32)  # (dt, vt)
    q = q_ref[...].astype(jnp.float32)  # (dt, 1)
    if metric == "l2":
        d = x - q
        o_ref[...] += jnp.sum(d * d, axis=0, keepdims=True)
    elif metric == "l1":
        o_ref[...] += jnp.sum(jnp.abs(x - q), axis=0, keepdims=True)
    else:  # ip (negated)
        o_ref[...] += -jnp.sum(x * q, axis=0, keepdims=True)


@functools.partial(jax.jit, static_argnames=("metric", "d_tile", "v_tile"))
def pdx_distance_pallas(
    T: jax.Array,
    q: jax.Array,
    metric: str = "l2",
    d_tile: int = 256,
    v_tile: int = 1024,
) -> jax.Array:
    """(D, V), (D,) -> (V,) float32. Inputs f32 or bf16."""
    D, V = T.shape
    d_tile = min(d_tile, D)
    v_tile = min(v_tile, V)
    nd = pl.cdiv(D, d_tile)
    nv = pl.cdiv(V, v_tile)
    q2 = q.reshape(D, 1)
    grid = (nv, nd)  # d innermost: each out block accumulates over all d-tiles
    out = pl.pallas_call(
        functools.partial(_pdx_dist_kernel, metric=metric),
        grid=grid,
        in_specs=[
            pl.BlockSpec((d_tile, 1), lambda j, i: (i, 0)),
            pl.BlockSpec((d_tile, v_tile), lambda j, i: (i, j)),
        ],
        out_specs=pl.BlockSpec((1, v_tile), lambda j, i: (0, j)),
        out_shape=jax.ShapeDtypeStruct((1, V), jnp.float32),
        interpret=_interpret(),
    )(q2, T)
    return out[0]


# --------------------------------------------------------------------------
# Fused PDXearch + ADSampling partition scan.
# --------------------------------------------------------------------------
def _prune_scan_kernel(
    q_ref, x_ref, ids_ref, thr_ref, o_ref, alive_ref,
    *, dim: int, d_tile: int, eps0: float,
):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)
        # PAD lanes (ids < 0) start dead: they can never surface as survivors
        alive_ref[...] = (ids_ref[...] >= 0).astype(alive_ref.dtype)

    alive = alive_ref[...]
    any_alive = jnp.sum(alive) > 0.0

    # PRUNE at tile granularity: once every lane in this partition is pruned
    # the remaining dimension tiles contribute no VPU work at all.
    @pl.when(any_alive)
    def _compute():
        x = x_ref[...].astype(jnp.float32)
        q = q_ref[...].astype(jnp.float32)
        d = x - q
        contrib = jnp.sum(d * d, axis=0, keepdims=True)
        acc = o_ref[...] + contrib * alive_ref[...]
        o_ref[...] = acc
        # ADSampling hypothesis test at d = (i+1)*d_tile dims seen (clipped).
        d_seen = jnp.minimum((i + 1) * d_tile, dim).astype(jnp.float32)
        bound = thr_ref[0, 0] * (1.0 + eps0 / jnp.sqrt(d_seen)) ** 2
        keep = (acc * (dim / d_seen) <= bound).astype(jnp.float32)
        alive_ref[...] = alive_ref[...] * keep


@functools.partial(
    jax.jit, static_argnames=("eps0", "d_tile", "v_tile", "logical_dim")
)
def pdx_prune_scan_pallas(
    T: jax.Array,
    q: jax.Array,
    thr: jax.Array,
    ids: jax.Array,
    eps0: float = 2.1,
    d_tile: int = 64,
    v_tile: int = 1024,
    logical_dim: int | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Fused distance+prune over one partition.

    (D, V), (D,), scalar-thr, (V,)-ids -> (dists (V,) f32, alive (V,) f32
    mask).  L2 metric (ADSampling's domain).  Lanes whose ``ids`` entry is
    negative (PAD columns) start dead.  ``logical_dim`` is the un-padded D
    used by the hypothesis test's dims-seen counter (padded dims contribute
    zero distance but must not inflate the estimator's sample count).
    """
    D, V = T.shape
    d_tile = min(d_tile, D)
    v_tile = min(v_tile, V)
    nd = pl.cdiv(D, d_tile)
    dim_for_test = logical_dim if logical_dim is not None else D
    q2 = q.reshape(D, 1)
    ids2 = ids.reshape(1, V)
    thr2 = jnp.asarray(thr, jnp.float32).reshape(1, 1)
    grid = (nd,)
    dists, alive = pl.pallas_call(
        functools.partial(
            _prune_scan_kernel, dim=dim_for_test, d_tile=d_tile, eps0=eps0
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((d_tile, 1), lambda i: (i, 0)),
            pl.BlockSpec((d_tile, V), lambda i: (i, 0)),
            pl.BlockSpec((1, V), lambda i: (0, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, V), lambda i: (0, 0)),
            pl.BlockSpec((1, V), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((1, V), jnp.float32),
            jax.ShapeDtypeStruct((1, V), jnp.float32),
        ],
        interpret=_interpret(),
    )(q2, T, ids2, thr2)
    return dists[0], alive[0]


# --------------------------------------------------------------------------
# Multi-partition megakernel: the whole store in ONE grid, quantized
# operands dequantized in-register.
# --------------------------------------------------------------------------
def _prune_scan_multi_kernel(
    q_ref, x_ref, ids_ref, thr_ref, scale_ref, offset_ref, o_ref, alive_ref,
    *, dim: int, d_tile: int, eps0: float, quantized: bool, packed: bool,
):
    i = pl.program_id(1)  # d-tile index (innermost => accumulation)

    @pl.when(i == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)
        alive_ref[...] = (ids_ref[...] >= 0).astype(alive_ref.dtype)

    alive = alive_ref[...]
    any_alive = jnp.sum(alive) > 0.0

    # Whole-tile compute skip: a partition whose lanes are all dead pays no
    # VPU work for its remaining dimension tiles.
    @pl.when(any_alive)
    def _compute():
        if packed:
            # int4 in-register unpack: the byte block (dt/2, V) holds the
            # even dim in its low nibble, the odd dim in its high nibble,
            # +8 biased.  Interleave back to (dt, V) quantization levels.
            xi = x_ref[0].astype(jnp.int32)                  # (dt/2, V)
            lo = (xi & 0xF) - 8
            hi = (xi >> 4) - 8
            x = jnp.stack([lo, hi], axis=1).reshape(
                2 * xi.shape[0], xi.shape[1]
            ).astype(jnp.float32)
        else:
            x = x_ref[0].astype(jnp.float32)                 # (dt, V)
        if quantized:
            # in-register dequantization: the f32 value never touches HBM
            x = x * scale_ref[...] + offset_ref[...]
        q = q_ref[...].astype(jnp.float32)                   # (dt, 1)
        d = x - q
        contrib = jnp.sum(d * d, axis=0, keepdims=True)      # (1, V)
        acc = o_ref[...] + contrib * alive_ref[...]
        o_ref[...] = acc
        d_seen = jnp.minimum((i + 1) * d_tile, dim).astype(jnp.float32)
        bound = thr_ref[0, 0] * (1.0 + eps0 / jnp.sqrt(d_seen)) ** 2
        keep = (acc * (dim / d_seen) <= bound).astype(jnp.float32)
        alive_ref[...] = alive_ref[...] * keep


@functools.partial(
    jax.jit,
    static_argnames=("eps0", "d_tile", "logical_dim", "quantized", "packed"),
)
def pdx_prune_scan_multi_pallas(
    T: jax.Array,
    ids: jax.Array,
    q: jax.Array,
    thr: jax.Array,
    scale: jax.Array,
    offset: jax.Array,
    eps0: float = 2.1,
    d_tile: int = 64,
    logical_dim: int | None = None,
    quantized: bool = False,
    packed: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Fused distance+prune over EVERY partition of a store in one kernel.

    (P, D, V) tiles (f32/bf16/int8), (P, V) ids, (D,) f32 query, scalar
    threshold, (D,) scale/offset dequant vectors -> (dists (P, V) f32,
    alive (P, V) f32 mask).  Grid is (partition, d-tile); the running
    distances and keep-mask for one partition live in VMEM across its
    d-tiles, so each stored byte is touched exactly once, at mirror width.

    ``packed`` takes an int4 mirror: (P, D/2, V) uint8 bytes unpacked
    in-register (q/scale/offset stay at the logical, even, D; ``d_tile``
    must be even).
    """
    P, Din, V = T.shape
    D = 2 * Din if packed else Din  # logical (padded) dimension count
    d_tile = min(d_tile, D)
    if packed and d_tile % 2:
        raise ValueError(f"packed scan needs an even d_tile, got {d_tile}")
    nd = pl.cdiv(D, d_tile)
    dim_for_test = logical_dim if logical_dim is not None else D
    q2 = q.reshape(D, 1)
    thr2 = jnp.asarray(thr, jnp.float32).reshape(1, 1)
    scale2 = scale.reshape(D, 1)
    offset2 = offset.reshape(D, 1)
    x_block = (1, d_tile // 2, V) if packed else (1, d_tile, V)
    grid = (P, nd)
    dists, alive = pl.pallas_call(
        functools.partial(
            _prune_scan_multi_kernel, dim=dim_for_test, d_tile=d_tile,
            eps0=eps0, quantized=quantized, packed=packed,
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((d_tile, 1), lambda p, i: (i, 0)),
            pl.BlockSpec(x_block, lambda p, i: (p, i, 0)),
            pl.BlockSpec((1, V), lambda p, i: (p, 0)),
            pl.BlockSpec((1, 1), lambda p, i: (0, 0)),
            pl.BlockSpec((d_tile, 1), lambda p, i: (i, 0)),
            pl.BlockSpec((d_tile, 1), lambda p, i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, V), lambda p, i: (p, 0)),
            pl.BlockSpec((1, V), lambda p, i: (p, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((P, V), jnp.float32),
            jax.ShapeDtypeStruct((P, V), jnp.float32),
        ],
        interpret=_interpret(),
    )(q2, T, ids, thr2, scale2, offset2)
    return dists, alive


# --------------------------------------------------------------------------
# Prefetch-skip megakernel: scalar-prefetched (partition, d-tile) pair
# schedule + in-kernel conditional DMA, so a partition's tiles stop leaving
# HBM at the d-tile where its last lane dies — not just when the previous
# cascade stage killed the whole partition.
# --------------------------------------------------------------------------
def _prune_scan_dskip_kernel(
    order_p_ref, order_t_ref, q_ref, ids_ref, thr_ref, scale_ref,
    offset_ref, x_any, o_ref, alive_ref, str_ref, tile, sem,
    *, dim: int, d_tile: int, eps0: float, quantized: bool, packed: bool,
    row_block: int,
):
    g = pl.program_id(0)
    p = order_p_ref[g]
    t = order_t_ref[g]
    real = p >= 0

    @pl.when(t == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)
        str_ref[...] = jnp.zeros_like(str_ref)
        # tail slots (p < 0) start dead wholesale; real slots seed the
        # keep-mask from the previous stage's ids (PAD/dead lanes < 0)
        alive_ref[...] = jnp.where(
            real, (ids_ref[...] >= 0).astype(alive_ref.dtype), 0.0
        )

    any_alive = jnp.sum(alive_ref[...]) > 0.0

    # The HBM->VMEM fetch itself is conditional: once every lane of this
    # partition is pruned, tiles t+1..T are never DMA'd.
    @pl.when(any_alive)
    def _fetch_and_compute():
        dma = pltpu.make_async_copy(
            x_any.at[p, pl.ds(t * row_block, row_block), :], tile, sem
        )
        dma.start()
        dma.wait()
        if packed:
            xi = tile[...].astype(jnp.int32)                 # (dt/2, V)
            lo = (xi & 0xF) - 8
            hi = (xi >> 4) - 8
            x = jnp.stack([lo, hi], axis=1).reshape(
                2 * xi.shape[0], xi.shape[1]
            ).astype(jnp.float32)
        else:
            x = tile[...].astype(jnp.float32)                # (dt, V)
        if quantized:
            x = x * scale_ref[...] + offset_ref[...]
        q = q_ref[...].astype(jnp.float32)                   # (dt, 1)
        d = x - q
        contrib = jnp.sum(d * d, axis=0, keepdims=True)      # (1, V)
        acc = o_ref[...] + contrib * alive_ref[...]
        o_ref[...] = acc
        str_ref[...] += 1.0
        d_seen = jnp.minimum((t + 1) * d_tile, dim).astype(jnp.float32)
        bound = thr_ref[0, 0] * (1.0 + eps0 / jnp.sqrt(d_seen)) ** 2
        keep = (acc * (dim / d_seen) <= bound).astype(jnp.float32)
        alive_ref[...] = alive_ref[...] * keep


@functools.partial(
    jax.jit,
    static_argnames=("eps0", "d_tile", "logical_dim", "quantized", "packed"),
)
def pdx_prune_scan_multi_prefetch_pallas(
    T: jax.Array,
    ids: jax.Array,
    q: jax.Array,
    thr: jax.Array,
    scale: jax.Array,
    offset: jax.Array,
    order_p: jax.Array,
    order_t: jax.Array,
    eps0: float = 2.1,
    d_tile: int = 64,
    logical_dim: int | None = None,
    quantized: bool = False,
    packed: bool = False,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """``pdx_prune_scan_multi_pallas`` with a ``PrefetchScalarGridSpec``-driven
    *(partition, d-tile)* pair schedule and d-tile-granular traffic skip.

    ``order_p``/``order_t`` are (P*nd,) int32 arrays enumerating the scan as
    flat pairs, partition-major: slot ``s = g // nd`` runs partition
    ``order_p[s*nd]`` (its leading entries are the partitions still alive
    after the previous cascade stage; tail slots carry ``order_p = -1`` and
    do nothing), and ``order_t[g] = g % nd`` walks that partition's d-tiles.
    The tile array lives in ANY memory space and each (p, t) tile is fetched
    with an explicit conditional DMA: an entry-dead partition fetches
    nothing (partition-granular skip, as before), and a partition whose last
    lane dies at tile t never fetches tiles t+1..T (the new d-tile-granular
    skip — previously one surviving lane streamed the whole partition).

    Returns SLOT-ordered ``(dists, alive, streamed)``; ``streamed[s, :]``
    broadcasts the number of d-tiles slot ``s`` actually fetched, which the
    caller meters as realized HBM traffic.  The caller scatters slots back
    to partition order (dead partitions report dist 0 / alive 0 /
    streamed 0).
    """
    P, Din, V = T.shape
    D = 2 * Din if packed else Din
    d_tile = min(d_tile, D)
    if packed and d_tile % 2:
        raise ValueError(f"packed scan needs an even d_tile, got {d_tile}")
    nd = pl.cdiv(D, d_tile)
    dim_for_test = logical_dim if logical_dim is not None else D
    row_block = d_tile // 2 if packed else d_tile
    q2 = q.reshape(D, 1)
    thr2 = jnp.asarray(thr, jnp.float32).reshape(1, 1)
    scale2 = scale.reshape(D, 1)
    offset2 = offset.reshape(D, 1)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(P * nd,),
        in_specs=[
            pl.BlockSpec((d_tile, 1), lambda g, op, ot: (ot[g], 0)),
            pl.BlockSpec(
                (1, V), lambda g, op, ot: (jnp.maximum(op[g], 0), 0)
            ),
            pl.BlockSpec((1, 1), lambda g, op, ot: (0, 0)),
            pl.BlockSpec((d_tile, 1), lambda g, op, ot: (ot[g], 0)),
            pl.BlockSpec((d_tile, 1), lambda g, op, ot: (ot[g], 0)),
            pl.BlockSpec(memory_space=pl.ANY),  # tiles: manual DMA only
        ],
        out_specs=[
            pl.BlockSpec((1, V), lambda g, op, ot: (g // nd, 0)),
            pl.BlockSpec((1, V), lambda g, op, ot: (g // nd, 0)),
            pl.BlockSpec((1, V), lambda g, op, ot: (g // nd, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((row_block, V), T.dtype),
            pltpu.SemaphoreType.DMA(()),
        ],
    )
    kernel = functools.partial(
        _prune_scan_dskip_kernel,
        dim=dim_for_test, d_tile=d_tile, eps0=eps0,
        quantized=quantized, packed=packed, row_block=row_block,
    )
    dists, alive, streamed = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((P, V), jnp.float32),
            jax.ShapeDtypeStruct((P, V), jnp.float32),
            jax.ShapeDtypeStruct((P, V), jnp.float32),
        ],
        interpret=_interpret(),
    )(
        order_p.astype(jnp.int32), order_t.astype(jnp.int32),
        q2, ids, thr2, scale2, offset2, T,
    )
    return dists, alive, streamed
