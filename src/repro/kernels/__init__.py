"""Pallas TPU kernels for the paper's compute hot-spot: the distance scan.

<name>.py hold pl.pallas_call kernels with explicit BlockSpec VMEM tiling;
ops.py are the jit'd public wrappers (padding, tile selection, the
pallas-vs-jnp body knob); ref.py are the pure-jnp oracles every kernel is
tested against (interpret=True on CPU).

Design notes
============

**Layout.**  A PDX partition tile is ``(D, V)`` with vectors on the 128-wide
lane axis: the running distances array of the paper's Algorithm 1 is one
VMEM row per partition, there is no horizontal reduction, and a dimension
slice is exactly the contiguous stretch a ``BlockSpec((d_tile, V))`` DMA
fetches.  The batched kernels exploit that the same tile is already K-major
for the MXU — ``(B, d) @ (d, V)`` with no relayout (paper Section 7's
transposition cost, avoided by construction).

**Grid order.**  Accumulating kernels put the dimension tile innermost so
one output block stays resident in VMEM across all its d-tiles; the
megakernel ``pdx_prune_scan_multi_pallas`` adds the partition as the outer
grid axis, so one ``pallas_call`` covers the whole store and per-partition
state (accumulator + keep-mask) never round-trips to HBM.

**Pruning.**  The ADSampling hypothesis test is fused per d-tile: after each
``(d_tile, V)`` accumulation the keep-mask is re-evaluated in place, and a
``pl.when(any_alive)`` guard skips the *entire* remaining VPU work of a
partition once every lane is dead (the PRUNE phase at tile granularity).
The HBM->VMEM fetch of later tiles still streams under the automatic
pipeline; hoisting it needs manual DMA with scalar prefetch
(``PrefetchScalarGridSpec``) and is deliberately out of scope while the
planner's unit of skip is the partition.

**Quantized mirrors.**  The scan is bandwidth-bound (paper Section 7), so
the executors stream reduced-precision device mirrors (bf16/int8, see
``repro.core.layout.device_mirror``) and dequantize **in-register**:
``x * scale_d + offset_d`` right after the VMEM load, accumulating in f32.
Each stored byte is touched exactly once, at mirror width; exactness is
restored by the planner's f32 re-rank against the master tiles.  PAD lanes
cannot be represented monotonically in int8, so every quantized kernel
seeds its keep-mask from ``ids >= 0`` instead of relying on the PAD_VALUE
sentinel.

**Masking contract.**  Kernels keep the alive mask as f32 internally (VPU
select-friendly, and bool outputs have no stable TPU layout story); the
``ops`` wrappers convert to bool at the boundary so callers never see the
representation.
"""
from .ops import (  # noqa: F401
    batched_distance_op,
    batched_distance_quant_op,
    nary_distance_op,
    pdx_distance_op,
    pdx_prune_scan_multi_op,
    pdx_prune_scan_op,
)
