"""Pallas TPU kernels for the paper's compute hot-spot: the distance scan.

<name>.py hold pl.pallas_call kernels with explicit BlockSpec VMEM tiling;
ops.py are the jit'd public wrappers (padding, tile selection); ref.py are
the pure-jnp oracles every kernel is tested against (interpret=True on CPU).
"""
from .ops import (  # noqa: F401
    batched_distance_op,
    nary_distance_op,
    pdx_distance_op,
    pdx_prune_scan_op,
)
