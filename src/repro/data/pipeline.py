"""Deterministic, resumable training data pipeline.

Batches are a pure function of (seed, step) via counter-keyed RNG, so
restart-from-checkpoint replays the exact stream with no stored iterator
state — the simplest correct fault-tolerance story for synthetic/tokenized
data.  ``Prefetcher`` overlaps host batch synthesis with device compute.
"""
from __future__ import annotations

import queue
import threading
from typing import Callable, Iterator, Optional

import jax
import numpy as np

from ..configs.base import ArchConfig
from ..launch.specs import text_len

__all__ = ["TokenStream", "Prefetcher"]


class TokenStream:
    """Synthetic LM token stream with next-token labels."""

    def __init__(
        self, cfg: ArchConfig, seq_len: int, batch: int, seed: int = 0,
        dtype=np.float32,
    ):
        self.cfg = cfg
        self.seq = text_len(cfg, seq_len)
        self.batch = batch
        self.seed = seed
        self.dtype = dtype

    def batch_at(self, step: int) -> dict[str, np.ndarray]:
        rng = np.random.default_rng((self.seed, step))
        toks = rng.integers(
            0, self.cfg.vocab, (self.batch, self.seq + 1), dtype=np.int64
        ).astype(np.int32)
        out = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
        if self.cfg.vlm:
            out["vision_embeds"] = rng.standard_normal(
                (self.batch, self.cfg.n_patches, self.cfg.d_model)
            ).astype(self.dtype)
        if self.cfg.encdec:
            out["enc_frames"] = rng.standard_normal(
                (self.batch, self.cfg.enc_seq, self.cfg.d_model)
            ).astype(self.dtype)
        return out

    def iter_from(self, step: int) -> Iterator[dict[str, np.ndarray]]:
        while True:
            yield self.batch_at(step)
            step += 1


class Prefetcher:
    """Background-thread prefetch of host batches (double buffering), with
    optional device placement (donatable input pipeline)."""

    def __init__(
        self,
        it: Iterator[dict[str, np.ndarray]],
        depth: int = 2,
        place: Optional[Callable] = None,
    ):
        self.it = it
        self.q: queue.Queue = queue.Queue(maxsize=depth)
        self.place = place or (lambda b: b)
        self._stop = False
        self.thread = threading.Thread(target=self._work, daemon=True)
        self.thread.start()

    def _work(self):
        try:
            for b in self.it:
                if self._stop:
                    return
                self.q.put(self.place(b))
        except BaseException as e:
            self.q.put(e)

    def next(self):
        item = self.q.get()
        if isinstance(item, BaseException):
            raise item
        return item

    def close(self):
        self._stop = True
        try:
            while True:
                self.q.get_nowait()
        except queue.Empty:
            pass
