"""Synthetic vector collections matching the paper's dataset taxonomy
(Section 2.2): *normal* (DEEP/GloVe/Contriever-like) vs *skewed*
(SIFT/GIST/MSong/OpenAI-like), plus *clustered* mixtures so IVF has real
structure to find.  Also exact ground-truth KNN and recall@k.
"""
from __future__ import annotations

import numpy as np

__all__ = ["make_dataset", "ground_truth", "recall_at_k", "DATASET_KINDS"]

DATASET_KINDS = ("normal", "skewed", "clustered")


def make_dataset(
    n: int,
    dim: int,
    kind: str = "normal",
    *,
    n_queries: int = 16,
    n_clusters: int = 64,
    seed: int = 0,
) -> tuple[np.ndarray, np.ndarray]:
    """Returns (X (n, dim), Q (n_queries, dim)) float32.

    normal    — i.i.d. standard normal dims (hard to prune; paper Table 2).
    skewed    — per-dimension gamma with varying scale (easy to prune).
    clustered — mixture of Gaussians (IVF-friendly), mildly anisotropic.
    """
    rng = np.random.default_rng(seed)
    if kind == "normal":
        X = rng.standard_normal((n, dim))
        Q = rng.standard_normal((n_queries, dim))
    elif kind == "skewed":
        shape = rng.uniform(0.5, 2.0, size=dim)
        scale = rng.uniform(0.2, 5.0, size=dim)
        X = rng.gamma(shape[None, :], scale[None, :], size=(n, dim))
        Q = rng.gamma(shape[None, :], scale[None, :], size=(n_queries, dim))
    elif kind == "clustered":
        centers = rng.standard_normal((n_clusters, dim)) * 4.0
        widths = rng.uniform(0.3, 1.2, size=(n_clusters, 1))
        ca = rng.integers(0, n_clusters, size=n)
        X = centers[ca] + rng.standard_normal((n, dim)) * widths[ca]
        qa = rng.integers(0, n_clusters, size=n_queries)
        Q = centers[qa] + rng.standard_normal((n_queries, dim)) * widths[qa]
    else:
        raise ValueError(f"kind must be one of {DATASET_KINDS}")
    return X.astype(np.float32), Q.astype(np.float32)


def ground_truth(
    X: np.ndarray, Q: np.ndarray, k: int, metric: str = "l2", chunk: int = 65536
) -> tuple[np.ndarray, np.ndarray]:
    """Exact top-k by brute force (numpy, chunked): (B, k) ids and dists."""
    B = Q.shape[0]
    ids = np.zeros((B, k), np.int64)
    ds = np.zeros((B, k), np.float32)
    for qi in range(B):
        q = Q[qi]
        best_d = None
        best_i = None
        for lo in range(0, len(X), chunk):
            xc = X[lo : lo + chunk]
            if metric == "l2":
                d = ((xc - q[None, :]) ** 2).sum(1)
            elif metric == "l1":
                d = np.abs(xc - q[None, :]).sum(1)
            else:
                d = -(xc @ q)
            idx = np.argpartition(d, min(k, len(d) - 1))[:k]
            cd, ci = d[idx], idx + lo
            if best_d is None:
                best_d, best_i = cd, ci
            else:
                alld = np.concatenate([best_d, cd])
                alli = np.concatenate([best_i, ci])
                sel = np.argpartition(alld, k - 1)[:k]
                best_d, best_i = alld[sel], alli[sel]
        order = np.argsort(best_d, kind="stable")
        ids[qi], ds[qi] = best_i[order], best_d[order]
    return ids, ds


def recall_at_k(found_ids: np.ndarray, true_ids: np.ndarray) -> float:
    """Mean |found ∩ true| / k over queries (paper Section 2.1)."""
    found_ids = np.atleast_2d(found_ids)
    true_ids = np.atleast_2d(true_ids)
    k = true_ids.shape[1]
    hits = 0
    for f, t in zip(found_ids, true_ids):
        hits += len(set(f.tolist()) & set(t.tolist()))
    return hits / (len(true_ids) * k)
