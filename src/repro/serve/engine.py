"""Batched LM serving engine: prefill + jitted greedy/temperature decode.

One of the package's two serving paths — this module is the *generation*
side (the serve-side counterpart of the dry-run's ``prefill``/``decode``
steps; on a real mesh the same functions run under jit with the sharding
rules from repro.dist.sharding, decode caches batch- or sequence-sharded).
The *vector-search* side is ``repro.serve.vector.VectorServer``: an async
continuous-batching front end over ``VectorSearchEngine`` with pow2
compiled-shape buckets, deadline/backpressure admission, and background
store maintenance — ``repro.serve.rag`` joins the two into a
retrieval-augmented pipeline.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..launch.specs import text_len
from ..models.lm import LMModel

__all__ = ["GenerationEngine"]


@dataclasses.dataclass
class GenerationEngine:
    model: LMModel
    params: dict
    cache_len: int

    def __post_init__(self):
        self._prefill = jax.jit(
            lambda p, b: self.model.prefill(p, b, self.cache_len)
        )
        self._decode = jax.jit(self.model.decode_step)

    def generate(
        self,
        batch: dict,
        max_new_tokens: int = 16,
        temperature: float = 0.0,
        seed: int = 0,
    ) -> np.ndarray:
        """batch: {'tokens': (B, S), ...modality extras}. Returns (B, new)."""
        cfg = self.model.cfg
        B, S = batch["tokens"].shape
        pos0 = S + (cfg.n_patches if cfg.vlm else 0)
        assert pos0 + max_new_tokens <= self.cache_len, "cache too small"
        logits, caches = self._prefill(self.params, batch)
        key = jax.random.key(seed)
        out = []
        tok = None
        for t in range(max_new_tokens):
            if temperature > 0:
                key, sub = jax.random.split(key)
                tok = jax.random.categorical(sub, logits / temperature, axis=-1)
            else:
                tok = jnp.argmax(logits, axis=-1)
            out.append(np.asarray(tok))
            if t == max_new_tokens - 1:
                break
            logits, caches = self._decode(
                self.params, tok[:, None].astype(jnp.int32), caches, pos0 + t
            )
        return np.stack(out, axis=1)

    def embed(self, batch: dict) -> np.ndarray:
        """Mean-pooled final hidden state — the RAG query/corpus embedding."""
        h = self.model.forward_train(self.params, batch, remat=False)
        return np.asarray(jnp.mean(h.astype(jnp.float32), axis=1))
