"""Online serving tier.

Two serving paths live here:

* **Vector search** (the PDX side): ``VectorServer`` in
  :mod:`repro.serve.vector` — continuous batching over a
  ``VectorSearchEngine`` with pow2 compiled-shape buckets, deadline /
  backpressure admission (:mod:`repro.serve.batcher`), host-plan /
  device-run overlap, and background store maintenance behind a version
  fence.
* **LM generation**: ``GenerationEngine`` in :mod:`repro.serve.engine`
  (prefill + jitted decode loop) and the retrieval-augmented pipeline in
  :mod:`repro.serve.rag` that joins the two.
"""
from .batcher import (
    AdmissionQueue,
    DeadlineExceeded,
    QueryItem,
    ServeError,
    ServerClosed,
    ServerOverloaded,
    pad_batch,
    shape_bucket,
)
from .vector import VectorServer, jit_compile_count

__all__ = [
    "VectorServer",
    "jit_compile_count",
    "AdmissionQueue",
    "QueryItem",
    "ServeError",
    "ServerOverloaded",
    "ServerClosed",
    "DeadlineExceeded",
    "shape_bucket",
    "pad_batch",
]
