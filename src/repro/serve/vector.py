"""Async online serving tier for vector search: continuous batching with
compiled-shape discipline, deadline/backpressure, overlapped host planning,
and off-path store maintenance.

``VectorServer`` wraps a ``VectorSearchEngine`` with three threads:

batcher
    Drains the ``AdmissionQueue`` (``repro.serve.batcher``), coalescing
    same-spec queries into a batch, pads it to a pow2 shape bucket
    (``core.plan.pow2_bucket`` — the demand-octave discipline the routing
    layer already applies to send budgets, so a drifting arrival rate
    cycles through at most ``log2(max_batch) + 1`` compiled shapes), runs
    the HOST half of the search (``plan_search`` + ``prepare_execute``
    under the store lock), and hands the prepared batch to the executor
    through a depth-1 queue.  That queue IS the double buffer: while the
    executor runs batch N's device work, the batcher is already planning
    and packing batch N+1 — for the routed executor the overlap is
    genuine (placement, bucket ranking, send-buffer packing all happen
    here), for host-local executors it overlaps planning and padding.

executor
    The sole store mutator.  Pops prepared batches (executes them with no
    lock held — nothing else may mutate), mutations (``insert``/``delete``
    applied under the store lock), and maintenance swaps.  Records the
    cross-thread query trace: ``start_query``/``use``/``finish_query``
    plus ``span_at`` for the queue wait and the batcher-side plan time, so
    every serving query lands in the shared trace ring with a ``queue``
    span in front of the usual plan → scan → merge taxonomy.

maintenance (optional)
    Periodically clones the store under the lock, runs
    ``MutablePDXStore.repack()`` on the clone OFF the serving path, and
    posts a version-fenced swap.  Mutations that land while the clone
    repacks are recorded in the store's oplog and REPLAYED onto the clone
    before adoption (``MutablePDXStore.oplog_start``/``replay``), so under
    continuous traffic the repack work is adopted instead of discarded;
    only an overflowed oplog (mutation flood) or replay id divergence
    falls back to discard-and-retry.  Compaction never blocks a query;
    BSA recalibration (which rewrites live vectors) deliberately stays
    with the synchronous ``engine.compact()``.

Backpressure and deadlines: the admission queue is bounded — a full queue
rejects at ``submit`` time with ``ServerOverloaded`` (bounded queue =
bounded latency).  Before that, overload *sheds*: when the queue is deeper
than ``shed_depth`` the batcher drops the batch's ``nprobe`` to
``shed_nprobe`` (IVF engines), trading recall for latency before refusing
work.  Each query may carry a deadline; expiry is checked both while
queued (an expired item never occupies a batch slot) and after execution.

Zero recompiles after warmup: ``warmup()`` pushes one synthetic batch per
shape bucket through the full prepare/run path (seeding jit, placement,
mirror, and write-head-merge caches — ``core.plan.warm_shapes``) and
snapshots the process-wide XLA compile counter; ``jit_compiles_since_warmup``
then asserts the steady state mints no new executables.
"""
from __future__ import annotations

import queue
import threading
import time
from concurrent.futures import Future
from typing import Optional

import numpy as np

from ..core.layout import MutablePDXStore
from ..core.plan import plan_search, pow2_bucket, prepare_execute, warm_shapes
from ..obs import metrics as _metrics
from ..obs import trace as _trace
from .batcher import (
    AdmissionQueue,
    DeadlineExceeded,
    QueryItem,
    ServerClosed,
    ServerOverloaded,
    pad_batch,
)

__all__ = ["VectorServer", "jit_compile_count"]


# --------------------------------------------------------- compile counting
# jax.monitoring fires '/jax/…compile…' events once per real XLA compile and
# nothing on jit cache hits, so counting them is exactly "executables minted".
_COMPILE_LOCK = threading.Lock()
_COMPILE_COUNT = 0
_COMPILE_LISTENER = False


def _on_jax_event(event: str, **kwargs) -> None:
    global _COMPILE_COUNT
    if "compile" in event:
        with _COMPILE_LOCK:
            _COMPILE_COUNT += 1
            n = _COMPILE_COUNT
        if _metrics.enabled():
            _metrics.gauge("repro_serve_jit_compiles", float(n))


def _ensure_compile_listener() -> None:
    global _COMPILE_LISTENER
    with _COMPILE_LOCK:
        if _COMPILE_LISTENER:
            return
        _COMPILE_LISTENER = True
    try:
        import jax

        jax.monitoring.register_event_listener(_on_jax_event)
    except Exception:
        pass  # older jax: counter stays 0, the gate degrades to a no-op


def jit_compile_count() -> int:
    """XLA compiles observed process-wide since the listener registered
    (0 until a ``VectorServer`` or explicit ``_ensure_compile_listener``)."""
    with _COMPILE_LOCK:
        return _COMPILE_COUNT


# ------------------------------------------------------------- work items
class _Shutdown:
    pass


_SHUTDOWN = _Shutdown()


class _Batch:
    __slots__ = (
        "items", "prepared", "bucket", "Qpad", "spec",
        "store_version", "t_plan0", "t_plan1", "shed",
    )

    def __init__(self, items, prepared, bucket, Qpad, spec, store_version,
                 t_plan0, t_plan1, shed):
        self.items = items
        self.prepared = prepared
        self.bucket = bucket
        self.Qpad = Qpad
        self.spec = spec
        self.store_version = store_version
        self.t_plan0 = t_plan0
        self.t_plan1 = t_plan1
        self.shed = shed


class _Mutation:
    __slots__ = ("kind", "payload", "future")

    def __init__(self, kind, payload, future):
        self.kind = kind          # "insert" | "delete"
        self.payload = payload
        self.future = future


class _Swap:
    __slots__ = ("clone", "expect_version")

    def __init__(self, clone, expect_version):
        self.clone = clone
        self.expect_version = expect_version


class VectorServer:
    """Continuous-batching front end over a ``VectorSearchEngine``.

    ``submit`` is async (returns a ``concurrent.futures.Future`` resolving
    to ``(ids, dists)``), ``search`` is its blocking wrapper; ``insert`` /
    ``delete`` return futures too and are serialized through the executor
    thread so the store has exactly one mutator.  Use as a context manager
    or call ``close()`` — ``drain=True`` (default) completes every queued
    query before the threads exit.
    """

    def __init__(
        self,
        engine,
        *,
        spec=None,
        max_batch: int = 64,
        queue_depth: int = 256,
        flush_interval_s: float = 0.002,
        default_timeout_s: Optional[float] = None,
        shed_depth: Optional[int] = None,
        shed_nprobe: int = 4,
        maintenance_interval_s: Optional[float] = None,
        head_fill_threshold: float = 0.75,
        fragmentation_threshold: float = 0.25,
    ):
        if max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {max_batch}")
        self.engine = engine
        self.spec = spec if spec is not None else engine.spec
        self.max_batch = int(max_batch)
        self.flush_interval_s = float(flush_interval_s)
        self.default_timeout_s = default_timeout_s
        self.shed_depth = shed_depth
        self.shed_nprobe = int(shed_nprobe)
        self.maintenance_interval_s = maintenance_interval_s
        self.head_fill_threshold = float(head_fill_threshold)
        self.fragmentation_threshold = float(fragmentation_threshold)

        self._queue = AdmissionQueue(queue_depth)
        self._work: "queue.Queue" = queue.Queue(maxsize=1)
        self._store_lock = threading.RLock()
        self._stop = threading.Event()
        self._closed = False
        self._close_lock = threading.Lock()
        self._warm_compiles: Optional[int] = None

        _ensure_compile_listener()

        self._batcher = threading.Thread(
            target=self._batcher_loop, name="serve-batcher", daemon=True
        )
        self._executor = threading.Thread(
            target=self._executor_loop, name="serve-executor", daemon=True
        )
        self._batcher.start()
        self._executor.start()
        self._maintenance = None
        if maintenance_interval_s is not None:
            self._maintenance = threading.Thread(
                target=self._maintenance_loop, name="serve-maintenance",
                daemon=True,
            )
            self._maintenance.start()

    # ------------------------------------------------------------- public API
    def submit(
        self,
        q: np.ndarray,
        spec=None,
        *,
        timeout_s: Optional[float] = None,
    ) -> Future:
        """Enqueue one (D,) query; the future resolves to ``(ids, dists)``
        (each ``(k,)``).  Raises ``ServerOverloaded`` when the admission
        queue is full and ``ServerClosed`` after ``close()``."""
        q = np.ascontiguousarray(np.asarray(q, np.float32))
        if q.ndim != 1:
            raise ValueError(f"submit takes one (D,) query, got {q.shape}")
        timeout_s = timeout_s if timeout_s is not None else self.default_timeout_s
        now = time.perf_counter()
        item = QueryItem(
            query=q,
            spec=spec if spec is not None else self.spec,
            future=Future(),
            t_enqueue=now,
            deadline=None if timeout_s is None else now + timeout_s,
        )
        if not self._queue.put(item):
            if _metrics.enabled():
                _metrics.counter("repro_serve_rejected_total")
            raise ServerOverloaded(
                f"admission queue full ({self._queue.maxsize})"
            )
        if _metrics.enabled():
            _metrics.gauge(
                "repro_serve_queue_depth", float(len(self._queue))
            )
        return item.future

    def search(self, q, spec=None, *, timeout_s=None):
        """Blocking ``submit``: returns ``(ids, dists)`` or raises the
        query's error (``DeadlineExceeded``, ``ServerClosed``, …)."""
        return self.submit(q, spec, timeout_s=timeout_s).result()

    def insert(self, X: np.ndarray) -> Future:
        """Async insert; resolves to the new ids.  Serialized through the
        executor thread between batches."""
        fut = Future()
        self._put_work(_Mutation("insert", np.asarray(X, np.float32), fut))
        return fut

    def delete(self, ids) -> Future:
        """Async delete; resolves to the number of rows tombstoned."""
        fut = Future()
        self._put_work(_Mutation("delete", ids, fut))
        return fut

    def queue_depth(self) -> int:
        return len(self._queue)

    def metrics(self) -> dict:
        return self.engine.metrics()

    def warmup(self, buckets=None, specs=None) -> dict:
        """Pre-compile every shape bucket (and the shed-nprobe variants, if
        shedding is configured), then snapshot the compile counter for
        ``jit_compiles_since_warmup``.  ``specs`` adds extra SearchSpecs to
        warm beyond the server default — e.g. a cascade spec (whose pow2
        survivor/re-rank shape menus compile exhaustively) or a tiered
        spec clients are known to send.  Returns {bucket: executor}."""
        if buckets is None:
            buckets = []
            b = 1
            while b <= self.max_batch:
                buckets.append(b)
                b *= 2
        all_specs = [self.spec] + list(specs or ())
        if self.shed_depth is not None and self.engine.ivf is not None:
            all_specs.append(self.spec.replace(nprobe=self.shed_nprobe))
        out = {}
        with self._store_lock:
            for sp in all_specs:
                out = warm_shapes(
                    sp, self.engine.store, self.engine.pruner, buckets,
                    ivf=self.engine.ivf, mesh=self.engine.mesh,
                )
        self._warm_compiles = jit_compile_count()
        return out

    def jit_compiles_since_warmup(self) -> int:
        """Executables minted after ``warmup()`` (the zero-recompile gate);
        raises if warmup was never run."""
        if self._warm_compiles is None:
            raise RuntimeError("call warmup() first")
        return jit_compile_count() - self._warm_compiles

    def close(self, drain: bool = True, timeout_s: float = 30.0) -> None:
        """Shut down.  ``drain=True`` lets queued queries complete first;
        ``drain=False`` fails them with ``ServerClosed``."""
        with self._close_lock:
            if self._closed:
                return
            self._closed = True
        if not drain:
            for item in self._queue.clear():
                if not item.future.done():
                    item.future.set_exception(
                        ServerClosed("server closed without drain")
                    )
        self._stop.set()
        self._queue.close()  # wakes the batcher; it drains then forwards
        self._batcher.join(timeout=timeout_s)
        self._executor.join(timeout=timeout_s)
        if self._maintenance is not None:
            self._maintenance.join(timeout=timeout_s)

    def __enter__(self) -> "VectorServer":
        return self

    def __exit__(self, *exc) -> bool:
        self.close()
        return False

    # -------------------------------------------------------------- internals
    def _put_work(self, item) -> None:
        if self._closed and not isinstance(item, (_Batch, _Shutdown)):
            raise ServerClosed("server is closed")
        self._work.put(item)

    def _fail_expired(self, expired) -> None:
        for item in expired:
            if _metrics.enabled():
                _metrics.counter(
                    "repro_serve_deadline_expired_total", where="queue"
                )
            if not item.future.done():
                item.future.set_exception(
                    DeadlineExceeded("deadline passed while queued")
                )

    def _batcher_loop(self) -> None:
        while True:
            batch, expired = self._queue.drain(
                self.max_batch,
                window_s=self.flush_interval_s,
                timeout_s=0.05,
            )
            self._fail_expired(expired)
            if not batch:
                if self._queue.closed and not len(self._queue):
                    self._work.put(_SHUTDOWN)
                    return
                continue

            spec = batch[0].spec
            shed = False
            if (
                self.shed_depth is not None
                and self.engine.ivf is not None
                and len(self._queue) >= self.shed_depth
                and spec.nprobe > self.shed_nprobe
            ):
                spec = spec.replace(nprobe=self.shed_nprobe)
                shed = True
                if _metrics.enabled():
                    _metrics.counter(
                        "repro_serve_shed_total", action="nprobe"
                    )

            Q = np.stack([item.query for item in batch])
            bucket = pow2_bucket(len(batch), cap=self.max_batch)
            Qpad = pad_batch(Q, bucket)

            # host half under the store lock: plan + prepare see a consistent
            # store; the device half runs on the executor thread, which is
            # also the only mutator — prepare(N+1) overlaps run(N).
            t_plan0 = time.perf_counter()
            with self._store_lock:
                version = getattr(self.engine.store, "version", None)
                prepared = self._prepare(Qpad, bucket, spec)
            t_plan1 = time.perf_counter()
            self._work.put(_Batch(
                batch, prepared, bucket, Qpad, spec, version,
                t_plan0, t_plan1, shed,
            ))
            if _metrics.enabled():
                _metrics.gauge(
                    "repro_serve_queue_depth", float(len(self._queue))
                )
                _metrics.observe(
                    "repro_serve_batch_fill", len(batch) / bucket,
                    bucket=bucket,
                )

    def _prepare(self, Qpad, bucket, spec):
        import jax.numpy as jnp

        eng = self.engine
        plan = plan_search(
            spec, eng.store, bucket, pruner=eng.pruner, ivf=eng.ivf,
            mesh=eng.mesh,
        )
        return prepare_execute(
            plan, spec, eng.store, eng.pruner, jnp.asarray(Qpad),
            ivf=eng.ivf, mesh=eng.mesh,
        )

    def _executor_loop(self) -> None:
        while True:
            work = self._work.get()
            if isinstance(work, _Shutdown):
                return
            if isinstance(work, _Mutation):
                self._apply_mutation(work)
                continue
            if isinstance(work, _Swap):
                self._apply_swap(work)
                continue
            self._run_batch(work)

    def _apply_mutation(self, m: _Mutation) -> None:
        try:
            with self._store_lock:
                if m.kind == "insert":
                    out = self.engine.insert(m.payload)
                else:
                    out = self.engine.delete(m.payload)
            m.future.set_result(out)
        except BaseException as e:  # surface on the caller's future
            m.future.set_exception(e)

    def _apply_swap(self, s: _Swap) -> None:
        replayed = 0
        with self._store_lock:
            store = self.engine.store
            ok = False
            if isinstance(store, MutablePDXStore):
                # delta-replay: mutations that landed while the clone was
                # repacking were recorded on the serving store; replaying
                # them onto the repacked clone makes adoption succeed under
                # continuous traffic instead of discarding the repack work.
                # ops is None when the log overflowed (or recording never
                # started) — then only the plain version fence can save us.
                ops = store.oplog_take()
                if store.version == s.expect_version:
                    ok = store.adopt(s.clone, expect_version=s.expect_version)
                elif ops is not None:
                    try:
                        replayed = s.clone.replay(ops)
                        # we hold the lock on the sole mutator thread, so
                        # the version cannot move between replay and adopt
                        ok = store.adopt(
                            s.clone, expect_version=store.version
                        )
                    except ValueError:
                        ok = False  # id divergence: never adopt
            if ok:
                self.engine._sync_ivf()
                if self.engine.pruner.name == "bond":
                    from ..core.pruners import make_bond
                    import jax.numpy as jnp

                    self.engine.pruner = make_bond(
                        jnp.asarray(store.dim_means),
                        zone_size=self.engine.zone_size,
                    )
                # BSA recalibration rewrites live vectors (not just
                # metadata) — that stays with synchronous engine.compact().
        if _metrics.enabled():
            _metrics.counter(
                "repro_serve_maintenance_total",
                event="swap" if ok else "discard",
            )
            if replayed:
                _metrics.counter(
                    "repro_serve_replayed_rows_total", float(replayed)
                )

    def _run_batch(self, b: _Batch) -> None:
        t_run = time.perf_counter()
        # a mutation or swap may have landed between prepare and now (FIFO
        # only orders the queue, not prepare time) — the prepared host state
        # would be stale, so re-prepare against the current store.
        version = getattr(self.engine.store, "version", None)
        if version != b.store_version:
            with self._store_lock:
                b.prepared = self._prepare(b.Qpad, b.bucket, b.spec)

        tr = _trace.start_query(
            n_queries=len(b.items), k=b.spec.k, bucket=b.bucket,
            executor=b.prepared.plan.executor, served=True,
        )
        try:
            with _trace.use(tr):
                t_enq = min(item.t_enqueue for item in b.items)
                _trace.span_at("queue", t_enq, t_run, depth_at_drain=len(b.items))
                _trace.span_at("plan", b.t_plan0, b.t_plan1)
                ids, dists = b.prepared.run()
        except BaseException as e:
            _trace.finish_query(tr)
            for item in b.items:
                if not item.future.done():
                    item.future.set_exception(e)
            return
        _trace.finish_query(tr)

        t_done = time.perf_counter()
        en = _metrics.enabled()
        if en:
            _metrics.counter(
                "repro_serve_batches_total", bucket=b.bucket,
                executor=b.prepared.plan.executor, shed=b.shed,
            )
            _metrics.counter(
                "repro_serve_queries_total", float(len(b.items))
            )
        for i, item in enumerate(b.items):
            if en:
                _metrics.observe(
                    "repro_serve_queue_wait_seconds", t_run - item.t_enqueue
                )
                _metrics.observe(
                    "repro_serve_latency_seconds", t_done - item.t_enqueue
                )
            if item.future.done():
                continue
            if item.deadline is not None and t_done > item.deadline:
                if en:
                    _metrics.counter(
                        "repro_serve_deadline_expired_total", where="result"
                    )
                item.future.set_exception(
                    DeadlineExceeded("deadline passed during execution")
                )
            else:
                item.future.set_result((ids[i].copy(), dists[i].copy()))

    def _maintenance_loop(self) -> None:
        while not self._stop.wait(self.maintenance_interval_s):
            store = self.engine.store
            if not isinstance(store, MutablePDXStore):
                continue
            head_fill = store.head_count / max(store.head_capacity, 1)
            if (
                head_fill < self.head_fill_threshold
                and store.fragmentation <= self.fragmentation_threshold
            ):
                continue
            with self._store_lock:
                base = store.version
                clone = store.clone()
                store.oplog_start()  # record deltas landing during repack
            clone.repack()  # the expensive part: no lock, off the serving path
            try:
                self._work.put(_Swap(clone, base), timeout=1.0)
            except queue.Full:
                with self._store_lock:
                    store.oplog_take()  # stop recording; clone is dropped
