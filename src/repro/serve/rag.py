"""Retrieval-augmented serving: the paper's technique as the retrieval
substrate of an LLM pipeline (paper §1: "LLM pipelines ... at the throughput
needed by LLMs").

Pipeline per request batch:
  1. embed queries with the LM (mean-pooled hidden states),
  2. PDX search (ADSampling / BOND / linear) over the document store,
  3. prepend retrieved document tokens to the prompt,
  4. generate.

The document store is a ``VectorSearchEngine`` — exact or IVF, any pruner —
so every assigned architecture gets the paper's technique in its serving
path without touching transformer internals (DESIGN.md §Arch-applicability).
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from ..core.engine import VectorSearchEngine
from ..obs import metrics as _metrics
from .engine import GenerationEngine

__all__ = ["RagPipeline"]


def _embed_docs(engine: GenerationEngine, doc_tokens: np.ndarray) -> np.ndarray:
    """LM-embed documents in chunks -> (n_docs, D) float32."""
    embeds = [
        np.asarray(
            engine.embed({"tokens": jnp.asarray(doc_tokens[lo : lo + 32])})
        )
        for lo in range(0, len(doc_tokens), 32)
    ]
    return np.concatenate(embeds, axis=0)


@dataclasses.dataclass
class RagPipeline:
    engine: GenerationEngine
    store: VectorSearchEngine
    doc_tokens: np.ndarray        # (n_docs, doc_len) int32
    retrieve_k: int = 1

    @classmethod
    def build(
        cls,
        engine: GenerationEngine,
        doc_tokens: np.ndarray,
        *,
        pruner: str = "adsampling",
        index: str = "flat",
        capacity: int = 256,
        retrieve_k: int = 1,
        mesh=None,
        routing: str = "bucket",
    ) -> "RagPipeline":
        """Embed every document with the LM and build the PDX store.

        ``mesh``/``routing`` flow into the search engine: with a
        "data"-axis mesh and an IVF index, retrieval batches are
        bucket-routed across shards (``routing="bucket"``, the default —
        one all-to-all + one packed all-gather per batch) instead of
        broadcast to a mirrored store."""
        X = _embed_docs(engine, doc_tokens)
        store = VectorSearchEngine.build(
            X, pruner=pruner, index=index, capacity=capacity, mesh=mesh,
            routing=routing,
        )
        return cls(
            engine=engine, store=store, doc_tokens=doc_tokens,
            retrieve_k=retrieve_k,
        )

    def add_documents(self, doc_tokens: np.ndarray) -> np.ndarray:
        """Absorb new documents into the live store; returns their doc ids.

        Embeds the documents with the LM and ``insert``s the embeddings —
        they land in the mutable store's write-head and are retrievable by
        the very next ``retrieve``/``answer`` call, no rebuild.  Store ids
        are allocated consecutively from the initial corpus size, so a doc's
        id stays its row in ``self.doc_tokens``.
        """
        doc_tokens = np.asarray(doc_tokens, np.int32)
        if len(doc_tokens) == 0:
            return np.zeros((0,), np.int32)
        ids = self.store.insert(_embed_docs(self.engine, doc_tokens))
        self.doc_tokens = np.concatenate([self.doc_tokens, doc_tokens], axis=0)
        return ids

    def retrieve(self, query_batch: dict) -> np.ndarray:
        """-> (B, retrieve_k) document ids.  One planned search for the whole
        embedding batch — the planner picks the batched (and, when the store
        carries a mesh, batched-sharded) executor instead of a per-query loop."""
        q_emb = np.atleast_2d(np.asarray(self.engine.embed(query_batch)))
        res = self.store.search(q_emb, self.store.spec.replace(k=self.retrieve_k))
        _metrics.counter(
            "repro_rag_retrievals_total", float(len(q_emb)),
            executor=res.plan.executor,
        )
        return np.asarray(res.ids)

    def answer(
        self, query_batch: dict, max_new_tokens: int = 16
    ) -> tuple[np.ndarray, np.ndarray]:
        """-> (generated tokens (B, new), retrieved doc ids (B, k))."""
        doc_ids = self.retrieve(query_batch)
        ctx = self.doc_tokens[doc_ids[:, 0]]          # (B, doc_len)
        tokens = np.concatenate(
            [ctx, np.asarray(query_batch["tokens"])], axis=1
        ).astype(np.int32)
        batch = dict(query_batch)
        batch["tokens"] = jnp.asarray(tokens)
        return self.engine.generate(batch, max_new_tokens), doc_ids
