"""Continuous-batching primitives for the online vector-serving tier.

The pieces ``repro.serve.vector.VectorServer`` is assembled from, kept
engine-free so they are testable without building a store:

``AdmissionQueue``
    A bounded, condition-variable FIFO of ``QueryItem``s.  ``put`` never
    blocks — a full queue REJECTS (the server maps that to
    ``ServerOverloaded``), which is the backpressure contract: latency is
    bounded by queue depth, never by an unbounded buffer.  ``drain``
    blocks for the first item, then coalesces up to ``max_batch`` items
    that share the first item's frozen ``SearchSpec`` (specs are hashable
    and equality-comparable, so "same compiled configuration" is one
    ``==``), waiting up to a flush window for stragglers.  Items whose
    deadline has already passed are filtered out and returned separately,
    so an expired query never occupies a batch slot.

``shape_bucket`` / ``pad_batch``
    The pow2 compiled-shape discipline: a coalesced batch of ``n`` queries
    is padded up to the next power of two (the same demand-octave trick
    ``dist.routing.plan_routing`` applies to send budgets), so a drifting
    arrival rate cycles through at most ``log2(max_batch) + 1`` distinct
    executor shapes instead of minting one per batch size.  Padding
    repeats the last real query — padded lanes cost the same arithmetic as
    real ones and are sliced off before futures complete, so no sentinel
    value can perturb the scan.
"""
from __future__ import annotations

import collections
import dataclasses
import threading
import time
from concurrent.futures import Future
from typing import Optional

import numpy as np

__all__ = [
    "ServeError",
    "ServerOverloaded",
    "ServerClosed",
    "DeadlineExceeded",
    "QueryItem",
    "AdmissionQueue",
    "shape_bucket",
    "pad_batch",
]


class ServeError(RuntimeError):
    """Base class of the serving tier's control-flow errors."""


class ServerOverloaded(ServeError):
    """The admission queue is full: the request is rejected at submit time
    (bounded queue = bounded latency; shedding happens before this)."""


class ServerClosed(ServeError):
    """The server is shut down (or shutting down without drain)."""


class DeadlineExceeded(ServeError):
    """The query's deadline passed before its result was produced."""


@dataclasses.dataclass
class QueryItem:
    """One enqueued query: payload + future + timing envelope.

    ``deadline`` is an absolute ``time.perf_counter`` instant (``None`` =
    no deadline); ``t_enqueue`` anchors the queue-wait span and latency
    metrics."""

    query: np.ndarray              # (D,) float32
    spec: object                   # frozen SearchSpec (hashable, ==-able)
    future: Future
    t_enqueue: float
    deadline: Optional[float] = None

    def expired(self, now: float) -> bool:
        return self.deadline is not None and now > self.deadline


def shape_bucket(n: int, max_batch: int) -> int:
    """Pow2 compiled-shape bucket for a batch of ``n`` queries, clamped to
    ``max_batch`` — the serving tier's demand octaves."""
    if n < 1:
        raise ValueError(f"batch size must be >= 1, got {n}")
    b = 1
    while b < n:
        b *= 2
    return min(b, max_batch)


def pad_batch(Q: np.ndarray, bucket: int) -> np.ndarray:
    """Pad (n, D) up to (bucket, D) by repeating the last row.  Repeating a
    real query keeps padded lanes numerically ordinary (no inf/sentinel
    entering the scan); their results are discarded by the caller."""
    n = len(Q)
    if n == bucket:
        return Q
    if n > bucket:
        raise ValueError(f"batch of {n} does not fit bucket {bucket}")
    return np.concatenate([Q, np.repeat(Q[-1:], bucket - n, axis=0)], axis=0)


class AdmissionQueue:
    """Bounded FIFO of ``QueryItem``s with coalescing drain.

    Thread-safe; many producers (caller threads) and one consumer (the
    batcher thread).  ``close()`` wakes every waiter; after close, ``put``
    raises ``ServerClosed`` and ``drain`` keeps returning queued items
    until the queue is empty (the drain-on-shutdown contract)."""

    def __init__(self, maxsize: int):
        if maxsize < 1:
            raise ValueError(f"maxsize must be >= 1, got {maxsize}")
        self.maxsize = int(maxsize)
        self._q: "collections.deque[QueryItem]" = collections.deque()
        self._cond = threading.Condition()
        self._closed = False

    def __len__(self) -> int:
        with self._cond:
            return len(self._q)

    @property
    def closed(self) -> bool:
        with self._cond:
            return self._closed

    def put(self, item: QueryItem) -> bool:
        """Enqueue; returns False (rejecting) when full — never blocks."""
        with self._cond:
            if self._closed:
                raise ServerClosed("admission queue is closed")
            if len(self._q) >= self.maxsize:
                return False
            self._q.append(item)
            self._cond.notify()
            return True

    def close(self) -> None:
        with self._cond:
            self._closed = True
            self._cond.notify_all()

    def clear(self) -> list:
        """Remove and return every queued item (no-drain shutdown)."""
        with self._cond:
            items = list(self._q)
            self._q.clear()
            return items

    def drain(
        self,
        max_batch: int,
        window_s: float = 0.0,
        timeout_s: Optional[float] = None,
    ) -> tuple[list, list]:
        """Block until at least one item arrives (or ``timeout_s`` elapses /
        the queue closes empty), then coalesce up to ``max_batch`` items
        sharing the FIRST item's spec, waiting up to ``window_s`` for
        stragglers once something is pending.  Returns ``(batch, expired)``
        — ``expired`` items' deadlines passed while queued; items with a
        different spec stay queued (front, original order) for the next
        drain.  ``([], [])`` signals timeout or closed-and-empty."""
        with self._cond:
            deadline = (
                None if timeout_s is None
                else time.perf_counter() + timeout_s
            )
            while not self._q:
                if self._closed:
                    return [], []
                if deadline is None:
                    self._cond.wait()
                else:
                    remaining = deadline - time.perf_counter()
                    if remaining <= 0:
                        return [], []
                    self._cond.wait(remaining)
            if window_s > 0 and not self._closed:
                t_end = time.perf_counter() + window_s
                while len(self._q) < max_batch and not self._closed:
                    remaining = t_end - time.perf_counter()
                    if remaining <= 0:
                        break
                    self._cond.wait(remaining)
            now = time.perf_counter()
            batch: list = []
            expired: list = []
            keep: list = []
            spec = None
            while self._q:
                item = self._q.popleft()
                if item.expired(now):
                    expired.append(item)
                    continue
                if spec is None:
                    spec = item.spec
                if item.spec == spec and len(batch) < max_batch:
                    batch.append(item)
                else:
                    keep.append(item)
            self._q.extendleft(reversed(keep))
            return batch, expired
