"""Process-wide metrics registry: labeled counters, gauges, and
log2-bucketed histograms.

Design constraints (the reason this is not a third-party client):

* **Zero cost when disabled.**  Every instrumentation site in the search
  stack calls ``metrics.enabled()`` first; the module-level convenience
  helpers (``counter``/``gauge``/``observe``) also guard themselves, so a
  disabled process never takes the lock, never allocates a label tuple,
  and never mutates the registry.
* **Thread-safe.**  A single ``threading.Lock`` guards all mutation —
  serving code mutates from request threads while a scraper snapshots.
* **Deterministic snapshots.**  ``snapshot()`` sorts metric names, label
  sets, and histogram buckets, so two registries fed the same event
  sequence serialize to byte-identical JSON (tested).
* **Bounded label cardinality.**  Each metric keeps at most
  ``max_series_per_metric`` distinct label sets; overflow events collapse
  into a reserved ``other="true"`` series and are counted in
  ``dropped_series`` — a buggy label (e.g. a raw id) can never grow the
  registry without bound.

Histograms are log2-bucketed: bucket ``i`` holds values in
``(2**(i-1), 2**i]`` (the upper edge is the Prometheus ``le`` label), with
dedicated underflow (``value <= 0``) and ``+Inf`` handling — one octave per
bucket, which is exactly the "demand octave" resolution the routing
telemetry wants.
"""
from __future__ import annotations

import json
import math
import os
import threading
from typing import Optional

__all__ = [
    "MetricsRegistry",
    "enabled",
    "set_enabled",
    "get_registry",
    "counter",
    "gauge",
    "observe",
]

# ---------------------------------------------------------------- enable flag
_ENABLED = os.environ.get("REPRO_OBS", "").lower() in ("1", "true", "on")


def enabled() -> bool:
    """Is observability on?  Instrumentation sites check this first; when
    False they must do no work beyond the check itself."""
    return _ENABLED


def set_enabled(on: bool) -> None:
    global _ENABLED
    _ENABLED = bool(on)


# ------------------------------------------------------------------ registry
_OVERFLOW_KEY = (("other", "true"),)


def _label_key(labels: dict) -> tuple:
    return tuple(sorted((k, str(v)) for k, v in labels.items()))


def _label_str(key: tuple) -> str:
    return ",".join(f"{k}={v}" for k, v in key)


class _Hist:
    __slots__ = ("count", "sum", "buckets")

    def __init__(self):
        self.count = 0
        self.sum = 0.0
        self.buckets: dict[Optional[int], int] = {}  # None = underflow (<= 0)

    def observe(self, value: float) -> None:
        self.count += 1
        self.sum += value
        idx = bucket_index(value)
        self.buckets[idx] = self.buckets.get(idx, 0) + 1


def bucket_index(value: float) -> Optional[int]:
    """Log2 bucket of ``value``: the smallest ``i`` with ``value <= 2**i``
    (``None`` for the underflow bucket ``value <= 0``)."""
    if value <= 0:
        return None
    return max(int(math.ceil(math.log2(value) - 1e-12)), -64)


def bucket_edge(idx: Optional[int]) -> float:
    """Upper (``le``) edge of a bucket index; the underflow edge is 0."""
    return 0.0 if idx is None else float(2.0 ** idx)


class MetricsRegistry:
    """Thread-safe registry of labeled counters, gauges, and histograms."""

    def __init__(self, max_series_per_metric: int = 64):
        self._lock = threading.Lock()
        self.max_series_per_metric = int(max_series_per_metric)
        self._counters: dict[str, dict[tuple, float]] = {}
        self._gauges: dict[str, dict[tuple, float]] = {}
        self._hists: dict[str, dict[tuple, _Hist]] = {}
        self.dropped_series = 0

    # ------------------------------------------------------------- recording
    def _series_key(self, family: dict, name: str, labels: dict) -> tuple:
        series = family.setdefault(name, {})
        key = _label_key(labels)
        if key not in series and len(series) >= self.max_series_per_metric:
            self.dropped_series += 1
            return _OVERFLOW_KEY
        return key

    def counter(self, name: str, value: float = 1.0, **labels) -> None:
        with self._lock:
            key = self._series_key(self._counters, name, labels)
            series = self._counters[name]
            series[key] = series.get(key, 0.0) + float(value)

    def gauge(self, name: str, value: float, **labels) -> None:
        with self._lock:
            key = self._series_key(self._gauges, name, labels)
            self._gauges[name][key] = float(value)

    def observe(self, name: str, value: float, **labels) -> None:
        with self._lock:
            key = self._series_key(self._hists, name, labels)
            series = self._hists[name]
            h = series.get(key)
            if h is None:
                h = series[key] = _Hist()
            h.observe(float(value))

    # --------------------------------------------------------------- reading
    def get(self, name: str, **labels) -> float:
        """Current value of a counter or gauge series (0.0 if absent)."""
        key = _label_key(labels)
        with self._lock:
            if name in self._counters:
                return self._counters[name].get(key, 0.0)
            if name in self._gauges:
                return self._gauges[name].get(key, 0.0)
        return 0.0

    def sum(self, name: str, **labels) -> float:
        """Sum of every counter series of ``name`` whose labels include the
        given ones — e.g. total bytes across components."""
        want = set(_label_key(labels))
        with self._lock:
            series = self._counters.get(name, {})
            return float(
                sum(v for k, v in series.items() if want <= set(k))
            )

    def snapshot(self) -> dict:
        """Deterministic plain-dict snapshot (sorted names, labels, buckets);
        ``json.dumps(snapshot, sort_keys=True)`` is byte-stable across
        registries fed the same events."""
        with self._lock:
            out: dict = {"counters": {}, "gauges": {}, "histograms": {}}
            for name in sorted(self._counters):
                out["counters"][name] = {
                    _label_str(k): v
                    for k, v in sorted(self._counters[name].items())
                }
            for name in sorted(self._gauges):
                out["gauges"][name] = {
                    _label_str(k): v
                    for k, v in sorted(self._gauges[name].items())
                }
            for name in sorted(self._hists):
                out["histograms"][name] = {}
                for k, h in sorted(self._hists[name].items()):
                    buckets = {
                        f"le_{bucket_edge(i)!r}": c
                        for i, c in sorted(
                            h.buckets.items(),
                            key=lambda kv: (kv[0] is not None, kv[0] or 0),
                        )
                    }
                    out["histograms"][name][_label_str(k)] = {
                        "count": h.count, "sum": h.sum, "buckets": buckets,
                    }
            out["dropped_series"] = self.dropped_series
            return out

    def dump_json(self, path: Optional[str] = None) -> str:
        text = json.dumps(self.snapshot(), sort_keys=True, indent=2)
        if path is not None:
            with open(path, "w") as f:
                f.write(text + "\n")
        return text

    def prometheus_text(self) -> str:
        """Prometheus text exposition (counters/gauges verbatim, histograms
        with cumulative ``_bucket{le=...}``/``_sum``/``_count`` series)."""
        def fmt_labels(key: tuple, extra: str = "") -> str:
            parts = [f'{k}="{v}"' for k, v in key]
            if extra:
                parts.append(extra)
            return "{" + ",".join(parts) + "}" if parts else ""

        lines: list[str] = []
        with self._lock:
            for name in sorted(self._counters):
                lines.append(f"# TYPE {name} counter")
                for k, v in sorted(self._counters[name].items()):
                    lines.append(f"{name}{fmt_labels(k)} {v:g}")
            for name in sorted(self._gauges):
                lines.append(f"# TYPE {name} gauge")
                for k, v in sorted(self._gauges[name].items()):
                    lines.append(f"{name}{fmt_labels(k)} {v:g}")
            for name in sorted(self._hists):
                lines.append(f"# TYPE {name} histogram")
                for k, h in sorted(self._hists[name].items()):
                    cum = 0
                    for i, c in sorted(
                        h.buckets.items(),
                        key=lambda kv: (kv[0] is not None, kv[0] or 0),
                    ):
                        cum += c
                        le = 'le="%g"' % bucket_edge(i)
                        lines.append(f"{name}_bucket{fmt_labels(k, le)} {cum}")
                    inf = 'le="+Inf"'
                    lines.append(
                        f"{name}_bucket{fmt_labels(k, inf)} {h.count}"
                    )
                    lines.append(f"{name}_sum{fmt_labels(k)} {h.sum:g}")
                    lines.append(f"{name}_count{fmt_labels(k)} {h.count}")
        return "\n".join(lines) + "\n"

    def reset(self) -> None:
        with self._lock:
            self._counters.clear()
            self._gauges.clear()
            self._hists.clear()
            self.dropped_series = 0


_REGISTRY = MetricsRegistry()


def get_registry() -> MetricsRegistry:
    return _REGISTRY


# ------------------------------------------- guarded convenience recorders
# These exist so call sites stay one line; each re-checks the flag so a
# direct call in disabled mode is still a no-op.
def counter(name: str, value: float = 1.0, **labels) -> None:
    if _ENABLED:
        _REGISTRY.counter(name, value, **labels)


def gauge(name: str, value: float, **labels) -> None:
    if _ENABLED:
        _REGISTRY.gauge(name, value, **labels)


def observe(name: str, value: float, **labels) -> None:
    if _ENABLED:
        _REGISTRY.observe(name, value, **labels)
