"""Per-query span tracer with a bounded ring buffer and Perfetto export.

One ``QueryTrace`` is recorded per ``VectorSearchEngine.search`` call (the
span taxonomy is documented in the package docstring: plan → route → scan →
rerank → merge under a ``query`` root).  Spans are wall-clock intervals
(``time.perf_counter``); because every executor materializes host arrays
before returning, a span closing after the executor body has already paid
the device fence — ``fence(x)`` is the explicit ``block_until_ready``
helper for call sites that hold device values open across a span edge.

Disabled mode (``obs.metrics.enabled() == False``) is a strict no-op: the
module-level ``query``/``span`` helpers return shared null context
managers, allocate nothing, touch no thread-local state, and never force a
device sync.

Threading model
---------------
The *current* trace is thread-local: concurrent searches on different
threads each record into their own ``QueryTrace`` and all finished traces
land in the one shared, lock-guarded ring — ``engine.metrics()`` /
``dump_trace()`` aggregate across every thread.  For serving loops where a
query's lifecycle crosses threads (enqueued on a caller thread, executed on
a worker), the context-manager API splits into explicit halves:

    trace = tracer.start_query(bucket=8)      # any thread, no binding
    with tracer.use(trace):                   # bind on the worker thread
        tracer.span_at("queue", t_enq, t_run) # record the already-elapsed wait
        ... spans recorded by the engine land on `trace` ...
    tracer.finish_query(trace)                # any thread -> shared ring

``finish_query`` unbinds the trace only from threads where it is current
(via ``use``), so finishing on thread B never leaves thread A's
thread-local pointing at a dead trace.
"""
from __future__ import annotations

import collections
import dataclasses
import json
import threading
import time
from typing import Optional

from . import metrics as _metrics

__all__ = [
    "Span",
    "QueryTrace",
    "Tracer",
    "get_tracer",
    "query",
    "span",
    "span_at",
    "start_query",
    "finish_query",
    "use",
    "fence",
    "current_trace",
]


@dataclasses.dataclass
class Span:
    """One traced phase: a closed wall-clock interval plus attributes."""

    name: str
    t0: float
    t1: float
    depth: int
    attrs: dict

    @property
    def duration_s(self) -> float:
        return self.t1 - self.t0


@dataclasses.dataclass
class QueryTrace:
    """All spans of one search call, in completion order."""

    trace_id: int
    t0: float
    t1: float = 0.0
    attrs: dict = dataclasses.field(default_factory=dict)
    spans: list = dataclasses.field(default_factory=list)

    @property
    def duration_s(self) -> float:
        return self.t1 - self.t0

    def span_names(self) -> tuple:
        return tuple(s.name for s in self.spans)

    def find(self, name: str) -> Optional[Span]:
        for s in self.spans:
            if s.name == name:
                return s
        return None


class _NullCtx:
    """Shared no-op context manager — the disabled-mode fast path."""

    __slots__ = ()

    def __enter__(self):
        return None

    def __exit__(self, *exc):
        return False


_NULL_CTX = _NullCtx()


class _SpanCtx:
    __slots__ = ("_tracer", "_trace", "name", "attrs", "_t0", "_depth")

    def __init__(self, tracer: "Tracer", trace: QueryTrace, name: str,
                 attrs: dict):
        self._tracer = tracer
        self._trace = trace
        self.name = name
        self.attrs = attrs

    def __enter__(self):
        tl = self._tracer._tl
        self._depth = getattr(tl, "depth", 0)
        tl.depth = self._depth + 1
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        t1 = time.perf_counter()
        self._tracer._tl.depth = self._depth
        self._trace.spans.append(Span(
            name=self.name, t0=self._t0, t1=t1, depth=self._depth,
            attrs=self.attrs,
        ))
        return False


class _QueryCtx:
    __slots__ = ("_tracer", "attrs", "_trace")

    def __init__(self, tracer: "Tracer", attrs: dict):
        self._tracer = tracer
        self.attrs = attrs

    def __enter__(self) -> QueryTrace:
        self._trace = self._tracer._start(self.attrs)
        return self._trace

    def __exit__(self, *exc):
        self._tracer._finish(self._trace)
        return False


class _UseCtx:
    """Binds an explicitly started trace as the calling thread's current
    trace for the duration of the block, restoring the previous binding on
    exit — a worker thread in a pool never inherits a stale current trace
    from an earlier query it executed."""

    __slots__ = ("_tracer", "_trace", "_prev", "_prev_depth")

    def __init__(self, tracer: "Tracer", trace: QueryTrace):
        self._tracer = tracer
        self._trace = trace

    def __enter__(self) -> QueryTrace:
        tl = self._tracer._tl
        self._prev = getattr(tl, "current", None)
        self._prev_depth = getattr(tl, "depth", 0)
        tl.current = self._trace
        tl.depth = 0
        return self._trace

    def __exit__(self, *exc):
        tl = self._tracer._tl
        tl.current = self._prev
        tl.depth = self._prev_depth
        return False


class Tracer:
    """Span recorder: per-thread current trace, bounded ring of finished
    traces, Chrome/Perfetto JSON export."""

    def __init__(self, capacity: int = 256):
        self.capacity = int(capacity)
        self._ring: "collections.deque[QueryTrace]" = collections.deque(
            maxlen=self.capacity
        )
        self._tl = threading.local()
        self._lock = threading.Lock()
        self._next_id = 0

    # ------------------------------------------------------------- recording
    def query(self, **attrs):
        """Context manager opening a new ``QueryTrace`` (the root span).
        Yields the trace when enabled, ``None`` (a shared null context)
        otherwise; nested traces are not supported — a nested call records
        nothing and leaves the outer trace current."""
        if not _metrics.enabled() or getattr(self._tl, "current", None):
            return _NULL_CTX
        return _QueryCtx(self, attrs)

    def span(self, name: str, **attrs):
        """Context manager recording one span on the current trace; a shared
        no-op when disabled or outside a ``query`` context."""
        trace = getattr(self._tl, "current", None)
        if trace is None or not _metrics.enabled():
            return _NULL_CTX
        return _SpanCtx(self, trace, name, attrs)

    def span_at(self, name: str, t0: float, t1: float, **attrs) -> None:
        """Record an already-elapsed interval as a span on the current trace
        (e.g. the queue wait a batcher measured before the worker thread
        bound the trace).  No-op when disabled or outside a trace."""
        trace = getattr(self._tl, "current", None)
        if trace is None or not _metrics.enabled():
            return
        trace.spans.append(Span(
            name=name, t0=float(t0), t1=float(t1),
            depth=getattr(self._tl, "depth", 0), attrs=attrs,
        ))

    # -------------------------------------------- cross-thread serving API
    def start_query(self, **attrs) -> Optional[QueryTrace]:
        """Allocate an open ``QueryTrace`` WITHOUT binding it to the calling
        thread — the first half of the cross-thread lifecycle (a serving
        loop starts the trace where the batch is formed and binds it on the
        worker that executes it, via ``use``).  Returns ``None`` when
        observability is disabled; every other API accepts that ``None``."""
        if not _metrics.enabled():
            return None
        with self._lock:
            tid = self._next_id
            self._next_id += 1
        return QueryTrace(trace_id=tid, t0=time.perf_counter(), attrs=attrs)

    def use(self, trace: Optional[QueryTrace]):
        """Context manager binding ``trace`` as the calling thread's current
        trace: ``span``/``span_at`` (and everything the engine records under
        an existing trace) land on it.  A shared no-op for ``trace=None``."""
        if trace is None:
            return _NULL_CTX
        return _UseCtx(self, trace)

    def finish_query(self, trace: Optional[QueryTrace]) -> None:
        """Close an explicitly started trace and append it to the shared
        ring.  Callable from any thread: the trace is unbound only where it
        is actually current, so finishing on a worker thread never leaves
        the starting thread's thread-local pointing at a dead trace."""
        if trace is None:
            return
        self._finish(trace)

    def _start(self, attrs: dict) -> QueryTrace:
        with self._lock:
            tid = self._next_id
            self._next_id += 1
        trace = QueryTrace(trace_id=tid, t0=time.perf_counter(), attrs=attrs)
        self._tl.current = trace
        self._tl.depth = 0
        return trace

    def _finish(self, trace: QueryTrace) -> None:
        trace.t1 = time.perf_counter()
        # unbind only if current HERE: a trace finished on thread B must not
        # clobber thread A's binding (the pre-serving code unconditionally
        # cleared the finisher's slot, which dangled cross-thread traces)
        if getattr(self._tl, "current", None) is trace:
            self._tl.current = None
        with self._lock:
            self._ring.append(trace)

    # --------------------------------------------------------------- reading
    def traces(self) -> list:
        with self._lock:
            return list(self._ring)

    def last(self) -> Optional[QueryTrace]:
        with self._lock:
            return self._ring[-1] if self._ring else None

    def clear(self) -> None:
        with self._lock:
            self._ring.clear()

    def export_chrome(self, path: Optional[str] = None) -> dict:
        """The ring as Chrome trace-event JSON (complete ``"X"`` events;
        loads in chrome://tracing and ui.perfetto.dev).  Each trace renders
        as one ``tid`` row: the ``query`` root plus its phase spans."""
        events = []
        for tr in self.traces():
            base = {"pid": 0, "tid": tr.trace_id, "ph": "X"}
            events.append({
                **base, "name": "query",
                "ts": tr.t0 * 1e6, "dur": max(tr.t1 - tr.t0, 0.0) * 1e6,
                "args": {k: str(v) for k, v in tr.attrs.items()},
            })
            for s in tr.spans:
                events.append({
                    **base, "name": s.name,
                    "ts": s.t0 * 1e6, "dur": max(s.t1 - s.t0, 0.0) * 1e6,
                    "args": {k: str(v) for k, v in s.attrs.items()},
                })
        doc = {"traceEvents": events, "displayTimeUnit": "ms"}
        if path is not None:
            with open(path, "w") as f:
                json.dump(doc, f, indent=2)
        return doc


_TRACER = Tracer()


def get_tracer() -> Tracer:
    return _TRACER


def query(**attrs):
    return _TRACER.query(**attrs)


def span(name: str, **attrs):
    return _TRACER.span(name, **attrs)


def span_at(name: str, t0: float, t1: float, **attrs) -> None:
    _TRACER.span_at(name, t0, t1, **attrs)


def start_query(**attrs) -> Optional[QueryTrace]:
    return _TRACER.start_query(**attrs)


def use(trace: Optional[QueryTrace]):
    return _TRACER.use(trace)


def finish_query(trace: Optional[QueryTrace]) -> None:
    _TRACER.finish_query(trace)


def current_trace() -> Optional[QueryTrace]:
    return getattr(_TRACER._tl, "current", None)


def fence(x):
    """``jax.block_until_ready`` on ``x``'s leaves when a trace is live, so
    the enclosing span's wall time includes device completion; identity
    (and zero extra syncs) otherwise."""
    if _metrics.enabled() and current_trace() is not None:
        import jax

        jax.block_until_ready(x)
    return x
