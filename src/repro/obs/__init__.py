"""repro.obs — runtime telemetry for the whole search stack.

Three cooperating pieces, all behind one process-wide enable flag
(``repro.obs.metrics.set_enabled`` / the ``REPRO_OBS=1`` environment
variable).  Disabled is the default and costs one boolean check per
instrumentation site: no registry mutation, no span objects, no extra
device synchronization.

``obs.metrics``
    A process-wide, thread-safe ``MetricsRegistry`` of labeled counters,
    gauges, and log2-bucketed histograms with a deterministic
    ``snapshot()``, JSON dump, and Prometheus-style text exposition.

``obs.trace``
    A span tracer producing per-query ``QueryTrace`` records, kept in a
    bounded ring buffer and exportable as Chrome/Perfetto trace JSON
    (``chrome://tracing`` / https://ui.perfetto.dev).

``obs.meters``
    Bytes-moved and collective accounting: the demand-bytes model of the
    fused keep-mask scan, the routed/broadcast wire-byte models (the single
    source of truth the benchmarks consume), and the jaxpr-walking
    ``collective_counts`` meter recorded per executor at compile time.
    Imported on demand (``from repro.obs import meters``): it pulls in the
    kernel oracles, which the always-imported registry/tracer must not.

Metric naming scheme
--------------------
Every metric is ``repro_<subsystem>_<noun>[_<unit>]`` with counters
suffixed ``_total``; label keys are lowercase identifiers.  The registered
families:

    repro_search_batches_total{executor}        search() calls per executor
    repro_search_queries_total{executor}        queries per executor
    repro_search_latency_seconds{executor}      per-batch wall time (histogram)
    repro_pruning_values_total{executor,kind}   kind=total|computed|avoided —
                                                the SearchStats work account,
                                                mirrored into the registry
    repro_cache_events_total{cache,event}       cache=exec|placement|routed|
                                                mirror, event=hit|miss
    repro_store_mutations_total{op}             op=insert|delete|flush|repack
    repro_store_rows_mutated_total{op}          rows touched per op
    repro_store_live_vectors                    gauge
    repro_store_head_fill                       gauge, write-head occupancy 0..1
    repro_store_meta_staleness                  gauge, mutations since last
                                                dim_means/dim_vars refresh
                                                over live rows
    repro_store_device_uploads_total            full sealed-tile re-uploads
    repro_mirror_builds_total{dtype}            mirror (re)quantize events
    repro_routing_demand                        histogram of per-batch max
                                                (src, dst) demand — the log2
                                                buckets ARE the demand octaves
    repro_routing_spill_rounds_total{rounds}    rounds=1|2 exchange rounds
    repro_routing_slot_occupancy                gauge, real / padded send slots
    repro_collectives_issued_total{executor,primitive}
                                                collectives issued at runtime,
                                                derived from the executed plan
    repro_collectives_per_call{executor,primitive}
                                                gauge, counted in the jaxpr at
                                                compile time (obs.meters)
    repro_device_bytes_total{executor,component,dtype}
                                                component=scan|rerank|
                                                all_to_all|all_gather|
                                                broadcast
    repro_rag_retrievals_total{executor}        serve-layer retrieval queries
    repro_serve_batches_total{bucket,executor,shed}
                                                executed serving batches per
                                                pow2 shape bucket
    repro_serve_queries_total                   queries completed by the server
    repro_serve_rejected_total                  submits refused (queue full)
    repro_serve_shed_total{action}              overload sheds (action=nprobe)
    repro_serve_deadline_expired_total{where}   where=queue|result
    repro_serve_maintenance_total{event}        event=swap|discard — version-
                                                fenced background repack
                                                adoptions vs stale clones
    repro_serve_queue_depth                     gauge, admission queue depth
    repro_serve_jit_compiles                    gauge, process-wide XLA
                                                compiles observed (the
                                                zero-recompile-after-warmup
                                                gate)
    repro_serve_batch_fill{bucket}              histogram, real / padded lanes
    repro_serve_queue_wait_seconds              histogram, submit -> execution
    repro_serve_latency_seconds                 histogram, submit -> result

(``repro_store_mutations_total`` also records ``op=adopt`` — a background
repack swapped in by ``MutablePDXStore.adopt``.)

Span taxonomy
-------------
One ``QueryTrace`` per ``VectorSearchEngine.search`` call (the root covers
the whole call); phases nest under it:

    plan    planner dispatch (``core.plan.plan_search``)
    route   IVF bucket ranking + exchange planning (adaptive per-query
            routing, or ``route_batch``/``plan_routing``/send-buffer packing
            on the routed path)
    scan    executor body — device work fenced by ``block_until_ready``
            (every executor returns host arrays, so the span wall includes
            device completion)
    rerank  exact f32 re-rank of reduced-precision candidates; on sharded
            quantized paths it runs fused on-shard inside the scan and is
            recorded as a zero-width annotation span (``fused="on-shard"``)
    merge   write-head merge + final top-k assembly

Served queries (``repro.serve.vector``) cross threads: the trace is opened
with ``trace.start_query`` where the batch forms, bound on the executor
thread with ``trace.use``, and prefixed with a ``queue`` span
(``trace.span_at``) covering the admission wait — the per-thread current
trace plus the shared finished-trace ring make concurrent worker traces
land in one place.

``SearchResult.trace`` carries the ``QueryTrace``;
``VectorSearchEngine.metrics()`` / ``dump_trace(path)`` surface the registry
snapshot and the Perfetto export.
"""
from . import metrics, trace

__all__ = ["metrics", "trace", "meters"]
