"""Bytes-moved and collective accounting — the single source of truth the
executors record at runtime and the benchmarks consume offline.

Three families:

* **Keep-mask demand model** (``fused_tile_counts`` /
  ``fused_demand_bytes``): replays the fused megakernel's exact per-d-tile
  ADSampling arithmetic (``kernels.ref.pdx_prune_scan_multi_ref``) and
  returns, per tile, how many lanes and partitions were still alive when
  the tile was reached.  Lanes × tile width is the ``SearchStats``
  ``values_computed`` account; partitions × tile width × capacity × mirror
  byte width is the demand-bytes model ``benchmarks/bench_kernels.py``
  gates on (the dtype factor is realized in HBM today, the pruning factor
  once tile fetches hoist behind the mask — see the kernel design notes).

* **Wire models** (``routed_batch_bytes`` / ``broadcast_batch_bytes``):
  per-batch byte totals of the routed all-to-all / packed all-gather and
  the mirrored-broadcast baseline, derived from the executed
  ``RoutingPlan`` — ``dist.routing`` records them into the registry and
  ``benchmarks/bench_routing.py`` reports the same numbers.

* **Collective meters**: ``collective_counts`` walks a traced jaxpr and
  counts collective primitives (lifted here from ``dist.pdx_sharded``,
  which re-exports it for compatibility);
  ``record_compile_collectives`` runs it once per (executor, shape key)
  and publishes ``repro_collectives_per_call`` gauges, while
  ``count_issued`` accumulates ``repro_collectives_issued_total`` from the
  executed plan — the parity of the two is a CI invariant.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from . import metrics
from ..kernels.ref import dequantize_ref

__all__ = [
    "collective_counts",
    "record_compile_collectives",
    "count_issued",
    "tile_widths",
    "fused_tile_counts",
    "fused_demand_bytes",
    "routed_batch_bytes",
    "broadcast_batch_bytes",
    "record_device_bytes",
    "cache_upload_wait",
]


# ------------------------------------------------------------ collectives
_COLLECTIVES = (
    "all_gather", "psum", "all_to_all", "ppermute", "reduce_scatter",
)


def collective_counts(fn, *args, **kwargs) -> dict[str, int]:
    """Trace ``fn(*args, **kwargs)`` and count collective primitives in the
    jaxpr (recursing into sub-jaxprs of pjit/shard_map/scan/...).  Used by
    tests and benchmarks to assert e.g. the batched path issues exactly one
    all-gather per batch, independent of batch size."""
    counts: dict[str, int] = {}

    def walk(jaxpr):
        for eqn in jaxpr.eqns:
            name = eqn.primitive.name
            if name in _COLLECTIVES:
                counts[name] = counts.get(name, 0) + 1
            for v in eqn.params.values():
                for sub in _subjaxprs(v):
                    walk(sub)

    def _subjaxprs(v):
        if hasattr(v, "eqns"):            # Jaxpr
            yield v
        elif hasattr(v, "jaxpr"):         # ClosedJaxpr
            yield v.jaxpr
        elif isinstance(v, (tuple, list)):
            for item in v:
                yield from _subjaxprs(item)

    walk(jax.make_jaxpr(fn)(*args, **kwargs).jaxpr)
    return counts


_COMPILE_METERED: set = set()


def record_compile_collectives(
    executor: str, key: tuple, fn, *args
) -> Optional[dict]:
    """Count ``fn``'s collectives once per (executor, shape ``key``) and
    publish them as ``repro_collectives_per_call`` gauges — the
    compile-time side of the collective invariant (``count_issued`` is the
    runtime side).  The abstract trace costs once per new executor shape,
    exactly when a compile happens anyway; no-op when disabled or already
    metered."""
    if not metrics.enabled():
        return None
    full = (executor,) + tuple(key)
    if full in _COMPILE_METERED:
        return None
    counts = collective_counts(fn, *args)
    for prim, n in counts.items():
        metrics.gauge(
            "repro_collectives_per_call", n, executor=executor,
            primitive=prim,
        )
    _COMPILE_METERED.add(full)
    return counts


def count_issued(executor: str, **primitives: int) -> None:
    """Accumulate ``repro_collectives_issued_total`` counters from the
    executed plan (e.g. ``count_issued("routed_bucket", all_to_all=rounds,
    all_gather=1)`` per batch)."""
    if not metrics.enabled():
        return
    for prim, n in primitives.items():
        metrics.counter(
            "repro_collectives_issued_total", float(n), executor=executor,
            primitive=prim,
        )


# ------------------------------------------------- keep-mask demand model
def tile_widths(D: int, d_tile: int = 64) -> np.ndarray:
    """Widths of the megakernel's d-tiles over a D-dimensional store."""
    edges = np.arange(0, D, d_tile)
    return np.minimum(edges + d_tile, D) - edges


@functools.partial(
    jax.jit, static_argnames=("d_tile", "eps0", "packed", "dim")
)
def _tile_walk(T, ids, q, thr, scale, offset, d_tile, eps0,
               packed=False, dim=None):
    """Replay of ``kernels.ref.pdx_prune_scan_multi_ref`` that returns the
    per-tile survivor counts instead of the distances: for each d-tile,
    how many lanes and how many partitions were alive when it was reached
    (lanes with ``ids < 0`` start dead; the hypothesis test runs once per
    tile on dequantized operands, so per-dtype rounding differences in the
    keep-mask are accounted).  ``packed``/``dim`` take a packed int4 mirror
    (the walk runs over the unpacked logical dimensions)."""
    T32 = dequantize_ref(T, scale, offset, dim_axis=1,
                         packed=packed, dim=dim)
    P, D, V = T32.shape
    q32 = q.astype(jnp.float32)
    acc = jnp.zeros((P, V), jnp.float32)
    alive = (ids >= 0).astype(jnp.float32)
    lanes, parts = [], []
    d_seen = 0
    while d_seen < D:
        hi = min(d_seen + d_tile, D)
        lanes.append(jnp.sum(alive))
        parts.append(jnp.sum(jnp.any(alive > 0, axis=1)))
        blk = T32[:, d_seen:hi, :] - q32[None, d_seen:hi, None]
        contrib = jnp.sum(blk * blk, axis=1)
        acc = acc + contrib * alive
        d_seen = hi
        d = jnp.float32(d_seen)
        bound = thr * (1.0 + eps0 / jnp.sqrt(d)) ** 2
        keep = acc * (D / d) <= bound
        alive = alive * keep.astype(jnp.float32)
    return jnp.stack(lanes), jnp.stack(parts)


def fused_tile_counts(
    mdata, ids, qt, thr, scale=None, offset=None, *,
    eps0: float, d_tile: int = 64, packed: bool = False,
    dim: Optional[int] = None,
) -> tuple[np.ndarray, np.ndarray]:
    """Per-d-tile (lanes alive, partitions alive) entering each tile of a
    fused keep-mask scan of the (P, D, V) mirror tiles ``mdata``.
    ``scale``/``offset`` are the mirror's dequantization vectors (pass
    ``None`` for f32/bf16 mirrors); ``packed``/``dim`` mark a packed int4
    mirror whose logical D is ``dim``.  Returns two (n_tiles,) float arrays.
    """
    D = dim if packed else mdata.shape[1]
    if scale is None:
        scale = jnp.ones((D,), jnp.float32)
    if offset is None:
        offset = jnp.zeros((D,), jnp.float32)
    lanes, parts = _tile_walk(
        mdata, jnp.asarray(ids), jnp.asarray(qt, jnp.float32),
        jnp.float32(thr), scale, offset, min(d_tile, D), float(eps0),
        packed=packed, dim=dim,
    )
    return np.asarray(lanes), np.asarray(parts)


def fused_demand_bytes(
    mirror, ids, qt, thr, *, p0: int, eps0: float, d_tile: int = 64
) -> float:
    """Demand bytes of one fused-scan query: the START partition streams
    once at f32 (the exact threshold seed), then a partition's d-tile is
    needed only while any of its lanes is alive, at mirror width.
    ``mirror`` is a ``core.layout.DeviceMirror``; ``p0`` the START
    partition (masked out of the pruned scan, exactly as the executor does).
    """
    C = mirror.data.shape[2]
    D = mirror.dim  # logical D (packed int4 halves the stored axis)
    ids_scan = jnp.asarray(ids).at[p0].set(-1)
    _, parts = fused_tile_counts(
        mirror.data, ids_scan, qt, thr, mirror.scale, mirror.offset,
        eps0=eps0, d_tile=d_tile, packed=mirror.packed, dim=mirror.dim,
    )
    w = tile_widths(D, d_tile)
    return float(D * C * 4 + (parts * w).sum() * C * mirror.bytes_per_value)


# --------------------------------------------------------------- wire models
def routed_batch_bytes(
    rp, *, n_shards: int, D: int, C: int, num_slots: int, nprobe: int,
    k: int, bytes_per_value: float = 4.0, rerank_mult: int = 4,
    quantized: bool = False,
) -> dict[str, float]:
    """Per-batch byte totals of one routed-bucket search under
    ``RoutingPlan`` ``rp``: the padded all-to-all payload (queries ‖
    bitcast bucket ids, f32 wire), the packed candidate all-gather, each
    shard's one mirror-slice scan, and — when quantized — the f32 master
    columns the on-shard re-rank gathers per delivered query."""
    n_dests = float((np.asarray(rp.dest_shard) >= 0).sum())
    return {
        "scan": float(num_slots * D * C * bytes_per_value),
        "rerank": (n_dests * rerank_mult * k * D * 4.0) if quantized else 0.0,
        "all_to_all": float(n_shards * n_shards * rp.budget * (D + nprobe) * 4),
        "all_gather": float(n_shards * (n_shards * rp.budget) * 2 * k * 4),
    }


def broadcast_batch_bytes(
    *, n_shards: int, B: int, D: int, k: int
) -> dict[str, float]:
    """Per-batch wire bytes of the mirrored-broadcast baseline: every query
    replicates to every shard, one packed (B, 2k) all-gather merges."""
    return {
        "all_to_all": 0.0,
        "broadcast": float(n_shards * B * D * 4),
        "all_gather": float(n_shards * B * 2 * k * 4),
    }


def cache_upload_wait(wait_us: float, total_us: float) -> None:
    """Record one async bucket-cache upload completion: the
    ``repro_cache_upload_wait_us`` histogram holds how long the host
    actually blocked on the in-flight H2D copies at ``BucketCache.wait``,
    and the ``repro_cache_upload_overlap_ratio`` gauge the fraction of the
    issue->complete window hidden behind compute (1.0 = the copy finished
    entirely under the overlapped scan, 0.0 = fully synchronous)."""
    if not metrics.enabled():
        return
    metrics.observe("repro_cache_upload_wait_us", float(wait_us))
    if total_us > 0:
        metrics.gauge(
            "repro_cache_upload_overlap_ratio",
            max(0.0, 1.0 - float(wait_us) / float(total_us)),
        )


def record_device_bytes(executor: str, dtype: str, components: dict) -> None:
    """Accumulate a components dict (as returned by the wire models) into
    ``repro_device_bytes_total{executor, component, dtype}`` counters."""
    if not metrics.enabled():
        return
    for comp, nbytes in components.items():
        if nbytes:
            metrics.counter(
                "repro_device_bytes_total", float(nbytes),
                executor=executor, component=comp, dtype=dtype,
            )
