"""whisper-small [audio] — enc-dec, 12L encoder + 12L decoder, d_model=768
12H d_ff=3072 vocab=51865 [arXiv:2212.04356; unverified].  The conv frontend
is a STUB: input_specs() provides precomputed frame embeddings
(B, 1500, d_model).  FFNs use the framework-uniform GLU form (see DESIGN.md:
substitutes Whisper's plain-GELU MLP; dims preserved)."""
from .base import ArchConfig, register


@register
def config() -> ArchConfig:
    return ArchConfig(
        name="whisper-small",
        family="audio",
        n_layers=12,
        d_model=768,
        n_heads=12,
        n_kv_heads=12,
        head_dim=64,
        d_ff=3072,
        vocab=51865,
        act="gelu",
        encdec=True,
        n_enc_layers=12,
        enc_seq=1500,
    )
