"""jamba-v0.1-52b [hybrid] — 32L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=65536, Mamba:attn 7:1 interleave (attn at position 4 of each 8-layer
period), MoE 16e top-2 every other layer [arXiv:2403.19887; hf].
Mamba sub-blocks use the Mamba2/SSD matmul form (same asymptotics as the
paper's Mamba-1, MXU-friendly; see DESIGN.md hardware-adaptation notes)."""
from .base import ArchConfig, register


@register
def config() -> ArchConfig:
    return ArchConfig(
        name="jamba-v0.1-52b",
        family="hybrid",
        n_layers=32,
        d_model=4096,
        n_heads=32,
        n_kv_heads=8,
        head_dim=128,
        d_ff=14336,
        vocab=65536,
        act="silu",
        moe=True,
        n_experts=16,
        top_k=2,
        d_ff_expert=14336,
        moe_period=2,
        hybrid_period=8,
        attn_positions=(4,),
        ssm=True,
        ssm_state=16,
        ssm_head_dim=64,
        ssm_expand=2,
        ssm_chunk=256,
        conv_kernel=4,
        subquadratic=True,
    )
