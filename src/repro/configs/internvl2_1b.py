"""internvl2-1b [vlm] — InternViT frontend (stub) + InternLM2 LM backbone.
24L d_model=896 14H (GQA kv=2) d_ff=4864 vocab=151655 [arXiv:2404.16821; hf].
The vision frontend is a STUB: input_specs() provides precomputed patch
embeddings (B, n_patches, d_model) that are prepended to the token stream.
"""
from .base import ArchConfig, register


@register
def config() -> ArchConfig:
    return ArchConfig(
        name="internvl2-1b",
        family="vlm",
        n_layers=24,
        d_model=896,
        n_heads=14,
        n_kv_heads=2,
        head_dim=64,
        d_ff=4864,
        vocab=151655,
        act="silu",
        rope_theta=1_000_000.0,
        tie_embeddings=True,
        vlm=True,
        n_patches=256,
    )
