"""Architecture + run configuration system.

``ArchConfig`` is the single source of truth for a model; every assigned
architecture file in this package instantiates one with the exact published
dimensions and registers it.  ``reduced()`` derives the CPU-smoke-test config
(same family/topology, tiny dims).  ``SHAPES`` defines the assigned
input-shape grid (seq_len x global_batch and which step each cell lowers).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

__all__ = ["ArchConfig", "ShapeSpec", "SHAPES", "register", "get_config", "list_configs"]


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                 # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0           # 0 -> d_model // n_heads
    act: str = "silu"           # glu gate activation: silu (SwiGLU) | gelu (GeGLU)
    qkv_bias: bool = False
    tie_embeddings: bool = False
    rope_theta: float = 10_000.0
    rms_eps: float = 1e-5
    # --- MoE ---
    moe: bool = False
    n_experts: int = 0
    top_k: int = 0
    n_shared: int = 0
    d_ff_expert: int = 0
    n_dense_layers: int = 0      # leading dense (non-MoE) layers
    d_ff_dense: int = 0          # ff width of those dense layers (0 -> d_ff)
    moe_period: int = 1          # MoE every `period`-th layer within the stack
    capacity_factor: float = 1.25
    router_aux_free: bool = False
    # --- MLA (deepseek-v3) ---
    mla: bool = False
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_head_dim: int = 0
    qk_rope_head_dim: int = 0
    v_head_dim: int = 0
    # --- SSM (mamba2 / jamba) ---
    ssm: bool = False
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_groups: int = 1
    ssm_chunk: int = 256
    conv_kernel: int = 4
    # --- hybrid (jamba): layer pattern within a period ---
    hybrid_period: int = 0
    attn_positions: tuple[int, ...] = ()
    # --- encoder-decoder (whisper) ---
    encdec: bool = False
    n_enc_layers: int = 0
    enc_seq: int = 1500          # stubbed conv-frontend output frames
    # --- VLM (internvl2) ---
    vlm: bool = False
    n_patches: int = 256         # stubbed vision-frontend patch embeddings
    # --- attention scaling for long ctx ---
    subquadratic: bool = False   # True for ssm/hybrid: long_500k runnable
    # --- misc ---
    scale_embed: bool = False    # gemma-style sqrt(d_model) embedding scale

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    def reduced(self) -> "ArchConfig":
        """Tiny same-topology config for CPU smoke tests."""
        return dataclasses.replace(
            self,
            name=self.name + "-smoke",
            n_layers=min(self.n_layers, 4 if not self.hybrid_period else self.hybrid_period),
            d_model=64,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads < self.n_heads else 4,
            head_dim=16,
            d_ff=128,
            vocab=256,
            n_experts=min(self.n_experts, 8),
            top_k=min(self.top_k, 2),
            n_shared=min(self.n_shared, 1),
            d_ff_expert=32 if self.moe else 0,
            n_dense_layers=min(self.n_dense_layers, 1),
            d_ff_dense=128 if self.n_dense_layers else 0,
            q_lora_rank=32 if self.q_lora_rank else 0,
            kv_lora_rank=32 if self.mla else 0,
            qk_nope_head_dim=16 if self.mla else 0,
            qk_rope_head_dim=8 if self.mla else 0,
            v_head_dim=16 if self.mla else 0,
            ssm_state=16 if self.ssm else 0,
            ssm_head_dim=16 if self.ssm else 64,
            ssm_chunk=16,
            n_enc_layers=min(self.n_enc_layers, 2),
            enc_seq=32,
            n_patches=8,
        )


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    step: str                    # "train" | "prefill" | "decode"


SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}


_REGISTRY: dict[str, Callable[[], ArchConfig]] = {}


def register(fn: Callable[[], ArchConfig]):
    cfg = fn()
    _REGISTRY[cfg.name] = fn
    return fn


def get_config(name: str) -> ArchConfig:
    if name not in _REGISTRY:
        from . import _load_all  # lazy-import arch modules

        _load_all()
    return _REGISTRY[name]()


def list_configs() -> list[str]:
    from . import _load_all

    _load_all()
    return sorted(_REGISTRY)


def shape_is_applicable(cfg: ArchConfig, shape: str) -> tuple[bool, str]:
    """The assigned-cell applicability rules (documented in DESIGN.md)."""
    if shape == "long_500k" and not cfg.subquadratic:
        return False, "pure full-attention arch: 500k decode needs sub-quadratic attention"
    return True, ""
