"""deepseek-v3-671b [moe] — 61L d_model=7168 128H MLA d_ff_expert=2048
vocab=129280, MoE 1 shared + 256 routed top-8, aux-loss-free routing bias,
3 leading dense layers (d_ff 18432) [arXiv:2412.19437; hf].
MTP head omitted (orthogonal to this study; see DESIGN.md)."""
from .base import ArchConfig, register


@register
def config() -> ArchConfig:
    return ArchConfig(
        name="deepseek-v3-671b",
        family="moe",
        n_layers=61,
        d_model=7168,
        n_heads=128,
        n_kv_heads=128,
        d_ff=2048,
        vocab=129280,
        act="silu",
        rope_theta=10_000.0,
        moe=True,
        n_experts=256,
        top_k=8,
        n_shared=1,
        d_ff_expert=2048,
        n_dense_layers=3,
        d_ff_dense=18432,
        router_aux_free=True,
        mla=True,
        q_lora_rank=1536,
        kv_lora_rank=512,
        qk_nope_head_dim=128,
        qk_rope_head_dim=64,
        v_head_dim=128,
    )
