"""Assigned-architecture registry.  ``get_config(name)`` / ``list_configs()``.

Each architecture lives in its own module with the exact published dims
[source tags in the module docstrings]; importing this package registers all.
"""
from .base import (  # noqa: F401
    SHAPES,
    ArchConfig,
    ShapeSpec,
    get_config,
    list_configs,
    register,
    shape_is_applicable,
)

_LOADED = False


def _load_all():
    global _LOADED
    if _LOADED:
        return
    _LOADED = True
    from . import (  # noqa: F401
        deepseek_moe_16b,
        deepseek_v3_671b,
        gemma_2b,
        granite_3_8b,
        internvl2_1b,
        jamba_v0_1_52b,
        llama3_2_3b,
        mamba2_370m,
        qwen2_72b,
        whisper_small,
    )
