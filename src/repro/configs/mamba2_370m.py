"""mamba2-370m [ssm] — 48L d_model=1024 attn-free vocab=50280,
ssm_state=128, SSD (state-space duality) [arXiv:2405.21060; unverified]."""
from .base import ArchConfig, register


@register
def config() -> ArchConfig:
    return ArchConfig(
        name="mamba2-370m",
        family="ssm",
        n_layers=48,
        d_model=1024,
        n_heads=0,
        n_kv_heads=0,
        d_ff=0,
        vocab=50280,
        tie_embeddings=True,
        ssm=True,
        ssm_state=128,
        ssm_head_dim=64,
        ssm_expand=2,
        ssm_groups=1,
        ssm_chunk=256,
        conv_kernel=4,
        subquadratic=True,
    )
