"""Input specs per (architecture x shape): ShapeDtypeStruct stand-ins for the
dry-run (no allocation) and concrete tiny batches for smoke tests.

Modality frontends are stubs per the assignment: [vlm] provides precomputed
patch embeddings, [audio] provides precomputed conv-frontend frames.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ArchConfig, ShapeSpec

__all__ = ["input_specs", "make_concrete_batch", "text_len"]


def text_len(cfg: ArchConfig, seq_len: int) -> int:
    """Token-stream length so that the model's total sequence == seq_len."""
    if cfg.vlm:
        return seq_len - cfg.n_patches
    return seq_len


def input_specs(
    cfg: ArchConfig,
    shape: ShapeSpec,
    *,
    dtype=jnp.bfloat16,
) -> dict[str, Any]:
    """ShapeDtypeStructs for the *batch* argument of the given step."""
    B = shape.global_batch
    if shape.step == "train":
        S = text_len(cfg, shape.seq_len)
        specs = {
            "tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
            "labels": jax.ShapeDtypeStruct((B, S), jnp.int32),
        }
    elif shape.step == "prefill":
        S = text_len(cfg, shape.seq_len)
        specs = {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32)}
    else:  # decode: one new token; the seq_len lives in the KV cache
        specs = {"tokens": jax.ShapeDtypeStruct((B, 1), jnp.int32)}
    if cfg.vlm and shape.step != "decode":
        specs["vision_embeds"] = jax.ShapeDtypeStruct(
            (B, cfg.n_patches, cfg.d_model), dtype
        )
    if cfg.encdec and shape.step != "decode":
        specs["enc_frames"] = jax.ShapeDtypeStruct(
            (B, cfg.enc_seq, cfg.d_model), dtype
        )
    return specs


def make_concrete_batch(
    cfg: ArchConfig, seq_len: int, batch: int, step: str, seed: int = 0,
    dtype=jnp.float32,
) -> dict[str, jax.Array]:
    """Tiny concrete batch for CPU smoke tests."""
    rng = np.random.default_rng(seed)
    S = text_len(cfg, seq_len)
    out: dict[str, jax.Array] = {}
    if step == "decode":
        out["tokens"] = jnp.asarray(
            rng.integers(0, cfg.vocab, (batch, 1)), jnp.int32
        )
        return out
    out["tokens"] = jnp.asarray(rng.integers(0, cfg.vocab, (batch, S)), jnp.int32)
    if step == "train":
        out["labels"] = jnp.asarray(
            rng.integers(0, cfg.vocab, (batch, S)), jnp.int32
        )
    if cfg.vlm:
        out["vision_embeds"] = jnp.asarray(
            rng.standard_normal((batch, cfg.n_patches, cfg.d_model)), dtype
        )
    if cfg.encdec:
        out["enc_frames"] = jnp.asarray(
            rng.standard_normal((batch, cfg.enc_seq, cfg.d_model)), dtype
        )
    return out
