"""Cost extraction for the roofline analysis.

XLA's HloCostAnalysis visits while-loop bodies ONCE — with scan-over-layers
models that undercounts FLOPs by ~n_layers.  Two fixes, both exact w.r.t.
loop structure:

* ``jaxpr_cost``  — walks the step function's jaxpr, counting dot FLOPs and
  operand/result bytes, multiplying scan bodies by their trip count
  (recursing through pjit/remat/cond/while).  This is the corrected
  HLO_FLOPs used in EXPERIMENTS.md §Roofline (XLA barely changes dot counts;
  remat recompute appears explicitly in the differentiated jaxpr, so the
  "useful-compute ratio" catches it as intended).

* ``collective_bytes_hlo`` — parses the *partitioned* HLO text, builds the
  computation call graph, extracts while trip counts from their condition
  computations, and multiplies collective payload bytes accordingly (an FSDP
  all-gather inside the layer scan counts n_layers times).
"""
from __future__ import annotations

import re
from collections import defaultdict
from typing import Any

import jax
import numpy as np

__all__ = ["jaxpr_cost", "collective_bytes_hlo"]


# ==========================================================================
# jaxpr walking
# ==========================================================================
def _aval_bytes(aval) -> float:
    try:
        return float(np.prod(aval.shape) * aval.dtype.itemsize)
    except Exception:
        return 0.0


def _dot_flops(eqn) -> float:
    (contract, batch) = eqn.params["dimension_numbers"]
    lhs, rhs = eqn.invars[0].aval, eqn.invars[1].aval
    out = eqn.outvars[0].aval
    k = 1.0
    for d in contract[0]:
        k *= lhs.shape[d]
    return 2.0 * float(np.prod(out.shape)) * k


def _conv_flops(eqn) -> float:
    out = eqn.outvars[0].aval
    rhs = eqn.invars[1].aval
    return 2.0 * float(np.prod(out.shape)) * float(np.prod(rhs.shape[1:]))


_ELEMENTWISE = {
    "add", "sub", "mul", "div", "max", "min", "exp", "log", "tanh",
    "logistic", "rsqrt", "sqrt", "abs", "neg", "sign", "floor", "pow",
    "integer_pow", "select_n", "and", "or", "not", "xor", "erf",
    "cos", "sin",
}


def jaxpr_cost(jaxpr) -> dict[str, float]:
    """closed jaxpr -> {'flops', 'dot_flops', 'ew_flops', 'bytes'} (global)."""

    def walk(jx, mult: float) -> dict[str, float]:
        acc = defaultdict(float)
        for eqn in jx.eqns:
            prim = eqn.primitive.name
            if prim == "dot_general":
                acc["dot_flops"] += mult * _dot_flops(eqn)
                acc["bytes"] += mult * (
                    sum(_aval_bytes(v.aval) for v in eqn.invars)
                    + sum(_aval_bytes(v.aval) for v in eqn.outvars)
                )
            elif prim == "conv_general_dilated":
                acc["dot_flops"] += mult * _conv_flops(eqn)
            elif prim == "scan":
                inner = walk(eqn.params["jaxpr"].jaxpr, mult * eqn.params["length"])
                for k, v in inner.items():
                    acc[k] += v
            elif prim == "while":
                # trip count unknowable in general; bound via cond constants
                inner = walk(eqn.params["body_jaxpr"].jaxpr, mult)
                for k, v in inner.items():
                    acc[k] += v
                acc["unbounded_while"] += 1
            elif prim == "cond":
                branches = [walk(b.jaxpr, mult) for b in eqn.params["branches"]]
                for k in set().union(*[set(b) for b in branches]):
                    acc[k] += max(b.get(k, 0.0) for b in branches)
            elif prim == "shard_map":
                # body shapes are per-shard: scale to global by the number
                # of participating devices
                mesh = eqn.params.get("mesh")
                manual = eqn.params.get("manual_axes", ())
                ndev = 1.0
                if mesh is not None:
                    for a in manual:
                        try:
                            ndev *= mesh.shape[a]
                        except Exception:
                            pass
                sub = eqn.params.get("jaxpr")
                if sub is not None:
                    inner = walk(getattr(sub, "jaxpr", sub), mult * ndev)
                    for k, v in inner.items():
                        acc[k] += v
            elif prim in ("pjit", "jit", "closed_call", "core_call",
                          "remat_call", "remat", "remat2", "custom_jvp_call",
                          "custom_vjp_call", "custom_vjp_call_jaxpr",
                          "checkpoint"):
                sub = eqn.params.get("jaxpr") or eqn.params.get("call_jaxpr")
                if sub is not None:
                    inner = walk(getattr(sub, "jaxpr", sub), mult)
                    for k, v in inner.items():
                        acc[k] += v
            elif prim in _ELEMENTWISE:
                acc["ew_flops"] += mult * float(
                    np.prod(eqn.outvars[0].aval.shape)
                )
                acc["bytes"] += mult * (
                    sum(_aval_bytes(v.aval) for v in eqn.invars)
                    + sum(_aval_bytes(v.aval) for v in eqn.outvars)
                )
            elif prim in ("gather", "scatter", "scatter-add", "scatter_add",
                          "dynamic_slice", "dynamic_update_slice", "take",
                          "reduce_sum", "reduce_max", "reduce_min", "argmax",
                          "cumsum", "cumlogsumexp", "sort", "top_k",
                          "broadcast_in_dim", "concatenate", "transpose",
                          "reshape", "convert_element_type", "rev", "pad",
                          "squeeze", "slice", "iota", "select_and_scatter"):
                acc["bytes"] += mult * (
                    sum(_aval_bytes(v.aval) for v in eqn.invars)
                    + sum(_aval_bytes(v.aval) for v in eqn.outvars)
                )
                if prim in ("reduce_sum", "reduce_max", "reduce_min",
                            "cumsum", "argmax"):
                    acc["ew_flops"] += mult * float(
                        np.prod(eqn.invars[0].aval.shape)
                    )
        return acc

    out = walk(jaxpr.jaxpr, 1.0)
    out["flops"] = out.get("dot_flops", 0.0) + out.get("ew_flops", 0.0)
    return dict(out)


# ==========================================================================
# Partitioned-HLO collective accounting with loop multipliers.
# ==========================================================================
_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s+\([^)]*\)\s*->", re.M)
_SHAPE = re.compile(r"(f32|f16|bf16|s32|u32|s8|u8|pred|s64|f64|c64)\[([\d,]*)\]")
_BYTES = {"f32": 4, "f16": 2, "bf16": 2, "s32": 4, "u32": 4, "s8": 1,
          "u8": 1, "pred": 1, "s64": 8, "f64": 8, "c64": 8}
_COLL = re.compile(
    r"=\s+((?:\([^)]*\))|(?:\S+))\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\("
)
_CALLSITE = re.compile(
    r"(?:condition|body|to_apply|calls|branch_computations)="
    r"(?:\{([^}]*)\}|%?([\w.\-]+))"
)
_WHILE = re.compile(r"\bwhile\(.*?condition=%?([\w.\-]+),?\s*body=%?([\w.\-]+)|"
                    r"\bwhile\(.*?body=%?([\w.\-]+),?\s*condition=%?([\w.\-]+)")
_CONST_CMP = re.compile(r"constant\((\d+)\)")


def _split_computations(text: str) -> dict[str, str]:
    """computation name -> body text.  A computation header is a top-level
    line '[ENTRY] %name (args...) -> result {' (args may nest parens)."""
    comps = {}
    cur_name = None
    cur_body: list[str] = []
    for line in text.splitlines():
        stripped = line.strip()
        is_header = (
            not line.startswith(" ")
            and stripped.endswith("{")
            and "->" in stripped
            and (stripped.startswith("%") or stripped.startswith("ENTRY"))
        )
        if is_header:
            if cur_name is not None:
                comps[cur_name] = "\n".join(cur_body)
            tok = stripped.split()[1] if stripped.startswith("ENTRY") else stripped.split()[0]
            cur_name = tok.lstrip("%")
            cur_body = [line]
        elif cur_name is not None:
            cur_body.append(line)
            if stripped == "}":
                comps[cur_name] = "\n".join(cur_body)
                cur_name, cur_body = None, []
    if cur_name is not None:
        comps[cur_name] = "\n".join(cur_body)
    return comps


def _shape_bytes(type_str: str) -> float:
    total = 0.0
    for m in _SHAPE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _BYTES[dt]
    return total


def collective_bytes_hlo(text: str) -> dict[str, Any]:
    """Partitioned HLO -> per-kind collective bytes with while multipliers."""
    comps = _split_computations(text)
    # local collective bytes per computation
    local: dict[str, dict[str, float]] = {}
    counts: dict[str, dict[str, int]] = {}
    for name, body in comps.items():
        d: dict[str, float] = defaultdict(float)
        c: dict[str, int] = defaultdict(int)
        for m in _COLL.finditer(body):
            d[m.group(2)] += _shape_bytes(m.group(1))
            c[m.group(2)] += 1
        local[name] = dict(d)
        counts[name] = dict(c)

    # call graph with multipliers
    trip_re = re.compile(r'known_trip_count[":{\s]+n["\s:]+"?(\d+)')
    edges: dict[str, list[tuple[str, float]]] = defaultdict(list)
    for name, body in comps.items():
        for line in body.splitlines():
            if re.search(r"=\s*(?:\([^=]*\)\s+)?while\(", line) or " while(" in line:
                trip = 1.0
                tm = trip_re.search(line)
                if tm:
                    trip = float(tm.group(1))
                cond_m = re.search(r"condition=%?([\w.\-]+)", line)
                body_m = re.search(r"body=%?([\w.\-]+)", line)
                if tm is None and cond_m and cond_m.group(1) in comps:
                    consts = _CONST_CMP.findall(comps[cond_m.group(1)])
                    if consts:
                        trip = float(max(int(x) for x in consts))
                if cond_m and cond_m.group(1) in comps:
                    edges[name].append((cond_m.group(1), trip))
                if body_m and body_m.group(1) in comps:
                    edges[name].append((body_m.group(1), trip))
            else:
                for cm in _CALLSITE.finditer(line):
                    targets = cm.group(1) or cm.group(2)
                    for t in re.split(r"[,\s]+", targets):
                        t = t.strip().lstrip("%")
                        if t and t in comps:
                            edges[name].append((t, 1.0))

    roots = [n for n in comps if n.startswith("main") or "ENTRY" in comps[n].splitlines()[0]]
    if not roots:
        roots = list(comps)[:1]

    total: dict[str, float] = defaultdict(float)
    total_counts: dict[str, float] = defaultdict(float)

    def dfs(name: str, mult: float, depth: int = 0):
        if depth > 32:
            return
        for kind, b in local.get(name, {}).items():
            total[kind] += mult * b
            total_counts[kind] += mult * counts[name].get(kind, 0)
        for child, m in edges.get(name, []):
            dfs(child, mult * m, depth + 1)

    for r in roots:
        dfs(r, 1.0)
    return {
        "bytes": dict(total),
        "count": {k: int(v) for k, v in total_counts.items()},
        "total": float(sum(total.values())),
    }
