"""Serving driver: batched generation with optional PDX retrieval (RAG).

    PYTHONPATH=src python -m repro.launch.serve --arch gemma-2b --reduced \
        --requests 4 --max-new 8 --rag
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from ..configs import get_config
from ..models.lm import build_model
from ..serve.engine import GenerationEngine
from ..serve.rag import RagPipeline

__all__ = ["main"]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--rag", action="store_true")
    ap.add_argument("--docs", type=int, default=64)
    ap.add_argument("--pruner", default="adsampling")
    args = ap.parse_args()

    import jax

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    cache_len = args.prompt_len * 3 + args.max_new + 8
    eng = GenerationEngine(model=model, params=params, cache_len=cache_len)

    rng = np.random.default_rng(0)
    batch = {
        "tokens": rng.integers(
            0, cfg.vocab, (args.requests, args.prompt_len)
        ).astype(np.int32)
    }
    if cfg.vlm:
        batch["vision_embeds"] = rng.standard_normal(
            (args.requests, cfg.n_patches, cfg.d_model)
        ).astype(np.float32)
    if cfg.encdec:
        batch["enc_frames"] = rng.standard_normal(
            (args.requests, cfg.enc_seq, cfg.d_model)
        ).astype(np.float32)

    if args.rag:
        docs = rng.integers(0, cfg.vocab, (args.docs, args.prompt_len)).astype(
            np.int32
        )
        rag = RagPipeline.build(eng, docs, pruner=args.pruner)
        t0 = time.perf_counter()
        out, doc_ids = rag.answer(batch, max_new_tokens=args.max_new)
        dt = time.perf_counter() - t0
        print(f"[serve] RAG answered {args.requests} reqs in {dt*1e3:.0f}ms; "
              f"retrieved docs {doc_ids[:, 0].tolist()}")
    else:
        t0 = time.perf_counter()
        out = eng.generate(batch, max_new_tokens=args.max_new)
        dt = time.perf_counter() - t0
    tput = args.requests * args.max_new / dt
    print(f"[serve] generated {out.shape} tokens, {tput:.1f} tok/s")
    print(f"[serve] first row: {out[0].tolist()}")


if __name__ == "__main__":
    main()
