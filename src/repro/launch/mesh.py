"""Production mesh construction.  A FUNCTION (not a module constant) so that
importing this module never touches jax device state — the 512-device
environment exists only inside dryrun.py's process.
"""
from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "MESH_SHAPES"]

MESH_SHAPES = {
    "single_pod": ((16, 16), ("data", "model")),
    "multi_pod": ((2, 16, 16), ("pod", "data", "model")),
}


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)
