import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""Dry-run for the paper's own workload at production scale: distributed
PDX similarity search over the 16x16 / 2x16x16 mesh.

Corpus: 100M vectors x 1536 dims (OpenAI-embedding scale, ~614 GB f32 —
1.2 GB/chip block-sharded).  Query batch: 128.  Variants:

  block            — partitions sharded across chips; local scan + local
                     top-k + all-gather(k) merge  (baseline, paper-faithful
                     data parallelism)
  dim              — paper §7's dimension sharding: psum of partial
                     distances (collective-heavy, reads only local dims)
  block_matmul     — beyond-paper: batched queries via the MXU matmul form
  block_matmul_bf16— + bf16 storage (halves the memory term)
  block_matmul_int8— + int8 storage w/ per-partition scales (4x less HBM;
                     dequant fused into the tile read)
  block_pruned     — + ADSampling masked pruning before the merge

Each lowers+compiles and records the same JSON schema as dryrun.py, so the
roofline table treats the paper's workload as a first-class cell.
"""
import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from ..core.topk import topk_init, topk_merge
from .analysis import collective_bytes_hlo, jaxpr_cost
from .mesh import make_production_mesh

N_VECTORS = 100_000_000
DIM = 1536
CAPACITY = 8192
QUERIES = 128
K = 10


def _scan_tiles_batched(data_l, ids_l, Q, k, metric_bf16=False):
    """(P_loc, D, C) x (B, D) -> per-shard TopK per query (matmul form)."""
    B = Q.shape[0]

    def body(state, inp):
        tile, tids = inp
        # int8 storage: dequantize on read (scale folded into the distance;
        # a real index stores per-partition scales — constant here since the
        # dry-run only measures structure)
        if tile.dtype == jnp.int8:
            tile_c = tile.astype(jnp.bfloat16) * jnp.bfloat16(0.02)
        elif metric_bf16:
            tile_c = tile.astype(jnp.bfloat16)
        else:
            tile_c = tile
        Qc = Q.astype(tile_c.dtype)
        cross = jax.lax.dot_general(
            Qc, tile_c, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        qn = jnp.sum(Q.astype(jnp.float32) ** 2, axis=1, keepdims=True)
        xn = jnp.sum(
            tile_c.astype(jnp.float32) ** 2, axis=0, keepdims=True
        )
        d = qn - 2.0 * cross + xn
        state = jax.vmap(topk_merge, (0, 0, None))(state, d, tids)
        return state, None

    init = jax.vmap(lambda _: topk_init(k))(jnp.arange(B))
    state, _ = jax.lax.scan(body, init, (data_l, ids_l))
    return state


def build_pdx_cell(variant: str, mesh, dtype=jnp.float32):
    n_parts = N_VECTORS // CAPACITY  # 12207 -> pad to multiple of 256
    nd = int(np.prod([mesh.shape[a] for a in mesh.axis_names]))
    n_parts = ((n_parts + nd - 1) // nd) * nd
    store_dtype = dtype
    if "bf16" in variant:
        store_dtype = jnp.bfloat16
    elif "int8" in variant:
        store_dtype = jnp.int8
    data = jax.ShapeDtypeStruct((n_parts, DIM, CAPACITY), store_dtype)
    ids = jax.ShapeDtypeStruct((n_parts, CAPACITY), jnp.int32)
    Q = jax.ShapeDtypeStruct((QUERIES, DIM), jnp.float32)
    shard_axes = tuple(mesh.axis_names)  # all axes shard the partition dim

    if variant.startswith("block"):
        pruned = "pruned" in variant
        matmul = "matmul" in variant

        def local(data_l, ids_l, Q_l):
            if matmul:
                st = _scan_tiles_batched(
                    data_l, ids_l, Q_l, K, metric_bf16="bf16" in variant
                )
            else:
                def one_q(q):
                    def body(state, inp):
                        tile, tids = inp
                        diff = tile.astype(jnp.float32) - q[:, None]
                        d = jnp.sum(diff * diff, axis=0)
                        if pruned:
                            # ADSampling-style mask on the first 64 dims
                            part = jnp.sum(diff[:64] * diff[:64], axis=0)
                            keep = part * (DIM / 64.0) <= (
                                topk_merge(state, d, tids).dists[-1]
                                * (1.0 + 2.1 / 8.0) ** 2
                            )
                            d = jnp.where(keep, d, jnp.inf)
                        return topk_merge(state, d, tids), None

                    st, _ = jax.lax.scan(body, topk_init(K), (data_l, ids_l))
                    return st

                st = jax.vmap(one_q)(Q_l)
            all_d = jax.lax.all_gather(st.dists, shard_axes)
            all_i = jax.lax.all_gather(st.ids, shard_axes)
            nrep = all_d.shape[0]
            merged = jax.vmap(
                lambda d, i: topk_merge(topk_init(K), d.reshape(-1), i.reshape(-1)),
                (1, 1),
            )(all_d.reshape(nrep, QUERIES, K), all_i.reshape(nrep, QUERIES, K))
            return merged.dists, merged.ids

        fn = shard_map(
            local, mesh=mesh,
            in_specs=(P(shard_axes), P(shard_axes), P()),
            out_specs=(P(), P()),
            check_rep=False,
        )
        return fn, (data, ids, Q), (
            NamedSharding(mesh, P(shard_axes)),
            NamedSharding(mesh, P(shard_axes)),
            NamedSharding(mesh, P()),
        )

    if variant == "dim":
        # dimensions sharded on 'model'; partitions on remaining axes
        daxes = tuple(a for a in mesh.axis_names if a != "model")

        def local_dim(data_l, ids_l, Q_l):
            def one_q(q_l):
                def body(acc_state, inp):
                    tile, tids = inp
                    diff = tile.astype(jnp.float32) - q_l[:, None]
                    partial = jnp.sum(diff * diff, axis=0)
                    total = jax.lax.psum(partial, "model")
                    return topk_merge(acc_state, total, tids), None

                st, _ = jax.lax.scan(body, topk_init(K), (data_l, ids_l))
                return st

            st = jax.vmap(one_q)(Q_l)  # queries share the dim shard
            all_d = jax.lax.all_gather(st.dists, daxes)
            all_i = jax.lax.all_gather(st.ids, daxes)
            nrep = all_d.shape[0]
            merged = jax.vmap(
                lambda d, i: topk_merge(topk_init(K), d.reshape(-1), i.reshape(-1)),
                (1, 1),
            )(all_d.reshape(nrep, QUERIES, K), all_i.reshape(nrep, QUERIES, K))
            return merged.dists, merged.ids

        fn = shard_map(
            local_dim, mesh=mesh,
            in_specs=(P(daxes, "model", None), P(daxes), P(None, "model")),
            out_specs=(P(), P()),
            check_rep=False,
        )
        return fn, (data, ids, Q), (
            NamedSharding(mesh, P(daxes, "model", None)),
            NamedSharding(mesh, P(daxes)),
            NamedSharding(mesh, P(None, "model")),
        )

    raise ValueError(variant)


def run_variant(variant: str, mesh_name: str, out_dir: str) -> dict:
    rec = {"arch": f"pdx-search-{variant}", "shape": "batch128_100Mx1536",
           "mesh": mesh_name, "step": "search"}
    mesh = make_production_mesh(multi_pod=(mesh_name == "multi_pod"))
    try:
        fn, args, shardings = build_pdx_cell(variant, mesh)
        jx = jax.make_jaxpr(fn)(*args)
        jcost = jaxpr_cost(jx)
        t0 = time.time()
        with mesh:
            lowered = jax.jit(fn, in_shardings=shardings).lower(*args)
            compiled = lowered.compile()
        dt = time.time() - t0
        mem = compiled.memory_analysis()
        coll = collective_bytes_hlo(compiled.as_text())
        mem_rec = {}
        if mem is not None:
            for kk in ("argument_size_in_bytes", "temp_size_in_bytes",
                       "peak_memory_in_bytes"):
                v = getattr(mem, kk, None)
                if v is not None:
                    mem_rec[kk] = int(v)
        rec.update(
            status="ok", compile_s=round(dt, 2), jaxpr_cost=jcost,
            collectives=coll, memory=mem_rec,
            n_devices=int(mesh.devices.size),
            params_total=float(N_VECTORS) * DIM, params_active=float(N_VECTORS) * DIM,
            tokens=QUERIES,
        )
        print(f"[dryrun-pdx] {variant} x {mesh_name}: OK compile {dt:.1f}s "
              f"flops={jcost.get('flops', 0):.3e} coll={coll['total']:.3e}B")
        print(f"  memory: {mem_rec}")
    except Exception as e:  # noqa: BLE001
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   trace=traceback.format_exc()[-2500:])
        print(f"[dryrun-pdx] {variant} x {mesh_name}: FAIL {e}")
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        with open(os.path.join(
            out_dir, f"pdx-search-{variant}__batch128__{mesh_name}.json"
        ), "w") as f:
            json.dump(rec, f, indent=1)
    return rec


VARIANTS = ["block", "dim", "block_matmul", "block_matmul_bf16",
            "block_matmul_int8", "block_pruned"]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--variant", default=None, choices=VARIANTS)
    ap.add_argument("--mesh", default="single_pod",
                    choices=["single_pod", "multi_pod", "both"])
    ap.add_argument("--out", default="results/dryrun_pdx")
    args = ap.parse_args()
    variants = [args.variant] if args.variant else VARIANTS
    meshes = ["single_pod", "multi_pod"] if args.mesh == "both" else [args.mesh]
    fails = 0
    for m in meshes:
        for v in variants:
            fails += run_variant(v, m, args.out)["status"] == "error"
    raise SystemExit(1 if fails else 0)


if __name__ == "__main__":
    main()
