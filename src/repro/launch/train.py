"""Training driver: config-driven, fault-tolerant, resumable.

    PYTHONPATH=src python -m repro.launch.train --arch llama3.2-3b \
        --reduced --steps 100 --batch 8 --seq 128 --ckpt-dir /tmp/ck

Wires together every substrate: model zoo, deterministic data pipeline with
prefetch, AdamW/Adafactor, remat train step, async checkpointing with
resume, straggler monitor, and (on a real mesh) the sharding rules — on CPU
it runs the reduced configs end-to-end (examples/train_lm.py drives a ~100M
model this way).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from ..configs import get_config
from ..data.pipeline import Prefetcher, TokenStream
from ..models.lm import build_model
from ..train import checkpoint as ckpt
from ..train.optimizer import OptConfig, opt_init
from ..train.straggler import StepTimeMonitor
from ..train.trainer import TrainConfig, make_train_step

__all__ = ["train_loop", "main"]


def train_loop(
    arch: str,
    *,
    reduced: bool = True,
    steps: int = 50,
    batch: int = 4,
    seq: int = 64,
    lr: float = 1e-3,
    ckpt_dir: str | None = None,
    ckpt_every: int = 25,
    accum_steps: int = 1,
    compress_grads: bool = False,
    seed: int = 0,
    log_every: int = 10,
    opt_kind: str = "adamw",
) -> dict:
    cfg = get_config(arch)
    if reduced:
        cfg = cfg.reduced()
    model = build_model(cfg)
    oc = OptConfig(lr=lr, warmup_steps=min(100, steps // 10 + 1), kind=opt_kind)
    tc = TrainConfig(opt=oc, accum_steps=accum_steps,
                     compress_grads=compress_grads)
    step_fn = jax.jit(make_train_step(model, tc))

    params = model.init(jax.random.key(seed))
    opt_state = opt_init(params, oc)
    start_step = 0
    saver = ckpt.AsyncCheckpointer(ckpt_dir) if ckpt_dir else None
    if ckpt_dir and ckpt.latest_step(ckpt_dir) is not None:
        start_step, tree = ckpt.restore(
            ckpt_dir, {"params": params, "opt": opt_state}
        )
        params, opt_state = tree["params"], tree["opt"]
        print(f"[train] resumed from step {start_step}")

    stream = TokenStream(cfg, seq, batch, seed=seed)
    pf = Prefetcher(
        stream.iter_from(start_step),
        place=lambda b: {k: jnp.asarray(v) for k, v in b.items()},
    )
    mon = StepTimeMonitor()
    ef_state = None
    if compress_grads:
        from ..train.compression import ef_init

        ef_state = ef_init(params)

    history = []
    try:
        for step in range(start_step, steps):
            b = pf.next()
            mon.start()
            if compress_grads:
                params, opt_state, metrics, ef_state = step_fn(
                    params, opt_state, b, ef_state
                )
            else:
                params, opt_state, metrics = step_fn(params, opt_state, b)
            loss = float(metrics["loss"])
            dt, slow = mon.stop()
            history.append(loss)
            if step % log_every == 0 or step == steps - 1:
                print(f"[train] step {step} loss {loss:.4f} "
                      f"({dt*1e3:.0f} ms{' STRAGGLER' if slow else ''})")
            if saver and (step + 1) % ckpt_every == 0:
                saver.save(step + 1, {"params": params, "opt": opt_state})
    finally:
        pf.close()
        if saver:
            saver.wait()
    return {"final_loss": history[-1], "history": history,
            "median_step_s": mon.median, "straggler_steps": mon.flagged}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--accum-steps", type=int, default=1)
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--opt", default="adamw", choices=["adamw", "adafactor"])
    args = ap.parse_args()
    out = train_loop(
        args.arch, reduced=args.reduced, steps=args.steps, batch=args.batch,
        seq=args.seq, lr=args.lr, ckpt_dir=args.ckpt_dir,
        ckpt_every=args.ckpt_every, accum_steps=args.accum_steps,
        compress_grads=args.compress_grads, opt_kind=args.opt,
    )
    print(f"[train] done: final_loss={out['final_loss']:.4f} "
          f"median_step={out['median_step_s']*1e3:.0f}ms")


if __name__ == "__main__":
    main()
