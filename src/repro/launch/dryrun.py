import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input-shape x
mesh) cell on the production mesh with ShapeDtypeStruct inputs (no
allocation), print memory_analysis / cost_analysis, and extract the roofline
terms (FLOPs, bytes, per-collective bytes) into a JSON record.

The two lines above MUST stay the first statements in this module: jax locks
the device count at first backend init, and the 512 placeholder CPU devices
exist only in dry-run processes (tests and benches see 1 device).

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch gemma-2b \
        --shape train_4k --mesh single_pod --out results/dryrun
    PYTHONPATH=src python -m repro.launch.dryrun --all --out results/dryrun
"""
import argparse
import json
import re
import time
import traceback

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs import SHAPES, get_config, list_configs, shape_is_applicable
from ..dist import hints
from ..dist.sharding import (
    batch_shardings,
    cache_shardings,
    data_axes,
    param_shardings,
)
from ..models.lm import build_model
from ..train.optimizer import OptConfig, opt_init
from ..train.trainer import TrainConfig, make_train_step
from .analysis import collective_bytes_hlo, jaxpr_cost
from .mesh import make_production_mesh
from .specs import input_specs

# Per-arch training policy (production choices; see DESIGN.md + EXPERIMENTS.md)
OPT_KIND = {"deepseek-v3-671b": "adafactor"}


def count_params(params_abs, path_prefix=()) -> tuple[float, float]:
    """(total, active) parameter counts; routed-expert tensors (stacked
    (L, E, d, f)) contribute top_k/E of themselves to 'active'."""
    total = active = 0.0
    for path, leaf in jax.tree_util.tree_flatten_with_path(params_abs)[0]:
        n = float(np.prod(leaf.shape))
        total += n
        keys = [str(getattr(p, "key", getattr(p, "idx", p))) for p in path]
        name = keys[-1]
        if name in ("w_gate", "w_up", "w_down") and len(leaf.shape) == 4 \
                and "shared" not in keys:
            active += 0.0  # filled in by caller with top_k/E fraction
        else:
            active += n
    return total, active


def _abstract(tree):
    return jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree,
    )


def build_cell(
    arch: str, shape_name: str, mesh, dtype=jnp.bfloat16,
    kv_dtype=None, remat=True, infer_params: bool = False,
):
    """Returns (fn, args, in_shardings) for jit lowering.

    ``kv_dtype``: decode-cache storage dtype (perf lever: f8 quantized KV).
    ``remat``: activation checkpointing in the train step (perf lever).
    ``infer_params``: weight-stationary serving — params TP-sharded only,
    replicated over the data axes (no per-step FSDP gathers).
    """
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    model = build_model(cfg)
    batch_abs = input_specs(cfg, shape, dtype=dtype)
    bsh = batch_shardings(batch_abs, mesh)

    params_abs = jax.eval_shape(
        lambda: model.init(jax.random.key(0), dtype=dtype)
    )
    psh = param_shardings(params_abs, mesh, cfg)
    if infer_params and shape.step != "train":
        from ..dist.sharding import data_axes as _daxes, strip_axes

        psh = strip_axes(psh, _daxes(mesh) )

    if shape.step == "train":
        oc = OptConfig(kind=OPT_KIND.get(arch, "adamw"))
        opt_abs = jax.eval_shape(lambda p: opt_init(p, oc), params_abs)
        osh = jax.tree.map(
            lambda leaf: NamedSharding(mesh, P()), opt_abs
        )
        # moment trees mirror the param shardings where shapes match
        if "mu" in opt_abs:
            osh["mu"], osh["nu"] = psh, psh
        else:  # adafactor: factored accumulators — replicate small leaves
            pass
        step_fn = make_train_step(model, TrainConfig(opt=oc, remat=remat))
        # steady-state out shardings: updated params/opt land exactly where
        # they came from => XLA can reduce-scatter gradients instead of
        # all-reducing the full tensors (perf lever, see EXPERIMENTS §Perf)
        metric_sh = {
            "grad_norm": NamedSharding(mesh, P()),
            "lr": NamedSharding(mesh, P()),
            "loss": NamedSharding(mesh, P()),
        }
        return (
            step_fn,
            (params_abs, opt_abs, batch_abs),
            (psh, osh, bsh),
            (psh, osh, metric_sh),
        )

    if shape.step == "prefill":
        def prefill_fn(params, batch):
            return model.prefill(params, batch, cache_len=shape.seq_len)

        return prefill_fn, (params_abs, batch_abs), (psh, bsh), None

    # decode: one new token against a seq_len cache
    caches_abs = jax.eval_shape(
        lambda: model.init_caches(
            shape.global_batch, shape.seq_len, dtype, kv_dtype=kv_dtype
        )
    )
    csh = cache_shardings(caches_abs, mesh, cfg)
    pos = shape.seq_len - 1

    def decode_fn(params, caches, tokens):
        return model.decode_step(params, tokens["tokens"], caches, pos)

    return (
        decode_fn,
        (params_abs, caches_abs, batch_abs),
        (psh, csh, bsh),
        None,
    )


def run_cell(
    arch: str, shape_name: str, mesh_name: str, out_dir: str,
    kv_dtype=None, remat=True, tag: str = "", use_hints: bool = False,
    infer_params: bool = False, out_shardings: bool = False,
) -> dict:
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name, "tag": tag}
    cfg = get_config(arch)
    ok, why = shape_is_applicable(cfg, shape_name)
    if not ok:
        rec.update(status="skipped", reason=why)
        if out_dir:
            os.makedirs(out_dir, exist_ok=True)
            fname = f"{arch}__{shape_name}__{mesh_name}{tag}.json".replace("/", "_")
            with open(os.path.join(out_dir, fname), "w") as f:
                json.dump(rec, f, indent=1)
        return rec
    mesh = make_production_mesh(multi_pod=(mesh_name == "multi_pod"))
    try:
        t0 = time.time()
        fn, args, shardings, out_sh = build_cell(
            arch, shape_name, mesh, kv_dtype=kv_dtype, remat=remat,
            infer_params=infer_params,
        )
        if not out_shardings:
            out_sh = None
        # exact loop-aware global cost from the jaxpr (see analysis.py)
        jx = jax.make_jaxpr(fn)(*args)
        jcost = jaxpr_cost(jx)
        params_abs = args[0]
        p_total, p_nonexpert = count_params(params_abs)
        expert_params = p_total - p_nonexpert
        frac = (cfg.top_k / cfg.n_experts) if cfg.moe else 0.0
        p_active = p_nonexpert + expert_params * frac
        import contextlib

        hint_ctx = (
            hints.activation_sharding(mesh, data_axes(mesh))
            if use_hints
            else contextlib.nullcontext()
        )
        with mesh, hint_ctx:
            if out_sh is not None:
                jitted = jax.jit(fn, in_shardings=shardings, out_shardings=out_sh)
            else:
                jitted = jax.jit(fn, in_shardings=shardings)
            lowered = jitted.lower(*args)
            t_lower = time.time() - t0
            t0 = time.time()
            compiled = lowered.compile()
            t_compile = time.time() - t0

        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        hlo = compiled.as_text()
        coll = collective_bytes_hlo(hlo)

        mem_rec = {}
        if mem is not None:
            for k in (
                "argument_size_in_bytes", "output_size_in_bytes",
                "temp_size_in_bytes", "generated_code_size_in_bytes",
                "peak_memory_in_bytes",
            ):
                v = getattr(mem, k, None)
                if v is not None:
                    mem_rec[k] = int(v)
            if not mem_rec.get("peak_memory_in_bytes") and mem_rec:
                # CPU backend reports no peak; args+outputs+temps bounds it.
                mem_rec["peak_memory_in_bytes"] = sum(
                    mem_rec.get(k, 0)
                    for k in ("argument_size_in_bytes",
                              "output_size_in_bytes", "temp_size_in_bytes")
                )
        cost_rec = {}
        if cost:
            for k in ("flops", "bytes accessed", "transcendentals"):
                if k in cost:
                    cost_rec[k] = float(cost[k])
        shape = SHAPES[shape_name]
        rec.update(
            status="ok",
            lower_s=round(t_lower, 2),
            compile_s=round(t_compile, 2),
            memory=mem_rec,
            cost=cost_rec,
            jaxpr_cost=jcost,
            collectives=coll,
            n_devices=int(mesh.devices.size),
            params_total=p_total,
            params_active=p_active,
            tokens=(
                shape.global_batch * shape.seq_len
                if shape.step in ("train", "prefill")
                else shape.global_batch
            ),
            step=shape.step,
        )
        print(f"[dryrun] {arch} x {shape_name} x {mesh_name}: OK "
              f"(lower {t_lower:.1f}s compile {t_compile:.1f}s "
              f"jaxpr_flops={jcost.get('flops', 0):.3e} "
              f"coll={coll['total']:.3e}B)")
        print(f"  memory_analysis: {mem_rec}")
    except Exception as e:  # noqa: BLE001 — record and continue
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   trace=traceback.format_exc()[-3000:])
        print(f"[dryrun] {arch} x {shape_name} x {mesh_name}: FAIL {e}")
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        fname = f"{arch}__{shape_name}__{mesh_name}{tag}.json".replace("/", "_")
        with open(os.path.join(out_dir, fname), "w") as f:
            json.dump(rec, f, indent=1)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES) + [None])
    ap.add_argument("--mesh", default="single_pod",
                    choices=["single_pod", "multi_pod", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--kv-dtype", default=None, choices=[None, "bf16", "f8"],
                    help="decode-cache dtype (perf lever)")
    ap.add_argument("--no-remat", action="store_true",
                    help="disable activation checkpointing (perf lever)")
    ap.add_argument("--hints", action="store_true",
                    help="anchor activation shardings (perf lever)")
    ap.add_argument("--infer-params", action="store_true",
                    help="weight-stationary serving sharding (perf lever)")
    ap.add_argument("--out-shardings", action="store_true",
                    help="steady-state train out-shardings (perf lever)")
    ap.add_argument("--tag", default="", help="suffix for the result file")
    args = ap.parse_args()

    kv_dtype = {None: None, "bf16": jnp.bfloat16,
                "f8": jnp.float8_e4m3fn}[args.kv_dtype]
    archs = list_configs() if (args.all or args.arch is None) else [args.arch]
    shapes = list(SHAPES) if (args.all or args.shape is None) else [args.shape]
    meshes = (
        ["single_pod", "multi_pod"] if args.mesh == "both" else [args.mesh]
    )
    n_fail = 0
    for mesh_name in meshes:
        for arch in archs:
            for shape_name in shapes:
                rec = run_cell(
                    arch, shape_name, mesh_name, args.out,
                    kv_dtype=kv_dtype, remat=not args.no_remat, tag=args.tag,
                    use_hints=args.hints, infer_params=args.infer_params,
                    out_shardings=args.out_shardings,
                )
                n_fail += rec["status"] == "error"
    print(f"[dryrun] done, {n_fail} failures")
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
