"""repro — production-grade JAX framework reproducing and extending

   PDX: A Data Layout for Vector Similarity Search (SIGMOD 2025).

Public API:
    repro.core.engine.VectorSearchEngine   — exact/IVF search w/ dimension pruning;
                                             one search() entry point driven by a
                                             declarative SearchSpec + query planner
                                             (repro.core.spec / repro.core.plan)
    repro.configs                          — assigned architecture registry
    repro.launch                           — mesh / dryrun / train / serve drivers
"""

__version__ = "1.0.0"
