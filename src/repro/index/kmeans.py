"""Lloyd's k-means in JAX — the IVF trainer (paper Section 2.1: FAISS uses a
non-optimized Lloyd's; we match that contract).  Chunked assignment keeps the
(N, K) distance matrix out of memory for large N.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["kmeans", "assign", "build_centroid_tree"]


@functools.partial(jax.jit, static_argnames=("chunk",))
def _assign_chunked(X: jax.Array, centroids: jax.Array, chunk: int = 16384):
    n = X.shape[0]
    cn = jnp.sum(centroids * centroids, axis=1)  # (K,)

    def body(lo, out):
        xc = jax.lax.dynamic_slice_in_dim(X, lo, chunk)
        d = (
            jnp.sum(xc * xc, axis=1, keepdims=True)
            - 2.0 * (xc @ centroids.T)
            + cn[None, :]
        )
        return jax.lax.dynamic_update_slice_in_dim(out, jnp.argmin(d, 1), lo, 0)

    npad = ((n + chunk - 1) // chunk) * chunk
    Xp = jnp.pad(X, ((0, npad - n), (0, 0)))
    out = jnp.zeros((npad,), jnp.int32)
    out = jax.lax.fori_loop(
        0, npad // chunk, lambda i, o: body(i * chunk, o), out
    )
    return out[:n]


def assign(X, centroids, chunk: int = 16384) -> jax.Array:
    """Nearest-centroid assignment, (N,) int32."""
    n = X.shape[0]
    chunk = min(chunk, max(n, 1))
    return _assign_chunked(jnp.asarray(X), jnp.asarray(centroids), chunk)


@jax.jit
def _update(X: jax.Array, a: jax.Array, centroids: jax.Array):
    k = centroids.shape[0]
    sums = jax.ops.segment_sum(X, a, num_segments=k)
    cnts = jax.ops.segment_sum(jnp.ones((X.shape[0],)), a, num_segments=k)
    new = sums / jnp.maximum(cnts, 1.0)[:, None]
    # Empty clusters keep their previous centroid (FAISS behaviour).
    return jnp.where((cnts > 0)[:, None], new, centroids), cnts


def kmeans(
    X: np.ndarray, k: int, iters: int = 10, seed: int = 0, chunk: int = 16384
) -> tuple[np.ndarray, np.ndarray]:
    """Returns (centroids (K, D) float32, assignments (N,) int32)."""
    X = jnp.asarray(X, jnp.float32)
    n = X.shape[0]
    if k > n:
        raise ValueError(f"k={k} > n={n}")
    rng = np.random.default_rng(seed)
    init = jnp.asarray(X[np.sort(rng.choice(n, size=k, replace=False))])
    centroids = init
    a = None
    for _ in range(iters):
        a = assign(X, centroids, chunk)
        centroids, _ = _update(X, a, centroids)
    a = assign(X, centroids, chunk)
    return np.asarray(centroids), np.asarray(a)


def build_centroid_tree(
    centroids: np.ndarray,
    super_k: int,
    *,
    iters: int = 10,
    seed: int = 0,
    balance: float = 1.5,
) -> tuple[np.ndarray, np.ndarray]:
    """k-means over the centroids themselves -> a two-level routing tree.

    Returns ``(super_centroids (SK, D) float32, children (SK, M) int32)``
    where row ``s`` of ``children`` lists the centroid ids assigned to
    super-centroid ``s``, right-padded with -1 to the max child count M.
    The child lists partition ``[0, K)``: every centroid appears in exactly
    one row, so ranking the top super-centroids and then only their
    children visits ``SK + nprobe_super * M`` centroids instead of K —
    sub-linear routing for nlist ~ 10^5 when SK ~ sqrt(K).

    The child table is -1-padded to the *fattest* super, so one runaway
    cluster would inflate M (and the routing cost bound) for every super.
    ``balance`` caps each super at ``ceil(balance * K / SK)`` children:
    centroids are assigned greedily (closest-first) to their nearest
    super with room, guaranteeing ``M <= ceil(balance * K / SK)``
    regardless of how lopsided the k-means clustering came out.
    """
    centroids = np.asarray(centroids, np.float32)
    K = centroids.shape[0]
    super_k = int(min(max(super_k, 1), K))
    sc, _ = kmeans(centroids, super_k, iters=iters, seed=seed)
    cap = max(int(np.ceil(balance * K / super_k)), 1)
    # (K, SK) distances; SK ~ sqrt(K), so this stays small even at 10^5.
    d2 = (
        np.sum(centroids * centroids, axis=1, keepdims=True)
        - 2.0 * centroids @ sc.T
        + np.sum(sc * sc, axis=1)[None, :]
    )
    pref = np.argsort(d2, axis=1)           # each centroid's super order
    order = np.argsort(d2.min(axis=1))      # closest-first claim order
    room = np.full(super_k, cap, np.int64)
    a = np.empty(K, np.int64)
    for cid in order:
        for s in pref[cid]:
            if room[s] > 0:
                a[cid] = s
                room[s] -= 1
                break
    counts = np.bincount(a, minlength=super_k)
    M = max(int(counts.max()), 1)
    children = np.full((super_k, M), -1, np.int32)
    fill = np.zeros(super_k, np.int64)
    for cid, s in enumerate(a):
        children[s, fill[s]] = cid
        fill[s] += 1
    return sc, children
