"""IVF index over PDX-resident buckets (paper Figure 2: buckets ≡ blocks).

Centroids themselves are stored in PDX layout so the find-nearest-buckets
phase uses the same dimension-major kernels (paper Table 7 note: "centroids
are also stored with PDX").
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..core.distance import nary_distance, pdx_distance
from ..core.layout import (
    PDXStore,
    build_bucketed_store,
    build_flat_store,
    device_mirror,
)
from ..core.pdxearch import SearchStats, pdxearch
from ..core.pruners import Pruner
from ..core.topk import TopK
from ..kernels.ref import dequantize_ref
from ..obs import metrics as _metrics
from .kmeans import build_centroid_tree, kmeans

__all__ = ["IVFIndex", "build_ivf"]

#: ``build_ivf(tree="auto")`` switches the flat centroid scan to the
#: two-level tree at this nlist — below it the flat single-dispatch scan is
#: both cheaper and tie-stable, above it sub-linear routing wins.
TREE_AUTO_NLIST = 4096


def _rank_centroids_impl(cdata, q, nlist: int, metric: str):
    """One dimension-major scan of ALL centroid tiles -> ascending bucket
    order.  vmap over the (Pc, D, C) tile stack replaces the old
    per-partition Python loop, and the argsort happens on device so the
    whole ranking is a single dispatch with one host sync at the caller."""
    d = jax.vmap(lambda tile: pdx_distance(tile, q, metric))(cdata)
    return jnp.argsort(d.reshape(-1)[:nlist])


_rank_centroids = jax.jit(
    _rank_centroids_impl, static_argnames=("metric", "nlist")
)


@functools.partial(jax.jit, static_argnames=("metric", "nlist"))
def _rank_centroids_batch(
    cdata: jax.Array, Q: jax.Array, nlist: int, metric: str
):
    """``_rank_centroids`` vmapped over a (B, D) query batch -> (B, nlist)
    ascending bucket orders in one dispatch.  Sharing the single-query body
    keeps batched and per-query routing agreeing on bucket ranking by
    construction."""
    return jax.vmap(
        lambda q: _rank_centroids_impl(cdata, q, nlist, metric)
    )(Q)


@functools.partial(
    jax.jit, static_argnames=("metric", "nlist", "packed", "dim")
)
def _rank_centroids_batch_mirror(
    cdata, Q, nlist: int, metric: str, scale, offset,
    packed: bool, dim: int | None,
):
    """Quantized-mirror bucket ranking: the int8/int4 centroid tiles
    dequantize in-register (XLA fuses the affine into the scan) and the
    exact ``_rank_centroids_impl`` arithmetic runs on the result, so
    single-query and batched routing still agree by construction.  Bucket
    *order* near centroid-distance ties may differ from f32 routing — the
    reason ``SearchSpec.route_dtype`` defaults to "f32"."""
    T32 = dequantize_ref(cdata, scale, offset, dim_axis=1,
                         packed=packed, dim=dim)
    return jax.vmap(
        lambda q: _rank_centroids_impl(T32, q, nlist, metric)
    )(Q)


@functools.partial(
    jax.jit, static_argnames=("metric", "nlist", "nprobe_super")
)
def _rank_centroids_tree(
    centroids: jax.Array,      # (K, D) horizontal f32
    supers: jax.Array,         # (SK, D) super-centroids
    children: jax.Array,       # (SK, M) int32 child lists, -1 right-pad
    Q: jax.Array,              # (B, D)
    nlist: int,
    metric: str,
    nprobe_super: int,
):
    """Two-level bucket ranking: rank SK super-centroids, keep the best
    ``nprobe_super``, then rank only *their* children.  Visits
    ``SK + nprobe_super * M`` centroids per query instead of nlist.

    Returns (B, nlist) int32 bucket orders, best first, right-padded with
    -1: unlike the flat argsort, a query only ranks the candidate set under
    its selected super-centroids, so consumers (``route``,
    ``partition_order``, ``plan_routing``) skip ids < 0."""
    SK, M = children.shape

    def one(q):
        ds = nary_distance(supers, q, metric)                 # (SK,)
        _, top = jax.lax.top_k(-ds, nprobe_super)             # best supers
        cand = children[top].reshape(-1)                      # (nps*M,)
        valid = cand >= 0
        dc = nary_distance(centroids[jnp.where(valid, cand, 0)], q, metric)
        dc = jnp.where(valid, dc, jnp.inf)                    # pads last
        order = jnp.argsort(dc)
        ranked = jnp.where(
            jnp.isfinite(dc[order]), cand[order], -1
        ).astype(jnp.int32)
        out = jnp.full((nlist,), -1, jnp.int32)
        n = min(int(ranked.shape[0]), nlist)
        # Children partition [0, nlist): ranked holds <= nlist valid ids,
        # and the sort packs them first, so truncation only drops pads.
        return out.at[:n].set(ranked[:n])

    return jax.vmap(one)(Q)


@jax.jit
def _nearest_centroid(centroids: jax.Array, X: jax.Array) -> jax.Array:
    """(K, D), (N, D) -> (N,) nearest-centroid bucket per row (L2, matching
    the k-means training objective); used for centroid assignment on insert."""
    cross = X @ centroids.T                       # (N, K) — MXU
    cn = jnp.sum(centroids * centroids, axis=1)   # (K,)
    return jnp.argmin(cn[None, :] - 2.0 * cross, axis=1).astype(jnp.int32)


@dataclasses.dataclass
class IVFIndex:
    store: PDXStore                 # bucket-contiguous PDX partitions
    centroid_store: PDXStore        # centroids, PDX layout (for bucket ranking)
    centroids: jax.Array            # (K, D) horizontal copy (k-means updates)
    part_offsets: np.ndarray        # (K,) first partition id of each bucket
    part_counts: np.ndarray         # (K,) partitions per bucket
    nlist: int
    # Two-level routing tree (None -> flat scan).  ``super_centroids`` is
    # (SK, D); ``super_children`` is the (SK, M) -1-padded child table from
    # ``kmeans.build_centroid_tree``; ``nprobe_super`` is how many supers a
    # query descends into.
    super_centroids: Optional[jax.Array] = None
    super_children: Optional[jax.Array] = None
    nprobe_super: int = 0

    @property
    def tree_enabled(self) -> bool:
        return self.super_centroids is not None

    def routing_cost(self) -> int:
        """Centroids ranked per query: nlist for the flat scan, the
        sub-linear ``SK + nprobe_super * M`` bound for the tree (the bench
        asserts this stays < nlist)."""
        if not self.tree_enabled:
            return self.nlist
        SK, M = self.super_children.shape
        return int(SK + self.nprobe_super * M)

    def attach_tree(
        self,
        super_k: Optional[int] = None,
        nprobe_super: Optional[int] = None,
        *,
        seed: int = 0,
    ) -> None:
        """(Re)build the two-level tree over the CURRENT centroids — also
        the recalibration hook: after BSA re-projects centroids the tree
        must be re-clustered in the rotated space."""
        if super_k is None:
            super_k = max(2, int(np.ceil(np.sqrt(self.nlist))))
        sc, children = build_centroid_tree(
            np.asarray(self.centroids), super_k, seed=seed
        )
        self.super_centroids = jnp.asarray(sc)
        self.super_children = jnp.asarray(children)
        if nprobe_super is None:
            nprobe_super = max(2, sc.shape[0] // 4)
        self.nprobe_super = int(min(max(nprobe_super, 1), sc.shape[0]))

    def _ranked_batch(
        self, Q: jax.Array, metric: str, dtype: str
    ) -> jax.Array:
        """(B, D) queries -> (B, nlist) ascending bucket orders, scanning
        the centroid tiles at ``dtype`` width (the data scan's dtype policy
        applied to routing; see ``core.layout``).  Records the routing scan
        bytes so ``BENCH_routing.json``/dashboards see the shrink.

        With a tree attached the orders come from the two-level descent
        instead of the flat scan and carry -1 right-pads (only the
        candidate set under each query's super-centroids is ranked); the
        tree ranks f32 centroids at both levels — its byte shrink comes
        from visiting ``routing_cost() << nlist`` centroids, not from a
        narrower dtype."""
        if self.tree_enabled:
            order = _rank_centroids_tree(
                self.centroids, self.super_centroids, self.super_children,
                Q, self.nlist, metric, self.nprobe_super,
            )
            if _metrics.enabled():
                _metrics.counter(
                    "repro_device_bytes_total",
                    float(Q.shape[0]) * self.routing_cost()
                    * self.centroids.shape[1] * 4.0,
                    executor="route", component="scan", dtype="f32",
                )
            return order
        if dtype == "f32":
            order = _rank_centroids_batch(
                self.centroid_store.data, Q, self.nlist, metric
            )
            bpv = 4.0
        else:
            m = device_mirror(self.centroid_store, dtype)
            sc = m.scale if m.quantized else None
            off = m.offset if m.quantized else None
            order = _rank_centroids_batch_mirror(
                m.data, Q, self.nlist, metric, sc, off, m.packed, m.dim
            )
            bpv = m.bytes_per_value
        if _metrics.enabled():
            Pc, Dc, Cc = self.centroid_store.data.shape
            _metrics.counter(
                "repro_device_bytes_total",
                float(Q.shape[0]) * Pc * Dc * Cc * bpv,
                executor="route", component="scan", dtype=dtype,
            )
        return order

    def rank_buckets(
        self, q: jax.Array, metric: str = "l2", dtype: str = "f32"
    ) -> np.ndarray:
        """Distance of q to every centroid -> bucket ids sorted ascending.
        ``dtype`` scans a quantized centroid mirror instead of f32."""
        return np.asarray(
            self._ranked_batch(
                jnp.asarray(q, jnp.float32)[None], metric, dtype
            )[0]
        )

    def assign(self, X: np.ndarray) -> np.ndarray:
        """(N, D) rows -> (N,) bucket assignments (nearest centroid).  This
        is the insert path of a mutable store: rows are bucket-assigned at
        insert time so a later repack can drain them bucket-contiguously."""
        X = jnp.atleast_2d(jnp.asarray(X, jnp.float32))
        return np.asarray(_nearest_centroid(self.centroids, X))

    def partition_order(self, bucket_order: np.ndarray, nprobe: int) -> np.ndarray:
        sel = bucket_order[:nprobe]
        parts = [
            np.arange(
                self.part_offsets[b], self.part_offsets[b] + self.part_counts[b]
            )
            for b in sel
            if b >= 0  # tree orders right-pad with -1
        ]
        return np.concatenate(parts) if parts else np.zeros(0, np.int64)

    def route_batch(
        self, Qt: jax.Array, nprobe: int, metric: str = "l2",
        dtype: str = "f32",
    ) -> np.ndarray:
        """Query routing for the distributed bucket-routed executor: rank
        buckets for a whole (B, D) batch of (already pruner-transformed)
        queries -> (B, min(nprobe, nlist)) bucket ids, best first.  The
        caller (``repro.dist.routing``) maps buckets onto owner shards via
        the placement and exchanges queries with one all-to-all.  ``dtype``
        runs the centroid scan over a quantized mirror (host-side, pre-
        collective: the exchange plan and collective count are unchanged)."""
        Qt = jnp.atleast_2d(jnp.asarray(Qt, jnp.float32))
        order = self._ranked_batch(Qt, metric, dtype)
        return np.asarray(order[:, : min(nprobe, self.nlist)])

    def route(
        self, qt: jax.Array, nprobe: int, metric: str = "l2",
        dtype: str = "f32",
    ) -> tuple[np.ndarray, int]:
        """Query routing for the planner's adaptive executor: rank buckets
        by centroid distance of the (already pruner-transformed) query and
        return ``(partition visit order, start_parts)`` — START linear-scans
        every partition of the nearest *non-empty* bucket to seed the top-k
        threshold (empty buckets own zero partitions and zero scan work)."""
        border = self.rank_buckets(qt, metric, dtype)
        order = self.partition_order(border, nprobe)
        start_parts = 0
        for b in border[:nprobe]:
            if b >= 0 and self.part_counts[b] > 0:
                start_parts = int(self.part_counts[b])
                break
        return order, start_parts

    def search(
        self,
        q: jax.Array,
        k: int,
        pruner: Pruner,
        *,
        nprobe: int = 8,
        metric: str = "l2",
        schedule: str = "adaptive",
        delta_d: int = 32,
        sel_frac: float = 0.2,
        group: int = 8,
        stats: Optional[SearchStats] = None,
    ) -> TopK:
        """Compatibility wrapper around ``route`` + ``pdxearch``.  Engine
        code goes through ``repro.core.plan``, which calls ``route`` and
        owns the executor choice; this stays for direct index users."""
        qt = pruner.transform_query(jnp.asarray(q, jnp.float32))
        order, start_parts = self.route(qt, nprobe, metric)
        return pdxearch(
            self.store,
            q,
            k,
            pruner,
            metric=metric,
            schedule=schedule,
            delta_d=delta_d,
            sel_frac=sel_frac,
            group=group,
            pid_order=order,
            start_parts=start_parts,
            stats=stats,
        )


def build_ivf(
    X: np.ndarray,
    nlist: int,
    *,
    capacity: int = 1024,
    kmeans_iters: int = 10,
    seed: int = 0,
    precomputed: Optional[tuple[np.ndarray, np.ndarray]] = None,
    tree: bool | str = "auto",
    super_k: Optional[int] = None,
    nprobe_super: Optional[int] = None,
) -> IVFIndex:
    """Train k-means (or take precomputed (centroids, assignments) so
    competitors share identical buckets, as the paper does) and pack buckets
    into PDX partitions.

    ``tree`` controls the two-level centroid routing tree: ``True`` builds
    it, ``False`` keeps the flat scan, ``"auto"`` builds it once nlist
    reaches ``TREE_AUTO_NLIST``.  ``super_k`` defaults to ~sqrt(nlist),
    ``nprobe_super`` to super_k // 4."""
    X = np.asarray(X, np.float32)
    if precomputed is not None:
        centroids, assignments = precomputed
    else:
        centroids, assignments = kmeans(X, nlist, iters=kmeans_iters, seed=seed)
    store, offsets, nparts = build_bucketed_store(X, assignments, nlist, capacity)
    cstore = build_flat_store(centroids, capacity=min(1024, max(64, nlist)))
    ivf = IVFIndex(
        store=store,
        centroid_store=cstore,
        centroids=jnp.asarray(centroids),
        part_offsets=offsets,
        part_counts=nparts,
        nlist=nlist,
    )
    want_tree = tree is True or (tree == "auto" and nlist >= TREE_AUTO_NLIST)
    if want_tree:
        ivf.attach_tree(super_k, nprobe_super, seed=seed)
    return ivf
