"""Bucket-routed distributed search: queries travel, tiles stay put.

The replicated-broadcast paths (``pdx_sharded``) send every query to every
shard and scan the whole striped store.  With a ``bucket`` placement
(``repro.dist.placement``) each shard *owns* a subset of the IVF buckets, so
a query only needs to visit the shards owning its top-``nprobe`` buckets —
the HARMONY-style routing the ROADMAP's "IVF bucket routing across hosts"
item calls for.  One batch flows through exactly two collectives:

1. **Route + exchange** — the router (``IVFIndex.route_batch``) ranks
   buckets per query; ``plan_routing`` turns that into a host-side exchange
   plan (which query goes to which owner shard, deduplicated).  Ragged
   per-shard query lists are padded to a static power-of-two *budget* (few
   distinct budgets => few retraces), queries and their selected bucket ids
   are packed into one buffer (int32 bucket ids bitcast to float32), and a
   single ``all_to_all`` delivers to each shard only the queries it owns
   buckets for.

2. **Masked local scan + hierarchical merge** — each shard scans *only its
   owned buckets* (its placement slice), masking each received query down to
   the buckets it actually selected, and keeps a shard-local top-k.  The
   per-shard (dists ‖ bitcast ids) candidate sets then cross the mesh in one
   packed ``all_gather`` (the PR 2 collective-packing trick), and the final
   per-query top-k merges only the candidate blocks from the shards that
   query was routed to.

Wire cost per batch: ``n² · budget · (D + nprobe)`` floats in the
all-to-all (budget shrinks with nprobe — fewer owner shards per query) plus
``n · n·budget · 2k`` floats in the all-gather, versus the broadcast path's
``n · B · D`` replicated queries + full-store scan on every shard.  Two
further byte levers ride on top:

* **Send-budget spill** — instead of padding every (src, dst) pair to the
  power-of-two ceiling of the *maximum* demand, ``plan_routing`` may split
  the exchange into two rounds ``(b1, b2)`` whenever ``b1 + b2`` moves
  fewer slots than the single padded round (high skew: one hot pair forces
  everyone to its ceiling).  Both rounds are slices of the same buffer and
  the split is static per plan, so the all-to-all count stays 1 or 2 with
  few distinct shapes.

* **Quantized shard scan** — with a reduced-precision device mirror
  (``spec.scan_dtype`` != "f32") each shard scans its *mirror* slice
  (bf16/int8, dequantized in-register by XLA) — 2x/4x fewer HBM bytes on
  the dominant term — and re-ranks its local top ``rerank_mult·k``
  candidates against its f32 master slice, so candidate distances are
  exact *before* they ever cross the mesh.  The wire deliberately stays
  f32 end to end: rounding queries in the all-to-all would make the
  re-rank exact relative to a perturbed query, and rounding candidate
  distances in the all-gather would swap cross-shard near-ties at the
  global k-boundary and hand rounded distances back to the caller — both
  were observed breaking id-parity with the f32 path on seed datasets,
  so the mirror's byte savings are taken where they are safe (the scan)
  and nowhere else.
"""
from __future__ import annotations

import collections
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from ..core.distance import batched_distance_matmul
from ..core.topk import TopK, rerank_positions, topk_init, topk_merge
from ..kernels.ref import dequantize_ref
from ..obs import metrics as _metrics
from ..obs import trace as _trace
from .placement import Placement

__all__ = [
    "RoutingPlan",
    "RoutedLaunch",
    "plan_routing",
    "build_send_buffer",
    "make_routed_fn",
    "prepare_routed",
    "launch_routed",
    "search_routed_bucket",
]

_INF = jnp.float32(jnp.inf)

# Sentinel bucket id for unused send slots: must match NO slot_bucket entry
# (pad slots carry -1, so -1 would wrongly select them).
_EMPTY_SEL = -2


def _pow2_at_least(x: int, lo: int = 1) -> int:
    c = lo
    while c < x:
        c *= 2
    return c


@dataclasses.dataclass(frozen=True)
class RoutingPlan:
    """Host-side exchange plan for one query batch.

    ``send_slot[s, t, j]`` — global query index source shard ``s`` puts in
    slot ``j`` of its message to shard ``t`` (-1 = unused pad slot).
    ``dest_shard``/``dest_slot`` (B, max_dest) — where each query's
    candidate blocks land after the all-gather (-1 pads).  ``src_of`` (B,)
    — the source shard each query originates on (contiguous split of the
    batch, mirroring how a (B, D) batch shards over the axis).
    """

    send_slot: np.ndarray
    dest_shard: np.ndarray
    dest_slot: np.ndarray
    src_of: np.ndarray
    budget: int       # static total slot count per (src, dst) = b1 + b2
    occupancy: int    # real (src, dst, slot) entries, for byte accounting
    round_budgets: tuple  # (b1, b2) all-to-all round widths; b2 == 0 means
                          # one round (balanced demand, no spill needed)


def plan_routing(
    sel: np.ndarray,
    bucket_shard: np.ndarray,
    bucket_parts: np.ndarray,
    n_shards: int,
) -> RoutingPlan:
    """Map each query's selected buckets onto owner shards.

    ``sel`` (B, nprobe) — ranked bucket ids per query.  Empty buckets own no
    partitions and are skipped (routing a query to their owner would move
    bytes for zero scan work).  Budgets are powers of two so shapes stay
    static across batches with similar routing pressure; when the max
    demand ``m`` fits in 3/4 of its pow2 ceiling, the exchange spills
    across TWO rounds ``(single/2, single/4)`` — 25% fewer padded slots
    than one round at the ceiling (e.g. demand 33 moves 48 slots per pair
    instead of 64).  Exactly two compiled shapes exist per demand octave
    (spilled or not) — a finer-grained spill would save more bytes at high
    skew but lets drifting demand mint a fresh executor shape per batch.
    """
    sel = np.asarray(sel)
    B = sel.shape[0]
    src_of = (np.arange(B, dtype=np.int64) * n_shards) // max(B, 1)
    # sel rows may carry -1 right-pads (two-level tree routing emits fewer
    # than nprobe buckets when the probed supers' children run short) —
    # drop them before the empty-bucket filter, which indexes bucket_parts
    dests = []
    for b in range(B):
        sb = sel[b][sel[b] >= 0]
        dests.append(np.unique(bucket_shard[sb[bucket_parts[sb] > 0]]))
    max_dest = min(sel.shape[1], n_shards)
    counts = np.zeros((n_shards, n_shards), np.int64)
    for b, ds in enumerate(dests):
        counts[src_of[b], ds] += 1
    m = max(int(counts.max(initial=0)), 1)
    single = _pow2_at_least(m)
    if single >= 4 and m <= 3 * single // 4:
        b1, b2 = single // 2, single // 4
    else:
        b1, b2 = single, 0
    budget = b1 + b2

    send_slot = np.full((n_shards, n_shards, budget), -1, np.int32)
    dest_shard = np.full((B, max_dest), -1, np.int32)
    dest_slot = np.full((B, max_dest), -1, np.int32)
    fill = np.zeros((n_shards, n_shards), np.int64)
    for b, ds in enumerate(dests):
        s = src_of[b]
        for j, t in enumerate(ds):
            slot = fill[s, t]
            fill[s, t] += 1
            send_slot[s, t, slot] = b
            dest_shard[b, j] = t
            dest_slot[b, j] = slot
    rp = RoutingPlan(
        send_slot=send_slot, dest_shard=dest_shard, dest_slot=dest_slot,
        src_of=src_of.astype(np.int32), budget=budget,
        occupancy=int(fill.sum()), round_budgets=(b1, b2),
    )
    if _metrics.enabled():
        # the histogram's log2 buckets ARE the demand octaves: each compiled
        # budget shape serves one bucket, so the bucket counts show exactly
        # how batches spread over executor shapes
        _metrics.observe("repro_routing_demand", float(m))
        _metrics.counter(
            "repro_routing_spill_rounds_total", rounds=2 if b2 else 1
        )
        _metrics.gauge(
            "repro_routing_slot_occupancy",
            rp.occupancy / max(n_shards * n_shards * budget, 1),
        )
    return rp


def build_send_buffer(
    Q: np.ndarray, sel: np.ndarray, rp: RoutingPlan
) -> np.ndarray:
    """Pack (queries ‖ bitcast selected-bucket ids) into the single
    (n, n, budget, D + nprobe) float32 all-to-all payload, covering both
    exchange rounds (slots ``[:b1]`` travel in round 1, the spill in
    round 2)."""
    Q = np.asarray(Q, np.float32)
    sel = np.asarray(sel, np.int32)
    n = rp.send_slot.shape[0]
    D, nprobe = Q.shape[1], sel.shape[1]
    send_q = np.zeros((n, n, rp.budget, D), np.float32)
    send_sel = np.full((n, n, rp.budget, nprobe), _EMPTY_SEL, np.int32)
    occ = rp.send_slot >= 0
    send_q[occ] = Q[rp.send_slot[occ]]
    send_sel[occ] = sel[rp.send_slot[occ]]
    return np.concatenate([send_q, send_sel.view(np.float32)], axis=-1)


# jitted routed executors keyed on their static configuration; every array
# (send buffer, tiles, routing indices) is a traced ARGUMENT, so one cache
# entry serves every batch / placement with the same shapes — repeated
# searches hit the jit executable instead of re-tracing the shard_map.
_ROUTED_CACHE: "collections.OrderedDict[tuple, object]" = (
    collections.OrderedDict()
)
_ROUTED_CACHE_MAX = 8


def _exchange(buf0, axis: str, rounds: tuple):
    """The query exchange: one all_to_all per non-empty round, slicing the
    shared (n, budget, W) buffer at ``b1``.  Concatenating the received
    rounds reproduces exactly the single-round layout (all_to_all permutes
    only the shard axis), so everything downstream is round-agnostic."""
    b1, b2 = rounds
    if not b2:
        return jax.lax.all_to_all(buf0, axis, 0, 0, tiled=True)
    r1 = jax.lax.all_to_all(buf0[:, :b1], axis, 0, 0, tiled=True)
    r2 = jax.lax.all_to_all(buf0[:, b1:], axis, 0, 0, tiled=True)
    return jnp.concatenate([r1, r2], axis=1)


def _routed_exec(mesh, axis: str, D: int, nprobe: int, k: int, metric: str,
                 rounds: tuple, quantized: bool, rk: int,
                 packed: bool = False, dim: int | None = None):
    key = (mesh, axis, D, nprobe, k, metric, rounds, quantized, rk,
           packed, dim)
    if key in _ROUTED_CACHE:
        _ROUTED_CACHE.move_to_end(key)
        _metrics.counter(
            "repro_cache_events_total", cache="routed", event="hit"
        )
        return _ROUTED_CACHE[key]
    _metrics.counter("repro_cache_events_total", cache="routed", event="miss")

    def local(buf, d_sh, i_sh, pb_sh, dest_shard, dest_slot, src_of,
              qd_sh, scale, offset):
        # buf local: (1, n, budget, D + nprobe) — my messages, one per dest.
        n, budget = buf.shape[1], buf.shape[2]
        B = dest_shard.shape[0]
        recv = _exchange(buf[0], axis, rounds)
        Bl = n * budget  # received queries, flat index = src * budget + slot
        Qr = recv[..., :D].reshape(Bl, D)
        selr = jax.lax.bitcast_convert_type(
            recv[..., D:], jnp.int32
        ).reshape(Bl, nprobe)
        # query q may scan local partition p iff p's bucket is one q selected
        allowed = (selr[:, :, None] == pb_sh[None, None, :]).any(axis=1)

        if not quantized:
            def body(state, inp):
                tile, tids, allow_p = inp  # (D, C), (C,), (Bl,)
                dmat = batched_distance_matmul(tile, Qr, metric)  # (Bl, C)
                dmat = jnp.where(allow_p[:, None], dmat, _INF)
                return (
                    jax.vmap(topk_merge, (0, 0, None))(state, dmat, tids),
                    None,
                )

            init = jax.vmap(lambda _: topk_init(k))(jnp.arange(Bl))
            res, _ = jax.lax.scan(body, init, (d_sh, i_sh, allowed.T))
        else:
            # mirror scan at reduced precision -> local top-rk positions,
            # then exact f32 re-rank against the MASTER slice — candidate
            # distances are exact before they ever cross the mesh
            W, _, C = qd_sh.shape
            pos = jnp.arange(W * C, dtype=jnp.int32).reshape(W, C)
            pos = jnp.where(i_sh >= 0, pos, -1)

            def body(state, inp):
                tileq, tpos, allow_p = inp
                # packed int4 unpacks in-body (two nibbles/byte along D);
                # int8/bf16 dequantize via the same reference op
                t32 = dequantize_ref(
                    tileq, scale, offset, packed=packed, dim=dim
                )
                dmat = batched_distance_matmul(t32, Qr, metric)
                dmat = jnp.where(allow_p[:, None], dmat, _INF)
                return (
                    jax.vmap(topk_merge, (0, 0, None))(state, dmat, tpos),
                    None,
                )

            init = jax.vmap(lambda _: topk_init(rk))(jnp.arange(Bl))
            cand, _ = jax.lax.scan(body, init, (qd_sh, pos, allowed.T))
            # exact f32 re-rank against the local MASTER slice
            res = rerank_positions(d_sh, i_sh, Qr, cand, k, metric)

        # candidate distances stay f32 on the wire even for quantized
        # scans: the hierarchical merge decides the global k-boundary, and
        # a rounded wire would both swap cross-shard near-ties there and
        # round the distances the caller gets back — exactness is the
        # on-shard re-rank's whole contract
        wire = jnp.concatenate(
            [res.dists,
             jax.lax.bitcast_convert_type(res.ids, jnp.float32)],
            axis=1,
        )  # (Bl, 2k)
        allp = jax.lax.all_gather(wire, axis)  # (n_dst, Bl, 2k)

        # hierarchical merge (replicated): per query, only the candidate
        # blocks from the shards it was routed to.
        pad = dest_shard < 0                                     # (B, max_dest)
        t = jnp.maximum(dest_shard, 0)
        row = src_of[:, None] * budget + jnp.maximum(dest_slot, 0)
        cand = allp[t, row]                                      # (B, md, 2k)
        cd = cand[..., :k]
        ci = jax.lax.bitcast_convert_type(cand[..., k:], jnp.int32)
        cd = jnp.where(pad[:, :, None], _INF, cd).reshape(B, -1)
        ci = jnp.where(pad[:, :, None], -1, ci).reshape(B, -1)
        merge = lambda dd, ii: topk_merge(topk_init(k), dd, ii)  # noqa: E731
        return jax.vmap(merge)(cd, ci)

    fn = jax.jit(shard_map(
        local,
        mesh=mesh,
        in_specs=(P(axis), P(axis), P(axis), P(axis), P(), P(), P(),
                  P(axis), P(), P()),
        out_specs=TopK(dists=P(), ids=P()),
        check_rep=False,
    ))
    _ROUTED_CACHE[key] = fn
    while len(_ROUTED_CACHE) > _ROUTED_CACHE_MAX:
        _ROUTED_CACHE.popitem(last=False)
    return fn


def make_routed_fn(mesh, placement: Placement, rp: RoutingPlan, D: int,
                   nprobe: int, k: int, metric: str = "l2",
                   mirror=None, rerank_mult: int = 4):
    """Bind the cached jitted routed executor to one (placement, routing
    plan): send_buffer -> (B, k) TopK.

    One all_to_all per exchange round (two only when the plan spilled a
    skewed budget) plus ONE packed all-gather (candidate merge) per call —
    independent of B and nprobe; ``collective_counts`` gates this in tests.
    With ``mirror`` (a ``core.layout.DeviceMirror``) each shard scans its
    arranged mirror slice and re-ranks locally against its f32 masters.
    """
    quantized = mirror is not None and mirror.dtype != "f32"
    rk = min(max(rerank_mult * k, k), placement.num_slots *
             placement.data.shape[2]) if quantized else k
    fn = _routed_exec(
        mesh, placement.axis, D, nprobe, k, metric, rp.round_budgets,
        quantized, rk,
        packed=mirror.packed if quantized else False,
        dim=mirror.dim if quantized else None,
    )
    slot_bucket = jnp.asarray(placement.slot_bucket, jnp.int32)
    dest_shard = jnp.asarray(rp.dest_shard)
    dest_slot = jnp.asarray(rp.dest_slot)
    src_of = jnp.asarray(rp.src_of)
    if quantized:
        qtiles = placement.arranged_mirror(mirror)
        scale, offset = mirror.scale, mirror.offset
    else:  # unused by the f32 body; tiny placeholders keep the arity fixed
        D_ = placement.data.shape[1]
        qtiles = placement.data
        scale = jnp.ones((D_,), jnp.float32)
        offset = jnp.zeros((D_,), jnp.float32)
    return lambda buf: fn(
        buf, placement.data, placement.ids, slot_bucket,
        dest_shard, dest_slot, src_of, qtiles, scale, offset,
    )


@dataclasses.dataclass
class RoutedLaunch:
    """Host-side product of ``prepare_routed``: everything needed to fire
    the device half of one routed batch.  Splitting lets a serving loop
    overlap batch N+1's host work (``plan_routing`` + send-buffer packing +
    executor-cache lookup) with batch N's device collectives — the
    double-buffering in ``repro.serve.vector``."""

    fn: object           # bound routed executor: send buffer -> (B, k) TopK
    buf: jax.Array       # packed send buffer, already on device
    buf_shape: tuple     # host buffer shape (compile-collectives cache key)
    rp: RoutingPlan
    n_shards: int
    D: int
    C: int
    num_slots: int
    nprobe: int
    k: int
    metric: str
    quantized: bool
    mirror_dtype: str
    mirror_bpv: float   # 0.5 for packed int4 — bytes, not whole bytes
    rerank_mult: int


def prepare_routed(
    mesh,
    placement: Placement,
    Q: jax.Array,
    sel: np.ndarray,
    k: int,
    *,
    metric: str = "l2",
    mirror=None,
    rerank_mult: int = 4,
) -> RoutedLaunch:
    """The HOST half of a routed batch search: exchange planning, send-
    buffer packing, executor-cache binding, and the (async) device upload.
    No collective is issued here — ``launch_routed`` fires the exchange.

    ``Q`` (B, D) — pruner-transformed queries; ``sel`` (B, nprobe) — ranked
    bucket ids per query (``IVFIndex.route_batch``)."""
    if placement.kind != "bucket":
        raise ValueError(
            f"routed search needs a 'bucket' placement, got {placement.kind!r}"
        )
    Qnp = np.asarray(Q, np.float32)
    selnp = np.asarray(sel, np.int32)
    quantized = mirror is not None and mirror.dtype != "f32"
    with _trace.span("route", nprobe=selnp.shape[1],
                     n_shards=placement.n_shards):
        rp = plan_routing(
            selnp, placement.bucket_shard, placement.bucket_parts,
            placement.n_shards,
        )
        buf = build_send_buffer(Qnp, selnp, rp)
        fn = make_routed_fn(
            mesh, placement, rp, Qnp.shape[1], selnp.shape[1], k, metric,
            mirror=mirror if quantized else None, rerank_mult=rerank_mult,
        )
    return RoutedLaunch(
        fn=fn, buf=jnp.asarray(buf), buf_shape=buf.shape, rp=rp,
        n_shards=placement.n_shards, D=Qnp.shape[1],
        C=placement.data.shape[2], num_slots=placement.num_slots,
        nprobe=selnp.shape[1], k=k, metric=metric, quantized=quantized,
        mirror_dtype=mirror.dtype if quantized else "f32",
        mirror_bpv=mirror.bytes_per_value if quantized else 4,
        rerank_mult=rerank_mult,
    )


def launch_routed(launch: RoutedLaunch) -> TopK:
    """The DEVICE half: issue the all-to-all exchange + masked shard scan +
    packed all-gather merge for a prepared batch; returns the replicated
    (B, k) TopK.  Also the metrics point — bytes/collectives are recorded
    when the exchange actually fires, not when it is planned."""
    if _metrics.enabled():
        from ..obs import meters as _meters

        rounds = 2 if launch.rp.round_budgets[1] else 1
        _meters.count_issued("routed_bucket", all_to_all=rounds, all_gather=1)
        comps = _meters.routed_batch_bytes(
            launch.rp, n_shards=launch.n_shards, D=launch.D,
            C=launch.C, num_slots=launch.num_slots,
            nprobe=launch.nprobe, k=launch.k,
            bytes_per_value=launch.mirror_bpv,
            rerank_mult=launch.rerank_mult, quantized=launch.quantized,
        )
        _meters.record_device_bytes(
            "routed_bucket", launch.mirror_dtype, comps
        )
        # compile-time gauge: count the collectives in the traced jaxpr
        # once per executor shape; parity with the issued counters above is
        # a CI invariant (benchmarks/bench_obs.py)
        _meters.record_compile_collectives(
            "routed_bucket",
            (launch.buf_shape, launch.rp.round_budgets, launch.quantized,
             launch.k, launch.metric, launch.n_shards),
            launch.fn, launch.buf,
        )
    if launch.quantized:
        # the exact f32 re-rank runs fused on-shard, pre-collective — a
        # zero-width annotation span marks it in the trace
        with _trace.span("rerank", fused="on-shard",
                         rk=launch.rerank_mult * launch.k):
            pass
    return _trace.fence(launch.fn(launch.buf))


def search_routed_bucket(
    mesh,
    placement: Placement,
    Q: jax.Array,
    sel: np.ndarray,
    k: int,
    *,
    metric: str = "l2",
    mirror=None,
    rerank_mult: int = 4,
) -> TopK:
    """Routed batch search over a ``bucket`` placement — the synchronous
    composition ``launch_routed(prepare_routed(...))``.

    Exact over the union of each query's selected buckets: the masked scan
    computes full distances (never prunes), so with nprobe == nlist this
    equals the exact full scan.  With a reduced-precision ``mirror`` the
    shard scan streams mirror-width bytes; the on-shard f32 re-rank keeps
    the merged candidates exact, and the wire stays f32 (see the module
    docstring for why rounding it breaks the k-boundary).  Returns a
    replicated (B, k) TopK.
    """
    return launch_routed(prepare_routed(
        mesh, placement, Q, sel, k, metric=metric, mirror=mirror,
        rerank_mult=rerank_mult,
    ))
