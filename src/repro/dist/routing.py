"""Bucket-routed distributed search: queries travel, tiles stay put.

The replicated-broadcast paths (``pdx_sharded``) send every query to every
shard and scan the whole striped store.  With a ``bucket`` placement
(``repro.dist.placement``) each shard *owns* a subset of the IVF buckets, so
a query only needs to visit the shards owning its top-``nprobe`` buckets —
the HARMONY-style routing the ROADMAP's "IVF bucket routing across hosts"
item calls for.  One batch flows through exactly two collectives:

1. **Route + exchange** — the router (``IVFIndex.route_batch``) ranks
   buckets per query; ``plan_routing`` turns that into a host-side exchange
   plan (which query goes to which owner shard, deduplicated).  Ragged
   per-shard query lists are padded to a static power-of-two *budget* (few
   distinct budgets => few retraces), queries and their selected bucket ids
   are packed into one buffer (int32 bucket ids bitcast to float32), and a
   single ``all_to_all`` delivers to each shard only the queries it owns
   buckets for.

2. **Masked local scan + hierarchical merge** — each shard scans *only its
   owned buckets* (its placement slice), masking each received query down to
   the buckets it actually selected, and keeps a shard-local top-k.  The
   per-shard (dists ‖ bitcast ids) candidate sets then cross the mesh in one
   packed ``all_gather`` (the PR 2 collective-packing trick), and the final
   per-query top-k merges only the candidate blocks from the shards that
   query was routed to.

Wire cost per batch: ``n² · budget · (D + nprobe)`` floats in the
all-to-all (budget shrinks with nprobe — fewer owner shards per query) plus
``n · n·budget · 2k`` floats in the all-gather, versus the broadcast path's
``n · B · D`` replicated queries + full-store scan on every shard.
"""
from __future__ import annotations

import collections
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from ..core.distance import batched_distance_matmul
from ..core.topk import TopK, topk_init, topk_merge
from .placement import Placement

__all__ = [
    "RoutingPlan",
    "plan_routing",
    "build_send_buffer",
    "make_routed_fn",
    "search_routed_bucket",
]

_INF = jnp.float32(jnp.inf)

# Sentinel bucket id for unused send slots: must match NO slot_bucket entry
# (pad slots carry -1, so -1 would wrongly select them).
_EMPTY_SEL = -2


def _pow2_at_least(x: int, lo: int = 1) -> int:
    c = lo
    while c < x:
        c *= 2
    return c


@dataclasses.dataclass(frozen=True)
class RoutingPlan:
    """Host-side exchange plan for one query batch.

    ``send_slot[s, t, j]`` — global query index source shard ``s`` puts in
    slot ``j`` of its message to shard ``t`` (-1 = unused pad slot).
    ``dest_shard``/``dest_slot`` (B, max_dest) — where each query's
    candidate blocks land after the all-gather (-1 pads).  ``src_of`` (B,)
    — the source shard each query originates on (contiguous split of the
    batch, mirroring how a (B, D) batch shards over the axis).
    """

    send_slot: np.ndarray
    dest_shard: np.ndarray
    dest_slot: np.ndarray
    src_of: np.ndarray
    budget: int       # static per-(src, dst) slot count (power of two)
    occupancy: int    # real (src, dst, slot) entries, for byte accounting


def plan_routing(
    sel: np.ndarray,
    bucket_shard: np.ndarray,
    bucket_parts: np.ndarray,
    n_shards: int,
) -> RoutingPlan:
    """Map each query's selected buckets onto owner shards.

    ``sel`` (B, nprobe) — ranked bucket ids per query.  Empty buckets own no
    partitions and are skipped (routing a query to their owner would move
    bytes for zero scan work).  The per-(src, dst) budget is the max real
    demand rounded up to a power of two, so shapes stay static across
    batches with similar routing pressure.
    """
    sel = np.asarray(sel)
    B = sel.shape[0]
    src_of = (np.arange(B, dtype=np.int64) * n_shards) // max(B, 1)
    dests = [
        np.unique(bucket_shard[sel[b][bucket_parts[sel[b]] > 0]])
        for b in range(B)
    ]
    max_dest = min(sel.shape[1], n_shards)
    counts = np.zeros((n_shards, n_shards), np.int64)
    for b, ds in enumerate(dests):
        counts[src_of[b], ds] += 1
    budget = _pow2_at_least(max(int(counts.max(initial=0)), 1))

    send_slot = np.full((n_shards, n_shards, budget), -1, np.int32)
    dest_shard = np.full((B, max_dest), -1, np.int32)
    dest_slot = np.full((B, max_dest), -1, np.int32)
    fill = np.zeros((n_shards, n_shards), np.int64)
    for b, ds in enumerate(dests):
        s = src_of[b]
        for j, t in enumerate(ds):
            slot = fill[s, t]
            fill[s, t] += 1
            send_slot[s, t, slot] = b
            dest_shard[b, j] = t
            dest_slot[b, j] = slot
    return RoutingPlan(
        send_slot=send_slot, dest_shard=dest_shard, dest_slot=dest_slot,
        src_of=src_of.astype(np.int32), budget=budget,
        occupancy=int(fill.sum()),
    )


def build_send_buffer(
    Q: np.ndarray, sel: np.ndarray, rp: RoutingPlan
) -> np.ndarray:
    """Pack (queries ‖ bitcast selected-bucket ids) into the single
    (n, n, budget, D + nprobe) float32 all-to-all payload."""
    Q = np.asarray(Q, np.float32)
    sel = np.asarray(sel, np.int32)
    n = rp.send_slot.shape[0]
    D, nprobe = Q.shape[1], sel.shape[1]
    send_q = np.zeros((n, n, rp.budget, D), np.float32)
    send_sel = np.full((n, n, rp.budget, nprobe), _EMPTY_SEL, np.int32)
    occ = rp.send_slot >= 0
    send_q[occ] = Q[rp.send_slot[occ]]
    send_sel[occ] = sel[rp.send_slot[occ]]
    return np.concatenate([send_q, send_sel.view(np.float32)], axis=-1)


# jitted routed executors keyed on their static configuration; every array
# (send buffer, tiles, routing indices) is a traced ARGUMENT, so one cache
# entry serves every batch / placement with the same shapes — repeated
# searches hit the jit executable instead of re-tracing the shard_map.
_ROUTED_CACHE: "collections.OrderedDict[tuple, object]" = (
    collections.OrderedDict()
)
_ROUTED_CACHE_MAX = 8


def _routed_exec(mesh, axis: str, D: int, nprobe: int, k: int, metric: str):
    key = (mesh, axis, D, nprobe, k, metric)
    if key in _ROUTED_CACHE:
        _ROUTED_CACHE.move_to_end(key)
        return _ROUTED_CACHE[key]

    def local(buf, d_sh, i_sh, pb_sh, dest_shard, dest_slot, src_of):
        # buf local: (1, n, budget, D + nprobe) — my messages, one per dest.
        n, budget = buf.shape[1], buf.shape[2]
        B = dest_shard.shape[0]
        recv = jax.lax.all_to_all(buf[0], axis, 0, 0, tiled=True)
        Bl = n * budget  # received queries, flat index = src * budget + slot
        Qr = recv[..., :D].reshape(Bl, D)
        selr = jax.lax.bitcast_convert_type(
            recv[..., D:], jnp.int32
        ).reshape(Bl, nprobe)
        # query q may scan local partition p iff p's bucket is one q selected
        allowed = (selr[:, :, None] == pb_sh[None, None, :]).any(axis=1)

        def body(state, inp):
            tile, tids, allow_p = inp  # (D, C), (C,), (Bl,)
            dmat = batched_distance_matmul(tile, Qr, metric)  # (Bl, C)
            dmat = jnp.where(allow_p[:, None], dmat, _INF)
            return jax.vmap(topk_merge, (0, 0, None))(state, dmat, tids), None

        init = jax.vmap(lambda _: topk_init(k))(jnp.arange(Bl))
        res, _ = jax.lax.scan(body, init, (d_sh, i_sh, allowed.T))

        packed = jnp.concatenate(
            [res.dists, jax.lax.bitcast_convert_type(res.ids, jnp.float32)],
            axis=1,
        )  # (Bl, 2k)
        allp = jax.lax.all_gather(packed, axis)  # (n_dst, Bl, 2k)

        # hierarchical merge (replicated): per query, only the candidate
        # blocks from the shards it was routed to.
        pad = dest_shard < 0                                     # (B, max_dest)
        t = jnp.maximum(dest_shard, 0)
        row = src_of[:, None] * budget + jnp.maximum(dest_slot, 0)
        cand = allp[t, row]                                      # (B, md, 2k)
        cd = jnp.where(pad[:, :, None], _INF, cand[..., :k]).reshape(B, -1)
        ci = jnp.where(
            pad[:, :, None], -1,
            jax.lax.bitcast_convert_type(cand[..., k:], jnp.int32),
        ).reshape(B, -1)
        merge = lambda dd, ii: topk_merge(topk_init(k), dd, ii)  # noqa: E731
        return jax.vmap(merge)(cd, ci)

    fn = jax.jit(shard_map(
        local,
        mesh=mesh,
        in_specs=(P(axis), P(axis), P(axis), P(axis), P(), P(), P()),
        out_specs=TopK(dists=P(), ids=P()),
        check_rep=False,
    ))
    _ROUTED_CACHE[key] = fn
    while len(_ROUTED_CACHE) > _ROUTED_CACHE_MAX:
        _ROUTED_CACHE.popitem(last=False)
    return fn


def make_routed_fn(mesh, placement: Placement, rp: RoutingPlan, D: int,
                   nprobe: int, k: int, metric: str = "l2"):
    """Bind the cached jitted routed executor to one (placement, routing
    plan): send_buffer -> (B, k) TopK.

    Exactly two collectives per call — one all_to_all (query exchange) and
    one packed all-gather (candidate merge) — independent of B and nprobe;
    ``collective_counts`` gates this in tests.
    """
    fn = _routed_exec(mesh, placement.axis, D, nprobe, k, metric)
    slot_bucket = jnp.asarray(placement.slot_bucket, jnp.int32)
    dest_shard = jnp.asarray(rp.dest_shard)
    dest_slot = jnp.asarray(rp.dest_slot)
    src_of = jnp.asarray(rp.src_of)
    return lambda buf: fn(
        buf, placement.data, placement.ids, slot_bucket,
        dest_shard, dest_slot, src_of,
    )


def search_routed_bucket(
    mesh,
    placement: Placement,
    Q: jax.Array,
    sel: np.ndarray,
    k: int,
    *,
    metric: str = "l2",
) -> TopK:
    """Routed batch search over a ``bucket`` placement.

    ``Q`` (B, D) — pruner-transformed queries; ``sel`` (B, nprobe) — ranked
    bucket ids per query (``IVFIndex.route_batch``).  Exact over the union
    of each query's selected buckets: the masked scan computes full
    distances (never prunes), so with nprobe == nlist this equals the exact
    full scan.  Returns a replicated (B, k) TopK.
    """
    if placement.kind != "bucket":
        raise ValueError(
            f"routed search needs a 'bucket' placement, got {placement.kind!r}"
        )
    Qnp = np.asarray(Q, np.float32)
    selnp = np.asarray(sel, np.int32)
    rp = plan_routing(
        selnp, placement.bucket_shard, placement.bucket_parts,
        placement.n_shards,
    )
    buf = build_send_buffer(Qnp, selnp, rp)
    fn = make_routed_fn(
        mesh, placement, rp, Qnp.shape[1], selnp.shape[1], k, metric
    )
    return fn(jnp.asarray(buf))
