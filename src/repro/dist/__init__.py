"""repro.dist — the distributed substrate: sharding rules, activation
hints, tile placement, sharded + bucket-routed PDXearch, and pipeline
parallelism.

Architecture
============

Two orthogonal questions structure the package: **which mesh axis** a value
crosses (below), and — for the vector store — **how tiles map onto that
axis**, which is an explicit ``placement.Placement`` value rather than
ad-hoc striping inside each executor.

Mesh axes (see ``repro.launch.mesh``):

  * ``pod``   — outermost data parallelism across pods; gradients cross this
    axis through the int8-compressed all-reduce (``repro.train.compression``).
  * ``data``  — FSDP + batch data parallelism within a pod.  Batches shard
    their leading dim over ``("pod", "data")`` (largest divisible suffix —
    outermost axes drop first, see ``sharding.batch_pspec``); PDX partitions
    ("blocks") map onto ``data`` through a ``Placement``.
  * ``model`` — tensor parallelism (Megatron-style column/row pairing) and
    expert parallelism for MoE; PDX *dimension* slices shard over ``model``
    in ``pdx_sharded.search_dim_sharded`` — the same axis split, because the
    PDX tile is dimension-major (paper Fig. 1) a dimension shard is a
    contiguous row slab of every tile.
  * ``stage`` — pipeline parallelism (``pipeline.pipeline_apply``): each
    device owns one stage's weights; microbatches flow through ``ppermute``.

Tile placements (``placement.Placement``) on the ``data`` axis:

  kind         tiles per shard              who visits whom
  ------------ ---------------------------- --------------------------------
  replicated   all of them                  queries stay put (dim-sharded
                                            search shards D inside the tile)
  block        a contiguous 1/n stripe,     every query visits every shard:
               padded to divisibility       per-query or per-batch top-k
                                            all-gather (``pdx_sharded``)
  bucket       its *owned* IVF buckets      queries visit only the shards
               (greedy size-balanced        owning their top-nprobe buckets:
               bucket -> shard assignment)  one all-to-all + one packed
                                            all-gather per batch
                                            (``routing``)

``block`` mirrors-or-stripes the store and broadcasts queries — fine for
exact scans, but the "replicated broadcast" anti-pattern for IVF serving.
``bucket`` inverts it: the store stays put, partitioned by ownership, and
the *queries* move, each to the few shards that can answer it.  The router
(``routing.plan_routing``) pads the ragged per-shard query lists to a
static power-of-two budget, packs queries with their selected bucket ids
into one bitcast buffer, and each shard scans only its owned buckets with a
per-query bucket mask; candidates merge hierarchically — shard-local top-k,
then one packed (dists ‖ bitcast ids) all-gather.  Placements are cached on
the store keyed by ``(tiles_version, n_shards, kind)`` (``core.plan``), so
a mutable store's head-only inserts never re-arrange the mesh layout and a
repack invalidates it exactly once.

Which sharding rule fires for which param family (``sharding.param_pspec``):

  family                          example leaves              spec (body)
  ------------------------------- --------------------------- ----------------
  column-parallel projections     wq wk wv w_gate w_up        ("data","model")
                                  w_dkv w_kr w_dq in_proj
                                  router
  row-parallel projections        wo w_down out_proj          ("model","data")
  head-stacked MLA tensors        w_uk w_uv w_uq w_q          ("data","model",None)
  routed-expert tensors (E,d,f)   w_gate w_up [w_down]        ("model","data",None)
  token embedding (V,d)           embed                       ("model","data")
  output head (d,V)               lm_head                     ("data","model")
  biases (last-dim features)      bq bk bv router_bias conv_b (...,"model")
  norms / scalars / ssm decay     *norm* A_log D              replicated

Stacked layer params (under a ``stack{i}`` key) carry a leading unit axis
that is never sharded: the body spec above is prefixed with ``None``.  Every
spec passes through the ``_divisible`` guard, which drops mesh axes whose
size does not divide the corresponding dim (and axes absent from the mesh),
so the same rules serve the (16,16) production pod, the (2,4) test mesh, and
a single CPU device.

Activation hints (``hints``) are ``with_sharding_constraint`` anchors inside
an ``activation_sharding(mesh, batch_axes)`` context and exact identities
outside it — model code calls them unconditionally and stays mesh-agnostic.
"""
from . import hints, pdx_sharded, pipeline, placement, routing, sharding

__all__ = [
    "hints", "pdx_sharded", "pipeline", "placement", "routing", "sharding",
]
