"""Placement — how PDX tiles map onto a device mesh.

Before this module every sharded executor re-derived its own striping and
padding from raw ``(data, ids)`` arrays; now that mapping is an explicit,
checked value.  A ``Placement`` owns the arranged-and-padded tile arrays plus
the metadata the executors and the query router need:

* ``replicated``   — every shard holds every tile (the dimension-sharded
  executor shards the *D* axis inside the tile instead; tiles replicate).
* ``block``        — partitions stripe contiguously over the mesh axis,
  padded with empty tiles to divisibility (the old
  ``pad_partitions_to_shards`` folded in).  Exact for every executor: a pad
  tile is all-``PAD_VALUE`` with ids ``-1``, so it can never rank into a
  top-k.
* ``bucket``       — bucket-*owned* sharding for IVF stores: a greedy
  size-balanced assignment gives each IVF bucket exactly one owner shard,
  partitions are permuted so each shard's slice is its owned buckets
  (bucket-contiguous within the slice), and per-slot bucket ids let a shard
  mask its scan down to the buckets a routed query selected.  This is the
  layout half of HARMONY-style distributed ANN: queries travel to the few
  shards owning their top-``nprobe`` buckets (see ``repro.dist.routing``)
  instead of the store being mirrored everywhere.

All builders end with ``check()`` — structural invariants (divisibility,
each partition placed exactly once, one owner shard per bucket, greedy load
balance) fail loudly at build time instead of as silent wrong answers.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..core.layout import PAD_VALUE

__all__ = ["Placement", "assign_buckets"]


def assign_buckets(bucket_parts: np.ndarray, n_shards: int) -> np.ndarray:
    """Greedy size-balanced bucket -> shard assignment.

    Buckets are placed largest-first (by partition count) onto the currently
    least-loaded shard, so ``max_load - min_load`` never exceeds the largest
    single bucket — the classic LPT bound.  Ties break on lower bucket /
    shard id, which keeps the assignment deterministic across processes
    (every host must derive the identical placement).

    Two consumers share this map: the bucket-owned ``Placement`` (each
    shard holds its buckets' mirror slices resident) and the tiered
    ``routed_tiered`` executor, which reuses the same assignment as the
    ``BucketCache`` region map — shard r's cache region only ever holds
    buckets assigned to r, so a routed query's prefetch lands exactly on
    the shards its scan will run on.
    """
    bucket_parts = np.asarray(bucket_parts, np.int64)
    order = np.argsort(-bucket_parts, kind="stable")  # largest first, id ties
    shard_of = np.empty(len(bucket_parts), np.int64)
    load = np.zeros(n_shards, np.int64)
    for b in order:
        s = int(np.argmin(load))  # argmin takes the lowest index on ties
        shard_of[b] = s
        load[s] += bucket_parts[b]
    return shard_of


@dataclasses.dataclass(frozen=True)
class Placement:
    """One arranged mapping of a store's tiles onto ``n_shards`` mesh shards.

    ``data``/``ids`` are the tiles as the executors consume them: for
    ``block``/``bucket`` the partition axis is permuted + padded so shard
    ``s`` owns the contiguous slice ``[s * parts_per_shard, (s + 1) *
    parts_per_shard)`` under a ``PartitionSpec(axis)``; for ``replicated``
    they are the source arrays untouched.

    ``part_perm[i]`` is the source partition sitting in slot ``i`` (-1 for a
    pad tile); ``slot_bucket[i]`` / ``bucket_shard[b]`` / ``bucket_parts[b]``
    carry the bucket structure for ``bucket`` placements (None otherwise).
    """

    kind: str                    # "replicated" | "block" | "bucket"
    axis: str                    # mesh axis the tiles map onto
    n_shards: int
    data: jax.Array              # (P', D, C)
    ids: jax.Array               # (P', C)
    part_perm: np.ndarray        # (P',) source partition per slot, -1 = pad
    bucket_shard: Optional[np.ndarray] = None   # (K,) owner shard per bucket
    slot_bucket: Optional[np.ndarray] = None    # (P',) bucket per slot, -1 pad
    bucket_parts: Optional[np.ndarray] = None   # (K,) partitions per bucket
    # arranged quantized-mirror tiles, cached per mirror dtype (the dict is
    # mutable inside the frozen dataclass by design: a placement is itself
    # cached per tiles_version, so entries can never outlive their tiles)
    _mirrors: dict = dataclasses.field(
        default_factory=dict, compare=False, repr=False
    )

    # ------------------------------------------------------------- properties
    @property
    def num_slots(self) -> int:
        return int(self.data.shape[0])

    @property
    def parts_per_shard(self) -> int:
        return self.num_slots // self.n_shards if self.kind != "replicated" \
            else self.num_slots

    # ------------------------------------------------------------- builders
    @classmethod
    def replicated(
        cls, data: jax.Array, ids: jax.Array, n_shards: int, axis: str = "model"
    ) -> "Placement":
        """Tiles present on every shard (dim-sharded / single-host use)."""
        pl = cls(
            kind="replicated", axis=axis, n_shards=n_shards,
            data=data, ids=ids,
            part_perm=np.arange(data.shape[0], dtype=np.int64),
        )
        pl.check()
        return pl

    @classmethod
    def block(
        cls, data: jax.Array, ids: jax.Array, n_shards: int, axis: str = "data"
    ) -> "Placement":
        """Contiguous partition striping, padded to divisibility with empty
        tiles.  Slot order == source order, so for an already-divisible store
        this is exactly the pre-Placement behavior (no copy, no permute)."""
        n_parts = data.shape[0]
        rem = (-n_parts) % n_shards
        perm = np.concatenate(
            [np.arange(n_parts, dtype=np.int64), np.full(rem, -1, np.int64)]
        )
        if rem:
            pad_d = jnp.full((rem,) + data.shape[1:], PAD_VALUE, data.dtype)
            pad_i = jnp.full((rem,) + ids.shape[1:], -1, ids.dtype)
            data = jnp.concatenate([data, pad_d], axis=0)
            ids = jnp.concatenate([ids, pad_i], axis=0)
        pl = cls(
            kind="block", axis=axis, n_shards=n_shards,
            data=data, ids=ids, part_perm=perm,
        )
        pl.check()
        return pl

    @classmethod
    def bucket(
        cls,
        data: jax.Array,
        ids: jax.Array,
        part_bucket: np.ndarray,
        num_buckets: int,
        n_shards: int,
        axis: str = "data",
    ) -> "Placement":
        """Bucket-owned sharding: ``part_bucket[p]`` is the IVF bucket of
        source partition ``p`` (-1 marks all-pad placeholder tiles, which are
        dropped — they hold no live vectors).  Each bucket lands wholly on
        one shard (greedy size-balanced), each shard's slice lists its
        buckets ascending with their partitions contiguous, and every shard
        is padded to the widest shard's slot count.

        The width padding is bounded by the greedy balance: at most one
        extra largest-bucket's worth of pad tiles per shard (LPT bound
        checked in ``check()``).  With many buckets per shard (nlist >>
        n_shards, the normal IVF regime) the waste is marginal; with nlist
        close to n_shards or heavily skewed clusters it can approach the
        largest bucket per shard — pad tiles are scanned (masked to inf),
        so prefer nlist >= a few x n_shards when sharding by bucket."""
        part_bucket = np.asarray(part_bucket, np.int64)
        if len(part_bucket) != data.shape[0]:
            raise ValueError(
                f"part_bucket covers {len(part_bucket)} partitions, store has "
                f"{data.shape[0]}"
            )
        bucket_parts = np.bincount(
            part_bucket[part_bucket >= 0], minlength=num_buckets
        ).astype(np.int64)
        bucket_shard = assign_buckets(bucket_parts, n_shards)

        shard_slots: list[list[int]] = [[] for _ in range(n_shards)]
        for b in range(num_buckets):  # ascending bucket id within each shard
            (parts,) = np.nonzero(part_bucket == b)
            shard_slots[int(bucket_shard[b])].extend(parts.tolist())
        width = max(1, max(len(sl) for sl in shard_slots))
        perm = np.full(n_shards * width, -1, np.int64)
        for s, sl in enumerate(shard_slots):
            perm[s * width : s * width + len(sl)] = sl

        safe = np.maximum(perm, 0)
        pad = perm < 0
        data_arr = jnp.asarray(data)[jnp.asarray(safe)]
        ids_arr = jnp.asarray(ids)[jnp.asarray(safe)]
        data_arr = jnp.where(
            jnp.asarray(pad)[:, None, None], jnp.asarray(PAD_VALUE, data.dtype),
            data_arr,
        )
        ids_arr = jnp.where(jnp.asarray(pad)[:, None], -1, ids_arr)
        slot_bucket = np.where(pad, -1, part_bucket[safe])

        pl = cls(
            kind="bucket", axis=axis, n_shards=n_shards,
            data=data_arr, ids=ids_arr, part_perm=perm,
            bucket_shard=bucket_shard, slot_bucket=slot_bucket,
            bucket_parts=bucket_parts,
        )
        pl.check()
        return pl

    # --------------------------------------------------------- mirror tiles
    def arrange(self, tiles: jax.Array, pad_value=0) -> jax.Array:
        """Apply this placement's slot permutation + padding to ANY (P, D, C)
        tile stack — the primitive that lets a reduced-precision device
        mirror (``core.layout.device_mirror``) ride the same tile->shard
        mapping as the f32 masters.  Pad slots are filled with ``pad_value``
        (their arranged ``ids`` are -1, which is what every quantized
        consumer masks on — int8 has no monotone PAD sentinel)."""
        if self.kind == "replicated":
            return tiles
        perm = self.part_perm
        if len(perm) == tiles.shape[0] and (perm == np.arange(len(perm))).all():
            return tiles  # already-divisible block placement: untouched
        safe = np.maximum(perm, 0)
        arranged = jnp.asarray(tiles)[jnp.asarray(safe)]
        pad = jnp.asarray(perm < 0)
        return jnp.where(
            pad[:, None, None],
            jnp.asarray(pad_value, tiles.dtype),
            arranged,
        )

    def arranged_mirror(self, mirror) -> jax.Array:
        """``arrange(mirror.data)``, cached per mirror dtype + version."""
        got = self._mirrors.get(mirror.dtype)
        if got is None or got[0] != mirror.tiles_version:
            got = (mirror.tiles_version, self.arrange(mirror.data))
            self._mirrors[mirror.dtype] = got
        return got[1]

    # ------------------------------------------------------------ invariants
    def check(self) -> None:
        """Structural invariants; raises ValueError on the first violation."""
        if self.kind not in ("replicated", "block", "bucket"):
            raise ValueError(f"unknown placement kind {self.kind!r}")
        if self.data.shape[0] != self.ids.shape[0] or \
                self.data.shape[0] != len(self.part_perm):
            raise ValueError("data/ids/part_perm slot counts disagree")
        real = self.part_perm[self.part_perm >= 0]
        if len(np.unique(real)) != len(real):
            raise ValueError("a source partition is placed more than once")
        if self.kind == "replicated":
            return
        if self.num_slots % self.n_shards:
            raise ValueError(
                f"{self.num_slots} slots not divisible over "
                f"{self.n_shards} shards"
            )
        if self.kind == "bucket":
            if self.bucket_shard is None or self.slot_bucket is None:
                raise ValueError("bucket placement missing bucket metadata")
            width = self.parts_per_shard
            owner_of_slot = np.arange(self.num_slots) // width
            live = self.slot_bucket >= 0
            if not (self.bucket_shard[self.slot_bucket[live]]
                    == owner_of_slot[live]).all():
                raise ValueError("a bucket's partitions span shard slices")
            load = np.bincount(
                self.bucket_shard, weights=self.bucket_parts,
                minlength=self.n_shards,
            )
            bound = max(int(self.bucket_parts.max(initial=0)), 1)
            if load.max() - load.min() > bound:
                raise ValueError(
                    f"greedy balance violated: loads {load} vs max bucket "
                    f"{bound}"
                )
