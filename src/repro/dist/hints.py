"""Activation-sharding hints: ``with_sharding_constraint`` anchors that are
exact identities outside an ``activation_sharding`` context.

Model code calls these unconditionally (residual stream, attention heads,
FFN hidden) and stays mesh-agnostic: off-mesh — single CPU device, unit
tests, eager eval — every hint returns its input unchanged.  Inside the
context the hint re-anchors the activation's layout so GSPMD keeps the
Megatron pattern (batch over the data axes, heads / FFN hidden over model,
residual stream replicated over model) instead of resharding mid-layer.
"""
from __future__ import annotations

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from .sharding import _batch_entry, _divisible, data_axes

__all__ = ["act", "activation_sharding", "ffn_hidden", "heads"]

# Stack of (mesh, batch_axes) contexts; empty means hints are identities.
_ACTIVE: list[tuple] = []


class activation_sharding:
    """Context manager activating the hints on ``mesh``.

    ``batch_axes`` — mesh axes the activations' batch dim shards over
    (defaults to the mesh's data axes).
    """

    def __init__(self, mesh, batch_axes=None):
        self.mesh = mesh
        self.batch_axes = (
            tuple(batch_axes) if batch_axes is not None else data_axes(mesh)
        )

    def __enter__(self):
        _ACTIVE.append((self.mesh, self.batch_axes))
        return self

    def __exit__(self, *exc):
        _ACTIVE.pop()
        return False


def _hint(x: jax.Array, body: tuple) -> jax.Array:
    """Constrain ``x`` to P(batch, *body) under the active context."""
    if not _ACTIVE:
        return x
    mesh, baxes = _ACTIVE[-1]
    spec = (_batch_entry(baxes),) + body
    spec = _divisible(P(*spec), x.shape, mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def act(x: jax.Array) -> jax.Array:
    """Residual stream (B, S, d): batch over data, d replicated (TP keeps
    the residual unsharded; column/row weight pairing reduces into it)."""
    return _hint(x, (None,) * (x.ndim - 1))


def heads(x: jax.Array) -> jax.Array:
    """Per-head activations (B, S, H, hd): heads over the model axis."""
    if x.ndim == 4:
        return _hint(x, (None, "model", None))
    return _hint(x, (None,) * (x.ndim - 1))


def ffn_hidden(h: jax.Array) -> jax.Array:
    """FFN hidden (B, S, f): the column-parallel output dim over model."""
    return _hint(h, (None,) * (h.ndim - 2) + ("model",))
