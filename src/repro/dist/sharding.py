"""Declarative sharding rules: param / batch / decode-cache partition specs.

The rules are name-based (the model zoo uses consistent leaf names across
architectures — see the family table in the package docstring) and every
spec passes through the ``_divisible`` guard before becoming a
``NamedSharding``, so the same rule set works on any mesh shape.
"""
from __future__ import annotations

import math
from typing import Iterable

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

__all__ = [
    "DATA_AXES",
    "batch_pspec",
    "batch_shardings",
    "cache_pspecs",
    "cache_shardings",
    "data_axes",
    "param_pspec",
    "param_shardings",
    "strip_axes",
]

# Mesh axes that carry (pure or FSDP) data parallelism, outermost first.
DATA_AXES = ("pod", "data")


def data_axes(mesh) -> tuple[str, ...]:
    """The mesh's data-parallel axis names, outermost first."""
    return tuple(a for a in mesh.axis_names if a in DATA_AXES)


def _axes_of(entry) -> tuple[str, ...]:
    return entry if isinstance(entry, tuple) else (entry,)


def _divisible(spec: P, shape: tuple[int, ...], mesh) -> P:
    """Divisibility guard: per dim, drop mesh axes absent from ``mesh`` and,
    if the remaining axis-size product does not divide the dim, drop the
    whole entry.  ``mesh`` only needs a ``.shape`` mapping (duck-typed)."""
    entries = list(spec) + [None] * (len(shape) - len(spec))
    out = []
    for size, entry in zip(shape, entries):
        if entry is None:
            out.append(None)
            continue
        names = tuple(n for n in _axes_of(entry) if n in mesh.shape)
        prod = math.prod(mesh.shape[n] for n in names)
        if not names or size % prod != 0:
            out.append(None)
        elif isinstance(entry, tuple):
            out.append(names)
        else:
            out.append(names[0])
    return P(*out)


def _batch_entry(axes: Iterable[str]):
    axes = tuple(axes)
    if not axes:
        return None
    return axes[0] if len(axes) == 1 else axes


# --------------------------------------------------------------------------
# Batch rules.
# --------------------------------------------------------------------------
def batch_pspec(mesh, global_batch: int) -> P:
    """Shard the batch dim over the largest divisible suffix of the data
    axes (drop outermost first: a batch too small for pod x data still
    shards over data alone)."""
    axes = list(data_axes(mesh))
    while axes and global_batch % math.prod(mesh.shape[a] for a in axes):
        axes.pop(0)
    if not axes:
        return P()
    return P(_batch_entry(axes))


def batch_shardings(batch, mesh):
    """Tree of NamedShardings: leading dim is the batch dim, rest replicated."""

    def one(leaf):
        if not getattr(leaf, "shape", ()):
            return NamedSharding(mesh, P())
        return NamedSharding(mesh, batch_pspec(mesh, leaf.shape[0]))

    return jax.tree.map(one, batch)


# --------------------------------------------------------------------------
# Param rules.
# --------------------------------------------------------------------------
_COL_2D = {
    "wq", "wk", "wv", "w_gate", "w_up", "w_dkv", "w_kr", "w_dq",
    "in_proj", "router",
}
_ROW_2D = {"wo", "w_down", "out_proj"}
_HEAD_3D = {"w_uk", "w_uv", "w_uq", "w_q"}
_SCALAR = {"A_log", "D", "norm_w"}


def param_pspec(path, leaf, cfg) -> P:
    """PartitionSpec for one param leaf, keyed on its tree path.

    Stacked layer params (under a ``stack{i}`` key) get a leading ``None``
    for the unit axis; the body follows the family table in the package
    docstring.  The result is *unguarded* — callers run ``_divisible``.
    """
    keys = [str(getattr(p, "key", getattr(p, "idx", p))) for p in path]
    name = keys[-1]
    stacked = bool(keys) and keys[0].startswith("stack")
    lead = (None,) if stacked else ()
    r = len(leaf.shape) - len(lead)

    if name == "embed":
        body = ("model", "data")
    elif name == "lm_head":
        body = ("data", "model")
    elif name.endswith("norm") or name in _SCALAR:
        body = (None,) * r
    elif name in ("w_gate", "w_up") and r == 3:    # routed experts (E, d, f)
        body = ("model", "data", None)
    elif name == "w_down" and r == 3:              # routed experts (E, f, d)
        body = ("model", None, "data")
    elif name in _HEAD_3D and r == 3:              # (d_in, H, head_feat)
        body = ("data", "model", None)
    elif name in _ROW_2D and r == 2:
        body = ("model", "data")
    elif name in _COL_2D and r == 2:
        body = ("data", "model")
    elif name == "conv_w":                         # (K, conv_dim)
        body = (None, "model")
    elif name.endswith(("_bias", "_b")) or (name.startswith("b") and r == 1):
        body = (None,) * (r - 1) + ("model",)
    else:
        body = (None,) * r
    return P(*(lead + body))


def param_shardings(params, mesh, cfg):
    """Full param tree -> NamedShardings (rules + divisibility guard)."""

    def one(path, leaf):
        spec = _divisible(param_pspec(path, leaf, cfg), leaf.shape, mesh)
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map_with_path(one, params)


# --------------------------------------------------------------------------
# Decode-cache rules.
# --------------------------------------------------------------------------
def cache_pspecs(caches, mesh, cfg):
    """Decode caches: leading unit axis replicated, batch dim (axis 1) over
    the data axes, KV-head axis of (L, B, S, Hkv, hd) leaves over model."""
    baxes = _batch_entry(data_axes(mesh))

    def one(leaf):
        shape = leaf.shape
        if len(shape) < 2:
            return P()
        spec = [None, baxes] + [None] * (len(shape) - 2)
        if len(shape) == 5 and shape[3] == getattr(cfg, "n_kv_heads", 0):
            spec[3] = "model"
        return _divisible(P(*spec), shape, mesh)

    return jax.tree.map(one, caches)


def cache_shardings(caches, mesh, cfg):
    """``cache_pspecs`` as NamedShardings (the jit in_shardings form)."""
    return jax.tree.map(
        lambda spec: NamedSharding(mesh, spec),
        cache_pspecs(caches, mesh, cfg),
        is_leaf=lambda s: isinstance(s, P),
    )


# --------------------------------------------------------------------------
# Spec surgery.
# --------------------------------------------------------------------------
def strip_axes(shardings, axes: Iterable[str]):
    """Remove the named mesh axes from every sharding in the tree (e.g. the
    weight-stationary serving layout: params TP-sharded, FSDP axes gone)."""
    axes = set(axes)

    def one(s):
        entries = []
        for e in s.spec:
            if e is None:
                entries.append(None)
            elif isinstance(e, tuple):
                kept = tuple(n for n in e if n not in axes)
                entries.append(kept if kept else None)
            else:
                entries.append(None if e in axes else e)
        return NamedSharding(s.mesh, P(*entries))

    return jax.tree.map(
        one, shardings, is_leaf=lambda x: isinstance(x, NamedSharding)
    )
