"""Microbatch pipeline parallelism over a ``stage`` mesh axis (GPipe
schedule, shard_map + ppermute).

Each device owns one stage's weights (the leading axis of ``stage_params``
shards over the axis).  Microbatch ``m`` enters stage 0 at step ``m`` and
exits stage ``S-1`` at step ``m + S - 1``; the schedule runs
``n_micro + n_stages - 1`` steps with activations shifting one stage per
step through ``ppermute``.  Bubble fraction: ``(S-1) / (n_micro + S - 1)``.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

__all__ = ["pipeline_apply"]


def pipeline_apply(
    mesh,
    stage_fn: Callable,
    stage_params,
    x: jax.Array,
    *,
    axis: str = "stage",
) -> jax.Array:
    """Run ``x`` (n_micro, mb, ...) through ``n_stages`` pipelined stages.

    ``stage_fn(w, xb) -> yb`` applies one stage to one microbatch;
    ``stage_params`` is a pytree whose leaves carry a leading stage axis of
    size ``mesh.shape[axis]``.  Output shapes must equal input shapes
    (residual-block pipelines).  Returns the (n_micro, mb, ...) outputs,
    replicated — numerically identical to applying the stages sequentially.
    """
    n_stages = mesh.shape[axis]
    n_micro = x.shape[0]
    leading = {leaf.shape[0] for leaf in jax.tree.leaves(stage_params)}
    if leading != {n_stages}:
        raise ValueError(
            f"stage_params leading dims {sorted(leading)} must all equal the "
            f"'{axis}' axis size {n_stages} (one stage per device)"
        )
    last = n_stages - 1
    ring = [(i, (i + 1) % n_stages) for i in range(n_stages)]

    def local(w, xs):
        w = jax.tree.map(lambda a: a[0], w)  # this device's stage weights
        sidx = jax.lax.axis_index(axis)
        carry = jnp.zeros_like(xs[0])
        out = jnp.zeros_like(xs)
        for t in range(n_micro + n_stages - 1):
            feed = xs[t] if t < n_micro else jnp.zeros_like(xs[0])
            inp = jnp.where(sidx == 0, feed, carry)
            y = stage_fn(w, inp)
            m = t - last
            if 0 <= m < n_micro:  # the last stage emits microbatch m now
                out = out.at[m].set(jnp.where(sidx == last, y, out[m]))
            carry = jax.lax.ppermute(y, axis, ring)
        # Only the last stage holds real outputs; psum replicates them.
        return jax.lax.psum(
            jnp.where(sidx == last, out, jnp.zeros_like(out)), axis
        )

    fn = shard_map(
        local,
        mesh=mesh,
        in_specs=(P(axis), P()),
        out_specs=P(),
        check_rep=False,
    )
    return fn(stage_params, x)
