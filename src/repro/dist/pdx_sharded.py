"""Distributed PDXearch over a device mesh — both natural decompositions of
the dimension-major layout:

* ``search_block_sharded`` — partitions (PDX blocks) shard over the ``data``
  axis: each device runs the masked jitted PDXearch on its local tiles, then
  the per-shard top-k sets are all-gathered and merged.  Exact for exact
  pruners (wire cost: ``n_dev * k`` floats+ids per query).

* ``search_dim_sharded`` — *dimension slices* shard over the ``model`` axis:
  each device accumulates partial distances over its contiguous row slab of
  every tile (a dimension shard of a PDX tile is contiguous — paper Fig. 1),
  one psum completes the distances, then a single top-k finishes.  Exact for
  all metrics whose distance decomposes over dimensions (l2 / l1 / ip).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from ..core.distance import pdx_distance
from ..core.pdxearch import _pdxearch_jit_impl, make_boundaries
from ..core.pruners import Pruner, make_plain_pruner
from ..core.topk import TopK, topk_init, topk_merge

__all__ = ["search_block_sharded", "search_dim_sharded"]


def search_block_sharded(
    mesh,
    data: jax.Array,
    ids: jax.Array,
    q: jax.Array,
    k: int,
    *,
    metric: str = "l2",
    pruner: Pruner | None = None,
    schedule: str = "adaptive",
    delta_d: int = 32,
    axis: str = "data",
) -> TopK:
    """Partition-sharded PDXearch: ``data`` (P, D, C) and ``ids`` (P, C)
    shard their leading (partition) dim over ``axis``; the query is
    replicated.  Returns a replicated TopK."""
    pruner = pruner or make_plain_pruner()
    n_shards = mesh.shape[axis]
    if data.shape[0] % n_shards:
        raise ValueError(
            f"{data.shape[0]} partitions not divisible over {n_shards} "
            f"'{axis}' shards"
        )
    bounds = make_boundaries(data.shape[1], schedule, delta_d)

    def local(d_sh, i_sh, q_rep):
        qt = pruner.transform_query(q_rep.astype(jnp.float32))
        perm = (
            pruner.dim_order(qt)
            if pruner.dim_order is not None
            else jnp.arange(d_sh.shape[1], dtype=jnp.int32)
        )
        res = _pdxearch_jit_impl(
            d_sh, i_sh, qt, perm, k, metric, bounds, pruner.keep_mask
        )
        all_d = jax.lax.all_gather(res.dists, axis, tiled=True)
        all_i = jax.lax.all_gather(res.ids, axis, tiled=True)
        return topk_merge(topk_init(k), all_d, all_i)

    fn = shard_map(
        local,
        mesh=mesh,
        in_specs=(P(axis), P(axis), P()),
        out_specs=TopK(dists=P(), ids=P()),
        check_rep=False,
    )
    return fn(data, ids, q)


def search_dim_sharded(
    mesh,
    data: jax.Array,
    ids: jax.Array,
    q: jax.Array,
    k: int,
    *,
    metric: str = "l2",
    axis: str = "model",
) -> TopK:
    """Dimension-sharded exact search: ``data`` (P, D, C) shards its D axis
    over ``axis`` (the query shards alongside), partial distances are
    psum'd, and one top-k over all candidates finishes the query."""
    n_shards = mesh.shape[axis]
    if data.shape[1] % n_shards:
        raise ValueError(
            f"D={data.shape[1]} not divisible over {n_shards} '{axis}' shards"
        )

    def local(d_sh, q_sh):
        part = jax.vmap(lambda t: pdx_distance(t, q_sh, metric))(d_sh)
        return jax.lax.psum(part, axis)  # (P, C) full distances, replicated

    dmat = shard_map(
        local,
        mesh=mesh,
        in_specs=(P(None, axis), P(axis)),
        out_specs=P(),
        check_rep=False,
    )(data, q.astype(jnp.float32))
    return topk_merge(topk_init(k), dmat.reshape(-1), ids.reshape(-1))
