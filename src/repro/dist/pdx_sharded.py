"""Distributed PDXearch over a device mesh — the natural decompositions of
the dimension-major layout, all expressed against a ``Placement``
(``repro.dist.placement``) that owns the tile->shard mapping:

* ``search_block_sharded`` — partitions (PDX blocks) stripe over the ``data``
  axis (a ``block`` placement): each device runs the masked jitted PDXearch
  on its local tiles, then the per-shard top-k sets are all-gathered and
  merged.  Exact for exact pruners (wire cost: ``n_dev * k`` floats+ids per
  query).

* ``search_dim_sharded`` — *dimension slices* shard over the ``model`` axis
  while the tiles replicate (a ``replicated`` placement): each device
  accumulates partial distances over its contiguous row slab of every tile
  (a dimension shard of a PDX tile is contiguous — paper Fig. 1), one psum
  completes the distances, then a single top-k finishes.  Exact for all
  metrics whose distance decomposes over dimensions (l2 / l1 / ip).

* ``search_batch_block_sharded`` — the batched distributed search: the MXU
  batch scan (``core.pdxearch.search_batch_matmul``) runs on each device's
  partition shard, then the per-shard (B, k) candidate sets cross the mesh
  in ONE packed all-gather per query *batch* (dists and bitcast ids share
  the collective), amortizing the merge latency that the per-query path
  pays B times.  The planner (``repro.core.plan``) picks this automatically
  when a mesh and B > 1 are present.

Padding to mesh divisibility lives in ``Placement.block`` (the former
``pad_partitions_to_shards``, kept below as a thin compatibility wrapper):
executors never re-derive striping themselves.  The bucket-*routed* search
— queries traveling to the shards that own their IVF buckets instead of the
store being mirrored — lives in ``repro.dist.routing`` on top of a
``bucket`` placement.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from ..core.pdxearch import (
    _pdxearch_jit_impl,
    _pdxearch_jit_stats_impl,
    make_boundaries,
    search_batch_matmul,
)
from ..core.distance import batched_distance_matmul, pdx_distance
from ..core.pruners import Pruner, make_plain_pruner
from ..core.topk import TopK, rerank_positions, topk_init, topk_merge
from ..kernels.ref import dequantize_ref
from .placement import Placement

__all__ = [
    "pad_partitions_to_shards",
    "search_block_sharded",
    "search_dim_sharded",
    "search_batch_block_sharded",
    "collective_counts",
]


def pad_partitions_to_shards(
    data: jax.Array, ids: jax.Array, n_shards: int
) -> tuple[jax.Array, jax.Array]:
    """Round the partition axis up to a multiple of ``n_shards`` with empty
    (all-``PAD_VALUE``, ids ``-1``) tiles.

    Compatibility wrapper: padding is owned by ``Placement.block`` now; this
    keeps the old array-in/array-out shape for direct callers.  Padding tiles
    rank nothing into a top-k (the pad sentinel is monotonically far away and
    ``topk_merge`` discards ids < 0), so the sharded result stays
    bit-identical to the unpadded scan.
    """
    pl = Placement.block(data, ids, n_shards)
    return pl.data, pl.ids


def _require(**named) -> None:
    """Explicit required-argument check: ``data``/``ids`` became optional so
    callers can pass a prebuilt ``placement=`` instead, but the query and k
    are always required — fail here with a clear TypeError rather than
    deep inside a trace."""
    for name, val in named.items():
        if val is None:
            raise TypeError(f"missing required argument: {name!r}")


def _block_placement(
    mesh, data, ids, axis: str, placement: Placement | None
) -> Placement:
    """Resolve the tile placement for a block-sharded executor: callers pass
    either raw (data, ids) arrays — striped + padded here — or a prebuilt
    (typically cached, see ``core.plan``) ``block``/``bucket`` placement."""
    if placement is None:
        return Placement.block(data, ids, mesh.shape[axis], axis=axis)
    if placement.n_shards != mesh.shape[axis]:
        raise ValueError(
            f"placement built for {placement.n_shards} shards, mesh axis "
            f"'{axis}' has {mesh.shape[axis]}"
        )
    return placement


def search_block_sharded(
    mesh,
    data: jax.Array | None = None,
    ids: jax.Array | None = None,
    q: jax.Array | None = None,
    k: int | None = None,
    *,
    metric: str = "l2",
    pruner: Pruner | None = None,
    schedule: str = "adaptive",
    delta_d: int = 32,
    axis: str = "data",
    placement: Placement | None = None,
    stats=None,
) -> TopK:
    """Partition-sharded PDXearch: the placement's (P', D, C) tiles and
    (P', C) ids shard their leading (partition) dim over ``axis``; the query
    is replicated.  Returns a replicated TopK.

    With a ``SearchStats`` in ``stats``, each shard runs the stats-carrying
    masked impl, the per-shard computed-values scalars psum across the
    mesh, and the totals land in ``stats`` — pruning power stays observable
    on the distributed path at the cost of one extra replicated scalar."""
    _require(q=q, k=k)
    pruner = pruner or make_plain_pruner()
    pl = _block_placement(mesh, data, ids, axis, placement)
    data, ids = pl.data, pl.ids
    bounds = make_boundaries(data.shape[1], schedule, delta_d)
    with_stats = stats is not None

    def local(d_sh, i_sh, q_rep):
        qt = pruner.transform_query(q_rep.astype(jnp.float32))
        perm = (
            pruner.dim_order(qt)
            if pruner.dim_order is not None
            else jnp.arange(d_sh.shape[1], dtype=jnp.int32)
        )
        if with_stats:
            res, computed = _pdxearch_jit_stats_impl(
                d_sh, i_sh, qt, perm, k, metric, bounds, pruner.keep_mask
            )
            computed = jax.lax.psum(computed, axis)
        else:
            res = _pdxearch_jit_impl(
                d_sh, i_sh, qt, perm, k, metric, bounds, pruner.keep_mask
            )
        all_d = jax.lax.all_gather(res.dists, axis, tiled=True)
        all_i = jax.lax.all_gather(res.ids, axis, tiled=True)
        merged = topk_merge(topk_init(k), all_d, all_i)
        return (merged, computed) if with_stats else merged

    out_specs = (
        (TopK(dists=P(), ids=P()), P()) if with_stats
        else TopK(dists=P(), ids=P())
    )
    fn = shard_map(
        local,
        mesh=mesh,
        in_specs=(P(axis), P(axis), P()),
        out_specs=out_specs,
        check_rep=False,
    )
    out = fn(data, ids, q)
    if not with_stats:
        return out
    res, computed = out
    D = data.shape[1]
    total = float(jnp.sum(ids >= 0)) * D
    computed = float(computed)
    stats.values_total += total
    stats.values_computed += computed
    stats.values_avoided += total - computed
    stats.partitions_visited += data.shape[0]
    return res


def search_dim_sharded(
    mesh,
    data: jax.Array | None = None,
    ids: jax.Array | None = None,
    q: jax.Array | None = None,
    k: int | None = None,
    *,
    metric: str = "l2",
    axis: str = "model",
    placement: Placement | None = None,
) -> TopK:
    """Dimension-sharded exact search: tiles replicate (a ``replicated``
    placement) while each (P, D, C) tile's D axis shards over ``axis`` (the
    query shards alongside), partial distances are psum'd, and one top-k
    over all candidates finishes the query."""
    _require(q=q, k=k)
    n_shards = mesh.shape[axis]
    if placement is None:
        placement = Placement.replicated(data, ids, n_shards, axis=axis)
    data, ids = placement.data, placement.ids
    if data.shape[1] % n_shards:
        raise ValueError(
            f"D={data.shape[1]} not divisible over {n_shards} '{axis}' shards"
        )

    def local(d_sh, q_sh):
        part = jax.vmap(lambda t: pdx_distance(t, q_sh, metric))(d_sh)
        return jax.lax.psum(part, axis)  # (P, C) full distances, replicated

    dmat = shard_map(
        local,
        mesh=mesh,
        in_specs=(P(None, axis), P(axis)),
        out_specs=P(),
        check_rep=False,
    )(data, q.astype(jnp.float32))
    return topk_merge(topk_init(k), dmat.reshape(-1), ids.reshape(-1))


def search_batch_block_sharded(
    mesh,
    data: jax.Array | None = None,
    ids: jax.Array | None = None,
    Q: jax.Array | None = None,
    k: int | None = None,
    *,
    metric: str = "l2",
    axis: str = "data",
    placement: Placement | None = None,
    mirror=None,
    rerank_mult: int = 4,
) -> TopK:
    """Batched block-sharded exact search: the placement's tiles stripe
    partitions over ``axis``; the (B, D) query batch is replicated.  Each
    device scans its shard with the MXU batch kernel, then the per-shard
    (B, k) top-k sets are exchanged in a single all-gather for the whole
    batch — dists and ids are packed into one (B, 2k) buffer (int32 ids
    bitcast to float32, bit-exact) so exactly ONE collective crosses the
    mesh per batch, versus 2·B for B per-query searches.

    With a reduced-precision ``mirror`` (``core.layout.DeviceMirror``) each
    shard scans its *arranged mirror* slice instead (bf16/int8 bytes from
    HBM, dequantized in-register) and re-ranks its local top
    ``rerank_mult * k`` candidates against its f32 master slice before the
    collective — still exactly ONE all-gather, carrying exact f32
    candidate distances (a rounded wire would swap cross-shard near-ties
    at the global k-boundary).  Returns a replicated batched TopK with
    (B, k) leaves."""
    _require(Q=Q, k=k)
    pl = _block_placement(mesh, data, ids, axis, placement)
    data, ids = pl.data, pl.ids
    n_shards = pl.n_shards
    if Q.ndim != 2:
        raise ValueError(f"Q must be (B, D), got shape {Q.shape}")
    quantized = mirror is not None and mirror.dtype != "f32"
    if not quantized:

        def local(d_sh, i_sh, Q_rep):
            B = Q_rep.shape[0]
            res = search_batch_matmul(d_sh, i_sh, Q_rep, k, metric)  # (B, k)
            packed = jnp.concatenate(
                [res.dists,
                 jax.lax.bitcast_convert_type(res.ids, jnp.float32)],
                axis=1,
            )  # (B, 2k)
            allp = jax.lax.all_gather(packed, axis, axis=1, tiled=True)
            allp = allp.reshape(B, n_shards, 2 * k)
            all_d = allp[:, :, :k].reshape(B, n_shards * k)
            all_i = jax.lax.bitcast_convert_type(
                allp[:, :, k:], jnp.int32
            ).reshape(B, n_shards * k)
            merge = lambda dd, ii: topk_merge(topk_init(k), dd, ii)  # noqa: E731
            return jax.vmap(merge)(all_d, all_i)

        fn = shard_map(
            local,
            mesh=mesh,
            in_specs=(P(axis), P(axis), P()),
            out_specs=TopK(dists=P(), ids=P()),
            check_rep=False,
        )
        return fn(data, ids, Q.astype(jnp.float32))

    qtiles = pl.arranged_mirror(mirror)
    rk = min(max(rerank_mult * k, k), qtiles.shape[0] * qtiles.shape[2])
    scale, offset = mirror.scale, mirror.offset
    # packed int4 mirrors unpack in-body (two nibbles per byte along D) —
    # no int8 cap: the shard scan streams the 0.5-byte tiles directly
    m_packed, m_dim = mirror.packed, mirror.dim

    def local_q(d_sh, i_sh, qd_sh, Q_rep):
        B = Q_rep.shape[0]
        W, _, C = qd_sh.shape
        pos = jnp.arange(W * C, dtype=jnp.int32).reshape(W, C)
        pos = jnp.where(i_sh >= 0, pos, -1)

        def body(state, inp):
            tileq, tpos = inp
            t32 = dequantize_ref(
                tileq, scale, offset, packed=m_packed, dim=m_dim
            )
            dmat = batched_distance_matmul(t32, Q_rep, metric)  # (B, C)
            return jax.vmap(topk_merge, (0, 0, None))(state, dmat, tpos), None

        init = jax.vmap(lambda _: topk_init(rk))(jnp.arange(B))
        cand, _ = jax.lax.scan(body, init, (qd_sh, pos))
        # exact f32 re-rank against the local MASTER slice, pre-collective
        res = rerank_positions(d_sh, i_sh, Q_rep, cand, k, metric)
        merge = lambda d_, i_: topk_merge(topk_init(k), d_, i_)  # noqa: E731

        # candidate distances stay f32 on the wire: the hierarchical merge
        # decides the global k-boundary, and a rounded wire (bf16) both
        # swaps cross-shard near-ties there and rounds the distances the
        # caller gets back — exactness is the re-rank's whole contract
        packed = jnp.concatenate(
            [res.dists,
             jax.lax.bitcast_convert_type(res.ids, jnp.float32)],
            axis=1,
        )  # (B, 2k)
        allp = jax.lax.all_gather(packed, axis, axis=1, tiled=True)
        allp = allp.reshape(B, n_shards, 2 * k)
        all_d = allp[:, :, :k].reshape(B, n_shards * k)
        all_i = jax.lax.bitcast_convert_type(
            allp[:, :, k:], jnp.int32
        ).reshape(B, n_shards * k)
        return jax.vmap(merge)(all_d, all_i)

    fn = shard_map(
        local_q,
        mesh=mesh,
        in_specs=(P(axis), P(axis), P(axis), P()),
        out_specs=TopK(dists=P(), ids=P()),
        check_rep=False,
    )
    return fn(data, ids, qtiles, Q.astype(jnp.float32))


# The jaxpr-walking collective meter moved to ``repro.obs.meters`` (it is
# telemetry, consumed by the registry's compile-time gauges as well as by
# tests); re-exported here because tests/benches import it from this module.
from ..obs.meters import _COLLECTIVES, collective_counts  # noqa: E402,F401
