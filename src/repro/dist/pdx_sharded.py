"""Distributed PDXearch over a device mesh — both natural decompositions of
the dimension-major layout:

* ``search_block_sharded`` — partitions (PDX blocks) shard over the ``data``
  axis: each device runs the masked jitted PDXearch on its local tiles, then
  the per-shard top-k sets are all-gathered and merged.  Exact for exact
  pruners (wire cost: ``n_dev * k`` floats+ids per query).

* ``search_dim_sharded`` — *dimension slices* shard over the ``model`` axis:
  each device accumulates partial distances over its contiguous row slab of
  every tile (a dimension shard of a PDX tile is contiguous — paper Fig. 1),
  one psum completes the distances, then a single top-k finishes.  Exact for
  all metrics whose distance decomposes over dimensions (l2 / l1 / ip).

* ``search_batch_block_sharded`` — the batched distributed search: the MXU
  batch scan (``core.pdxearch.search_batch_matmul``) runs on each device's
  partition shard, then the per-shard (B, k) candidate sets cross the mesh
  in ONE packed all-gather per query *batch* (dists and bitcast ids share
  the collective), amortizing the merge latency that the per-query path
  pays B times.  The planner (``repro.core.plan``) picks this automatically
  when a mesh and B > 1 are present.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from ..core.distance import pdx_distance
from ..core.layout import PAD_VALUE
from ..core.pdxearch import (
    _pdxearch_jit_impl,
    make_boundaries,
    search_batch_matmul,
)
from ..core.pruners import Pruner, make_plain_pruner
from ..core.topk import TopK, topk_init, topk_merge

__all__ = [
    "pad_partitions_to_shards",
    "search_block_sharded",
    "search_dim_sharded",
    "search_batch_block_sharded",
    "collective_counts",
]


def pad_partitions_to_shards(
    data: jax.Array, ids: jax.Array, n_shards: int
) -> tuple[jax.Array, jax.Array]:
    """Round the partition axis up to a multiple of ``n_shards`` with empty
    (all-``PAD_VALUE``, ids ``-1``) tiles.

    A frozen store is built divisible once and stays divisible; a mutable
    store's partition count drifts under insert/delete/repack churn, and
    without padding every repack would knock it off the block-sharded
    executors.  Padding tiles rank nothing into a top-k (the pad sentinel is
    monotonically far away and ``topk_merge`` discards ids < 0), so the
    sharded result stays bit-identical to the unpadded scan.
    """
    n_parts = data.shape[0]
    rem = (-n_parts) % n_shards
    if rem == 0:
        return data, ids
    pad_d = jnp.full((rem,) + data.shape[1:], PAD_VALUE, data.dtype)
    pad_i = jnp.full((rem,) + ids.shape[1:], -1, ids.dtype)
    return (
        jnp.concatenate([data, pad_d], axis=0),
        jnp.concatenate([ids, pad_i], axis=0),
    )


def search_block_sharded(
    mesh,
    data: jax.Array,
    ids: jax.Array,
    q: jax.Array,
    k: int,
    *,
    metric: str = "l2",
    pruner: Pruner | None = None,
    schedule: str = "adaptive",
    delta_d: int = 32,
    axis: str = "data",
) -> TopK:
    """Partition-sharded PDXearch: ``data`` (P, D, C) and ``ids`` (P, C)
    shard their leading (partition) dim over ``axis``; the query is
    replicated.  Returns a replicated TopK."""
    pruner = pruner or make_plain_pruner()
    n_shards = mesh.shape[axis]
    if data.shape[0] % n_shards:
        raise ValueError(
            f"{data.shape[0]} partitions not divisible over {n_shards} "
            f"'{axis}' shards"
        )
    bounds = make_boundaries(data.shape[1], schedule, delta_d)

    def local(d_sh, i_sh, q_rep):
        qt = pruner.transform_query(q_rep.astype(jnp.float32))
        perm = (
            pruner.dim_order(qt)
            if pruner.dim_order is not None
            else jnp.arange(d_sh.shape[1], dtype=jnp.int32)
        )
        res = _pdxearch_jit_impl(
            d_sh, i_sh, qt, perm, k, metric, bounds, pruner.keep_mask
        )
        all_d = jax.lax.all_gather(res.dists, axis, tiled=True)
        all_i = jax.lax.all_gather(res.ids, axis, tiled=True)
        return topk_merge(topk_init(k), all_d, all_i)

    fn = shard_map(
        local,
        mesh=mesh,
        in_specs=(P(axis), P(axis), P()),
        out_specs=TopK(dists=P(), ids=P()),
        check_rep=False,
    )
    return fn(data, ids, q)


def search_dim_sharded(
    mesh,
    data: jax.Array,
    ids: jax.Array,
    q: jax.Array,
    k: int,
    *,
    metric: str = "l2",
    axis: str = "model",
) -> TopK:
    """Dimension-sharded exact search: ``data`` (P, D, C) shards its D axis
    over ``axis`` (the query shards alongside), partial distances are
    psum'd, and one top-k over all candidates finishes the query."""
    n_shards = mesh.shape[axis]
    if data.shape[1] % n_shards:
        raise ValueError(
            f"D={data.shape[1]} not divisible over {n_shards} '{axis}' shards"
        )

    def local(d_sh, q_sh):
        part = jax.vmap(lambda t: pdx_distance(t, q_sh, metric))(d_sh)
        return jax.lax.psum(part, axis)  # (P, C) full distances, replicated

    dmat = shard_map(
        local,
        mesh=mesh,
        in_specs=(P(None, axis), P(axis)),
        out_specs=P(),
        check_rep=False,
    )(data, q.astype(jnp.float32))
    return topk_merge(topk_init(k), dmat.reshape(-1), ids.reshape(-1))


def search_batch_block_sharded(
    mesh,
    data: jax.Array,
    ids: jax.Array,
    Q: jax.Array,
    k: int,
    *,
    metric: str = "l2",
    axis: str = "data",
) -> TopK:
    """Batched block-sharded exact search: ``data`` (P, D, C) / ``ids``
    (P, C) shard partitions over ``axis``; the (B, D) query batch is
    replicated.  Each device scans its shard with the MXU batch kernel, then
    the per-shard (B, k) top-k sets are exchanged in a single all-gather for
    the whole batch — dists and ids are packed into one (B, 2k) buffer
    (int32 ids bitcast to float32, bit-exact) so exactly ONE collective
    crosses the mesh per batch, versus 2·B for B per-query searches.
    Returns a replicated batched TopK with (B, k) leaves."""
    n_shards = mesh.shape[axis]
    if data.shape[0] % n_shards:
        raise ValueError(
            f"{data.shape[0]} partitions not divisible over {n_shards} "
            f"'{axis}' shards"
        )
    if Q.ndim != 2:
        raise ValueError(f"Q must be (B, D), got shape {Q.shape}")

    def local(d_sh, i_sh, Q_rep):
        B = Q_rep.shape[0]
        res = search_batch_matmul(d_sh, i_sh, Q_rep, k, metric)  # (B, k)
        packed = jnp.concatenate(
            [res.dists, jax.lax.bitcast_convert_type(res.ids, jnp.float32)],
            axis=1,
        )  # (B, 2k)
        allp = jax.lax.all_gather(packed, axis, axis=1, tiled=True)
        allp = allp.reshape(B, n_shards, 2 * k)
        all_d = allp[:, :, :k].reshape(B, n_shards * k)
        all_i = jax.lax.bitcast_convert_type(
            allp[:, :, k:], jnp.int32
        ).reshape(B, n_shards * k)
        merge = lambda dd, ii: topk_merge(topk_init(k), dd, ii)  # noqa: E731
        return jax.vmap(merge)(all_d, all_i)

    fn = shard_map(
        local,
        mesh=mesh,
        in_specs=(P(axis), P(axis), P()),
        out_specs=TopK(dists=P(), ids=P()),
        check_rep=False,
    )
    return fn(data, ids, Q.astype(jnp.float32))


_COLLECTIVES = (
    "all_gather", "psum", "all_to_all", "ppermute", "reduce_scatter",
)


def collective_counts(fn, *args, **kwargs) -> dict[str, int]:
    """Trace ``fn(*args, **kwargs)`` and count collective primitives in the
    jaxpr (recursing into sub-jaxprs of pjit/shard_map/scan/...).  Used by
    tests and benchmarks to assert e.g. the batched path issues exactly one
    all-gather per batch, independent of batch size."""
    counts: dict[str, int] = {}

    def walk(jaxpr):
        for eqn in jaxpr.eqns:
            name = eqn.primitive.name
            if name in _COLLECTIVES:
                counts[name] = counts.get(name, 0) + 1
            for v in eqn.params.values():
                for sub in _subjaxprs(v):
                    walk(sub)

    def _subjaxprs(v):
        if hasattr(v, "eqns"):            # Jaxpr
            yield v
        elif hasattr(v, "jaxpr"):         # ClosedJaxpr
            yield v.jaxpr
        elif isinstance(v, (tuple, list)):
            for item in v:
                yield from _subjaxprs(item)

    walk(jax.make_jaxpr(fn)(*args, **kwargs).jaxpr)
    return counts
