"""The paper's primary contribution: the PDX layout + PDXearch + pruners.

Public API: VectorSearchEngine (engine.py) wraps everything; the pieces
(layout, distance kernels, pruning predicates, search phases) are importable
individually for composition and testing.
"""
from .engine import SearchStats, VectorSearchEngine  # noqa: F401
from .layout import PDXStore, build_bucketed_store, build_flat_store  # noqa: F401
from .pdxearch import pdxearch, pdxearch_jit, search_batch_matmul  # noqa: F401
from .pruners import (  # noqa: F401
    make_adsampling,
    make_bond,
    make_bsa,
    make_plain_pruner,
)
