"""The paper's primary contribution: the PDX layout + PDXearch + pruners,
fronted by a declarative spec/plan API.

Layering:

  * ``spec``     — ``SearchSpec`` (what to search: k, metric, pruning
                   config, nprobe, execution hints) and ``SearchResult``
                   (ids, dists, stats, plan trace).
  * ``plan``     — the query planner and executor registry: maps a
                   ``(SearchSpec, store, query shape, optional mesh)`` to
                   adaptive / jit-masked / batch-matmul / block-sharded /
                   dim-sharded / batch-block-sharded execution.
  * ``engine``   — ``VectorSearchEngine``: the single public entry point;
                   ``engine.search(q_or_Q, spec)`` plans and executes, and
                   ``insert``/``delete``/``compact`` mutate the store live
                   (upgrading it to a versioned ``MutablePDXStore``).
  * ``layout`` / ``distance`` / ``pruners`` / ``pdxearch`` / ``topk`` — the
    building blocks (PDX tiles frozen and mutable, distance kernels,
    pruning predicates, the three-phase search, streaming top-k),
    importable individually for composition and testing.
"""
from .engine import VectorSearchEngine  # noqa: F401
from .layout import (  # noqa: F401
    MutablePDXStore,
    PDXStore,
    build_bucketed_store,
    build_flat_store,
)
from .pdxearch import (  # noqa: F401
    SearchStats,
    pdxearch,
    pdxearch_jit,
    search_batch_matmul,
)
from .plan import ExecutionPlan, execute, executor_names, plan_search  # noqa: F401
from .pruners import (  # noqa: F401
    make_adsampling,
    make_bond,
    make_bsa,
    make_plain_pruner,
)
from .spec import SearchResult, SearchSpec  # noqa: F401
