"""PDXearch — the paper's three-phase dimension-by-dimension pruned search.

Execution modes:

* ``pdxearch`` (adaptive, host-orchestrated):  faithful to the paper's
  Section 4 algorithm — START linear-scans the first partition to seed the
  top-k threshold; WARMUP streams dimension slices in exponentially growing
  steps evaluating the pruning predicate branchlessly on *all* vectors; once
  the surviving fraction drops below ``sel_frac`` (paper: 20%), PRUNE
  compacts survivor columns (capacity rounded to a power of two to bound
  recompilation) and finishes only those.  Real work reduction, measurable
  on CPU; on TPU the compaction is a lane gather and skipped dimension
  slices are skipped HBM→VMEM DMAs.

* ``pdxearch_jit`` (fully jitted, masked): the same semantics with pruning
  expressed as masks instead of compaction — the shape-static variant used
  by the distributed search (shard_map) and the dry-run.  Identical results;
  no data-dependent shapes.

* ``search_batch_matmul``: beyond-paper batched-query path — the PDX tile is
  already K-major, so the distance matrix is one MXU matmul per tile.
"""
from __future__ import annotations

import collections
import dataclasses
import functools
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from ..obs import metrics as _metrics
from .distance import batched_distance_matmul, pdx_distance
from .layout import PDXStore
from .pruners import Pruner
from .topk import TopK, topk_init, topk_merge, topk_threshold

__all__ = [
    "SearchStats",
    "make_boundaries",
    "pdxearch",
    "pdxearch_jit",
    "search_batch_matmul",
]

_INF = jnp.float32(jnp.inf)


@dataclasses.dataclass
class SearchStats:
    """Work accounting for the paper's pruning-power metric (Tables 2/6)."""

    values_total: float = 0.0     # D * vectors visited
    values_computed: float = 0.0  # dimension values actually used in DCOs
    values_avoided: float = 0.0   # paper's pruning power numerator
    partitions_visited: int = 0
    prune_phase_entries: int = 0

    @property
    def pruning_power(self) -> float:
        if self.values_total == 0:
            return 0.0
        return self.values_avoided / self.values_total

    @property
    def computed_fraction(self) -> float:
        if self.values_total == 0:
            return 1.0
        return self.values_computed / self.values_total


def make_boundaries(
    dim: int, schedule: str = "adaptive", delta_d: int = 32, start: int = 2
) -> tuple[int, ...]:
    """Cumulative dimension boundaries at which the predicate is evaluated.

    adaptive (paper's fix for Issue #1): 2, 6, 14, 30, 62, ... doubling steps.
    fixed (ADSampling/BSA original): delta_d, 2*delta_d, ...
    """
    bounds: list[int] = []
    if schedule == "adaptive":
        b, step = 0, start
        while b < dim:
            b = min(b + step, dim)
            bounds.append(b)
            step *= 2
    elif schedule == "fixed":
        b = 0
        while b < dim:
            b = min(b + delta_d, dim)
            bounds.append(b)
    else:
        raise ValueError(f"unknown schedule {schedule!r}")
    return tuple(bounds)


# --------------------------------------------------------------------------
# Per-pruner jitted step functions (cached so jax.jit's shape cache is reused
# across queries; the predicate closure is baked in).  Keyed on
# (pruner fingerprint, metric, store version), NOT id(): object ids are
# recycled after GC, so an id key could alias a dead pruner's cached
# predicate onto a new, different pruner — and the cache grew without bound.
# The store version (monotone, bumped by every MutablePDXStore mutation) is
# part of the key so a search after insert()/delete() can never reuse an
# executor traced while the tiles looked different; frozen stores are
# version 0 forever and keep hitting one entry.  The trade-off is explicit:
# tiles flow into the steps as traced arguments (nothing below closes over
# them), so version keying buys auditability at the cost of a retrace on
# the first adaptive search after each mutation — churn-heavy serving
# should batch mutations or search through the shape-keyed batch/masked
# paths, which don't pay it.  LRU-bounded: each entry pins jit executables
# plus the predicate's closed-over arrays.
# --------------------------------------------------------------------------
_EXEC_CACHE: "collections.OrderedDict[tuple[str, str, int], tuple]" = (
    collections.OrderedDict()
)
_EXEC_CACHE_MAX = 16


def _accum_gdc(block: jax.Array, qd: jax.Array, metric: str) -> jax.Array:
    """(G, d, C), (d,) -> (G, C) partial-distance contribution."""
    if metric == "l2":
        diff = block - qd[None, :, None]
        return jnp.sum(diff * diff, axis=1)
    if metric == "l1":
        return jnp.sum(jnp.abs(block - qd[None, :, None]), axis=1)
    return -jnp.sum(block * qd[None, :, None], axis=1)


def _accum_rows(block: jax.Array, qd: jax.Array, metric: str) -> jax.Array:
    """(cap, d), (d,) -> (cap,)."""
    if metric == "l2":
        diff = block - qd[None, :]
        return jnp.sum(diff * diff, axis=1)
    if metric == "l1":
        return jnp.sum(jnp.abs(block - qd[None, :]), axis=1)
    return -jnp.sum(block * qd[None, :], axis=1)


def _get_exec(pruner: Pruner, metric: str, version: int = 0):
    key = (pruner.fingerprint, metric, version)
    if key in _EXEC_CACHE:
        _EXEC_CACHE.move_to_end(key)
        _metrics.counter("repro_cache_events_total", cache="exec", event="hit")
        return _EXEC_CACHE[key]
    _metrics.counter("repro_cache_events_total", cache="exec", event="miss")

    @jax.jit
    def warmup_step(data, pids, dims, qdims, acc, alive, thr, b):
        # Gather only the dimension rows of this step for the visited
        # partitions: (G, d, C).  With a query-aware order (BOND) ``dims`` is
        # a slice of the permutation; sequential pruners pass an iota.
        block = data[pids[:, None], dims[None, :], :]
        acc = acc + _accum_gdc(block, qdims, metric)
        alive = alive & pruner.keep_mask(acc, b, thr)
        return acc, alive, alive.sum()

    @jax.jit
    def prune_step(data, p_sel, c_sel, dims, qdims, acc, alive, thr, b):
        # Compacted survivors: gather (cap, d) values, vector-major.
        block = data[p_sel[:, None], dims[None, :], c_sel[:, None]]
        acc = acc + _accum_rows(block, qdims, metric)
        alive = alive & pruner.keep_mask(acc, b, thr)
        return acc, alive

    @functools.partial(jax.jit, static_argnames=("cap",))
    def compact(alive, acc, gids, cap):
        flat_alive = alive.reshape(-1)
        idx = jnp.nonzero(flat_alive, size=cap, fill_value=flat_alive.shape[0])[0]
        valid = idx < flat_alive.shape[0]
        idx = jnp.minimum(idx, flat_alive.shape[0] - 1)
        return (
            idx,
            valid,
            acc.reshape(-1)[idx],
            jnp.where(valid, gids.reshape(-1)[idx], -1),
        )

    fns = (warmup_step, prune_step, compact)
    _EXEC_CACHE[key] = fns
    while len(_EXEC_CACHE) > _EXEC_CACHE_MAX:
        _EXEC_CACHE.popitem(last=False)
    return fns


@jax.jit
def _start_scan(data, pids, q):
    """START phase: full linear scan of the seed partitions (L2-space of the
    pruner's transformed coordinates; exact because transforms are isometries
    or the identity)."""
    tiles = data[pids]  # (S, D, C)
    diff = tiles - q[None, :, None]
    return jnp.sum(diff * diff, axis=1)  # (S, C)


def _start_scan_metric(data, pids, q, metric):
    if metric == "l2":
        return _start_scan(data, pids, q)
    tiles = data[pids]
    return jax.vmap(lambda t: pdx_distance(t, q, metric))(tiles)


def _pow2_at_least(x: int, lo: int = 64) -> int:
    # 4x steps: few distinct capacities => few jit variants (compile-count
    # bounded; a slightly larger compacted gather is cheaper than a recompile)
    c = lo
    while c < x:
        c *= 4
    return c


# --------------------------------------------------------------------------
# Mode B — adaptive host-orchestrated PDXearch (the paper's algorithm).
# --------------------------------------------------------------------------
def pdxearch(
    store: PDXStore,
    q: jax.Array,
    k: int,
    pruner: Pruner,
    *,
    metric: str = "l2",
    schedule: str = "adaptive",
    delta_d: int = 32,
    sel_frac: float = 0.2,
    group: int = 8,
    pid_order: Optional[np.ndarray] = None,
    start_parts: int = 1,
    stats: Optional[SearchStats] = None,
) -> TopK:
    """Search ``store`` for the top-k nearest neighbours of ``q``.

    ``pid_order`` — partition visit order (e.g. IVF bucket ranking); defaults
    to sequential.  The first ``start_parts`` partitions form the START phase.
    """
    if metric == "ip" and not pruner.name == "linear":
        raise ValueError("pruned PDXearch requires a monotone metric (l2/l1)")
    D, C = store.dim, store.capacity
    qt = pruner.transform_query(jnp.asarray(q, jnp.float32))
    perm = pruner.dim_order(qt) if pruner.dim_order is not None else None
    qp = qt[perm] if perm is not None else qt
    bounds = make_boundaries(D, schedule, delta_d)
    warmup_step, prune_step, compact = _get_exec(
        pruner, metric, getattr(store, "version", 0)
    )

    if pid_order is None:
        pid_order = np.arange(store.num_partitions)
    pid_order = np.asarray(pid_order)
    counts = np.asarray(store.counts)

    state = topk_init(k)

    # -- PHASE 0: START -----------------------------------------------------
    start_pids = jnp.asarray(pid_order[:start_parts])
    d0 = _start_scan_metric(store.data, start_pids, qt, metric)
    state = topk_merge(state, d0.reshape(-1), store.ids[start_pids].reshape(-1))
    if stats is not None:
        nvalid = float(counts[pid_order[:start_parts]].sum())
        stats.values_total += nvalid * D
        stats.values_computed += nvalid * D
        stats.partitions_visited += start_parts

    dims_all = perm if perm is not None else jnp.arange(D, dtype=jnp.int32)

    # -- WARMUP / PRUNE over remaining partitions, in groups ----------------
    rest = pid_order[start_parts:]
    for lo in range(0, len(rest), group):
        pids_np = rest[lo : lo + group]
        pids = jnp.asarray(pids_np)
        G = len(pids_np)
        thr = topk_threshold(state)
        acc = jnp.zeros((G, C), jnp.float32)
        gids = store.ids[pids]
        alive = gids >= 0
        n_valid = float(counts[pids_np].sum())
        if stats is not None:
            stats.values_total += n_valid * D
            stats.partitions_visited += G

        prev = 0
        cand_d = cand_i = None
        prev_alive = int(np.asarray(alive.sum()))
        for b in bounds:
            dims = jax.lax.dynamic_slice_in_dim(dims_all, prev, b - prev)
            qdims = jax.lax.dynamic_slice_in_dim(qp, prev, b - prev)
            acc, alive, n_alive = warmup_step(
                store.data, pids, dims, qdims, acc, alive,
                thr, jnp.float32(b),
            )
            n_alive = int(n_alive)
            if stats is not None:
                stats.values_computed += prev_alive * (b - prev)
                stats.values_avoided += (prev_alive - n_alive) * (D - b)
            prev_alive = n_alive
            prev = b
            if b < D and n_alive <= sel_frac * max(n_valid, 1.0):
                # ---- PHASE 2: PRUNE — compact survivors, finish them ------
                cap = _pow2_at_least(max(n_alive, 1))
                idx, valid, acc_c, ids_c = compact(alive, acc, gids, cap)
                p_sel = pids[idx // C]
                c_sel = idx % C
                alive_c = valid
                if stats is not None:
                    stats.prune_phase_entries += 1
                pa = n_alive
                for b2 in bounds:
                    if b2 <= prev:
                        continue
                    dims = jax.lax.dynamic_slice_in_dim(dims_all, prev, b2 - prev)
                    qdims = jax.lax.dynamic_slice_in_dim(qp, prev, b2 - prev)
                    acc_c, alive_c = prune_step(
                        store.data, p_sel, c_sel, dims, qdims, acc_c,
                        alive_c, thr, jnp.float32(b2),
                    )
                    if stats is not None:
                        na = int(np.asarray(alive_c.sum()))
                        stats.values_computed += pa * (b2 - prev)
                        stats.values_avoided += (pa - na) * (D - b2)
                        pa = na
                    prev = b2
                cand_d = jnp.where(alive_c, acc_c, _INF)
                cand_i = ids_c
                break
        if cand_d is None:  # finished WARMUP without entering PRUNE
            cand_d = jnp.where(alive, acc, _INF).reshape(-1)
            cand_i = gids.reshape(-1)
        state = topk_merge(state, cand_d, cand_i)
    return state


# --------------------------------------------------------------------------
# Mode A — fully jitted masked PDXearch (shape-static; used by repro.dist).
# --------------------------------------------------------------------------
@functools.partial(
    jax.jit,
    static_argnames=("k", "metric", "bounds", "keep_mask_fn"),
)
def _pdxearch_jit_impl(data, ids, q, perm, k, metric, bounds, keep_mask_fn):
    P, D, C = data.shape
    dims_all = perm
    steps = []
    prev = 0
    for b in bounds:
        steps.append((prev, b))
        prev = b

    def scan_partition(state: TopK, inputs):
        tile, tids = inputs  # (D, C), (C,)
        thr = topk_threshold(state)
        acc = jnp.zeros((C,), jnp.float32)
        alive = tids >= 0
        for (d0, d1) in steps:
            dd = jax.lax.dynamic_slice_in_dim(dims_all, d0, d1 - d0)
            block = tile[dd, :]  # (d, C)
            qd = q[dd]
            if metric == "l2":
                diff = block - qd[:, None]
                acc = acc + jnp.sum(diff * diff, axis=0)
            elif metric == "l1":
                acc = acc + jnp.sum(jnp.abs(block - qd[:, None]), axis=0)
            else:
                acc = acc - jnp.sum(block * qd[:, None], axis=0)
            alive = alive & keep_mask_fn(acc, jnp.float32(d1), thr)
        cand = jnp.where(alive, acc, _INF)
        return topk_merge(state, cand, tids), None

    # START: partition 0 unpruned
    init = topk_merge(
        topk_init(k),
        pdx_distance(data[0], q, metric),
        ids[0],
    )
    state, _ = jax.lax.scan(scan_partition, init, (data[1:], ids[1:]))
    return state


@functools.partial(
    jax.jit,
    static_argnames=("k", "metric", "bounds", "keep_mask_fn"),
)
def _pdxearch_jit_stats_impl(
    data, ids, q, perm, k, metric, bounds, keep_mask_fn
):
    """``_pdxearch_jit_impl`` plus work accounting: also returns the scalar
    count of dimension values computed (alive lanes entering each step ×
    step width, START partition at full D) — the masked-path analogue of the
    adaptive executor's ``SearchStats`` bookkeeping, kept as a separate
    traced function so the stats-free path stays untouched."""
    P, D, C = data.shape
    dims_all = perm
    steps = []
    prev = 0
    for b in bounds:
        steps.append((prev, b))
        prev = b

    def scan_partition(carry, inputs):
        state, computed = carry
        tile, tids = inputs  # (D, C), (C,)
        thr = topk_threshold(state)
        acc = jnp.zeros((C,), jnp.float32)
        alive = tids >= 0
        for (d0, d1) in steps:
            computed = computed + jnp.sum(alive) * jnp.float32(d1 - d0)
            dd = jax.lax.dynamic_slice_in_dim(dims_all, d0, d1 - d0)
            block = tile[dd, :]  # (d, C)
            qd = q[dd]
            if metric == "l2":
                diff = block - qd[:, None]
                acc = acc + jnp.sum(diff * diff, axis=0)
            elif metric == "l1":
                acc = acc + jnp.sum(jnp.abs(block - qd[:, None]), axis=0)
            else:
                acc = acc - jnp.sum(block * qd[:, None], axis=0)
            alive = alive & keep_mask_fn(acc, jnp.float32(d1), thr)
        cand = jnp.where(alive, acc, _INF)
        return (topk_merge(state, cand, tids), computed), None

    init = topk_merge(
        topk_init(k),
        pdx_distance(data[0], q, metric),
        ids[0],
    )
    computed0 = jnp.sum(ids[0] >= 0) * jnp.float32(D)
    (state, computed), _ = jax.lax.scan(
        scan_partition, (init, computed0), (data[1:], ids[1:])
    )
    return state, computed


def pdxearch_jit(
    store: PDXStore,
    q: jax.Array,
    k: int,
    pruner: Pruner,
    *,
    metric: str = "l2",
    schedule: str = "adaptive",
    delta_d: int = 32,
    stats: Optional[SearchStats] = None,
) -> TopK:
    qt = pruner.transform_query(jnp.asarray(q, jnp.float32))
    perm = (
        pruner.dim_order(qt)
        if pruner.dim_order is not None
        else jnp.arange(store.dim, dtype=jnp.int32)
    )
    bounds = make_boundaries(store.dim, schedule, delta_d)
    if stats is None:
        return _pdxearch_jit_impl(
            store.data, store.ids, qt, perm, k, metric, bounds,
            pruner.keep_mask,
        )
    state, computed = _pdxearch_jit_stats_impl(
        store.data, store.ids, qt, perm, k, metric, bounds, pruner.keep_mask
    )
    D = store.dim
    total = float(np.asarray(store.counts).sum()) * D
    computed = float(computed)
    stats.values_total += total
    stats.values_computed += computed
    stats.values_avoided += total - computed
    stats.partitions_visited += store.num_partitions
    return state


# --------------------------------------------------------------------------
# Batched-query MXU path (beyond-paper).
# --------------------------------------------------------------------------
@functools.partial(jax.jit, static_argnames=("k", "metric"))
def search_batch_matmul(
    data: jax.Array, ids: jax.Array, Q: jax.Array, k: int, metric: str = "l2"
) -> TopK:
    """Exact linear scan for a (B, D) query batch over (P, D, C) PDX tiles.

    Each tile is K-major for the (B,D)x(D,C) matmul — the PDX layout *is* the
    MXU operand layout, no transposition needed (cf. paper Section 7 on the
    cost of on-the-fly transposition for horizontal storage).
    """
    B = Q.shape[0]

    def body(state: TopK, inputs):
        tile, tids = inputs
        dmat = batched_distance_matmul(tile, Q, metric)  # (B, C)
        state = jax.vmap(topk_merge, (0, 0, None))(state, dmat, tids)
        return state, None

    init = jax.vmap(lambda _: topk_init(k))(jnp.arange(B))
    state, _ = jax.lax.scan(body, init, (data, ids))
    return state
