"""VectorSearchEngine — the framework's single public vector-search API.

Combines layout + index + pruner into the object a service embeds, and
delegates *execution mode* to the query planner (``repro.core.plan``): one
``search`` call serves single queries and batches, exact and pruned scans,
IVF routing, and the mesh-sharded distributed paths.  NumPy in, NumPy out.

    eng = VectorSearchEngine.build(X, index="ivf", pruner="adsampling")
    spec = SearchSpec(k=10, nprobe=16)
    ids, dists = eng.search(q, spec)        # single query
    res = eng.search(Q, spec)               # (B, D) batch — planner batches
    res.plan.executor, res.plan.reason      # which mode ran, and why

With a device mesh (built via ``jax.make_mesh`` or given at build time) the
planner dispatches to the ``repro.dist`` sharded executors automatically —
including the fused batched path that issues one top-k all-gather per query
batch, and for IVF engines the bucket-routed path where each query travels
only to the shards owning its top-nprobe buckets (one all-to-all + one
packed all-gather per batch; ``SearchSpec.routing="broadcast"`` opts back
into host-side routing):

    eng = VectorSearchEngine.build(X, mesh=jax.make_mesh((8,), ("data",)))
    res = eng.search(Q, SearchSpec(k=10))   # -> "batch-block-sharded"

    eng = VectorSearchEngine.build(X, index="ivf", mesh=mesh)
    res = eng.search(Q, SearchSpec(k=10, nprobe=4))  # -> "routed_bucket"

Migration from the pre-spec API (the deprecated ``search_jit``/
``search_batch`` shims have been removed; equivalents below):

    old call / kwarg                        spec/plan equivalent
    --------------------------------------  --------------------------------
    search(q, k=10)                         search(q, SearchSpec(k=10))
    search(q, k, nprobe=16)                 SearchSpec(k=k, nprobe=16)
    search_jit(q, k)                        SearchSpec(k=k, prefer_static=True)
                                            (or executor="jit-masked")
    search_batch(Q, k)                      search(Q, SearchSpec(k=k))
    dist.pdx_sharded.search_block_sharded   search(q, spec, mesh=mesh)
    dist.pdx_sharded.search_dim_sharded     search(q, spec, mesh=model_mesh)
    build(schedule=, delta_d=, sel_frac=,   SearchSpec(schedule=, delta_d=,
          group=, metric=)                    sel_frac=, group=, metric=)
                                            (build kwargs still accepted —
                                             they seed ``engine.spec``)
    rebuild store to add vectors            insert(X) -> new ids (write-head
                                              absorbs them; searched exactly
                                              by every executor immediately)
    rebuild store to remove vectors         delete(ids) (tombstones; slots
                                              poisoned + reusable)
    rebuild store to defragment             compact() (drains tombstones +
                                              write-head into lane-aligned
                                              tiles, refreshes the store's
                                              dim_means/dim_vars, rebuilds a
                                              BOND pruner on them, and
                                              recalibrates BSA's PCA from a
                                              fresh survivor sample — the
                                              live rows are re-projected in
                                              place)

Mutation upgrades the frozen ``PDXStore`` into a versioned
``core.layout.MutablePDXStore`` in place on first use; searches observe
``store.version`` through the plan trace and jitted-executor caches are
keyed on it, so no executor ever runs against stale tiles.

Pruner *algorithm* selection (``pruner="adsampling"``, ``eps0``, ``bsa_m``,
``zone_size``) stays a build-time choice: those transforms are baked into
the stored vectors.  Everything about a single query is a ``SearchSpec``.

Device-scan precision is a *spec* knob, not store state: the store keeps
f32 masters and lazily materializes a quantized device mirror per
``tiles_version`` (see ``core.layout.device_mirror``), so

    eng.search(Q, SearchSpec(scan_dtype="bf16"))   # 2x fewer scan bytes
    eng.search(Q, SearchSpec(scan_dtype="int8"))   # 4x fewer scan bytes

stream 2 or 1 bytes per dimension value through the hot loop (on a mesh,
through every shard's scan) while the top ``rerank_mult * k`` candidates
are re-ranked against the f32 masters — returned distances stay exact.
``build(scan_dtype=..., kernel=...)`` seeds the engine's default spec.

Multi-resolution cascades compose those precisions per query
(``SearchSpec.cascade``): a skinny projection mirror scans first, a packed
int4/int8 full-dimension pass covers its survivors (HBM traffic for pruned
partitions is skipped outright on the Pallas path), and the exact f32
re-rank terminates the pipeline —

    eng.search(Q, SearchSpec(cascade=("proj32:int8", "int4", "f32")))

``SearchSpec.route_dtype`` applies the same dtype policy to the IVF
centroid-routing scan.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Optional

import jax.numpy as jnp
import numpy as np

from ..index.ivf import IVFIndex, build_ivf
from ..obs import metrics as _metrics
from ..obs import trace as _trace
from .layout import MutablePDXStore, PDXStore, build_flat_store, pdx_to_nary
from .pdxearch import SearchStats
from .plan import ExecutionPlan, execute, plan_search
from .pruners import (
    Pruner,
    make_adsampling,
    make_bond,
    make_bond_decreasing,
    make_bsa,
    make_plain_pruner,
)
from .spec import SearchResult, SearchSpec

__all__ = ["VectorSearchEngine", "SearchSpec", "SearchResult", "SearchStats"]

PRUNERS = ("linear", "adsampling", "bsa", "bond", "bond-decreasing")


def _make_pruner(
    name: str,
    X: np.ndarray,
    *,
    eps0: float,
    bsa_m: float,
    zone_size: int,
    seed: int,
) -> Pruner:
    if name == "linear":
        return make_plain_pruner()
    if name == "adsampling":
        return make_adsampling(X.shape[1], eps0=eps0, seed=seed)
    if name == "bsa":
        sample = X[: min(len(X), 65536)]
        return make_bsa(sample, m=bsa_m, seed=seed)
    if name == "bond":
        return make_bond(jnp.asarray(X.mean(axis=0)), zone_size=zone_size)
    if name == "bond-decreasing":
        return make_bond_decreasing(X.shape[1])
    raise ValueError(f"pruner must be one of {PRUNERS}, got {name!r}")


@dataclasses.dataclass
class VectorSearchEngine:
    """Store + pruner + optional IVF index + optional mesh, searched through
    the planner.  ``spec`` holds the engine's default ``SearchSpec`` (seeded
    from build kwargs); per-call specs override it."""

    store: PDXStore
    pruner: Pruner
    spec: SearchSpec = SearchSpec()
    ivf: Optional[IVFIndex] = None
    mesh: Any = None
    zone_size: int = 0          # BOND zone grouping (kept for pruner refresh)
    head_capacity: int = 256    # write-head size on mutable upgrade

    # ------------------------------------------------------------------ build
    @classmethod
    def build(
        cls,
        X: np.ndarray,
        *,
        metric: str = "l2",
        index: str = "flat",
        pruner: str = "adsampling",
        capacity: int = 1024,
        nlist: Optional[int] = None,
        eps0: float = 2.1,
        bsa_m: float = 3.0,
        zone_size: int = 0,
        schedule: str = "adaptive",
        delta_d: int = 32,
        sel_frac: float = 0.2,
        group: int = 8,
        kmeans_iters: int = 10,
        seed: int = 0,
        precomputed_ivf=None,
        spec: Optional[SearchSpec] = None,
        mesh: Any = None,
        routing: str = "bucket",
        scan_dtype: str = "f32",
        kernel: str = "auto",
        rerank_mult: int = 4,
        cascade: Optional[tuple] = None,
        route_dtype: str = "f32",
        tree="auto",
        super_k: Optional[int] = None,
        nprobe_super: Optional[int] = None,
    ) -> "VectorSearchEngine":
        X = np.ascontiguousarray(np.asarray(X, np.float32))
        pr = _make_pruner(
            pruner, X, eps0=eps0, bsa_m=bsa_m, zone_size=zone_size, seed=seed
        )
        Xt = pr.preprocess(X) if pr.needs_preprocess else X
        ivf = None
        if index == "ivf":
            nlist = nlist or max(int(np.sqrt(len(X))), 1)
            ivf = build_ivf(
                Xt, nlist, capacity=capacity, kmeans_iters=kmeans_iters,
                seed=seed, precomputed=precomputed_ivf, tree=tree,
                super_k=super_k, nprobe_super=nprobe_super,
            )
            store = ivf.store
        elif index == "flat":
            store = build_flat_store(Xt, capacity=capacity)
        else:
            raise ValueError(f"index must be 'flat' or 'ivf', got {index!r}")
        if spec is None:
            spec = SearchSpec(
                metric=metric, schedule=schedule, delta_d=delta_d,
                sel_frac=sel_frac, group=group, routing=routing,
                scan_dtype=scan_dtype, kernel=kernel,
                rerank_mult=rerank_mult, cascade=cascade,
                route_dtype=route_dtype,
            )
        return cls(store=store, pruner=pr, spec=spec, ivf=ivf, mesh=mesh,
                   zone_size=zone_size)

    # ----------------------------------------------------------------- search
    def search(
        self,
        q: np.ndarray,
        spec: Optional[SearchSpec] = None,
        *,
        stats: Optional[SearchStats] = None,
        mesh: Any = None,
        **overrides,
    ) -> SearchResult:
        """Search for the nearest neighbours of ``q`` under ``spec``.

        ``q`` — one (D,) query or a (B, D) batch; the result's ids/dists
        match that shape ((k,) or (B, k)).  ``spec`` defaults to the
        engine's; keyword ``overrides`` (any ``SearchSpec`` field, e.g.
        ``k=``, ``nprobe=``) apply on top of it, which also keeps the
        legacy ``search(q, k=10, nprobe=16)`` call shape working.  ``mesh``
        overrides the engine mesh for this call.  The returned
        ``SearchResult`` unpacks as ``(ids, dists)`` and carries the
        ``ExecutionPlan`` trace.
        """
        if isinstance(spec, (int, np.integer)):  # legacy positional k
            overrides.setdefault("k", spec)
            spec = None
        base = spec if spec is not None else self.spec
        if overrides:
            base = base.replace(**overrides)
        Q = jnp.asarray(q, jnp.float32)
        if Q.ndim not in (1, 2):
            raise ValueError(f"q must be (D,) or (B, D), got shape {Q.shape}")
        single = Q.ndim == 1
        Qb = Q[None, :] if single else Q
        use_mesh = mesh if mesh is not None else self.mesh
        t0 = time.perf_counter()
        with _trace.query(n_queries=Qb.shape[0], k=base.k) as qtrace:
            with _trace.span("plan"):
                plan = plan_search(
                    base, self.store, Qb.shape[0], pruner=self.pruner,
                    ivf=self.ivf, mesh=use_mesh,
                )
            if qtrace is not None:
                qtrace.attrs["executor"] = plan.executor
            before = dataclasses.replace(stats) if (
                stats is not None and _metrics.enabled()
            ) else None
            ids, dists = execute(
                plan, base, self.store, self.pruner, Qb,
                ivf=self.ivf, mesh=use_mesh, stats=stats,
            )
        if _metrics.enabled():
            B = Qb.shape[0]
            _metrics.counter(
                "repro_search_batches_total", executor=plan.executor
            )
            _metrics.counter(
                "repro_search_queries_total", float(B),
                executor=plan.executor,
            )
            _metrics.observe(
                "repro_search_latency_seconds", time.perf_counter() - t0,
                executor=plan.executor,
            )
            if before is not None:
                for kind, attr in (
                    ("total", "values_total"),
                    ("computed", "values_computed"),
                    ("avoided", "values_avoided"),
                ):
                    delta = getattr(stats, attr) - getattr(before, attr)
                    if delta:
                        _metrics.counter(
                            "repro_pruning_values_total", delta,
                            executor=plan.executor, kind=kind,
                        )
        if single:
            ids, dists = ids[0], dists[0]
        return SearchResult(ids=ids, dists=dists, spec=base, plan=plan,
                            stats=stats, trace=qtrace)

    def plan(
        self,
        q: np.ndarray,
        spec: Optional[SearchSpec] = None,
        *,
        mesh: Any = None,
    ) -> ExecutionPlan:
        """Dry-run the planner: which executor would ``search(q, spec)`` use."""
        Q = jnp.asarray(q, jnp.float32)
        n_queries = 1 if Q.ndim == 1 else Q.shape[0]
        return plan_search(
            spec if spec is not None else self.spec, self.store, n_queries,
            pruner=self.pruner, ivf=self.ivf,
            mesh=mesh if mesh is not None else self.mesh,
        )

    # --------------------------------------------------------------- mutation
    def _ensure_mutable(self) -> MutablePDXStore:
        """Upgrade the frozen store into a MutablePDXStore on first mutation
        (in place; the IVF index keeps pointing at the same store object)."""
        if not isinstance(self.store, MutablePDXStore):
            kwargs = dict(head_capacity=self.head_capacity)
            if self.ivf is not None:
                kwargs.update(
                    num_buckets=self.ivf.nlist,
                    part_counts=self.ivf.part_counts,
                )
            self.store = MutablePDXStore.from_store(self.store, **kwargs)
            if self.ivf is not None:
                self.ivf.store = self.store
        return self.store

    def _sync_ivf(self) -> None:
        """Repacks move bucket boundaries; refresh the index's view of them."""
        if self.ivf is not None and isinstance(self.store, MutablePDXStore):
            self.ivf.part_offsets = self.store.part_offsets
            self.ivf.part_counts = self.store.part_counts

    def insert(self, X: np.ndarray) -> np.ndarray:
        """Add vectors; returns their new ids (valid for ``delete`` and in
        search results).  Rows land in the store's write-head — searched
        exactly by every executor from this call on — and are drained into
        sealed PDX tiles by a later flush/``compact()``.  IVF engines assign
        each row to its nearest centroid at insert time so the repack keeps
        buckets contiguous."""
        X = np.atleast_2d(np.ascontiguousarray(np.asarray(X, np.float32)))
        store = self._ensure_mutable()
        Xt = self.pruner.preprocess(X) if self.pruner.needs_preprocess else X
        assignments = self.ivf.assign(Xt) if self.ivf is not None else None
        new_ids = store.insert(Xt, assignments=assignments)
        self._sync_ivf()
        return new_ids

    def delete(self, ids) -> int:
        """Tombstone vectors by id; returns how many were live.  Their slots
        are poisoned (never rank into a top-k) and become reusable."""
        store = self._ensure_mutable()
        removed = store.delete(ids)
        self._sync_ivf()
        return removed

    def compact(self) -> None:
        """Repack: drain tombstones + write-head into minimal lane-aligned
        tiles and refresh store metadata (dim_means/dim_vars).  Pruner
        calibration follows the surviving collection: a BOND pruner is
        rebuilt from the repacked collection means, and a BSA pruner's PCA
        is recalibrated from a fresh sample of the survivors — the stored
        vectors are rotated back through the old components and re-projected
        with the new ones in place (``replace_live_vectors``), so post-churn
        pruning power matches a freshly built engine instead of decaying
        with distribution shift.  Either way the pruner fingerprint changes,
        naturally invalidating jit caches."""
        store = self._ensure_mutable()
        store.repack()
        self._sync_ivf()
        if self.pruner.name == "bond":
            self.pruner = make_bond(
                jnp.asarray(store.dim_means), zone_size=self.zone_size
            )
        elif self.pruner.name == "bsa" and self.pruner.aux is not None:
            self._recalibrate_bsa(store)

    def _recalibrate_bsa(self, store: MutablePDXStore) -> None:
        """Refit BSA's PCA on the post-churn collection (ROADMAP follow-up:
        until now only BOND metadata refreshed on compact).  The projection
        is orthogonal, so the original-space vectors are recovered exactly
        (up to float rounding) as ``X_t @ C.T``; a fresh sample refits the
        components and residual-energy quantiles, and the store's live rows
        are re-projected in place.  IVF centroids ride along: bucket
        assignments are rotation-invariant (orthogonal transforms preserve
        L2), so only their coordinates change, never bucket membership."""
        Xt = pdx_to_nary(store)  # live vectors, old projected space, id order
        if len(Xt) < 2:
            return  # no covariance to fit; keep the current calibration
        C_old = np.asarray(self.pruner.aux["components"], np.float32)
        X_orig = Xt @ C_old.T
        sample = X_orig[: min(len(X_orig), 65536)]  # mirror build-time sampling
        new_pruner = make_bsa(
            sample, m=self.pruner.aux["m"], seed=self.pruner.aux["seed"]
        )
        store.replace_live_vectors(new_pruner.preprocess(X_orig))
        if self.ivf is not None:
            cents = new_pruner.preprocess(
                np.asarray(self.ivf.centroids) @ C_old.T
            )
            self.ivf.centroids = jnp.asarray(cents)
            self.ivf.centroid_store = build_flat_store(
                cents, capacity=self.ivf.centroid_store.capacity
            )
            if self.ivf.tree_enabled:
                # The two-level tree clusters *centroids*; re-cluster it in
                # the rotated space, keeping the configured fan-out.
                self.ivf.attach_tree(
                    int(self.ivf.super_children.shape[0]),
                    self.ivf.nprobe_super,
                    seed=self.pruner.aux["seed"],
                )
        self.pruner = new_pruner

    # --------------------------------------------------------- observability
    def metrics(self) -> dict:
        """Deterministic snapshot of the process-wide metrics registry
        (``repro.obs.metrics``) — counters, gauges, histograms.  Enable
        recording with ``repro.obs.metrics.set_enabled(True)`` or
        ``REPRO_OBS=1``; see the ``repro.obs`` docstring for the families."""
        return _metrics.get_registry().snapshot()

    def dump_trace(self, path: Optional[str] = None) -> dict:
        """Recorded ``QueryTrace`` ring as Chrome/Perfetto trace JSON
        (written to ``path`` when given; loadable at ui.perfetto.dev)."""
        return _trace.get_tracer().export_chrome(path)

    # ------------------------------------------------------------------ util
    @property
    def metric(self) -> str:
        return self.spec.metric

    @property
    def num_vectors(self) -> int:
        return self.store.num_vectors

    @property
    def dim(self) -> int:
        return self.store.dim
