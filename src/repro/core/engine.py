"""VectorSearchEngine — the framework's public vector-search API.

Combines layout + index + pruner + PDXearch into the object a service embeds
(cf. the paper's open-source C++/Python PDX library).  NumPy in, NumPy out.

    eng = VectorSearchEngine.build(X, index="ivf", pruner="adsampling")
    ids, dists = eng.search(q, k=10, nprobe=16)
    ids, dists = eng.search_batch(Q, k=10)          # MXU batched path
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax.numpy as jnp
import numpy as np

from ..index.ivf import IVFIndex, build_ivf
from .layout import PDXStore, build_flat_store
from .pdxearch import (
    SearchStats,
    pdxearch,
    pdxearch_jit,
    search_batch_matmul,
)
from .pruners import (
    Pruner,
    make_adsampling,
    make_bond,
    make_bond_decreasing,
    make_bsa,
    make_plain_pruner,
)

__all__ = ["VectorSearchEngine", "SearchStats"]

PRUNERS = ("linear", "adsampling", "bsa", "bond", "bond-decreasing")


def _make_pruner(
    name: str,
    X: np.ndarray,
    *,
    eps0: float,
    bsa_m: float,
    zone_size: int,
    seed: int,
) -> Pruner:
    if name == "linear":
        return make_plain_pruner()
    if name == "adsampling":
        return make_adsampling(X.shape[1], eps0=eps0, seed=seed)
    if name == "bsa":
        sample = X[: min(len(X), 65536)]
        return make_bsa(sample, m=bsa_m, seed=seed)
    if name == "bond":
        return make_bond(jnp.asarray(X.mean(axis=0)), zone_size=zone_size)
    if name == "bond-decreasing":
        return make_bond_decreasing(X.shape[1])
    raise ValueError(f"pruner must be one of {PRUNERS}, got {name!r}")


@dataclasses.dataclass
class VectorSearchEngine:
    store: PDXStore
    pruner: Pruner
    metric: str
    ivf: Optional[IVFIndex] = None
    schedule: str = "adaptive"
    delta_d: int = 32
    sel_frac: float = 0.2
    group: int = 8

    # ------------------------------------------------------------------ build
    @classmethod
    def build(
        cls,
        X: np.ndarray,
        *,
        metric: str = "l2",
        index: str = "flat",
        pruner: str = "adsampling",
        capacity: int = 1024,
        nlist: Optional[int] = None,
        eps0: float = 2.1,
        bsa_m: float = 3.0,
        zone_size: int = 0,
        schedule: str = "adaptive",
        delta_d: int = 32,
        sel_frac: float = 0.2,
        group: int = 8,
        kmeans_iters: int = 10,
        seed: int = 0,
        precomputed_ivf=None,
    ) -> "VectorSearchEngine":
        X = np.ascontiguousarray(np.asarray(X, np.float32))
        pr = _make_pruner(
            pruner, X, eps0=eps0, bsa_m=bsa_m, zone_size=zone_size, seed=seed
        )
        Xt = pr.preprocess(X) if pr.needs_preprocess else X
        ivf = None
        if index == "ivf":
            nlist = nlist or max(int(np.sqrt(len(X))), 1)
            ivf = build_ivf(
                Xt, nlist, capacity=capacity, kmeans_iters=kmeans_iters,
                seed=seed, precomputed=precomputed_ivf,
            )
            store = ivf.store
        elif index == "flat":
            store = build_flat_store(Xt, capacity=capacity)
        else:
            raise ValueError(f"index must be 'flat' or 'ivf', got {index!r}")
        return cls(
            store=store, pruner=pr, metric=metric, ivf=ivf,
            schedule=schedule, delta_d=delta_d, sel_frac=sel_frac, group=group,
        )

    # ----------------------------------------------------------------- search
    def search(
        self,
        q: np.ndarray,
        k: int = 10,
        *,
        nprobe: int = 8,
        stats: Optional[SearchStats] = None,
    ) -> tuple[np.ndarray, np.ndarray]:
        q = jnp.asarray(q, jnp.float32)
        if self.ivf is not None:
            res = self.ivf.search(
                q, k, self.pruner, nprobe=nprobe, metric=self.metric,
                schedule=self.schedule, delta_d=self.delta_d,
                sel_frac=self.sel_frac, group=self.group, stats=stats,
            )
        else:
            res = pdxearch(
                self.store, q, k, self.pruner, metric=self.metric,
                schedule=self.schedule, delta_d=self.delta_d,
                sel_frac=self.sel_frac, group=self.group, stats=stats,
            )
        return np.asarray(res.ids), np.asarray(res.dists)

    def search_jit(self, q: np.ndarray, k: int = 10):
        """Shape-static masked variant (repro.dist uses this form)."""
        res = pdxearch_jit(
            self.store, jnp.asarray(q, jnp.float32), k, self.pruner,
            metric=self.metric, schedule=self.schedule, delta_d=self.delta_d,
        )
        return np.asarray(res.ids), np.asarray(res.dists)

    def search_batch(self, Q: np.ndarray, k: int = 10):
        """Beyond-paper batched exact scan (MXU matmul form). Queries must be
        pre-transformed only by isometries, so this uses raw coordinates when
        the pruner is a projection (results are identical either way)."""
        Qj = jnp.asarray(Q, jnp.float32)
        if self.pruner.needs_preprocess:
            Qj = jnp.stack([self.pruner.transform_query(r) for r in Qj])
        res = search_batch_matmul(
            self.store.data, self.store.ids, Qj, k, self.metric
        )
        return np.asarray(res.ids), np.asarray(res.dists)

    # ------------------------------------------------------------------ util
    @property
    def num_vectors(self) -> int:
        return self.store.num_vectors

    @property
    def dim(self) -> int:
        return self.store.dim
