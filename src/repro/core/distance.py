"""Distance kernels on horizontal (N-ary), PDX, and DSM layouts — pure jnp.

These are the reference implementations of the paper's Algorithm 1 and the
baselines it compares against.  The Pallas TPU kernels in ``repro.kernels``
implement the same contracts with explicit VMEM tiling; these jnp versions are
both the oracles for those kernels and the (XLA-autovectorized) CPU kernels
used by the benchmark harness — matching the paper's claim that PDX needs no
hand-written intrinsics, only a vectorization-friendly layout.

Conventions:
  * horizontal data: ``X   (N, D)``  — one row per vector
  * PDX data:        ``T   (D, V)``  — one row per dimension (a partition tile)
  * metrics return *uncorrected* values (squared L2; raw IP, larger=closer is
    NOT applied here — engines negate IP so that all metrics minimize).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

__all__ = [
    "METRICS",
    "nary_distance",
    "pdx_distance",
    "pdx_partial",
    "pdx_accumulate",
    "batched_distance_matmul",
]

METRICS = ("l2", "ip", "l1")


def _check_metric(metric: str) -> None:
    if metric not in METRICS:
        raise ValueError(f"metric must be one of {METRICS}, got {metric!r}")


# --------------------------------------------------------------------------
# Horizontal (vector-at-a-time) kernels — the paper's N-ary baseline.
# --------------------------------------------------------------------------
@functools.partial(jax.jit, static_argnames=("metric",))
def nary_distance(X: jax.Array, q: jax.Array, metric: str = "l2") -> jax.Array:
    """(N, D), (D,) -> (N,). Reduction runs along each row (per-vector)."""
    _check_metric(metric)
    if metric == "l2":
        diff = X - q[None, :]
        return jnp.sum(diff * diff, axis=1)
    if metric == "l1":
        return jnp.sum(jnp.abs(X - q[None, :]), axis=1)
    return -jnp.sum(X * q[None, :], axis=1)  # ip, negated to minimize


# --------------------------------------------------------------------------
# PDX (dimension-at-a-time) kernels — the paper's Algorithm 1.
# --------------------------------------------------------------------------
@functools.partial(jax.jit, static_argnames=("metric",))
def pdx_distance(T: jax.Array, q: jax.Array, metric: str = "l2") -> jax.Array:
    """(D, V), (D,) -> (V,). Accumulation runs across dimensions; each value
    of the output vector lives in its own SIMD lane (no horizontal reduce)."""
    _check_metric(metric)
    if metric == "l2":
        diff = T - q[:, None]
        return jnp.sum(diff * diff, axis=0)
    if metric == "l1":
        return jnp.sum(jnp.abs(T - q[:, None]), axis=0)
    return -jnp.sum(T * q[:, None], axis=0)


@functools.partial(jax.jit, static_argnames=("metric",))
def pdx_accumulate(
    T_slice: jax.Array, q_slice: jax.Array, acc: jax.Array, metric: str = "l2"
) -> jax.Array:
    """Partial-distance accumulation over a dimension slice.

    (d, V), (d,), (V,) -> (V,).  This is the inner step of PDXearch: the
    running ``distances`` array of Algorithm 1 stays resident (registers on
    CPU, VMEM scratch on TPU) while dimension slices stream through.
    """
    _check_metric(metric)
    if metric == "l2":
        diff = T_slice - q_slice[:, None]
        return acc + jnp.sum(diff * diff, axis=0)
    if metric == "l1":
        return acc + jnp.sum(jnp.abs(T_slice - q_slice[:, None]), axis=0)
    return acc - jnp.sum(T_slice * q_slice[:, None], axis=0)


def pdx_partial(
    T: jax.Array, q: jax.Array, d0: int, d1: int, acc: jax.Array, metric: str = "l2"
) -> jax.Array:
    """Accumulate dimensions [d0, d1) of tile T into acc (static bounds)."""
    return pdx_accumulate(T[d0:d1], q[d0:d1], acc, metric)


# --------------------------------------------------------------------------
# Batched-query matmul form (beyond-paper, MXU-native).
#
# ||q - x||^2 = ||q||^2 - 2 q.x + ||x||^2 — over a PDX tile the -2 Q X term is
# a single (B, d) @ (d, V) matmul with the PDX tile already in the K-major
# layout the MXU wants.  L1 has no matmul form; engines fall back to vmapped
# pdx_distance for it.
# --------------------------------------------------------------------------
@functools.partial(jax.jit, static_argnames=("metric",))
def batched_distance_matmul(
    T: jax.Array, Q: jax.Array, metric: str = "l2"
) -> jax.Array:
    """(D, V), (B, D) -> (B, V) for l2/ip."""
    if metric == "l1":
        return jax.vmap(lambda q: pdx_distance(T, q, "l1"))(Q)
    cross = Q @ T  # (B, V) — MXU
    if metric == "ip":
        return -cross
    qn = jnp.sum(Q * Q, axis=1, keepdims=True)  # (B, 1)
    xn = jnp.sum(T * T, axis=0, keepdims=True)  # (1, V)
    return qn - 2.0 * cross + xn
