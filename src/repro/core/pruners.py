"""Dimension-pruning predicates hosted by PDXearch.

Each pruner bundles:
  * ``preprocess``  — offline transform of the collection (and its inverse
    requirements on queries), e.g. ADSampling's random rotation, BSA's PCA.
  * ``transform_query`` — per-query preparation.
  * ``keep_mask(partial, d, thr)`` — the pruning predicate evaluated at a
    WARMUP/PRUNE step: True = vector still alive after seeing ``d`` dims.
  * ``is_exact`` — whether pruning preserves exact top-k (BOND does; the
    probabilistic pruners trade a bounded error for earlier pruning).

All predicates are branchless (mask-valued), matching the paper's vectorized
bounds evaluation that is "done in a loop separated from the distance
calculations" (Section 4).

References: ADSampling [Gao & Long, SIGMOD'23], BSA [Yang et al., 2024],
BOND [de Vries et al., SIGMOD'02].
"""
from __future__ import annotations

import dataclasses
import hashlib
import itertools
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "Pruner",
    "pruner_fingerprint",
    "make_plain_pruner",
    "make_adsampling",
    "make_bsa",
    "pca_components",
    "make_bond",
    "random_orthogonal",
]


def pruner_fingerprint(name: str, *params) -> str:
    """Stable identity of a pruning predicate: name + hash of its parameters.

    Two pruners with equal fingerprints have functionally identical
    ``keep_mask``/``dim_order`` closures, so jit caches (and ``SearchSpec``
    plan traces) can key on this instead of object identity — object ids are
    reused after GC, which both aliased unrelated pruners and leaked cache
    entries (see ``core.pdxearch._EXEC_CACHE``).
    """
    h = hashlib.sha1()
    for p in params:
        if isinstance(p, (np.ndarray, jax.Array)):
            a = np.asarray(p)
            h.update(str(a.shape).encode())
            h.update(a.tobytes())
        else:
            h.update(repr(p).encode())
    return f"{name}:{h.hexdigest()[:16]}"


_ANON_IDS = itertools.count()


@dataclasses.dataclass(frozen=True)
class Pruner:
    name: str
    is_exact: bool
    needs_preprocess: bool
    # (X (N,D) numpy) -> transformed X; build-time.
    preprocess: Callable[[np.ndarray], np.ndarray]
    # (q (D,)) -> transformed q (jnp).
    transform_query: Callable[[jax.Array], jax.Array]
    # (partial (V,), n_dims_seen scalar, thr scalar) -> keep mask (V,) bool.
    keep_mask: Callable[[jax.Array, jax.Array, jax.Array], jax.Array]
    # Optional query-aware dimension order: (q (D,)) -> permutation (D,) int32.
    dim_order: Optional[Callable[[jax.Array], jax.Array]] = None
    # Stable identity (name + param hash).  Factories set it; a directly
    # constructed Pruner without one gets a process-unique fallback, so two
    # hand-built pruners with different closures can never share a jit-cache
    # entry (a counter, unlike id(), is never reused after GC).
    fingerprint: str = ""
    # Factory parameters needed to rebuild/invert the transform later (e.g.
    # BSA's PCA components so compact() can recalibrate from a fresh
    # sample).  Excluded from equality/hash: the fingerprint already covers
    # identity, and the dict payload is unhashable.
    aux: Optional[dict] = dataclasses.field(
        default=None, compare=False, repr=False
    )

    def __post_init__(self):
        if not self.fingerprint:
            object.__setattr__(
                self, "fingerprint", f"{self.name}:anon{next(_ANON_IDS)}"
            )


# --------------------------------------------------------------------------
# No-op pruner: PDX linear scan (never prunes). Baseline in Figures 9/10.
# --------------------------------------------------------------------------
def make_plain_pruner() -> Pruner:
    return Pruner(
        name="linear",
        is_exact=True,
        needs_preprocess=False,
        preprocess=lambda X: X,
        transform_query=lambda q: q,
        keep_mask=lambda partial, d, thr: jnp.ones_like(partial, dtype=bool),
        fingerprint=pruner_fingerprint("linear"),
    )


# --------------------------------------------------------------------------
# ADSampling — random orthogonal projection + hypothesis-test pruning.
#
# After rotating by a random orthogonal matrix, the partial squared distance
# over the first d of D dims, scaled by D/d, is an unbiased estimator of the
# full squared distance whose error concentrates as 1/sqrt(d).  ADSampling
# prunes v when    sqrt(partial * D / d)  >  thr * (1 + eps0 / sqrt(d))
# i.e. when even an (eps0/sqrt(d))-inflated threshold is exceeded.
# --------------------------------------------------------------------------
def random_orthogonal(dim: int, seed: int) -> np.ndarray:
    rng = np.random.default_rng(seed)
    a = rng.standard_normal((dim, dim)).astype(np.float64)
    q, r = np.linalg.qr(a)
    q *= np.sign(np.diag(r))[None, :]  # fix signs -> Haar distributed
    return q.astype(np.float32)


def make_adsampling(dim: int, eps0: float = 2.1, seed: int = 0) -> Pruner:
    P = random_orthogonal(dim, seed)
    Pj = jnp.asarray(P)

    def keep_mask(partial: jax.Array, d: jax.Array, thr: jax.Array) -> jax.Array:
        d = jnp.maximum(d.astype(jnp.float32), 1.0)
        ratio = jnp.float32(dim) / d
        bound = thr * (1.0 + eps0 / jnp.sqrt(d)) ** 2  # squared-space
        return partial * ratio <= bound

    return Pruner(
        name="adsampling",
        is_exact=False,
        needs_preprocess=True,
        preprocess=lambda X: (np.asarray(X, np.float32) @ P.T),
        transform_query=lambda q: Pj @ q,
        keep_mask=keep_mask,
        fingerprint=pruner_fingerprint("adsampling", dim, eps0, seed),
        # the fused Pallas scan executors bake the hypothesis test into the
        # kernel; they need the raw eps0, not just the keep_mask closure
        aux={"eps0": eps0, "dim": dim, "seed": seed},
    )


# --------------------------------------------------------------------------
# BSA — PCA projection + error-quantile pruning.
#
# Project onto PCA components ordered by decreasing eigenvalue; the energy not
# yet seen after d dims is bounded via the per-dimension residual variances
# (Cauchy–Schwarz in the original paper; we calibrate the same bound
# empirically from the collection, which is exactly the information the paper
# stores as per-block metadata).  Prune when even the most optimistic
# completion of the partial distance exceeds the threshold:
#     partial + max(0, mu_res(d) - m * sigma_res(d))  >  thr
# ``m`` plays the paper's multiplier role (higher m = safer = later pruning).
# --------------------------------------------------------------------------
def pca_components(X_sample: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """PCA of a row sample -> ((D, D) orthonormal components as columns,
    ordered by decreasing eigenvalue; (D,) eigenvalues in that order).

    Shared by BSA (full-rank projection + residual-energy pruning) and the
    cascade's skinny projection mirror (``core.layout.projection_mirror``):
    orthonormal columns make any rank-R prefix projection a *contraction*,
    so projected L2 distances lower-bound full distances — the exact-safe
    keep test the cascade's first stage relies on."""
    X_sample = np.asarray(X_sample, dtype=np.float32)
    mean = X_sample.mean(axis=0)
    cov = np.cov((X_sample - mean).T).astype(np.float64)
    if cov.ndim == 0:  # D == 1 degenerate
        cov = cov.reshape(1, 1)
    eigval, eigvec = np.linalg.eigh(cov)
    order = np.argsort(eigval)[::-1]
    components = eigvec[:, order].astype(np.float32)  # (D, D), col = component
    return components, eigval[order]


def make_bsa(X_sample: np.ndarray, m: float = 3.0, seed: int = 0) -> Pruner:
    X_sample = np.asarray(X_sample, dtype=np.float32)
    n, dim = X_sample.shape
    components, eigval = pca_components(X_sample)

    # Residual-energy statistics per cut d: for pairwise squared distances the
    # expected残 energy in dims >= d is 2 * sum_{j>=d} lambda_j; its spread is
    # calibrated from eigenvalue tails (chi-square-like second moment).
    lam = np.maximum(eigval, 0.0)
    tail = 2.0 * np.concatenate([np.cumsum(lam[::-1])[::-1], [0.0]])  # (D+1,)
    tail_var = 8.0 * np.concatenate([np.cumsum((lam**2)[::-1])[::-1], [0.0]])
    mu_res = jnp.asarray(tail, dtype=jnp.float32)          # index by d
    sigma_res = jnp.asarray(np.sqrt(tail_var), dtype=jnp.float32)

    Cj = jnp.asarray(components)

    def keep_mask(partial: jax.Array, d: jax.Array, thr: jax.Array) -> jax.Array:
        d = jnp.clip(d.astype(jnp.int32), 0, dim)
        lower = partial + jnp.maximum(mu_res[d] - m * sigma_res[d], 0.0)
        return lower <= thr

    return Pruner(
        name="bsa",
        is_exact=False,
        needs_preprocess=True,
        preprocess=lambda X: (np.asarray(X, np.float32) @ components),
        transform_query=lambda q: q @ Cj,
        keep_mask=keep_mask,
        fingerprint=pruner_fingerprint("bsa", components, m),
        aux={"components": components, "m": m, "seed": seed},
    )


# --------------------------------------------------------------------------
# PDX-BOND — the paper's own pruner.  No preprocessing; exact.
#
# Predicate: the monotone partial distance itself (a lower bound of the full
# distance for L2/L1).  Power comes from the query-aware dimension order:
# visit dimensions by decreasing |q_d - collection_mean_d| ("distance to
# means", Figure 5), optionally grouped in contiguous zones for sequential
# access (the zone logic lives in PDXearch since it owns the step schedule).
# --------------------------------------------------------------------------
def make_bond(dim_means: jax.Array, zone_size: int = 0) -> Pruner:
    means = jnp.asarray(dim_means)
    dim = means.shape[0]

    def dim_order(q: jax.Array) -> jax.Array:
        score = jnp.abs(q - means)
        if zone_size and zone_size > 1:
            nz = dim // zone_size
            zone_score = score[: nz * zone_size].reshape(nz, zone_size).sum(axis=1)
            zrank = jnp.argsort(-zone_score)
            base = zrank[:, None] * zone_size + jnp.arange(zone_size)[None, :]
            perm = base.reshape(-1)
            if nz * zone_size < dim:  # leftover dims go last, in order
                perm = jnp.concatenate(
                    [perm, jnp.arange(nz * zone_size, dim, dtype=perm.dtype)]
                )
            return perm.astype(jnp.int32)
        return jnp.argsort(-score).astype(jnp.int32)

    return Pruner(
        name="bond",
        is_exact=True,
        needs_preprocess=False,
        preprocess=lambda X: X,
        transform_query=lambda q: q,
        keep_mask=lambda partial, d, thr: partial <= thr,
        dim_order=dim_order,
        fingerprint=pruner_fingerprint("bond", means, zone_size),
    )


def make_bond_decreasing(dim: int) -> Pruner:
    """BOND's original 'decreasing query value' criterion (Figure 5 baseline)."""

    def dim_order(q: jax.Array) -> jax.Array:
        return jnp.argsort(-q).astype(jnp.int32)

    return Pruner(
        name="bond-decreasing",
        is_exact=True,
        needs_preprocess=False,
        preprocess=lambda X: X,
        transform_query=lambda q: q,
        keep_mask=lambda partial, d, thr: partial <= thr,
        dim_order=dim_order,
        fingerprint=pruner_fingerprint("bond-decreasing", dim),
    )
