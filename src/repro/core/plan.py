"""Query planner + executor registry — *how* a ``SearchSpec`` executes.

``plan_search`` maps (spec, store, query count, optional mesh) onto one of
the registered executors; ``execute`` runs the chosen plan.  All executors
answer the same question — top-k under the spec's metric/pruner config —
and differ only in execution strategy:

  adaptive             host-orchestrated PDXearch (paper Section 4); the
                       only executor with per-query IVF routing.
  jit-masked           shape-static masked PDXearch (whole search jittable).
  batch-matmul         exact MXU scan of a (B, D) query batch.
  block-sharded        PDX partitions sharded over the mesh "data" axis;
                       per-query top-k all-gather.
  dim-sharded          dimension slices sharded over the mesh "model" axis;
                       psum completes distances.
  batch-block-sharded  batch-matmul fused with block sharding: ONE packed
                       top-k all-gather per query *batch* (the ROADMAP's
                       "batched distributed search").
  routed_bucket        bucket-owned sharding (IVF + "data" mesh): queries
                       travel to the shards owning their top-nprobe buckets
                       via one all-to-all, each shard scans only its owned
                       buckets (masked per query), candidates merge
                       hierarchically through one packed all-gather.
  fused-scan           the ``repro.kernels`` megakernel: ONE Pallas grid
                       over (partition, d-tile) with the ADSampling test
                       fused per tile, streaming the store's device mirror
                       at ``spec.scan_dtype`` width (bf16/int8 operands
                       dequantized in-register).
  fused-batch          the quantized MXU batch kernel over the mirror —
                       the batched counterpart of fused-scan.

Both fused executors re-rank the top ``rerank_mult * k`` candidates
against the f32 master tiles whenever ``scan_dtype != "f32"``, so returned
distances stay exact; ``spec.kernel`` picks the Pallas kernels or their
jnp twin bodies (same contract, XLA-fused).

Planner rules, in order: a forced ``spec.executor`` wins; an IVF index on
a "data"-axis mesh routes by bucket ownership (unless
``spec.routing="broadcast"`` keeps routing host-side); a usable mesh picks
a sharded executor (batched when B > 1 and ``spec.batch_collectives``) —
on the mesh, a non-f32 ``scan_dtype`` flows *into* the batched/routed
sharded executors (quantized shard scan + on-shard f32 re-rank) rather
than changing the dispatch, while the per-query block-/dim-sharded paths
scan the f32 masters and say so in their plan reason;
otherwise a Pallas-eligible spec (``kernel="pallas"``, a TPU backend with
``kernel="auto"``, or any reduced-precision ``scan_dtype``) picks a fused
executor, batches take the MXU scan and single queries the adaptive (or,
with ``spec.prefer_static``, the masked) path.  Every fallback records its
reason in the ``ExecutionPlan`` trace.  A stats request no longer changes
dispatch: every executor accounts ``SearchStats`` work now — exactly on
the pruned paths (adaptive, jit-masked, block-sharded, fused-scan), as
full-scan totals on the exact paths, and per selected bucket on the routed
path — so ``pruning_power`` is observable wherever a query lands.

When observability is on (``repro.obs``), ``execute`` wraps the executor
body in a ``scan`` span and the write-head merge in a ``merge`` span,
executors record ``repro_device_bytes_total`` from the mirror dtype and
executed plan, and the placement cache counts hits/misses — see the
``repro.obs`` package docstring for the full metric/span taxonomy.

Tile->shard mappings are ``repro.dist.placement.Placement`` values, cached
on the store per ``(tiles_version, n_shards, kind)`` — arranging + padding
copies the tiles, which must cost once per sealed-tile mutation, not once
per search, and the dict key means the same store serving two mesh sizes
(or both a block and a bucket layout) never thrashes the cache.

Mutable stores (``core.layout.MutablePDXStore``) flow through the same
planner: the plan trace records ``store.version`` (so a cached/compared
plan is visibly tied to the tiles it saw), ``execute`` merges the store's
unflushed write-head rows *exactly* (never pruned) into every executor's
top-k, and the block-sharded executors pad the partition axis with empty
tiles when churn has left it indivisible by the mesh — a mutable store
never falls off the sharded fast path just because a repack changed P.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..obs import metrics as _metrics
from ..obs import trace as _trace
from .distance import nary_distance, pdx_distance
from .layout import (
    BucketCache,
    DeviceMirror,
    MutablePDXStore,
    PDXStore,
    device_mirror,
    projection_mirror,
)
from .pdxearch import SearchStats, pdxearch, pdxearch_jit, search_batch_matmul
from .pruners import Pruner
from .spec import SearchSpec, parse_cascade_stage
from .topk import (
    TopK,
    rerank_positions,
    topk_from_batch,
    topk_init,
    topk_merge,
    topk_threshold,
)

__all__ = [
    "ExecutionPlan",
    "PreparedSearch",
    "executor_names",
    "plan_search",
    "execute",
    "prepare_execute",
    "pow2_bucket",
    "warm_shapes",
    "register_executor",
]


@dataclasses.dataclass(frozen=True)
class ExecutionPlan:
    """Plan trace: which executor runs, and why the planner picked it."""

    executor: str
    reason: str
    n_queries: int
    pruner: str = ""            # pruner fingerprint (stable identity)
    mesh_axes: tuple = ()
    store_version: int = 0      # MutablePDXStore.version (frozen stores: 0)


# -------------------------------------------------------------------- registry
# name -> fn(store, pruner, Q(B,D), spec, *, ivf, mesh, stats) -> (ids, dists)
# with ids/dists shaped (B, k).
_EXECUTORS: dict[str, Callable] = {}


def register_executor(name: str):
    def deco(fn):
        _EXECUTORS[name] = fn
        return fn
    return deco


def executor_names() -> tuple[str, ...]:
    return tuple(_EXECUTORS)


def pow2_bucket(n: int, cap: Optional[int] = None) -> int:
    """Smallest power of two >= ``n`` (clamped to ``cap`` when given) — the
    compiled-shape batch buckets of the serving tier, the same demand-octave
    discipline ``dist.routing.plan_routing`` applies to send budgets: a
    drifting load cycles through at most ``log2(cap) + 1`` distinct executor
    shapes instead of minting one per batch size."""
    if n < 1:
        raise ValueError(f"batch size must be >= 1, got {n}")
    b = 1
    while b < n:
        b *= 2
    return b if cap is None else min(b, cap)


# --------------------------------------------------------------------- planner
def plan_search(
    spec: SearchSpec,
    store: PDXStore,
    n_queries: int,
    *,
    pruner: Optional[Pruner] = None,
    ivf=None,
    mesh=None,
) -> ExecutionPlan:
    """Choose an executor for ``n_queries`` queries against ``store``."""
    fp = pruner.fingerprint if pruner is not None else ""
    axes = tuple(getattr(mesh, "axis_names", ())) if mesh is not None else ()
    version = getattr(store, "version", 0)

    def plan(executor: str, reason: str) -> ExecutionPlan:
        # don't drop spec knobs silently: record exactly what the chosen
        # executor honors.  Only the fused executors run Pallas bodies,
        # and only these five scan a reduced-precision device mirror.
        mirror_ok = executor in (
            "fused-scan", "fused-batch", "batch-block-sharded",
            "routed_bucket", "cascade-scan", "cascade-batch", "tiered-scan",
            "routed_tiered",
        )
        if spec.kernel == "pallas" and not (
            executor.startswith("fused")
            or executor in ("cascade-scan", "cascade-batch")
        ):
            reason += " (kernel='pallas' noted: this executor runs jnp bodies)"
        if spec.scan_dtype != "f32" and not mirror_ok:
            reason += (
                f" (scan_dtype={spec.scan_dtype!r} ignored: this executor "
                "scans the f32 masters)"
            )
        if spec.hbm_slots is not None and executor not in (
            "tiered-scan", "routed_tiered"
        ):
            reason += (
                " (hbm_slots ignored: tiered serving needs an IVF index "
                "and this executor scans a fully-resident store/mirror)"
            )
        if spec.cascade is not None and executor not in (
            "cascade-scan", "cascade-batch"
        ):
            reason += (
                " (cascade ignored: only the host-side cascade executors "
                "run stage pipelines)"
            )
        return ExecutionPlan(
            executor=executor, reason=reason, n_queries=n_queries,
            pruner=fp, mesh_axes=axes, store_version=version,
        )

    if spec.executor is not None:
        if spec.executor not in _EXECUTORS:
            raise ValueError(
                f"unknown executor {spec.executor!r}; "
                f"registered: {executor_names()}"
            )
        return plan(spec.executor, "forced by spec.executor")

    if mesh is not None:
        if ivf is not None:
            if "data" in axes and spec.routing == "bucket":
                n_sh = mesh.shape["data"]
                if spec.hbm_slots is not None:
                    return plan(
                        "routed_tiered",
                        f"mesh 'data' axis ({n_sh} shards) + IVF + "
                        f"hbm_slots={spec.hbm_slots}: region-split bucket "
                        f"cache, shard-local pool scan + one packed top-k "
                        f"all-gather, exact host-RAM re-rank "
                        f"(nprobe={spec.nprobe})",
                    )
                return plan(
                    "routed_bucket",
                    f"mesh 'data' axis ({n_sh} shards) + IVF: bucket-owned "
                    f"placement, all-to-all query routing + hierarchical "
                    f"top-k merge (nprobe={spec.nprobe})",
                )
            note = (
                "mesh ignored: spec.routing='broadcast' keeps IVF bucket "
                "routing host-side; "
                if "data" in axes
                else f"mesh ignored: IVF bucket routing needs a 'data' axis, "
                     f"mesh has {axes}; "
            )
            return _host_plan(spec, n_queries, ivf, plan, note=note)
        if "data" in axes:
            n_sh = mesh.shape["data"]
            divisible = store.num_partitions % n_sh == 0
            # a mutable store's partition count drifts with churn; the block
            # executors pad it with empty tiles, so it stays on the fast path
            if divisible or isinstance(store, MutablePDXStore):
                pad_note = (
                    "" if divisible
                    else f" (P={store.num_partitions} padded to divisibility)"
                )
                if n_queries > 1 and spec.batch_collectives:
                    return plan(
                        "batch-block-sharded",
                        f"mesh 'data' axis ({n_sh} shards), batch of "
                        f"{n_queries}: one top-k all-gather per batch"
                        + pad_note,
                    )
                return plan(
                    "block-sharded",
                    f"mesh 'data' axis ({n_sh} shards): per-query "
                    "shard-local PDXearch + top-k all-gather" + pad_note,
                )
            return _host_plan(
                spec, n_queries, ivf, plan,
                note=f"mesh ignored: {store.num_partitions} partitions not "
                     f"divisible over {n_sh} 'data' shards; ",
            )
        if "model" in axes:
            n_sh = mesh.shape["model"]
            if store.dim % n_sh == 0:
                return plan(
                    "dim-sharded",
                    f"mesh 'model' axis ({n_sh} shards): dimension-slab "
                    "partial distances + psum",
                )
            return _host_plan(
                spec, n_queries, ivf, plan,
                note=f"mesh ignored: D={store.dim} not divisible over "
                     f"{n_sh} 'model' shards; ",
            )
        return _host_plan(
            spec, n_queries, ivf, plan,
            note=f"mesh ignored: no 'data'/'model' axis in {axes}; ",
        )

    return _host_plan(spec, n_queries, ivf, plan)


def _resolve_pallas(spec: SearchSpec) -> bool:
    """Does ``spec.kernel`` resolve to the Pallas bodies here?"""
    if spec.kernel == "pallas":
        return True
    if spec.kernel == "jnp":
        return False
    return jax.default_backend() == "tpu"


def _wants_fused(spec: SearchSpec) -> bool:
    """A spec opts into the fused mirror-scanning executors by forcing the
    Pallas kernels, by running on a TPU backend with ``kernel="auto"``, or
    by requesting any reduced-precision scan (which only they honor
    host-side)."""
    return (
        spec.kernel == "pallas"
        or spec.scan_dtype != "f32"
        or (spec.kernel == "auto" and jax.default_backend() == "tpu")
    )


def _host_plan(spec, n_queries, ivf, plan, note: str = "") -> ExecutionPlan:
    if spec.hbm_slots is not None and ivf is not None:
        return plan(
            "tiered-scan",
            note + f"hbm_slots={spec.hbm_slots}: bucket-granular HBM cache "
                   f"over the routed set (scan_dtype={spec.scan_dtype}, "
                   f"nprobe={spec.nprobe}), exact host-RAM re-rank",
        )
    if spec.cascade is not None:
        body = "pallas" if _resolve_pallas(spec) else "jnp"
        where = "IVF-routed START, " if ivf is not None else ""
        if n_queries > 1:
            return plan(
                "cascade-batch",
                note + f"multi-resolution cascade {'→'.join(spec.cascade)} "
                       f"batched over the MXU ({where}kernel={body}, "
                       f"B={n_queries})",
            )
        return plan(
            "cascade-scan",
            note + f"multi-resolution cascade {'→'.join(spec.cascade)} "
                   f"({where}kernel={body}, B={n_queries})",
        )
    if _wants_fused(spec):
        body = "pallas" if _resolve_pallas(spec) else "jnp"
        if n_queries == 1 and spec.metric == "l2":
            where = "IVF-routed START, " if ivf is not None else ""
            return plan(
                "fused-scan",
                note + f"fused megakernel mirror scan ({where}scan_dtype="
                       f"{spec.scan_dtype}, kernel={body})",
            )
        extra = "; IVF store scanned exactly, all buckets" if ivf else ""
        return plan(
            "fused-batch",
            note + f"fused batched mirror scan (scan_dtype={spec.scan_dtype}"
                   f", kernel={body}, B={n_queries}){extra}",
        )
    if n_queries > 1 and ivf is None:
        return plan("batch-matmul",
                    note + f"batch of {n_queries} on one host: exact MXU scan")
    if spec.prefer_static and ivf is None:
        return plan("jit-masked",
                    note + "prefer_static: shape-static masked PDXearch")
    where = "IVF-routed" if ivf is not None else "flat"
    return plan("adaptive", note + f"{where} host-orchestrated PDXearch")


# ------------------------------------------------------------------- execution
def execute(
    plan: ExecutionPlan,
    spec: SearchSpec,
    store: PDXStore,
    pruner: Pruner,
    Q: jax.Array,
    *,
    ivf=None,
    mesh=None,
    stats: Optional[SearchStats] = None,
) -> tuple[np.ndarray, np.ndarray]:
    """Run ``plan`` for the (B, D) query batch ``Q`` -> (B, k) ids/dists.

    For mutable stores this is also the write-head merge point: whatever
    executor ran over the sealed tiles, the unflushed write-head rows are
    scanned exactly (never pruned — they carry no pruner metadata yet) and
    merged into every query's top-k, so freshly inserted vectors are
    reachable through all executors, sharded paths included.
    """
    fn = _EXECUTORS[plan.executor]
    with _trace.span("scan", executor=plan.executor,
                     scan_dtype=spec.scan_dtype):
        ids, dists = fn(
            store, pruner, Q, spec, ivf=ivf, mesh=mesh, stats=stats
        )
    with _trace.span("merge", executor=plan.executor):
        return _merge_write_head(
            store, pruner, Q, spec, np.asarray(ids), np.asarray(dists),
            stats=stats,
        )


@dataclasses.dataclass
class PreparedSearch:
    """The host half of one planned batch; ``run()`` performs the device
    half.  Produced by ``prepare_execute`` so a serving loop can overlap
    batch N+1's host-side planning (routing, send-buffer packing,
    placement/cache lookups) with batch N's device collectives — the
    double-buffering in ``repro.serve.vector``.  ``run()`` must be called
    exactly once, and the store must not be mutated between ``prepare``
    and ``run`` (the serving loop serializes both under its store lock /
    executor thread)."""

    plan: ExecutionPlan
    spec: SearchSpec
    _run: Callable[[], tuple[np.ndarray, np.ndarray]]

    def run(self) -> tuple[np.ndarray, np.ndarray]:
        return self._run()


def prepare_execute(
    plan: ExecutionPlan,
    spec: SearchSpec,
    store: PDXStore,
    pruner: Pruner,
    Q: jax.Array,
    *,
    ivf=None,
    mesh=None,
    stats: Optional[SearchStats] = None,
) -> PreparedSearch:
    """Split ``execute`` into host preparation (now) and device execution
    (``PreparedSearch.run()``, later).

    For ``routed_bucket`` the split is genuine: placement lookup, batch
    transform, bucket ranking, exchange planning, and send-buffer packing
    all happen here, and ``run()`` only fires the collectives.  For every
    other executor the host share is negligible, so the whole ``execute``
    is deferred into ``run()`` — callers get one uniform contract."""
    if plan.executor == "routed_bucket":
        launch, sel = _prepare_routed_host(
            store, pruner, Q, spec, ivf=ivf, mesh=mesh
        )

        def _run():
            with _trace.span("scan", executor=plan.executor,
                             scan_dtype=spec.scan_dtype):
                ids, dists = _run_routed_device(
                    launch, sel, store, spec, ivf=ivf, stats=stats
                )
            with _trace.span("merge", executor=plan.executor):
                return _merge_write_head(
                    store, pruner, Q, spec, ids, dists, stats=stats
                )

        return PreparedSearch(plan=plan, spec=spec, _run=_run)

    if plan.executor in ("tiered-scan", "routed_tiered"):
        # the host half ends with the first chunk's ensure() — the cache
        # uploads of batch N+1 overlap batch N's device scan through the
        # serving loop's depth-1 handoff (routing-driven prefetch)
        if plan.executor == "tiered-scan":
            tl = _prepare_tiered_host(store, pruner, Q, spec, ivf=ivf)
            runner = lambda: _run_tiered_device(        # noqa: E731
                tl, store, spec, ivf=ivf, stats=stats
            )
        else:
            tl = _prepare_routed_tiered_host(
                store, pruner, Q, spec, ivf=ivf, mesh=mesh
            )
            runner = lambda: _run_routed_tiered_device(  # noqa: E731
                tl, store, spec, ivf=ivf, mesh=mesh, stats=stats
            )

        def _run_tiered():
            with _trace.span("scan", executor=plan.executor,
                             scan_dtype=spec.scan_dtype):
                ids, dists = runner()
            with _trace.span("merge", executor=plan.executor):
                return _merge_write_head(
                    store, pruner, Q, spec, ids, dists, stats=stats
                )

        return PreparedSearch(plan=plan, spec=spec, _run=_run_tiered)

    return PreparedSearch(
        plan=plan, spec=spec,
        _run=lambda: execute(
            plan, spec, store, pruner, Q, ivf=ivf, mesh=mesh, stats=stats
        ),
    )


def warm_shapes(
    spec: SearchSpec,
    store: PDXStore,
    pruner: Pruner,
    buckets,
    *,
    ivf=None,
    mesh=None,
) -> dict:
    """Pre-compile the executor for each batch-shape bucket by pushing one
    real synthetic batch per bucket through ``prepare_execute().run()`` —
    seeding the jit shape caches, the placement/mirror caches, and (for
    mutable stores) the static-shape write-head merge, so a serving loop's
    steady state mints no new executables.  Returns {bucket: executor}.

    On a routed mesh the all-to-all budget is data-dependent (a demand
    octave per skew level); the warmup batch spreads queries across the
    batch index, which warms the common low-demand octave — the first
    heavily skewed batch may still compile its (single) spilled shape."""
    out = {}
    D = store.dim
    rng = np.random.default_rng(0)
    for b in sorted(set(int(x) for x in buckets)):
        Qb = rng.standard_normal((b, D)).astype(np.float32)
        plan = plan_search(
            spec, store, b, pruner=pruner, ivf=ivf, mesh=mesh
        )
        prepare_execute(
            plan, spec, store, pruner, jnp.asarray(Qb), ivf=ivf, mesh=mesh
        ).run()
        if getattr(store, "head_capacity", None):
            # churn serving inserts into the head mid-stream: warm the
            # (bucket, head_capacity) merge executable even while empty
            H = jnp.full((store.head_capacity, D), 0.0, jnp.float32)
            Qt = _transform_batch(pruner, jnp.asarray(Qb))
            _head_distances(H, Qt, spec.metric)
        if spec.cascade is not None:
            # the cascade executors pick pow2 compaction / re-rank shapes
            # from runtime survivor counts — compile the whole menu, not
            # just the one path the warm batch took
            _warm_cascade_menu(spec, store, pruner, b, _resolve_pallas(spec))
        out[b] = plan.executor
    return out


@functools.partial(jax.jit, static_argnames=("metric",))
def _head_distances(H, Qt, metric):
    """(H_cap, D) full head buffer x (B, D) queries -> (B, H_cap) distances.
    Shape-static in the head CAPACITY, not the live count: under serving
    churn the fill level changes every insert, and a fill-shaped trace
    would mint one executable per distinct fill — this is one executable
    per (B, head_capacity) pair, warmed once by ``warm_shapes``."""
    return jax.vmap(lambda q: nary_distance(H, q, metric))(Qt)


def _merge_write_head(
    store, pruner: Pruner, Q: jax.Array, spec: SearchSpec,
    ids: np.ndarray, dists: np.ndarray,
    stats: Optional[SearchStats] = None,
) -> tuple[np.ndarray, np.ndarray]:
    """Merge the store's live write-head rows into the (B, k) top-k — exact,
    unpruned, in the pruner-transformed space the sealed tiles live in.

    The distance pass runs over the FULL head buffer (dead rows masked to
    +inf host-side) so its compiled shape depends only on ``head_capacity``
    and the batch bucket — never on the drifting fill level."""
    head_snapshot = getattr(store, "head_snapshot", None)
    if head_snapshot is None:
        return ids, dists
    hids, hvecs = head_snapshot()                    # full (H,), (H, D)
    live = hids >= 0
    m = int(live.sum())
    if m == 0:
        return ids, dists
    Qt = _transform_batch(pruner, Q)                             # (B, D)
    hd = np.asarray(
        _head_distances(jnp.asarray(hvecs, jnp.float32), Qt, spec.metric)
    )  # (B, H)
    hd = np.where(live[None, :], hd, np.inf)
    if stats is not None:  # the LIVE head rows are scanned in full, unpruned
        work = float(len(Q) * m * hvecs.shape[1])
        stats.values_total += work
        stats.values_computed += work
    all_d = np.concatenate([dists.astype(np.float32), hd.astype(np.float32)],
                           axis=1)
    all_i = np.concatenate(
        [ids, np.broadcast_to(hids.astype(ids.dtype), hd.shape)], axis=1
    )
    order = np.argsort(all_d, axis=1, kind="stable")[:, : spec.k]
    return (
        np.take_along_axis(all_i, order, axis=1),
        np.take_along_axis(all_d, order, axis=1),
    )


def _exact_scan_stats(stats: Optional[SearchStats], store, B: int) -> None:
    """Work accounting for the exact full-scan executors: every live value
    is computed, nothing avoided — the honest baseline ``pruning_power``
    compares against."""
    if stats is None:
        return
    work = float(np.asarray(store.counts).sum()) * store.dim * B
    stats.values_total += work
    stats.values_computed += work
    stats.partitions_visited += store.num_partitions * B


@register_executor("adaptive")
def _exec_adaptive(store, pruner, Q, spec, *, ivf, mesh, stats):
    out_i, out_d = [], []
    for q in Q:
        if ivf is not None:
            with _trace.span("route", nprobe=spec.nprobe):
                qt = pruner.transform_query(q)
                order, start_parts = ivf.route(
                    qt, spec.nprobe, spec.metric, spec.route_dtype
                )
        else:
            order, start_parts = None, 1
        res = pdxearch(
            store, q, spec.k, pruner, metric=spec.metric,
            schedule=spec.schedule, delta_d=spec.delta_d,
            sel_frac=spec.sel_frac, group=spec.group,
            pid_order=order, start_parts=start_parts, stats=stats,
        )
        out_i.append(np.asarray(res.ids))
        out_d.append(np.asarray(res.dists))
    return np.stack(out_i), np.stack(out_d)


@register_executor("jit-masked")
def _exec_jit_masked(store, pruner, Q, spec, *, ivf, mesh, stats):
    if ivf is not None:
        raise ValueError(
            "jit-masked executor has no IVF routing (bucket ranking is "
            "data-dependent); use the adaptive executor"
        )
    out_i, out_d = [], []
    for q in Q:
        res = pdxearch_jit(
            store, q, spec.k, pruner, metric=spec.metric,
            schedule=spec.schedule, delta_d=spec.delta_d, stats=stats,
        )
        out_i.append(np.asarray(res.ids))
        out_d.append(np.asarray(res.dists))
    return np.stack(out_i), np.stack(out_d)


def _transform_batch(pruner: Pruner, Q: jax.Array) -> jax.Array:
    """Pruner query transforms are per-vector; vmap lifts them to batches."""
    if not pruner.needs_preprocess:
        return Q
    return jax.vmap(pruner.transform_query)(Q)


@register_executor("batch-matmul")
def _exec_batch_matmul(store, pruner, Q, spec, *, ivf, mesh, stats):
    # Exact scan over ALL partitions (IVF engines included: their store holds
    # every bucket, so this is exact; nprobe does not apply).
    Qt = _transform_batch(pruner, Q)
    res = search_batch_matmul(store.data, store.ids, Qt, spec.k, spec.metric)
    B = Q.shape[0]
    _exact_scan_stats(stats, store, B)
    if _metrics.enabled():
        P, D, C = store.data.shape
        _metrics.counter(
            "repro_device_bytes_total", float(B) * P * D * C * 4,
            executor="batch-matmul", component="scan", dtype="f32",
        )
    return np.asarray(res.ids), np.asarray(res.dists)


# ------------------------------------------------- fused mirror executors
# The merge of the kernel island into the serving stack: these are the only
# executors that stream the store's reduced-precision device mirror
# (core.layout.device_mirror) and the only callers of the repro.kernels
# Pallas ops.  Candidates are tracked as flat tile POSITIONS (p * C + c),
# not global ids, so the exact f32 re-rank can gather master columns with
# one fancy index; positions map to ids only at the end.
def _rerank_k(spec: SearchSpec, store) -> int:
    if spec.scan_dtype == "f32":
        return spec.k
    cap = store.num_partitions * store.capacity
    return min(spec.rerank_mult * spec.k, cap)


@functools.partial(
    jax.jit, static_argnames=("rk", "metric", "use_pallas", "quantized",
                              "packed", "dim")
)
def _fused_batch_scan(
    mdata, ids, Qt, scale, offset, rk, metric, use_pallas, quantized,
    packed: bool = False, dim: int | None = None,
) -> TopK:
    """Scan every mirror tile with the quantized batch kernel -> per-query
    top-``rk`` flat positions (PAD lanes carry position -1)."""
    from ..kernels.ops import batched_distance_quant_op
    from ..kernels.ref import dequantize_ref

    P = mdata.shape[0]
    C = mdata.shape[2]
    sc = scale if quantized else None
    off = offset if quantized else None
    pos = jnp.arange(P * C, dtype=jnp.int32).reshape(P, C)
    pos = jnp.where(ids >= 0, pos, -1)

    def body(state: TopK, inp):
        tile, tpos = inp
        if metric == "l1":  # no matmul form; dequantize + vmapped VPU scan
            t32 = dequantize_ref(tile, sc, off, packed=packed, dim=dim)
            dmat = jax.vmap(lambda q: pdx_distance(t32, q, "l1"))(Qt)
        else:
            dmat = batched_distance_quant_op(
                tile, Qt, sc, off, metric, use_pallas,
                packed=packed, dim=dim,
            )
        return jax.vmap(topk_merge, (0, 0, None))(state, dmat, tpos), None

    init = jax.vmap(lambda _: topk_init(rk))(jnp.arange(Qt.shape[0]))
    state, _ = jax.lax.scan(body, init, (mdata, pos))
    return state


@jax.jit
def _positions_to_ids(store_ids, cand: TopK) -> TopK:
    safe = jnp.maximum(cand.ids, 0)
    gids = jnp.where(cand.ids >= 0, store_ids.reshape(-1)[safe], -1)
    return TopK(dists=cand.dists, ids=gids)


@register_executor("fused-batch")
def _exec_fused_batch(store, pruner, Q, spec, *, ivf, mesh, stats):
    """Exact-over-store scan of the device mirror at ``spec.scan_dtype``
    width (IVF engines included — all buckets, like batch-matmul), f32
    re-ranked when the mirror is reduced-precision."""
    mirror = device_mirror(store, spec.scan_dtype)
    Qt = _transform_batch(pruner, jnp.asarray(Q, jnp.float32))
    rk = _rerank_k(spec, store)
    cand = _fused_batch_scan(
        mirror.data, store.ids, Qt, mirror.scale, mirror.offset,
        rk, spec.metric, _resolve_pallas(spec), mirror.quantized,
        packed=mirror.packed, dim=mirror.dim,
    )
    if spec.scan_dtype == "f32":
        res = _positions_to_ids(store.ids, cand)
    else:
        with _trace.span("rerank", rk=rk):
            res = _trace.fence(rerank_positions(
                store.data, store.ids, Qt, cand, spec.k, spec.metric
            ))
    B = Q.shape[0]
    _exact_scan_stats(stats, store, B)
    if _metrics.enabled():
        P, C = mirror.data.shape[0], mirror.data.shape[2]
        D = mirror.dim  # logical D (packed int4 halves the stored axis)
        _metrics.counter(
            "repro_device_bytes_total",
            float(B) * P * D * C * mirror.bytes_per_value,
            executor="fused-batch", component="scan", dtype=mirror.dtype,
        )
        if spec.scan_dtype != "f32":
            _metrics.counter(
                "repro_device_bytes_total", float(B) * rk * D * 4,
                executor="fused-batch", component="rerank", dtype="f32",
            )
    return np.asarray(res.ids), np.asarray(res.dists)


@register_executor("fused-scan")
def _exec_fused_scan(store, pruner, Q, spec, *, ivf, mesh, stats):
    """Single-query megakernel scan: ONE Pallas grid over (partition,
    d-tile), ADSampling keep-mask fused per tile, mirror operands
    dequantized in-register, dead partitions skipped whole-tile.

    The threshold is seeded by an exact f32 START scan of one partition —
    the IVF-routed nearest bucket's first partition when an index exists,
    partition 0 otherwise.  The START partition is masked OUT of the
    megakernel scan (its lanes would otherwise enter the merge pool twice
    and crowd out the k-th distinct neighbour) and its candidates merge
    exactly, unpruned — a hypothesis-test casualty there is impossible.
    Pruners other than ADSampling scan unpruned (thr = inf): they get the
    bandwidth win without a foreign predicate."""
    if spec.metric != "l2":
        raise ValueError(
            "fused-scan is L2-only (ADSampling's domain); the planner "
            "routes other metrics to fused-batch"
        )
    mirror = device_mirror(store, spec.scan_dtype)
    use_pallas = _resolve_pallas(spec)
    rk = _rerank_k(spec, store)
    prune = pruner.name == "adsampling" and pruner.aux is not None
    eps0 = float(pruner.aux["eps0"]) if prune else 2.1
    sc = mirror.scale if mirror.quantized else None
    off = mirror.offset if mirror.quantized else None
    out_i, out_d = [], []
    for q in Q:
        qt = pruner.transform_query(jnp.asarray(q, jnp.float32))
        p0 = 0
        if ivf is not None:
            order, _ = ivf.route(qt, 1, "l2", dtype=spec.route_dtype)
            if len(order):
                p0 = int(order[0])
        start = topk_from_batch(
            pdx_distance(store.data[p0], qt, "l2"), store.ids[p0], spec.k
        )
        thr = topk_threshold(start) if prune else jnp.float32(np.inf)
        res = _fused_scan_one(
            mirror.data, store.data, store.ids, jnp.int32(p0), qt, thr,
            sc, off, eps0, rk, spec.k, use_pallas,
            spec.scan_dtype == "f32", start,
            packed=mirror.packed, dim=mirror.dim,
        )
        if stats is not None:
            _fused_scan_stats(stats, store, mirror, p0, qt, thr, eps0)
        out_i.append(np.asarray(res.ids))
        out_d.append(np.asarray(res.dists))
    if spec.scan_dtype != "f32":
        # the exact re-rank runs fused inside _fused_scan_one — record it
        # as a zero-width annotation span plus its gather bytes
        with _trace.span("rerank", fused="in-kernel", rk=rk):
            pass
        _metrics.counter(
            "repro_device_bytes_total",
            float(len(Q)) * rk * store.dim * 4,
            executor="fused-scan", component="rerank", dtype="f32",
        )
    return np.stack(out_i), np.stack(out_d)


def _fused_scan_stats(stats, store, mirror, p0, qt, thr, eps0) -> None:
    """Work accounting for the megakernel: replay the per-d-tile keep-mask
    walk (``obs.meters.fused_tile_counts``) to recover how many lanes each
    tile computed — an explicit second pass over the mirror, paid only when
    stats are requested (the fused kernel itself can't count without
    spilling its mask).  The START partition is masked out of the walk and
    charged at full D, exactly mirroring the executor."""
    from ..obs import meters as _meters

    counts = np.asarray(store.counts)
    P, C = mirror.data.shape[0], mirror.data.shape[2]
    D = mirror.dim  # logical D (packed int4 halves the stored axis)
    ids_scan = store.ids.at[p0].set(-1)
    lanes, parts = _meters.fused_tile_counts(
        mirror.data, ids_scan, qt, thr, mirror.scale, mirror.offset,
        eps0=eps0, packed=mirror.packed, dim=mirror.dim,
    )
    w = _meters.tile_widths(D)
    total = float(counts.sum()) * D
    computed = float(counts[p0]) * D + float((lanes * w).sum())
    stats.values_total += total
    stats.values_computed += computed
    stats.values_avoided += total - computed
    stats.partitions_visited += P
    if _metrics.enabled():
        demand = (
            D * C * 4 + float((parts * w).sum()) * C * mirror.bytes_per_value
        )
        _metrics.counter(
            "repro_device_bytes_total", demand,
            executor="fused-scan", component="scan", dtype=mirror.dtype,
        )


@functools.partial(
    jax.jit,
    static_argnames=("eps0", "rk", "k", "use_pallas", "exact", "packed",
                     "dim"),
)
def _fused_scan_one(
    mdata, master, ids, p0, qt, thr, scale, offset, eps0, rk, k, use_pallas,
    exact, start: TopK, packed: bool = False, dim: int | None = None,
) -> TopK:
    from ..kernels.ops import pdx_prune_scan_multi_op

    P, _, C = mdata.shape
    # the START partition was scanned exactly already: kill its lanes so the
    # megakernel whole-tile-skips it and its ids never enter the pool twice
    ids_scan = ids.at[p0].set(-1)
    dists, alive = pdx_prune_scan_multi_op(
        mdata, ids_scan, qt, thr, scale, offset, eps0=eps0,
        use_pallas=use_pallas, packed=packed, dim=dim,
    )
    flat_d = jnp.where(alive, dists, jnp.inf).reshape(-1)
    cand = topk_from_batch(flat_d, jnp.arange(P * C, dtype=jnp.int32), rk)
    # dead lanes carry +inf: only real survivors are selected unless fewer
    # than rk survive, and PAD positions resolve to id -1 below either way
    if exact:
        res = _positions_to_ids(ids_scan, TopK(cand.dists, cand.ids))
    else:
        res = rerank_positions(
            master, ids_scan, qt[None],
            TopK(cand.dists[None], cand.ids[None]), k, "l2",
        )
        res = TopK(dists=res.dists[0], ids=res.ids[0])
    return topk_merge(res, start.dists, start.ids)


# ------------------------------------------------- cascade executor
def _quant_err_norm(mirror) -> float:
    """L2 norm bound of a quantized mirror's reconstruction error vector.

    Per-dimension rounding error is at most ``scale_d / 2`` (the observed-
    range affine never clips), so ``||x_hat - x|| <= 0.5 * ||scale||`` for
    every live vector.  By the triangle inequality any vector with true
    distance ``<= thr`` has dequantized distance ``<= (sqrt(thr) + err)^2``
    — the exact-safe threshold inflation the cascade's quantized keep tests
    apply (without it, int4's coarse step at high D prunes true neighbours
    wholesale)."""
    if not mirror.quantized:
        return 0.0
    return 0.5 * float(np.linalg.norm(np.asarray(mirror.scale)))


@functools.partial(
    jax.jit,
    static_argnames=("eps0", "d_tile", "use_pallas", "packed", "dim",
                     "first"),
)
def _cascade_stage(
    mdata, ids_scan, alive_prev, qs, thr, scale, offset, eps0, d_tile,
    use_pallas, packed, dim, first,
):
    """One cascade scan stage over the (P, D_i, C) stage mirror ``mdata``
    -> ``(dists, alive, streamed)``.

    Stage N+1 seeds its keep-mask from stage N's alive bitmap: dead lanes'
    ids are forced to -1, so the kernels' ``ids >= 0`` convention carries
    the mask across stages.  Later stages run through the prefetch-skip
    wrapper's *(partition, d-tile)* pair schedule: entry-dead partitions
    fetch nothing and a partition stops fetching at the d-tile where its
    last lane dies (conditional in-kernel DMA on the Pallas path).
    ``streamed`` is the per-partition fetched-d-tile count the executor
    meters as realized traffic; the first stage has every partition live
    and streams plainly (streamed = all tiles)."""
    from ..kernels.ops import (
        pdx_prune_scan_multi_op,
        pdx_prune_scan_multi_prefetch_op,
    )

    if first:
        P = mdata.shape[0]
        logical = dim if packed else mdata.shape[1]
        nd = -(-logical // min(d_tile, logical))
        dists, alive = pdx_prune_scan_multi_op(
            mdata, ids_scan, qs, thr, scale, offset, eps0=eps0,
            d_tile=d_tile, use_pallas=use_pallas, packed=packed, dim=dim,
        )
        return dists, alive, jnp.full((P,), float(nd), jnp.float32)
    ids_i = jnp.where(alive_prev, ids_scan, -1)
    return pdx_prune_scan_multi_prefetch_op(
        mdata, ids_i, qs, thr, scale, offset, eps0=eps0,
        d_tile=d_tile, use_pallas=use_pallas, packed=packed, dim=dim,
    )


@functools.partial(jax.jit, static_argnames=("rk", "k"))
def _cascade_finish(master, ids_scan, qt, dists, alive, rk, k,
                    start: TopK) -> TopK:
    """Exact terminal stage: top-``rk`` surviving flat positions by their
    last approximate stage distance, re-scored against the f32 masters,
    merged with the exact START candidates."""
    flat_d = jnp.where(alive, dists, jnp.inf).reshape(-1)
    cand = topk_from_batch(
        flat_d, jnp.arange(flat_d.shape[0], dtype=jnp.int32), rk
    )
    res = rerank_positions(
        master, ids_scan, qt[None],
        TopK(cand.dists[None], cand.ids[None]), k, "l2",
    )
    return topk_merge(
        TopK(dists=res.dists[0], ids=res.ids[0]), start.dists, start.ids
    )


@register_executor("cascade-scan")
def _exec_cascade_scan(store, pruner, Q, spec, *, ivf, mesh, stats):
    """Multi-resolution cascade: each ``spec.cascade`` stage scans a
    narrower-then-wider sequence of device mirrors over the survivors of
    the previous stage, ending in the exact f32 re-rank.

    A ``"projN[:dtype]"`` first stage scans a rank-N PCA projection mirror
    with the exact-safe lower-bound keep test (single d-tile, so the test
    fires once at full projected dimensionality — safe for ANY pruner);
    full-dimension dtype stages run the ADSampling keep test when the
    engine pruner is ADSampling and unpruned (thr = inf) otherwise, like
    fused-scan.  The threshold comes from an exact f32 START scan of the
    IVF-routed nearest bucket's first partition (partition 0 without an
    index), which is masked out of every stage and merged exactly."""
    if spec.metric != "l2":
        raise ValueError("cascade-scan is L2-only (spec validation enforces "
                         "this)")
    if spec.cascade is None:
        raise ValueError("cascade-scan executor needs spec.cascade")
    scan_stages = [parse_cascade_stage(s) for s in spec.cascade][:-1]
    mirrors = [
        projection_mirror(store, rank, dt) if kind == "proj"
        else device_mirror(store, dt)
        for kind, dt, rank in scan_stages
    ]
    use_pallas = _resolve_pallas(spec)
    P, C, D = store.num_partitions, store.capacity, store.dim
    rk = min(spec.rerank_mult * spec.k, P * C)
    prune = pruner.name == "adsampling" and pruner.aux is not None
    eps0 = float(pruner.aux["eps0"]) if prune else 2.1
    qerrs = [_quant_err_norm(m) for m in mirrors]
    counts = np.asarray(store.counts)
    meter = stats is not None or _metrics.enabled()
    out_i, out_d = [], []
    for q in Q:
        qt = pruner.transform_query(jnp.asarray(q, jnp.float32))
        p0 = 0
        if ivf is not None:
            order, _ = ivf.route(qt, 1, "l2", dtype=spec.route_dtype)
            if len(order):
                p0 = int(order[0])
        start = topk_from_batch(
            pdx_distance(store.data[p0], qt, "l2"), store.ids[p0], spec.k
        )
        thr = topk_threshold(start)
        ids_scan = store.ids.at[p0].set(-1)
        dists = alive = None
        lanes_in = float(counts.sum() - counts[p0])
        computed = float(counts[p0]) * D  # START (re-rank added below)
        for si, ((kind, dt, rank), mirror) in enumerate(
            zip(scan_stages, mirrors)
        ):
            # exact-safe quantization slack: anything within thr of the
            # query sits within (sqrt(thr) + qerr)^2 in dequantized space
            thr_q = (jnp.sqrt(thr) + qerrs[si]) ** 2
            if kind == "proj":
                # single d-tile covering the whole projection: the keep
                # test fires once at d = rank, where orthonormal-projection
                # L2 lower-bounds the full L2 exactly (eps 0 — intermediate
                # ADSampling-style scaled tests are unsafe on PCA-projected
                # coordinates)
                qs = qt @ mirror.components
                thr_i, eps_i, d_tile = thr_q, 0.0, rank
            else:
                qs = qt
                thr_i = thr_q if prune else jnp.float32(np.inf)
                eps_i, d_tile = eps0, 64
            sc = mirror.scale if mirror.quantized else None
            off = mirror.offset if mirror.quantized else None
            dists, alive, streamed = _cascade_stage(
                mirror.data, ids_scan, alive, qs, thr_i, sc, off,
                eps_i, d_tile, use_pallas, mirror.packed, mirror.dim,
                si == 0,
            )
            if meter:
                n_surv = float(np.asarray(alive.sum()))
                # realized HBM traffic at d-tile granularity: a partition
                # fetched ``streamed`` tiles of this stage's mirror before
                # its last lane died (the first stage streams everything)
                dims_f = np.minimum(
                    np.asarray(streamed, np.float64) * d_tile,
                    float(mirror.dim),
                )
                stage_bytes = (
                    float(dims_f.sum()) * C * mirror.bytes_per_value
                )
                if stats is not None:
                    computed += lanes_in * mirror.dim
                if _metrics.enabled():
                    _metrics.counter(
                        "repro_cascade_stage_survivors", n_surv,
                        stage=str(si), stage_name=spec.cascade[si],
                    )
                    _metrics.counter(
                        "repro_cascade_stage_bytes", stage_bytes,
                        stage=str(si), stage_name=spec.cascade[si],
                    )
                    # what partition-granular skip would have streamed (an
                    # entering partition fetches its FULL stage mirror) —
                    # the realized counter above undercuts this by exactly
                    # the mid-scan d-tile savings
                    _metrics.counter(
                        "repro_cascade_stage_bytes_partition_model",
                        float((np.asarray(streamed) > 0).sum())
                        * mirror.dim * C * mirror.bytes_per_value,
                        stage=str(si), stage_name=spec.cascade[si],
                    )
                    _metrics.counter(
                        "repro_device_bytes_total", stage_bytes,
                        executor="cascade-scan", component="scan",
                        dtype=mirror.dtype,
                    )
                lanes_in = n_surv
        # the survivors of the (exact-safe, quantization-inflated) final
        # keep test are EXACTLY the candidates that could still enter the
        # top-k, so the re-rank must cover them all — a top-rk cut by the
        # last stage's noisy distances silently drops true neighbours when
        # int4's reordering radius exceeds rerank_mult*k.  rk widens to the
        # survivor count, pow2-bucketed so jit specializations stay bounded.
        n_alive = int(np.asarray((alive > 0).sum()))
        rk_eff = rk
        if n_alive > rk_eff:
            rk_eff = min(1 << (n_alive - 1).bit_length(), P * C)
        computed += float(rk_eff) * D
        res = _cascade_finish(
            store.data, ids_scan, qt, dists, alive, rk_eff, spec.k, start
        )
        if stats is not None:
            total = float(counts.sum()) * D
            stats.values_total += total
            stats.values_computed += computed
            stats.values_avoided += max(total - computed, 0.0)
            stats.partitions_visited += P
        if _metrics.enabled():
            _metrics.counter(
                "repro_device_bytes_total", float(D * C * 4),
                executor="cascade-scan", component="start", dtype="f32",
            )
            _metrics.counter(
                "repro_device_bytes_total", float(rk_eff * D * 4),
                executor="cascade-scan", component="rerank", dtype="f32",
            )
        out_i.append(np.asarray(res.ids))
        out_d.append(np.asarray(res.dists))
    with _trace.span("rerank", fused="in-kernel", rk=rk):
        pass
    return np.stack(out_i), np.stack(out_d)


@functools.partial(
    jax.jit,
    static_argnames=("eps0", "d_tile", "use_pallas", "packed", "dim"),
)
def _cascade_batch_stage(
    mdata, idx, alive, qs, thr, scale, offset, eps0, d_tile, use_pallas,
    packed, dim,
):
    """One MXU-batched cascade stage: gather the union-survivor columns of
    the (P, D_i, C) stage mirror into a compacted (D_i, S) tile, run the
    batched d-tile keep-test ladder over the whole query batch, scatter
    dists/alive back to flat (B, P*C) slot order (flat slot = p*C + c).
    ``idx`` is the pow2-padded union-survivor index list; pad entries carry
    P*C and land in a throwaway column that is sliced off."""
    from ..kernels.ops import batched_cascade_stage_op

    P, Dp, C = mdata.shape
    PC = P * C
    B = alive.shape[0]
    flat = mdata.transpose(1, 0, 2).reshape(Dp, PC)
    Tc = flat[:, jnp.minimum(idx, PC - 1)]
    alive_ext = jnp.concatenate(
        [alive, jnp.zeros((B, 1), alive.dtype)], axis=1
    )
    d_c, a_c = batched_cascade_stage_op(
        Tc, alive_ext[:, idx], qs, thr, scale, offset, eps0=eps0,
        d_tile=d_tile, use_pallas=use_pallas, packed=packed, dim=dim,
    )
    d_full = jnp.zeros((B, PC + 1), jnp.float32).at[:, idx].set(d_c)
    a_full = jnp.zeros((B, PC + 1), jnp.bool_).at[:, idx].set(a_c)
    return d_full[:, :PC], a_full[:, :PC]


@register_executor("cascade-batch")
def _exec_cascade_batch(store, pruner, Q, spec, *, ivf, mesh, stats):
    """Batch-native multi-resolution cascade: each ``spec.cascade`` stage
    runs ONCE over the whole query batch instead of once per query,
    carrying a shared (B, P*C) survivor bitmap between stages.

    Per stage, the union of every query's survivors is compacted to a
    pow2-bucketed column set (compiled shapes stay bounded), the stage
    mirror's surviving columns are gathered once, and the d-tile ladder
    runs through the batched quantized MXU kernel with per-query
    thresholds — a column fetched for any query is scanned for all B, so
    stage bytes are paid per batch, not per query.  START threshold
    seeding and the exact f32 re-rank stay per query with the same
    arithmetic as ``cascade-scan``: the final top-k depends only on the
    survivor bitmap and the exact re-rank (the rk cut always covers every
    survivor), both of which this executor reproduces, so ids match the
    per-query path bitwise.  The planner keeps the host loop as the B=1
    fallback."""
    if spec.metric != "l2":
        raise ValueError("cascade-batch is L2-only (spec validation "
                         "enforces this)")
    if spec.cascade is None:
        raise ValueError("cascade-batch executor needs spec.cascade")
    scan_stages = [parse_cascade_stage(s) for s in spec.cascade][:-1]
    mirrors = [
        projection_mirror(store, rank, dt) if kind == "proj"
        else device_mirror(store, dt)
        for kind, dt, rank in scan_stages
    ]
    use_pallas = _resolve_pallas(spec)
    P, C, D = store.num_partitions, store.capacity, store.dim
    PC = P * C
    B = Q.shape[0]
    rk = min(spec.rerank_mult * spec.k, PC)
    prune = pruner.name == "adsampling" and pruner.aux is not None
    eps0 = float(pruner.aux["eps0"]) if prune else 2.1
    qerrs = [_quant_err_norm(m) for m in mirrors]
    counts = np.asarray(store.counts)
    meter = stats is not None or _metrics.enabled()
    # START stays per query (exact arithmetic parity with cascade-scan)
    qts, starts, p0s = [], [], []
    for q in Q:
        qt = pruner.transform_query(jnp.asarray(q, jnp.float32))
        p0 = 0
        if ivf is not None:
            order, _ = ivf.route(qt, 1, "l2", dtype=spec.route_dtype)
            if len(order):
                p0 = int(order[0])
        starts.append(topk_from_batch(
            pdx_distance(store.data[p0], qt, "l2"), store.ids[p0], spec.k
        ))
        qts.append(qt)
        p0s.append(p0)
    Qt = jnp.stack(qts)                                   # (B, D)
    thr = jnp.stack([topk_threshold(s) for s in starts])  # (B,)
    p0_arr = np.asarray(p0s, np.int32)
    slot_part = jnp.arange(PC, dtype=jnp.int32) // C
    alive = (store.ids.reshape(-1)[None, :] >= 0) & (
        slot_part[None, :] != jnp.asarray(p0_arr)[:, None]
    )                                                     # (B, P*C)
    lanes_in = (counts.sum() - counts[p0_arr]).astype(np.float64)
    computed = counts[p0_arr].astype(np.float64) * D
    dists = None
    for si, ((kind, dt, rank), mirror) in enumerate(
        zip(scan_stages, mirrors)
    ):
        thr_q = (jnp.sqrt(thr) + qerrs[si]) ** 2
        if kind == "proj":
            Qs = Qt @ mirror.components
            thr_i, eps_i, d_tile = thr_q, 0.0, rank
        else:
            Qs = Qt
            thr_i = thr_q if prune else jnp.full((B,), np.inf, jnp.float32)
            eps_i, d_tile = eps0, 64
        # host-synced union count -> pow2-bucketed compacted shape
        union = np.asarray(jnp.any(alive, axis=0))
        S = pow2_bucket(max(int(union.sum()), 1), PC)
        nz = np.flatnonzero(union)
        idx_np = np.full((S,), PC, np.int32)
        idx_np[: nz.size] = nz
        idx = jnp.asarray(idx_np)
        sc = mirror.scale if mirror.quantized else None
        off = mirror.offset if mirror.quantized else None
        dists, alive = _cascade_batch_stage(
            mirror.data, idx, alive, Qs, thr_i, sc, off, eps_i, d_tile,
            use_pallas, mirror.packed, mirror.dim,
        )
        if meter:
            surv_b = np.asarray(jnp.sum(alive, axis=1)).astype(np.float64)
            # realized traffic: the compacted union columns are gathered
            # once and shared by the whole batch — the batched path's
            # bytes win over B per-query mirror walks
            stage_bytes = float(S) * mirror.dim * mirror.bytes_per_value
            if stats is not None:
                computed += lanes_in * mirror.dim
            if _metrics.enabled():
                _metrics.counter(
                    "repro_cascade_stage_survivors", float(surv_b.sum()),
                    stage=str(si), stage_name=spec.cascade[si],
                )
                _metrics.counter(
                    "repro_cascade_stage_bytes", stage_bytes,
                    stage=str(si), stage_name=spec.cascade[si],
                )
                _metrics.counter(
                    "repro_device_bytes_total", stage_bytes,
                    executor="cascade-batch", component="scan",
                    dtype=mirror.dtype,
                )
            lanes_in = surv_b
    # exact per-query finish: rk widens to the survivor count so the
    # re-rank covers every lane the keep tests spared (see cascade-scan)
    n_alive_b = np.asarray(jnp.sum(alive, axis=1))
    out_i, out_d = [], []
    for b in range(B):
        n_alive = int(n_alive_b[b])
        rk_eff = rk
        if n_alive > rk_eff:
            rk_eff = min(1 << (n_alive - 1).bit_length(), PC)
        ids_scan = store.ids.at[p0s[b]].set(-1)
        res = _cascade_finish(
            store.data, ids_scan, qts[b], dists[b], alive[b], rk_eff,
            spec.k, starts[b],
        )
        computed[b] += float(rk_eff) * D
        if _metrics.enabled():
            _metrics.counter(
                "repro_device_bytes_total", float(D * C * 4),
                executor="cascade-batch", component="start", dtype="f32",
            )
            _metrics.counter(
                "repro_device_bytes_total", float(rk_eff * D * 4),
                executor="cascade-batch", component="rerank", dtype="f32",
            )
        out_i.append(np.asarray(res.ids))
        out_d.append(np.asarray(res.dists))
    if stats is not None:
        total = float(counts.sum()) * D
        stats.values_total += total * B
        stats.values_computed += float(computed.sum())
        stats.values_avoided += max(total * B - float(computed.sum()), 0.0)
        stats.partitions_visited += P * B
    with _trace.span("rerank", fused="in-kernel", rk=rk):
        pass
    return np.stack(out_i), np.stack(out_d)


def _warm_cascade_menu(spec, store, pruner, B: int, use_pallas: bool) -> None:
    """Pre-compile the cascade executors' data-dependent shape menus for
    batch shape ``B``: every pow2 survivor-compaction width ``S`` the
    batched stage gather can request, and every pow2-widened re-rank
    ``rk_eff`` the finish can request.  One real warm batch only seeds the
    shapes its own survivor counts happen to hit; a serving steady state
    must mint no executables for ANY survivor profile, so the whole menu
    compiles up front (it is log2(P*C)-bounded per stage)."""
    scan_stages = [parse_cascade_stage(s) for s in spec.cascade][:-1]
    mirrors = [
        projection_mirror(store, rank, dt) if kind == "proj"
        else device_mirror(store, dt)
        for kind, dt, rank in scan_stages
    ]
    P, C, D = store.num_partitions, store.capacity, store.dim
    PC = P * C
    prune = pruner.name == "adsampling" and pruner.aux is not None
    eps0 = float(pruner.aux["eps0"]) if prune else 2.1
    menu = []
    s = 1
    while s < PC:
        menu.append(s)
        s *= 2
    menu.append(PC)
    qt0 = pruner.transform_query(jnp.zeros((D,), jnp.float32))
    start = topk_from_batch(
        pdx_distance(store.data[0], qt0, "l2"), store.ids[0], spec.k
    )
    if B > 1:  # the B=1 fallback never compacts batched stages
        alive0 = jnp.zeros((B, PC), jnp.bool_)
        thr0 = jnp.zeros((B,), jnp.float32)
        for (kind, dt, rank), mirror in zip(scan_stages, mirrors):
            Qs = jnp.zeros((B, rank if kind == "proj" else D), jnp.float32)
            eps_i = 0.0 if kind == "proj" else eps0
            d_tile = rank if kind == "proj" else 64
            sc = mirror.scale if mirror.quantized else None
            off = mirror.offset if mirror.quantized else None
            for S in menu:
                idx = jnp.full((S,), PC, jnp.int32)
                _cascade_batch_stage(
                    mirror.data, idx, alive0, Qs, thr0, sc, off, eps_i,
                    d_tile, use_pallas, mirror.packed, mirror.dim,
                )
    rk = min(spec.rerank_mult * spec.k, PC)
    rks = {rk}
    p = 1
    while p < PC:
        if p > rk:
            rks.add(p)
        p *= 2
    if PC > rk:
        rks.add(PC)  # the widened cut caps at PC (PC need not be pow2)
    if B > 1:
        dd, aa = jnp.zeros((PC,), jnp.float32), jnp.zeros((PC,), jnp.bool_)
    else:
        dd, aa = jnp.zeros((P, C), jnp.float32), jnp.zeros((P, C), jnp.bool_)
    for r in sorted(rks):
        _cascade_finish(store.data, store.ids, qt0, dd, aa, r, spec.k, start)


def _get_placement(store, n_shards: int, kind: str, *, ivf=None, axis="data"):
    """The store's tile->shard ``Placement``, cached per ``(tiles_version,
    n_shards, kind)`` — arranging/padding copies the tiles, which must cost
    once per sealed-tile mutation, not once per search.  A dict (not a
    single slot) so one store serving two mesh sizes, or both block and
    bucket layouts, never thrashes; stale-version entries are evicted so
    churn doesn't pin dead device arrays."""
    from ..dist.placement import Placement  # no core<->dist cycle

    version = getattr(store, "tiles_version", 0)
    key = (version, n_shards, kind)
    cache = getattr(store, "_placement_cache", None)
    if cache is None:
        cache = {}
        store._placement_cache = cache
    pl = cache.get(key)
    _metrics.counter(
        "repro_cache_events_total", cache="placement",
        event="hit" if pl is not None else "miss",
    )
    if pl is None:
        if kind == "block":
            pl = Placement.block(store.data, store.ids, n_shards, axis=axis)
        elif kind == "bucket":
            pb = getattr(store, "_part_bucket", None)
            if pb is None:  # frozen store: derive from the (synced) index
                pb = np.repeat(np.arange(ivf.nlist), ivf.part_counts)
            if len(pb) < store.num_partitions:  # all-pad placeholder tiles
                pb = np.concatenate(
                    [pb, np.full(store.num_partitions - len(pb), -1, np.int64)]
                )
            pl = Placement.bucket(
                store.data, store.ids, pb, ivf.nlist, n_shards, axis=axis
            )
        else:
            raise ValueError(f"no cached placement kind {kind!r}")
        for stale in [kk for kk in cache if kk[0] != version]:
            del cache[stale]
        cache[key] = pl
    return pl


@register_executor("block-sharded")
def _exec_block_sharded(store, pruner, Q, spec, *, ivf, mesh, stats):
    from ..dist.pdx_sharded import search_block_sharded

    pl = _get_placement(store, mesh.shape["data"], "block")
    out_i, out_d = [], []
    for q in Q:
        res = search_block_sharded(
            mesh, q=q, k=spec.k, metric=spec.metric,
            pruner=pruner, schedule=spec.schedule, delta_d=spec.delta_d,
            placement=pl, stats=stats,
        )
        out_i.append(np.asarray(res.ids))
        out_d.append(np.asarray(res.dists))
    return np.stack(out_i), np.stack(out_d)


@register_executor("dim-sharded")
def _exec_dim_sharded(store, pruner, Q, spec, *, ivf, mesh, stats):
    from ..dist.pdx_sharded import search_dim_sharded
    from ..dist.placement import Placement

    pl = Placement.replicated(store.data, store.ids, mesh.shape["model"])
    out_i, out_d = [], []
    for q in Q:
        qt = pruner.transform_query(q)
        res = search_dim_sharded(
            mesh, q=qt, k=spec.k, metric=spec.metric, placement=pl,
        )
        out_i.append(np.asarray(res.ids))
        out_d.append(np.asarray(res.dists))
    _exact_scan_stats(stats, store, len(Q))
    return np.stack(out_i), np.stack(out_d)


@register_executor("batch-block-sharded")
def _exec_batch_block_sharded(store, pruner, Q, spec, *, ivf, mesh, stats):
    from ..dist.pdx_sharded import search_batch_block_sharded

    pl = _get_placement(store, mesh.shape["data"], "block")
    Qt = _transform_batch(pruner, Q)
    dt = spec.scan_dtype
    mirror = device_mirror(store, dt) if dt != "f32" else None
    res = search_batch_block_sharded(
        mesh, Q=Qt, k=spec.k, metric=spec.metric, placement=pl,
        mirror=mirror, rerank_mult=spec.rerank_mult,
    )
    B = Q.shape[0]
    _exact_scan_stats(stats, store, B)
    if _metrics.enabled():
        from ..obs import meters as _meters

        n_sh = mesh.shape["data"]
        _meters.count_issued("batch-block-sharded", all_gather=1)
        P, D, C = store.data.shape
        bpv = mirror.bytes_per_value if mirror is not None else 4
        dtype = mirror.dtype if mirror is not None else "f32"
        wire = _meters.broadcast_batch_bytes(
            n_shards=n_sh, B=B, D=store.dim, k=spec.k
        )
        wire["scan"] = float(P * D * C * bpv)
        _meters.record_device_bytes("batch-block-sharded", dtype, wire)
    return np.asarray(res.ids), np.asarray(res.dists)


def _prepare_routed_host(store, pruner, Q, spec, *, ivf, mesh):
    """Host half of the routed executor: placement lookup, batch transform,
    bucket ranking, exchange planning, send-buffer packing.  No collective
    fires here — that's ``_run_routed_device``'s job."""
    if ivf is None:
        raise ValueError("routed_bucket executor needs an IVF index")
    if mesh is None or "data" not in getattr(mesh, "axis_names", ()):
        raise ValueError(
            "routed_bucket executor needs a mesh with a 'data' axis, got "
            f"{mesh!r}"
        )
    from ..dist.routing import prepare_routed

    pl = _get_placement(store, mesh.shape["data"], "bucket", ivf=ivf)
    Qt = _transform_batch(pruner, Q)
    sel = ivf.route_batch(Qt, spec.nprobe, spec.metric, spec.route_dtype)
    dt = spec.scan_dtype
    mirror = device_mirror(store, dt) if dt != "f32" else None
    launch = prepare_routed(
        mesh, pl, Qt, sel, spec.k, metric=spec.metric,
        mirror=mirror, rerank_mult=spec.rerank_mult,
    )
    return launch, sel


def _run_routed_device(launch, sel, store, spec, *, ivf, stats):
    """Device half: fire the prepared exchange + scan + merge collectives,
    then account the selected-bucket work."""
    from ..dist.routing import launch_routed

    res = launch_routed(launch)
    if stats is not None:
        # exact over each query's selected buckets: every live value in a
        # probed bucket is computed, everything outside is avoided by
        # routing (not by a pruning predicate — values_total counts only
        # visited partitions, matching the adaptive+IVF convention)
        counts = np.asarray(store.counts)
        po = np.asarray(ivf.part_offsets)
        pc = np.asarray(ivf.part_counts)
        bucket_rows = np.array(
            [counts[po[b]: po[b] + pc[b]].sum() for b in range(ivf.nlist)],
            dtype=np.float64,
        )
        sel_np = np.asarray(sel)
        valid = sel_np >= 0
        safe = np.where(valid, sel_np, 0)
        work = float(np.where(valid, bucket_rows[safe], 0.0).sum()) * store.dim
        stats.values_total += work
        stats.values_computed += work
        stats.partitions_visited += int(np.where(valid, pc[safe], 0).sum())
    return np.asarray(res.ids), np.asarray(res.dists)


@register_executor("routed_bucket")
def _exec_routed_bucket(store, pruner, Q, spec, *, ivf, mesh, stats):
    """Bucket-routed distributed search: queries travel to the shards that
    own their top-nprobe buckets (one all-to-all + one packed all-gather
    per batch — see ``repro.dist.routing``).  Exact over each query's
    selected buckets; with nprobe >= nlist it equals the exact full scan.

    Split into ``_prepare_routed_host`` (placement, routing plan, buffer
    packing) and ``_run_routed_device`` (collectives) so a serving loop can
    overlap batch N+1's host planning with batch N's device work — the
    blocking path here is simply the two halves back to back."""
    launch, sel = _prepare_routed_host(
        store, pruner, Q, spec, ivf=ivf, mesh=mesh
    )
    return _run_routed_device(launch, sel, store, spec, ivf=ivf, stats=stats)


# ------------------------------------------------- tiered executors
# Beyond-HBM serving: the host-RAM f32 masters stay authoritative, device
# HBM holds only a fixed slot-pool (``core.layout.BucketCache``) of the
# quantized tile extents of recently-routed IVF buckets.  A batch flows:
# route (two-level centroid tree when attached) -> ensure() admits the
# routed buckets (LRU-evicting cold ones) -> masked pool scan at
# ``spec.scan_dtype`` width -> exact re-rank against the host masters.
# ``prepare_execute`` puts routing + ensure() in the host half, so the
# serving loop's depth-1 handoff overlaps batch N+1's uploads (the
# prefetch) with batch N's device scan.

def _get_bucket_cache(store, spec, *, ivf, n_regions=1, bucket_region=None):
    """The store's ``BucketCache`` for this spec's (capacity, dtype,
    regions), cached on the store — pool allocation + quant-param passes
    must cost once per configuration, not once per batch.  Generation
    invalidation is the cache's own job (``tiles_version``)."""
    key = (spec.hbm_slots, spec.scan_dtype, int(n_regions))
    caches = getattr(store, "_tiered_cache", None)
    if caches is None:
        caches = {}
        store._tiered_cache = caches
    bc = caches.get(key)
    if bc is None:
        po = pc = None
        if getattr(store, "num_buckets", None) is None:
            po = np.asarray(ivf.part_offsets)
            pc = np.asarray(ivf.part_counts)
        bc = BucketCache(
            store, capacity_slots=spec.hbm_slots, dtype=spec.scan_dtype,
            n_regions=n_regions, bucket_region=bucket_region,
            part_offsets=po, part_counts=pc,
        )
        caches[key] = bc
    elif bucket_region is not None:
        bc._bucket_region = np.asarray(bucket_region, np.int64)
    return bc


def _tiered_scan_body(pool, pos, allowed, Qt, sc, off, rk, metric,
                      use_pallas, packed, dim):
    """Masked pool scan: every cached tile, each query restricted to the
    slots of its routed buckets — the tiered twin of ``_fused_batch_scan``
    (trace-level helper: runs standalone under jit and inside the
    routed-tiered shard_map body)."""
    from ..kernels.ops import batched_distance_quant_op
    from ..kernels.ref import dequantize_ref

    def body(state, inp):
        tile, tpos, allow_s = inp      # (D', C), (C,), (B,)
        if metric == "l1":
            t32 = dequantize_ref(tile, sc, off, packed=packed, dim=dim)
            dmat = jax.vmap(lambda q: pdx_distance(t32, q, "l1"))(Qt)
        else:
            dmat = batched_distance_quant_op(
                tile, Qt, sc, off, metric, use_pallas,
                packed=packed, dim=dim,
            )
        dmat = jnp.where(allow_s[:, None], dmat, jnp.inf)
        return jax.vmap(topk_merge, (0, 0, None))(state, dmat, tpos), None

    init = jax.vmap(lambda _: topk_init(rk))(jnp.arange(Qt.shape[0]))
    state, _ = jax.lax.scan(body, init, (pool, pos, allowed.T))
    return state


@functools.partial(
    jax.jit, static_argnames=("rk", "metric", "use_pallas", "quantized",
                              "packed", "dim")
)
def _tiered_pool_scan(
    pool, slot_ids, slot_bucket, sel, Qt, scale, offset, rk, metric,
    use_pallas, quantized, packed: bool = False, dim: int | None = None,
) -> TopK:
    """Single-host tiered scan -> per-query top-``rk`` flat POOL positions
    (s * C + c; dead/free lanes carry -1).  Positions resolve to global
    ids host-side through ``BucketCache.slot_ids_host`` — the exact
    re-rank never touches device copies of the full store."""
    S, _, C = pool.shape
    sc = scale if quantized else None
    off = offset if quantized else None
    # -1 marks BOTH unrouted sel pads (tree routing) and free pool slots;
    # remap sel pads to -2 so they can never select a free slot's tiles
    sel_safe = jnp.where(sel >= 0, sel, -2)
    allowed = (
        sel_safe[:, :, None] == slot_bucket[None, None, :]
    ).any(axis=1)                                             # (B, S)
    pos = jnp.arange(S * C, dtype=jnp.int32).reshape(S, C)
    pos = jnp.where(slot_ids >= 0, pos, -1)
    return _tiered_scan_body(
        pool, pos, allowed, Qt, sc, off, rk, metric, use_pallas, packed, dim
    )


def _host_master_rows(store) -> tuple[np.ndarray, np.ndarray]:
    """Sorted-by-id flat view of the live host-RAM f32 master rows, cached
    per ``tiles_version`` — the authoritative tier the tiered executors
    re-rank against (write-head rows merge separately and sealed tiles only
    change with tiles_version, so the sort amortizes over serving)."""
    ver = getattr(store, "tiles_version", 0)
    cached = getattr(store, "_host_rows_cache", None)
    if cached is not None and cached[0] == ver:
        return cached[1], cached[2]
    data = getattr(store, "_data", None)
    if data is not None:
        ids = store._ids
    else:
        data = np.asarray(store.data)
        ids = np.asarray(store.ids)
    flat_ids = np.asarray(ids).reshape(-1)
    live = flat_ids >= 0
    rows = np.ascontiguousarray(
        np.transpose(np.asarray(data, np.float32), (0, 2, 1))
    ).reshape(-1, data.shape[1])[live]
    flat_ids = flat_ids[live]
    order = np.argsort(flat_ids, kind="stable")
    out = (ver, flat_ids[order], rows[order])
    store._host_rows_cache = out
    return out[1], out[2]


def _tiered_rerank(
    store, cache: BucketCache, cand: TopK, Qt_np: np.ndarray, k: int,
    metric: str,
) -> tuple[np.ndarray, np.ndarray]:
    """Exact re-rank of pool-scan candidates against the HOST masters:
    positions -> cached global ids -> master rows (binary search on the
    sorted-id view) -> exact f32 metric -> top-k.  This replaces
    ``topk.rerank_positions`` for the tiered path, where gathering from a
    device-resident master copy would defeat the whole beyond-HBM point."""
    slot_ids = cache.slot_ids_host().reshape(-1)
    sorted_ids, rows = _host_master_rows(store)
    pos = np.asarray(cand.ids)
    B = pos.shape[0]
    out_i = np.full((B, k), -1, np.int64)
    out_d = np.full((B, k), np.inf, np.float32)
    for b in range(B):
        p = pos[b]
        gids = np.where(p >= 0, slot_ids[np.maximum(p, 0)], -1)
        gids = gids[gids >= 0]
        if gids.size == 0:
            continue
        loc = np.searchsorted(sorted_ids, gids)  # cached ids are all live
        x = rows[loc]
        q = Qt_np[b]
        if metric == "l2":
            d = ((x - q) ** 2).sum(axis=1)
        elif metric == "l1":
            d = np.abs(x - q).sum(axis=1)
        else:
            d = -(x @ q)
        order = np.argsort(d, kind="stable")[: k]
        out_i[b, : len(order)] = gids[order]
        out_d[b, : len(order)] = d[order].astype(np.float32)
    return out_i, out_d


def _tiered_chunks(
    sel: np.ndarray, cnts: np.ndarray, region_of, region_slots: int,
) -> list[list[int]]:
    """Greedy query chunking so each chunk's union bucket demand fits the
    pool (per region): batches whose routed set overflows the cache run as
    several ensure+scan rounds instead of failing.  A chunk is cut when
    admitting the next query's buckets would overflow any region."""
    B = sel.shape[0]
    chunks: list[list[int]] = []
    cur: list[int] = []
    seen: set[int] = set()
    demand: dict[int, int] = {}
    for b in range(B):
        row = [int(x) for x in sel[b]
               if x >= 0 and int(cnts[int(x)]) > 0]
        new = [x for x in dict.fromkeys(row) if x not in seen]
        add: dict[int, int] = {}
        for x in new:
            r = region_of(x)
            add[r] = add.get(r, 0) + int(cnts[x])
        fits = all(
            demand.get(r, 0) + a <= region_slots for r, a in add.items()
        )
        if cur and not fits:
            chunks.append(cur)
            cur, seen, demand = [], set(), {}
            new = list(dict.fromkeys(row))
            add = {}
            for x in new:
                r = region_of(x)
                add[r] = add.get(r, 0) + int(cnts[x])
        cur.append(b)
        seen.update(new)
        for r, a in add.items():
            demand[r] = demand.get(r, 0) + a
    if cur:
        chunks.append(cur)
    return chunks


def _chunk_passes(
    chunk_sel: np.ndarray, cnts: np.ndarray, region_of, region_slots: int,
) -> list[tuple[list[int], dict | None]]:
    """Pass schedule for one chunk's routed bucket union: a list of
    ``(bucket_list, parts)`` upload requests, each fitting every cache
    region.  The common case — demand fits — is one full pass.  A bucket
    whose extent alone exceeds a region is cut into region-sized
    sub-extents (``parts[b] = (part_i, n_parts)``, ceil-divided), and the
    items pack greedily into sequential passes; the run loop scans each
    pass and merges top-k, so a single query whose routed demand exceeds
    the slot pool succeeds instead of raising."""
    uniq: list[int] = []
    for row in chunk_sel:
        for x in row:
            x = int(x)
            if x >= 0 and x < len(cnts) and int(cnts[x]) > 0:
                uniq.append(x)
    uniq = list(dict.fromkeys(uniq))
    demand: dict[int, int] = {}
    for b in uniq:
        r = region_of(b)
        demand[r] = demand.get(r, 0) + int(cnts[b])
    if all(d <= region_slots for d in demand.values()):
        return [(uniq, None)]
    items: list[tuple[int, tuple | None, int]] = []
    for b in uniq:
        c = int(cnts[b])
        if c > region_slots:
            n_parts = -(-c // region_slots)
            per = -(-c // n_parts)
            for pi in range(n_parts):
                items.append((b, (pi, n_parts), min(per, c - pi * per)))
        else:
            items.append((b, None, c))
    passes: list[tuple[list[int], dict | None]] = []
    cur: list[int] = []
    parts: dict[int, tuple] = {}
    used: dict[int, int] = {}
    for b, part, size in items:
        r = region_of(b)
        if cur and used.get(r, 0) + size > region_slots:
            passes.append((cur, parts or None))
            cur, parts, used = [], {}, {}
        cur.append(b)
        if part is not None:
            parts[b] = part
        used[r] = used.get(r, 0) + size
    if cur:
        passes.append((cur, parts or None))
    return passes


def _merge_topk_rows(
    i1: np.ndarray, d1: np.ndarray, i2: np.ndarray, d2: np.ndarray, k: int,
) -> tuple[np.ndarray, np.ndarray]:
    """Row-wise top-k merge of two (B, k) id/dist result blocks with id
    dedup — later passes of a split chunk rescan still-resident buckets
    (and leftover sub-extents), so the same vector can surface twice; the
    exact re-rank makes duplicate distances identical, keep one."""
    B = i1.shape[0]
    out_i = np.full((B, k), -1, np.int64)
    out_d = np.full((B, k), np.inf, np.float32)
    for b in range(B):
        ids = np.concatenate([i1[b], i2[b]])
        ds = np.concatenate([d1[b], d2[b]]).astype(np.float32)
        live = ids >= 0
        ids, ds = ids[live], ds[live]
        if ids.size == 0:
            continue
        order = np.lexsort((ds, ids))
        ids, ds = ids[order], ds[order]
        keep = np.ones(ids.size, bool)
        keep[1:] = ids[1:] != ids[:-1]
        ids, ds = ids[keep], ds[keep]
        order = np.argsort(ds, kind="stable")[:k]
        out_i[b, : order.size] = ids[order]
        out_d[b, : order.size] = ds[order]
    return out_i, out_d


@dataclasses.dataclass
class _TieredLaunch:
    """Host-side product of ``_prepare_tiered_host``: the routed set, the
    chunk schedule with each chunk's pass schedule, and the FIRST pass's
    in-flight upload ticket — ``issue``-ing it at prepare time is the
    prefetch (the H2D copies overlap the previous batch's device scan
    through the serving handoff, and ``run`` only pays the residual
    ``wait``).  Later passes issue inside ``run``, one ahead of the scan;
    functional pool updates keep every captured snapshot consistent."""

    cache: BucketCache
    Qt: jax.Array
    Qt_np: np.ndarray
    sel: np.ndarray
    chunks: list
    passes: list
    ticket: object
    rk: int
    use_pallas: bool


def _tiered_rk(spec: SearchSpec, cache: BucketCache, C: int) -> int:
    if spec.scan_dtype == "f32":
        return spec.k
    return min(spec.rerank_mult * spec.k, cache.capacity_slots * C)


def _prepare_tiered_host(store, pruner, Q, spec, *, ivf) -> _TieredLaunch:
    """Host half of the tiered executor: batch transform, bucket routing,
    chunk planning, and the first chunk's ``ensure`` (the prefetch)."""
    if ivf is None:
        raise ValueError(
            "tiered-scan executor needs an IVF index (spec.hbm_slots caches "
            "at bucket granularity, which only routing defines)"
        )
    cache = _get_bucket_cache(store, spec, ivf=ivf)
    Qt = _transform_batch(pruner, jnp.asarray(Q, jnp.float32))
    with _trace.span("route", nprobe=spec.nprobe, tiered=True):
        sel = np.asarray(
            ivf.route_batch(Qt, spec.nprobe, spec.metric, spec.route_dtype)
        )
    _, cnts = cache._bucket_extent()
    chunks = _tiered_chunks(sel, cnts, cache._region_of, cache.region_slots)
    passes = [
        _chunk_passes(sel[chunk], cnts, cache._region_of, cache.region_slots)
        for chunk in chunks
    ]
    blist, parts = passes[0][0]
    with _trace.span("prefetch", buckets=len(blist)):
        ticket = cache.issue(np.asarray(blist, np.int64), parts=parts)
    C = store.capacity
    return _TieredLaunch(
        cache=cache, Qt=Qt, Qt_np=np.asarray(Qt), sel=sel, chunks=chunks,
        passes=passes, ticket=ticket,
        rk=_tiered_rk(spec, cache, C), use_pallas=_resolve_pallas(spec),
    )


def _tiered_stats(stats, store, cache, sel, ivf) -> None:
    """Selected-bucket work accounting, matching the routed convention:
    every live value in a probed bucket is computed, everything outside is
    avoided by routing."""
    if stats is None:
        return
    counts = np.asarray(store.counts)
    offs, cnts = cache._bucket_extent()
    nb = len(cnts)
    bucket_rows = np.array(
        [counts[offs[b]: offs[b] + cnts[b]].sum() for b in range(nb)],
        dtype=np.float64,
    )
    valid = sel >= 0
    safe = np.where(valid, sel, 0)
    work = float(np.where(valid, bucket_rows[safe], 0.0).sum()) * store.dim
    stats.values_total += work
    stats.values_computed += work
    stats.partitions_visited += int(np.where(valid, cnts[safe], 0).sum())


def _tiered_steps(launch: _TieredLaunch) -> list[tuple[int, int]]:
    """Flattened (chunk, pass) schedule of a tiered launch."""
    return [
        (ci, pi)
        for ci in range(len(launch.chunks))
        for pi in range(len(launch.passes[ci]))
    ]


def _tiered_step_ready(cache, launch, ticket, ci, pi):
    """Settle the step's prefetch ticket and hand back a consistent scan
    snapshot.  The ticket normally covers exactly this pass; when a
    concurrent batch's ``issue`` stole slots in between (the serving loop
    prepares N+1 while N runs), re-admit synchronously — correctness never
    rides on the overlap."""
    cache.wait(ticket)
    blist, parts = launch.passes[ci][pi]
    if not cache.resident_ok(np.asarray(blist, np.int64), parts=parts):
        cache.ensure(np.asarray(blist, np.int64), parts=parts)
    return cache.snapshot()


def _tiered_step_issue_next(cache, launch, steps, si):
    """Start the NEXT step's uploads (host quantize + async H2D) while the
    step just dispatched is still scanning on device."""
    if si + 1 >= len(steps):
        return None
    nci, npi = steps[si + 1]
    blist, parts = launch.passes[nci][npi]
    return cache.issue(np.asarray(blist, np.int64), parts=parts)


def _run_tiered_device(launch: _TieredLaunch, store, spec, *, ivf, stats):
    """Device half: per (chunk, pass) step, settle the step's prefetch
    ticket -> masked pool scan -> issue the NEXT step's uploads under the
    scan -> exact host re-rank; multi-pass chunks (routed demand beyond
    the slot pool) merge their per-pass top-k, chunk results concatenate
    back into batch order."""
    cache, sel = launch.cache, launch.sel
    B = sel.shape[0]
    out_i = np.full((B, spec.k), -1, np.int64)
    out_d = np.full((B, spec.k), np.inf, np.float32)
    C = store.capacity
    steps = _tiered_steps(launch)
    ticket = launch.ticket
    for si, (ci, pi) in enumerate(steps):
        chunk = launch.chunks[ci]
        arrays, slot_ids = _tiered_step_ready(cache, launch, ticket, ci, pi)
        pool, ids_dev, slot_bucket, scale, offset = arrays
        sel_dev = jnp.asarray(sel[chunk], jnp.int32)
        cand = _tiered_pool_scan(
            pool, ids_dev, slot_bucket, sel_dev, launch.Qt[jnp.asarray(chunk)],
            scale, offset, launch.rk, spec.metric, launch.use_pallas,
            cache.quantized, packed=cache.packed, dim=cache.dim,
        )
        # the scan is in flight: overlap the next step's staging + copy
        ticket = _tiered_step_issue_next(cache, launch, steps, si)
        ids_c, dists_c = _tiered_rerank(
            store, _TieredSnapshot(slot_ids), cand, launch.Qt_np[chunk],
            spec.k, spec.metric,
        )
        if pi == 0:
            out_i[chunk] = ids_c
            out_d[chunk] = dists_c
        else:
            out_i[chunk], out_d[chunk] = _merge_topk_rows(
                out_i[chunk], out_d[chunk], ids_c, dists_c, spec.k
            )
        if _metrics.enabled():
            S = cache.capacity_slots
            _metrics.counter(
                "repro_device_bytes_total",
                float(S) * cache.dim * C * cache.bytes_per_value,
                executor="tiered-scan", component="scan", dtype=cache.dtype,
            )
    cache.wait(ticket)
    _tiered_stats(stats, store, cache, sel, ivf)
    return out_i, out_d


class _TieredSnapshot:
    """Adapter handing ``_tiered_rerank`` a frozen ``slot_ids_host`` copy
    (a later chunk's ensure() must not remap an earlier chunk's candidate
    positions mid-resolution)."""

    def __init__(self, slot_ids: np.ndarray):
        self._slot_ids = np.array(slot_ids, copy=True)

    def slot_ids_host(self) -> np.ndarray:
        return self._slot_ids


@register_executor("tiered-scan")
def _exec_tiered_scan(store, pruner, Q, spec, *, ivf, mesh, stats):
    """Tiered beyond-HBM search: route -> ensure (bucket-granular LRU HBM
    cache) -> masked quantized pool scan -> exact host-RAM re-rank.  The
    blocking composition of ``_prepare_tiered_host`` + ``_run_tiered_device``
    (the serving loop overlaps the two halves across batches)."""
    launch = _prepare_tiered_host(store, pruner, Q, spec, ivf=ivf)
    return _run_tiered_device(launch, store, spec, ivf=ivf, stats=stats)


# ------------------------------------------------- routed tiered (mesh)
_TIERED_SHARD_CACHE: dict = {}


def _tiered_shard_exec(mesh, axis: str, rk: int, metric: str,
                       quantized: bool, packed: bool, dim: int | None,
                       use_pallas: bool):
    """Cached jitted shard_map executor for the routed-tiered scan: the
    slot pool is region-split over the mesh 'data' axis (region r == shard
    r's slice), queries + routed sets replicate, each shard scans only its
    region's cached tiles (masked to each query's routed buckets), and the
    per-shard top-``rk`` GLOBAL pool positions cross the mesh in ONE packed
    all-gather — candidate resolution + the exact re-rank stay host-side
    against the RAM masters."""
    key = (mesh, axis, rk, metric, quantized, packed, dim, use_pallas)
    fn = _TIERED_SHARD_CACHE.get(key)
    if fn is not None:
        _metrics.counter(
            "repro_cache_events_total", cache="tiered-shard", event="hit"
        )
        return fn
    _metrics.counter(
        "repro_cache_events_total", cache="tiered-shard", event="miss"
    )
    n_sh = mesh.shape[axis]

    def local(pool_sh, pos_sh, sb_sh, sel_rep, Qt_rep, scale, offset):
        sc = scale if quantized else None
        off = offset if quantized else None
        sel_safe = jnp.where(sel_rep >= 0, sel_rep, -2)
        allowed = (
            sel_safe[:, :, None] == sb_sh[None, None, :]
        ).any(axis=1)                                      # (B, S_r)
        cand = _tiered_scan_body(
            pool_sh, pos_sh, allowed, Qt_rep, sc, off, rk, metric,
            use_pallas, packed, dim,
        )
        B = Qt_rep.shape[0]
        packed_buf = jnp.concatenate(
            [cand.dists,
             jax.lax.bitcast_convert_type(cand.ids, jnp.float32)],
            axis=1,
        )                                                  # (B, 2rk)
        allp = jax.lax.all_gather(packed_buf, axis, axis=1, tiled=True)
        allp = allp.reshape(B, n_sh, 2 * rk)
        all_d = allp[:, :, :rk].reshape(B, n_sh * rk)
        all_p = jax.lax.bitcast_convert_type(
            allp[:, :, rk:], jnp.int32
        ).reshape(B, n_sh * rk)
        merge = lambda dd, ii: topk_merge(topk_init(rk), dd, ii)  # noqa: E731
        return jax.vmap(merge)(all_d, all_p)

    def wrapper(pool, ids_dev, slot_bucket, sel, Qt, scale, offset):
        S, _, C = pool.shape
        pos = jnp.arange(S * C, dtype=jnp.int32).reshape(S, C)
        pos = jnp.where(ids_dev >= 0, pos, -1)
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P

        return shard_map(
            local,
            mesh=mesh,
            in_specs=(P(axis), P(axis), P(axis), P(), P(), P(), P()),
            out_specs=TopK(dists=P(), ids=P()),
            check_rep=False,
        )(pool, pos, slot_bucket, sel, Qt, scale, offset)

    fn = jax.jit(wrapper)
    _TIERED_SHARD_CACHE[key] = fn
    return fn


def _prepare_routed_tiered_host(store, pruner, Q, spec, *, ivf, mesh):
    """Host half of routed-tiered: region assignment (bucket -> owner shard,
    the same greedy LPT balance bucket placements use), routing, chunk
    planning, first-chunk prefetch."""
    if ivf is None:
        raise ValueError("routed_tiered executor needs an IVF index")
    if mesh is None or "data" not in getattr(mesh, "axis_names", ()):
        raise ValueError(
            "routed_tiered executor needs a mesh with a 'data' axis, got "
            f"{mesh!r}"
        )
    from ..dist.placement import assign_buckets

    n_sh = mesh.shape["data"]
    # derive the region split from the CURRENT bucket extents (deterministic
    # across hosts); the cache regenerates whole-pool on tiles_version bumps,
    # so a refreshed assignment can never mix with stale residency
    tmp = _get_bucket_cache(store, spec, ivf=ivf, n_regions=n_sh)
    _, cnts = tmp._bucket_extent()
    region = assign_buckets(cnts, n_sh)
    cache = _get_bucket_cache(
        store, spec, ivf=ivf, n_regions=n_sh, bucket_region=region
    )
    Qt = _transform_batch(pruner, jnp.asarray(Q, jnp.float32))
    with _trace.span("route", nprobe=spec.nprobe, tiered=True,
                     n_shards=n_sh):
        sel = np.asarray(
            ivf.route_batch(Qt, spec.nprobe, spec.metric, spec.route_dtype)
        )
    chunks = _tiered_chunks(sel, cnts, cache._region_of, cache.region_slots)
    passes = [
        _chunk_passes(sel[chunk], cnts, cache._region_of, cache.region_slots)
        for chunk in chunks
    ]
    blist, parts = passes[0][0]
    with _trace.span("prefetch", buckets=len(blist)):
        ticket = cache.issue(np.asarray(blist, np.int64), parts=parts)
    return _TieredLaunch(
        cache=cache, Qt=Qt, Qt_np=np.asarray(Qt), sel=sel, chunks=chunks,
        passes=passes, ticket=ticket,
        rk=_tiered_rk(spec, cache, store.capacity),
        use_pallas=_resolve_pallas(spec),
    )


def _run_routed_tiered_device(launch: _TieredLaunch, store, spec, *, ivf,
                              mesh, stats):
    cache, sel = launch.cache, launch.sel
    B = sel.shape[0]
    out_i = np.full((B, spec.k), -1, np.int64)
    out_d = np.full((B, spec.k), np.inf, np.float32)
    fn = _tiered_shard_exec(
        mesh, "data", launch.rk, spec.metric, cache.quantized,
        cache.packed, cache.dim, launch.use_pallas,
    )
    C = store.capacity
    steps = _tiered_steps(launch)
    ticket = launch.ticket
    for si, (ci, pi) in enumerate(steps):
        chunk = launch.chunks[ci]
        arrays, slot_ids = _tiered_step_ready(cache, launch, ticket, ci, pi)
        pool, ids_dev, slot_bucket, scale, offset = arrays
        sel_dev = jnp.asarray(sel[chunk], jnp.int32)
        cand = fn(
            pool, ids_dev, slot_bucket, sel_dev,
            launch.Qt[jnp.asarray(chunk)], scale, offset,
        )
        ticket = _tiered_step_issue_next(cache, launch, steps, si)
        ids_c, dists_c = _tiered_rerank(
            store, _TieredSnapshot(slot_ids), cand, launch.Qt_np[chunk],
            spec.k, spec.metric,
        )
        if pi == 0:
            out_i[chunk] = ids_c
            out_d[chunk] = dists_c
        else:
            out_i[chunk], out_d[chunk] = _merge_topk_rows(
                out_i[chunk], out_d[chunk], ids_c, dists_c, spec.k
            )
        if _metrics.enabled():
            from ..obs import meters as _meters

            _meters.count_issued("routed_tiered", all_gather=1)
            n_sh = mesh.shape["data"]
            _meters.record_device_bytes("routed_tiered", cache.dtype, {
                "scan": float(cache.capacity_slots) * cache.dim * C
                        * cache.bytes_per_value,
                "all_gather": float(n_sh * len(chunk) * 2 * launch.rk * 4),
            })
    cache.wait(ticket)
    _tiered_stats(stats, store, cache, sel, ivf)
    return out_i, out_d


@register_executor("routed_tiered")
def _exec_routed_tiered(store, pruner, Q, spec, *, ivf, mesh, stats):
    """Distributed tiered search: each mesh shard caches one region of the
    bucket pool (regions follow the same greedy bucket->shard balance as
    bucket placements), scans only its region's routed tiles, and the
    global candidate merge crosses the mesh in ONE packed all-gather per
    chunk; id resolution + exact f32 re-rank stay on the host masters."""
    launch = _prepare_routed_tiered_host(
        store, pruner, Q, spec, ivf=ivf, mesh=mesh
    )
    return _run_routed_tiered_device(
        launch, store, spec, ivf=ivf, mesh=mesh, stats=stats
    )
