"""Declarative search specification — *what* to search, not *how*.

The paper's point is that one layout (PDX) serves many search strategies:
exact scans, ADSampling/BSA/BOND dimension pruning, IVF routing, batched
MXU scans, and the sharded distributed paths.  A ``SearchSpec`` captures the
strategy-level knobs once; the planner (``repro.core.plan``) maps a
``(spec, store, query shape, optional mesh)`` onto the right execution mode,
so callers never hand-pick ``search`` vs ``search_jit`` vs ``search_batch``
vs the ``repro.dist`` entry points again.

    spec = SearchSpec(k=10, nprobe=16)
    res = engine.search(q, spec)          # single query
    res = engine.search(Q, spec)          # (B, D) batch — planner batches
    res.ids, res.dists, res.plan          # plan records executor + reason

Specs are frozen (hashable, reusable across queries and engines) and
validated at construction.  The pruning *algorithm* (ADSampling's rotation,
BSA's PCA, BOND's means) is build-time engine state — it transforms the
stored vectors — so the spec carries its runtime configuration (boundary
schedule, selectivity threshold, grouping) and the planner records the
engine pruner's stable fingerprint in the plan trace.

Specs are also store-agnostic: the same spec searches a frozen ``PDXStore``
and a live ``MutablePDXStore`` under churn.  The mutable store's monotone
``version`` is not spec state — it rides in the ``ExecutionPlan`` trace
(``plan.store_version``) and in the jitted-executor cache keys, so a spec
reused across mutations always executes against the tiles it claims to.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from .distance import METRICS
from .layout import SCAN_DTYPES
from .pdxearch import SearchStats

__all__ = ["SearchSpec", "SearchResult", "parse_cascade_stage"]

SCHEDULES = ("adaptive", "fixed")
ROUTINGS = ("broadcast", "bucket")
KERNELS = ("auto", "pallas", "jnp")
# Full-dimension dtypes a cascade may run between the (optional) projection
# stage and the mandatory exact "f32" re-rank terminator.
CASCADE_MID_DTYPES = ("bf16", "int8", "int4")


def parse_cascade_stage(stage: str) -> tuple[str, str, int]:
    """One cascade stage string -> (kind, dtype, rank).

    Stage grammar:
      "projN"         — rank-N learned-projection scan, f32 mirror
      "projN:dtype"   — rank-N projection scan at a quantized mirror dtype
      "bf16"|"int8"|"int4" — full-dimension scan at that mirror dtype
      "f32"           — the exact full-precision re-rank (always last)

    Returns ``kind`` in ("proj", "scan", "exact"); ``rank`` is 0 except for
    projection stages.  Raises ValueError on anything else.
    """
    if stage == "f32":
        return ("exact", "f32", 0)
    if stage in CASCADE_MID_DTYPES:
        return ("scan", stage, 0)
    if stage.startswith("proj"):
        body = stage[4:]
        rank_s, _, dt = body.partition(":")
        dt = dt or "f32"
        if rank_s.isdigit() and int(rank_s) >= 1 and dt in SCAN_DTYPES:
            return ("proj", dt, int(rank_s))
    raise ValueError(
        f"bad cascade stage {stage!r}: expected 'projN[:dtype]', one of "
        f"{CASCADE_MID_DTYPES}, or the final 'f32'"
    )


@dataclasses.dataclass(frozen=True)
class SearchSpec:
    """Declarative description of one vector-similarity search.

    Result shaping
      k          — neighbours to return per query.
      metric     — "l2" | "l1" | "ip" (all minimized; ip is negated).

    Pruning configuration (PDXearch phases; see ``core.pdxearch``)
      schedule   — boundary schedule: "adaptive" (exponential steps, the
                   paper's fix for fixed-Δd tail latency) or "fixed".
      delta_d    — step size for the "fixed" schedule.
      sel_frac   — surviving fraction below which the PRUNE phase compacts
                   survivors (paper: 0.2).
      group      — partitions evaluated per pruning round (host path).

    IVF routing
      nprobe     — buckets probed when the engine has an IVF index.
      routing    — distributed query routing on a "data"-axis mesh:
                   "bucket" (default) routes each query only to the shards
                   owning its top-nprobe buckets (one all-to-all per batch,
                   bucket-owned placement); "broadcast" keeps the IVF
                   routing host-side (the pre-placement behavior).  Without
                   a mesh or an IVF index the knob is inert.

    Device-scan precision (the bandwidth lever; see ``core.layout``'s
    dtype-policy block)
      scan_dtype  — operand precision of the device scan: "f32" streams the
                    master tiles; "bf16"/"int8" stream the quantized device
                    mirror (2x/4x fewer bytes per dimension value) and the
                    executor re-ranks the top ``rerank_mult * k`` candidates
                    against the f32 masters, so *returned distances stay
                    exact*.  On a mesh the batched/routed sharded
                    executors scan their mirror slices the same way (the
                    per-query block-/dim-sharded paths scan f32 masters
                    and record that in the plan reason); queries and
                    candidate distances stay f32 on the wire (rounding
                    either breaks exact k-boundary ordering — see
                    ``repro.dist.routing``).
      kernel      — scan implementation: "pallas" forces the fused Pallas
                    executors (``repro.kernels``; interpret mode off-TPU),
                    "jnp" forces the XLA-fused jnp bodies, "auto" picks
                    pallas on a TPU backend and jnp elsewhere.
      rerank_mult — exact-re-rank candidate multiplier (top ``rerank_mult *
                    k`` approximate candidates are re-scored in f32 when
                    ``scan_dtype != "f32"``).
      cascade     — multi-resolution scan pipeline, e.g.
                    ``("proj32:int4", "int8", "f32")``: an optional skinny
                    learned-projection stage first (``"projN[:dtype]"`` —
                    rank-N PCA mirror, exact-safe lower-bound keep test),
                    then full-dimension scans at decreasing-width mirror
                    dtypes over the survivors of the previous stage, ending
                    in the mandatory exact ``"f32"`` re-rank.  Each stage
                    seeds its keep-mask from the previous stage's alive
                    bitmap, so later (wider) stages only touch survivors;
                    the Pallas path skips pruned partitions' HBM traffic
                    entirely (prefetch-skip).  None (default) = the
                    single-level ``scan_dtype`` behavior.  L2 only.
      route_dtype — precision of the IVF centroid routing scan ("f32"
                    default; "int8"/"int4" stream a quantized centroid
                    mirror so routing bytes shrink with the same dtype
                    policy as the data scan).  Near-tie bucket *order* may
                    differ from f32 routing at partial nprobe.
      hbm_slots   — tiered serving: cap the device-resident working set at
                    this many tile slots and manage them as a bucket-
                    granular LRU cache (``core.layout.BucketCache``) fed by
                    IVF routing, instead of mirroring the whole store in
                    HBM.  Requires an IVF index; ``scan_dtype`` picks the
                    cached tiles' precision and the exact f32 re-rank runs
                    against the host-RAM masters.  None (default) keeps the
                    fully-resident mirror behavior.

    Execution hints (planner inputs, never change *results* beyond the
    pruner's own approximation)
      executor          — force a registered executor by name (see
                          ``repro.core.plan.executor_names()``); None lets
                          the planner choose.
      prefer_static     — prefer the shape-static masked path over the
                          host-orchestrated adaptive one (for callers that
                          need the whole search inside one jit).
      batch_collectives — on a mesh, amortize the top-k merge collective
                          over the whole query batch (one all-gather per
                          batch) instead of issuing it per query.
    """

    k: int = 10
    metric: str = "l2"
    schedule: str = "adaptive"
    delta_d: int = 32
    sel_frac: float = 0.2
    group: int = 8
    nprobe: int = 8
    executor: Optional[str] = None
    prefer_static: bool = False
    batch_collectives: bool = True
    routing: str = "bucket"
    scan_dtype: str = "f32"
    kernel: str = "auto"
    rerank_mult: int = 4
    cascade: Optional[tuple] = None
    route_dtype: str = "f32"
    hbm_slots: Optional[int] = None

    def __post_init__(self):
        if self.k < 1:
            raise ValueError(f"k must be >= 1, got {self.k}")
        if self.metric not in METRICS:
            raise ValueError(f"metric must be one of {METRICS}, got {self.metric!r}")
        if self.schedule not in SCHEDULES:
            raise ValueError(
                f"schedule must be one of {SCHEDULES}, got {self.schedule!r}"
            )
        if self.delta_d < 1:
            raise ValueError(f"delta_d must be >= 1, got {self.delta_d}")
        if not (0.0 < self.sel_frac <= 1.0):
            raise ValueError(f"sel_frac must be in (0, 1], got {self.sel_frac}")
        if self.group < 1:
            raise ValueError(f"group must be >= 1, got {self.group}")
        if self.nprobe < 1:
            raise ValueError(f"nprobe must be >= 1, got {self.nprobe}")
        if self.routing not in ROUTINGS:
            raise ValueError(
                f"routing must be one of {ROUTINGS}, got {self.routing!r}"
            )
        if self.scan_dtype not in SCAN_DTYPES:
            raise ValueError(
                f"scan_dtype must be one of {SCAN_DTYPES}, "
                f"got {self.scan_dtype!r}"
            )
        if self.kernel not in KERNELS:
            raise ValueError(
                f"kernel must be one of {KERNELS}, got {self.kernel!r}"
            )
        if self.rerank_mult < 1:
            raise ValueError(
                f"rerank_mult must be >= 1, got {self.rerank_mult}"
            )
        if self.route_dtype not in SCAN_DTYPES:
            raise ValueError(
                f"route_dtype must be one of {SCAN_DTYPES}, "
                f"got {self.route_dtype!r}"
            )
        if self.hbm_slots is not None and self.hbm_slots < 1:
            raise ValueError(
                f"hbm_slots must be >= 1 when set, got {self.hbm_slots}"
            )
        if self.cascade is not None:
            stages = self.cascade
            if not (
                isinstance(stages, tuple)
                and len(stages) >= 2
                and all(isinstance(s, str) for s in stages)
            ):
                raise ValueError(
                    f"cascade must be a tuple of >= 2 stage strings, "
                    f"got {stages!r}"
                )
            if self.metric != "l2":
                raise ValueError(
                    "cascade scans are L2-only (the projection lower bound "
                    f"and the ADSampling test both assume it), got metric="
                    f"{self.metric!r}"
                )
            parsed = [parse_cascade_stage(s) for s in stages]  # may raise
            if parsed[-1][0] != "exact":
                raise ValueError(
                    f"cascade must end with the exact 'f32' re-rank, "
                    f"got {stages!r}"
                )
            for pos, (kind, _, _) in enumerate(parsed):
                if kind == "proj" and pos != 0:
                    raise ValueError(
                        f"a projection stage must come first, got {stages!r}"
                    )
                if kind == "exact" and pos != len(parsed) - 1:
                    raise ValueError(
                        f"'f32' is the terminal re-rank stage, got {stages!r}"
                    )
            if len(set(stages)) != len(stages):
                raise ValueError(f"duplicate cascade stages in {stages!r}")

    def replace(self, **changes) -> "SearchSpec":
        """A copy with ``changes`` applied (specs are immutable)."""
        return dataclasses.replace(self, **changes)


@dataclasses.dataclass
class SearchResult:
    """Search output plus its provenance.

    ``ids``/``dists`` are (k,) for a single query, (B, k) for a batch.
    ``plan`` is the ``repro.core.plan.ExecutionPlan`` the planner chose
    (executor name + reason + the store version searched), ``stats`` the
    work accounting when requested, and ``trace`` the per-query span record
    (``repro.obs.trace.QueryTrace``) when observability is enabled.

    Unpacks like the legacy ``(ids, dists)`` tuple::

        ids, dists = engine.search(q, spec)
    """

    ids: np.ndarray
    dists: np.ndarray
    spec: SearchSpec
    plan: "ExecutionPlan"  # noqa: F821 — repro.core.plan (no import cycle)
    stats: Optional[SearchStats] = None
    trace: Optional["QueryTrace"] = None  # noqa: F821 — repro.obs.trace

    def __iter__(self):
        yield self.ids
        yield self.dists

    def __getitem__(self, i):
        return (self.ids, self.dists)[i]

    def __len__(self) -> int:
        return 2
