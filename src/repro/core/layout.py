"""PDX (Partition Dimensions Across) layout — the paper's core data structure.

A PDX *partition* stores up to ``capacity`` vectors dimension-major as a
``(D, capacity)`` tile, so a dimension slice ``data[d0:d1, :]`` is one
contiguous stretch per dimension (the paper's Figure 1).  Partitions map to
IVF buckets (approximate search) or horizontal slabs (exact search).

On TPU the trailing (vector) axis maps onto the 128-wide lane dimension, which
is why capacities here default to lane multiples; the paper's CPU-optimal
64-vector micro-block becomes a kernel tiling detail (see repro.kernels).

Build-time code is NumPy (offline, like index construction in FAISS); the
resulting arrays are device arrays consumed by jitted search code.

Two store flavours share the tile format:

Both flavours keep **f32 masters** and expose reduced-precision **device
mirrors** (``device_mirror(store, "bf16"|"int8")``): the scan hot path is
bandwidth-bound (paper Section 7), so the planner streams 1-2 bytes per
dimension value and re-ranks the surviving candidates against the f32
masters for exact returned distances.  Mirrors cache per ``tiles_version``
exactly like the f32 upload — see the dtype-policy block below.

* ``PDXStore`` — frozen build artifact (a dataclass of device arrays).
* ``MutablePDXStore`` — the versioned, mutable serving store (the paper's
  closing pitch: PDX "can work on vector data as-is ... attractive for
  vector databases with frequent updates").  It keeps NumPy master copies
  of the tiles plus a horizontal *write-head* buffer that absorbs inserts
  (scanned exactly, unpruned, until flushed), per-partition free-slot
  bitmaps (slots whose ``ids == -1`` are reusable), tombstoning deletes
  (slot poisoned to ``PAD_VALUE`` so it can never enter a top-k), and a
  ``repack()`` step that drains tombstones and the write-head back into
  lane-aligned, bucket-contiguous tiles.  ``store.version`` increases
  monotonically with every mutation; executors key their jit caches on it.
"""
from __future__ import annotations

import collections
import concurrent.futures
import dataclasses
import functools
import os
import threading
import time
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from ..obs import metrics as _metrics

__all__ = [
    "PDXPartition",
    "PDXStore",
    "MutablePDXStore",
    "DeviceMirror",
    "ProjectionMirror",
    "BucketCache",
    "SCAN_DTYPES",
    "device_mirror",
    "projection_mirror",
    "unpack_int4",
    "build_flat_store",
    "build_bucketed_store",
    "pdx_to_nary",
]

# Sentinel padding value: a coordinate far from any real data so padded slots
# can never enter a top-k result (distances are monotone increasing in L2/L1).
PAD_VALUE = np.float32(3.0e18)

# ==========================================================================
# Dtype policy — quantized device mirrors.
#
# Masters stay f32 NumPy/device arrays (exactness lives there: the planner
# re-ranks candidates against them whenever the scan ran reduced-precision).
# The *device mirror* the scan executors actually stream is materialized at
# one of three precisions; the paper's Section 7 point is that the scan is
# bandwidth-bound, so bytes-per-dimension-value is the lever:
#
#   f32   4 B/value — the master tiles themselves (today's behavior).
#   bf16  2 B/value — plain downcast; same exponent range as f32, so the
#         PAD_VALUE sentinel keeps its monotone hugeness.
#   int8  1 B/value — per-dimension affine quantization
#         q = clip(round((x - offset_d) / scale_d), -127, 127) with
#         offset_d = dim_means[d] (the running moments the mutable store
#         already maintains, so a repack re-centers the codebook for free)
#         and scale_d sized to the *observed* max deviation of dimension d
#         over live slots — one masked pass at mirror build.  A k·sigma
#         range from dim_vars alone clips heavy tails (skewed datasets,
#         rows correlated with a pruner rotation) hard enough to corrupt
#         candidate selection, so the range is measured, not assumed; the
#         moments still provide the centering.  PAD columns quantize to
#         garbage by construction; every quantized consumer masks lanes
#         with ``ids < 0``.
#   int4  0.5 B/value — the same per-dimension affine, 15 levels
#         (clip to ±7), two values packed per byte along the dimension
#         axis: byte ``d`` of a packed tile holds dimension ``2d`` in its
#         low nibble and ``2d + 1`` in its high nibble, biased by +8 so
#         the payload is an unsigned nibble.  Consumers unpack in-register
#         (``kernels.pdx_scan``) or via ``unpack_int4``; ``data.shape[1]``
#         is ceil(D/2), so int4 consumers must take D from ``mirror.dim``.
#
# Mirrors are cached on the store keyed on ``tiles_version`` (like the f32
# upload): head-only inserts never re-quantize, a repack/flush invalidates.
# ==========================================================================
SCAN_DTYPES = ("f32", "bf16", "int8", "int4")
_BYTES_PER_VALUE = {"f32": 4, "bf16": 2, "int8": 1, "int4": 0.5}


@dataclasses.dataclass(frozen=True)
class DeviceMirror:
    """One device-resident copy of a store's sealed tiles at a scan dtype.

    ``data`` is (P, D, C) in the mirror dtype — (P, ceil(D/2), C) uint8 for
    the packed "int4" mirror, whose logical D is ``dim``; ``scale``/
    ``offset`` are the (D,) f32 dequantization vectors (ones/zeros for f32
    and bf16, so every consumer can apply ``x * scale + offset``
    unconditionally)."""

    dtype: str           # "f32" | "bf16" | "int8" | "int4"
    data: jax.Array      # (P, D, C) mirror-dtype tiles (packed for int4)
    scale: jax.Array     # (D,) f32
    offset: jax.Array    # (D,) f32
    tiles_version: int
    dim: int = 0         # logical D (== data.shape[1] except when packed)

    @property
    def bytes_per_value(self) -> float:
        return _BYTES_PER_VALUE[self.dtype]

    @property
    def packed(self) -> bool:
        return self.dtype == "int4"

    @property
    def quantized(self) -> bool:
        return self.dtype in ("int8", "int4")


@jax.jit
def _quantize_int8(data, ids, means):
    live = (ids >= 0)[:, None, :]  # (P, 1, C)
    dev = jnp.abs(data - means[None, :, None])
    absmax = jnp.max(jnp.where(live, dev, 0.0), axis=(0, 2))  # (D,)
    scale = jnp.maximum(absmax, 1e-6) / 127.0
    offset = means
    q = jnp.round((data - offset[None, :, None]) / scale[None, :, None])
    return jnp.clip(q, -127, 127).astype(jnp.int8), scale, offset


@jax.jit
def _quantize_int4(data, ids, means):
    """Same observed-range affine as int8 at 15 levels, packed 2-per-byte
    along D (low nibble = even dim, high nibble = odd dim, +8 bias).  Odd D
    pads one zero-level nibble; dequantizing it yields exactly ``offset``
    of a dimension no consumer reads (ops pad q/scale/offset to match)."""
    live = (ids >= 0)[:, None, :]
    dev = jnp.abs(data - means[None, :, None])
    absmax = jnp.max(jnp.where(live, dev, 0.0), axis=(0, 2))
    scale = jnp.maximum(absmax, 1e-6) / 7.0
    offset = means
    q = jnp.clip(
        jnp.round((data - offset[None, :, None]) / scale[None, :, None]),
        -7, 7,
    ).astype(jnp.int32)
    if q.shape[1] % 2:
        q = jnp.pad(q, ((0, 0), (0, 1), (0, 0)))  # zero level -> nibble 8
    qb = (q + 8).astype(jnp.uint8)
    packed = qb[:, 0::2, :] | (qb[:, 1::2, :] << 4)
    return packed, scale, offset


def unpack_int4(packed: jax.Array, dim_axis: int = 0,
                dim: Optional[int] = None) -> jax.Array:
    """Packed int4 tile -> int8 quantization levels in [-7, 7].

    ``dim_axis`` is the packed-dimension axis (0 for a (Dp, V) tile, 1 for
    (P, Dp, V) stacks); the result doubles that axis, sliced back to
    ``dim`` when given (odd logical D)."""
    p = packed.astype(jnp.int32)
    lo = (p & 0xF) - 8
    hi = (p >> 4) - 8
    full = jnp.stack([lo, hi], axis=dim_axis + 1)
    shape = list(packed.shape)
    shape[dim_axis] *= 2
    full = full.reshape(shape)
    if dim is not None and dim != shape[dim_axis]:
        full = jax.lax.slice_in_dim(full, 0, dim, axis=dim_axis)
    return full.astype(jnp.int8)


def device_mirror(store, dtype: str = "f32") -> DeviceMirror:
    """The store's device mirror at ``dtype``, cached per ``tiles_version``.

    Works on frozen and mutable stores alike (frozen stores are version 0
    forever and keep hitting one entry per dtype); stale-version entries are
    evicted so churn never pins dead quantized tiles on device."""
    if dtype not in SCAN_DTYPES:
        raise ValueError(f"scan dtype must be one of {SCAN_DTYPES}, got {dtype!r}")
    version = getattr(store, "tiles_version", 0)
    cache = getattr(store, "_mirror_cache", None)
    if cache is None:
        cache = {}
        try:
            store._mirror_cache = cache
        except AttributeError:  # exotic frozen store: build uncached
            pass
    key = (dtype, version)
    mirror = cache.get(key)
    _metrics.counter(
        "repro_cache_events_total", cache="mirror",
        event="hit" if mirror is not None else "miss",
    )
    if mirror is None:
        _metrics.counter("repro_mirror_builds_total", dtype=dtype)
        data = store.data  # triggers the mutable store's lazy f32 sync
        D = data.shape[1]
        if dtype == "f32":
            mdata = data
            scale = jnp.ones((D,), jnp.float32)
            offset = jnp.zeros((D,), jnp.float32)
        elif dtype == "bf16":
            mdata = data.astype(jnp.bfloat16)
            scale = jnp.ones((D,), jnp.float32)
            offset = jnp.zeros((D,), jnp.float32)
        elif dtype == "int8":
            means = jnp.asarray(store.dim_means, jnp.float32)
            mdata, scale, offset = _quantize_int8(data, store.ids, means)
        else:  # int4 (packed two-per-byte)
            means = jnp.asarray(store.dim_means, jnp.float32)
            mdata, scale, offset = _quantize_int4(data, store.ids, means)
        mirror = DeviceMirror(
            dtype=dtype, data=mdata, scale=scale, offset=offset,
            tiles_version=version, dim=D,
        )
        for stale in [kk for kk in cache if kk[1] != version]:
            del cache[stale]
        cache[key] = mirror
    return mirror


@dataclasses.dataclass(frozen=True)
class ProjectionMirror:
    """A skinny learned-projection copy of the sealed tiles (LeanVec-style).

    ``data`` is (P, rank, C) in the mirror dtype — packed (P, ceil(rank/2),
    C) uint8 for int4 — holding the tiles projected onto the top-``rank``
    PCA components of the collection.  Because the components are
    orthonormal, the projected squared L2 distance **lower-bounds** the full
    distance for every query, so a plain ``proj_dist <= thr`` keep test is
    exact-safe regardless of which pruner runs the later full-dimension
    stages.  Same consumer contract as ``DeviceMirror``: ``x * scale +
    offset`` dequantizes, lanes with ``ids < 0`` are garbage, ``dim`` is the
    logical projected dimensionality (= rank)."""

    dtype: str             # "f32" | "bf16" | "int8" | "int4"
    data: jax.Array        # (P, rank, C) projected tiles (packed for int4)
    scale: jax.Array       # (rank,) f32
    offset: jax.Array      # (rank,) f32
    components: jax.Array  # (D, rank) f32 orthonormal columns: q_proj = q @ C
    tiles_version: int
    dim: int               # logical projected dimensionality == rank

    @property
    def rank(self) -> int:
        return self.dim

    @property
    def bytes_per_value(self) -> float:
        return _BYTES_PER_VALUE[self.dtype]

    @property
    def packed(self) -> bool:
        return self.dtype == "int4"

    @property
    def quantized(self) -> bool:
        return self.dtype in ("int8", "int4")


def projection_mirror(store, rank: int, dtype: str = "f32") -> ProjectionMirror:
    """The store's rank-``rank`` PCA projection mirror, cached like
    ``device_mirror`` per ``tiles_version``.

    PCA components come from the same machinery BSA uses
    (``core.pruners.pca_components``) fit on the live rows; they are shared
    across dtype/rank variants of one tiles_version (fitting dominates the
    build).  Projected tiles are quantized with the standard per-dimension
    affine recipe when ``dtype`` asks for it, with the projected collection
    means as the quantization centers."""
    if dtype not in SCAN_DTYPES:
        raise ValueError(f"scan dtype must be one of {SCAN_DTYPES}, got {dtype!r}")
    D = store.dim
    if not 1 <= rank <= D:
        raise ValueError(f"projection rank must be in [1, {D}], got {rank}")
    version = getattr(store, "tiles_version", 0)
    cache = getattr(store, "_proj_cache", None)
    if cache is None:
        cache = {}
        try:
            store._proj_cache = cache
        except AttributeError:
            pass
    key = (rank, dtype, version)
    mirror = cache.get(key)
    _metrics.counter(
        "repro_cache_events_total", cache="proj_mirror",
        event="hit" if mirror is not None else "miss",
    )
    if mirror is None:
        _metrics.counter("repro_mirror_builds_total", dtype=f"proj:{dtype}")
        comps = cache.get(("comps", version))
        if comps is None:
            from .pruners import pca_components  # deferred: pruners is a leaf

            sample = pdx_to_nary(store)[:65536]
            if len(sample) < 2:  # degenerate: identity "projection"
                comps = np.eye(D, dtype=np.float32)
            else:
                comps, _ = pca_components(sample)
            cache[("comps", version)] = comps
        Cj = jnp.asarray(comps[:, :rank])  # (D, rank)
        data = store.data  # triggers the mutable store's lazy f32 sync
        proj = jnp.einsum("dr,pdc->prc", Cj, data)
        means = Cj.T @ jnp.asarray(store.dim_means, jnp.float32)  # (rank,)
        if dtype == "f32":
            mdata = proj
            scale = jnp.ones((rank,), jnp.float32)
            offset = jnp.zeros((rank,), jnp.float32)
        elif dtype == "bf16":
            mdata = proj.astype(jnp.bfloat16)
            scale = jnp.ones((rank,), jnp.float32)
            offset = jnp.zeros((rank,), jnp.float32)
        elif dtype == "int8":
            mdata, scale, offset = _quantize_int8(proj, store.ids, means)
        else:  # int4
            mdata, scale, offset = _quantize_int4(proj, store.ids, means)
        mirror = ProjectionMirror(
            dtype=dtype, data=mdata, scale=scale, offset=offset,
            components=Cj, tiles_version=version, dim=rank,
        )
        for stale in [
            kk for kk in cache if kk[0] != "comps" and kk[2] != version
        ]:
            del cache[stale]
        if ("comps", version) in cache:
            for stale in [
                kk for kk in cache if kk[0] == "comps" and kk[1] != version
            ]:
                del cache[stale]
        cache[key] = mirror
    return mirror


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class PDXPartition:
    """One PDX partition: ``data[d, i]`` = dimension ``d`` of vector ``i``."""

    data: jax.Array        # (D, capacity) float
    ids: jax.Array         # (capacity,) int32 original row ids, -1 for padding
    count: int             # number of valid vectors (static, build-time)

    def tree_flatten(self):
        return (self.data, self.ids), (self.count,)

    @classmethod
    def tree_unflatten(cls, aux, children):
        data, ids = children
        return cls(data=data, ids=ids, count=aux[0])

    @property
    def dim(self) -> int:
        return self.data.shape[0]

    @property
    def capacity(self) -> int:
        return self.data.shape[1]


@dataclasses.dataclass
class PDXStore:
    """A collection of equal-capacity PDX partitions, batched into one array.

    ``data``   (P, D, C)  dimension-major tiles
    ``ids``    (P, C)     original row ids (-1 padding)
    ``counts`` (P,)       valid vectors per partition
    ``dim_means`` (D,)    collection-wide per-dimension means (BOND metadata)
    ``dim_vars``  (D,)    per-dimension variances (BSA block metadata)
    """

    data: jax.Array
    ids: jax.Array
    counts: jax.Array
    dim_means: jax.Array
    dim_vars: jax.Array

    @property
    def num_partitions(self) -> int:
        return self.data.shape[0]

    @property
    def dim(self) -> int:
        return self.data.shape[1]

    @property
    def capacity(self) -> int:
        return self.data.shape[2]

    @property
    def num_vectors(self) -> int:
        return int(np.sum(np.asarray(self.counts)))

    def partition(self, p: int) -> PDXPartition:
        return PDXPartition(
            data=self.data[p], ids=self.ids[p], count=int(self.counts[p])
        )


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def _pack_groups(
    X: np.ndarray,
    groups: Sequence[np.ndarray],
    capacity: int,
    row_ids: Optional[np.ndarray] = None,
) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Pack row-id groups into (P, D, C) dimension-major tiles.

    Empty groups emit NO partition (an empty IVF bucket must cost zero scan
    work — a full all-``PAD_VALUE`` tile is pure wasted DMA + FLOPs).
    ``row_ids`` maps a row index to its stored id (default: the row index
    itself; mutable-store repacks pass the surviving sparse ids).
    """
    n, d = X.shape
    parts_data, parts_ids, parts_counts = [], [], []
    for rows in groups:
        rows = np.asarray(rows, dtype=np.int64)
        for lo in range(0, len(rows), capacity):
            chunk = rows[lo : lo + capacity]
            tile = np.full((d, capacity), PAD_VALUE, dtype=X.dtype)
            ids = np.full((capacity,), -1, dtype=np.int32)
            tile[:, : len(chunk)] = X[chunk].T
            ids[: len(chunk)] = chunk if row_ids is None else row_ids[chunk]
            parts_data.append(tile)
            parts_ids.append(ids)
            parts_counts.append(len(chunk))
    if not parts_data:  # fully empty collection: one all-pad placeholder
        parts_data.append(np.full((d, capacity), PAD_VALUE, dtype=X.dtype))
        parts_ids.append(np.full((capacity,), -1, dtype=np.int32))
        parts_counts.append(0)
    return (
        np.stack(parts_data),
        np.stack(parts_ids),
        np.asarray(parts_counts, dtype=np.int32),
    )


def _store_from_packed(
    X: np.ndarray, data: np.ndarray, ids: np.ndarray, counts: np.ndarray
) -> PDXStore:
    return PDXStore(
        data=jnp.asarray(data),
        ids=jnp.asarray(ids),
        counts=jnp.asarray(counts),
        dim_means=jnp.asarray(X.mean(axis=0)),
        dim_vars=jnp.asarray(X.var(axis=0)),
    )


def build_flat_store(X: np.ndarray, capacity: int = 1024) -> PDXStore:
    """Exact-search store: horizontal slabs of ``capacity`` vectors.

    The paper uses 10K-vector partitions for exact search (Section 6.5); we
    default to a lane-friendly 1024 and let callers pick the paper's value.
    """
    X = np.asarray(X, dtype=np.float32)
    n = X.shape[0]
    groups = [np.arange(lo, min(lo + capacity, n)) for lo in range(0, n, capacity)]
    return _store_from_packed(X, *_pack_groups(X, groups, capacity))


def build_bucketed_store(
    X: np.ndarray, assignments: np.ndarray, num_buckets: int, capacity: int
) -> tuple[PDXStore, np.ndarray, np.ndarray]:
    """IVF-style store: one group per bucket, split into capacity-sized tiles.

    Returns (store, part_offsets, part_counts_per_bucket):
      partitions ``part_offsets[b] : part_offsets[b] + nparts[b]`` belong to
      bucket ``b`` (partitions are laid out bucket-contiguously, mirroring the
      paper's Figure 2 where IVF buckets map onto PDX blocks).
    """
    X = np.asarray(X, dtype=np.float32)
    assignments = np.asarray(assignments)
    groups, nparts = [], np.zeros(num_buckets, dtype=np.int64)
    for b in range(num_buckets):
        rows = np.nonzero(assignments == b)[0]
        groups.append(rows)
        # empty bucket => zero partitions => zero scan work (its offset simply
        # equals the next bucket's; partition_order yields an empty range)
        nparts[b] = _round_up(len(rows), capacity) // capacity
    data, ids, counts = _pack_groups(X, groups, capacity)
    offsets = np.concatenate([[0], np.cumsum(nparts)[:-1]])
    return _store_from_packed(X, data, ids, counts), offsets, nparts


def pdx_to_nary(store) -> np.ndarray:
    """Inverse transposition (round-trip oracle for tests).

    Works on frozen and mutable stores alike: live slots may sit anywhere in
    a tile (tombstones leave holes) and ids may be sparse (deleted ids are
    never reused), so row ``r`` of the output is the live vector with the
    ``r``-th smallest id.  For a freshly built store ids are dense 0..n-1 and
    this is the exact inverse of the build transposition.  Unflushed
    write-head rows of a ``MutablePDXStore`` are included.
    """
    data = np.asarray(store.data)
    ids = np.asarray(store.ids)
    live = ids >= 0  # (P, C)
    all_ids = [ids[live]]
    all_vecs = [np.swapaxes(data, 1, 2)[live]]  # (n_live, D)
    if hasattr(store, "head_live"):
        hids, hvecs = store.head_live()
        all_ids.append(hids)
        all_vecs.append(hvecs)
    flat_ids = np.concatenate(all_ids)
    flat_vecs = np.concatenate(all_vecs) if flat_ids.size else np.zeros(
        (0, store.dim), dtype=data.dtype
    )
    order = np.argsort(flat_ids, kind="stable")
    return np.ascontiguousarray(flat_vecs[order])


# ==========================================================================
# Tiered bucket cache — the beyond-HBM device working set.
#
# ``device_mirror`` materializes the WHOLE store at the scan dtype, which
# caps collection size at device HBM.  ``BucketCache`` keeps the f32 masters
# authoritative in host RAM and manages a fixed pool of tile-sized device
# slots as a bucket-granular cache: routing tells it which IVF buckets a
# batch will scan (``ensure``), cold buckets are LRU-evicted, and the
# requested buckets' tile extents are quantized host-side and uploaded.
# Quantization parameters are computed ONCE per store generation over all
# live masters with NumPy arithmetic that matches ``_quantize_int8``/
# ``_quantize_int4`` op-for-op, so a cached bucket's tiles are bitwise
# identical to the fully-resident mirror's — eviction/readmission can never
# change a candidate set.  ``generation`` tags every entry with the store's
# ``tiles_version``; any sealed-tile mutation invalidates the whole pool
# exactly like the mirror cache.
# ==========================================================================
@jax.jit
def _quantize_extent_int8(x, scale, offset):
    """(m, D, C) f32 tile extent -> int8 levels at the GIVEN per-dim affine
    (the cache's per-generation global params) — same rounding/clip ops as
    ``_quantize_int8`` so cached and fully-resident tiles match bitwise."""
    q = jnp.round((x - offset[None, :, None]) / scale[None, :, None])
    return jnp.clip(q, -127, 127).astype(jnp.int8)


@jax.jit
def _quantize_extent_int4(x, scale, offset):
    q = jnp.clip(
        jnp.round((x - offset[None, :, None]) / scale[None, :, None]),
        -7, 7,
    ).astype(jnp.int32)
    if q.shape[1] % 2:
        q = jnp.pad(q, ((0, 0), (0, 1), (0, 0)))
    qb = (q + 8).astype(jnp.uint8)
    return qb[:, 0::2, :] | (qb[:, 1::2, :] << 4)


def _locked(fn):
    """Serialize a ``BucketCache`` entry point on the instance RLock."""
    @functools.wraps(fn)
    def inner(self, *args, **kwargs):
        with self._lock:
            return fn(self, *args, **kwargs)
    return inner


# Single shared staging worker for async uploads: ``issue`` hands it the
# f32 extent copy, it quantizes + starts the device transfer off the query
# thread (NumPy ufuncs release the GIL, so staging genuinely overlaps the
# scan the query thread is driving).  One worker everywhere keeps upload
# ordering trivially FIFO and matches the depth-1 ticket discipline.
_stager: Optional[concurrent.futures.ThreadPoolExecutor] = None
_stager_lock = threading.Lock()


def _stage_pool() -> concurrent.futures.ThreadPoolExecutor:
    global _stager
    if _stager is None:
        with _stager_lock:
            if _stager is None:
                _stager = concurrent.futures.ThreadPoolExecutor(
                    max_workers=1, thread_name_prefix="bucket-cache-stager"
                )
    return _stager


class _UploadTicket:
    """In-flight async upload batch from ``BucketCache.issue``: the
    admission stats, the in-flight staged tiles (a Future from the staging
    worker per missed extent, or an already-transferred device array on
    the legacy sync path), the issue timestamp, and the request (for a
    stale-generation redo).  ``BucketCache.wait`` installs it into the
    pool.  Holding the pending entries here until ``wait`` is the depth-1
    double buffer: upload batch N's staging stays alive while batch N+1
    is being staged, and never deeper — ``issue`` drains any outstanding
    ticket first."""

    __slots__ = (
        "stats", "pending", "buckets", "parts", "t_issue", "generation",
        "done",
    )

    def __init__(self, stats, pending, buckets, parts, t_issue, generation):
        self.stats = stats
        self.pending = pending    # [(slots np, tile Future|dev, ids dev)]
        self.buckets = buckets
        self.parts = parts
        self.t_issue = t_issue
        self.generation = generation
        self.done = False


def _host_quant_params(
    data: np.ndarray, ids: np.ndarray, means: np.ndarray, dtype: str
) -> tuple[np.ndarray, np.ndarray]:
    """Per-dimension (scale, offset) over the live host masters, float32
    arithmetic mirroring the jitted quantizers: offset = dim means, scale =
    live-masked absmax / 127 (int8) or / 7 (int4).  abs/sub/max/div are all
    exactly-rounded IEEE ops, so this equals the on-device computation."""
    D = data.shape[1]
    if dtype in ("f32", "bf16"):
        return np.ones((D,), np.float32), np.zeros((D,), np.float32)
    means = np.asarray(means, np.float32)
    live = (ids >= 0)[:, None, :]
    dev = np.abs(data - means[None, :, None]).astype(np.float32)
    absmax = np.max(np.where(live, dev, np.float32(0.0)), axis=(0, 2))
    # XLA strength-reduces the quantizers' ``/ denom`` to ``* (1/denom)``;
    # multiply by the f32 reciprocal here too or the scales drift one ulp.
    rdenom = np.float32(1.0 / (127.0 if dtype == "int8" else 7.0))
    scale = np.maximum(absmax, np.float32(1e-6)) * rdenom
    return scale.astype(np.float32), means


class BucketCache:
    """Fixed slot-pool device cache of bucket tile extents (see block
    comment above).

    ``capacity_slots`` tiles are pre-allocated once; each resident IVF
    bucket owns the contiguous run of its partitions inside the pool (tile-
    aligned extents, any slot order — the scan masks by ``slot_bucket``, it
    never assumes pool adjacency).  ``n_regions`` > 1 splits the pool into
    equal contiguous regions with independent free lists + LRU chains; the
    routed executor aligns regions with ``Placement.bucket_shard`` so each
    device shard caches exactly the buckets it owns and pool uploads land in
    that shard's slice of the sharded pool array.

    Concurrency: pool updates are functional (``array.at[slots].set``), so
    an in-flight device scan that captured the previous pool array snapshot
    keeps scanning consistent tiles while ``ensure`` builds the next one —
    this is what lets the serve executor overlap batch N+1's uploads with
    batch N's scan without a device-side lock.
    """

    def __init__(
        self,
        store,
        *,
        capacity_slots: int,
        dtype: str = "int8",
        n_regions: int = 1,
        bucket_region: Optional[np.ndarray] = None,
        part_offsets: Optional[np.ndarray] = None,
        part_counts: Optional[np.ndarray] = None,
    ):
        if dtype not in SCAN_DTYPES:
            raise ValueError(
                f"scan dtype must be one of {SCAN_DTYPES}, got {dtype!r}"
            )
        if capacity_slots < 1:
            raise ValueError(f"capacity_slots must be >= 1, got {capacity_slots}")
        if n_regions < 1:
            raise ValueError(f"n_regions must be >= 1, got {n_regions}")
        self.store = store
        self.dtype = dtype
        self.n_regions = int(n_regions)
        self.region_slots = max(capacity_slots // self.n_regions, 1)
        self.capacity_slots = self.region_slots * self.n_regions
        if bucket_region is None:
            self._bucket_region = None  # every bucket -> region 0
        else:
            self._bucket_region = np.asarray(bucket_region, np.int64)
        # frozen stores carry no bucket structure of their own; the builder
        # (IVF) passes the extent table explicitly.
        self._static_extent = None
        if part_offsets is not None:
            self._static_extent = (
                np.asarray(part_offsets, np.int64),
                np.asarray(part_counts, np.int64),
            )
        self.generation = -1
        # A/B knob (benches, regression triage): True restores the legacy
        # upload path — f32 masters over the bus, quantized on device,
        # blocking at issue — instead of async host-staged transfers.
        self.sync_uploads = False
        # Staging strategy: host-side quantize (1-2 bytes/dim over the
        # bus, staged on the worker thread) pays off when there is a real
        # H2D bus to shrink or a spare core to stage on.  On a single-core
        # CPU backend neither exists — the fused device quantizer is less
        # total work, so async uploads dispatch it without blocking.
        self.stage_on_host = (
            jax.default_backend() != "cpu" or (os.cpu_count() or 1) > 1
        )
        # populated by _revalidate (needs store geometry):
        self._pool = None            # (S, D', C) device, mirror dtype
        self._ids_dev = None         # (S, C) int32 device
        self._slot_bucket = None     # (S,) int64 host, -1 = free/invalid
        self._slot_bucket_dev = None
        self._slot_ids = None        # (S, C) int32 host mirror of _ids_dev
        self._scale = None           # (D,) f32 device
        self._offset = None
        self._scale_np = None
        self._offset_np = None
        self._resident: list = []    # per region: OrderedDict key -> slots
        self._free: list = []        # per region: list of free slot indices
        self._inflight: Optional[_UploadTicket] = None  # depth-1 pipeline
        # the serving loop prepares batch N+1 (issue) on the batcher thread
        # while batch N scans (wait/arrays) on the executor thread — every
        # public entry point takes this; reentrant because ensure nests
        # issue+wait and a stale-generation wait re-enters ensure.
        self._lock = threading.RLock()

    # ------------------------------------------------------------ geometry
    @property
    def dim(self) -> int:
        return self.store.dim

    @property
    def packed(self) -> bool:
        return self.dtype == "int4"

    @property
    def quantized(self) -> bool:
        return self.dtype in ("int8", "int4")

    @property
    def bytes_per_value(self) -> float:
        return _BYTES_PER_VALUE[self.dtype]

    @property
    def resident_slots(self) -> int:
        return self.capacity_slots - sum(len(f) for f in self._free)

    def resident_buckets(self) -> list[int]:
        return [k if isinstance(k, int) else k[0]
                for reg in self._resident for k in reg]

    def _region_of(self, b: int) -> int:
        if self._bucket_region is None:
            return 0
        return int(self._bucket_region[b])

    def _masters(self) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Host-side (data, ids, means) views — NumPy masters for the
        mutable store, a host pull for a frozen one (host RAM is the
        authoritative tier either way)."""
        data = getattr(self.store, "_data", None)
        if data is not None:
            return data, self.store._ids, self.store._dim_means
        return (
            np.asarray(self.store.data),
            np.asarray(self.store.ids),
            np.asarray(self.store.dim_means, np.float32),
        )

    def _bucket_extent(self) -> tuple[np.ndarray, np.ndarray]:
        """Current (part_offsets, part_counts) — re-read per call for
        mutable stores: repack/adopt moves bucket -> partition ownership."""
        if getattr(self.store, "num_buckets", None) is not None:
            return (
                np.asarray(self.store.part_offsets, np.int64),
                np.asarray(self.store.part_counts, np.int64),
            )
        if self._static_extent is None:
            raise ValueError(
                "store has no bucket structure; pass part_offsets/"
                "part_counts to BucketCache"
            )
        return self._static_extent

    # -------------------------------------------------------- invalidation
    def _revalidate(self) -> None:
        gen = getattr(self.store, "tiles_version", 0)
        if gen == self.generation:
            return
        if self.generation >= 0 and _metrics.enabled():
            _metrics.counter(
                "repro_tiered_cache_events_total", event="invalidate"
            )
        data, ids, means = self._masters()
        P, D, C = data.shape
        Dp = (D + 1) // 2 if self.packed else D
        pool_dt = {
            "f32": jnp.float32, "bf16": jnp.bfloat16,
            "int8": jnp.int8, "int4": jnp.uint8,
        }[self.dtype]
        S = self.capacity_slots
        self._pool = jnp.zeros((S, Dp, C), pool_dt)
        self._ids_dev = jnp.full((S, C), -1, jnp.int32)
        self._slot_ids = np.full((S, C), -1, np.int32)
        self._slot_bucket = np.full((S,), -1, np.int64)
        self._slot_bucket_dev = jnp.asarray(self._slot_bucket)
        sc, off = _host_quant_params(data, ids, means, self.dtype)
        self._scale_np, self._offset_np = sc, off
        self._scale = jnp.asarray(sc)
        self._offset = jnp.asarray(off)
        self._resident = [
            collections.OrderedDict() for _ in range(self.n_regions)
        ]
        self._free = [
            list(range(r * self.region_slots, (r + 1) * self.region_slots))
            for r in range(self.n_regions)
        ]
        self.generation = gen

    # ------------------------------------------------------------- serving
    def _host_quantize(self, x: np.ndarray, scale=None, offset=None):
        """(m, D, C) f32 host extent -> pool-dtype staging array.  NumPy
        arithmetic bitwise-matching the jitted extent quantizers (sub/div/
        rint/clip are all exactly-rounded IEEE ops on both paths), so a
        host-staged upload equals on-device quantization bit for bit —
        while the H2D copy shrinks to 1-2 bytes per dimension instead of
        the f32 masters.  ``scale``/``offset`` pin the quant params when
        the staging worker runs after the issue that captured them."""
        sc = self._scale_np if scale is None else scale
        off = self._offset_np if offset is None else offset
        if self.dtype == "int8":
            # in-place passes (one ~x-sized temp total): the staging
            # worker shares cores with the scan, so every avoided
            # temporary is scan time.  Same sub/div/rint/clip op sequence
            # as the jitted twin — bitwise parity is load-bearing.
            q = np.subtract(x, off[None, :, None], dtype=np.float32)
            np.divide(q, sc[None, :, None], out=q)
            np.rint(q, out=q)
            np.clip(q, -127, 127, out=q)
            return q.astype(np.int8)
        if self.dtype == "int4":
            q = np.subtract(x, off[None, :, None], dtype=np.float32)
            np.divide(q, sc[None, :, None], out=q)
            np.rint(q, out=q)
            np.clip(q, -7, 7, out=q)
            q = q.astype(np.int32)
            if q.shape[1] % 2:
                q = np.pad(q, ((0, 0), (0, 1), (0, 0)))
            qb = (q + 8).astype(np.uint8)
            return qb[:, 0::2, :] | (qb[:, 1::2, :] << 4)
        if self.dtype == "bf16":
            return np.asarray(x, np.float32).astype(jnp.bfloat16)
        return np.ascontiguousarray(x, np.float32)

    def _device_quantize(self, ext):
        """Pool-dtype tile from an on-device f32 extent — the jitted
        twins of ``_host_quantize`` (bitwise-equal results)."""
        if self.dtype == "int8":
            return _quantize_extent_int8(ext, self._scale, self._offset)
        if self.dtype == "int4":
            return _quantize_extent_int4(ext, self._scale, self._offset)
        if self.dtype == "bf16":
            return ext.astype(jnp.bfloat16)
        return ext

    @staticmethod
    def _sub_extent(off, cnt, part):
        """Row window of sub-extent ``part = (part_i, n_parts)`` of a
        bucket extent — ceil-divided so every part fits a region."""
        if part is None:
            return off, cnt
        pi, n_parts = part
        per = -(-cnt // n_parts)
        return off + pi * per, max(min(per, cnt - pi * per), 0)

    @_locked
    def resident_ok(self, buckets, parts: Optional[dict] = None) -> bool:
        """True when every (sub-)extent of the request is still resident —
        the run loop's cheap guard against a concurrent batch's ``issue``
        having evicted tiles between this pass's prefetch and its scan."""
        if getattr(self.store, "tiles_version", 0) != self.generation:
            return False
        _, cnts = self._bucket_extent()
        for b in np.asarray(buckets, np.int64).reshape(-1):
            b = int(b)
            if b < 0 or b >= len(cnts) or int(cnts[b]) == 0:
                continue
            part = (parts or {}).get(b)
            key = b if part is None else (b,) + tuple(part)
            if key not in self._resident[self._region_of(b)]:
                return False
        return True

    @_locked
    def issue(self, buckets, parts: Optional[dict] = None) -> _UploadTicket:
        """Asynchronous half of ``ensure``: run the LRU admission
        bookkeeping and hand every missing extent to the staging worker,
        which host-quantizes it and STARTS its ``jax.device_put`` —
        returning a ticket whose ``wait`` installs the in-flight copies
        into the pool.  Staging and copies overlap whatever the query
        thread and device are executing (the
        previous chunk's scan in the tiered loop, the previous batch's
        whole search through the serving handoff).  Depth-1 discipline:
        issuing while another ticket is in flight waits that one first,
        so at most one upload batch is ever pending.

        ``parts`` maps bucket -> ``(part_index, n_parts)`` to admit one
        region-sized sub-extent of a bucket too large for its region; the
        tiered executor scans each sub-extent in its own pass and merges
        top-k, so a single query whose routed demand exceeds the slot pool
        succeeds instead of raising."""
        if self._inflight is not None:
            self.wait(self._inflight)
        self._revalidate()
        offs, cnts = self._bucket_extent()
        data, ids, _ = self._masters()
        hits = misses = evicted = uploaded = 0
        pending: list = []
        seen = set()
        for b in np.asarray(buckets, np.int64).reshape(-1):
            b = int(b)
            part = (parts or {}).get(b)
            key = b if part is None else (b,) + tuple(part)
            if b < 0 or key in seen:
                continue
            seen.add(key)
            cnt = int(cnts[b]) if b < len(cnts) else 0
            off, cnt = self._sub_extent(int(offs[b]) if cnt else 0, cnt, part)
            if cnt == 0:
                continue
            r = self._region_of(b)
            res = self._resident[r]
            if key in res:
                hits += 1
                res.move_to_end(key)
                continue
            misses += 1
            if cnt > self.region_slots:
                raise ValueError(
                    f"bucket {b} spans {cnt} tiles > region capacity "
                    f"{self.region_slots}; split it via parts= or raise "
                    "hbm_slots"
                )
            while len(self._free[r]) < cnt:
                # Evict the coldest entry NOT requested by this batch —
                # everything in ``seen`` is pinned for the upcoming scan.
                victim = next((o for o in res if o not in seen), None)
                if victim is None:
                    raise ValueError(
                        f"batch demands more tiles than region {r} holds "
                        f"({self.region_slots} slots); raise hbm_slots or "
                        "split the batch"
                    )
                old_slots = res.pop(victim)
                self._free[r].extend(old_slots.tolist())
                self._slot_bucket[old_slots] = -1
                evicted += 1
            slots = np.asarray(
                [self._free[r].pop() for _ in range(cnt)], np.int64
            )
            ext_ids = np.ascontiguousarray(ids[off : off + cnt], np.int32)
            ext = np.ascontiguousarray(data[off : off + cnt], np.float32)
            if self.sync_uploads:
                # legacy path: the full-width f32 extent crosses the bus,
                # quantizes on device, and the host stalls until it lands —
                # bitwise-identical tiles (see _host_quantize), 2-4x the
                # H2D payload and zero overlap
                tile = self._device_quantize(jax.device_put(ext))
                jax.block_until_ready(tile)
                pending.append((slots, tile, jax.device_put(ext_ids)))
            elif self.stage_on_host:
                # quantize + device_put on the staging worker: the heavy
                # NumPy pass runs off the query thread, overlapping
                # whatever scan that thread dispatches next, and only the
                # quantized bytes cross the bus
                fut = _stage_pool().submit(
                    lambda x=ext, sc=self._scale_np, of=self._offset_np:
                        jax.device_put(self._host_quantize(x, sc, of))
                )
                pending.append((slots, fut, jax.device_put(ext_ids)))
            else:
                # single-core CPU: fused device quantize dispatched
                # asynchronously — same total work as the legacy path but
                # ``wait`` blocks once per upload batch, not per miss
                tile = self._device_quantize(jax.device_put(ext))
                pending.append((slots, tile, jax.device_put(ext_ids)))
            res[key] = slots
            self._slot_ids[slots] = ext_ids
            self._slot_bucket[slots] = b
            uploaded += cnt
            if _metrics.enabled():
                # actual H2D payload: quantized staging bytes on the
                # host-staged path, the f32 extent otherwise
                staged_host = not self.sync_uploads and self.stage_on_host
                _metrics.counter(
                    "repro_tiered_prefetch_bytes_total",
                    float(cnt * self.dim * data.shape[2])
                    * (self.bytes_per_value if staged_host else 4.0),
                    dtype=self.dtype,
                )
        ticket = _UploadTicket(
            stats={"hits": hits, "misses": misses,
                   "evicted": evicted, "uploaded_slots": uploaded},
            pending=pending, buckets=np.asarray(buckets, np.int64),
            parts=parts, t_issue=time.perf_counter(),
            generation=self.generation,
        )
        self._inflight = ticket
        return ticket

    @_locked
    def wait(self, ticket: Optional[_UploadTicket]) -> dict:
        """Blocking half of ``ensure``: install the ticket's in-flight
        copies into the pool (functional ``.at[slots].set`` updates —
        snapshots captured by earlier ``arrays()`` calls stay consistent),
        block until the H2D transfers land, and meter how long the host
        actually waited vs the full issue->complete window
        (``repro_cache_upload_wait_us`` / ``..._overlap_ratio``): a wait
        near zero means the copies hid entirely behind compute."""
        if ticket is None:
            return {"hits": 0, "misses": 0, "evicted": 0,
                    "uploaded_slots": 0}
        if ticket.done:
            return ticket.stats
        ticket.done = True
        if self._inflight is ticket:
            self._inflight = None
        if getattr(self.store, "tiles_version", 0) != ticket.generation:
            # the store mutated mid-flight: the pool is (about to be)
            # rebuilt; drop the stale copies and re-admit synchronously
            return self.ensure(ticket.buckets, parts=ticket.parts)
        t0 = time.perf_counter()
        if ticket.pending:
            resolved = []
            for slots, tile_dev, ids_dev in ticket.pending:
                if isinstance(tile_dev, concurrent.futures.Future):
                    tile_dev = tile_dev.result()
                jslots = jnp.asarray(slots)
                self._pool = self._pool.at[jslots].set(tile_dev)
                self._ids_dev = self._ids_dev.at[jslots].set(ids_dev)
                resolved.append(tile_dev)
            jax.block_until_ready(resolved)
            done = time.perf_counter()
            from ..obs.meters import cache_upload_wait

            cache_upload_wait(
                (done - t0) * 1e6, (done - ticket.t_issue) * 1e6
            )
        stats = ticket.stats
        if stats["evicted"] or stats["uploaded_slots"]:
            self._slot_bucket_dev = jnp.asarray(self._slot_bucket)
        if _metrics.enabled():
            for key, event in (("hits", "hit"), ("misses", "miss"),
                               ("evicted", "evict")):
                if stats[key]:
                    _metrics.counter(
                        "repro_tiered_cache_events_total",
                        float(stats[key]), event=event,
                    )
            _metrics.gauge(
                "repro_tiered_cache_resident_slots",
                float(self.resident_slots),
            )
        return stats

    @_locked
    def ensure(self, buckets, parts: Optional[dict] = None) -> dict:
        """Admit every requested bucket (routed set of the NEXT batch —
        calling this from the host/prepare phase is the prefetch), evicting
        cold LRU entries per region as needed.  Returns
        ``{"hits", "misses", "evicted", "uploaded_slots"}``.  The
        synchronous composition of ``issue`` + ``wait``; callers that can
        overlap uploads with compute use the halves directly.

        Raises ValueError only when one bucket alone exceeds a region AND
        no ``parts`` sub-extent split was requested (the tiered executor
        always splits, so oversized routed demand succeeds there)."""
        return self.wait(self.issue(buckets, parts=parts))

    @_locked
    def arrays(self):
        """Snapshot of the device-side cache state for a scan closure:
        ``(pool, slot_ids, slot_bucket, scale, offset)``.  Functional pool
        updates mean later ``ensure`` calls never mutate these arrays; an
        in-flight upload ticket is installed first, so the snapshot always
        reflects everything admitted so far."""
        if self._inflight is not None:
            self.wait(self._inflight)
        self._revalidate()
        return (
            self._pool, self._ids_dev, self._slot_bucket_dev,
            self._scale, self._offset,
        )

    @_locked
    def snapshot(self) -> tuple:
        """Atomic ``(arrays(), slot_ids copy)`` pair — the run loop's scan
        inputs and its id-resolution table must come from the same instant
        or a concurrent ``issue`` could remap ids between the two reads."""
        return self.arrays(), np.array(self.slot_ids_host(), copy=True)

    @_locked
    def slot_ids_host(self) -> np.ndarray:
        """(S, C) host copy of the pool's vector ids (candidate positions
        from a pool scan resolve to global ids through this)."""
        if self._inflight is not None:
            self.wait(self._inflight)
        self._revalidate()
        return self._slot_ids


# ==========================================================================
# Mutable PDX — the versioned serving store.
# ==========================================================================
class MutablePDXStore:
    """Versioned, mutable PDX store: sealed tiles + write-head + tombstones.

    Presents the same read interface as ``PDXStore`` (``data``/``ids``/
    ``counts`` device arrays, ``dim``/``capacity``/``num_partitions``), so
    every executor consumes it unchanged; mutation happens on NumPy master
    copies and the device mirror is refreshed lazily, once per version.

    Mutation model
      * ``insert(V)`` appends rows to a small horizontal *write-head*
        ``(head_capacity, D)`` buffer.  Write-head rows are scanned exactly
        (unpruned) by every executor — the planner merges them into each
        top-k (see ``repro.core.plan.execute``) — until a flush drains them
        into sealed tiles.
      * ``delete(ids)`` tombstones: the slot's id becomes -1 (which is also
        the free-slot bitmap bit) and its column is poisoned to
        ``PAD_VALUE`` so no metric can ever rank it into a top-k.
      * ``flush()`` drains live write-head rows into free sealed slots
        (bucket-local for bucketed stores, preserving the bucket-contiguous
        layout); when free slots run out it falls back to ``repack()``.
      * ``repack()`` rebuilds lane-aligned tiles from scratch out of the
        surviving rows (bucket-contiguous for IVF) — the "background
        re-pack" of the ROADMAP.  Partition count shrinks back to the
        minimum, tombstone holes disappear, and pruner metadata
        (``dim_means``/``dim_vars``) is refreshed from running moments.

    ``version`` increases on every mutating call; jitted-executor caches
    (``core.pdxearch._EXEC_CACHE``) and plan traces key on it so a search
    can never reuse state derived from stale tiles.  ``tiles_version``
    increases only when the *sealed* tiles change (sealed delete, flush,
    repack): the device mirror and the sharded executors' ``Placement``
    cache key on it, so a head-only insert never re-uploads the whole store
    or re-arranges a distributed placement.  Under bucket-owned sharding
    (``repro.dist.placement``) this means an insert lands in the owning
    shard's slice for free: the row's bucket is assigned at insert time,
    ``flush`` fills free slots inside that bucket's partitions — which live
    in the owner shard's contiguous slice — and the placement is only
    rebuilt when a flush/repack actually moves sealed tiles.

    Pruner metadata is *incrementally* maintained: running per-dimension
    sum / sum-of-squares are updated O(D) per inserted/deleted row, and the
    public ``dim_means``/``dim_vars`` snapshot is refreshed on repack or
    whenever the fraction of mutations since the last refresh exceeds
    ``meta_staleness`` — never on every insert.
    """

    def __init__(
        self,
        data: np.ndarray,
        ids: np.ndarray,
        counts: np.ndarray,
        dim_means: np.ndarray,
        dim_vars: np.ndarray,
        *,
        head_capacity: int = 256,
        num_buckets: Optional[int] = None,
        part_bucket: Optional[np.ndarray] = None,
        meta_staleness: float = 0.25,
    ):
        # np.asarray over a jax array yields a read-only view; these are the
        # mutable masters, so force writable copies.
        self._data = np.array(data, dtype=np.float32, copy=True, order="C")
        self._ids = np.array(ids, dtype=np.int32, copy=True, order="C")
        self._counts = np.asarray(counts, np.int32).copy()
        # NOTE the per-partition free-slot bitmap IS `self._ids < 0` — a slot
        # is reusable iff its id is the -1 sentinel, with no second array to
        # keep in sync (see _plan_free_slot_fill).
        self._dim_means = np.asarray(dim_means, np.float32).copy()
        self._dim_vars = np.asarray(dim_vars, np.float32).copy()
        self.meta_staleness = float(meta_staleness)
        # version: every mutation (cache keys / plan traces key on it).
        # tiles_version: only mutations that touch the SEALED tiles (sealed
        # delete, flush, repack) — head-only inserts leave it alone, so the
        # device mirror / padded-tile caches skip the full-store re-upload.
        self.version = 0
        self.tiles_version = 0

        P, D, C = self._data.shape
        if head_capacity < 1:
            raise ValueError(
                f"head_capacity must be >= 1, got {head_capacity}"
            )
        self.head_capacity = int(head_capacity)
        self._head_data = np.full(
            (self.head_capacity, D), PAD_VALUE, dtype=np.float32
        )
        self._head_ids = np.full((self.head_capacity,), -1, dtype=np.int32)
        self._head_assign = np.full((self.head_capacity,), -1, dtype=np.int32)
        self._head_n = 0  # append pointer (holes stay until flush)

        # bucket structure (IVF): which bucket owns each sealed partition
        self.num_buckets = num_buckets
        if num_buckets is not None:
            if part_bucket is None:
                raise ValueError("bucketed store needs part_bucket")
            self._part_bucket = np.asarray(part_bucket, np.int64).copy()
        else:
            self._part_bucket = np.full((P,), -1, dtype=np.int64)

        # id -> location map ('s', p, c) sealed | ('h', j) write-head
        self._id_loc = self._build_id_loc()
        self._next_id = 1 + max(self._id_loc, default=-1)

        # running per-dimension moments over live rows (float64 for drift)
        live = self._ids >= 0
        live_vecs = np.swapaxes(self._data, 1, 2)[live].astype(np.float64)
        self._sum = live_vecs.sum(axis=0)
        self._sumsq = (live_vecs**2).sum(axis=0)
        self._n_live = int(live.sum())
        self._mutations_since_meta = 0

        self._dev: Optional[tuple] = None
        self._dev_version = -1
        # mutation oplog (delta-replay for background maintenance): None =
        # not recording; a list accumulates ("insert"|"delete", ...) entries
        # between oplog_start() and oplog_take().
        self._oplog: Optional[list] = None
        self._oplog_limit = 8192

    # -------------------------------------------------- mutation oplog
    def oplog_start(self, limit: int = 8192) -> None:
        """Begin recording mutations (insert/delete) applied to THIS store.

        The maintenance thread calls this right after cloning: mutations
        that land while the clone repacks off-thread are replayed onto the
        clone before ``adopt``, so adoption succeeds under continuous
        traffic instead of discarding the repack work.  Bounded by
        ``limit`` rows — a flood larger than that makes replay pointless
        (the clone is about as stale as a fresh clone is cheap), so the log
        overflows and ``oplog_take`` reports it."""
        self._oplog = []
        self._oplog_limit = int(limit)
        self._oplog_rows = 0

    def oplog_take(self) -> Optional[list]:
        """Stop recording and return the recorded ops in application order,
        or None if the log overflowed ``limit`` rows (caller should discard
        its clone).  Entries are ``("insert", V, assignments, ids)`` /
        ``("delete", ids)`` with defensively copied arrays."""
        ops, self._oplog = self._oplog, None
        if ops is not None and self._oplog_rows > self._oplog_limit:
            return None
        return ops

    def _oplog_record(self, entry: tuple, rows: int) -> None:
        if self._oplog is None:
            return
        self._oplog_rows += rows
        if self._oplog_rows <= self._oplog_limit:
            self._oplog.append(entry)

    def replay(self, ops: list) -> int:
        """Apply an ``oplog_take`` list to this store (the maintenance
        clone); returns rows replayed.  Replayed inserts must reproduce the
        recorded ids — guaranteed because ``clone()`` copies ``_next_id``
        and id assignment is sequential — and a mismatch raises, because a
        store with diverged ids must never be adopted."""
        rows = 0
        for op in ops:
            if op[0] == "insert":
                _, V, assignments, ids = op
                got = self.insert(V, assignments)
                if not np.array_equal(got, ids):
                    raise ValueError(
                        "oplog replay id divergence: "
                        f"expected {ids[:4]}..., got {got[:4]}..."
                    )
                rows += len(ids)
            else:
                rows += self.delete(op[1])
        return rows

    def _build_id_loc(self) -> dict[int, tuple]:
        """Vectorized sealed-slot scan (a Python loop over P*C slots would
        dominate repack latency at 100k+ vectors)."""
        ps, cs = np.nonzero(self._ids >= 0)
        return {
            i: ("s", p, c)
            for i, p, c in zip(
                self._ids[ps, cs].tolist(), ps.tolist(), cs.tolist()
            )
        }

    # ----------------------------------------------------------- constructors
    @classmethod
    def from_store(
        cls,
        store: PDXStore,
        *,
        head_capacity: int = 256,
        num_buckets: Optional[int] = None,
        part_counts: Optional[np.ndarray] = None,
        meta_staleness: float = 0.25,
    ) -> "MutablePDXStore":
        """Unseal a frozen ``PDXStore``.  For a bucketed (IVF) store pass its
        per-bucket ``part_counts`` so repack keeps bucket contiguity (the
        layout is bucket-contiguous, so counts fully determine ownership)."""
        part_bucket = None
        if num_buckets is not None:
            nparts = np.asarray(part_counts, np.int64)
            part_bucket = np.repeat(np.arange(num_buckets), nparts)
            if len(part_bucket) < store.num_partitions:  # pad placeholders
                part_bucket = np.concatenate([
                    part_bucket,
                    np.full(
                        store.num_partitions - len(part_bucket), -1, np.int64
                    ),
                ])
        return cls(
            np.asarray(store.data), np.asarray(store.ids),
            np.asarray(store.counts), np.asarray(store.dim_means),
            np.asarray(store.dim_vars),
            head_capacity=head_capacity, num_buckets=num_buckets,
            part_bucket=part_bucket, meta_staleness=meta_staleness,
        )

    def _bump(self, tiles: bool = False):
        self.version += 1
        if tiles:
            self.tiles_version += 1

    # ------------------------------------------------------ PDXStore interface
    def _sync_device(self):
        if self._dev_version != self.tiles_version:
            _metrics.counter("repro_store_device_uploads_total")
            self._dev = (
                jnp.array(self._data),
                jnp.array(self._ids),
                jnp.array(self._counts),
            )
            self._dev_version = self.tiles_version

    def _obs_mutation(self, op: str, rows: int) -> None:
        """Record one mutation event plus the store-health gauges the
        serving tier watches (live rows, write-head fill, metadata
        staleness).  One enabled() check when observability is off."""
        if not _metrics.enabled():
            return
        _metrics.counter("repro_store_mutations_total", op=op)
        _metrics.counter("repro_store_rows_mutated_total", float(rows), op=op)
        _metrics.gauge("repro_store_live_vectors", float(self._n_live))
        _metrics.gauge(
            "repro_store_head_fill",
            self.head_count / max(self.head_capacity, 1),
        )
        _metrics.gauge(
            "repro_store_meta_staleness",
            self._mutations_since_meta / max(self._n_live, 1),
        )

    @property
    def data(self) -> jax.Array:
        self._sync_device()
        return self._dev[0]

    @property
    def ids(self) -> jax.Array:
        self._sync_device()
        return self._dev[1]

    @property
    def counts(self) -> jax.Array:
        self._sync_device()
        return self._dev[2]

    @property
    def dim_means(self) -> jax.Array:
        return jnp.asarray(self._dim_means)

    @property
    def dim_vars(self) -> jax.Array:
        return jnp.asarray(self._dim_vars)

    @property
    def num_partitions(self) -> int:
        return self._data.shape[0]

    @property
    def dim(self) -> int:
        return self._data.shape[1]

    @property
    def capacity(self) -> int:
        return self._data.shape[2]

    @property
    def num_vectors(self) -> int:
        """Live vectors: sealed non-tombstoned slots + unflushed head rows."""
        return int(self._counts.sum()) + int((self._head_ids >= 0).sum())

    def partition(self, p: int) -> PDXPartition:
        return PDXPartition(
            data=self.data[p], ids=self.ids[p], count=int(self._counts[p])
        )

    # -------------------------------------------------------- bucket structure
    @property
    def part_offsets(self) -> np.ndarray:
        """(K,) first partition id of each bucket (bucket-contiguous layout)."""
        nparts = self.part_counts
        return np.concatenate([[0], np.cumsum(nparts)[:-1]]).astype(np.int64)

    @property
    def part_counts(self) -> np.ndarray:
        """(K,) partitions per bucket; 0 for empty buckets."""
        if self.num_buckets is None:
            raise ValueError("flat store has no bucket structure")
        return np.bincount(
            self._part_bucket[self._part_bucket >= 0],
            minlength=self.num_buckets,
        ).astype(np.int64)

    # -------------------------------------------------------------- write-head
    @property
    def head_count(self) -> int:
        return int((self._head_ids >= 0).sum())

    def head_live(self) -> tuple[np.ndarray, np.ndarray]:
        """Live write-head rows -> ((m,) ids, (m, D) vectors).  These must be
        merged *exactly* (no pruning) into every executor's top-k."""
        mask = self._head_ids >= 0
        return self._head_ids[mask].copy(), self._head_data[mask].copy()

    def head_snapshot(self) -> tuple[np.ndarray, np.ndarray]:
        """The FULL write-head buffer -> ((head_capacity,) ids, (head_capacity,
        D) vectors), dead slots included (id -1, data ``PAD_VALUE``).  Unlike
        ``head_live`` the returned shapes never change, so the merge kernel in
        ``core.plan`` compiles once per (batch bucket, head_capacity) instead
        of once per fill level — the serving tier's zero-recompile contract
        under churn."""
        return self._head_ids.copy(), self._head_data.copy()

    # --------------------------------------------------------------- mutation
    def insert(
        self, V: np.ndarray, assignments: Optional[np.ndarray] = None
    ) -> np.ndarray:
        """Absorb rows into the write-head; returns their new global ids.

        ``assignments`` — per-row IVF bucket (centroid assignment done at
        insert time by the index); required for bucketed stores.  A full
        write-head flushes itself (free-slot fill, falling back to repack).
        """
        V = np.atleast_2d(np.ascontiguousarray(np.asarray(V, np.float32)))
        if V.shape[1] != self.dim:
            raise ValueError(f"expected (N, {self.dim}) rows, got {V.shape}")
        if self.num_buckets is not None:
            if assignments is None:
                raise ValueError("bucketed store insert needs assignments")
            assignments = np.asarray(assignments, np.int32)
            if assignments.shape != (len(V),):
                raise ValueError("one bucket assignment per inserted row")
        new_ids = np.arange(
            self._next_id, self._next_id + len(V), dtype=np.int32
        )
        self._next_id += len(V)
        pos = 0  # chunked copies: bulk-load cost is slice assignments, not rows
        while pos < len(V):
            if self._head_n == self.head_capacity:
                self.flush()
            j0, take = self._head_n, min(
                self.head_capacity - self._head_n, len(V) - pos
            )
            self._head_data[j0 : j0 + take] = V[pos : pos + take]
            self._head_ids[j0 : j0 + take] = new_ids[pos : pos + take]
            if assignments is not None:
                self._head_assign[j0 : j0 + take] = assignments[pos : pos + take]
            self._id_loc.update(
                (i, ("h", j0 + off))
                for off, i in enumerate(new_ids[pos : pos + take].tolist())
            )
            self._head_n += take
            pos += take
        self._sum += V.astype(np.float64).sum(axis=0)
        self._sumsq += (V.astype(np.float64) ** 2).sum(axis=0)
        self._n_live += len(V)
        self._mutations_since_meta += len(V)
        self._maybe_refresh_meta()
        self._oplog_record(
            (
                "insert", V.copy(),
                None if assignments is None else assignments.copy(),
                new_ids.copy(),
            ),
            len(V),
        )
        self._bump()  # head-only: sealed tiles untouched (unless flush ran)
        self._obs_mutation("insert", len(V))
        return new_ids

    def delete(self, ids) -> int:
        """Tombstone rows by id; returns how many were live.  Sealed slots
        are poisoned to ``PAD_VALUE`` and their free-bitmap bit set.

        Batched: the id array is resolved to (partition, column) coordinates
        up front, then every slot is poisoned in one fancy-indexed pass and
        the running moments are updated with one reduction — a 10k-id delete
        costs a handful of NumPy calls, not 10k per-row assignments."""
        sealed_p, sealed_c, head_j = [], [], []
        for i in np.atleast_1d(np.asarray(ids, np.int64)):
            loc = self._id_loc.pop(int(i), None)  # also dedups repeated ids
            if loc is None:
                continue
            if loc[0] == "s":
                sealed_p.append(loc[1])
                sealed_c.append(loc[2])
            else:
                head_j.append(loc[1])
        removed = len(sealed_p) + len(head_j)
        if not removed:
            return 0
        if sealed_p:
            ps = np.asarray(sealed_p, np.int64)
            cs = np.asarray(sealed_c, np.int64)
            vecs = self._data[ps, :, cs].astype(np.float64)  # (m, D)
            self._sum -= vecs.sum(axis=0)
            self._sumsq -= (vecs**2).sum(axis=0)
            self._data[ps, :, cs] = PAD_VALUE
            self._ids[ps, cs] = -1
            np.subtract.at(self._counts, ps, 1)
        if head_j:
            js = np.asarray(head_j, np.int64)
            vecs = self._head_data[js].astype(np.float64)
            self._sum -= vecs.sum(axis=0)
            self._sumsq -= (vecs**2).sum(axis=0)
            self._head_data[js] = PAD_VALUE
            self._head_ids[js] = -1
        self._n_live -= removed
        self._mutations_since_meta += removed
        self._maybe_refresh_meta()
        self._oplog_record(
            ("delete", np.atleast_1d(np.asarray(ids, np.int64)).copy()),
            removed,
        )
        self._bump(tiles=bool(sealed_p))
        self._obs_mutation("delete", removed)
        return removed

    def flush(self) -> None:
        """Drain live write-head rows into free sealed slots (reusing the
        free-slot bitmap; bucket-local for bucketed stores).  Falls back to a
        full ``repack()`` when free slots run out."""
        rows = np.nonzero(self._head_ids >= 0)[0]
        if len(rows) == 0:
            self._reset_head()  # only tombstoned head rows, if any: a no-op
            return
        placements = self._plan_free_slot_fill(rows)
        if placements is None:
            self.repack()
            return
        for j, (p, c) in zip(rows, placements):
            i = int(self._head_ids[j])
            self._data[p, :, c] = self._head_data[j]
            self._ids[p, c] = i
            self._counts[p] += 1
            self._id_loc[i] = ("s", p, int(c))
        self._reset_head()
        self._bump(tiles=True)
        self._obs_mutation("flush", len(rows))

    def _plan_free_slot_fill(self, rows) -> Optional[list]:
        """(p, c) free slot per head row, or None if any row has no slot.
        Free slots are enumerated once per bucket, not once per row."""
        free = self._ids < 0  # the free-slot bitmap
        if self.num_buckets is None:
            free_p, free_c = np.nonzero(free)
            if len(free_p) < len(rows):
                return None
            return list(zip(free_p[: len(rows)], free_c[: len(rows)]))
        placements: dict[int, tuple] = {}
        for b in np.unique(self._head_assign[rows]):
            mine = rows[self._head_assign[rows] == b]
            free_p, free_c = np.nonzero(free & (self._part_bucket == b)[:, None])
            if len(free_p) < len(mine):
                return None
            for j, p, c in zip(mine, free_p, free_c):
                placements[int(j)] = (p, c)
        return [placements[int(j)] for j in rows]

    def _reset_head(self):
        self._head_data[:] = PAD_VALUE
        self._head_ids[:] = -1
        self._head_assign[:] = -1
        self._head_n = 0

    def repack(self) -> None:
        """Drain tombstones and the write-head back into minimal lane-aligned
        tiles (bucket-contiguous for IVF), then refresh pruner metadata."""
        C = self.capacity
        live = self._ids >= 0
        hmask = self._head_ids >= 0
        all_ids = np.concatenate([self._ids[live], self._head_ids[hmask]])
        all_vecs = np.concatenate(
            [np.swapaxes(self._data, 1, 2)[live], self._head_data[hmask]]
        )
        all_bucket = np.concatenate([
            np.repeat(self._part_bucket, C).reshape(self._ids.shape)[live],
            self._head_assign[hmask].astype(np.int64),
        ])
        order = np.argsort(all_ids, kind="stable")  # deterministic layout
        all_ids, all_vecs, all_bucket = (
            all_ids[order], all_vecs[order], all_bucket[order],
        )

        if self.num_buckets is None:
            buckets = [-1]
            groups = [np.arange(len(all_ids))]
        else:
            buckets = list(range(self.num_buckets))
            groups = [np.nonzero(all_bucket == b)[0] for b in buckets]
        self._data, self._ids, self._counts = _pack_groups(
            all_vecs, groups, C, row_ids=all_ids
        )
        nparts = [-(-len(g) // C) for g in groups]
        if sum(nparts) == 0:  # nothing survived: the all-pad placeholder tile
            self._part_bucket = np.asarray([-1], dtype=np.int64)
        else:
            self._part_bucket = np.repeat(buckets, nparts).astype(np.int64)
        self._id_loc = self._build_id_loc()
        self._reset_head()
        self._refresh_meta()
        self._bump(tiles=True)
        self._obs_mutation("repack", len(all_ids))

    def replace_live_vectors(self, X: np.ndarray) -> None:
        """Overwrite every live sealed vector, row ``r`` of ``X`` replacing
        the vector with the ``r``-th smallest id (the ``pdx_to_nary``
        order).  Ids, bucket assignments, and tile geometry are untouched —
        this is the store-level primitive for re-projecting a collection in
        place (e.g. recalibrating BSA's PCA on compact, where the stored
        coordinates change but identity and bucket structure do not).
        Requires a drained write-head (call after ``flush``/``repack``)."""
        if self.head_count:
            raise ValueError(
                "replace_live_vectors needs a drained write-head; "
                "flush() or repack() first"
            )
        X = np.asarray(X, np.float32)
        ps, cs = np.nonzero(self._ids >= 0)
        if len(ps) != len(X):
            raise ValueError(
                f"{len(X)} replacement rows for {len(ps)} live vectors"
            )
        order = np.argsort(self._ids[ps, cs], kind="stable")
        self._data[ps[order], :, cs[order]] = X
        self._sum = X.astype(np.float64).sum(axis=0)
        self._sumsq = (X.astype(np.float64) ** 2).sum(axis=0)
        self._refresh_meta()
        self._bump(tiles=True)

    # ------------------------------------------------- incremental metadata
    def _maybe_refresh_meta(self):
        if self._mutations_since_meta > self.meta_staleness * max(
            self._n_live, 1
        ):
            self._refresh_meta()

    def _refresh_meta(self):
        """Snapshot dim_means/dim_vars (BOND / BSA block metadata) from the
        running moments — O(D), independent of collection size."""
        n = max(self._n_live, 1)
        mean = self._sum / n
        self._dim_means = mean.astype(np.float32)
        self._dim_vars = np.maximum(self._sumsq / n - mean**2, 0.0).astype(
            np.float32
        )
        self._mutations_since_meta = 0

    # ------------------------------------------- background maintenance
    @property
    def fragmentation(self) -> float:
        """Fraction of sealed slots that are pad/tombstone holes — the
        maintenance thread's repack trigger."""
        P, _, C = self._data.shape
        return 1.0 - float(self._counts.sum()) / float(P * C)

    def clone(self) -> "MutablePDXStore":
        """Deep, independent copy of all host-side state (device cache
        excluded — the clone re-uploads lazily on first read).  The serving
        tier's maintenance thread clones under the store lock, repacks the
        clone unlocked off the serving path, and swaps it back in with
        ``adopt``."""
        other = MutablePDXStore.__new__(MutablePDXStore)
        other._data = self._data.copy()
        other._ids = self._ids.copy()
        other._counts = self._counts.copy()
        other._dim_means = self._dim_means.copy()
        other._dim_vars = self._dim_vars.copy()
        other.meta_staleness = self.meta_staleness
        other.version = self.version
        other.tiles_version = self.tiles_version
        other.head_capacity = self.head_capacity
        other._head_data = self._head_data.copy()
        other._head_ids = self._head_ids.copy()
        other._head_assign = self._head_assign.copy()
        other._head_n = self._head_n
        other.num_buckets = self.num_buckets
        other._part_bucket = self._part_bucket.copy()
        other._id_loc = dict(self._id_loc)
        other._next_id = self._next_id
        other._sum = self._sum.copy()
        other._sumsq = self._sumsq.copy()
        other._n_live = self._n_live
        other._mutations_since_meta = self._mutations_since_meta
        other._dev = None
        other._dev_version = -1
        other._oplog = None  # clones never inherit an active recording
        other._oplog_limit = self._oplog_limit
        return other

    def adopt(self, other: "MutablePDXStore", *, expect_version: int) -> bool:
        """Version-fenced swap: take ``other``'s state iff this store is
        still at ``expect_version`` (i.e. no mutation landed since ``other``
        was cloned from it).  Returns False — and changes nothing — when the
        fence fails; the caller just discards the stale clone and re-clones
        later.  On success the device cache is dropped (the adopted tiles
        re-upload lazily) and both versions bump past every prior value, so
        every version-keyed cache (executors, placements, mirrors)
        invalidates."""
        if self.version != expect_version:
            return False
        for attr in (
            "_data", "_ids", "_counts", "_dim_means", "_dim_vars",
            "_head_data", "_head_ids", "_head_assign", "_head_n",
            "_part_bucket", "_id_loc", "_next_id",
            "_sum", "_sumsq", "_n_live", "_mutations_since_meta",
        ):
            setattr(self, attr, getattr(other, attr))
        self._dev = None
        self._dev_version = -1
        self._bump(tiles=True)
        self._obs_mutation("adopt", self._n_live)
        return True
