"""PDX (Partition Dimensions Across) layout — the paper's core data structure.

A PDX *partition* stores up to ``capacity`` vectors dimension-major as a
``(D, capacity)`` tile, so a dimension slice ``data[d0:d1, :]`` is one
contiguous stretch per dimension (the paper's Figure 1).  Partitions map to
IVF buckets (approximate search) or horizontal slabs (exact search).

On TPU the trailing (vector) axis maps onto the 128-wide lane dimension, which
is why capacities here default to lane multiples; the paper's CPU-optimal
64-vector micro-block becomes a kernel tiling detail (see repro.kernels).

Build-time code is NumPy (offline, like index construction in FAISS); the
resulting arrays are device arrays consumed by jitted search code.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "PDXPartition",
    "PDXStore",
    "build_flat_store",
    "build_bucketed_store",
    "pdx_to_nary",
]

# Sentinel padding value: a coordinate far from any real data so padded slots
# can never enter a top-k result (distances are monotone increasing in L2/L1).
PAD_VALUE = np.float32(3.0e18)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class PDXPartition:
    """One PDX partition: ``data[d, i]`` = dimension ``d`` of vector ``i``."""

    data: jax.Array        # (D, capacity) float
    ids: jax.Array         # (capacity,) int32 original row ids, -1 for padding
    count: int             # number of valid vectors (static, build-time)

    def tree_flatten(self):
        return (self.data, self.ids), (self.count,)

    @classmethod
    def tree_unflatten(cls, aux, children):
        data, ids = children
        return cls(data=data, ids=ids, count=aux[0])

    @property
    def dim(self) -> int:
        return self.data.shape[0]

    @property
    def capacity(self) -> int:
        return self.data.shape[1]


@dataclasses.dataclass
class PDXStore:
    """A collection of equal-capacity PDX partitions, batched into one array.

    ``data``   (P, D, C)  dimension-major tiles
    ``ids``    (P, C)     original row ids (-1 padding)
    ``counts`` (P,)       valid vectors per partition
    ``dim_means`` (D,)    collection-wide per-dimension means (BOND metadata)
    ``dim_vars``  (D,)    per-dimension variances (BSA block metadata)
    """

    data: jax.Array
    ids: jax.Array
    counts: jax.Array
    dim_means: jax.Array
    dim_vars: jax.Array

    @property
    def num_partitions(self) -> int:
        return self.data.shape[0]

    @property
    def dim(self) -> int:
        return self.data.shape[1]

    @property
    def capacity(self) -> int:
        return self.data.shape[2]

    @property
    def num_vectors(self) -> int:
        return int(np.sum(np.asarray(self.counts)))

    def partition(self, p: int) -> PDXPartition:
        return PDXPartition(
            data=self.data[p], ids=self.ids[p], count=int(self.counts[p])
        )


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def _pack_groups(
    X: np.ndarray, groups: Sequence[np.ndarray], capacity: int
) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Pack row-id groups into (P, D, C) dimension-major tiles."""
    n, d = X.shape
    parts_data, parts_ids, parts_counts = [], [], []
    for rows in groups:
        rows = np.asarray(rows, dtype=np.int64)
        for lo in range(0, max(len(rows), 1), capacity):
            chunk = rows[lo : lo + capacity]
            tile = np.full((d, capacity), PAD_VALUE, dtype=X.dtype)
            ids = np.full((capacity,), -1, dtype=np.int32)
            if len(chunk):
                tile[:, : len(chunk)] = X[chunk].T
                ids[: len(chunk)] = chunk
            parts_data.append(tile)
            parts_ids.append(ids)
            parts_counts.append(len(chunk))
    return (
        np.stack(parts_data),
        np.stack(parts_ids),
        np.asarray(parts_counts, dtype=np.int32),
    )


def _store_from_packed(
    X: np.ndarray, data: np.ndarray, ids: np.ndarray, counts: np.ndarray
) -> PDXStore:
    return PDXStore(
        data=jnp.asarray(data),
        ids=jnp.asarray(ids),
        counts=jnp.asarray(counts),
        dim_means=jnp.asarray(X.mean(axis=0)),
        dim_vars=jnp.asarray(X.var(axis=0)),
    )


def build_flat_store(X: np.ndarray, capacity: int = 1024) -> PDXStore:
    """Exact-search store: horizontal slabs of ``capacity`` vectors.

    The paper uses 10K-vector partitions for exact search (Section 6.5); we
    default to a lane-friendly 1024 and let callers pick the paper's value.
    """
    X = np.asarray(X, dtype=np.float32)
    n = X.shape[0]
    groups = [np.arange(lo, min(lo + capacity, n)) for lo in range(0, n, capacity)]
    return _store_from_packed(X, *_pack_groups(X, groups, capacity))


def build_bucketed_store(
    X: np.ndarray, assignments: np.ndarray, num_buckets: int, capacity: int
) -> tuple[PDXStore, np.ndarray, np.ndarray]:
    """IVF-style store: one group per bucket, split into capacity-sized tiles.

    Returns (store, part_offsets, part_counts_per_bucket):
      partitions ``part_offsets[b] : part_offsets[b] + nparts[b]`` belong to
      bucket ``b`` (partitions are laid out bucket-contiguously, mirroring the
      paper's Figure 2 where IVF buckets map onto PDX blocks).
    """
    X = np.asarray(X, dtype=np.float32)
    assignments = np.asarray(assignments)
    groups, nparts = [], np.zeros(num_buckets, dtype=np.int64)
    for b in range(num_buckets):
        rows = np.nonzero(assignments == b)[0]
        groups.append(rows)
        nparts[b] = max(_round_up(len(rows), capacity) // capacity, 1)
    data, ids, counts = _pack_groups(X, groups, capacity)
    offsets = np.concatenate([[0], np.cumsum(nparts)[:-1]])
    return _store_from_packed(X, data, ids, counts), offsets, nparts


def pdx_to_nary(store: PDXStore) -> np.ndarray:
    """Inverse transposition (round-trip oracle for tests)."""
    data = np.asarray(store.data)
    ids = np.asarray(store.ids)
    counts = np.asarray(store.counts)
    n = int(counts.sum())
    out = np.zeros((n, store.dim), dtype=data.dtype)
    for p in range(store.num_partitions):
        c = int(counts[p])
        if c:
            out[ids[p, :c]] = data[p, :, :c].T
    return out
