"""Streaming top-k candidate set — the jit-friendly analogue of the paper's
max-heap.  State is a fixed-size (k,) pair of (distances, ids), merged with
candidate batches via lax.top_k; the running threshold (paper: "current best
k-th exact distance") is ``heap_dists[-1]`` since we keep it sorted ascending.

Also home of ``rerank_positions``, the exact-f32 re-rank every quantized
scan path shares: candidates selected from a reduced-precision mirror are
tracked as flat tile *positions* (``p * C + c``, -1 = pad), their master
columns are gathered, and the final top-k is rebuilt from exact distances
with global ids.  Lives here (not in the executors) because the host fused
executors and both shard_map bodies must agree on the PAD-position
convention bit for bit.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

__all__ = [
    "TopK", "topk_init", "topk_merge", "topk_threshold", "rerank_positions",
]

INF = jnp.float32(jnp.inf)


class TopK(NamedTuple):
    dists: jax.Array  # (k,) ascending
    ids: jax.Array    # (k,) int32, -1 = empty slot


def topk_init(k: int) -> TopK:
    return TopK(dists=jnp.full((k,), INF), ids=jnp.full((k,), -1, jnp.int32))


@jax.jit
def topk_merge(state: TopK, cand_dists: jax.Array, cand_ids: jax.Array) -> TopK:
    """Merge a (m,) candidate batch into the (k,) state. Padded candidates
    must carry dist=+inf (or id=-1 with huge dist) and are never selected."""
    k = state.dists.shape[0]
    # Guard: candidates with id == -1 are padding slots from partial tiles.
    cand_dists = jnp.where(cand_ids < 0, INF, cand_dists)
    all_d = jnp.concatenate([state.dists, cand_dists])
    all_i = jnp.concatenate([state.ids, cand_ids])
    neg_top, idx = jax.lax.top_k(-all_d, k)
    return TopK(dists=-neg_top, ids=all_i[idx])


@functools.partial(jax.jit, static_argnames=("k",))
def topk_from_batch(cand_dists: jax.Array, cand_ids: jax.Array, k: int) -> TopK:
    return topk_merge(topk_init(k), cand_dists, cand_ids)


def topk_threshold(state: TopK) -> jax.Array:
    """Pruning threshold: worst distance currently in the candidate set."""
    return state.dists[-1]


@functools.partial(jax.jit, static_argnames=("k", "metric"))
def rerank_positions(
    master: jax.Array,
    ids: jax.Array,
    Q: jax.Array,
    cand: TopK,
    k: int,
    metric: str = "l2",
) -> TopK:
    """Exact f32 re-rank: ``cand.ids`` are flat tile positions (-1 = pad)
    into the (P, D, C) ``master`` tiles; gather those columns, recompute
    their distances to the (B, D) queries, and keep the best ``k`` as
    global ids from the (P, C) ``ids`` array."""
    from .distance import nary_distance  # topk is imported by distance users

    P, D, C = master.shape
    safe = jnp.maximum(cand.ids, 0)                      # (B, rk) positions
    vecs = master[safe // C, :, safe % C]                # (B, rk, D) f32
    d = jax.vmap(lambda V_, q_: nary_distance(V_, q_, metric))(vecs, Q)
    d = jnp.where(cand.ids >= 0, d, INF)
    gids = jnp.where(cand.ids >= 0, ids.reshape(-1)[safe], -1)
    merge = lambda dd, ii: topk_merge(topk_init(k), dd, ii)  # noqa: E731
    return jax.vmap(merge)(d, gids)
