"""Streaming top-k candidate set — the jit-friendly analogue of the paper's
max-heap.  State is a fixed-size (k,) pair of (distances, ids), merged with
candidate batches via lax.top_k; the running threshold (paper: "current best
k-th exact distance") is ``heap_dists[-1]`` since we keep it sorted ascending.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

__all__ = ["TopK", "topk_init", "topk_merge", "topk_threshold"]

INF = jnp.float32(jnp.inf)


class TopK(NamedTuple):
    dists: jax.Array  # (k,) ascending
    ids: jax.Array    # (k,) int32, -1 = empty slot


def topk_init(k: int) -> TopK:
    return TopK(dists=jnp.full((k,), INF), ids=jnp.full((k,), -1, jnp.int32))


@jax.jit
def topk_merge(state: TopK, cand_dists: jax.Array, cand_ids: jax.Array) -> TopK:
    """Merge a (m,) candidate batch into the (k,) state. Padded candidates
    must carry dist=+inf (or id=-1 with huge dist) and are never selected."""
    k = state.dists.shape[0]
    # Guard: candidates with id == -1 are padding slots from partial tiles.
    cand_dists = jnp.where(cand_ids < 0, INF, cand_dists)
    all_d = jnp.concatenate([state.dists, cand_dists])
    all_i = jnp.concatenate([state.ids, cand_ids])
    neg_top, idx = jax.lax.top_k(-all_d, k)
    return TopK(dists=-neg_top, ids=all_i[idx])


@functools.partial(jax.jit, static_argnames=("k",))
def topk_from_batch(cand_dists: jax.Array, cand_ids: jax.Array, k: int) -> TopK:
    return topk_merge(topk_init(k), cand_dists, cand_ids)


def topk_threshold(state: TopK) -> jax.Array:
    """Pruning threshold: worst distance currently in the candidate set."""
    return state.dists[-1]
